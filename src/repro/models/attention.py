"""Attention primitives: GQA projections, chunked online-softmax attention
(XLA analogue of flash attention — bounded memory for 32k prefill), sliding
window banding, logit softcap, and a position-tagged KV cache that supports
both full-length and ring (windowed) buffers.

The Pallas TPU kernel in ``repro.kernels.swa_attention`` implements the same
math with explicit VMEM tiling; ``repro.kernels.swa_attention.ref`` mirrors
this module and the kernel is asserted allclose against it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, rope, softcap

NEG_INF = -1e30
_CHUNK = 1024  # kv-block size for the online-softmax scan


# ---------------------------------------------------------------------------
# Projections
# ---------------------------------------------------------------------------

def init_attention(key, d_model, n_heads, n_kv_heads, head_dim, dtype,
                   qkv_bias=False):
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d_model, n_heads * head_dim), dtype),
        "wk": dense_init(ks[1], (d_model, n_kv_heads * head_dim), dtype),
        "wv": dense_init(ks[2], (d_model, n_kv_heads * head_dim), dtype),
        "wo": dense_init(ks[3], (n_heads * head_dim, d_model),
                         dtype, fan_in=n_heads * head_dim),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads * head_dim,), dtype)
        p["bk"] = jnp.zeros((n_kv_heads * head_dim,), dtype)
        p["bv"] = jnp.zeros((n_kv_heads * head_dim,), dtype)
    return p


def qkv_proj(p, x, n_heads, n_kv_heads, head_dim):
    B, S, _ = x.shape
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    return (q.reshape(B, S, n_heads, head_dim),
            k.reshape(B, S, n_kv_heads, head_dim),
            v.reshape(B, S, n_kv_heads, head_dim))


def out_proj(p, o):
    B, S = o.shape[:2]
    return o.reshape(B, S, -1) @ p["wo"]


# ---------------------------------------------------------------------------
# Core attention
# ---------------------------------------------------------------------------

def _mask(q_pos, kv_pos, causal, window):
    """(Sq, Skv) boolean validity. kv_pos < 0 marks empty cache slots.

    ``window`` may be None (no banding), a python int, or a traced int32
    scalar (per-layer windows ride through lax.scan); 0 disables banding.
    """
    m = kv_pos[None, :] >= 0
    if causal:
        m &= kv_pos[None, :] <= q_pos[:, None]
    if window is not None:
        w = jnp.asarray(window, jnp.int32)
        w = jnp.where(w > 0, w, jnp.int32(2 ** 30))
        m &= kv_pos[None, :] > q_pos[:, None] - w
    return m


def attend(q, k, v, *, q_pos, kv_pos, causal=True, window=0, cap=0.0):
    """GQA attention with online-softmax over kv chunks.

    q: (B, Sq, nq, hd); k, v: (B, Skv, nkv, hd); q_pos: (Sq,), kv_pos: (Skv,)
    Returns (B, Sq, nq, hd).
    """
    B, Sq, nq, hd = q.shape
    Skv, nkv = k.shape[1], k.shape[2]
    g = nq // nkv
    scale = hd ** -0.5
    qg = q.reshape(B, Sq, nkv, g, hd).astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    if Skv <= _CHUNK:
        s = jnp.einsum("bqkgh,bskh->bkgqs", qg, kf)
        s = softcap(s, cap)
        s = jnp.where(_mask(q_pos, kv_pos, causal, window)[None, None, None], s, NEG_INF)
        m = jnp.max(s, axis=-1, keepdims=True)
        p = jnp.exp(s - jax.lax.stop_gradient(jnp.maximum(m, NEG_INF / 2)))
        l = jnp.sum(p, axis=-1, keepdims=True)
        o = jnp.einsum("bkgqs,bskh->bkgqh", p, vf) / jnp.maximum(l, 1e-30)
        return o.reshape(B, nkv * g, Sq, hd).transpose(0, 2, 1, 3).astype(q.dtype)

    # chunked path: pad Skv to a multiple of _CHUNK with invalid slots
    pad = (-Skv) % _CHUNK
    if pad:
        kf = jnp.pad(kf, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, (0, pad), constant_values=-1)
    n_chunks = kf.shape[1] // _CHUNK
    kc = kf.reshape(B, n_chunks, _CHUNK, nkv, hd).transpose(1, 0, 2, 3, 4)
    vc = vf.reshape(B, n_chunks, _CHUNK, nkv, hd).transpose(1, 0, 2, 3, 4)
    pc = kv_pos.reshape(n_chunks, _CHUNK)

    m0 = jnp.full((B, nkv, g, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, nkv, g, Sq), jnp.float32)
    a0 = jnp.zeros((B, nkv, g, Sq, hd), jnp.float32)

    @jax.checkpoint
    def body(carry, xs):
        m, l, acc = carry
        kch, vch, pch = xs
        s = jnp.einsum("bqkgh,bskh->bkgqs", qg, kch)
        s = softcap(s, cap)
        s = jnp.where(_mask(q_pos, pch, causal, window)[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        # guard: a fully-masked chunk keeps m_new at NEG_INF; clamp so
        # exp(NEG_INF - NEG_INF) does not turn masked scores into 1.0
        p = jnp.exp(s - jnp.maximum(m_new, NEG_INF / 2)[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum("bkgqs,bskh->bkgqh", p, vch)
        return (m_new, l, acc), None

    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kc, vc, pc))
    o = acc / jnp.maximum(l, 1e-30)[..., None]
    return o.reshape(B, nkv * g, Sq, hd).transpose(0, 2, 1, 3).astype(q.dtype)


# ---------------------------------------------------------------------------
# KV cache
# ---------------------------------------------------------------------------

def init_cache(batch, n_kv, buf_len, head_dim, dtype):
    """Position-tagged cache. ``pos`` = -1 marks empty slots; a windowed
    buffer (buf_len == window) becomes a ring buffer transparently."""
    return {
        "k": jnp.zeros((batch, buf_len, n_kv, head_dim), dtype),
        "v": jnp.zeros((batch, buf_len, n_kv, head_dim), dtype),
        "pos": jnp.full((buf_len,), -1, jnp.int32),
    }


def cache_update(cache, k_new, v_new, index):
    """Write k/v for ``k_new.shape[1]`` tokens starting at absolute position
    ``index`` into the (possibly ring) buffer. Returns the updated cache.

    Invariant: position ``p`` always lives in slot ``p % buf`` — single-token
    decode, chunked-prefill streaming, and full prefill all agree on the
    layout, so a chunk write that crosses the ring seam wraps instead of
    clamping, and a later decode step overwrites exactly the slot whose
    position expired."""
    buf = cache["k"].shape[1]
    S = k_new.shape[1]
    if S > buf:
        # ValueError, not assert: serving-facing path, must survive -O
        raise ValueError(
            f"cache_update: {S}-token write exceeds buf_len {buf} — stream "
            f"the prompt in chunks of at most buf_len")
    if S == buf and type(index) is int and index % buf == 0:
        # prefill exactly fills the buffer (slot i == pos index+i mod buf)
        pos = index + jnp.arange(buf, dtype=jnp.int32)
        return {"k": k_new.astype(cache["k"].dtype),
                "v": v_new.astype(cache["v"].dtype), "pos": pos}
    if S == 1:
        slot = jnp.mod(index, buf)
        k = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype),
                                         (0, slot, 0, 0))
        v = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype),
                                         (0, slot, 0, 0))
        pos = jax.lax.dynamic_update_slice(cache["pos"],
                                           jnp.asarray([index], jnp.int32), (slot,))
        return {"k": k, "v": v, "pos": pos}
    # general chunk write: scatter at mod positions (wrap-safe; the S
    # positions are distinct because S <= buf)
    pos = index + jnp.arange(S, dtype=jnp.int32)
    slots = jnp.mod(pos, buf)
    k = cache["k"].at[:, slots].set(k_new.astype(cache["k"].dtype))
    v = cache["v"].at[:, slots].set(v_new.astype(cache["v"].dtype))
    return {"k": k, "v": v, "pos": cache["pos"].at[slots].set(pos)}


__all__ = [
    "attend", "cache_update", "init_attention", "init_cache", "out_proj",
    "qkv_proj", "rope",
]
