"""Unified model API over all assigned architecture families.

``build_model(cfg)`` returns a ``ModelAPI`` with a family-independent
signature used by the trainer, the serving engine, and the dry-run:

    init(key)                                   -> params
    loss(params, batch)                         -> (loss, metrics)
    prefill(params, batch, buf_len, window=0)   -> (last_logits, states)
    decode_step(params, states, token, index, window=0) -> (logits, states)
    make_state(params, batch, buf_len, window=0) -> (blank states, start)
    prefill_chunk(params, states, tokens, index, window=0) -> (logits, states)

``make_state``/``prefill_chunk`` are the streaming/serving lanes: blank
per-request decode state (primed with any non-token context — encoder
frames, vlm prefix — so ``start`` is the first TOKEN position) plus a
multi-token chunk step, so prompts longer than ``buf_len`` stream through
the ring buffer and the serving engine resets a slot by inserting a fresh
``make_state`` pytree (chunk-by-chunk prefill reproduces the one-shot
``prefill``).

``batch`` keys: tokens (B,S), labels (B,S) [loss only], and per family the
stubbed modality inputs: prefix (B,P,D) for vlm/audio decoder-only,
enc (B,F,D) for enc-dec (see DESIGN.md: the frontends are the one sanctioned
stub — input_specs() supplies embeddings of the right shape).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable

from repro.configs.base import ModelConfig
from repro.models import encdec as encdec_lib
from repro.models import transformer as lm


@dataclass(frozen=True)
class ModelAPI:
    cfg: ModelConfig
    init: Callable[..., Any]
    loss: Callable[..., Any]
    prefill: Callable[..., Any]
    decode_step: Callable[..., Any]
    make_state: Callable[..., Any]
    prefill_chunk: Callable[..., Any]


def build_model(cfg: ModelConfig) -> ModelAPI:
    if cfg.n_enc_layers:
        def init(key):
            return encdec_lib.init_encdec(cfg, key)

        def loss(params, batch):
            return encdec_lib.encdec_loss(cfg, params, batch)

        def prefill(params, batch, buf_len, window=0):
            return encdec_lib.encdec_prefill(cfg, params, batch["tokens"],
                                             batch["enc"], buf_len, window)

        def decode_step(params, states, token, index, window=0):
            return encdec_lib.encdec_decode_step(cfg, params, states, token,
                                                 index, window)

        def make_state(params, batch, buf_len, window=0):
            return encdec_lib.encdec_make_state(
                cfg, params, batch["tokens"].shape[0], batch["enc"], buf_len,
                window)

        def prefill_chunk(params, states, tokens, index, window=0):
            return encdec_lib.encdec_prefill_chunk(cfg, params, states,
                                                   tokens, index, window)
    else:
        def init(key):
            return lm.init_lm(cfg, key)

        def loss(params, batch):
            return lm.lm_loss(cfg, params, batch)

        def prefill(params, batch, buf_len, window=0):
            return lm.lm_prefill(cfg, params, batch["tokens"], buf_len,
                                 prefix=batch.get("prefix"),
                                 serve_window=window)

        def decode_step(params, states, token, index, window=0):
            return lm.lm_decode_step(cfg, params, states, token, index,
                                     serve_window=window)

        def make_state(params, batch, buf_len, window=0):
            return lm.lm_make_state(cfg, params, batch["tokens"].shape[0],
                                    buf_len, prefix=batch.get("prefix"),
                                    serve_window=window)

        def prefill_chunk(params, states, tokens, index, window=0):
            return lm.lm_prefill_chunk(cfg, params, states, tokens, index,
                                       serve_window=window)

    return ModelAPI(cfg=cfg, init=init, loss=loss, prefill=prefill,
                    decode_step=decode_step, make_state=make_state,
                    prefill_chunk=prefill_chunk)
