"""Shared primitive layers: norms, RoPE, gated MLPs, inits, softcap."""
from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, fan_in=None):
    """Variance-scaling normal init (fan-in)."""
    fan_in = fan_in if fan_in is not None else shape[-2] if len(shape) >= 2 else shape[-1]
    std = fan_in ** -0.5
    return (jax.random.normal(key, shape) * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x, w, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + w.astype(jnp.float32))).astype(dt)


# ---------------------------------------------------------------------------
# Softcap (gemma2)
# ---------------------------------------------------------------------------

def softcap(x, cap: float):
    if not cap:
        return x
    return jnp.tanh(x / cap) * cap


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope(x, positions, theta: float):
    """Apply rotary embeddings.

    x: (..., S, H, hd); positions: broadcastable to (..., S) int32.
    """
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-jnp.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    ang = ang[..., None, :]  # broadcast over heads: (..., S, 1, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------

def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[name]


def init_mlp(key, d_model, d_ff, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, (d_model, d_ff), dtype),
        "w_up": dense_init(k2, (d_model, d_ff), dtype),
        "w_down": dense_init(k3, (d_ff, d_model), dtype),
    }


def mlp(p, x, act: str):
    g = act_fn(act)(x @ p["w_gate"])
    return (g * (x @ p["w_up"])) @ p["w_down"]
