"""Decoder-only LM assembly, config-driven over the block pattern.

Layers with identical parameter structure are stacked and executed with
``lax.scan`` (per-layer window sizes ride along as a scanned array), so an
80-layer config lowers to a compact HLO. Heterogeneous patterns (zamba2's
mamba+shared-attn, xlstm's mlstm+slstm) are executed as a scan over pattern
*cycles* with the pattern unrolled inside the body; shared blocks close over
a single parameter set but keep per-occurrence KV caches.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models import xlstm as xlstm_lib
from repro.models.layers import embed_init, init_mlp, mlp, rms_norm, softcap


# ---------------------------------------------------------------------------
# Block init / apply
# ---------------------------------------------------------------------------

def init_attn_block(key, cfg, dtype):
    ks = jax.random.split(key, 2)
    d = cfg.d_model
    p = {
        "ln1": jnp.zeros((d,), dtype),
        "attn": attn.init_attention(ks[0], d, cfg.n_heads, cfg.n_kv_heads,
                                    cfg.head_dim, dtype, cfg.qkv_bias),
        "ln2": jnp.zeros((d,), dtype),
    }
    if cfg.d_ff:
        p["mlp"] = init_mlp(ks[1], d, cfg.d_ff, dtype)
    if cfg.post_block_norm:
        p["post1"] = jnp.zeros((d,), dtype)
        p["post2"] = jnp.zeros((d,), dtype)
    return p


def init_moe_block(key, cfg, dtype):
    ks = jax.random.split(key, 2)
    d = cfg.d_model
    return {
        "ln1": jnp.zeros((d,), dtype),
        "attn": attn.init_attention(ks[0], d, cfg.n_heads, cfg.n_kv_heads,
                                    cfg.head_dim, dtype, cfg.qkv_bias),
        "ln2": jnp.zeros((d,), dtype),
        "moe": moe_lib.init_moe(ks[1], cfg, dtype),
    }


def _self_attention(p, h, cfg, window, cache, index):
    """Shared attention plumbing. Returns (attn output, new cache)."""
    B, S, _ = h.shape
    q, k, v = attn.qkv_proj(p, h, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim)
    if cache is None:
        pos = jnp.arange(S, dtype=jnp.int32)
        q = attn.rope(q, pos, cfg.rope_theta)
        k = attn.rope(k, pos, cfg.rope_theta)
        o = attn.attend(q, k, v, q_pos=pos, kv_pos=pos, causal=True,
                        window=window, cap=cfg.attn_logit_softcap)
        return attn.out_proj(p, o), None
    pos = index + jnp.arange(S, dtype=jnp.int32)
    q = attn.rope(q, pos, cfg.rope_theta)
    k = attn.rope(k, pos, cfg.rope_theta)
    cache = attn.cache_update(cache, k, v, index)
    o = attn.attend(q, cache["k"], cache["v"], q_pos=pos, kv_pos=cache["pos"],
                    causal=True, window=window, cap=cfg.attn_logit_softcap)
    return attn.out_proj(p, o), cache


def attn_block(p, x, cfg, window=None, cache=None, index=0):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    o, cache = _self_attention(p["attn"], h, cfg, window, cache, index)
    if "post1" in p:
        o = rms_norm(o, p["post1"], cfg.norm_eps)
    x = x + o
    if "mlp" in p:
        m = mlp(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps), cfg.act)
        if "post2" in p:
            m = rms_norm(m, p["post2"], cfg.norm_eps)
        x = x + m
    return x, cache, jnp.float32(0.0)


def moe_block(p, x, cfg, window=None, cache=None, index=0):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    o, cache = _self_attention(p["attn"], h, cfg, window, cache, index)
    x = x + o
    m, aux = moe_lib.moe_mlp(p["moe"], rms_norm(x, p["ln2"], cfg.norm_eps), cfg)
    return x + m, cache, aux


def _seq_constrain(x, cfg):
    """Sequence-parallel activations (§Perf seqshard plan): pin the residual
    stream's sequence dim to the model axis between blocks, so norms and
    element-wise ops run on S/TP tokens and the TP all-reduces lower to
    reduce-scatter + all-gather pairs. No-op without an ambient model axis."""
    if not cfg.seq_shard_acts or x.ndim != 3:
        return x
    try:
        import warnings
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            from jax.interpreters import pxla
            mesh = pxla.thread_resources.env.physical_mesh
        if mesh.empty or "model" not in mesh.axis_names:
            return x
        if x.shape[1] % mesh.shape["model"]:
            return x
        from jax.sharding import PartitionSpec
        return jax.lax.with_sharding_constraint(
            x, PartitionSpec(None, "model", None))
    except Exception:
        return x


def _apply_block(kind, p, x, cfg, window, state, index):
    """Dispatch. Returns (x, new_state, aux). With cfg.remat the block body
    is rematerialized in the backward pass (activation checkpointing)."""
    x = _seq_constrain(x, cfg)
    if cfg.remat and state is None:
        fn = jax.checkpoint(
            lambda pp, xx, ww: _apply_block_inner(kind, pp, xx, cfg, ww,
                                                  None, index))
        return fn(p, x, window if window is not None else 0)
    return _apply_block_inner(kind, p, x, cfg, window, state, index)


def _apply_block_inner(kind, p, x, cfg, window, state, index):
    if kind in ("attn", "shared_attn"):
        return attn_block(p, x, cfg, window=window, cache=state, index=index)
    if kind == "moe":
        return moe_block(p, x, cfg, window=window, cache=state, index=index)
    if kind == "mamba":
        out, st = ssm_lib.mamba_forward(p, x, cfg, state)
        return x + out, st, jnp.float32(0.0)
    if kind == "mlstm":
        out, st = xlstm_lib.mlstm_forward(p, x, cfg, state)
        return x + out, st, jnp.float32(0.0)
    if kind == "slstm":
        out, st = xlstm_lib.slstm_forward(p, x, cfg, state)
        return x + out, st, jnp.float32(0.0)
    raise ValueError(kind)


_INIT = {
    "attn": init_attn_block,
    "shared_attn": init_attn_block,
    "moe": init_moe_block,
    "mamba": ssm_lib.init_mamba,
    "mlstm": xlstm_lib.init_mlstm,
    "slstm": xlstm_lib.init_slstm,
}


def _block_state(kind, cfg, batch, buf_len, dtype):
    """Fresh decode/prefill state for one block."""
    if kind in ("attn", "shared_attn", "moe"):
        return attn.init_cache(batch, cfg.n_kv_heads, buf_len, cfg.head_dim, dtype)
    if kind == "mamba":
        return ssm_lib.init_mamba_state(cfg, batch, dtype)
    if kind in ("mlstm", "slstm"):
        d_in, H, P = xlstm_lib.dims(cfg)
        if kind == "mlstm":
            return (jnp.zeros((batch, H, P, P), jnp.float32),
                    jnp.zeros((batch, H, P), jnp.float32),
                    jnp.full((batch, H), -1e30, jnp.float32))
        zero = jnp.zeros((batch, H, P), jnp.float32)
        return (zero, zero + 1e-6, zero, zero - 1e30)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Pattern machinery
# ---------------------------------------------------------------------------

def _merged_pattern(cfg):
    """Pattern positions as (kind, window); local_attn folds into attn."""
    out = []
    for k in cfg.layer_pattern:
        if k == "local_attn":
            out.append(("attn", cfg.sliding_window))
        else:
            out.append((k, 0))
    return out


def _layout(cfg):
    """Decide the execution layout.

    uniform: all pattern positions share one structure -> one scan of L.
    cycle:   scan over full pattern cycles + unrolled remainder.
    """
    pat = _merged_pattern(cfg)
    kinds = {k for k, _ in pat}
    if kinds <= {"attn"} or kinds == {"moe"}:
        return "uniform"
    return "cycle"


def _windows(cfg):
    pat = _merged_pattern(cfg)
    return jnp.asarray([pat[i % len(pat)][1] for i in range(cfg.n_layers)],
                       jnp.int32)


def init_blocks(cfg, key, dtype):
    """Returns a pure array pytree; layout metadata is derived from cfg."""
    pat = _merged_pattern(cfg)
    L = cfg.n_layers
    if _layout(cfg) == "uniform":
        kind = pat[0][0]
        keys = jax.random.split(key, L)
        stacked = jax.vmap(lambda k: _INIT[kind](k, cfg, dtype))(keys)
        return {"stack": stacked}
    # cycle layout
    p_len = len(pat)
    n_cycles, rem = divmod(L, p_len)
    params = {}
    keys = iter(jax.random.split(key, (n_cycles + 2) * p_len + 1))
    cyc = {}
    for j, (kind, _) in enumerate(pat):
        if kind == "shared_attn":
            continue  # weights shared, init once below
        ks = jnp.stack([jax.random.fold_in(next(keys), c) for c in range(n_cycles)])
        cyc[f"b{j}"] = jax.vmap(lambda k: _INIT[kind](k, cfg, dtype))(ks)
    params["cycle"] = cyc
    if any(k == "shared_attn" for k, _ in pat):
        params["shared"] = _INIT["shared_attn"](next(keys), cfg, dtype)
    if rem:
        rem_p = {}
        for j in range(rem):
            kind = pat[j][0]
            if kind == "shared_attn":
                continue
            rem_p[f"b{j}"] = _INIT[kind](next(keys), cfg, dtype)
        params["remainder"] = rem_p
    return params


def init_states(cfg, blocks, batch, buf_len, dtype):
    """Fresh stacked states matching ``run_blocks`` expectations."""
    del blocks
    pat = _merged_pattern(cfg)
    if _layout(cfg) == "uniform":
        one = _block_state(pat[0][0], cfg, batch, buf_len, dtype)
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape),
                            one)
    n_cycles, rem = divmod(cfg.n_layers, len(pat))
    st = {"cycle": {}, "remainder": {}}
    for j, (kind, _) in enumerate(pat):
        one = _block_state(kind, cfg, batch, buf_len, dtype)
        st["cycle"][f"b{j}"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_cycles,) + a.shape), one)
    for j in range(rem):
        st["remainder"][f"b{j}"] = _block_state(pat[j][0], cfg, batch, buf_len, dtype)
    return st


def run_blocks(blocks, x, cfg, states=None, index=0, serve_window=0):
    """Execute the block stack. Returns (x, new_states, aux)."""
    pat = _merged_pattern(cfg)

    def eff_window(w):
        if serve_window:
            return jnp.int32(serve_window) if not isinstance(w, int) else serve_window
        return w

    if _layout(cfg) == "uniform":
        kind = pat[0][0]
        windows = _windows(cfg)
        if serve_window:
            windows = jnp.minimum(jnp.where(windows == 0, serve_window, windows),
                                  serve_window)

        def body(carry, xs):
            h, aux = carry
            p, w, st = xs
            h, st, a = _apply_block(kind, p, h, cfg, w, st, index)
            return (h, aux + a), st

        xs = (blocks["stack"], windows, states)
        (x, aux), new_states = jax.lax.scan(body, (x, jnp.float32(0.0)), xs)
        return x, new_states, aux

    # cycle layout ----------------------------------------------------------
    n_cycles, rem = divmod(cfg.n_layers, len(pat))
    shared = blocks.get("shared")
    aux0 = jnp.float32(0.0)

    def cycle_body(carry, xs):
        h, aux = carry
        cyc_params, cyc_states = xs
        new_states = {}
        for j, (kind, w) in enumerate(pat):
            p = shared if kind == "shared_attn" else cyc_params[f"b{j}"]
            st = None if cyc_states is None else cyc_states[f"b{j}"]
            h, st, a = _apply_block(kind, p, h, cfg, eff_window(w), st, index)
            aux = aux + a
            new_states[f"b{j}"] = st
        return (h, aux), (new_states if cyc_states is not None else None)

    cyc_states = None if states is None else states["cycle"]
    xs = (blocks["cycle"], cyc_states)
    (x, aux), new_cyc = jax.lax.scan(cycle_body, (x, aux0), xs)

    new_rem = {}
    for j in range(rem):
        kind, w = pat[j]
        p = shared if kind == "shared_attn" else blocks["remainder"][f"b{j}"]
        st = None if states is None else states["remainder"].get(f"b{j}")
        x, st, a = _apply_block(kind, p, x, cfg, eff_window(w), st, index)
        aux = aux + a
        new_rem[f"b{j}"] = st
    new_states = None if states is None else {"cycle": new_cyc, "remainder": new_rem}
    return x, new_states, aux


# ---------------------------------------------------------------------------
# Full LM
# ---------------------------------------------------------------------------

def init_lm(cfg, key):
    dtype = jnp.dtype(cfg.dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "embed": embed_init(k1, (cfg.vocab_size, cfg.d_model), dtype),
        "blocks": init_blocks(cfg, k2, dtype),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = embed_init(k3, (cfg.d_model, cfg.vocab_size), dtype)
    return p


def _embed(params, cfg, tokens, prefix=None):
    x = jnp.take(params["embed"], tokens, axis=0)
    x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    if prefix is not None:
        x = jnp.concatenate([prefix.astype(x.dtype), x], axis=1)
    return x


def _head(params, cfg, x):
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = x @ params["embed"].T
    else:
        logits = x @ params["lm_head"]
    return softcap(logits.astype(jnp.float32), cfg.final_logit_softcap)


def lm_logits(cfg, params, tokens, prefix=None):
    """Teacher-forced logits over the token positions only."""
    x = _embed(params, cfg, tokens, prefix)
    x, _, aux = run_blocks(params["blocks"], x, cfg)
    if prefix is not None:
        x = x[:, prefix.shape[1]:]
    return _head(params, cfg, x), aux


def cross_entropy(logits, labels):
    """labels < 0 are masked out."""
    valid = labels >= 0
    lse = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, jnp.maximum(labels, 0)[..., None],
                                 axis=-1)[..., 0]
    nll = jnp.where(valid, lse - picked, 0.0)
    return nll.sum() / jnp.maximum(valid.sum(), 1)


def lm_loss(cfg, params, batch):
    logits, aux = lm_logits(cfg, params, batch["tokens"],
                            batch.get("prefix"))
    loss = cross_entropy(logits, batch["labels"])
    total = loss + cfg.router_aux_coef * aux
    return total, {"loss": loss, "aux": aux}


def lm_prefill(cfg, params, tokens, buf_len, prefix=None, serve_window=0):
    """Run the prompt through the stack, filling caches.
    Returns (last-token logits, states)."""
    x = _embed(params, cfg, tokens, prefix)
    B = x.shape[0]
    dtype = jnp.dtype(cfg.dtype)
    states = init_states(cfg, params["blocks"], B, buf_len, dtype)
    x, states, _ = run_blocks(params["blocks"], x, cfg, states=states, index=0,
                              serve_window=serve_window)
    return _head(params, cfg, x[:, -1:])[:, 0], states


def lm_make_state(cfg, params, batch_size, buf_len, prefix=None,
                  serve_window=0):
    """Blank decode states for ``batch_size`` sequences plus the stream
    start index (serving slot-reset / chunked-prefill entry point).

    Without a prefix this is just ``init_states`` and start 0. With a
    prefix (vlm/audio decoder-only) the prefix embeddings are run through
    the stack first — they occupy absolute positions ``0..P-1`` — and the
    returned start index is ``P``, so the caller streams raw TOKENS only
    (chunked prefill never needs to re-split the modality stub)."""
    dtype = jnp.dtype(cfg.dtype)
    states = init_states(cfg, params["blocks"], batch_size, buf_len, dtype)
    if prefix is None:
        return states, 0
    x = prefix.astype(dtype)
    _, states, _ = run_blocks(params["blocks"], x, cfg, states=states,
                              index=0, serve_window=serve_window)
    return states, prefix.shape[1]


def lm_prefill_chunk(cfg, params, states, tokens, index, serve_window=0):
    """Run ``tokens`` (B, C) through the stack at absolute positions
    ``index..index+C-1``, updating the (possibly ring) caches / recurrent
    states in place. Returns (last-token logits (B, V), new states) —
    exactly ``lm_prefill`` restricted to one stream chunk, so feeding a
    prompt chunk-by-chunk reproduces the one-shot prefill."""
    x = _embed(params, cfg, tokens)
    x, states, _ = run_blocks(params["blocks"], x, cfg, states=states,
                              index=index, serve_window=serve_window)
    return _head(params, cfg, x[:, -1:])[:, 0], states


def lm_decode_step(cfg, params, states, token, index, serve_window=0):
    """One decode step. token: (B, 1) int32; index: scalar int32 absolute
    position. Returns (logits (B, V), new states)."""
    x = _embed(params, cfg, token)
    x, states, _ = run_blocks(params["blocks"], x, cfg, states=states,
                              index=index, serve_window=serve_window)
    return _head(params, cfg, x)[:, 0], states
