"""Mixture-of-Experts MLP: top-k capacity routing with dispatch/combine
einsums (Switch/Mesh-TF style — the GSPMD-friendly formulation: the expert
dimension shards over the "model" axis and XLA inserts the all-to-all).

Supports llama4-scout (16e top-1 + shared expert) and dbrx (16e top-4).
Aux load-balance loss follows Switch Transformer: E * sum(importance * load).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import act_fn, dense_init, init_mlp, mlp


def _constrain(x, spec_axes):
    """Pin the routing tensors' expert dim to the ambient mesh's model axis
    (if one is active) so GSPMD keeps them expert-sharded instead of
    all-reducing the full (T, E, C) tensor across the TP group — found to be
    the dominant collective in the train_4k dry-run (§Perf iteration 2).
    No-op on meshes without a 'model' axis (CPU tests)."""
    try:
        import warnings
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            from jax.interpreters import pxla
            mesh = pxla.thread_resources.env.physical_mesh
        if mesh.empty or "model" not in mesh.axis_names:
            return x
        from jax.sharding import PartitionSpec
        return jax.lax.with_sharding_constraint(x, PartitionSpec(*spec_axes))
    except Exception:
        return x


def init_moe(key, cfg, dtype):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, e), jnp.float32),  # router in fp32
        "w_gate": dense_init(ks[1], (e, d, f), dtype, fan_in=d),
        "w_up": dense_init(ks[2], (e, d, f), dtype, fan_in=d),
        "w_down": dense_init(ks[3], (e, f, d), dtype, fan_in=f),
    }
    if cfg.shared_expert:
        p["shared"] = init_mlp(ks[4], d, f, dtype)
    return p


def _capacity(tokens_per_group: int, cfg) -> int:
    c = int(tokens_per_group * cfg.top_k / cfg.n_experts * cfg.capacity_factor)
    return max(c, cfg.top_k)


def moe_mlp(p, x, cfg):
    """x: (B, S, D) -> (out (B, S, D), aux_loss scalar).

    Tokens are grouped per batch row (decode: one group over the batch) so
    the dispatch tensor stays (Tg, E, C)-sized.
    """
    B, S, D = x.shape
    if S == 1:  # decode: group over batch
        xg = x.reshape(1, B, D)
    else:
        xg = x
    G, Tg, _ = xg.shape
    E, K = cfg.n_experts, cfg.top_k
    C = _capacity(Tg, cfg)

    logits = (xg.astype(jnp.float32) @ p["router"])            # (G, Tg, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(probs, K)                        # (G, Tg, K)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    oh_e = jax.nn.one_hot(ids, E, dtype=jnp.float32)            # (G, Tg, K, E)
    # position of each (token, k) entry within its expert queue, token-major
    flat = oh_e.reshape(G, Tg * K, E)
    pos = jnp.cumsum(flat, axis=1) - flat                       # (G, Tg*K, E)
    pos_own = jnp.sum(pos * flat, axis=-1).reshape(G, Tg, K).astype(jnp.int32)
    keep = (pos_own < C).astype(jnp.float32)
    oh_c = jax.nn.one_hot(pos_own, C, dtype=jnp.float32)        # (G, Tg, K, C)

    combine = jnp.einsum("gtke,gtkc->gtec",
                         oh_e * (gates * keep)[..., None], oh_c)  # (G, Tg, E, C)
    combine = _constrain(combine, (None, None, "model", None))
    dispatch = (combine > 0).astype(xg.dtype)

    ein = jnp.einsum("gtec,gtd->gecd", dispatch, xg)            # (G, E, C, D)
    ein = _constrain(ein, (None, "model", None, None))
    h = jnp.einsum("gecd,edf->gecf", ein, p["w_gate"])
    u = jnp.einsum("gecd,edf->gecf", ein, p["w_up"])
    h = act_fn(cfg.act)(h) * u
    eout = jnp.einsum("gecf,efd->gecd", h, p["w_down"])         # (G, E, C, D)
    eout = _constrain(eout, (None, "model", None, None))
    # combine contraction dtype: bf16 halves the dispatch/combine collective
    # payload on the expert-parallel axis (§Perf); accumulate in fp32.
    cdt = jnp.dtype(cfg.moe_combine_dtype)
    out = jnp.einsum("gecd,gtec->gtd", eout.astype(cdt), combine.astype(cdt),
                     preferred_element_type=jnp.float32).astype(x.dtype)

    # Switch aux loss: E * sum_e importance_e * load_e
    importance = probs.mean(axis=(0, 1))                        # (E,)
    load = oh_e[:, :, 0, :].mean(axis=(0, 1))                   # first-choice
    aux = E * jnp.sum(importance * load)

    out = out.reshape(B, S, D)
    if cfg.shared_expert:
        out = out + mlp(p["shared"], x, cfg.act)
    return out, aux
