"""Mamba2 (SSD) block — chunked selective-state-space scan.

Per head h, scalar decay a_t = exp(-exp(A_log_h) * dt_t):
    H_t = a_t * H_{t-1} + (dt_t * x_t) outer B_t          (H: (P, N))
    y_t = H_t @ C_t + D_h * x_t
Train/prefill uses the chunked SSD formulation (intra-chunk dense matmuls on
the MXU + inter-chunk scan over states); decode carries (H, conv) state.
The Pallas kernel in ``repro.kernels.mamba_scan`` implements the intra-chunk
part with VMEM tiling and is validated against ``_ssd_reference`` here.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, rms_norm

NEG_INF = -1e30


def dims(cfg):
    d_in = cfg.ssm_expand * cfg.d_model
    heads = cfg.ssm_heads or d_in // 64
    head_p = d_in // heads
    return d_in, heads, head_p


def init_mamba(key, cfg, dtype):
    d = cfg.d_model
    d_in, H, P = dims(cfg)
    N = cfg.ssm_state
    conv_dim = d_in + 2 * N
    ks = jax.random.split(key, 4)
    return {
        "ln": jnp.zeros((d,), dtype),
        "in_proj": dense_init(ks[0], (d, 2 * d_in + 2 * N + H), dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, conv_dim)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 8.0, H)).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm": jnp.zeros((d_in,), dtype),
        "out_proj": dense_init(ks[2], (d_in, d), dtype),
    }


def _split(p, u, cfg):
    """in_proj -> z (gate), xBC (conv stream), dt."""
    d_in, H, _ = dims(cfg)
    N = cfg.ssm_state
    zxbcdt = u @ p["in_proj"]
    z = zxbcdt[..., :d_in]
    xBC = zxbcdt[..., d_in:2 * d_in + 2 * N]
    dt = zxbcdt[..., 2 * d_in + 2 * N:]
    return z, xBC, dt


def _causal_conv(xBC, w, b, conv_state=None):
    """Depthwise causal conv over time. xBC: (B, S, Cd); w: (K, Cd)."""
    K = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros(xBC.shape[:1] + (K - 1,) + xBC.shape[2:], xBC.dtype)
    else:
        pad = conv_state
    xp = jnp.concatenate([pad, xBC], axis=1)            # (B, S+K-1, Cd)
    out = sum(xp[:, i:i + xBC.shape[1]] * w[i] for i in range(K)) + b
    new_state = xp[:, -(K - 1):] if K > 1 else pad[:, :0]
    return jax.nn.silu(out), new_state


def _ssd_chunked(xh, B_, C_, a_log, chunk, h0=None):
    """Chunked SSD scan.

    xh: (Bt, S, H, P) inputs already scaled by dt; B_, C_: (Bt, S, N);
    a_log: (Bt, S, H) per-step log decay (<= 0). ``h0`` (Bt, H, P, N) is
    the carried-in state for streamed (chunked) prefill — the inter-chunk
    recursion starts from it exactly as if the earlier tokens had been in
    this call. Returns y: (Bt, S, H, P) and final state (Bt, H, P, N).
    """
    Bt, S, H, P = xh.shape
    N = B_.shape[-1]
    pad = (-S) % chunk
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0)))
        C_ = jnp.pad(C_, ((0, 0), (0, pad), (0, 0)))
        a_log = jnp.pad(a_log, ((0, 0), (0, pad), (0, 0)))
    Sp = xh.shape[1]
    nc = Sp // chunk
    xh = xh.reshape(Bt, nc, chunk, H, P)
    B_ = B_.reshape(Bt, nc, chunk, N)
    C_ = C_.reshape(Bt, nc, chunk, N)
    a_log = a_log.reshape(Bt, nc, chunk, H)

    la = jnp.cumsum(a_log, axis=2)                      # (Bt, nc, L, H)
    # intra-chunk: y[t] = sum_{s<=t} exp(la_t - la_s) (C_t.B_s) xh[s]
    seg = la[:, :, :, None, :] - la[:, :, None, :, :]   # (Bt, nc, t, s, H)
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    # mask BEFORE exp: masked (s > t) entries have seg > 0 and would
    # overflow, poisoning gradients through the where.
    seg = jnp.where(causal[None, None, :, :, None], seg, NEG_INF)
    decay = jnp.exp(seg)
    G = jnp.einsum("bctn,bcsn->bcts", C_, B_)           # (Bt, nc, t, s)
    y_intra = jnp.einsum("bcts,bctsh,bcshp->bcthp", G, decay, xh)

    # chunk states: states_c = sum_s exp(la_end - la_s) B_s (x) xh_s
    rem = jnp.exp(la[:, :, -1:, :] - la)                # (Bt, nc, L, H)
    states = jnp.einsum("bcsn,bcsh,bcshp->bchpn", B_, rem, xh)
    chunk_decay = jnp.exp(la[:, :, -1, :])              # (Bt, nc, H)

    def body(h_prev, xs):
        st, dc, C_c, la_c = xs
        # inter-chunk contribution: y[t] += exp(la_t) C_t . h_prev
        y_int = jnp.einsum("btn,bhpn,bth->bthp", C_c, h_prev, jnp.exp(la_c))
        h_new = dc[:, :, None, None] * h_prev + st
        return h_new, y_int

    if h0 is None:
        h0 = jnp.zeros((Bt, H, P, N), jnp.float32)
    xs = (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2),
          C_.transpose(1, 0, 2, 3), la.transpose(1, 0, 2, 3))
    h_final, y_inter = jax.lax.scan(body, h0, xs)
    y = y_intra + y_inter.transpose(1, 0, 2, 3, 4)
    y = y.reshape(Bt, Sp, H, P)[:, :S]
    return y, h_final


def mamba_forward(p, x, cfg, state=None):
    """x: (B, S, D). state: None (train/prefill from scratch) or
    {"ssm": (B,H,P,N), "conv": (B,K-1,Cd)} for decode.
    Returns (out (B,S,D), new_state)."""
    d_in, H, P = dims(cfg)
    N = cfg.ssm_state
    u = rms_norm(x, p["ln"], cfg.norm_eps)
    z, xBC, dt_raw = _split(p, u, cfg)
    conv_in = None if state is None else state["conv"]
    xBC, conv_state = _causal_conv(xBC, p["conv_w"], p["conv_b"], conv_in)
    xs = xBC[..., :d_in]
    B_ = xBC[..., d_in:d_in + N].astype(jnp.float32)
    C_ = xBC[..., d_in + N:].astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    a_log = -jnp.exp(p["A_log"]) * dt                                 # (B,S,H)

    Bt, S, _ = x.shape
    xh = xs.reshape(Bt, S, H, P).astype(jnp.float32)
    xh_dt = xh * dt[..., None]

    if S == 1 and state is not None:
        h_prev = state["ssm"]
        a = jnp.exp(a_log[:, 0])                        # (B, H)
        h_new = (a[:, :, None, None] * h_prev
                 + jnp.einsum("bhp,bn->bhpn", xh_dt[:, 0], B_[:, 0]))
        y = jnp.einsum("bhpn,bn->bhp", h_new, C_[:, 0])[:, None]
        ssm_state = h_new
    else:
        h0 = None if state is None else state["ssm"]
        y, ssm_state = _ssd_chunked(xh_dt, B_, C_, a_log, cfg.ssm_chunk,
                                    h0=h0)

    y = y + p["D"][None, None, :, None] * xh
    y = y.reshape(Bt, S, d_in).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = y @ p["out_proj"]
    new_state = {"ssm": ssm_state, "conv": conv_state}
    return out, new_state


def init_mamba_state(cfg, batch, dtype):
    d_in, H, P = dims(cfg)
    N = cfg.ssm_state
    conv_dim = d_in + 2 * N
    return {
        "ssm": jnp.zeros((batch, H, P, N), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
    }
