"""Encoder-decoder backbone (seamless-m4t): encoder over stubbed frame
embeddings, decoder with self- + cross-attention. Layers are stacked and
scanned like the decoder-only path."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models.layers import embed_init, init_mlp, mlp, rms_norm
from repro.models.transformer import (
    _embed, _head, cross_entropy, init_attn_block,
)


def _init_dec_block(key, cfg, dtype):
    ks = jax.random.split(key, 3)
    d = cfg.d_model
    return {
        "ln1": jnp.zeros((d,), dtype),
        "attn": attn.init_attention(ks[0], d, cfg.n_heads, cfg.n_kv_heads,
                                    cfg.head_dim, dtype, cfg.qkv_bias),
        "lnx": jnp.zeros((d,), dtype),
        "xattn": attn.init_attention(ks[1], d, cfg.n_heads, cfg.n_kv_heads,
                                     cfg.head_dim, dtype, cfg.qkv_bias),
        "ln2": jnp.zeros((d,), dtype),
        "mlp": init_mlp(ks[2], d, cfg.d_ff, dtype),
    }


def init_encdec(cfg, key):
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 5)
    enc_keys = jax.random.split(ks[0], cfg.n_enc_layers)
    dec_keys = jax.random.split(ks[1], cfg.n_layers)
    return {
        "embed": embed_init(ks[2], (cfg.vocab_size, cfg.d_model), dtype),
        "enc": jax.vmap(lambda k: init_attn_block(k, cfg, dtype))(enc_keys),
        "enc_norm": jnp.zeros((cfg.d_model,), dtype),
        "dec": jax.vmap(lambda k: _init_dec_block(k, cfg, dtype))(dec_keys),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
        "lm_head": embed_init(ks[3], (cfg.d_model, cfg.vocab_size), dtype),
    }


def encode(cfg, params, enc_in):
    """enc_in: stubbed frame embeddings (B, F, D) from the audio frontend."""
    x = enc_in.astype(jnp.dtype(cfg.dtype))
    F = x.shape[1]
    pos = jnp.arange(F, dtype=jnp.int32)

    def body(h, p):
        u = rms_norm(h, p["ln1"], cfg.norm_eps)
        q, k, v = attn.qkv_proj(p["attn"], u, cfg.n_heads, cfg.n_kv_heads,
                                cfg.head_dim)
        q = attn.rope(q, pos, cfg.rope_theta)
        k = attn.rope(k, pos, cfg.rope_theta)
        o = attn.attend(q, k, v, q_pos=pos, kv_pos=pos, causal=False)
        h = h + attn.out_proj(p["attn"], o)
        h = h + mlp(p["mlp"], rms_norm(h, p["ln2"], cfg.norm_eps), cfg.act)
        return h, None

    x, _ = jax.lax.scan(body, x, params["enc"])
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _dec_block(p, x, cfg, cross_k, cross_v, cache, index, window=0):
    B, S, _ = x.shape
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    q, k, v = attn.qkv_proj(p["attn"], h, cfg.n_heads, cfg.n_kv_heads,
                            cfg.head_dim)
    pos = (index + jnp.arange(S, dtype=jnp.int32) if cache is not None
           else jnp.arange(S, dtype=jnp.int32))
    q = attn.rope(q, pos, cfg.rope_theta)
    k = attn.rope(k, pos, cfg.rope_theta)
    if cache is None:
        o = attn.attend(q, k, v, q_pos=pos, kv_pos=pos, causal=True)
    else:
        cache = attn.cache_update(cache, k, v, index)
        o = attn.attend(q, cache["k"], cache["v"], q_pos=pos,
                        kv_pos=cache["pos"], causal=True, window=window)
    x = x + attn.out_proj(p["attn"], o)

    hx = rms_norm(x, p["lnx"], cfg.norm_eps)
    qx = (hx @ p["xattn"]["wq"]).reshape(B, S, cfg.n_heads, cfg.head_dim)
    F = cross_k.shape[1]
    fpos = jnp.arange(F, dtype=jnp.int32)
    ox = attn.attend(qx, cross_k, cross_v, q_pos=pos, kv_pos=fpos, causal=False)
    x = x + attn.out_proj(p["xattn"], ox)

    x = x + mlp(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps), cfg.act)
    return x, cache


def _cross_kv(p, enc_out, cfg):
    B, F, _ = enc_out.shape
    k = (enc_out @ p["xattn"]["wk"]).reshape(B, F, cfg.n_kv_heads, cfg.head_dim)
    v = (enc_out @ p["xattn"]["wv"]).reshape(B, F, cfg.n_kv_heads, cfg.head_dim)
    return k, v


def decode_stack(cfg, params, x, enc_out=None, states=None, index=0,
                 window=0):
    """Run the decoder stack. states: None (train) or
    {"self": stacked cache, "ck": (L,B,F,nkv,hd), "cv": ...}. ``window``
    bands the cached self-attention (serving ring buffer); cross-attention
    always sees every encoder frame."""
    if states is None:
        def body(h, p):
            ck, cv = _cross_kv(p, enc_out, cfg)
            h, _ = _dec_block(p, h, cfg, ck, cv, None, 0)
            return h, None
        x, _ = jax.lax.scan(body, x, params["dec"])
        return x, None

    def body(h, xs):
        p, cache, ck, cv = xs
        h, cache = _dec_block(p, h, cfg, ck, cv, cache, index, window=window)
        return h, cache

    x, self_cache = jax.lax.scan(
        body, x, (params["dec"], states["self"], states["ck"], states["cv"]))
    return x, {"self": self_cache, "ck": states["ck"], "cv": states["cv"]}


def encdec_loss(cfg, params, batch):
    enc_out = encode(cfg, params, batch["enc"])
    x = _embed(params, cfg, batch["tokens"])
    x, _ = decode_stack(cfg, params, x, enc_out=enc_out)
    logits = _head(params, cfg, x)
    loss = cross_entropy(logits, batch["labels"])
    return loss, {"loss": loss, "aux": jnp.float32(0.0)}


def encdec_make_state(cfg, params, batch_size, enc_in, buf_len,
                      serve_window=0):
    """Blank decoder states primed with the request's encoder pass: the
    cross k/v lanes are computed ONCE here and ride in the state pytree
    (serving slot insertion carries them per slot). Returns
    (states, start index 0)."""
    del serve_window
    dtype = jnp.dtype(cfg.dtype)
    enc_out = encode(cfg, params, enc_in)
    L = cfg.n_layers
    one = attn.init_cache(batch_size, cfg.n_kv_heads, buf_len, cfg.head_dim,
                          dtype)
    self_cache = jax.tree.map(lambda a: jnp.broadcast_to(a, (L,) + a.shape),
                              one)
    ck, cv = jax.vmap(lambda p: _cross_kv(p, enc_out, cfg))(params["dec"])
    return {"self": self_cache, "ck": ck, "cv": cv}, 0


def encdec_prefill_chunk(cfg, params, states, tokens, index, serve_window=0):
    """One stream chunk of decoder prefill (see ``lm_prefill_chunk``)."""
    x = _embed(params, cfg, tokens)
    x, states = decode_stack(cfg, params, x, states=states, index=index,
                             window=serve_window)
    return _head(params, cfg, x[:, -1:])[:, 0], states


def encdec_prefill(cfg, params, tokens, enc_in, buf_len, serve_window=0):
    states, _ = encdec_make_state(cfg, params, tokens.shape[0], enc_in,
                                  buf_len)
    x = _embed(params, cfg, tokens)
    x, states = decode_stack(cfg, params, x, states=states, index=0,
                             window=serve_window)
    return _head(params, cfg, x[:, -1:])[:, 0], states


def encdec_decode_step(cfg, params, states, token, index, serve_window=0):
    x = _embed(params, cfg, token)
    x, states = decode_stack(cfg, params, x, states=states, index=index,
                             window=serve_window)
    return _head(params, cfg, x)[:, 0], states
