"""xLSTM blocks: mLSTM (matrix memory, parallelizable) and sLSTM (scalar
memory with recurrent gate connections), following arXiv:2405.04517 with the
standard exponential-gating stabilizer. d_ff = 0 in the config: each block
carries its own up/down projection (expand factor ``ssm_expand``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, rms_norm


def dims(cfg):
    d_in = cfg.ssm_expand * cfg.d_model
    H = cfg.ssm_heads or cfg.n_heads
    return d_in, H, d_in // H


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def init_mlstm(key, cfg, dtype):
    d = cfg.d_model
    d_in, H, P = dims(cfg)
    ks = jax.random.split(key, 7)
    return {
        "ln": jnp.zeros((d,), dtype),
        "w_up": dense_init(ks[0], (d, 2 * d_in), dtype),
        "wq": dense_init(ks[1], (d_in, d_in), dtype),
        "wk": dense_init(ks[2], (d_in, d_in), dtype),
        "wv": dense_init(ks[3], (d_in, d_in), dtype),
        "w_i": dense_init(ks[4], (d_in, H), jnp.float32),
        "b_i": jnp.zeros((H,), jnp.float32),
        "w_f": dense_init(ks[5], (d_in, H), jnp.float32),
        "b_f": jnp.ones((H,), jnp.float32) * 3.0,  # forget-gate bias init
        "norm": jnp.zeros((d_in,), dtype),
        "w_down": dense_init(ks[6], (d_in, d), dtype),
    }


def _mlstm_step(carry, xs, P):
    C, n, m = carry                              # (B,H,P,P), (B,H,P), (B,H)
    q, k, v, i_raw, f_raw = xs                   # (B,H,P) x3, (B,H) x2
    m_new = jnp.maximum(f_raw + m, i_raw)
    i = jnp.exp(i_raw - m_new)
    f = jnp.exp(f_raw + m - m_new)
    C = f[..., None, None] * C + i[..., None, None] * (k[..., :, None] * v[..., None, :])
    n = f[..., None] * n + i[..., None] * k
    num = jnp.einsum("bhpq,bhp->bhq", C, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhp,bhp->bh", n, q)), 1.0)
    h = num / den[..., None]
    return (C, n, m_new), h


def _mlstm_chunked(q, k, v, i_raw, f_raw, state, chunk):
    """Chunkwise-parallel mLSTM — EXACT stabilized equivalent of the
    per-step recurrence (same log-gate algebra incl. the running max m),
    but processes L timesteps per scan step with dense (L,L)/(L,P) matmuls.
    Beyond-paper perf optimization (EXPERIMENTS.md §Perf): scan carry
    traffic drops by the chunk factor and the contractions hit the MXU.

    q,k,v: (B, S, H, P) fp32; i_raw, f_raw: (B, S, H). Returns (h, state).
    """
    B, S, H, P = q.shape
    L = min(chunk, S)
    pad = (-S) % L
    if pad:
        zp = lambda a: jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))
        q, k, v = zp(q), zp(k), zp(v)
        # padded steps: i = -inf (no write), f = 0 (identity decay)
        i_raw = jnp.pad(i_raw, ((0, 0), (0, pad), (0, 0)),
                        constant_values=-1e30)
        f_raw = jnp.pad(f_raw, ((0, 0), (0, pad), (0, 0)))
    Sp = q.shape[1]
    nc = Sp // L
    ch = lambda a: a.reshape((B, nc, L) + a.shape[2:]).transpose(
        (1, 0) + tuple(range(2, a.ndim + 1)))
    qc, kc, vc = ch(q), ch(k), ch(v)                 # (nc, B, L, H, P)
    ic, fc = ch(i_raw), ch(f_raw)                    # (nc, B, L, H)

    causal = jnp.tril(jnp.ones((L, L), bool))

    def body(carry, xs):
        C0, n0, m0 = carry                           # (B,H,P,P),(B,H,P),(B,H)
        qq, kk, vv, ii, ff = xs
        F = jnp.cumsum(ff, axis=1)                   # (B, L, H)
        a = ii - F                                   # i_log_s - F_s
        m_intra = F + jax.lax.cummax(a, axis=1)      # (B, L, H)
        m_prev = m0[:, None] + F                     # (B, L, H)
        m = jnp.maximum(m_intra, m_prev)
        # intra-chunk weights w[t,s] = exp(i_s + F_t - F_s - m_t)
        logw = (ii - F)[:, None, :, :] + F[:, :, None, :] - m[:, :, None, :]
        logw = jnp.where(causal[None, :, :, None], logw, -1e30)
        w = jnp.exp(logw)                            # (B, t, s, H)
        scores = jnp.einsum("bthp,bshp->btsh", qq, kk)
        sw = scores * w
        num = jnp.einsum("btsh,bshp->bthp", sw, vv)
        den = jnp.sum(sw * 1.0, axis=2)              # sum_s w * (q.k) -> (B,t,H)
        carry_scale = jnp.exp(m_prev - m)            # (B, L, H)
        num = num + carry_scale[..., None] * jnp.einsum(
            "bhpq,bthp->bthq", C0, qq)
        den = den + carry_scale * jnp.einsum("bhp,bthp->bth", n0, qq)
        h = num / jnp.maximum(jnp.abs(den), 1.0)[..., None]

        # end-of-chunk state at stabilizer m_L
        mL = m[:, -1]                                # (B, H)
        FL = F[:, -1]                                # (B, H)
        decay0 = jnp.exp(m0 + FL - mL)               # (B, H)
        sscale = jnp.exp(ii + FL[:, None] - F - mL[:, None])   # (B, L, H)
        C_new = (decay0[:, :, None, None] * C0
                 + jnp.einsum("blh,blhp,blhq->bhpq", sscale, kk, vv))
        n_new = (decay0[:, :, None] * n0
                 + jnp.einsum("blh,blhp->bhp", sscale, kk))
        return (C_new, n_new, mL), h

    state, hs = jax.lax.scan(body, state, (qc, kc, vc, ic, fc))
    h = hs.transpose(1, 0, 2, 3, 4).reshape(B, Sp, H, P)[:, :S]
    return h, state


def mlstm_forward(p, x, cfg, state=None):
    """x: (B, S, D) -> (out, state). state: (C, n, m)."""
    d_in, H, P = dims(cfg)
    B, S, _ = x.shape
    u = rms_norm(x, p["ln"], cfg.norm_eps)
    up = u @ p["w_up"]
    xi, z = up[..., :d_in], up[..., d_in:]
    q = (xi @ p["wq"]).reshape(B, S, H, P).astype(jnp.float32) * P ** -0.5
    k = (xi @ p["wk"]).reshape(B, S, H, P).astype(jnp.float32) * P ** -0.5
    v = (xi @ p["wv"]).reshape(B, S, H, P).astype(jnp.float32)
    i_raw = xi.astype(jnp.float32) @ p["w_i"] + p["b_i"]   # (B,S,H)
    f_raw = xi.astype(jnp.float32) @ p["w_f"] + p["b_f"]

    if state is None:
        state = (jnp.zeros((B, H, P, P), jnp.float32),
                 jnp.zeros((B, H, P), jnp.float32),
                 jnp.full((B, H), -1e30, jnp.float32))

    if cfg.xlstm_chunk and S > 1:
        h, state = _mlstm_chunked(q, k, v, i_raw, f_raw, state,
                                  cfg.xlstm_chunk)
        h = h.reshape(B, S, d_in).astype(x.dtype)
    else:
        xs = tuple(a.transpose(1, 0, 2, 3) for a in (q, k, v)) + tuple(
            a.transpose(1, 0, 2) for a in (i_raw, f_raw))
        state, hs = jax.lax.scan(lambda c, s: _mlstm_step(c, s, P), state, xs)
        h = hs.transpose(1, 0, 2, 3).reshape(B, S, d_in).astype(x.dtype)
    h = rms_norm(h * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    return h @ p["w_down"], state


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def init_slstm(key, cfg, dtype):
    d = cfg.d_model
    d_in, H, P = dims(cfg)
    ks = jax.random.split(key, 7)
    return {
        "ln": jnp.zeros((d,), dtype),
        "w_up": dense_init(ks[0], (d, 2 * d_in), dtype),
        "w_gates": dense_init(ks[1], (d_in, 4 * d_in), dtype),  # z,i,f,o
        "r_gates": (jax.random.normal(ks[2], (H, P, 4 * P)) * P ** -0.5
                    ).astype(jnp.float32),                      # block-diag recurrent
        "b_gates": jnp.concatenate([
            jnp.zeros((2 * d_in,)), jnp.ones((d_in,)) * 3.0, jnp.zeros((d_in,))
        ]).astype(jnp.float32),
        "norm": jnp.zeros((d_in,), dtype),
        "w_down": dense_init(ks[3], (d_in, d), dtype),
    }


def slstm_forward(p, x, cfg, state=None):
    """x: (B, S, D) -> (out, state). state: (c, n, h, m) each (B, H, P)."""
    d_in, H, P = dims(cfg)
    B, S, _ = x.shape
    u = rms_norm(x, p["ln"], cfg.norm_eps)
    up = u @ p["w_up"]
    xi, zgate = up[..., :d_in], up[..., d_in:]
    g_in = (xi.astype(jnp.float32) @ p["w_gates"].astype(jnp.float32)
            + p["b_gates"])                                     # (B,S,4*d_in)

    if state is None:
        zero = jnp.zeros((B, H, P), jnp.float32)
        state = (zero, zero + 1e-6, zero, zero - 1e30)

    def step(carry, g_t):
        c, n, h, m = carry
        rec = jnp.einsum("bhp,hpq->bhq", h, p["r_gates"])       # (B,H,4P)
        g = g_t.reshape(B, H, 4 * P) + rec
        z_r, i_r, f_r, o_r = jnp.split(g, 4, axis=-1)           # (B,H,P)
        m_new = jnp.maximum(f_r + m, i_r)
        i = jnp.exp(i_r - m_new)
        f = jnp.exp(f_r + m - m_new)
        c = f * c + i * jnp.tanh(z_r)
        n = f * n + i
        h_new = jax.nn.sigmoid(o_r) * c / jnp.maximum(n, 1e-6)
        return (c, n, h_new, m_new), h_new

    state, hs = jax.lax.scan(step, state, g_in.transpose(1, 0, 2))
    h = hs.transpose(1, 0, 2, 3).reshape(B, S, d_in).astype(x.dtype)
    h = rms_norm(h * jax.nn.silu(zgate), p["norm"], cfg.norm_eps)
    return h @ p["w_down"], state
