"""Pallas TPU kernel for the fused DPPF pull-push consensus update.

DPPF's consensus is memory-bound: it touches every parameter of every
worker once for the distance and once for the update. The TPU-native
formulation (DESIGN.md §5):

  phase 1 (sq_dist): grid over row blocks of the (rows, 128) padded view;
    each step accumulates a partial sum-of-squares into an SMEM scalar
    accumulator — one HBM read of x and a.
  phase 2 (apply): one fused read-modify-write pass computing
    x + (a - x) * coef with the scalar coef prefetched.

Block shape (BLOCK_ROWS, 128) keeps the working set in VMEM and the lane
dimension hardware-aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
BLOCK_ROWS = 256  # 256*128*4B*2 tensors = 256 KiB of VMEM per step


def _sq_dist_kernel(x_ref, a_ref, o_ref):
    # the (1,) output block maps to the same slot every grid step, so it
    # acts as the cross-step accumulator (standard revisiting pattern).
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[0] = jnp.float32(0.0)

    d = x_ref[...].astype(jnp.float32) - a_ref[...].astype(jnp.float32)
    o_ref[0] += jnp.sum(d * d)


def _apply_kernel(coef_ref, x_ref, a_ref, o_ref):
    xf = x_ref[...].astype(jnp.float32)
    af = a_ref[...].astype(jnp.float32)
    o_ref[...] = (xf + (af - xf) * coef_ref[0]).astype(o_ref.dtype)


def _pad_view(x):
    n = x.shape[0]
    rows = -(-n // LANE)
    pad = rows * LANE - n
    xp = jnp.pad(x, (0, pad)) if pad else x
    return xp.reshape(rows, LANE), n


@functools.partial(jax.jit, static_argnames=("interpret",))
def sq_dist(x, a, *, interpret=True):
    """||x - a||^2 via the blockwise reduction kernel. x, a: (n,)."""
    xv, _ = _pad_view(x)
    av, _ = _pad_view(a)
    rows = xv.shape[0]
    grid = -(-rows // BLOCK_ROWS)
    if rows % BLOCK_ROWS:
        pad_r = grid * BLOCK_ROWS - rows
        xv = jnp.pad(xv, ((0, pad_r), (0, 0)))
        av = jnp.pad(av, ((0, pad_r), (0, 0)))
    out = pl.pallas_call(
        _sq_dist_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, LANE), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_ROWS, LANE), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((1,), jnp.float32),
        interpret=interpret,
    )(xv, av)
    return out[0]


@functools.partial(jax.jit, static_argnames=("interpret",))
def apply_update(x, a, coef, *, interpret=True):
    """out = x + (a - x) * coef in one fused pass. x, a: (n,)."""
    xv, n = _pad_view(x)
    av, _ = _pad_view(a)
    rows = xv.shape[0]
    grid = -(-rows // BLOCK_ROWS)
    if rows % BLOCK_ROWS:
        pad_r = grid * BLOCK_ROWS - rows
        xv = jnp.pad(xv, ((0, pad_r), (0, 0)))
        av = jnp.pad(av, ((0, pad_r), (0, 0)))
    coef = jnp.asarray(coef, jnp.float32).reshape(1)
    out = pl.pallas_call(
        _apply_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((BLOCK_ROWS, LANE), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_ROWS, LANE), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xv.shape, x.dtype),
        interpret=interpret,
    )(coef, xv, av)
    return out.reshape(-1)[:n]
