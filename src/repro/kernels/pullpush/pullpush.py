"""Pallas TPU kernels for the DPPF consensus hot path.

DPPF's consensus is memory-bound: it touches every parameter of every
worker once for the distance and once for the update. Two generations of
kernels live here (DESIGN.md §Consensus-engine):

* ``sq_dist`` / ``apply_update`` — the original per-vector pair: a blockwise
  sum-of-squares reduction and a separate fused read-modify-write pass.
  Kept as the minimal reference kernels (and for their tests).

* ``fused_round`` — the ConsensusEngine kernel: ONE ``pallas_call`` whose
  grid runs two phases over the same column blocks of the flat ``(R, n)``
  worker matrix. Phase 0 accumulates a block-centered Gram matrix (distances
  for *all* rows in one read); phase 1 derives the per-row pull/push
  coefficients from the Gram in-kernel and applies the row-mixing update in
  one read-modify-write pass. This replaces the per-worker
  ``sq_dist`` + ``apply_update`` pair and their duplicated padding logic.

Block shape (rows, LANE)/(rows, block_cols) keeps the working set in VMEM
and the lane dimension hardware-aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANE = 128
BLOCK_ROWS = 256  # 256*128*4B*2 tensors = 256 KiB of VMEM per step
SUBLANE = 8       # fp32 sublane quantum: row counts are padded to this


def _round_up(x, m):
    return -(-x // m) * m


# ---------------------------------------------------------------------------
# Shared padding helpers (used by every kernel below)
# ---------------------------------------------------------------------------

def _pad_view(x):
    """(n,) -> lane-aligned (rows, LANE) view. Returns (view, n)."""
    n = x.shape[0]
    rows = _round_up(n, LANE) // LANE
    pad = rows * LANE - n
    xp = jnp.pad(x, (0, pad)) if pad else x
    return xp.reshape(rows, LANE), n


def _pad_grid(views, block_rows=BLOCK_ROWS):
    """Pad (rows, LANE) views to a whole number of row blocks.

    Returns (padded_views, grid) — the single source of the grid/padding
    arithmetic that used to be copied between ``sq_dist`` and
    ``apply_update``.
    """
    rows = views[0].shape[0]
    grid = _round_up(rows, block_rows) // block_rows
    pad_r = grid * block_rows - rows
    if pad_r:
        views = [jnp.pad(v, ((0, pad_r), (0, 0))) for v in views]
    return views, grid


# ---------------------------------------------------------------------------
# Reference pair: separate distance + apply kernels
# ---------------------------------------------------------------------------

def _sq_dist_kernel(x_ref, a_ref, o_ref):
    # the (1,) output block maps to the same slot every grid step, so it
    # acts as the cross-step accumulator (standard revisiting pattern).
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[0] = jnp.float32(0.0)

    d = x_ref[...].astype(jnp.float32) - a_ref[...].astype(jnp.float32)
    o_ref[0] += jnp.sum(d * d)


def _apply_kernel(coef_ref, x_ref, a_ref, o_ref):
    xf = x_ref[...].astype(jnp.float32)
    af = a_ref[...].astype(jnp.float32)
    o_ref[...] = (xf + (af - xf) * coef_ref[0]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def sq_dist(x, a, *, interpret=True):
    """||x - a||^2 via the blockwise reduction kernel. x, a: (n,)."""
    xv, _ = _pad_view(x)
    av, _ = _pad_view(a)
    (xv, av), grid = _pad_grid([xv, av])
    out = pl.pallas_call(
        _sq_dist_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, LANE), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_ROWS, LANE), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((1,), jnp.float32),
        interpret=interpret,
    )(xv, av)
    return out[0]


@functools.partial(jax.jit, static_argnames=("interpret",))
def apply_update(x, a, coef, *, interpret=True):
    """out = x + (a - x) * coef in one fused pass. x, a: (n,)."""
    xv, n = _pad_view(x)
    av, _ = _pad_view(a)
    (xv, av), grid = _pad_grid([xv, av])
    coef = jnp.asarray(coef, jnp.float32).reshape(1)
    out = pl.pallas_call(
        _apply_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((BLOCK_ROWS, LANE), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_ROWS, LANE), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xv.shape, x.dtype),
        interpret=interpret,
    )(coef, xv, av)
    return out.reshape(-1)[:n]


# ---------------------------------------------------------------------------
# ConsensusEngine kernel: one pallas_call, two phases over one grid
# ---------------------------------------------------------------------------

def _eye(n, dtype=jnp.float32):
    """2D-iota identity (TPU requires >=2D iota inside kernels)."""
    r = jax.lax.broadcasted_iota(jnp.int32, (n, n), 0)
    c = jax.lax.broadcasted_iota(jnp.int32, (n, n), 1)
    return (r == c).astype(dtype)


def _fused_round_kernel(x_ref, t_ref, c0_ref, c1_ref,
                        o_ref, r_ref, g_ref, g_acc, coef_scr, *, eps):
    phase = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when((phase == 0) & (j == 0))
    def _init():
        g_acc[...] = jnp.zeros_like(g_acc)

    @pl.when(phase == 0)
    def _gram():
        x = x_ref[...]
        # Block-centered Gram: shifting every column by its row-0 value is
        # free (loaded block is in VMEM) and removes the catastrophic
        # cancellation of an uncentered x @ x.T — entries are O(spread^2),
        # not O(||x||^2). Any zero-sum quadratic form of G is exact.
        e = x - x[0:1, :]
        g_acc[...] += jnp.dot(e, e.T, preferred_element_type=jnp.float32)
        o_ref[...] = x  # placeholder; phase 1 overwrites every block

    @pl.when((phase == 1) & (j == 0))
    def _coef():
        G = g_acc[...]
        T = t_ref[...]
        R = G.shape[0]
        eye = _eye(R)
        # r^2_i = (e_i - T_i)^T G (e_i - T_i), vectorized over rows.
        tg = jnp.dot(T, G, preferred_element_type=jnp.float32)
        diag_g = jnp.sum(G * eye, axis=1, keepdims=True)
        diag_tg = jnp.sum(T * G, axis=1, keepdims=True)       # G symmetric
        diag_tgt = jnp.sum(tg * T, axis=1, keepdims=True)
        r2 = diag_g - 2.0 * diag_tg + diag_tgt
        r = jnp.sqrt(jnp.maximum(r2, 0.0))
        coef_scr[...] = c0_ref[...] + c1_ref[...] / jnp.maximum(r, eps)
        r_ref[...] = r
        g_ref[...] = G

    @pl.when(phase == 1)
    def _apply():
        # uniform gap form tx + (1-c)(x - tx): the row-stochastic dot
        # accumulates O(||x||) terms (no |c| amplification), c = 1
        # reproduces the target bitwise (hard pull), and a huge |c| scales
        # a difference of nearby values — exact in every regime, unlike a
        # single W @ x GEMM whose rounding grows with |c| * ||x||
        x = x_ref[...]
        c = coef_scr[...]
        tx = jnp.dot(t_ref[...], x, preferred_element_type=jnp.float32)
        o_ref[...] = tx + (1.0 - c) * (x - tx)


@functools.partial(jax.jit,
                   static_argnames=("eps", "block_cols", "interpret"))
def fused_round(flat, T, c0, c1, *, eps=1e-12, block_cols=2048,
                interpret=True):
    """One consensus stage over the flat (R, n) worker matrix, fused.

    Per row i: ``r_i = ||x_i - T_i @ x||``, ``coef_i = c0_i + c1_i /
    max(r_i, eps)``, ``out_i = x_i + coef_i * (T_i @ x - x_i)`` — i.e. one
    row-mixing ``W @ x`` with ``W = I + diag(coef) (T - I)``. ``T`` must be
    row-stochastic (rows sum to 1); that makes every distance a zero-sum
    quadratic form of the Gram, which the block-centering computes exactly.

    Single ``pallas_call``, grid (2, n_blocks): phase 0 accumulates the
    Gram (one HBM read of x), phase 1 applies the mixing (one more read +
    the only write). Returns ``(out (R, n) f32, r (R,), G (R, R))`` — G is
    the *block-centered* Gram: only zero-sum quadratic forms of it are
    meaningful (see repro/core/engine.py).
    """
    R, n = flat.shape
    Rp = _round_up(max(R, SUBLANE), SUBLANE)
    bc = min(block_cols, _round_up(n, LANE))
    nb = _round_up(n, bc) // bc
    # pad rows: identity target + zero coefs => rows (and G forms) inert
    xp, tp = _pad_flat(flat, Rp, bc, nb), _pad_target(T, Rp)
    c0p = jnp.zeros((Rp, 1), jnp.float32).at[:R, 0].set(
        jnp.broadcast_to(jnp.asarray(c0, jnp.float32), (R,)))
    c1p = jnp.zeros((Rp, 1), jnp.float32).at[:R, 0].set(
        jnp.broadcast_to(jnp.asarray(c1, jnp.float32), (R,)))

    out, r, G = pl.pallas_call(
        functools.partial(_fused_round_kernel, eps=eps),
        grid=(2, nb),
        in_specs=[
            pl.BlockSpec((Rp, bc), lambda p, j: (0, j)),
            pl.BlockSpec((Rp, Rp), lambda p, j: (0, 0)),
            pl.BlockSpec((Rp, 1), lambda p, j: (0, 0)),
            pl.BlockSpec((Rp, 1), lambda p, j: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((Rp, bc), lambda p, j: (0, j)),
            pl.BlockSpec((Rp, 1), lambda p, j: (0, 0)),
            pl.BlockSpec((Rp, Rp), lambda p, j: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Rp, nb * bc), jnp.float32),
            jax.ShapeDtypeStruct((Rp, 1), jnp.float32),
            jax.ShapeDtypeStruct((Rp, Rp), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((Rp, Rp), jnp.float32),   # Gram accumulator
            pltpu.VMEM((Rp, 1), jnp.float32),    # per-row coefficients
        ],
        interpret=interpret,
    )(xp, tp, c0p, c1p)
    return out[:R, :n], r[:R, 0], G[:R, :R]


# ---------------------------------------------------------------------------
# Sharded variant: split phases with a host-side psum epilogue
# ---------------------------------------------------------------------------
#
# Under shard_map each device holds a COLUMN shard (R, n_local) of the flat
# view, so the two phases of ``fused_round`` cannot live in one pallas_call:
# the Gram must be completed across shards before any coefficient exists.
# ``partial_gram`` and ``mix_shard`` are the two phases as standalone
# kernels; ``fused_round_sharded`` chains them around a trace-level
# ``lax.psum`` (the "host-side" epilogue — it lowers to the mesh collective,
# not to kernel code). Block-centering still applies per column block, and
# partial Grams ADD across shards: each block's centering shift is a rank-2
# perturbation that cancels in every zero-sum quadratic form, which is the
# only way the Gram is ever read.


def _partial_gram_kernel(x_ref, g_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        g_ref[...] = jnp.zeros_like(g_ref)

    x = x_ref[...]
    e = x - x[0:1, :]                      # block-centered (see fused_round)
    g_ref[...] += jnp.dot(e, e.T, preferred_element_type=jnp.float32)


def _mix_kernel(c_ref, x_ref, t_ref, o_ref):
    x = x_ref[...]
    tx = jnp.dot(t_ref[...], x, preferred_element_type=jnp.float32)
    o_ref[...] = tx + (1.0 - c_ref[...]) * (x - tx)


def _pad_flat(flat, Rp, bc, nb):
    """(R, n) -> zero-padded (Rp, nb*bc) fp32 — the one copy of the flat
    matrix padding, shared by ``fused_round`` and both phase kernels."""
    R, n = flat.shape
    return jnp.pad(flat.astype(jnp.float32), ((0, Rp - R), (0, nb * bc - n)))


def _pad_target(T, Rp):
    """(R, R) -> (Rp, Rp) with IDENTITY pad rows, so padding stays inert in
    both the Gram forms and the mixing (shared by the same callers)."""
    R = T.shape[0]
    tp = jnp.zeros((Rp, Rp), jnp.float32).at[:R, :R].set(
        T.astype(jnp.float32))
    return tp + jnp.diag((jnp.arange(Rp) >= R).astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("block_cols", "interpret"))
def partial_gram(flat, *, block_cols=2048, interpret=True):
    """Block-centered Gram of a (R, n_local) column shard — phase 0 of
    ``fused_round`` as its own kernel. Zero-sum quadratic forms of the
    summed per-shard outputs equal those of the full-width Gram."""
    R, n = flat.shape
    Rp = _round_up(max(R, SUBLANE), SUBLANE)
    bc = min(block_cols, _round_up(n, LANE))
    nb = _round_up(n, bc) // bc
    xp = _pad_flat(flat, Rp, bc, nb)
    G = pl.pallas_call(
        _partial_gram_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((Rp, bc), lambda j: (0, j))],
        out_specs=pl.BlockSpec((Rp, Rp), lambda j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((Rp, Rp), jnp.float32),
        interpret=interpret,
    )(xp)
    return G[:R, :R]


@functools.partial(jax.jit, static_argnames=("block_cols", "interpret"))
def mix_shard(flat, T, coef, *, block_cols=2048, interpret=True):
    """Apply ``out_i = x_i + coef_i (T_i x - x_i)`` to a (R, n_local)
    column shard with PRECOMPUTED coefficients — phase 1 of ``fused_round``
    (same uniform gap form, exact at c = 1 and for huge |c|)."""
    R, n = flat.shape
    Rp = _round_up(max(R, SUBLANE), SUBLANE)
    bc = min(block_cols, _round_up(n, LANE))
    nb = _round_up(n, bc) // bc
    xp, tp = _pad_flat(flat, Rp, bc, nb), _pad_target(T, Rp)
    cp = jnp.zeros((Rp, 1), jnp.float32).at[:R, 0].set(
        jnp.broadcast_to(jnp.asarray(coef, jnp.float32), (R,)))
    out = pl.pallas_call(
        _mix_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((Rp, 1), lambda j: (0, 0)),
            pl.BlockSpec((Rp, bc), lambda j: (0, j)),
            pl.BlockSpec((Rp, Rp), lambda j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((Rp, bc), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((Rp, nb * bc), jnp.float32),
        interpret=interpret,
    )(cp, xp, tp)
    return out[:R, :n]


def mix_from_gram(flat, T, c0, c1, G, *, eps=1e-12, block_cols=2048,
                  interpret=True):
    """Gather-free mixing epilogue: one consensus stage whose column
    contraction ALREADY happened — ``G`` is a completed (block-centered or
    plain) Gram, e.g. the psum'd sum of per-chunk ``partial_gram`` calls
    the double-buffered overlap dispatches mid-scan (one emission per
    column chunk; chunk boundaries only re-anchor the block centering,
    which cancels in every zero-sum form). Derives ``r``/``coef`` at trace
    level from ``G`` and applies the ``mix_shard`` kernel — the only work
    left at the round boundary. Returns ``(out, r, G)`` like
    ``fused_round``.
    """
    R = flat.shape[0]
    V = jnp.eye(R, dtype=jnp.float32) - T.astype(jnp.float32)
    r = jnp.sqrt(jnp.maximum(jnp.sum((V @ G) * V, axis=1), 0.0))
    coef = (jnp.broadcast_to(jnp.asarray(c0, jnp.float32), (R,))
            + jnp.asarray(c1, jnp.float32) / jnp.maximum(r, eps))
    out = mix_shard(flat, T, coef, block_cols=block_cols,
                    interpret=interpret)
    return out, r, G


def fused_round_sharded(flat, T, c0, c1, *, axis, eps=1e-12,
                        block_cols=2048, interpret=True):
    """``fused_round`` for a column shard under shard_map.

    ``flat`` is the local (R, n_local) shard; ``axis`` names the mesh
    axis/axes the columns are sharded over. Runs the partial-Gram kernel,
    completes the Gram with ``lax.psum(G, axis)`` (the round's only
    engine-level collective — (R, R) bytes), derives r/coef at trace level,
    and applies the mixing kernel shard-locally. Returns ``(out, r, G)``
    with the same meaning as ``fused_round`` (G is the global
    block-centered Gram: zero-sum forms only). Must be called inside a
    ``shard_map`` that binds ``axis``.
    """
    R = flat.shape[0]
    G = partial_gram(flat, block_cols=block_cols, interpret=interpret)
    G = jax.lax.psum(G, axis)
    V = jnp.eye(R, dtype=jnp.float32) - T.astype(jnp.float32)
    r = jnp.sqrt(jnp.maximum(jnp.sum((V @ G) * V, axis=1), 0.0))
    coef = (jnp.broadcast_to(jnp.asarray(c0, jnp.float32), (R,))
            + jnp.asarray(c1, jnp.float32) / jnp.maximum(r, eps))
    out = mix_shard(flat, T, coef, block_cols=block_cols,
                    interpret=interpret)
    return out, r, G
