"""Pure-jnp oracles for the DPPF consensus kernels.

Semantics (paper Eq. 5, per worker, flat parameter vector):
    r    = ||x - a||_2
    coef = alpha - lam / max(r, eps)
    out  = x + (a - x) * coef
The naive jnp version issues >= 4 HBM passes over x (sub, square-reduce,
then read x and a again for the update); the Pallas kernels fuse the work
into one or two passes (see pullpush.py).
"""
from __future__ import annotations

import jax.numpy as jnp


def sq_dist_ref(x, a):
    """Sum of squared differences, fp32 accumulation. x, a: (n,)."""
    d = x.astype(jnp.float32) - a.astype(jnp.float32)
    return jnp.sum(d * d)


def apply_ref(x, a, coef):
    """out = x + (a - x) * coef (coef scalar, fp32 math, cast back)."""
    xf = x.astype(jnp.float32)
    af = a.astype(jnp.float32)
    return (xf + (af - xf) * coef).astype(x.dtype)


def pullpush_ref(x, a, alpha, lam, eps=1e-12):
    r = jnp.sqrt(sq_dist_ref(x, a))
    coef = alpha - lam / jnp.maximum(r, eps)
    return apply_ref(x, a, coef), r


def fused_round_ref(flat, T, c0, c1, eps=1e-12):
    """Oracle for ``pullpush.fused_round`` (without the centered-Gram trick).

    flat (R, n); T (R, R) row-stochastic; c0, c1 scalars or (R,).
    Returns (out, r) — r_i = ||x_i - T_i @ x||.
    """
    f = flat.astype(jnp.float32)
    targets = T.astype(jnp.float32) @ f
    r = jnp.sqrt(jnp.sum(jnp.square(f - targets), axis=1))
    coef = (jnp.broadcast_to(jnp.asarray(c0, jnp.float32), r.shape)
            + jnp.asarray(c1, jnp.float32) / jnp.maximum(r, eps))
    # same uniform gap form as the kernel: exact at c = 1 and for huge |c|
    out = targets + (1.0 - coef)[:, None] * (f - targets)
    return out, r
