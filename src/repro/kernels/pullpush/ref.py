"""Pure-jnp oracle for the fused DPPF pull-push consensus kernel.

Semantics (paper Eq. 5, per worker, flat parameter vector):
    r    = ||x - a||_2
    coef = alpha - lam / max(r, eps)
    out  = x + (a - x) * coef
The naive jnp version issues >= 4 HBM passes over x (sub, square-reduce,
then read x and a again for the update); the Pallas kernel fuses each phase
into a single pass (see pullpush.py).
"""
from __future__ import annotations

import jax.numpy as jnp


def sq_dist_ref(x, a):
    """Sum of squared differences, fp32 accumulation. x, a: (n,)."""
    d = x.astype(jnp.float32) - a.astype(jnp.float32)
    return jnp.sum(d * d)


def apply_ref(x, a, coef):
    """out = x + (a - x) * coef (coef scalar, fp32 math, cast back)."""
    xf = x.astype(jnp.float32)
    af = a.astype(jnp.float32)
    return (xf + (af - xf) * coef).astype(x.dtype)


def pullpush_ref(x, a, alpha, lam, eps=1e-12):
    r = jnp.sqrt(sq_dist_ref(x, a))
    coef = alpha - lam / jnp.maximum(r, eps)
    return apply_ref(x, a, coef), r
