"""jit'd public wrapper: fused DPPF consensus over worker-stacked pytrees.

``pullpush_kernel(stacked, alpha, lam)`` mirrors
``repro.core.pullpush.pullpush`` but routes the flat per-worker math through
the Pallas kernels (interpret=True on CPU; compiled on TPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.pullpush import pullpush as k
from repro.kernels.pullpush import ref


def _flatten_workers(stacked):
    """(M, n) flat view + unflatten closure."""
    leaves, treedef = jax.tree_util.tree_flatten(stacked)
    M = leaves[0].shape[0]
    flat = jnp.concatenate(
        [l.reshape(M, -1).astype(jnp.float32) for l in leaves], axis=1)

    def unflatten(flat_new):
        out, i = [], 0
        for l in leaves:
            n = l[0].size
            out.append(flat_new[:, i:i + n].reshape(l.shape).astype(l.dtype))
            i += n
        return jax.tree_util.tree_unflatten(treedef, out)

    return flat, unflatten


@functools.partial(jax.jit, static_argnames=("interpret", "use_kernel"))
def pullpush_fused(stacked, alpha, lam, eps=1e-12, *, interpret=True,
                   use_kernel=True):
    """Eq. 5 over a worker-stacked pytree via the Pallas kernels.
    Returns (new_stacked, per-worker distances)."""
    flat, unflatten = _flatten_workers(stacked)
    a = jnp.mean(flat, axis=0)  # consensus all-reduce

    if use_kernel:
        sq = jax.vmap(lambda x: k.sq_dist(x, a, interpret=interpret))(flat)
    else:
        sq = jax.vmap(lambda x: ref.sq_dist_ref(x, a))(flat)
    r = jnp.sqrt(sq)
    coef = alpha - lam / jnp.maximum(r, eps)

    if use_kernel:
        new = jax.vmap(lambda x, c: k.apply_update(x, a, c,
                                                   interpret=interpret))(flat, coef)
    else:
        new = jax.vmap(lambda x, c: ref.apply_ref(x, a, c))(flat, coef)
    return unflatten(new), r
