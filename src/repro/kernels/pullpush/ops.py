"""jit'd public wrapper: fused DPPF consensus over worker-stacked pytrees.

``pullpush_fused(stacked, alpha, lam)`` mirrors
``repro.core.pullpush.pullpush`` but routes the math through the flat
ConsensusEngine (one ``fused_round`` Pallas call, or the Gram+GEMM jnp
path with ``use_kernel=False``).

This is the convenience entry point for a one-off call on a pytree — it
flattens per call. The training hot path does NOT go through here: the
trainer holds the engine's persistent flat view and calls
``consensus.apply_round(..., engine=...)`` directly, so the flatten happens
once per run (DESIGN.md §Consensus-engine).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.engine import ConsensusEngine


@functools.partial(jax.jit,
                   static_argnames=("eps", "interpret", "use_kernel"))
def pullpush_fused(stacked, alpha, lam, *, eps=1e-12, interpret=True,
                   use_kernel=True):
    """Eq. 5 over a worker-stacked pytree via the consensus engine.
    Returns (new_stacked, per-worker distances).

    The jnp branch uses the engine's exact gap-space stages (this wrapper
    flattens per call anyway, so the fast path's persistent-buffer economy
    doesn't apply — keep plain Eq. 5 semantics at every scale)."""
    engine = ConsensusEngine.from_stacked(
        stacked, use_kernel=use_kernel, interpret=interpret, eps=eps,
        precise=True)
    flat = engine.flatten(stacked)
    M = engine.layout.M
    T = jnp.broadcast_to(engine.uniform, (M, M))
    alpha = jnp.broadcast_to(jnp.asarray(alpha, jnp.float32), (M,))
    lam = jnp.broadcast_to(jnp.asarray(lam, jnp.float32), (M,))
    new, r, _, _ = engine.stage(flat, T, alpha, -lam)
    return engine.unflatten(new), r
