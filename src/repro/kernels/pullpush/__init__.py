from repro.kernels.pullpush.ops import pullpush_fused
from repro.kernels.pullpush.pullpush import apply_update, sq_dist
from repro.kernels.pullpush.ref import apply_ref, pullpush_ref, sq_dist_ref

__all__ = ["apply_ref", "apply_update", "pullpush_fused", "pullpush_ref",
           "sq_dist", "sq_dist_ref"]
