from repro.kernels.pullpush.ops import pullpush_fused
from repro.kernels.pullpush.pullpush import (
    apply_update, fused_round, fused_round_sharded, mix_from_gram, mix_shard,
    partial_gram, sq_dist,
)
from repro.kernels.pullpush.ref import (
    apply_ref, fused_round_ref, pullpush_ref, sq_dist_ref,
)

__all__ = ["apply_ref", "apply_update", "fused_round", "fused_round_ref",
           "fused_round_sharded", "mix_from_gram", "mix_shard",
           "partial_gram", "pullpush_fused", "pullpush_ref", "sq_dist",
           "sq_dist_ref"]
