"""Pure-jnp oracle for the Mamba2 SSD intra-chunk kernel.

Per (batch, head, chunk) with chunk length L, state dim N, head dim P:
  la          = cumsum(a_log) within the chunk                  (L,)
  y_intra[t]  = sum_{s<=t} exp(la_t - la_s) * (C_t . B_s) * x_s (L, P)
  state       = sum_s exp(la_L - la_s) * B_s (x) x_s            (P, N)
(the inter-chunk recurrence over states is cheap and stays in jnp).
"""
from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30


def chunk_ref(x, B_, C_, a_log):
    """x: (L, P); B_, C_: (L, N); a_log: (L,) -> (y (L, P), state (P, N))."""
    L = x.shape[0]
    la = jnp.cumsum(a_log)
    seg = la[:, None] - la[None, :]                 # (t, s)
    causal = jnp.tril(jnp.ones((L, L), bool))
    seg = jnp.where(causal, seg, NEG_INF)
    decay = jnp.exp(seg)
    G = C_ @ B_.T                                   # (t, s)
    y = (G * decay) @ x                             # (L, P)
    rem = jnp.exp(la[-1] - la)                      # (L,)
    state = (B_ * rem[:, None]).T @ x               # (N, P) -> transpose
    return y, state.T


def ssd_chunks_ref(x, B_, C_, a_log):
    """Batched oracle. x: (B, H, nc, L, P); B_, C_: (B, nc, L, N);
    a_log: (B, H, nc, L). Returns (y like x, states (B, H, nc, P, N))."""
    import jax
    def per_bh(xh, al, Bb, Cb):
        def per_chunk(xc, ac, bc, cc):
            return chunk_ref(xc, bc, cc, ac)
        return jax.vmap(per_chunk)(xh, al, Bb, Cb)
    def per_b(xb, ab, Bb, Cb):
        return jax.vmap(lambda xh, ah: per_bh(xh, ah, Bb, Cb))(xb, ab)
    return jax.vmap(per_b)(x, a_log, B_, C_)
