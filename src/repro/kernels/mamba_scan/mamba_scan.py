"""Pallas TPU kernel for the Mamba2 SSD intra-chunk computation.

Grid (B, H, nc): each step processes one (batch, head, chunk) tile entirely
in VMEM — x (L, P), B/C (L, N), a_log (L,) — and emits the intra-chunk
output (L, P) plus the chunk state (P, N). Both contractions are dense
(L x L) @ (L x P) and (N x L) @ (L x P) matmuls on the MXU; with the
default L = 128, N = 64..128, P = 64..128 the working set is < 1 MiB.

The sequential inter-chunk state recurrence (a length-nc scan over tiny
(P, N) states) stays in jnp — it is latency-, not bandwidth-, bound and
does not benefit from a kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(x_ref, b_ref, c_ref, a_ref, y_ref, st_ref, *, L):
    x = x_ref[0, 0, 0].astype(jnp.float32)          # (L, P)
    B_ = b_ref[0, 0].astype(jnp.float32)            # (L, N)
    C_ = c_ref[0, 0].astype(jnp.float32)            # (L, N)
    a = a_ref[0, 0, 0].astype(jnp.float32)          # (L,)

    la = jnp.cumsum(a)
    seg = la[:, None] - la[None, :]
    causal = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    seg = jnp.where(causal, seg, NEG_INF)
    decay = jnp.exp(seg)
    G = jax.lax.dot_general(C_, B_, (((1,), (1,)), ((), ())))   # (L, L)
    y = jax.lax.dot_general(G * decay, x, (((1,), (0,)), ((), ())))
    rem = jnp.exp(la[L - 1] - la)                   # (L,)
    st = jax.lax.dot_general(x, B_ * rem[:, None],
                             (((0,), (0,)), ((), ())))          # (P, N)
    y_ref[0, 0, 0] = y.astype(y_ref.dtype)
    st_ref[0, 0, 0] = st.astype(st_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_chunks(x, B_, C_, a_log, *, interpret=True):
    """x: (B, H, nc, L, P); B_, C_: (B, nc, L, N); a_log: (B, H, nc, L).
    Returns (y (B, H, nc, L, P), states (B, H, nc, P, N))."""
    Bt, H, nc, L, P = x.shape
    N = B_.shape[-1]
    kernel = functools.partial(_kernel, L=L)
    y, st = pl.pallas_call(
        kernel,
        grid=(Bt, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, 1, L, P), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, L, N), lambda b, h, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, L, N), lambda b, h, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, L), lambda b, h, c: (b, h, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, L, P), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, P, N), lambda b, h, c: (b, h, c, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bt, H, nc, L, P), jnp.float32),
            jax.ShapeDtypeStruct((Bt, H, nc, P, N), jnp.float32),
        ],
        interpret=interpret,
    )(x, B_, C_, a_log)
    return y, st
