"""jit'd wrapper: full chunked SSD scan (kernel intra-chunk + jnp
inter-chunk recurrence). Mirrors repro.models.ssm._ssd_chunked semantics."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.mamba_scan.mamba_scan import ssd_chunks
from repro.kernels.mamba_scan.ref import ssd_chunks_ref


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def ssd_scan(x, B_, C_, a_log, *, use_kernel=True, interpret=True):
    """x: (B, H, nc, L, P); B_, C_: (B, nc, L, N); a_log: (B, H, nc, L).
    Full scan: returns y including cross-chunk contributions, final state."""
    if use_kernel:
        y_intra, states = ssd_chunks(x, B_, C_, a_log, interpret=interpret)
    else:
        y_intra, states = ssd_chunks_ref(x, B_, C_, a_log)

    la = jnp.cumsum(a_log, axis=-1)                 # (B, H, nc, L)
    chunk_decay = jnp.exp(la[..., -1])              # (B, H, nc)

    def body(h_prev, xs):
        st, dc, C_c, la_c = xs
        # (B, L, N) x (B, H, P, N) x (B, H, L) -> (B, H, L, P)
        y_int = jnp.einsum("bln,bhpn,bhl->bhlp", C_c, h_prev, jnp.exp(la_c))
        h_new = dc[..., None, None] * h_prev + st
        return h_new, y_int

    Bt, H, nc = a_log.shape[:3]
    N = B_.shape[-1]
    P = x.shape[-1]
    h0 = jnp.zeros((Bt, H, P, N), jnp.float32)
    xs = (states.transpose(2, 0, 1, 3, 4), chunk_decay.transpose(2, 0, 1),
          C_.transpose(1, 0, 2, 3), la.transpose(2, 0, 1, 3))
    h_final, y_inter = jax.lax.scan(body, h0, xs)
    y = y_intra + y_inter.transpose(1, 2, 0, 3, 4)
    return y, h_final
