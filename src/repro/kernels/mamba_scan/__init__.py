from repro.kernels.mamba_scan.mamba_scan import ssd_chunks
from repro.kernels.mamba_scan.ops import ssd_scan
from repro.kernels.mamba_scan.ref import chunk_ref, ssd_chunks_ref

__all__ = ["chunk_ref", "ssd_chunks", "ssd_chunks_ref", "ssd_scan"]
