"""jit'd wrapper dispatching between the Pallas flash kernel and the oracle.

Model code calls ``attention(q, k, v, ...)`` in the (B, S, H, hd) layout used
by repro.models; this wrapper transposes to head-major, runs the kernel, and
transposes back. ``use_kernel=False`` (default on CPU paths) falls through
to the reference; the TPU launcher flips it on.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.swa_attention.ref import swa_attention_ref
from repro.kernels.swa_attention.swa_attention import swa_attention


@functools.partial(jax.jit, static_argnames=("causal", "window", "cap",
                                             "use_kernel", "interpret"))
def attention(q, k, v, *, causal=True, window=0, cap=0.0, use_kernel=True,
              interpret=True):
    """q: (B, S, H, hd); k, v: (B, S, Hkv, hd) -> (B, S, H, hd)."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    if use_kernel:
        ot = swa_attention(qt, kt, vt, causal=causal, window=window, cap=cap,
                           interpret=interpret)
    else:
        ot = swa_attention_ref(qt, kt, vt, causal=causal, window=window,
                               cap=cap)
    return ot.transpose(0, 2, 1, 3)
