from repro.kernels.swa_attention.ops import attention
from repro.kernels.swa_attention.ref import swa_attention_ref
from repro.kernels.swa_attention.swa_attention import swa_attention

__all__ = ["attention", "swa_attention", "swa_attention_ref"]
