"""Pallas TPU sliding-window flash attention (forward).

Grid (B, H, Sq/BQ, Skv/BK); the kv dimension is innermost and sequential,
accumulating the online softmax in VMEM scratch (m, l, acc) and writing the
output tile once on the last kv step. Window banding masks per-block and
skips the matmuls of fully-out-of-band blocks with ``pl.when`` — the
MXU-aligned analogue of banded sparsity that makes long_500k serving
sub-quadratic (DESIGN.md).

Block shapes default to (BQ, hd) x (BK, hd) = (128, hd) x (512, hd): with
hd <= 256 the working set (q, k, v tiles + acc) stays well under VMEM, and
both matmul dims are multiples of the 128-lane MXU width.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
DEFAULT_BQ = 128
DEFAULT_BK = 512


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            causal, window, cap, bq, bk, scale):
    jq = pl.program_id(2)
    jk = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(jk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = jq * bq
    k_start = jk * bk
    # block-level band check: any (q, kv) pair in range?
    q_last, k_first = q_start + bq - 1, k_start
    in_band = True
    if causal:
        in_band = k_first <= q_last
    if window:
        in_band = jnp.logical_and(in_band,
                                  k_start + bk - 1 > q_start - window)

    @pl.when(in_band)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale        # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)                # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)
        if cap:
            s = jnp.tanh(s / cap) * cap
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - jnp.maximum(m_new, NEG_INF / 2)[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
        acc_ref[...] = (acc_ref[...] * corr[:, None]
                        + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ()))))
        m_ref[...] = m_new

    @pl.when(jk == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "cap", "bq", "bk", "interpret"))
def swa_attention(q, k, v, *, causal=True, window=0, cap=0.0,
                  bq=DEFAULT_BQ, bk=DEFAULT_BK, interpret=True):
    """q: (B, H, Sq, hd); k, v: (B, Hkv, Skv, hd) -> (B, H, Sq, hd)."""
    B, H, Sq, hd = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    g = H // Hkv
    bq = min(bq, Sq)
    bk = min(bk, Skv)
    pad_q = (-Sq) % bq
    pad_k = (-Skv) % bk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    Sqp, Skvp = q.shape[2], k.shape[2]
    grid = (B, H, Sqp // bq, Skvp // bk)

    kernel = functools.partial(_kernel, causal=causal, window=window,
                               cap=cap, bq=bq, bk=bk, scale=hd ** -0.5)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, iq, jk: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, iq, jk, g=g: (b, h // g, jk, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, iq, jk, g=g: (b, h // g, jk, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd),
                               lambda b, h, iq, jk: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sqp, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    if pad_q:
        out = out[:, :, :Sq]
    return out
