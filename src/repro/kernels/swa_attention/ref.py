"""Pure-jnp oracle for the sliding-window flash attention kernel.

Layout (B, H, S, hd) — kernel-friendly head-major. Causal + window banding
+ GQA head grouping + optional logit softcap (gemma2).
"""
from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30


def swa_attention_ref(q, k, v, *, causal=True, window=0, cap=0.0):
    """q: (B, H, Sq, hd); k, v: (B, Hkv, Skv, hd) -> (B, H, Sq, hd)."""
    B, H, Sq, hd = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    g = H // Hkv
    qf = q.astype(jnp.float32) * hd ** -0.5
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    qg = qf.reshape(B, Hkv, g, Sq, hd)
    s = jnp.einsum("bkgqh,bksh->bkgqs", qg, kf)
    if cap:
        s = jnp.tanh(s / cap) * cap
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - jnp.maximum(m, NEG_INF / 2))
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bkgqs,bksh->bkgqh", p, vf) / jnp.maximum(l, 1e-30)
    return o.reshape(B, H, Sq, hd).astype(q.dtype)
