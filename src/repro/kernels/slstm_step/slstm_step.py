"""Pallas TPU kernel for the sLSTM recurrence with VMEM-pinned recurrent
weights.

The faithful per-timestep scan re-streams the per-head recurrent matrix
R (P, 4P) from HBM every step — the dominant memory term of the xlstm
prefill/train roofline after the mLSTM was chunked (EXPERIMENTS.md §Perf
pair 1, iteration 2). The sLSTM h-recurrence is nonlinear so the TIME loop
cannot be parallelized exactly; but R is loop-invariant, so the kernel
processes T_BLK timesteps per grid step with R resident in VMEM:

  grid (B, H, T/T_BLK); per step: R tile (P, 4P) + gate block (T_BLK, 4P)
  in VMEM, fori over T_BLK recurrence steps on (P,) vectors, state carried
  across T grid steps in VMEM scratch.

R traffic drops by T_BLK (e.g. 128x): per layer at T=32k, P=512, H=4:
524 GB -> 4 GB. VMEM: R 4 MiB + gates 1 MiB + states ~10 KiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

T_BLK = 128


def _kernel(g_ref, r_ref, c0_ref, n0_ref, h0_ref, m0_ref,
            out_ref, cf_ref, nf_ref, hf_ref, mf_ref,
            c_s, n_s, h_s, m_s, *, t_blk, P, t_valid):
    jt = pl.program_id(2)
    nt = pl.num_programs(2)

    @pl.when(jt == 0)
    def _init():
        c_s[...] = c0_ref[0, 0]
        n_s[...] = n0_ref[0, 0]
        h_s[...] = h0_ref[0, 0]
        m_s[...] = m0_ref[0, 0]

    R = r_ref[0].astype(jnp.float32)                 # (P, 4P) resident

    def step(t, carry):
        c, n, h, m = carry
        g = g_ref[0, t, 0].astype(jnp.float32)       # (4P,)
        rec = jax.lax.dot_general(h[None, :], R,
                                  (((1,), (0,)), ((), ())))[0]
        g = g + rec
        z_r, i_r = g[:P], g[P:2 * P]
        f_r, o_r = g[2 * P:3 * P], g[3 * P:]
        m_new = jnp.maximum(f_r + m, i_r)
        ie = jnp.exp(i_r - m_new)
        fe = jnp.exp(f_r + m - m_new)
        c_new = fe * c + ie * jnp.tanh(z_r)
        n_new = fe * n + ie
        h_new = jax.nn.sigmoid(o_r) * c_new / jnp.maximum(n_new, 1e-6)
        out_ref[0, t, 0] = h_new.astype(out_ref.dtype)
        # padded tail steps must leave the state untouched
        valid = jt * t_blk + t < t_valid
        keep = lambda new, old: jnp.where(valid, new, old)
        return (keep(c_new, c), keep(n_new, n), keep(h_new, h),
                keep(m_new, m))

    carry = (c_s[...], n_s[...], h_s[...], m_s[...])
    c, n, h, m = jax.lax.fori_loop(0, t_blk, step, carry)
    c_s[...], n_s[...], h_s[...], m_s[...] = c, n, h, m

    @pl.when(jt == nt - 1)
    def _finalize():
        cf_ref[0, 0] = c
        nf_ref[0, 0] = n
        hf_ref[0, 0] = h
        mf_ref[0, 0] = m


@functools.partial(jax.jit, static_argnames=("t_blk", "t_valid", "interpret"))
def slstm_steps(g_in, R, state, *, t_blk=T_BLK, t_valid=None, interpret=True):
    """g_in: (B, T, H, 4P) fp32; R: (H, P, 4P); state: (c, n, h, m) each
    (B, H, P). Returns (h_out (B, T, H, P), final state). T must be padded
    to a multiple of t_blk by the caller (ops.py handles it); ``t_valid``
    marks the unpadded length (state updates stop there)."""
    B, T, H, P4 = g_in.shape
    P = P4 // 4
    assert T % t_blk == 0, (T, t_blk)
    c0, n0, h0, m0 = state
    kernel = functools.partial(_kernel, t_blk=t_blk, P=P,
                               t_valid=t_valid if t_valid is not None else T)
    grid = (B, H, T // t_blk)
    out, cf, nf, hf, mf = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, t_blk, 1, P4), lambda b, h, t: (b, t, h, 0)),
            pl.BlockSpec((1, P, P4), lambda b, h, t: (h, 0, 0)),
            pl.BlockSpec((1, 1, P), lambda b, h, t: (b, h, 0)),
            pl.BlockSpec((1, 1, P), lambda b, h, t: (b, h, 0)),
            pl.BlockSpec((1, 1, P), lambda b, h, t: (b, h, 0)),
            pl.BlockSpec((1, 1, P), lambda b, h, t: (b, h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, t_blk, 1, P), lambda b, h, t: (b, t, h, 0)),
            pl.BlockSpec((1, 1, P), lambda b, h, t: (b, h, 0)),
            pl.BlockSpec((1, 1, P), lambda b, h, t: (b, h, 0)),
            pl.BlockSpec((1, 1, P), lambda b, h, t: (b, h, 0)),
            pl.BlockSpec((1, 1, P), lambda b, h, t: (b, h, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, H, P), jnp.float32),
            jax.ShapeDtypeStruct((B, H, P), jnp.float32),
            jax.ShapeDtypeStruct((B, H, P), jnp.float32),
            jax.ShapeDtypeStruct((B, H, P), jnp.float32),
            jax.ShapeDtypeStruct((B, H, P), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((P,), jnp.float32),
            pltpu.VMEM((P,), jnp.float32),
            pltpu.VMEM((P,), jnp.float32),
            pltpu.VMEM((P,), jnp.float32),
        ],
        interpret=interpret,
    )(g_in, R, c0, n0, h0, m0)
    return out, (cf, nf, hf, mf)
