"""Pure-jnp oracle for the sLSTM recurrence kernel.

Inputs: pre-computed input gate projections g_in (B, T, H, 4P), recurrent
block-diagonal weights R (H, P, 4P), state (c, n, h, m) each (B, H, P).
Per step (exponential gating with the standard max-stabilizer):
    g  = g_in[t] + h @ R            -> split z, i, f, o  (P each)
    m' = max(f + m, i);  ie = exp(i - m');  fe = exp(f + m - m')
    c  = fe c + ie tanh(z);  n = fe n + ie
    h  = sigmoid(o) * c / max(n, 1e-6)
Matches repro.models.xlstm.slstm_forward's inner scan exactly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def slstm_steps_ref(g_in, R, state):
    """g_in: (B, T, H, 4P); R: (H, P, 4P); state: (c, n, h, m) (B, H, P).
    Returns (h_out (B, T, H, P), final state)."""
    B, T, H, P4 = g_in.shape
    P = P4 // 4

    def step(carry, g_t):
        c, n, h, m = carry
        rec = jnp.einsum("bhp,hpq->bhq", h, R)
        g = g_t + rec
        z_r, i_r, f_r, o_r = jnp.split(g, 4, axis=-1)
        m_new = jnp.maximum(f_r + m, i_r)
        ie = jnp.exp(i_r - m_new)
        fe = jnp.exp(f_r + m - m_new)
        c = fe * c + ie * jnp.tanh(z_r)
        n = fe * n + ie
        h_new = jax.nn.sigmoid(o_r) * c / jnp.maximum(n, 1e-6)
        return (c, n, h_new, m_new), h_new

    state, hs = jax.lax.scan(step, state, g_in.transpose(1, 0, 2, 3))
    return hs.transpose(1, 0, 2, 3), state
