from repro.kernels.slstm_step.ops import slstm_scan
from repro.kernels.slstm_step.ref import slstm_steps_ref
from repro.kernels.slstm_step.slstm_step import slstm_steps

__all__ = ["slstm_scan", "slstm_steps", "slstm_steps_ref"]
