"""jit'd wrapper: pads T to the block size, runs the kernel or the oracle.
Padding uses i = -inf (no write) and f = 0 (identity decay) gate values so
padded steps leave the state untouched; padded h rows are discarded."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.slstm_step.ref import slstm_steps_ref
from repro.kernels.slstm_step.slstm_step import T_BLK, slstm_steps


@functools.partial(jax.jit, static_argnames=("t_blk", "use_kernel",
                                             "interpret"))
def slstm_scan(g_in, R, state, *, t_blk=T_BLK, use_kernel=True,
               interpret=True):
    """g_in: (B, T, H, 4P); R: (H, P, 4P); state: (c, n, h, m) (B, H, P)."""
    if not use_kernel:
        return slstm_steps_ref(g_in, R, state)
    B, T, H, P4 = g_in.shape
    P = P4 // 4
    t_blk = min(t_blk, T)
    pad = (-T) % t_blk
    if pad:
        g_in = jnp.pad(g_in, ((0, 0), (0, pad), (0, 0), (0, 0)))
    out, st = slstm_steps(g_in, R, state, t_blk=t_blk, t_valid=T,
                          interpret=interpret)
    return out[:, :T], st
