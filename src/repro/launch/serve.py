"""Serving launcher: batched prefill + decode with the KV-cache engine.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --smoke \
      --batch 4 --prompt-len 64 --new-tokens 32 [--window 64]
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.checkpoint import load_pytree
from repro.configs import ARCHS, get_arch, reduced
from repro.data import TokenTask
from repro.models import build_model
from repro.serving import generate


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b", choices=sorted(ARCHS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--window", type=int, default=0,
                    help="sliding-window serving variant (long-context)")
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    model = build_model(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = model.init(key)
    if args.ckpt:
        params, _ = load_pytree(args.ckpt, params)

    task = TokenTask(vocab_size=cfg.vocab_size, seq_len=args.prompt_len)
    batch = {"tokens": task.sample(jax.random.fold_in(key, 1), args.batch)}
    if cfg.n_enc_layers:
        batch["enc"] = 0.02 * jax.random.normal(
            jax.random.fold_in(key, 2),
            (args.batch, cfg.n_prefix, cfg.d_model))
    elif cfg.n_prefix:
        batch["prefix"] = 0.02 * jax.random.normal(
            jax.random.fold_in(key, 2),
            (args.batch, cfg.n_prefix, cfg.d_model))

    buf = (args.window or (args.prompt_len + args.new_tokens
                           + (cfg.n_prefix if not cfg.n_enc_layers else 0)))
    t0 = time.time()
    toks, _ = generate(model, params, batch, max_new_tokens=args.new_tokens,
                       buf_len=buf, window=args.window)
    jax.block_until_ready(toks)
    dt = time.time() - t0
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} "
          f"new={args.new_tokens} window={args.window}")
    print(f"generated shape {toks.shape}; "
          f"{args.batch * args.new_tokens / dt:.1f} tok/s (host CPU)")
    print("sample:", toks[0][:16].tolist())
    return toks


if __name__ == "__main__":
    main()
