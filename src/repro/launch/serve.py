"""Serving launcher: continuous-batching request streams over SlotEngine.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --smoke \
      --requests 8 --max-slots 4 --prompt-len 32 --new-tokens 16 \
      [--static] [--window W] [--chunk C] [--temp 0.8 --topk 40 --topp 0.95]

The stream mixes prompt lengths (p/2, p, 2p cycling) so admissions and
evictions interleave mid-decode. A tiny warmup stream runs first so
compile time and warm throughput are reported SEPARATELY (the
``_time_donated`` discipline from benchmarks/microbench.py — a timer
started before the first call measures XLA, not serving).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.checkpoint import load_pytree
from repro.configs import ARCHS, get_arch, reduced
from repro.models import build_model
from repro.serving import GREEDY, Request, SamplingParams, SlotEngine, serve


def mixed_lengths(base: int, n: int):
    """Deterministic mixed prompt lengths: p/2, p, 2p cycling."""
    cycle = [max(1, base // 2), base, 2 * base]
    return [cycle[i % 3] for i in range(n)]


def build_requests(cfg, key, lens, new_tokens):
    rng = np.random.default_rng(int(np.asarray(key)[-1]))
    reqs = []
    for i, l in enumerate(lens):
        enc = None
        if cfg.n_enc_layers:
            enc = 0.02 * np.asarray(jax.random.normal(
                jax.random.fold_in(key, 100 + i),
                (cfg.n_prefix, cfg.d_model)))
        reqs.append(Request(
            rid=i, tokens=rng.integers(0, cfg.vocab_size, (l,)),
            max_new_tokens=new_tokens, enc=enc))
    return reqs


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b", choices=sorted(ARCHS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--window", type=int, default=0,
                    help="sliding-window serving variant (ring buffer)")
    ap.add_argument("--chunk", type=int, default=0,
                    help="streaming-prefill chunk (0 = auto)")
    ap.add_argument("--buf-len", type=int, default=0,
                    help="cache positions per slot (0 = auto)")
    ap.add_argument("--temp", type=float, default=0.0,
                    help="sampling temperature (0 = greedy)")
    ap.add_argument("--topk", type=int, default=0)
    ap.add_argument("--topp", type=float, default=1.0)
    ap.add_argument("--static", action="store_true",
                    help="static batching baseline (admission barrier)")
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    model = build_model(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = model.init(key)
    if args.ckpt:
        params, _ = load_pytree(args.ckpt, params)

    sampling = (GREEDY if args.temp == 0.0 else SamplingParams(
        temperature=args.temp, top_k=args.topk, top_p=args.topp))

    lens = mixed_lengths(args.prompt_len, args.requests)
    prefix = cfg.n_prefix if not cfg.n_enc_layers else 0
    buf = args.buf_len or (args.window + (args.chunk or 1)
                           if args.window
                           else prefix + max(lens) + args.new_tokens)

    example = {"tokens": np.zeros((1, 1), np.int32)}
    if cfg.n_enc_layers:
        example["enc"] = np.zeros((1, cfg.n_prefix, cfg.d_model), np.float32)
    engine = SlotEngine(model, params, max_slots=args.max_slots,
                        buf_len=buf, window=args.window, chunk=args.chunk,
                        sampling=sampling, example=example)

    # warmup stream: hits every compiled lane (incl. the chunked-prefill
    # lane via a long prompt) so the timed stream is compile-free
    warm_lens = [max(lens), min(lens)][:min(2, args.requests)]
    warm = build_requests(cfg, jax.random.fold_in(key, 1), warm_lens, 2)
    t0 = time.perf_counter()
    serve(engine, warm, mode="continuous", key=jax.random.fold_in(key, 2))
    compile_s = time.perf_counter() - t0

    reqs = build_requests(cfg, jax.random.fold_in(key, 3), lens,
                          args.new_tokens)
    mode = "static" if args.static else "continuous"
    report = serve(engine, reqs, mode=mode, key=jax.random.fold_in(key, 4))

    print(f"arch={cfg.name} mode={mode} slots={args.max_slots} "
          f"requests={args.requests} lens={lens} new={args.new_tokens} "
          f"window={args.window} buf={buf} chunk={engine.chunk} "
          f"sampling={'greedy' if sampling.greedy else sampling}")
    print(f"compile (warmup stream): {compile_s:.2f}s; lanes "
          f"{engine.compile_cache_sizes()}")
    print(f"warm: {report.tok_s:.1f} tok/s over {report.steps} steps, "
          f"occupancy {report.occupancy:.2f}, "
          f"ttft mean {report.ttft_mean_s * 1e3:.1f}ms, "
          f"{report.generated} tokens in {report.wall_s:.2f}s (host CPU)")
    r0 = report.results[0]
    print("sample rid=0:", r0.tokens[:16])
    return report


if __name__ == "__main__":
    main()
