"""Training launcher: DPPF (or DDP) on any assigned architecture.

CPU-runnable end-to-end driver (the examples call this); on a real pod the
same script runs under the production mesh with the dry-run's shardings.

  PYTHONPATH=src python -m repro.launch.train --arch yi-6b --smoke \
      --workers 4 --tau 4 --alpha 0.1 --lam 0.5 --steps 200
"""
from __future__ import annotations

import argparse
import os
import tempfile
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import load_train_state, save_pytree, save_train_state
from repro.configs import ARCHS, DPPFConfig, get_arch, reduced
from repro.core import methods as method_registry
from repro.data import TokenTask, make_lm_batch, make_round_batch
from repro.models import build_model
from repro.optim import make_optimizer
from repro.train import (
    ChaosMembership, ChaosPlan, FaultInjector, RoundClock,
    ScheduleMembership, Supervisor, init_train_state, make_ddp_step,
    make_round_step, make_sharded_round_step, shard_train_state,
)
from repro.train.clock import RoundMetricsLogger
from repro.train.trainer import TrainState, average_params


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b", choices=sorted(ARCHS))
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--d-model", type=int, default=0,
                    help="override d_model of the smoke config (e.g. scale "
                         "toward ~100M params)")
    ap.add_argument("--layers", type=int, default=0)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--tau", type=int, default=4)
    ap.add_argument("--alpha", type=float, default=0.1)
    ap.add_argument("--lam", type=float, default=0.5)
    # method choices and help come from the registry (core.methods): one
    # line per registered MethodSpec, aliases included in the choices
    method_help = "; ".join(
        f"{s.name} = {s.doc}"
        for s in (method_registry.get_method(n)
                  for n in method_registry.method_names(aliases=False)))
    flat_only = ", ".join(
        n for n in method_registry.method_names(aliases=False)
        if method_registry.get_method(n).requires_flat)
    ap.add_argument("--method", "--consensus", dest="consensus",
                    default="simple_avg",
                    choices=method_registry.method_names(),
                    help="consensus method (registry core.methods): "
                         + method_help)
    ap.add_argument("--engine", default="flat", choices=["tree", "flat"],
                    help="consensus execution engine (flat = persistent "
                         "(R, n) view — worker rows plus aux consensus-"
                         "state rows — with fused Gram/mixing round "
                         "update). Registry methods marked flat-only "
                         f"({flat_only}) refuse engine=tree")
    ap.add_argument("--overlap", default="none",
                    choices=["none", "staleness1", "doublebuf",
                             "staleness_k"],
                    help="staleness1 = apply the consensus computed from "
                         "the previous round's snapshot, hiding the "
                         "all-reduce behind the tau local steps; doublebuf "
                         "= additionally dispatch the snapshot's worker-"
                         "row gather + partial-Gram psum in chunks "
                         "interleaved with the scan, leaving only the mix "
                         "GEMM at the boundary (flat engine only); "
                         "staleness_k = generalize the carry to a k-deep "
                         "snapshot ring (--staleness) whose mid-scan "
                         "gather runs as a ppermute ring, spreading one "
                         "consensus over k rounds of compute")
    ap.add_argument("--overlap-chunks", type=int, default=4,
                    help="doublebuf/staleness_k: column chunks the "
                         "mid-scan snapshot comm splits into (1 = "
                         "bit-for-bit staleness1 consensus numerics)")
    ap.add_argument("--staleness", type=int, default=1,
                    help="staleness_k: ring depth k — round r applies the "
                         "consensus of the round-(r-k) snapshot; rounds "
                         "0..k-1 are exact-consensus pipeline fill (k=1 "
                         "is bit-for-bit doublebuf at --overlap-chunks 1)")
    ap.add_argument("--elastic", action="store_true",
                    help="staleness_k: bounded-async elastic rounds — a "
                         "worker row may sit out up to k rounds (frozen "
                         "params, dropped from the Gram target weights) "
                         "and rejoins with an EASGD-style catch-up pull")
    ap.add_argument("--elastic-catchup", type=float, default=0.5,
                    help="elastic: fraction of the gap to the active-row "
                         "mean a rejoining row closes on re-entry")
    ap.add_argument("--elastic-drop", default="", metavar="W,A,B",
                    help="elastic demo: mark worker row W inactive for "
                         "rounds [A, B) via train.set_participation (the "
                         "bounded-staleness clamp still forces a rejoin "
                         "after k missed rounds); runs through the same "
                         "supervisor loop as --chaos, as the trivial "
                         "ScheduleMembership provider")
    ap.add_argument("--chaos", default="", metavar="PLAN.json",
                    help="run under the fault-tolerant supervisor with a "
                         "replayable ChaosPlan (train.chaos): scripted "
                         "kill/stall/netdrop windows drive the heartbeat "
                         "membership table, oom events raise "
                         "RESOURCE_EXHAUSTED at the trainer boundary "
                         "(batch shrinks and the round replays from the "
                         "last good checkpoint), corrupt_ckpt events tear "
                         "a written checkpoint (the restore ladder falls "
                         "back to the previous rotation copy). The same "
                         "plan replays to a bit-identical recovery-event "
                         "sequence")
    ap.add_argument("--quorum", type=int, default=0,
                    help="minimum active worker rows for a consensus "
                         "round; below it the round degrades to local-"
                         "only steps (consensus skipped bit-exactly, "
                         "logged, backed off). 0 = disabled; requires a "
                         "membership source (--chaos or --elastic-drop)")
    ap.add_argument("--heartbeat-timeout", type=float, default=0.9,
                    help="seconds of heartbeat silence before a "
                         "membership poll counts a missed deadline (the "
                         "chaos clock is virtual: one round = 1s, so the "
                         "default suspects a worker on its first fully "
                         "silent round); must be > 0")
    ap.add_argument("--retry-budget", type=int, default=3,
                    help="supervisor: max CONSECUTIVE failed rounds "
                         "(restore + replay each) before the failure "
                         "propagates")
    ap.add_argument("--sharded", action="store_true",
                    help="run the round under shard_map on all local "
                         "devices (launch.mesh.make_flat_engine_mesh; "
                         "flat engine only)")
    ap.add_argument("--mesh", default="", metavar="W,F,M",
                    help="workers,fsdp,model — run the round under "
                         "shard_map on a hierarchical 3-axis mesh of "
                         "local devices (launch.mesh.make_hier_engine_"
                         "mesh; flat engine only): worker rows over the "
                         "first axis, flat-view columns over fsdp x "
                         "model. E.g. --mesh 2,2,2 under XLA_FLAGS="
                         "--xla_force_host_platform_device_count=8")
    ap.add_argument("--lam-schedule", default="increasing")
    ap.add_argument("--tau-schedule", default="fixed",
                    choices=["fixed", "qsr"],
                    help="qsr = Quadratic Synchronization Rule (§7.2): "
                         "tau_t = max(tau, floor((qsr_beta/lr_t)^2)) per "
                         "round — fewer consensus all-reduces as the "
                         "cosine LR decays")
    ap.add_argument("--qsr-beta", type=float, default=0.0,
                    help="QSR beta (required > 0 with --tau-schedule qsr)")
    ap.add_argument("--optimizer", default="sgd", choices=["sgd", "adamw"])
    ap.add_argument("--sam-rho", type=float, default=0.0)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8, help="per-worker batch")
    ap.add_argument("--lr", type=float, default=0.3)
    ap.add_argument("--warmup", type=int, default=0,
                    help="linear LR warmup steps; the RoundClock samples "
                         "the FULL schedule (warmup + cosine) — QSR rounds "
                         "inside the warmup keep the base tau instead of "
                         "blowing up on the tiny warmup LR")
    ap.add_argument("--log-every-round", default="", metavar="PATH",
                    help="write one JSON line of the unified round-metrics "
                         "dict (consensus_dist/pull_force/push_force/"
                         "staleness, plus the clock position) per round to "
                         "PATH (train.clock.RoundMetricsLogger; the ddp "
                         "branch logs per step on its tau=1 clock)")
    ap.add_argument("--legacy-metrics", action="store_true",
                    help="re-emit the deprecated boolean 'stale' field "
                         "next to the integer 'staleness' in "
                         "--log-every-round records")
    ap.add_argument("--autotune", action="store_true",
                    help="probe-search the operating point before training "
                         "(train.autotune, DESIGN.md §Autotune): power-of-"
                         "two batch probes with OOM backoff + binary "
                         "refinement, then a joint (tau, overlap_chunks) "
                         "sweep at the frontier batch, scored by measured "
                         "round time reconciled against the roofline "
                         "overlap model; training then runs at the chosen "
                         "point (--batch/--max-batch bound the ladder, "
                         "--tau seeds the tau ladder {tau, 2*tau})")
    ap.add_argument("--tune-plan", default="", metavar="PATH",
                    help="with --autotune: write the searched TunePlan "
                         "JSON to PATH; without: load a committed TunePlan "
                         "from PATH and train at its chosen point (replay "
                         "is deterministic — the plan pins batch, tau, "
                         "overlap_chunks)")
    ap.add_argument("--probe-budget", type=int, default=16,
                    help="autotune: max probes (distinct candidates "
                         "measured or OOMed); on exhaustion the best "
                         "point found so far wins")
    ap.add_argument("--max-batch", type=int, default=0,
                    help="autotune: batch-ladder ceiling (0 = 8x --batch)")
    ap.add_argument("--tune-oom-above", type=int, default=0,
                    help="autotune fault injection (CI): probes with "
                         "batch > this raise a scripted RESOURCE_EXHAUSTED "
                         "before touching the device, exercising the "
                         "backoff path without real memory pressure "
                         "(0 = off)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt", default="",
                    help="checkpoint path: final (serving) params are "
                         "written here as before; DPPF runs additionally "
                         "keep a mid-run resume point at "
                         "<ckpt>.state.npz and resume from it when it "
                         "exists")
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args(argv)
    mspec = method_registry.get_method(args.consensus)
    if (args.sharded or args.mesh) and (args.engine != "flat"
                                        or not mspec.communicates):
        ap.error("--sharded/--mesh require --engine flat and a "
                 "communicating consensus method (the shard_map round "
                 "runs on the flat engine's (R, n) view)")
    if args.sharded and args.mesh:
        ap.error("--sharded and --mesh are mutually exclusive (--mesh IS "
                 "a sharded run on an explicit workers,fsdp,model shape)")
    if (args.autotune or args.tune_plan) and (
            args.tau_schedule == "qsr" or args.qsr_beta > 0):
        ap.error("--autotune/--tune-plan pin a fixed tau at the measured "
                 "comm/compute crossover; --tau-schedule qsr would "
                 "re-adapt it — drop --qsr-beta when tuning")
    if args.autotune and not mspec.communicates:
        ap.error("--autotune searches the communication round's operating "
                 "point and needs a communicating consensus method")
    mesh_shape = ()
    if args.mesh:
        try:
            mesh_shape = tuple(int(x) for x in args.mesh.split(","))
            if len(mesh_shape) != 3:
                raise ValueError
        except ValueError:
            ap.error("--mesh expects three comma-separated ints: "
                     "workers,fsdp,model (e.g. --mesh 2,2,2)")
    # supervisor / membership flag validation — all before any model work
    drop_spec = ()
    if args.elastic_drop:
        try:
            drop_spec = tuple(int(x) for x in args.elastic_drop.split(","))
            if len(drop_spec) != 3 or not 0 <= drop_spec[0] < args.workers:
                raise ValueError
        except ValueError:
            ap.error("--elastic-drop expects W,A,B with worker row "
                     "0 <= W < --workers (e.g. --elastic-drop 2,3,5)")
        if not 0 <= drop_spec[1] < drop_spec[2]:
            ap.error(f"--elastic-drop window [{drop_spec[1]}, "
                     f"{drop_spec[2]}) is empty or negative — need "
                     "0 <= A < B (e.g. --elastic-drop 2,3,5)")
    if args.chaos and drop_spec:
        ap.error("--chaos and --elastic-drop are mutually exclusive (the "
                 "plan's kill/stall/netdrop events already script the "
                 "membership windows)")
    if args.heartbeat_timeout <= 0:
        ap.error("--heartbeat-timeout must be > 0 seconds")
    if args.retry_budget < 0:
        ap.error("--retry-budget must be >= 0")
    if not 0 <= args.quorum <= args.workers:
        ap.error(f"--quorum {args.quorum} must be in [0, --workers] "
                 f"({args.workers})")
    chaos_plan = None
    if args.chaos:
        try:
            chaos_plan = ChaosPlan.load(args.chaos)
        except ValueError as e:
            ap.error(f"--chaos {args.chaos}: {e}")
    if args.quorum and chaos_plan is None and not drop_spec:
        ap.error("--quorum needs a membership source: a --chaos plan or "
                 "an --elastic-drop window")
    needs_membership = bool(drop_spec) or args.quorum > 0 or (
        chaos_plan is not None and bool(chaos_plan.membership_events()))
    if needs_membership and args.overlap != "staleness_k":
        ap.error("membership-driven rounds (--elastic-drop / --quorum / "
                 "a --chaos plan with kill|stall|netdrop events) ride the "
                 "elastic staleness_k carry — add --overlap staleness_k "
                 "(with --staleness K)")
    if needs_membership and not mspec.communicates:
        ap.error("membership/quorum supervision needs a communicating "
                 "consensus method (a local-only method never syncs, so "
                 "there is nothing to degrade or rejoin)")

    cfg = get_arch(args.arch)
    if args.smoke:
        over = {}
        if args.d_model:
            over.update(d_model=args.d_model,
                        head_dim=max(args.d_model // 4, 32),
                        d_ff=2 * args.d_model if cfg.d_ff else 0)
        if args.layers:
            over["n_layers"] = args.layers
        cfg = reduced(cfg, **over)
    model = build_model(cfg)
    n_params = sum(l.size for l in jax.tree.leaves(
        jax.eval_shape(model.init, jax.random.PRNGKey(0))))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M workers={args.workers} "
          f"tau={args.tau} alpha={args.alpha} lam={args.lam}")

    task = TokenTask(vocab_size=cfg.vocab_size, seq_len=args.seq)
    dcfg = DPPFConfig(alpha=args.alpha, lam=args.lam, tau=args.tau,
                      consensus=args.consensus, engine=args.engine,
                      overlap=args.overlap,
                      overlap_chunks=args.overlap_chunks,
                      staleness=args.staleness,
                      elastic=args.elastic or needs_membership,
                      elastic_catchup=args.elastic_catchup,
                      lam_schedule=args.lam_schedule,
                      tau_schedule=args.tau_schedule, qsr_beta=args.qsr_beta)
    opt = make_optimizer(args.optimizer, momentum=0.9, weight_decay=1e-3)
    key = jax.random.PRNGKey(args.seed)

    # --autotune: search the (batch, tau, overlap_chunks) operating point
    # on the real round step before committing to a plan; --tune-plan
    # alone replays a committed TunePlan (DESIGN.md §Autotune)
    batch_size, tune_plan = args.batch, None
    if args.autotune:
        from repro.train import (TuneSpace, inject_oom_above,
                                 make_lm_model_fn, make_round_probe_runner)
        from repro.train import autotune as tune
        space = TuneSpace(min_batch=args.batch,
                          max_batch=args.max_batch or args.batch * 8,
                          taus=(args.tau, args.tau * 2), chunks=(1, 2, 4),
                          probe_budget=args.probe_budget,
                          overlap=args.overlap, staleness=args.staleness)
        runner = make_round_probe_runner(
            model.init, model.loss, opt, dcfg, args.workers,
            lambda cand: make_round_batch(task, args.seed, args.workers,
                                          cand.tau, 0, cand.batch, cfg),
            base_lr=args.lr, total_steps=args.steps, seed=args.seed)
        if args.tune_oom_above:
            runner = inject_oom_above(runner, args.tune_oom_above)
        model_fn = make_lm_model_fn(n_params=n_params, seq=args.seq,
                                    workers=args.workers,
                                    overlap=args.overlap,
                                    staleness=args.staleness)
        tune_plan = tune(runner, model_fn, space)
        ch = tune_plan.chosen
        print(f"autotune: chose batch={ch.batch} tau={ch.tau} "
              f"chunks={ch.overlap_chunks} after {tune_plan.probes_used} "
              f"probes (OOM batches: {list(tune_plan.failures) or 'none'}, "
              f"model scale {tune_plan.residual_scale:.3f})")
        if args.tune_plan:
            tune_plan.save(args.tune_plan)
            print(f"tune plan -> {args.tune_plan}")
    elif args.tune_plan:
        from repro.train import TunePlan
        tune_plan = TunePlan.load(args.tune_plan)
        ch = tune_plan.chosen
        print(f"tune plan <- {args.tune_plan}: batch={ch.batch} "
              f"tau={ch.tau} chunks={ch.overlap_chunks}")

    # the RoundClock is the single source of truth for step/round
    # accounting: round plan (incl. the steps % tau remainder, warmup
    # rounds, QSR-adaptive taus — stale-LR ruled under overlap), lam_t,
    # and LR position (DESIGN.md §Round-clock)
    if tune_plan is not None:
        clock = RoundClock.from_tune_plan(tune_plan, base_lr=args.lr,
                                          total_steps=args.steps,
                                          warmup=args.warmup, dcfg=dcfg)
        dcfg = dcfg.apply_tune_plan(tune_plan)
        batch_size = tune_plan.chosen.batch
    else:
        clock = RoundClock.from_config(dcfg, base_lr=args.lr,
                                       total_steps=args.steps,
                                       warmup=args.warmup)
    logger = RoundMetricsLogger(args.log_every_round,
                                legacy=args.legacy_metrics) \
        if args.log_every_round else None

    t0 = time.time()
    if not mspec.communicates:
        p0 = model.init(key)
        state = TrainState(params=p0, opt=opt.init(p0), cstate={},
                           t=jnp.zeros((), jnp.int32))
        step = jax.jit(make_ddp_step(model.loss, opt, clock=clock,
                                     sam_rho=args.sam_rho))
        for s in range(args.steps):
            batch = jax.tree.map(
                lambda *xs: jnp.stack(xs),
                *[make_lm_batch(task, args.seed, m, s, args.batch, cfg)
                  for m in range(args.workers)])
            state, m = step(state, batch)
            if logger is not None:   # ddp: per step on the tau=1 clock
                logger(s, m)
            if s % (args.log_every * args.tau) == 0:
                print(f"step {s:5d} loss {float(m['train_loss']):.4f}")
        final = state.params
    else:
        state = init_train_state(model.init, opt, dcfg, args.workers, key)
        # the resume point lives NEXT TO the final-params checkpoint (which
        # keeps its serving format at args.ckpt, see launch/serve.py)
        state_file = stem = ""
        if args.ckpt:
            stem = args.ckpt[:-4] if args.ckpt.endswith(".npz") else args.ckpt
            state_file = stem + ".state.npz"
        if state_file and os.path.exists(state_file):
            state = load_train_state(state_file, state, clock=clock)
            # the saved round index belongs to the plan that WROTE the
            # checkpoint; if this run's plan differs (changed --steps /
            # --lr / tau schedule), re-derive the position from the step
            # counter — a silent mismatch would replay or skip data
            import dataclasses as _dc
            t_res, rnd = int(state.t), int(state.round)
            if rnd >= clock.total_rounds or clock.rounds[rnd].start != t_res:
                rnd = clock.round_of_step(t_res)   # raises if t > steps
                if rnd < clock.total_rounds and \
                        clock.rounds[rnd].start != t_res:
                    raise ValueError(
                        f"checkpoint step {t_res} is mid-round in this "
                        f"run's plan (round {rnd} starts at "
                        f"{clock.rounds[rnd].start}) — resume with the "
                        "original --steps/--lr/--tau-schedule/--qsr-beta")
                state = _dc.replace(
                    state, round=jnp.asarray(rnd, jnp.int32))
            print(f"resumed from {state_file} at step {t_res} "
                  f"(round {rnd})")
        if args.sharded or mesh_shape:
            if mesh_shape:
                from repro.launch.mesh import make_hier_engine_mesh
                mesh, plan = make_hier_engine_mesh(*mesh_shape)
            else:
                from repro.launch.mesh import make_flat_engine_mesh
                mesh, plan = make_flat_engine_mesh(args.workers)
            print(f"sharded round on mesh {dict(mesh.shape)}")
            # resume happened ABOVE on host arrays, so a checkpoint written
            # under any mesh shape (or none) reshards here — the 2x2x2 ->
            # 8x1 cross-shape resume the tests pin
            state = shard_train_state(state, mesh, plan, dcfg=dcfg)
            step = jax.jit(make_sharded_round_step(
                model.loss, opt, dcfg, mesh=mesh, plan=plan, clock=clock,
                sam_rho=args.sam_rho), donate_argnums=0)
        else:
            # donation keeps the flat engine's (R, n) view (and the opt
            # state) in place across rounds — no per-round parameter copies
            step = jax.jit(make_round_step(model.loss, opt, dcfg,
                                           clock=clock,
                                           sam_rho=args.sam_rho),
                           donate_argnums=0)
        # the fault-tolerant supervisor owns the round iteration
        # (train/supervisor.py): it iterates the clock's round plan (every
        # step runs — the remainder round is part of the plan; a QSR tau
        # change simply retraces under jit), polls membership into the
        # participation mask, degrades below-quorum rounds to local-only
        # steps, and recovers failed rounds from rotation checkpoints.
        # With no membership and no chaos it is bit-for-bit the plain
        # `for spec in clock.rounds` loop this replaced.
        membership = injector = None
        if chaos_plan is not None:
            injector = FaultInjector(chaos_plan)
            if needs_membership:
                membership = ChaosMembership(chaos_plan, args.workers,
                                             timeout=args.heartbeat_timeout)
        elif drop_spec:
            membership = ScheduleMembership(args.workers, [drop_spec])
        sup_dir = ""
        if chaos_plan is not None:
            # recovery checkpoints (the sup_last/sup_prev rotation pair)
            # live next to the resume point when --ckpt names one, else
            # in a scratch dir for this run only
            sup_dir = stem + ".sup" if stem \
                else tempfile.mkdtemp(prefix="dppf-sup-")
        place_fn = None
        if args.sharded or mesh_shape:
            place_fn = (lambda st:
                        shard_train_state(st, mesh, plan, dcfg=dcfg))

        def on_round(spec, m):
            if spec.index % args.log_every == 0:
                # state.t after the step == spec.start + spec.tau
                print(f"round {spec.index:4d} "
                      f"(step {spec.start + spec.tau:5d} "
                      f"tau {spec.tau:3d}) "
                      f"loss {float(m['train_loss']):.4f} "
                      f"consensus_dist {float(m['consensus_dist']):.3f} "
                      f"lam_t {float(m.get('lam_t', 0)):.3f}")

        sup = Supervisor(clock, workers=args.workers, membership=membership,
                         quorum=args.quorum, retry_budget=args.retry_budget,
                         chaos=injector, ckpt_dir=sup_dir,
                         tune_plan=tune_plan, batch_size=batch_size,
                         logger=logger, on_round=on_round,
                         place_fn=place_fn, seed=args.seed)
        state = sup.run(
            state, step,
            lambda spec, bs: make_round_batch(task, args.seed, args.workers,
                                              spec.tau, spec.start, bs, cfg),
            start_round=int(state.round))
        if sup.events:
            s = sup.summary()
            print("supervisor events: " + " ".join(s["event_seq"]))
            print("supervisor counters: " + " ".join(
                f"{k}={v}" for k, v in s["counters"].items())
                  + f" final_batch={s['final_batch']}")
        print(f"comm rounds {clock.total_rounds} "
              f"(fixed tau={args.tau} would take {clock.fixed_rounds}; "
              f"all-reduces saved {clock.fixed_rounds - clock.total_rounds})")
        if state_file:
            save_train_state(state_file, state)
            print(f"train-state resume point -> {state_file}")
        final = average_params(state)

    # held-out eval
    eval_batch = make_lm_batch(task, args.seed + 999, 0, 10 ** 6,
                               batch_size * args.workers, cfg)
    loss, _ = jax.jit(model.loss)(final, eval_batch)
    if logger is not None:
        logger.close()
        print(f"round metrics -> {args.log_every_round}")
    print(f"eval loss {float(loss):.4f}  wall {time.time() - t0:.1f}s")
    if args.ckpt:
        save_pytree(args.ckpt, final, extra={"steps": args.steps})
        print(f"checkpoint -> {args.ckpt}")
    return float(loss)


if __name__ == "__main__":
    main()
