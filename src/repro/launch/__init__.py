# Launchers: mesh.py (production mesh + sharding rules), dryrun.py
# (multi-pod lower+compile sweep), train.py, serve.py, roofline.py.
# NOTE: dryrun must be executed as a MODULE ENTRYPOINT (python -m
# repro.launch.dryrun) — it sets XLA_FLAGS before importing jax.
