"""Config inspector: print every assigned architecture's resolved config,
analytic param counts, per-chip memory on the production plans, and the
decode policy per input shape — the pre-flight check an oncall runs before
launching a job.

  PYTHONPATH=src python -m repro.launch.validate [--arch <id>]
"""
from __future__ import annotations

import argparse

from repro.configs import ARCHS, INPUT_SHAPES, get_arch
from repro.launch import specs as specs_lib

TP = 16
HBM_GB = 16.0  # v5e


def describe(name: str):
    cfg = get_arch(name)
    n = cfg.param_count()
    na = cfg.active_param_count()
    blocks = cfg.blocks()
    kinds = {k: blocks.count(k) for k in sorted(set(blocks))}
    print(f"\n== {name} [{cfg.family}]  ({cfg.source})")
    print(f"   L={cfg.n_layers} d={cfg.d_model} H={cfg.n_heads} "
          f"kv={cfg.n_kv_heads} ff={cfg.d_ff} V={cfg.vocab_size} "
          f"blocks={kinds}")
    print(f"   params={n/1e9:.2f}B active={na/1e9:.2f}B")
    for plan, shards in (("baseline M=16 TP=16", TP),
                         ("hier M=4 fsdp=4 TP=16", TP * 4)):
        bf16 = n * 2 / shards / 1e9
        mom32 = n * 4 / shards / 1e9
        fit = "FITS" if bf16 + mom32 <= HBM_GB else "OVER"
        print(f"   {plan}: params(bf16)+mom(fp32) = "
              f"{bf16 + mom32:5.1f} GB/chip [{fit}]")
    for sname, shape in INPUT_SHAPES.items():
        if shape.kind != "decode":
            continue
        w = specs_lib.serve_window_for(cfg, shape)
        buf = specs_lib.buf_len_for(cfg, shape)
        mode = ("recurrent/native" if cfg.is_recurrent and w == 0 else
                f"window={w} ring" if w else "full cache")
        print(f"   {sname}: buf={buf} ({mode})")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=sorted(ARCHS))
    args = ap.parse_args(argv)
    for name in ([args.arch] if args.arch else sorted(ARCHS)):
        describe(name)


if __name__ == "__main__":
    main()
