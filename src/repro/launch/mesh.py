"""Production mesh definitions and the sharding rule engine.

The DPPF mapping (DESIGN.md §2): the worker axis enumerates DPPF replicas
(each holds distinct parameters), the model axis is tensor-parallel within
a replica, optional fsdp axes shard weight storage within a replica
(hierarchical-DPPF extension).

All builders are FUNCTIONS — importing this module never touches jax device
state (required so smoke tests see 1 device while the dry-run sees 512).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import MeshPlan


def make_production_mesh(*, multi_pod: bool = False):
    """The assigned production mesh: 16x16 = 256 chips per pod;
    2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_hierarchical_mesh(workers: int, fsdp: int, model: int,
                           *, multi_pod: bool = False, devices=None):
    """Re-view the chips as (worker, fsdp, model) so big models FSDP-shard
    within each DPPF worker (DESIGN.md §Hierarchical-mesh). ``devices=None``
    targets the assigned production pod — the product must equal 256
    chips (512 multi-pod). Pass an explicit device list (e.g. the host's
    forced CPU devices) to build the same 3-axis plan at any size; the
    product must then cover exactly those devices."""
    if min(workers, fsdp, model) < 1:
        # ValueError, not assert: user-facing (--mesh) and must survive -O
        raise ValueError(f"hierarchical mesh axes must all be >= 1, got "
                         f"{workers}x{fsdp}x{model}")
    if devices is None:
        n = 512 if multi_pod else 256
        kind = "multi-pod" if multi_pod else "single-pod"
        pool = jax.devices()[:n]
    else:
        pool = list(devices)
        n = len(pool)
        kind = f"{n} given devices"
    if workers * fsdp * model != n:
        raise ValueError(
            f"hierarchical mesh shape {workers}x{fsdp}x{model} = "
            f"{workers * fsdp * model} chips must use exactly {n} "
            f"({kind})")
    devs = np.asarray(pool).reshape(workers, fsdp, model)
    return Mesh(devs, ("data", "fsdp", "model"))


def hierarchical_plan() -> MeshPlan:
    """The MeshPlan matching ``make_hierarchical_mesh``'s axis names: DPPF
    workers on "data", weight-storage column shards on "fsdp",
    tensor-parallel on "model"."""
    return MeshPlan(worker_axes=("data",), fsdp_axes=("fsdp",),
                    model_axes=("model",))


def make_hier_engine_mesh(workers: int, fsdp: int, model: int):
    """``(mesh, plan)`` over the host's local devices for the sharded flat
    engine — ``launch/train.py --mesh workers,fsdp,model``. Unlike the
    production builder this validates against the actual local device
    count (force it with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``)."""
    devs = jax.devices()
    need = workers * fsdp * model
    if need > len(devs):
        raise ValueError(
            f"hierarchical mesh {workers}x{fsdp}x{model} needs {need} "
            f"devices, host has {len(devs)} (set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={need})")
    mesh = make_hierarchical_mesh(workers, fsdp, model,
                                  devices=devs[:need])
    return mesh, hierarchical_plan()


def make_cpu_mesh():
    """1-device mesh for tests/benches (same code path, trivial shardings)."""
    devs = np.asarray(jax.devices()[:1]).reshape(1, 1)
    return Mesh(devs, ("data", "model"))


def make_flat_engine_mesh(workers: int):
    """All local devices as a (data, model) mesh for the sharded flat
    engine: worker rows over the largest device count dividing ``workers``,
    the remainder as column (fsdp-style) shards of the (R, n) view.
    Returns ``(mesh, plan)`` ready for ``make_sharded_round_step``."""
    devs = jax.devices()
    rows = math.gcd(workers, len(devs))
    cols = len(devs) // rows
    mesh = Mesh(np.asarray(devs[:rows * cols]).reshape(rows, cols),
                ("data", "model"))
    return mesh, MeshPlan(worker_axes=("data",), model_axes=("model",))


# ---------------------------------------------------------------------------
# Sharding rules
# ---------------------------------------------------------------------------

# leaf-name -> (model-sharded dim from the right, fsdp-sharded dim from the
# right). None = replicated over that axis group.
_RULES = {
    # attention / dense projections: shard the output features
    "wq": (-1, -2), "wk": (-1, -2), "wv": (-1, -2),
    "bq": (-1, None), "bk": (-1, None), "bv": (-1, None),
    "wo": (-2, -1),
    # gated MLP
    "w_gate": (-1, -2), "w_up": (-1, -2), "w_down": (-2, -1),
    # embeddings / head
    "embed": (-1, -2), "lm_head": (-1, -2),
    # mamba
    "in_proj": (-1, -2), "out_proj": (-2, -1), "conv_w": (-1, None),
    "conv_b": (-1, None), "norm": (-1, None),
    # xlstm
    "w_i": (-1, None), "w_f": (-1, None), "w_gates": (-1, -2),
    "r_gates": (None, None),
    # moe router
    "router": (-1, None),
}

# inside a "moe" subtree the expert tables shard the EXPERT dim (-3)
_MOE_RULES = {"w_gate": (-3, -1), "w_up": (-3, -1), "w_down": (-3, -1)}


def _axes_size(mesh, axes):
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _axes_entry(axes):
    return axes if len(axes) > 1 else axes[0]


def _leaf_spec(mesh, path, shape, plan: MeshPlan, stacked: bool):
    names = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
    name = names[-1] if names else ""
    in_moe = "moe" in names
    rules = _MOE_RULES if (in_moe and name in _MOE_RULES) else _RULES
    model_dim, fsdp_dim = rules.get(name, (None, None))
    nd = len(shape)
    lo = 1 if stacked else 0  # dims below this are the worker stack

    spec = [None] * nd
    if stacked and nd > 0:
        spec[0] = _axes_entry(plan.worker_axes)

    # matrices (2 feature dims) may fall back to the sibling feature dim;
    # bias/vector leaves must never shard their layer-stack prefix dims
    two_feature = fsdp_dim is not None

    def try_shard(dim, axes):
        """Place ``axes`` on ``dim`` if free + divisible; else (matrices
        only) try the sibling feature dim; else give up (replicate)."""
        if dim is None or not axes:
            return
        size = _axes_size(mesh, axes)
        cands = [dim] + ([-1 if dim == -2 else -2] if two_feature else [])
        for d in cands:
            if nd + d < lo:
                continue
            if spec[d] is None and shape[d] % size == 0 and shape[d] >= size:
                spec[d] = _axes_entry(axes)
                return

    try_shard(model_dim, plan.model_axes)
    try_shard(fsdp_dim, plan.fsdp_axes)
    return P(*spec)


def param_shardings(mesh: Mesh, params, plan: MeshPlan, *, stacked=True):
    """NamedShardings for a (possibly worker-stacked) parameter pytree."""
    def one(path, leaf):
        return NamedSharding(mesh, _leaf_spec(mesh, path, np.shape(leaf),
                                              plan, stacked))
    return jax.tree_util.tree_map_with_path(one, params)


def flat_col_axes(mesh: Mesh, n: int, plan: MeshPlan):
    """Effective column axis group for the flat view's column dim. The ONE
    copy of the column-divisibility rule — shared by `flat_view_sharding`,
    `train.trainer.make_sharded_round_step` (in_specs AND the engine's
    partial-Gram psum group), and the staleness-1 snapshot placement.

    Preference order: the full ``fsdp + model`` group when its size
    divides n (the hierarchical mesh's normal case — the psum then spans
    BOTH axes), else fsdp alone, else model alone, else ``()`` (columns
    replicate and the psum degenerates to a no-op)."""
    for axes in (plan.fsdp_axes + plan.model_axes, plan.fsdp_axes,
                 plan.model_axes):
        if axes and n % _axes_size(mesh, axes) == 0:
            return tuple(axes)
    return ()


def flat_col_entry(mesh: Mesh, n: int, plan: MeshPlan):
    """PartitionSpec entry form of `flat_col_axes` (None = replicated)."""
    axes = flat_col_axes(mesh, n, plan)
    return _axes_entry(axes) if axes else None


def flat_view_sharding(mesh: Mesh, shape, plan: MeshPlan):
    """Sharding rule for the flat engine's persistent (R, n) view: rows
    over the worker axes, columns over fsdp+model axes — each only when
    divisible. Aux rows (easgd center) usually break row divisibility, in
    which case rows replicate here and `make_sharded_round_step` still
    row-shards the worker block via its shard_map in_specs.

    A 3-D ``(k, R, n)`` shape is the staleness-k snapshot ring (leading
    ring dim replicated, same row/column rule per slot)."""
    *ring, R, n = shape
    spec = [None] * len(ring) + [None, flat_col_entry(mesh, n, plan)]
    if plan.worker_axes and R % _axes_size(mesh, plan.worker_axes) == 0:
        spec[-2] = _axes_entry(plan.worker_axes)
    return NamedSharding(mesh, P(*spec))


def ring_gather(x_loc, axes, *, world: int, axis=0):
    """Worker-row gather as a ``ppermute`` ring — ``world - 1``
    neighbor-to-neighbor hops of ONE local row block each, in place of one
    monolithic ``lax.all_gather``.

    Contract: the result is bit-for-bit ``jax.lax.all_gather(x_loc, axes,
    axis=axis, tiled=True)`` — shard i's block lands at offset
    ``i * x_loc.shape[axis]`` (the same row-major concatenation order, see
    ``train.trainer._lin_index``) and blocks are moved verbatim, so
    precise-mode parity is automatic. What changes is the transport: the
    peak per-hop collective payload is one block (``1/world`` of the
    all_gather payload) and each hop only talks to the two ring neighbors,
    which lets the staleness-k scan interleave hops with its compute
    segments (DESIGN.md §Overlap).

    Multi-axis worker groups fall back to ``all_gather`` (a ring needs a
    single linear axis order); ``world == 1`` is the identity. Call only
    inside ``shard_map`` over ``axes``; ``world`` is the static product of
    the mapped axis sizes.
    """
    if world == 1:
        return x_loc
    if len(axes) != 1:
        return jax.lax.all_gather(x_loc, axes, axis=axis, tiled=True)
    ax = axes[0]
    idx = jax.lax.axis_index(ax)
    m_loc = x_loc.shape[axis]
    # rotate "forward": shard i hands its buffer to shard (i+1) % world, so
    # after hop h the buffer holds the block of shard (idx - h - 1) % world
    perm = [(i, (i + 1) % world) for i in range(world)]
    out_shape = list(x_loc.shape)
    out_shape[axis] = world * m_loc
    out = jnp.zeros(tuple(out_shape), x_loc.dtype)
    out = jax.lax.dynamic_update_slice_in_dim(out, x_loc, idx * m_loc, axis)
    buf = x_loc
    for hop in range(world - 1):
        buf = jax.lax.ppermute(buf, ax, perm)
        src = jnp.mod(idx - hop - 1, world)
        out = jax.lax.dynamic_update_slice_in_dim(out, buf, src * m_loc, axis)
    return out


def batch_shardings(mesh: Mesh, batch, plan: MeshPlan, *, round_dims=True):
    """Round batches (tau, M, B, ...): M over worker axes. Per-step DDP
    batches (M, B, ...): M over worker axes at dim 0."""
    wdim = 1 if round_dims else 0
    w = plan.worker_axes if len(plan.worker_axes) > 1 else plan.worker_axes[0]

    def one(path, leaf):
        spec = [None] * np.ndim(leaf)
        if np.ndim(leaf) > wdim:
            spec[wdim] = w
        if plan.fsdp_axes and np.ndim(leaf) > wdim + 1:
            spec[wdim + 1] = (plan.fsdp_axes if len(plan.fsdp_axes) > 1
                              else plan.fsdp_axes[0])
        return NamedSharding(mesh, P(*spec))
    return jax.tree_util.tree_map_with_path(one, batch)


def serve_shardings(mesh: Mesh, tree, plan: MeshPlan, *, batch: int,
                    data_ok: bool):
    """Inference tensors. Per leaf:
      * the batch dim (detected by size == ``batch``) shards over the data
        axes when divisible;
      * for KV caches (k/v leaves, layout (..., B, buf, nkv, hd)) the model
        axis goes on nkv when divisible, else hd; with batch=1 (long_500k)
        the buf dim shards over data instead — context-parallel decode;
      * other state leaves shard their last model-divisible dim over model
        (mLSTM matrix memories etc.), everything else replicates.
    """
    data_axes = plan.worker_axes + plan.fsdp_axes
    d_size = _axes_size(mesh, data_axes)
    m_size = _axes_size(mesh, plan.model_axes)

    def one(path, leaf):
        names = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        name = names[-1] if names else ""
        shape = np.shape(leaf)
        nd = len(shape)
        spec = [None] * nd
        if name == "pos" or nd == 0:
            return NamedSharding(mesh, P(*spec))
        is_int = np.issubdtype(np.asarray(leaf).dtype
                               if not hasattr(leaf, "dtype") else leaf.dtype,
                               np.integer)
        # batch dim: first dim whose size == batch
        b_dim = next((i for i, s in enumerate(shape) if s == batch), None)
        if data_ok and b_dim is not None and batch % d_size == 0:
            spec[b_dim] = _axes_entry(data_axes)
            b_used = True
        else:
            b_used = False
        if name in ("k", "v") and nd >= 4:
            if not b_used and shape[-3] % d_size == 0:
                spec[-3] = _axes_entry(data_axes)      # context parallel
            if shape[-2] % m_size == 0:
                spec[-2] = _axes_entry(plan.model_axes)
            elif shape[-1] % m_size == 0:
                spec[-1] = _axes_entry(plan.model_axes)
        elif is_int:
            pass  # token/int inputs: batch sharding only
        else:
            # generic state: last model-divisible, non-batch dim
            for d in range(nd - 1, -1, -1):
                if spec[d] is None and d != b_dim and shape[d] % m_size == 0 \
                        and shape[d] >= m_size:
                    spec[d] = _axes_entry(plan.model_axes)
                    break
        return NamedSharding(mesh, P(*spec))
    return jax.tree_util.tree_map_with_path(one, tree)


def replicated(mesh: Mesh, tree):
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)
