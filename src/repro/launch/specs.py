"""ShapeDtypeStruct stand-ins for every model input — the dry-run's inputs.

``input_specs(cfg, shape, plan, mode)`` returns abstract specs (no device
allocation) for the jitted step of each workload kind:
  train  -> fused DPPF round batch (tau, M, B_local, S) [+ modality stubs]
  ddp    -> per-step batch (M, B_local, S)
  prefill-> (B, S) prompt batch
  decode -> (token, index, states) with a KV cache of seq_len (or the
            sliding-window ring buffer for the long_500k serving variant)

Modality frontends are STUBS by assignment: specs provide the frame/patch
embeddings directly (DESIGN.md).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import ShapeDtypeStruct as SDS

from repro.configs.base import InputShape, MeshPlan, ModelConfig
from repro.models import build_model

TOK = jnp.int32


def serve_window_for(cfg: ModelConfig, shape: InputShape) -> int:
    """Sub-quadratic policy for long_500k (DESIGN.md): recurrent archs keep
    their native O(1)/full-cache path; dense archs serve with a sliding
    window (gemma2 uses its native 4096)."""
    if shape.name != "long_500k":
        return 0
    if cfg.is_recurrent:
        return 0
    return cfg.sliding_window or 8192


def buf_len_for(cfg: ModelConfig, shape: InputShape) -> int:
    w = serve_window_for(cfg, shape)
    if w:
        return w
    # decoder-only prefix archs (vlm/audio stubs) cache prefix + tokens
    extra = cfg.n_prefix if not cfg.n_enc_layers else 0
    return shape.seq_len + extra


def _modality_specs(cfg: ModelConfig, lead: tuple):
    out = {}
    if cfg.n_enc_layers:
        out["enc"] = SDS(lead + (cfg.n_prefix, cfg.d_model), jnp.float32)
    elif cfg.n_prefix:
        out["prefix"] = SDS(lead + (cfg.n_prefix, cfg.d_model), jnp.float32)
    return out


def train_batch_specs(cfg: ModelConfig, shape: InputShape, n_workers: int,
                      tau: int, *, per_step=False):
    if shape.global_batch % n_workers:
        # ValueError, not assert: user-facing dry-run path, -O safe
        raise ValueError(
            f"{shape.name}: global batch {shape.global_batch} not divisible "
            f"by {n_workers} workers")
    b_local = shape.global_batch // n_workers
    lead = (n_workers, b_local) if per_step else (tau, n_workers, b_local)
    specs = {
        "tokens": SDS(lead + (shape.seq_len,), TOK),
        "labels": SDS(lead + (shape.seq_len,), TOK),
    }
    specs.update(_modality_specs(cfg, lead))
    return specs


def prefill_batch_specs(cfg: ModelConfig, shape: InputShape):
    lead = (shape.global_batch,)
    specs = {"tokens": SDS(lead + (shape.seq_len,), TOK)}
    specs.update(_modality_specs(cfg, lead))
    return specs


def param_specs(cfg: ModelConfig):
    model = build_model(cfg)
    return jax.eval_shape(model.init, jax.random.PRNGKey(0))


def decode_state_specs(cfg: ModelConfig, shape: InputShape):
    """Abstract KV-cache/state specs via eval_shape of a prefill that fills
    the buffer — no allocation ever happens."""
    model = build_model(cfg)
    buf = buf_len_for(cfg, shape)
    window = serve_window_for(cfg, shape)
    params = param_specs(cfg)
    # a dummy short prompt is enough to materialize the state STRUCTURE;
    # the buffer length is what the dry-run cares about.
    batch = {"tokens": SDS((shape.global_batch, 1), TOK)}
    batch.update(_modality_specs(cfg, (shape.global_batch,)))
    if "prefix" in batch:  # decode states do not include the prefix
        del batch["prefix"]

    def fn(p, b):
        return model.prefill(p, b, buf_len=buf, window=window)[1]

    return jax.eval_shape(fn, params, batch)


def decode_step_specs(cfg: ModelConfig, shape: InputShape):
    token = SDS((shape.global_batch, 1), TOK)
    index = SDS((), TOK)
    states = decode_state_specs(cfg, shape)
    return token, index, states


def input_specs(cfg: ModelConfig, shape: InputShape, plan: MeshPlan,
                mode: str, n_workers: int, tau: int = 4):
    if mode == "train":
        return train_batch_specs(cfg, shape, n_workers, tau)
    if mode == "ddp":
        return train_batch_specs(cfg, shape, n_workers, tau, per_step=True)
    if mode == "prefill":
        return prefill_batch_specs(cfg, shape)
    if mode == "decode":
        return decode_step_specs(cfg, shape)
    raise ValueError(mode)
