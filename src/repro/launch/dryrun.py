import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# The two lines above MUST run before any jax import (device count locks at
# first backend init). Everything else follows.

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh)
combination on the production mesh, print memory/cost analysis, parse the
collective schedule, and emit a JSON record per combo for §Roofline.

Usage:
  python -m repro.launch.dryrun --arch gemma2-2b --shape train_4k \
      --mesh single --out results/dryrun
  python -m repro.launch.dryrun --all --mesh both
Plans:
  baseline  worker=data axis (M=16/32), TP=16  (the paper-faithful mapping)
  hier      hierarchical DPPF: M=4 workers x fsdp=4 x TP=16 (memory hillclimb)
  seqshard  baseline + sequence-sharded activations (hillclimb)

The hand-picked hillclimb plan SWEEPS (the committed ``opt``/``seqshard``/
``hier_opt`` record files) are superseded by ``launch/train.py
--autotune`` (DESIGN.md §Autotune), which probe-searches the
batch/tau/overlap_chunks operating point on real rounds and commits a
replayable TunePlan instead; the plan names above remain runnable for
one-off roofline comparisons.
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, DPPFConfig, INPUT_SHAPES, MeshPlan
from repro.launch import mesh as mesh_lib
from repro.launch import roofline as rf
from repro.launch import specs as specs_lib
from repro.models import build_model
from repro.optim import make_optimizer
from repro.serving import make_serve_step
from repro.train import (RoundClock, init_train_state, make_round_step,
                         make_ddp_step)
from repro.train.trainer import TrainState

# the LR/step budget every train-mode dry-run compiles against (and the
# clock the report's round-plan table renders)
TRAIN_LR, TRAIN_STEPS = 0.1, 1000


def _sds(tree_specs, tree_shardings):
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        tree_specs, tree_shardings)


def _plan_for(name: str, multi_pod: bool) -> MeshPlan:
    worker = ("pod", "data") if multi_pod else ("data",)
    if name in ("baseline", "opt"):
        return MeshPlan(worker_axes=worker)
    if name in ("hier", "hier_opt"):
        # M=4(8) workers, fsdp within worker; mesh axes renamed by
        # make_hierarchical_mesh to (data, fsdp, model)
        return MeshPlan(worker_axes=("data",), fsdp_axes=("fsdp",))
    if name == "seqshard":
        return MeshPlan(worker_axes=worker, seq_shard_acts=True)
    raise ValueError(name)


def _cfg_for(arch: str, plan_name: str, train: bool):
    """'opt' = beyond-paper optimized model config (§Perf): chunked mLSTM +
    bf16 MoE combine (+ bf16 momentum, applied in build_train)."""
    cfg = ARCHS[arch]
    if train:
        cfg = dataclasses.replace(cfg, remat=True)
    if plan_name in ("opt", "hier_opt"):
        cfg = dataclasses.replace(cfg, xlstm_chunk=256,
                                  moe_combine_dtype="bfloat16")
    if plan_name == "seqshard":
        cfg = dataclasses.replace(cfg, seq_shard_acts=True)
    return cfg


def _mesh_for(plan_name: str, multi_pod: bool):
    if plan_name in ("hier", "hier_opt"):
        return mesh_lib.make_hierarchical_mesh(8 if multi_pod else 4, 4, 16,
                                               multi_pod=multi_pod)
    return mesh_lib.make_production_mesh(multi_pod=multi_pod)


def _n_workers(mesh, plan):
    return int(jnp.prod(jnp.asarray([mesh.shape[a] for a in plan.worker_axes])))


# ---------------------------------------------------------------------------
# Builders per workload kind
# ---------------------------------------------------------------------------

def build_train(arch, shape, mesh, plan, *, ddp=False, tau=4,
                plan_name="baseline", overlap="none", staleness=1):
    cfg = _cfg_for(arch, plan_name, train=True)
    model = build_model(cfg)
    # the overlapped round needs the flat engine (the stale snapshot is a
    # flat (R, n) buffer — or a (k, R, n) ring under staleness_k); exact
    # rounds keep the tree engine the committed records were built with
    dcfg = DPPFConfig(tau=tau, consensus="ddp" if ddp else "simple_avg",
                      engine="flat" if overlap != "none" else "tree",
                      overlap=overlap, staleness=staleness)
    opt = make_optimizer(
        "sgd", momentum=0.9, weight_decay=1e-3,
        state_dtype="bfloat16" if plan_name in ("opt", "hier_opt")
        else "float32")
    M = _n_workers(mesh, plan)

    if ddp:
        step = make_ddp_step(model.loss, opt, base_lr=TRAIN_LR,
                             total_steps=TRAIN_STEPS)

        def _ddp_state(k):
            p = model.init(k)
            return TrainState(params=p, opt=opt.init(p), cstate={},
                              t=jnp.zeros((), jnp.int32))

        state_specs = jax.eval_shape(_ddp_state, jax.random.PRNGKey(0))
        p_sh = mesh_lib.param_shardings(mesh, state_specs.params, plan,
                                        stacked=False)
        st_sh = dataclasses.replace(
            state_specs,
            params=p_sh, opt={"mu": p_sh},
            cstate={}, t=NamedSharding(mesh, P()))
        batch_specs = specs_lib.input_specs(cfg, shape, plan, "ddp", M, tau)
        b_sh = mesh_lib.batch_shardings(mesh, batch_specs, plan,
                                        round_dims=False)
    else:
        step = make_round_step(model.loss, opt, dcfg, base_lr=TRAIN_LR,
                               total_steps=TRAIN_STEPS)
        state_specs = jax.eval_shape(
            lambda k: init_train_state(model.init, opt, dcfg, M, k),
            jax.random.PRNGKey(0))
        if state_specs.engine is not None:
            # flat engine (overlap runs): the persistent (R, n) view under
            # the flat-view storage rule
            p_sh = mesh_lib.flat_view_sharding(
                mesh, state_specs.params.shape, plan)
        else:
            p_sh = mesh_lib.param_shardings(mesh, state_specs.params, plan,
                                            stacked=True)
        snap_sh = None
        if state_specs.snap is not None:
            # overlap snapshot: a second (R, n) flat buffer — or the
            # (k, R, n) staleness ring — placed under the flat-view
            # storage rule (flat_view_sharding is ring-aware); the
            # per-round scalars replicated
            snap_sh = {k: NamedSharding(mesh, P())
                       for k in state_specs.snap if k != "x"}
            snap_sh["x"] = mesh_lib.flat_view_sharding(
                mesh, state_specs.snap["x"].shape, plan)
        st_sh = dataclasses.replace(
            state_specs,
            params=p_sh, opt={"mu": p_sh},
            cstate={}, t=NamedSharding(mesh, P()), snap=snap_sh,
            round=NamedSharding(mesh, P()))   # clock position: replicated
        batch_specs = specs_lib.input_specs(cfg, shape, plan, "train", M, tau)
        b_sh = mesh_lib.batch_shardings(mesh, batch_specs, plan,
                                        round_dims=True)

    args = (_sds(state_specs, st_sh), _sds(batch_specs, b_sh))
    return jax.jit(step), args, cfg


def build_prefill(arch, shape, mesh, plan, plan_name="baseline"):
    cfg = _cfg_for(arch, plan_name, train=False)
    model = build_model(cfg)
    params_specs = specs_lib.param_specs(cfg)
    p_sh = mesh_lib.param_shardings(mesh, params_specs, plan, stacked=False)
    batch_specs = specs_lib.prefill_batch_specs(cfg, shape)
    data_ok = shape.global_batch % mesh.shape[plan.worker_axes[0]] == 0
    b_sh = mesh_lib.serve_shardings(mesh, batch_specs, plan,
                                    batch=shape.global_batch, data_ok=data_ok)
    buf = specs_lib.buf_len_for(cfg, shape)

    def prefill(params, batch):
        return model.prefill(params, batch, buf_len=buf)

    args = (_sds(params_specs, p_sh), _sds(batch_specs, b_sh))
    return jax.jit(prefill), args, cfg


def build_decode(arch, shape, mesh, plan, plan_name="baseline"):
    cfg = _cfg_for(arch, plan_name, train=False)
    model = build_model(cfg)
    window = specs_lib.serve_window_for(cfg, shape)
    serve_step = make_serve_step(model, window=window)
    params_specs = specs_lib.param_specs(cfg)
    p_sh = mesh_lib.param_shardings(mesh, params_specs, plan, stacked=False)
    token_s, index_s, state_specs = specs_lib.decode_step_specs(cfg, shape)
    data_dim = mesh.shape[plan.worker_axes[0]]
    data_ok = shape.global_batch % data_dim == 0 and shape.global_batch >= data_dim
    st_sh = mesh_lib.serve_shardings(mesh, state_specs, plan,
                                     batch=shape.global_batch, data_ok=data_ok)
    tok_sh = NamedSharding(mesh, P(plan.worker_axes[0] if data_ok else None,
                                   None))
    args = (_sds(params_specs, p_sh), _sds(state_specs, st_sh),
            jax.ShapeDtypeStruct(token_s.shape, token_s.dtype, sharding=tok_sh),
            jax.ShapeDtypeStruct(index_s.shape, index_s.dtype,
                                 sharding=NamedSharding(mesh, P())))
    return jax.jit(serve_step), args, cfg


# ---------------------------------------------------------------------------
# Runner
# ---------------------------------------------------------------------------

def run_one(arch, shape_name, mesh_kind, *, mode=None, plan_name="baseline",
            tau=4, out_dir="results/dryrun", overlap="none", staleness=1):
    shape = INPUT_SHAPES[shape_name]
    multi_pod = mesh_kind == "multi"
    mesh = _mesh_for(plan_name, multi_pod)
    plan = _plan_for(plan_name, multi_pod)
    mode = mode or ("train" if shape.kind == "train" else shape.kind)
    if overlap != "none" and mode not in ("train",):
        raise ValueError("--overlap applies to train-mode dry-runs only")

    t0 = time.time()
    if mode in ("train", "ddp"):
        fn, args, cfg = build_train(arch, shape, mesh, plan,
                                    ddp=(mode == "ddp"), tau=tau,
                                    plan_name=plan_name, overlap=overlap,
                                    staleness=staleness)
    elif mode == "prefill":
        fn, args, cfg = build_prefill(arch, shape, mesh, plan, plan_name)
    else:
        fn, args, cfg = build_decode(arch, shape, mesh, plan, plan_name)

    with mesh:
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = {}
    try:
        ma = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            v = getattr(ma, k, None)
            if v is not None:
                mem[k] = int(v)
    except Exception as e:  # CPU backend may not support it
        mem["error"] = str(e)

    cost = {}
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        for k in ("flops", "bytes accessed", "transcendentals"):
            if k in ca:
                cost[k.replace(" ", "_")] = float(ca[k])
    except Exception as e:
        cost["error"] = str(e)

    hlo = compiled.as_text()
    n_model = mesh.shape.get("model", 1)
    ana = rf.analyze_hlo(hlo, n_model=n_model)  # trip-count-corrected
    coll = ana["collectives"]
    scale = 1.0 / tau if mode == "train" else 1.0
    terms = rf.roofline(ana["flops"], ana["bytes"], coll,
                        seconds_scale=scale)
    mf = rf.model_flops(cfg, shape, mode=mode)
    chips = int(mesh.devices.size)

    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind, "mode": mode,
        "plan": plan_name, "chips": chips, "tau": tau, "overlap": overlap,
        "n_workers": _n_workers(mesh, plan) if mode in ("train", "ddp") else None,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": mem, "cost_raw_xla": cost,
        "hlo_flops_per_dev": ana["flops"], "hlo_bytes_per_dev": ana["bytes"],
        "collectives": coll,
        "collective_axis_bytes": ana["collective_axis_bytes"],
        "roofline": {k: v for k, v in terms.items()},
        "model_flops_total": mf,
        "model_flops_per_chip_step": mf / chips,
        "useful_flop_ratio": (mf / chips) / max(ana["flops"] * scale, 1.0),
        "param_count": cfg.param_count(),
        "active_param_count": cfg.active_param_count(),
    }
    if mode == "train":
        # modeled exact/staleness1/doublebuf/staleness-k round time (incl.
        # the ppermute-ring term) vs the comm/compute crossover
        # (launch.roofline.overlap_model) — rendered by roofline_report.py
        # and the EXPERIMENTS.md §Overlap-roofline table
        rec["overlap_model"] = rf.overlap_model(
            terms, ana["collective_axis_bytes"],
            R=_n_workers(mesh, plan), seconds_scale=scale)
        rec["staleness"] = staleness if overlap == "staleness_k" else None
    os.makedirs(out_dir, exist_ok=True)
    tag = f"{arch}_{shape_name}_{mesh_kind}_{mode}_{plan_name}"
    if overlap == "staleness_k":
        tag += f"_{overlap}{staleness}"
    elif overlap != "none":
        tag += f"_{overlap}"
    with open(os.path.join(out_dir, tag + ".json"), "w") as f:
        json.dump(rec, f, indent=1)
    print(f"[OK] {tag}: compile={t_compile:.1f}s "
          f"flops/dev={cost.get('flops', 0):.3e} "
          f"coll={sum(v['bytes'] for v in coll.values()):.3e}B "
          f"bottleneck={terms['bottleneck']}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--mode", default=None,
                    choices=[None, "train", "ddp", "prefill", "decode"])
    ap.add_argument("--plan", default="baseline",
                    choices=["baseline", "hier", "seqshard", "opt", "hier_opt"])
    ap.add_argument("--tau", type=int, default=4)
    ap.add_argument("--overlap", default="none",
                    choices=["none", "staleness1", "doublebuf",
                             "staleness_k"],
                    help="compile the overlapped round (flat engine) "
                         "instead of the exact tree round — train-mode "
                         "combos only; every train record additionally "
                         "carries the modeled exact/staleness1/doublebuf/"
                         "staleness-k + ring comparison (overlap_model)")
    ap.add_argument("--staleness", type=int, default=1,
                    help="staleness_k: snapshot-ring depth k")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    # round-plan report: the clock every train-mode combo compiles against
    # (DESIGN.md §Round-clock) — tau from the CLI, the dry-run LR budget
    print(RoundClock(total_steps=TRAIN_STEPS, tau=args.tau,
                     base_lr=TRAIN_LR, overlap=args.overlap,
                     staleness=args.staleness).plan_table())
    print()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    archs = sorted(ARCHS) if args.all or not args.arch else [args.arch]
    shapes = (list(INPUT_SHAPES) if args.all or not args.shape
              else [args.shape])
    if args.overlap == "staleness_k":
        suffix = f"_{args.overlap}{args.staleness}"
    elif args.overlap != "none":
        suffix = f"_{args.overlap}"
    else:
        suffix = ""

    failures = []
    for mk in meshes:
        for a in archs:
            for s in shapes:
                tag = f"{a}_{s}_{mk}"
                mode = (args.mode or
                        ("train" if INPUT_SHAPES[s].kind == "train"
                         else INPUT_SHAPES[s].kind))
                if args.overlap != "none" and mode != "train":
                    print(f"[skip] {tag} (--overlap is train-only)")
                    continue
                path = os.path.join(
                    args.out, f"{a}_{s}_{mk}_{mode}_{args.plan}"
                    f"{suffix}.json")
                if os.path.exists(path):
                    print(f"[skip] {tag} (cached)")
                    continue
                try:
                    run_one(a, s, mk, mode=args.mode, plan_name=args.plan,
                            tau=args.tau, out_dir=args.out,
                            overlap=args.overlap, staleness=args.staleness)
                except Exception as e:
                    failures.append((tag, repr(e)))
                    print(f"[FAIL] {tag}: {e}")
                    traceback.print_exc()
    if failures:
        print(f"{len(failures)} failures:")
        for t, e in failures:
            print(" ", t, e)
        raise SystemExit(1)
    print("all dry-runs passed")


if __name__ == "__main__":
    main()
