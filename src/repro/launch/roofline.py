"""Roofline-term derivation from compiled dry-run artifacts.

Hardware model (TPU v5e target):
  peak 197 TFLOP/s bf16 per chip; 819 GB/s HBM; ~50 GB/s/link ICI.

XLA's ``cost_analysis`` counts while-loop (scan) bodies ONCE, which
undercounts layer-stacked models by ~L*tau (verified: gemma2 raw HLO flops
= model flops / ~14). We therefore run our own static analysis over the
post-partitioning HLO: walk the computation call graph, multiply every
op by the product of enclosing ``known_trip_count``s, and accumulate
  * dot FLOPs         (2 * numel(result) * contracted-dim product)
  * fusion-boundary bytes (operands + results of top-level ops — an HBM
    traffic model where each fusion is one pass over its buffers)
  * collective payload bytes per kind.
All numbers are PER DEVICE (the compiled module is the SPMD-partitioned
per-device program; verified against a hand-sharded matmul).
"""
from __future__ import annotations

import re
from collections import defaultdict

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z]+[0-9a-z]*)\[([0-9,]*)\]")
_SKIP_BYTES_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                   "bitcast", "after-all", "partition-id", "replica-id",
                   "iota"}


def _type_info(type_str):
    """(bytes, [shapes]) for a (possibly tuple) HLO type string."""
    total, shapes = 0, []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        shape = [int(d) for d in dims.split(",")] if dims else []
        n = 1
        for d in shape:
            n *= d
        total += n * _DTYPE_BYTES[dt]
        shapes.append((dt, shape))
    return total, shapes


_OP_RE = re.compile(r"^\s*(%[\w\.\-]+)\s*=\s*(.*)$")
_GROUP_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
# IotaReplicaGroupList: [G,S]<=[d0,d1,..]T(p0,p1,..) — groups formed by
# arange(prod(d)).reshape(d).transpose(p).reshape(G, S)
_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?")
_PAIR_RE = re.compile(r"source_target_pairs=\{\{(\d+),(\d+)\}")
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s+\(")
_ATTR_COMP_RE = re.compile(
    r"(?:body|condition|to_apply|calls)=\{?%([\w\.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count\":\{\"n\":\"(\d+)\"')
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPERAND_RE = re.compile(r"%[\w\.\-]+")


class HloOp:
    __slots__ = ("name", "op", "result_bytes", "result_shapes", "operands",
                 "callees", "trip", "contract_dims", "axis", "line")

    def __init__(self, name, op, result_bytes, result_shapes, operands,
                 callees, trip, contract_dims, axis, line):
        self.name, self.op = name, op
        self.result_bytes, self.result_shapes = result_bytes, result_shapes
        self.operands, self.callees = operands, callees
        self.trip, self.contract_dims = trip, contract_dims
        self.axis = axis
        self.line = line


def _classify_axis(line, n_model):
    """Which mesh axis a collective spans: 'model' (ids within one TP row),
    'data' (worker/pod axes; ids congruent mod n_model), or 'mixed'.
    Device order is row-major (..., data, model)."""
    ids = None
    m = _GROUP_RE.search(line)
    if m:
        ids = [int(x) for x in m.group(1).split(",")]
    if ids is None:
        m = _IOTA_RE.search(line)
        if m:
            import numpy as _np
            g, s = int(m.group(1)), int(m.group(2))
            dims = [int(x) for x in m.group(3).split(",")]
            arr = _np.arange(int(_np.prod(dims))).reshape(dims)
            if m.group(4):
                perm = [int(x) for x in m.group(4).split(",")]
                arr = arr.transpose(perm)
            ids = arr.reshape(g, s)[0].tolist()
    if ids is None:
        p = _PAIR_RE.search(line)
        if p:
            ids = [int(p.group(1)), int(p.group(2))]
    if not ids or len(ids) < 2:
        return "unknown"
    if all(i // n_model == ids[0] // n_model for i in ids):
        return "model"
    if all(i % n_model == ids[0] % n_model for i in ids):
        return "data"
    return "mixed"


def _parse_op(line, n_model=16):
    m = _OP_RE.match(line)
    if not m or "=" not in line:
        return None
    name, rest = m.group(1), m.group(2)
    # result type: leading tuple-or-scalar type, then "op-name(".
    if rest.startswith("("):
        depth, i = 0, 0
        for i, ch in enumerate(rest):
            depth += ch == "("
            depth -= ch == ")"
            if depth == 0:
                break
        type_str, tail = rest[:i + 1], rest[i + 1:].strip()
    else:
        sp = rest.find(" ")
        type_str, tail = rest[:sp], rest[sp + 1:].strip()
    om = re.match(r"([\w\-\.]+)\((.*)$", tail)
    if not om:
        return None
    op = om.group(1)
    body = om.group(2)
    # strip metadata / backend_config payloads before scanning attributes
    attr_part = body
    for cut in ("metadata={", "backend_config="):
        j = attr_part.find(cut)
        if j >= 0:
            attr_part = attr_part[:j]
    operand_part = attr_part.split(")", 1)[0]
    operands = _OPERAND_RE.findall(operand_part)
    callees = _ATTR_COMP_RE.findall(attr_part)
    trip = None
    tm = _TRIP_RE.search(body)
    if tm:
        trip = int(tm.group(1))
    cd = None
    cm = _CONTRACT_RE.search(attr_part)
    if cm:
        cd = [int(x) for x in cm.group(1).split(",") if x]
    rb, rs = _type_info(type_str)
    axis = None
    base = op.replace("-start", "")
    if base in COLLECTIVES:
        axis = _classify_axis(body, n_model)
    return HloOp(name, op, rb, rs, operands, callees, trip, cd, axis, line)


def parse_hlo(text, n_model=16):
    """-> (computations: {name: [HloOp]}, entry name)"""
    comps, cur, cur_name = {}, None, None
    entry = None
    for line in text.splitlines():
        if line.startswith("}"):
            cur = None
            continue
        cm = _COMP_RE.match(line)
        if cm and line.rstrip().endswith("{"):
            cur_name = cm.group(2)
            cur = comps.setdefault(cur_name, [])
            if cm.group(1):
                entry = cur_name
            continue
        if cur is None:
            continue
        op = _parse_op(line, n_model)
        if op:
            cur.append(op)
    return comps, entry


def _multipliers(comps, entry):
    """Computation -> dynamic execution count (trip-count products)."""
    mult = defaultdict(float)
    mult[entry] = 1.0
    order = [entry]
    seen = {entry}
    # propagate breadth-first; the call graph is a DAG in compiled HLO
    i = 0
    while i < len(order):
        c = order[i]
        i += 1
        for op in comps.get(c, []):
            trip = op.trip if (op.op == "while" and op.trip) else 1
            for callee in op.callees:
                mult[callee] += mult[c] * trip
                if callee not in seen:
                    seen.add(callee)
                    order.append(callee)
    return mult


def _fusion_targets(comps):
    targets = set()
    for ops in comps.values():
        for op in ops:
            if op.op in ("fusion",):
                targets.update(op.callees)
            if op.op in ("reduce", "reduce-window", "scatter", "sort",
                         "map", "select-and-scatter"):
                targets.update(op.callees)  # scalar apply fns
    return targets


def analyze_hlo(text, n_model=16):
    comps, entry = parse_hlo(text, n_model)
    mult = _multipliers(comps, entry)
    fusion_targets = _fusion_targets(comps)

    # symbol tables for operand shape lookup (per computation)
    shapes = {}
    for cname, ops in comps.items():
        for op in ops:
            shapes[(cname, op.name)] = op.result_shapes

    flops = 0.0
    bytes_acc = 0.0
    coll = {k: {"bytes": 0.0, "count": 0.0} for k in COLLECTIVES}
    axis_bytes = {"model": 0.0, "data": 0.0, "mixed": 0.0, "unknown": 0.0}

    for cname, ops in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        is_fusion_body = cname in fusion_targets
        for op in ops:
            base = op.op.replace("-start", "").replace("-done", "")
            if base in COLLECTIVES and not op.op.endswith("-done"):
                coll[base]["bytes"] += op.result_bytes * m
                coll[base]["count"] += m
                axis_bytes[op.axis or "unknown"] += op.result_bytes * m
            if op.op == "dot":
                k = 1
                if op.contract_dims and op.operands:
                    lhs = shapes.get((cname, op.operands[0]))
                    if lhs and lhs[0][1]:
                        for dim in op.contract_dims:
                            if dim < len(lhs[0][1]):
                                k *= lhs[0][1][dim]
                numel = 0
                for _, shp in op.result_shapes:
                    n = 1
                    for d in shp:
                        n *= d
                    numel += n
                flops += 2.0 * numel * k * m
            if not is_fusion_body and op.op not in _SKIP_BYTES_OPS:
                b = op.result_bytes
                for o in op.operands:
                    info = shapes.get((cname, o))
                    if info:
                        for dt, shp in info:
                            n = 1
                            for d in shp:
                                n *= d
                            b += n * _DTYPE_BYTES.get(dt, 0)
                bytes_acc += b * m
    return {"flops": flops, "bytes": bytes_acc, "collectives": coll,
            "collective_axis_bytes": axis_bytes}


def roofline(flops, bytes_accessed, coll, *, seconds_scale=1.0):
    """Three roofline terms in seconds (optionally scaled, e.g. 1/tau to
    amortize a fused round over its local steps)."""
    total_coll = sum(v["bytes"] for v in coll.values())
    terms = {
        "compute_s": flops / PEAK_FLOPS * seconds_scale,
        "memory_s": bytes_accessed / HBM_BW * seconds_scale,
        "collective_s": total_coll / ICI_BW * seconds_scale,
    }
    terms["bottleneck"] = max(
        [k for k in terms if k.endswith("_s")], key=lambda k: terms[k])
    return terms


def overlap_model(terms, axis_bytes, *, R=8, seconds_scale=1.0):
    """Modeled round time per overlap mode against the comm/compute
    crossover (DESIGN.md §Overlap).

    The consensus traffic is the worker-axis ("data") collective payload:
    the worker-row all-gather (O(R x n_local) bytes) plus the (R, R)
    partial-Gram psum. Tensor-parallel ("model"-axis) collectives fire
    INSIDE the local steps and are serial with compute in every mode.
    Per round, with ``work = compute_s + memory_s`` the overlappable
    window:

    * ``exact``      — all consensus traffic lands serially at the
      boundary:          ``work + model_s + data_s``
    * ``staleness1`` — the stale (R, R) psum hides behind the scan, but
      the FRESH worker-row gather (the delta is applied to the gathered
      view) stays on the boundary critical path:
                         ``work + model_s + max(data_s - psum_s, 0)
                          + max(psum_s - work, 0)``
    * ``doublebuf``  — gather AND psum belong to the round-(k-1) snapshot
      and dispatch chunk-by-chunk under the scan; the boundary is local:
                         ``work + model_s + max(data_s - work, 0)``
    * ``staleness_k`` — the doublebuf recursion generalized to a k-deep
      snapshot ring whose worker-row gather runs as a ppermute ring
      (R-1 hops of one row each instead of one bisection-limited
      all-gather). Each hop moves ``gather_bytes / R`` and the ring's
      wire time is ``ring_s = data_s * (R-1)/R``; with k rounds of
      compute to hide it behind:
                         ``work + model_s + max(ring_s - k*work, 0)``

    ``crossover = data_s / work``: below 1 the double-buffered round hides
    its entire consensus cost; above 1 the round is communication-bound
    and hiding saturates at the compute window — which staleness-k widens
    k-fold. ``psum_s`` uses the engine's (R, R) fp32 payload.

    Returned ring fields: ``gather_bytes`` (the worker-axis consensus
    payload), ``ring_bytes_per_hop = gather_bytes / R`` (structurally
    <= gather_bytes), ``ring_hops = R - 1``, ``ring_s``, and
    ``staleness_k_s`` — a ``{str(k): seconds}`` dict for k in {1, 2, 4}.
    By construction ``staleness_k_s[k] <= doublebuf_s <= staleness1_s <=
    exact_s`` (check_bench pins the ordering on the committed records).
    """
    work = terms["compute_s"] + terms["memory_s"]
    model_s = axis_bytes.get("model", 0.0) / ICI_BW * seconds_scale
    gather_bytes = (axis_bytes.get("data", 0.0)
                    + axis_bytes.get("mixed", 0.0)
                    + axis_bytes.get("unknown", 0.0))
    data_s = gather_bytes / ICI_BW * seconds_scale
    psum_s = min(R * R * 4 / ICI_BW * seconds_scale, data_s)
    ring_s = data_s * (R - 1) / max(R, 1)
    rows = {
        "exact_s": work + model_s + data_s,
        "staleness1_s": (work + model_s + max(data_s - psum_s, 0.0)
                         + max(psum_s - work, 0.0)),
        "doublebuf_s": work + model_s + max(data_s - work, 0.0),
        "gather_bytes": gather_bytes,
        "ring_bytes_per_hop": gather_bytes / max(R, 1),
        "ring_hops": R - 1,
        "ring_s": ring_s,
        "staleness_k_s": {str(k): work + model_s + max(ring_s - k * work,
                                                       0.0)
                          for k in (1, 2, 4)},
    }
    rows["crossover"] = data_s / work if work > 0 else float("inf")
    rows["overlap_gain"] = (rows["exact_s"] / rows["doublebuf_s"]
                            if rows["doublebuf_s"] > 0 else 1.0)
    return rows


def probe_round_model(*, work_s_per_step: float, tau: int,
                      gather_bytes: float, R: int = 8, mode: str = "none",
                      staleness: int = 1) -> float:
    """One overlap mode's modeled round seconds for an autotune probe
    (``train/autotune.py``): tau local steps of ``work_s_per_step``
    against a ``gather_bytes`` worker-axis consensus payload, routed
    through ``overlap_model`` so probes, the microbench's ``modeled_us``,
    and the committed roofline tables share ONE formula set. Pure
    arithmetic — structural for check_bench. ValueError on an unknown
    mode (user-facing via ``--overlap``)."""
    if mode not in ("none", "staleness1", "doublebuf", "staleness_k"):
        raise ValueError(f"unknown overlap mode {mode!r}")
    if tau < 1:
        raise ValueError(f"tau must be >= 1, got {tau}")
    if staleness < 1:
        raise ValueError(f"staleness must be >= 1, got {staleness}")
    rows = overlap_model(
        {"compute_s": work_s_per_step * tau, "memory_s": 0.0},
        {"data": float(gather_bytes)}, R=R)
    if mode == "none":
        return rows["exact_s"]
    if mode == "staleness1":
        return rows["staleness1_s"]
    if mode == "doublebuf":
        return rows["doublebuf_s"]
    by_k = rows["staleness_k_s"].get(str(staleness))
    if by_k is not None:
        return by_k
    work = work_s_per_step * tau
    return work + max(rows["ring_s"] - staleness * work, 0.0)


def reconcile_probes(pairs):
    """Model-vs-measured reconciliation for the autotune search:
    ``pairs`` yields (measured_us, modeled_us). Returns the median
    measured/modeled ratio as the calibration ``scale`` (a single
    positive scale never changes a per-sample-score argmin, so the
    chosen point stays a deterministic function of the feasibility
    frontier), plus the worst-case log residual AFTER calibration —
    how far any probe sits from the scaled model, the TunePlan's
    model-quality record. Empty/degenerate input -> identity scale."""
    import math as _math
    ratios = sorted(m / md for m, md in pairs if md > 0 and m > 0)
    if not ratios:
        return {"scale": 1.0, "max_abs_log_residual": 0.0, "n": 0}
    n = len(ratios)
    if n % 2:
        scale = ratios[n // 2]
    else:
        scale = 0.5 * (ratios[n // 2 - 1] + ratios[n // 2])
    worst = max(abs(_math.log(r / scale)) for r in ratios)
    return {"scale": scale, "max_abs_log_residual": worst, "n": n}


def model_flops(cfg, shape, *, mode: str) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE); decode D = batch
    tokens (1 new token per sequence). Global, all chips."""
    n = cfg.active_param_count()
    if mode in ("train", "ddp"):
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    tokens = shape.global_batch  # decode: one token per sequence
    return 2.0 * n * tokens


def serving_model(cfg, *, max_slots: int, chunk: int,
                  state_bytes_per_slot: float, dtype_bytes: int = 2):
    """Prefill-vs-decode roofline for the continuous-batching engine
    (DESIGN.md §Serving).

    Decode is the memory-bound regime: one token per active slot reads
    EVERY live parameter plus each slot's decode state (read + write), so
    arithmetic intensity grows with slot occupancy and the engine only
    turns compute-bound past ``crossover_slots``. A prefill chunk is the
    compute-bound regime: C tokens of one request against one slot's
    state. ``prefill_tokens_per_decode_step`` — how many chunked-prefill
    tokens cost the same as ONE full decode step — is the admission-
    packing guidance: below it, admitting mid-decode is (roofline-)free.

    ``state_bytes_per_slot`` must be MEASURED from a blank request state
    pytree (benchmarks/bench_serving.py does), not guessed from shapes.
    Pure arithmetic — structural for check_bench.
    """
    if max_slots < 1:
        raise ValueError(f"max_slots must be >= 1, got {max_slots}")
    if chunk < 1:
        raise ValueError(f"chunk must be >= 1, got {chunk}")
    n_act = cfg.active_param_count()
    param_bytes = cfg.param_count() * dtype_bytes

    dec_compute = 2.0 * n_act * max_slots / PEAK_FLOPS
    dec_memory = (param_bytes + 2.0 * max_slots * state_bytes_per_slot) / HBM_BW
    decode_s = max(dec_compute, dec_memory)

    pre_compute = 2.0 * n_act * chunk / PEAK_FLOPS
    pre_memory = (param_bytes + 2.0 * state_bytes_per_slot) / HBM_BW
    prefill_s = max(pre_compute, pre_memory)

    # slots needed before a decode step stops being a parameter stream
    denom = 2.0 * n_act / PEAK_FLOPS - 2.0 * state_bytes_per_slot / HBM_BW
    crossover = (param_bytes / HBM_BW) / denom if denom > 0 else float("inf")

    return {
        "params_bytes": float(param_bytes),
        "state_bytes_per_slot": float(state_bytes_per_slot),
        "decode_s": decode_s,
        "decode_bound": "compute" if dec_compute >= dec_memory else "memory",
        "decode_tok_s": max_slots / decode_s,
        "prefill_s": prefill_s,
        "prefill_bound": "compute" if pre_compute >= pre_memory else "memory",
        "prefill_tok_s": chunk / prefill_s,
        "crossover_slots": crossover,
        "prefill_tokens_per_decode_step": decode_s / (prefill_s / chunk),
    }


DISK_BW = 1.2e9  # checkpoint restore stream (NVMe-class sequential read)


def supervisor_model(*, rounds: int, tau: int, work_s_per_step: float,
                     gather_bytes: float, R: int = 8, staleness: int = 1,
                     degraded_rounds: int = 0, retried_rounds: int = 0,
                     restores: int = 0, restore_bytes: float = 0.0,
                     backoff_s: float = 0.0):
    """Fault-timeline accounting for the round supervisor
    (``train/supervisor.py``), priced with the same ``probe_round_model``
    formula set the autotuner and microbench use.

    A healthy staleness-k round costs ``round_s`` (tau local steps plus
    whatever ring-gather tail the k-deep carry could not hide). The
    supervisor's recovery actions then perturb the timeline three ways:

    * a DEGRADED round (below quorum, ``sync=0``) skips the consensus
      application, so its boundary never waits on the ring tail — it
      costs only the ``tau * work_s_per_step`` local window and SAVES
      ``round_s - local_s`` against the healthy price;
    * a RETRIED round (failed step, restored, replayed) re-executes in
      full — one extra ``round_s`` each, plus the restore's checkpoint
      read (``restore_bytes / DISK_BW`` per restore);
    * deterministic backoff sleeps add straight wall time (``backoff_s``
      totals them; CI runs on virtual time and passes 0).

    Returns fault-free vs faulted wall seconds and the net overhead
    fraction. Pure arithmetic — structural for check_bench; all guards
    ValueError (python -O)."""
    if rounds < 1:
        raise ValueError(f"rounds must be >= 1, got {rounds}")
    if not 0 <= degraded_rounds <= rounds:
        raise ValueError(
            f"degraded_rounds must be in [0, rounds], got "
            f"{degraded_rounds} of {rounds}")
    if retried_rounds < 0 or restores < 0:
        raise ValueError(
            f"retried_rounds ({retried_rounds}) and restores ({restores}) "
            "must be >= 0")
    if restore_bytes < 0 or backoff_s < 0:
        raise ValueError(
            f"restore_bytes ({restore_bytes}) and backoff_s ({backoff_s}) "
            "must be >= 0")
    round_s = probe_round_model(
        work_s_per_step=work_s_per_step, tau=tau,
        gather_bytes=gather_bytes, R=R, mode="staleness_k",
        staleness=staleness)
    local_s = work_s_per_step * tau
    fault_free_s = rounds * round_s
    degraded_saved_s = degraded_rounds * (round_s - local_s)
    restore_s = restores * (float(restore_bytes) / DISK_BW)
    retry_s = retried_rounds * round_s
    faulted_s = (fault_free_s - degraded_saved_s + retry_s + restore_s
                 + float(backoff_s))
    out = {
        "round_s": round_s,
        "local_s": local_s,
        "fault_free_s": fault_free_s,
        "degraded_saved_s": degraded_saved_s,
        "retry_s": retry_s,
        "restore_s": restore_s,
        "backoff_s": float(backoff_s),
        "faulted_s": faulted_s,
        "overhead_frac": (faulted_s / fault_free_s - 1.0
                          if fault_free_s > 0 else 0.0),
    }
    return {k: round(v, 6) for k, v in out.items()}


# retained for backward compatibility with simple parsing callers
def collective_bytes(hlo_text: str):
    return analyze_hlo(hlo_text)["collectives"]
