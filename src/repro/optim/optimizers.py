"""Optimizers: SGD(+momentum, weight decay), AdamW, and the SAM gradient
transform (Foret'21) used by the DDP-SAM / DPPF-SAM comparisons (Table 4).

Pure-functional: ``opt.init(params) -> state``;
``opt.step(params, grads, state, lr) -> (params, state)``.
States are pytrees, so they stack/vmap across DPPF workers transparently.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable[[Any], Any]
    step: Callable[..., Any]


def _tmap(f, *ts, **kw):
    return jax.tree.map(f, *ts, **kw)


def make_optimizer(name: str, *, momentum=0.9, weight_decay=0.0,
                   b1=0.9, b2=0.95, eps=1e-8,
                   state_dtype="float32") -> Optimizer:
    sdt = jnp.dtype(state_dtype)
    if name == "sgd":
        def init(params):
            return {"mu": _tmap(lambda p: jnp.zeros_like(p, sdt), params)}

        def step(params, grads, state, lr):
            def upd(p, g, m):
                g = g.astype(jnp.float32) + weight_decay * p.astype(jnp.float32)
                m = (momentum * m.astype(jnp.float32) + g).astype(sdt)
                return (p.astype(jnp.float32)
                        - lr * m.astype(jnp.float32)).astype(p.dtype), m
            flat = _tmap(upd, params, grads, state["mu"])
            new_p = _tmap(lambda t: t[0], flat, is_leaf=lambda x: isinstance(x, tuple))
            new_m = _tmap(lambda t: t[1], flat, is_leaf=lambda x: isinstance(x, tuple))
            return new_p, {"mu": new_m}
        return Optimizer("sgd", init, step)

    if name == "adamw":
        def init(params):
            z = _tmap(lambda p: jnp.zeros_like(p, jnp.float32), params)
            return {"m": z, "v": jax.tree.map(jnp.copy, z),
                    "t": jnp.zeros((), jnp.int32)}

        def step(params, grads, state, lr):
            t = state["t"] + 1
            tf = t.astype(jnp.float32)

            def upd(p, g, m, v):
                g = g.astype(jnp.float32)
                m = b1 * m + (1 - b1) * g
                v = b2 * v + (1 - b2) * jnp.square(g)
                mhat = m / (1 - b1 ** tf)
                vhat = v / (1 - b2 ** tf)
                new_p = (p.astype(jnp.float32)
                         - lr * (mhat / (jnp.sqrt(vhat) + eps)
                                 + weight_decay * p.astype(jnp.float32)))
                return new_p.astype(p.dtype), m, v
            flat = _tmap(upd, params, grads, state["m"], state["v"])
            pick = lambda i: _tmap(lambda tup: tup[i], flat,
                                   is_leaf=lambda x: isinstance(x, tuple))
            return pick(0), {"m": pick(1), "v": pick(2), "t": t}
        return Optimizer("adamw", init, step)

    raise ValueError(name)


def sam_gradient(loss_fn, params, batch, rho, eps=1e-12):
    """SAM: gradient at the ascent point p + rho * g/||g||.
    Returns ((loss, aux), sharpness-aware grads)."""
    (loss0, aux), g = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                      for l in jax.tree.leaves(g)))
    scale = rho / jnp.maximum(gn, eps)
    p_adv = jax.tree.map(
        lambda p, gg: (p.astype(jnp.float32)
                       + scale * gg.astype(jnp.float32)).astype(p.dtype),
        params, g)
    (_, _), g_adv = jax.value_and_grad(loss_fn, has_aux=True)(p_adv, batch)
    return (loss0, aux), g_adv
