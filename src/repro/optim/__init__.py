from repro.optim.optimizers import Optimizer, make_optimizer, sam_gradient

__all__ = ["Optimizer", "make_optimizer", "sam_gradient"]
