"""internvl2-2b [vlm] — InternViT + InternLM2-1.8B backbone: 24L d_model=2048
16H (GQA kv=8) d_ff=8192 vocab=92553 [arXiv:2404.16821].
The InternViT vision encoder + MLP projector are a STUB: ``input_specs()``
provides precomputed patch embeddings (batch, n_patches, 2048) prepended to
the token sequence (early fusion)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=92553,
    n_prefix=256,           # ViT patch embeddings per image (stubbed)
    rope_theta=1000000.0,
    source="arXiv:2404.16821",
)
