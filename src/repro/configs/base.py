"""Config schema for the DPPF framework.

A ``ModelConfig`` fully describes one of the assigned architectures; a
``MeshPlan`` describes how a model is laid out on the production mesh; an
``InputShape`` is one of the four assigned workload shapes.

All configs are plain frozen dataclasses so they hash, compare, and print
deterministically (used as cache keys by the dry-run harness).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------

# Block kinds understood by models/transformer.py. A layer pattern is cycled
# over the depth of the network.
BLOCK_KINDS = (
    "attn",         # GQA attention + dense MLP
    "local_attn",   # sliding-window attention + dense MLP (gemma2 odd layers)
    "moe",          # GQA attention + mixture-of-experts MLP
    "mamba",        # Mamba2 (SSD) block
    "shared_attn",  # attention+MLP block with weights shared across positions
    "mlstm",        # xLSTM matrix-memory block
    "slstm",        # xLSTM scalar-memory block
)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | hybrid | ssm | encdec | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // n_heads
    source: str = ""                # citation for the config

    # --- attention options ---------------------------------------------------
    qkv_bias: bool = False          # qwen2
    rope_theta: float = 10000.0
    sliding_window: int = 0         # window size for local_attn blocks
    attn_logit_softcap: float = 0.0  # gemma2: 50.0
    final_logit_softcap: float = 0.0  # gemma2: 30.0
    post_block_norm: bool = False   # gemma2 uses pre+post norms

    # --- layer pattern (cycled over n_layers) --------------------------------
    layer_pattern: Tuple[str, ...] = ("attn",)

    # --- MoE ------------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    shared_expert: bool = False     # llama4-scout
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # --- SSM (Mamba2) ----------------------------------------------------------
    ssm_state: int = 0
    ssm_heads: int = 0              # 0 -> derived from expand*d_model/64
    ssm_expand: int = 2
    ssm_chunk: int = 128
    ssm_conv: int = 4

    # --- encoder-decoder -------------------------------------------------------
    n_enc_layers: int = 0           # >0 => enc-dec model (seamless)

    # --- modality frontend stub -----------------------------------------------
    # Number of precomputed prefix embeddings (image patches / audio frames)
    # prepended to the token sequence. The frontend itself is a STUB: the
    # input pipeline / input_specs() provides embeddings of shape
    # (batch, n_prefix, d_model) directly (see DESIGN.md).
    n_prefix: int = 0

    # --- misc -------------------------------------------------------------------
    remat: bool = False             # checkpoint each block (dry-run/prod on)
    # beyond-paper perf knobs (EXPERIMENTS.md §Perf)
    xlstm_chunk: int = 0            # >0: chunkwise-parallel mLSTM
    moe_combine_dtype: str = "float32"  # bf16 halves MoE dispatch collectives
    seq_shard_acts: bool = False    # sequence-parallel residual activations
    act: str = "silu"               # silu | gelu
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"         # compute/weight dtype for full-size runs

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        assert self.n_heads % max(self.n_kv_heads, 1) == 0, (
            f"{self.name}: n_heads must be a multiple of n_kv_heads")
        for k in self.layer_pattern:
            assert k in BLOCK_KINDS, f"unknown block kind {k!r}"

    # Derived ------------------------------------------------------------------
    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads

    def block_kind(self, layer: int) -> str:
        return self.layer_pattern[layer % len(self.layer_pattern)]

    def blocks(self) -> Tuple[str, ...]:
        return tuple(self.block_kind(i) for i in range(self.n_layers))

    @property
    def is_recurrent(self) -> bool:
        """True if the arch has a sub-quadratic (stateful) sequence mixer."""
        return any(k in ("mamba", "mlstm", "slstm") for k in self.blocks())

    @property
    def has_sliding_window(self) -> bool:
        return any(k == "local_attn" for k in self.blocks())

    def param_count(self) -> int:
        """Analytic parameter count (total, incl. all experts)."""
        d, f, hd = self.d_model, self.d_ff, self.head_dim
        nq, nkv = self.n_heads, self.n_kv_heads
        n_attn = d * nq * hd + 2 * d * nkv * hd + nq * hd * d
        if self.qkv_bias:
            n_attn += (nq + 2 * nkv) * hd
        n_mlp = 3 * d * f  # gated MLP
        n = 0
        for kind in self.blocks():
            if kind in ("attn", "local_attn"):
                n += n_attn + n_mlp + 2 * d
            elif kind == "moe":
                e = n_attn + 2 * d + d * self.n_experts  # attn + norms + router
                e += self.n_experts * 3 * d * f
                if self.shared_expert:
                    e += 3 * d * f
                n += e
            elif kind == "mamba":
                d_in = self.ssm_expand * d
                heads = self.ssm_heads or d_in // 64
                n += (d * (2 * d_in + 2 * self.ssm_state * 0 + heads)  # in_proj-ish
                      + 2 * d_in * self.ssm_state + d_in * d + d
                      + self.ssm_conv * d_in)
            elif kind == "shared_attn":
                pass  # counted once below
            elif kind in ("mlstm", "slstm"):
                d_in = self.ssm_expand * d
                n += 4 * d * d_in + d_in * d + 2 * d
        if "shared_attn" in self.blocks():
            n += n_attn + n_mlp + 2 * d
        n += self.vocab_size * d            # embedding
        if not self.tie_embeddings:
            n += d * self.vocab_size        # lm head
        n += d                              # final norm
        if self.n_enc_layers:
            n += self.n_enc_layers * (n_attn + n_mlp + 2 * d)
            n += self.n_layers * (n_attn + d)  # cross-attention in decoder
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only top_k experts)."""
        if self.n_experts == 0:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        dense_experts = self.n_experts - self.top_k - (1 if self.shared_expert else 0)
        n_moe_layers = sum(1 for k in self.blocks() if k == "moe")
        return self.param_count() - n_moe_layers * dense_experts * 3 * d * f


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A smoke-test variant of the same family: 2 layers, d_model<=256,
    <=4 experts, tiny vocab. Shapes shrink; the block pattern is preserved."""
    changes = dict(
        name=cfg.name + "-smoke",
        # at least one full pattern cycle so every block kind is exercised
        n_layers=max(2, len(cfg.layer_pattern)),
        d_model=256,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=64,
        d_ff=512 if cfg.d_ff else 0,
        vocab_size=512,
        n_experts=min(cfg.n_experts, 4) if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        # no capacity drops at smoke scale -> decode == teacher forcing
        capacity_factor=4.0 if cfg.n_experts else cfg.capacity_factor,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_heads=8 if cfg.ssm_state else 0,
        ssm_chunk=32,
        sliding_window=64 if cfg.sliding_window else 0,
        n_enc_layers=2 if cfg.n_enc_layers else 0,
        n_prefix=8 if cfg.n_prefix else 0,
        dtype="float32",
    )
    changes.update(overrides)
    return dataclasses.replace(cfg, **changes)


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Mesh / parallelism plan
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MeshPlan:
    """How a workload maps onto mesh axes.

    worker_axes enumerate DPPF workers (each index holds a distinct model
    replica). model_axes are tensor-parallel within a worker. fsdp_axes
    (hierarchical-DPPF extension, see DESIGN.md) shard weight storage within
    a worker; GSPMD inserts the gathers.
    """
    worker_axes: Tuple[str, ...] = ("data",)
    model_axes: Tuple[str, ...] = ("model",)
    fsdp_axes: Tuple[str, ...] = ()
    seq_shard_acts: bool = False     # sequence-sharded activations (hillclimb)
    microbatch: int = 1              # grad-accumulation microbatches per local step
    remat: bool = True               # checkpoint each block in backward

    @property
    def all_axes(self) -> Tuple[str, ...]:
        return self.worker_axes + self.fsdp_axes + self.model_axes


@dataclass(frozen=True)
class DPPFConfig:
    """Hyperparameters of the paper's algorithm (Alg. 1 + Eq. 5)."""
    alpha: float = 0.1          # pull strength
    lam: float = 0.5            # push strength lambda
    tau: int = 4                # communication period (local steps per round)
    lam_schedule: str = "increasing"   # fixed | increasing | decreasing (§C.2)
    consensus: str = "simple_avg"       # any repro.core.methods registry name
                                        # (simple_avg/dppf, easgd, lsgd,
                                        # mgrawa/grawa, hard, ddp, parle,
                                        # lpf_sgd, entropy_sgd)
    push: bool = True           # False => vanilla soft-consensus baseline
    exact_second_term: bool = False     # keep T2 (ablation §D.1)
    # communication-period schedule (train.clock.RoundClock): "fixed" keeps
    # tau constant; "qsr" adapts it to the cosine LR per the Quadratic
    # Synchronization Rule (Gu et al. 2024, paper §7.2)
    tau_schedule: str = "fixed"
    qsr_beta: float = 0.0       # QSR: tau_t = max(tau, floor((beta/eta)^2));
                                # >0 also opts into QSR when tau_schedule
                                # was left at "fixed" (legacy convention)
    eps: float = 1e-12          # norm guard
    # consensus execution engine: "tree" walks the stacked pytree (reference
    # path), "flat" runs every method on the persistent (R, n) flat view
    # (workers + aux state rows) via repro.core.engine.ConsensusEngine
    # (DESIGN.md §Consensus-engine)
    engine: str = "tree"
    # round-boundary overlap: "none" applies the consensus computed from
    # THIS round's post-local-step params (exact, the paper's Alg. 1);
    # "staleness1" applies the consensus computed from the PREVIOUS round's
    # snapshot, so the round's all-reduce hides behind the tau local steps;
    # "doublebuf" additionally stores that snapshot ROW-SHARDED and
    # dispatches its worker-row gather + partial-Gram psum in
    # ``overlap_chunks`` column chunks interleaved with the scan's local
    # steps, leaving only the coefficient math and the mix GEMM at the
    # round boundary; "staleness_k" generalizes doublebuf to a k-deep ring
    # of snapshots — round r applies the consensus of the round-(r-k)
    # snapshot, rounds 0..k-1 are exact-consensus pipeline fill, and the
    # sharded worker-row gather runs as a ppermute ring of R-1 single-row
    # hops (DESIGN.md §Overlap). Flat engine only.
    overlap: str = "none"
    # doublebuf/staleness_k: number of column chunks the mid-scan snapshot
    # gather + partial-Gram psum are split into (1 = one un-chunked
    # dispatch, bit-for-bit the staleness1 consensus; more chunks
    # interleave finer with the tau local steps — effective count is
    # capped by tau and by the local column count)
    overlap_chunks: int = 4
    # staleness_k: pipeline depth k — the snapshot ring holds k buffers and
    # the consensus applied after round r was computed from round r-k.
    # k=1 is the doublebuf recursion (and bit-for-bit staleness1 when
    # overlap_chunks=1). Ignored by the other overlap modes.
    staleness: int = 1
    # bounded-async elastic membership (staleness_k only): a per-row
    # participation mask rides the snapshot carry; an inactive worker row
    # keeps its params frozen and drops out of the consensus target
    # weights (the row-stochastic lowering renormalizes over active rows).
    # A row is forced back in after ``staleness`` consecutive misses
    # (bounded staleness) and rejoins with an EASGD-style catch-up pull of
    # strength ``elastic_catchup`` toward the active-fleet mean.
    elastic: bool = False
    elastic_catchup: float = 0.5

    def __post_init__(self):
        # ValueError, not assert: every check here guards a user-facing
        # config path and must survive python -O (a silently dropped check
        # would train with a misconfigured engine/schedule/overlap)
        if self.engine not in ("tree", "flat"):
            raise ValueError(f"unknown consensus engine {self.engine!r}")
        # registry lookup raises ValueError on an unknown method name; a
        # local import keeps configs importable without pulling jax at
        # module load
        from repro.core.methods import get_method
        spec = get_method(self.consensus)
        if spec.requires_flat and self.engine != "flat":
            raise ValueError(
                f"consensus={self.consensus!r} requires engine='flat' "
                "(its push force is a flat-view vector stage)")
        if self.tau_schedule not in ("fixed", "qsr"):
            raise ValueError(f"unknown tau schedule {self.tau_schedule!r}")
        if self.tau_schedule == "qsr" and self.qsr_beta <= 0:
            raise ValueError("tau_schedule='qsr' needs qsr_beta > 0")
        if self.overlap not in ("none", "staleness1", "doublebuf",
                                "staleness_k"):
            raise ValueError(f"unknown overlap mode {self.overlap!r}")
        if self.overlap != "none" and self.engine != "flat":
            raise ValueError(
                f"overlap={self.overlap!r} requires engine='flat' (the "
                "stale consensus snapshot lives in the flat view)")
        if self.overlap_chunks < 1:
            raise ValueError(
                f"overlap_chunks must be >= 1, got {self.overlap_chunks}")
        if self.staleness < 1:
            raise ValueError(
                f"staleness must be >= 1, got {self.staleness}")
        if self.elastic and self.overlap != "staleness_k":
            raise ValueError(
                "elastic=True requires overlap='staleness_k' (the "
                "participation mask rides the snapshot ring carry)")
        if self.elastic and self.exact_second_term:
            raise ValueError(
                "elastic=True does not support exact_second_term (the "
                "masked lowering only covers coefficient stages)")
        if not 0.0 <= self.elastic_catchup <= 1.0:
            raise ValueError(
                f"elastic_catchup must be in [0, 1], got "
                f"{self.elastic_catchup}")

    def apply_tune_plan(self, plan) -> "DPPFConfig":
        """Graft an autotune ``TunePlan`` (dataclass or its ``to_dict()``
        JSON form) onto this config: tau, overlap mode/chunks/staleness
        from the searched point, ``tau_schedule`` pinned to "fixed" —
        autotune placed tau at the measured comm/compute crossover, and a
        QSR schedule would re-adapt it away from that point, so the
        combination is rejected. ``dataclasses.replace`` re-runs
        ``__post_init__``, surfacing engine/overlap conflicts between the
        plan and this config."""
        if self.tau_schedule == "qsr" or self.qsr_beta > 0:
            raise ValueError(
                "autotune picks a fixed tau from the measured comm/compute "
                "crossover; tau_schedule='qsr' would re-adapt it — drop "
                "qsr_beta / use tau_schedule='fixed' when tuning")
        if isinstance(plan, dict):
            chosen = plan["chosen"]
            tau, chunks = int(chosen["tau"]), int(chosen["overlap_chunks"])
            overlap = str(plan.get("overlap", "none"))
            staleness = int(plan.get("staleness", 1))
        else:
            tau, chunks = int(plan.chosen.tau), int(plan.chosen.overlap_chunks)
            overlap, staleness = plan.overlap, int(plan.staleness)
        return dataclasses.replace(
            self, tau=tau, overlap=overlap, overlap_chunks=chunks,
            staleness=staleness, tau_schedule="fixed")

    @property
    def valley_width(self) -> float:
        """Theorem 1 target: lim E||Delta+|| = lambda/alpha."""
        return self.lam / self.alpha
