"""Architecture registry: ``--arch <id>`` resolves here."""
from __future__ import annotations

from repro.configs.base import (
    DPPFConfig,
    INPUT_SHAPES,
    InputShape,
    MeshPlan,
    ModelConfig,
    reduced,
)

from repro.configs import (  # noqa: E402
    dbrx_132b,
    gemma2_2b,
    internlm2_20b,
    internvl2_2b,
    llama4_scout_17b_a16e,
    qwen2_72b,
    seamless_m4t_medium,
    xlstm_350m,
    yi_6b,
    zamba2_7b,
)

ARCHS: dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        seamless_m4t_medium,
        internlm2_20b,
        llama4_scout_17b_a16e,
        dbrx_132b,
        zamba2_7b,
        gemma2_2b,
        internvl2_2b,
        qwen2_72b,
        xlstm_350m,
        yi_6b,
    )
}


def get_arch(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


def get_shape(name: str) -> InputShape:
    if name not in INPUT_SHAPES:
        raise KeyError(f"unknown shape {name!r}; available: {sorted(INPUT_SHAPES)}")
    return INPUT_SHAPES[name]


__all__ = [
    "ARCHS",
    "DPPFConfig",
    "INPUT_SHAPES",
    "InputShape",
    "MeshPlan",
    "ModelConfig",
    "get_arch",
    "get_shape",
    "reduced",
]
