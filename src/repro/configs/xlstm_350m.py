"""xlstm-350m [ssm] — 24L d_model=1024 4H (kv=4) d_ff=0 vocab=50304;
mLSTM (matrix memory) blocks with interleaved sLSTM (scalar memory) blocks
at ratio 3:1 [arXiv:2405.04517]. d_ff=0: blocks carry their own up/down
projections (expand factor 2)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    layer_pattern=("mlstm", "mlstm", "mlstm", "slstm"),
    ssm_expand=2,
    ssm_heads=4,
    tie_embeddings=True,
    source="arXiv:2405.04517",
)
