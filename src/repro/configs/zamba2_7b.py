"""zamba2-7b [hybrid] — 81L d_model=3584 32H (GQA kv=32) d_ff=14336
vocab=32000, ssm_state=64; Mamba2 backbone with a SHARED attention+MLP block
interleaved every 6 layers [arXiv:2411.15242]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab_size=32000,
    # 5 mamba blocks then the shared attention block, cycled over 81 layers.
    layer_pattern=("mamba", "mamba", "mamba", "mamba", "mamba", "shared_attn"),
    ssm_state=64,
    ssm_heads=112,          # expand*d_model / 64
    ssm_expand=2,
    source="arXiv:2411.15242",
)
