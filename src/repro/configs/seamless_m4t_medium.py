"""seamless-m4t-medium [audio] — enc-dec multimodal backbone.

12L d_model=1024 16H (GQA kv=16) d_ff=4096 vocab=256206 [arXiv:2308.11596].
The speech frontend (mel-spectrogram + conv feature extractor) is a STUB:
``input_specs()`` provides precomputed frame embeddings (batch, frames, 1024)
consumed by the text/unit decoder's encoder stack.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    n_layers=12,            # decoder layers
    n_enc_layers=12,        # encoder layers over frame embeddings
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    n_prefix=1536,          # audio frames fed to the encoder (stubbed embeds)
    rope_theta=10000.0,
    act="gelu",
    source="arXiv:2308.11596",
)
