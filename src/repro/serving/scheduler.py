"""Host-side scheduler for the continuous-batching SlotEngine.

``Scheduler`` owns the FIFO request queue and the per-slot host mirrors
(prompt tail being fed, tokens kept so far); ``serve`` drives the engine's
compiled lanes step by step. Two packing modes:

* ``continuous`` — a request is admitted the moment a slot frees up,
  mid-decode of everything else (the engine's lanes make that free).
* ``static``    — classic static batching: admit a full batch, then
  barrier until EVERY slot finishes before admitting the next batch.

Both modes run the SAME compiled decode step, so their step counts are a
structural (timer-free) measure of scheduling efficiency: on mixed-length
traces continuous needs no more steps than static (BENCH_serving.json's
``continuous_ge_static``).
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field

import jax
import numpy as np

from repro.serving.engine import SlotEngine

MODES = ("continuous", "static")


@dataclass
class Request:
    """One serving request. ``enc``/``prefix`` carry per-request modality
    context (encoder frames, vlm prefix); shapes must match the engine's
    example batch. ``key`` (raw uint32[2]) seeds the slot's sampling lanes;
    None derives one from the stream key by rid, so results are
    independent of slot placement and co-residents."""
    rid: int
    tokens: np.ndarray
    max_new_tokens: int
    enc: np.ndarray | None = None
    prefix: np.ndarray | None = None
    key: np.ndarray | None = None

    def __post_init__(self):
        self.tokens = np.asarray(self.tokens).reshape(-1)
        if self.tokens.size < 1:
            raise ValueError(f"request {self.rid}: empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError(
                f"request {self.rid}: max_new_tokens must be >= 1, got "
                f"{self.max_new_tokens}")


@dataclass
class RequestResult:
    rid: int
    tokens: list
    ttft_s: float
    admitted_step: int
    finished_step: int


@dataclass
class ServeReport:
    results: dict
    steps: int
    generated: int
    occupancy: float      # active slot-steps / (steps * max_slots)
    wall_s: float
    tok_s: float
    ttft_mean_s: float
    mode: str


@dataclass
class _SlotRec:
    req: Request
    tail: list
    fed: int
    out: list = field(default_factory=list)
    admitted_step: int = 0
    ttft_s: float = 0.0


class Scheduler:
    """FIFO queue + slot table. ``admit`` packs free slots from the queue
    (continuous: any free slot, any time; static: only when the whole
    table is empty)."""

    def __init__(self, max_slots: int, mode: str = "continuous"):
        if max_slots < 1:
            raise ValueError(f"max_slots must be >= 1, got {max_slots}")
        if mode not in MODES:
            raise ValueError(f"mode must be one of {MODES}, got {mode!r}")
        self.max_slots = max_slots
        self.mode = mode
        self.queue = deque()
        self.table = [None] * max_slots

    def submit(self, req: Request, engine: SlotEngine):
        if engine.window == 0:
            total = engine.start0 + req.tokens.size + req.max_new_tokens
            if total > engine.buf_len:
                raise ValueError(
                    f"request {req.rid}: {total} total positions exceed "
                    f"buf_len {engine.buf_len} and the engine has no "
                    f"sliding window — raise buf_len or serve with "
                    f"window > 0")
        self.queue.append(req)

    @property
    def busy(self):
        return any(r is not None for r in self.table)

    def free_slots(self):
        if self.mode == "static" and self.busy:
            return []
        return [s for s, r in enumerate(self.table) if r is None]


def _request_batch(req: Request):
    batch = {"tokens": np.asarray([[0]], np.int32)}
    if req.enc is not None:
        batch["enc"] = np.asarray(req.enc)[None] if req.enc.ndim == 2 \
            else np.asarray(req.enc)
    if req.prefix is not None:
        batch["prefix"] = np.asarray(req.prefix)[None] if req.prefix.ndim == 2 \
            else np.asarray(req.prefix)
    return batch


def serve(engine: SlotEngine, requests, mode: str = "continuous",
          key=None) -> ServeReport:
    """Serve ``requests`` to completion. Returns per-request outputs plus
    step/occupancy (structural) and wall-clock (timing) metrics."""
    sched = Scheduler(engine.max_slots, mode=mode)
    for r in requests:
        sched.submit(r, engine)

    base_key = key if key is not None else jax.random.PRNGKey(0)
    slots = engine.blank_slots()
    feed = np.zeros((engine.max_slots,), np.int32)
    results = {}
    steps = 0
    active_slot_steps = 0
    t0 = time.perf_counter()

    while sched.queue or sched.busy:
        for s in sched.free_slots():
            if not sched.queue:
                break
            req = sched.queue.popleft()
            state, start = engine.request_state(_request_batch(req))
            state, idx, tail = engine.prefill_chunks(state, req.tokens, start)
            rkey = req.key if req.key is not None else np.asarray(
                jax.random.fold_in(base_key, req.rid), np.uint32)
            slots = engine.insert(slots, state, s, idx, -(len(tail) - 1),
                                  req.max_new_tokens, rkey)
            sched.table[s] = _SlotRec(req=req, tail=tail, fed=0,
                                      admitted_step=steps)
            feed[s] = tail[0]

        nxt, slots = engine.decode(slots, feed)
        steps += 1
        now = time.perf_counter()
        for s, rec in enumerate(sched.table):
            if rec is None:
                continue
            active_slot_steps += 1
            if rec.fed + 1 < len(rec.tail):
                # still feeding the prompt tail; the sample is a by-product
                rec.fed += 1
                feed[s] = rec.tail[rec.fed]
                continue
            tok = int(nxt[s])
            if not rec.out:
                rec.ttft_s = now - t0
            rec.out.append(tok)
            feed[s] = tok
            if len(rec.out) == rec.req.max_new_tokens:
                results[rec.req.rid] = RequestResult(
                    rid=rec.req.rid, tokens=rec.out, ttft_s=rec.ttft_s,
                    admitted_step=rec.admitted_step, finished_step=steps)
                sched.table[s] = None   # engine flipped `active` in-compile

    wall = time.perf_counter() - t0
    generated = sum(len(r.tokens) for r in results.values())
    return ServeReport(
        results=results,
        steps=steps,
        generated=generated,
        occupancy=(active_slot_steps / (steps * engine.max_slots)
                   if steps else 0.0),
        wall_s=wall,
        tok_s=generated / wall if wall > 0 else 0.0,
        ttft_mean_s=(sum(r.ttft_s for r in results.values()) / len(results)
                     if results else 0.0),
        mode=mode,
    )


__all__ = ["MODES", "Request", "RequestResult", "Scheduler", "ServeReport",
           "serve"]
