"""Serving engine: batched prefill + greedy decode over the KV cache.

``make_serve_step`` builds the single-token decode function that the
decode-shape dry-runs lower (decode_32k / long_500k): ONE new token against
a cache of seq_len. ``window`` activates the sliding-window serving variant
(ring-buffer cache) that makes long_500k sub-quadratic for dense archs
(DESIGN.md §Decode-shape applicability).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.registry import ModelAPI


def make_serve_step(model: ModelAPI, window: int = 0):
    """decode one token: (params, states, token (B,1), index) -> (logits, states)."""
    def serve_step(params, states, token, index):
        return model.decode_step(params, states, token, index, window=window)
    return serve_step


def decode_key(key, i: int):
    """Sampling key for generated token ``i``: token 0 consumes the
    caller's key directly, tokens ``i >= 1`` fold the token index in.
    ``fold_in(k, i) != k``, so the first draw and the chain never collide —
    this helper IS that contract (tested in tests/test_clock.py). Host
    ``i`` only; the scan body inlines the ``i >= 1`` branch."""
    if i == 0:
        return key
    return jax.random.fold_in(key, i)


def generate(model: ModelAPI, params, batch, *, max_new_tokens: int,
             buf_len: int, window: int = 0, greedy: bool = True, key=None):
    """Prefill the prompt then decode ``max_new_tokens`` greedily (or
    sampled). ``max_new_tokens == 1`` is a plain prefill-then-pick (the
    decode scan runs zero times). Returns (tokens (B, max_new_tokens),
    final logits)."""
    prompt = batch["tokens"]
    B, S = prompt.shape
    prefix = 0
    if "prefix" in batch:
        prefix = batch["prefix"].shape[1]
    logits, states = model.prefill(params, batch, buf_len=buf_len,
                                   window=window)
    start = S + (prefix if not model.cfg.n_enc_layers else 0)

    def pick(lg, k):
        if greedy:
            return jnp.argmax(lg, axis=-1).astype(jnp.int32)
        return jax.random.categorical(k, lg).astype(jnp.int32)

    k0 = key if key is not None else jax.random.PRNGKey(0)
    tok0 = pick(logits, decode_key(k0, 0))

    def body(carry, i):
        tok, states = carry
        lg, states = model.decode_step(params, states, tok[:, None],
                                       start + i, window=window)
        nxt = pick(lg, jax.random.fold_in(k0, i))   # decode_key, i >= 1
        return (nxt, states), tok

    (last, _), toks = jax.lax.scan(body, (tok0, states),
                                   jnp.arange(1, max_new_tokens,
                                              dtype=jnp.int32))
    out = jnp.concatenate([toks.T, last[:, None]], axis=1)
    return out, logits
