"""Serving engine: continuous batching over the position-tagged KV/ring
cache, plus the one-shot ``generate`` entry point.

Two layers:

* ``generate`` — prefill-then-decode for a fixed batch. The decode scan is
  jitted with DONATED states (one compile per (model, max_new_tokens,
  window, sampling) tuple; the prompt start index is a traced scalar so
  prompt length does not retrace the loop). Prompts longer than
  ``buf_len`` stream through the ring buffer in fixed-size chunks via
  ``ModelAPI.make_state`` / ``prefill_chunk`` (window mode only — without
  a sliding window a ring overwrite would silently truncate the prompt).

* ``SlotEngine`` — the continuous-batching core. A fixed ``(max_slots,)``
  decode batch where per-slot index / generated-token counter / PRNG key /
  budget / active lanes ride IN the slot-state pytree, so a single
  compiled decode step serves admissions and evictions mid-stream with no
  recompiles: admission = (jitted blank request state) + (jitted chunked
  prefill of all full chunks) + (jitted donated insert into the slot
  axis); the prompt TAIL (1..chunk tokens) is fed through the decode step
  itself so the first sampled token comes out of the same compiled step
  (fused sampling, per-slot ``decode_key`` fold-in contract); eviction is
  the in-compile budget check flipping the active lane. The host-side
  ``Scheduler`` (repro.serving.scheduler) packs the request queue into
  slots and mirrors the lane arithmetic.

``make_serve_step`` builds the single-token decode function that the
decode-shape dry-runs lower (decode_32k / long_500k): ONE new token against
a cache of seq_len. ``window`` activates the sliding-window serving variant
(ring-buffer cache) that makes long_500k sub-quadratic for dense archs
(DESIGN.md §Decode-shape applicability and §Serving).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.registry import ModelAPI
from repro.serving.sampling import (
    GREEDY, SamplingParams, mask_logits, sample_token,
)


def make_serve_step(model: ModelAPI, window: int = 0):
    """decode one token: (params, states, token (B,1), index) -> (logits, states)."""
    def serve_step(params, states, token, index):
        return model.decode_step(params, states, token, index, window=window)
    return serve_step


def decode_key(key, i: int):
    """Sampling key for generated token ``i``: token 0 consumes the
    caller's key directly, tokens ``i >= 1`` fold the token index in.
    ``fold_in(k, i) != k``, so the first draw and the chain never collide —
    this helper IS that contract (tested in tests/test_clock.py). Host
    ``i`` only; the scan body inlines the ``i >= 1`` branch."""
    if i == 0:
        return key
    return jax.random.fold_in(key, i)


def default_chunk(buf_len: int) -> int:
    """Streaming-prefill chunk size when the caller does not pick one."""
    return min(buf_len, 128)


def _resolve_sampling(greedy, sampling):
    if sampling is not None:
        return sampling
    # greedy=False with no explicit params is the legacy pure-categorical
    # sampler: temperature 1, no truncation
    return GREEDY if greedy else SamplingParams()


def _pick(lg, k, sp):
    """Batched pick with ONE shared key per step (generate's legacy
    contract); the slot engine uses per-slot keys via sample_token."""
    if sp.greedy:
        return jnp.argmax(lg, axis=-1).astype(jnp.int32)
    return jax.random.categorical(k, mask_logits(lg, sp)).astype(jnp.int32)


@functools.lru_cache(maxsize=None)
def _prefill_jit(model: ModelAPI, buf_len: int, window: int):
    return jax.jit(lambda params, batch: model.prefill(
        params, batch, buf_len=buf_len, window=window))


@functools.lru_cache(maxsize=None)
def _chunk_jit(model: ModelAPI, window: int):
    return jax.jit(
        lambda params, states, toks, idx: model.prefill_chunk(
            params, states, toks, idx, window=window),
        donate_argnums=1)


@functools.lru_cache(maxsize=None)
def _decode_loop_jit(model: ModelAPI, max_new_tokens: int, window: int,
                     sp: SamplingParams):
    """Jitted decode scan with donated states. ``start`` is traced, so
    calls of identical (batch, buf) shape NEVER retrace — pinned by the
    compile-counter test. Exposed via generate(...) only."""
    def loop(params, states, logits0, k0, start):
        tok0 = _pick(logits0, decode_key(k0, 0), sp)

        def body(carry, i):
            tok, states = carry
            # token i-1 sits at absolute position start + i - 1 (the first
            # generated token IS position `start`; the historical start+i
            # convention left a one-position gap after the prompt)
            lg, states = model.decode_step(params, states, tok[:, None],
                                           start + i - 1, window=window)
            nxt = _pick(lg, jax.random.fold_in(k0, i), sp)  # decode_key, i >= 1
            return (nxt, states), tok

        (last, fin), toks = jax.lax.scan(body, (tok0, states),
                                         jnp.arange(1, max_new_tokens,
                                                    dtype=jnp.int32))
        # returning the final states gives the donated input an output to
        # alias into (and callers a resumable cache)
        return jnp.concatenate([toks.T, last[:, None]], axis=1), fin

    return jax.jit(loop, donate_argnums=1)


def decode_loop_cache_size(model: ModelAPI, max_new_tokens: int, window: int,
                           sp: SamplingParams = GREEDY) -> int:
    """Compile count of generate's decode loop for this config. Backs the
    no-retrace test: two generate calls of identical shape must leave
    this at 1."""
    return _decode_loop_jit(model, max_new_tokens, window, sp)._cache_size()


def _ring_check_chunk(buf_len, window, chunk):
    """Ring-streaming contract: a C-token chunk write overwrites C slots,
    and the chunk's EARLIEST query still needs window-1 of history — so
    exact chunked streaming needs buf_len >= window + chunk - 1 slack
    (per-token decode is the chunk == 1 corner, where buf_len == window
    suffices). Validated, not silently truncated."""
    if not 1 <= chunk <= buf_len:
        raise ValueError(
            f"chunk must be in [1, buf_len={buf_len}], got {chunk}")
    if window and chunk > buf_len - window + 1:
        raise ValueError(
            f"chunk {chunk} with window {window} needs buf_len >= "
            f"{window + chunk - 1} (got {buf_len}): a chunk write would "
            f"clobber ring slots its own queries still attend to")


def _ring_default_chunk(buf_len, window):
    if window:
        return max(1, min(default_chunk(buf_len), buf_len - window + 1))
    return default_chunk(buf_len)


def _stream_prefill(model, params, batch, buf_len, window, chunk):
    """Chunked prefill for prompts longer than buf_len: run every chunk
    through the jitted prefill_chunk lane (ring writes wrap via
    cache_update's mod-scatter). Returns (last logits, states)."""
    tokens = batch["tokens"]
    _ring_check_chunk(buf_len, window, chunk)
    states, start = model.make_state(params, batch, buf_len, window=window)
    S = tokens.shape[1]
    cf = _chunk_jit(model, window)
    idx, logits = start, None
    n_full = S // chunk
    for j in range(n_full):
        logits, states = cf(params, states, tokens[:, j * chunk:(j + 1) * chunk],
                            idx)
        idx += chunk
    if S - n_full * chunk:
        logits, states = cf(params, states, tokens[:, n_full * chunk:], idx)
    return logits, states


def generate(model: ModelAPI, params, batch, *, max_new_tokens: int,
             buf_len: int, window: int = 0, greedy: bool = True, key=None,
             sampling: SamplingParams | None = None, chunk: int = 0):
    """Prefill the prompt then decode ``max_new_tokens`` greedily (or
    sampled). ``max_new_tokens == 1`` is a plain prefill-then-pick (the
    decode scan runs zero times). ``sampling`` overrides ``greedy`` with
    fused temperature/top-k/top-p. Prompts longer than ``buf_len`` stream
    chunk-wise through the ring buffer (requires ``window > 0``). Returns
    (tokens (B, max_new_tokens), final prefill logits)."""
    if max_new_tokens < 1:
        raise ValueError(f"max_new_tokens must be >= 1, got {max_new_tokens}")
    if window > buf_len:
        raise ValueError(
            f"buf_len {buf_len} smaller than window {window}: the ring "
            f"buffer must hold at least one full attention window")
    sp = _resolve_sampling(greedy, sampling)
    prompt = batch["tokens"]
    B, S = prompt.shape
    prefix = batch["prefix"].shape[1] if "prefix" in batch else 0
    extra = prefix if not model.cfg.n_enc_layers else 0

    if extra + S <= buf_len:
        logits, states = _prefill_jit(model, buf_len, window)(params, batch)
    else:
        if window <= 0:
            raise ValueError(
                f"prompt of {S} tokens (+{extra} prefix) exceeds buf_len "
                f"{buf_len} without a sliding window: ring overwrite would "
                f"silently truncate the prompt — pass window > 0 or grow "
                f"buf_len")
        logits, states = _stream_prefill(
            model, params, batch, buf_len, window,
            chunk or _ring_default_chunk(buf_len, window))
    start = S + extra

    k0 = key if key is not None else jax.random.PRNGKey(0)
    out, _ = _decode_loop_jit(model, max_new_tokens, window, sp)(
        params, states, logits, k0, start)
    return out, logits


# ---------------------------------------------------------------------------
# Continuous batching
# ---------------------------------------------------------------------------

class SlotEngine:
    """Compiled lanes for slot-based continuous batching.

    The slot-state pytree is ``{"model": <per-slot model states stacked on
    axis 0>, "index", "gen", "budget", "key", "active"}``. One decode step
    vmaps ``ModelAPI.decode_step`` over the slot axis with per-slot
    index/key lanes, samples in-compile (``sample_token`` with the
    ``decode_key`` fold-in contract on the per-slot generated-token
    counter), freezes inactive slots' states, and flips ``active`` off the
    moment a slot's budget is exhausted. All four lanes — decode, chunk
    prefill, request state, slot insert — compile exactly once for a given
    engine; admissions and evictions never retrace.

    ``gen`` is the generated-token index of the NEXT sample; it starts at
    ``-(tail_len - 1)`` so the step that consumes the last prompt-tail
    token lands on ``gen == 0`` (first kept sample, keyed by the request
    key itself). Samples drawn while ``gen < 0`` are prompt-feeding
    by-products and are discarded by the host scheduler.
    """

    def __init__(self, model: ModelAPI, params, *, max_slots: int,
                 buf_len: int, window: int = 0, chunk: int = 0,
                 sampling: SamplingParams = GREEDY, example=None):
        if max_slots < 1:
            raise ValueError(f"max_slots must be >= 1, got {max_slots}")
        if buf_len < 1:
            raise ValueError(f"buf_len must be >= 1, got {buf_len}")
        if window < 0:
            raise ValueError(f"window must be >= 0, got {window}")
        if window > buf_len:
            raise ValueError(
                f"buf_len {buf_len} smaller than window {window}: the ring "
                f"buffer must hold at least one full attention window")
        # default smaller than generate's streaming chunk: a request's
        # prompt TAIL (up to `chunk` tokens) rides the per-token decode
        # lane, so huge chunks trade prefill efficiency for tail latency
        chunk = chunk or min(32, _ring_default_chunk(buf_len, window))
        _ring_check_chunk(buf_len, window, chunk)
        self.model = model
        self.params = params
        self.max_slots = max_slots
        self.buf_len = buf_len
        self.window = window
        self.chunk = chunk
        self.sampling = sampling
        if example is None:
            if model.cfg.n_enc_layers:
                raise ValueError(
                    "enc-dec serving needs an example batch carrying the "
                    "encoder-frame shape (example={'tokens': ..., 'enc': ...})")
            example = {"tokens": np.zeros((1, 1), np.int32)}
        self.example = example

        w = window

        def fresh(params, batch):
            return model.make_state(params, batch, buf_len, window=w)

        def chunk_step(params, state, toks, idx):
            return model.prefill_chunk(params, state, toks, idx, window=w)

        sp = sampling

        def step(params, slots, toks):
            def one(mstate, tok, idx, gen, key, act):
                lg, new = model.decode_step(params, mstate, tok[None, None],
                                            idx, window=w)
                i = jnp.maximum(gen, 0)
                k = jnp.where(i == 0, key, jax.random.fold_in(key, i))
                nxt = sample_token(lg[0].astype(jnp.float32), k, sp)
                new = jax.tree.map(lambda n, o: jnp.where(act, n, o),
                                   new, mstate)
                return nxt, new

            nxt, new_model = jax.vmap(one)(
                slots["model"], toks, slots["index"], slots["gen"],
                slots["key"], slots["active"])
            act = slots["active"]
            gen_after = slots["gen"] + 1
            return nxt, {
                "model": new_model,
                "index": jnp.where(act, slots["index"] + 1, slots["index"]),
                "gen": jnp.where(act, gen_after, slots["gen"]),
                "budget": slots["budget"],
                "key": slots["key"],
                "active": act & (gen_after < slots["budget"]),
            }

        def insert(slots, mstate, slot, idx0, gen0, budget, key):
            model_new = jax.tree.map(
                lambda all_, one: jax.lax.dynamic_update_slice(
                    all_, one[None].astype(all_.dtype),
                    (slot,) + (0,) * one.ndim),
                slots["model"], mstate)
            return {
                "model": model_new,
                "index": slots["index"].at[slot].set(idx0),
                "gen": slots["gen"].at[slot].set(gen0),
                "budget": slots["budget"].at[slot].set(budget),
                "key": slots["key"].at[slot].set(key),
                "active": slots["active"].at[slot].set(True),
            }

        self._fresh = jax.jit(fresh)
        self._chunk = jax.jit(chunk_step, donate_argnums=1)
        self._decode = jax.jit(step, donate_argnums=1)
        # donate only the slot table: the B=1 request state is a
        # dynamic_update_slice operand, never aliasable into the output
        self._insert = jax.jit(insert, donate_argnums=0)

        blank, start0 = self._fresh(self.params, self.example)
        self.start0 = int(start0)
        self._blank = jax.tree.map(lambda a: np.asarray(a), blank)

    # -- host API ----------------------------------------------------------

    def blank_slots(self):
        """Fresh all-inactive slot states (max_slots stacked blanks)."""
        S = self.max_slots
        return {
            "model": jax.tree.map(
                lambda a: jnp.asarray(np.repeat(a[None], S, axis=0)),
                self._blank),
            "index": jnp.zeros((S,), jnp.int32),
            "gen": jnp.zeros((S,), jnp.int32),
            "budget": jnp.ones((S,), jnp.int32),
            "key": jnp.zeros((S, 2), jnp.uint32),
            "active": jnp.zeros((S,), bool),
        }

    def request_state(self, batch):
        """Blank per-request (B=1) state primed with modality context.
        Returns (state, start index of the first prompt token)."""
        state, start = self._fresh(self.params, batch)
        return state, int(start)

    def prefill_chunks(self, state, tokens, start):
        """Stream all FULL chunks of a request's prompt through the jitted
        chunk lane; the remaining 1..chunk tail tokens are returned for
        the host to feed through the decode step (the step consuming the
        last tail token yields generated token 0). Returns
        (state, index of first tail token, tail list)."""
        tokens = np.asarray(tokens).reshape(-1)
        if tokens.size < 1:
            raise ValueError("empty prompt")
        n_full = (tokens.size - 1) // self.chunk
        idx = start
        for j in range(n_full):
            _, state = self._chunk(
                self.params, state,
                tokens[None, j * self.chunk:(j + 1) * self.chunk].astype(np.int32),
                np.int32(idx))
            idx += self.chunk
        return state, idx, [int(t) for t in tokens[n_full * self.chunk:]]

    def insert(self, slots, state, slot, idx0, gen0, budget, key):
        """Admit a prefilled request into a slot (donated write of the
        model state + all lanes)."""
        if not 0 <= slot < self.max_slots:
            raise ValueError(
                f"slot {slot} out of range for max_slots {self.max_slots}")
        if budget < 1:
            raise ValueError(f"max_new_tokens must be >= 1, got {budget}")
        return self._insert(slots, state, np.int32(slot), np.int32(idx0),
                            np.int32(gen0), np.int32(budget),
                            np.asarray(key, np.uint32))

    def decode(self, slots, toks):
        """One continuous-batching decode step over all slots. ``toks``:
        (max_slots,) int32 tokens being fed (prompt tail or previous
        sample; junk for inactive slots). Returns (sampled (max_slots,)
        np.int32, new slots)."""
        nxt, slots = self._decode(self.params, slots,
                                  np.asarray(toks, np.int32))
        return np.asarray(nxt), slots

    def compile_cache_sizes(self):
        """Per-lane XLA compile counts — the no-recompile-after-warmup
        test pins these to stay flat across admissions/evictions."""
        return {
            "fresh": self._fresh._cache_size(),
            "chunk": self._chunk._cache_size(),
            "decode": self._decode._cache_size(),
            "insert": self._insert._cache_size(),
        }
