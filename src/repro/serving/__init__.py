from repro.serving.engine import (
    SlotEngine, decode_key, decode_loop_cache_size, default_chunk, generate,
    make_serve_step,
)
from repro.serving.sampling import GREEDY, SamplingParams, sample_token
from repro.serving.scheduler import Request, Scheduler, ServeReport, serve

__all__ = [
    "GREEDY", "Request", "SamplingParams", "Scheduler", "ServeReport",
    "SlotEngine", "decode_key", "decode_loop_cache_size", "default_chunk",
    "generate", "make_serve_step", "sample_token", "serve",
]
