from repro.serving.engine import decode_key, generate, make_serve_step

__all__ = ["decode_key", "generate", "make_serve_step"]
