"""Fused batched sampling for the decode scan: temperature, top-k, and
top-p (nucleus) filtering composed into one traced function over a
``(V,)`` logit row, vmapped per slot by the serving engine.

The filters compose in the standard order temperature -> top-k -> top-p
(a token must survive BOTH truncations), all inside the compiled decode
step — no host round-trip between logits and the sampled token. Greedy
decoding is the ``temperature == 0`` corner and ignores the key.

``SamplingParams`` is a frozen dataclass so an engine's sampling config
is hashable and participates in jit-cache keys; validation raises
``ValueError`` (not assert) so it survives ``python -O``
(tests/optcheck.py).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

NEG_INF = -1e30


@dataclass(frozen=True)
class SamplingParams:
    """top_k == 0 and top_p == 1.0 disable the respective truncation;
    temperature == 0.0 means greedy (argmax)."""
    temperature: float = 1.0
    top_k: int = 0
    top_p: float = 1.0

    def __post_init__(self):
        if self.temperature < 0.0:
            raise ValueError(
                f"temperature must be >= 0, got {self.temperature}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(
                f"top_p must be in (0, 1], got {self.top_p}")

    @property
    def greedy(self) -> bool:
        return self.temperature == 0.0


GREEDY = SamplingParams(temperature=0.0)


def _top_k_mask(logits, k):
    """Keep the k largest logits per row (ties at the threshold all
    survive — strictly a superset of k, matching the usual impl)."""
    kth = jax.lax.top_k(logits, k)[0][..., -1:]
    return jnp.where(logits >= kth, logits, NEG_INF)


def _top_p_mask(logits, p):
    """Nucleus: keep the smallest prefix of the probability-sorted vocab
    whose mass reaches p. The EXCLUSIVE cumulative sum keeps the first
    token unconditionally, so the mask can never empty the vocab."""
    sort = jnp.flip(jnp.sort(logits, axis=-1), axis=-1)
    probs = jax.nn.softmax(sort, axis=-1)
    mass_before = jnp.cumsum(probs, axis=-1) - probs
    sorted_keep = mass_before < p
    # threshold = smallest kept logit; everything >= it survives
    thresh = jnp.min(jnp.where(sorted_keep, sort, jnp.inf), axis=-1,
                     keepdims=True)
    return jnp.where(logits >= thresh, logits, NEG_INF)


def mask_logits(logits, sp: SamplingParams):
    """Temperature + top-k + top-p over ``(..., V)`` logits. Greedy (and
    the no-op params temperature=1/top_k=0/top_p=1) return the input
    bit-identically, preserving the legacy ``categorical(key, logits)``
    semantics pinned in tests/test_clock.py."""
    if sp.greedy:
        return logits
    x = logits
    if sp.temperature != 1.0:
        x = x / jnp.float32(sp.temperature)
    if sp.top_k:
        x = _top_k_mask(x, min(sp.top_k, x.shape[-1]))
    if sp.top_p < 1.0:
        x = _top_p_mask(x, jnp.float32(sp.top_p))
    return x


def sample_token(logits, key, sp: SamplingParams):
    """One token id (int32) from one ``(V,)`` float32 logit row."""
    if sp.greedy:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, mask_logits(logits, sp)).astype(jnp.int32)


def sample_batch(logits, keys, sp: SamplingParams):
    """(B, V) logits + (B,) per-row keys -> (B,) tokens, one independent
    draw per row (the serving engine's per-slot lanes)."""
    if sp.greedy:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    x = mask_logits(logits, sp)
    return jax.vmap(
        lambda lg, k: jax.random.categorical(k, lg))(x, keys).astype(jnp.int32)


__all__ = ["GREEDY", "SamplingParams", "mask_logits", "sample_batch",
           "sample_token"]
