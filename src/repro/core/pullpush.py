"""DPPF pull-push updates (paper §5, Eq. 4/5; Appendix E.1, D.1).

All functions operate on *worker-stacked* parameter pytrees: every leaf has
a leading worker dimension M. On the production mesh that dimension is
sharded over the worker axes, so ``jnp.mean(..., axis=0)`` here lowers to
the round's single all-reduce — the only data-axis collective in DPPF.

This module (with ``consensus.apply_round(engine=None)``) is the REFERENCE
path: the production hot path runs the same math on the persistent flat
view via ``repro.core.engine.ConsensusEngine`` (DESIGN.md §Consensus-engine)
and is parity-tested against it per method in tests/test_engine.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Stacked-tree utilities
# ---------------------------------------------------------------------------

def tree_mean0(stacked):
    """x_A: mean over the worker dimension (THE consensus collective)."""
    return jax.tree.map(lambda a: jnp.mean(a.astype(jnp.float32), axis=0), stacked)


def worker_sq_dists(stacked, center):
    """||x_m - x_A||^2 per worker, summed over all leaves. -> (M,) fp32."""
    def leaf(a, c):
        d = a.astype(jnp.float32) - c[None]
        return jnp.sum(jnp.square(d), axis=tuple(range(1, d.ndim)))
    parts = jax.tree.leaves(jax.tree.map(leaf, stacked, center))
    return jnp.sum(jnp.stack(parts), axis=0)


def worker_dists(stacked, center=None):
    """||x_m - x_A|| per worker -> (M,). This is the paper's relaxed MV
    quantity (consensus distance, Fig. 2b)."""
    if center is None:
        center = tree_mean0(stacked)
    return jnp.sqrt(worker_sq_dists(stacked, center))


def _bcast(v, a):
    """Broadcast a per-worker scalar (M,) over a stacked leaf (M, ...)."""
    return v.reshape(v.shape + (1,) * (a.ndim - 1)).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Eq. 5: fused pull-push (x_C = x_A)
# ---------------------------------------------------------------------------

def pullpush(stacked, alpha, lam, eps=1e-12):
    """x_m <- x_m + (x_A - x_m) * (alpha - lam / ||x_m - x_A||).

    Returns (new_stacked, metrics). One consensus all-reduce; the push term
    adds no communication (the paper's D.1 simplification).
    """
    center = tree_mean0(stacked)
    r = worker_dists(stacked, center)                      # (M,)
    coef = alpha - lam / jnp.maximum(r, eps)               # (M,)

    def leaf(a, c):
        gap = c[None] - a.astype(jnp.float32)
        return (a.astype(jnp.float32) + gap * _bcast(coef, a)).astype(a.dtype)

    new = jax.tree.map(leaf, stacked, center)
    # post-update distance: new gap = gap * (1 - coef), mean preserved
    r_post = r * jnp.abs(1.0 - coef)
    metrics = {
        "consensus_dist": jnp.mean(r_post),     # relaxed MV, post-round
        "pre_dist": jnp.mean(r),
        "pull_force": alpha * jnp.mean(r),      # ||alpha * (x_A - x_m)||
        "push_force": jnp.float32(lam),         # unit-normed * lam (Fig. 3)
    }
    return new, metrics


def pull_only(stacked, target, alpha):
    """Soft consensus x_m <- (1-alpha) x_m + alpha x_C.
    ``target`` is either a center tree (no worker dim) or a stacked tree."""
    def leaf(a, c):
        cf = c.astype(jnp.float32)
        if cf.ndim != a.ndim:
            cf = cf[None]
        return ((1.0 - alpha) * a.astype(jnp.float32) + alpha * cf).astype(a.dtype)
    return jax.tree.map(leaf, stacked, target)


def push_only(stacked, lam, center=None, eps=1e-12):
    """x_m <- x_m + lam * (x_m - x_A)/||x_m - x_A|| (push force alone)."""
    if center is None:
        center = tree_mean0(stacked)
    r = worker_dists(stacked, center)
    scale = lam / jnp.maximum(r, eps)

    def leaf(a, c):
        d = a.astype(jnp.float32) - c[None]
        return (a.astype(jnp.float32) + d * _bcast(scale, a)).astype(a.dtype)

    return jax.tree.map(leaf, stacked, center)


# ---------------------------------------------------------------------------
# Exact two-term update (Appendix E.1 / ablation D.1)
# ---------------------------------------------------------------------------

def exact_push(stacked, lam_r, eps=1e-12):
    """-lam_r dR/dx_m = (lam_r/M^2) (M u_m - sum_j u_j), u_m = d_m/||d_m||.

    Keeps the second term the paper drops; needs the mean unit direction,
    i.e. a second all-reduce (this is why the paper's simplification is the
    communication-efficient choice)."""
    center = tree_mean0(stacked)
    r = worker_dists(stacked, center)
    inv = 1.0 / jnp.maximum(r, eps)

    def unit(a, c):
        d = a.astype(jnp.float32) - c[None]
        return d * _bcast(inv, a)

    units = jax.tree.map(unit, stacked, center)
    mean_unit = tree_mean0(units)                  # second collective
    M = r.shape[0]

    def leaf(a, u, mu):
        upd = (lam_r / M) * (u - mu[None])
        return (a.astype(jnp.float32) + upd).astype(a.dtype)

    return jax.tree.map(leaf, stacked, units, mean_unit)


def push_terms_norms(stacked, lam_r, eps=1e-12):
    """(||T1||, ||T2||, ||T1+T2||) per worker — Figure 7 ablation."""
    center = tree_mean0(stacked)
    r = worker_dists(stacked, center)
    inv = 1.0 / jnp.maximum(r, eps)

    def unit(a, c):
        d = a.astype(jnp.float32) - c[None]
        return d * _bcast(inv, a)

    units = jax.tree.map(unit, stacked, center)
    mean_unit = tree_mean0(units)
    M = r.shape[0]
    t1 = jax.tree.map(lambda u: (lam_r / M) * u, units)
    t2 = jax.tree.map(lambda mu: (lam_r / M) * mu, mean_unit)

    def norm_stacked(tree):
        parts = [jnp.sum(jnp.square(l), axis=tuple(range(1, l.ndim)))
                 for l in jax.tree.leaves(tree)]
        return jnp.sqrt(jnp.sum(jnp.stack(parts), axis=0))

    n1 = norm_stacked(t1)
    n2 = jnp.sqrt(sum(jnp.sum(jnp.square(l)) for l in jax.tree.leaves(t2)))
    both = jax.tree.map(lambda a, b: a - b[None], t1, t2)
    n12 = norm_stacked(both)
    return n1, n2, n12
