"""Soft-consensus family (paper §3 Alg. 1, §7.1) and their DPPF couplings.

Every method produces a consensus target x_C; the round update is
    pull:  x_m <- (1-alpha) x_m + alpha x_C
    push:  x_m <- x_m + lam (x_m - x_A)/||x_m - x_A||        (if DPPF)
For simple_avg + push the two fuse into Eq. 5 (pullpush.pullpush).

Methods are DATA: ``repro.core.methods`` registers a ``MethodSpec`` per
method (target-weight rule, aux-row contract, coefficient flags, input
needs) and this module lowers any spec to generic engine stages — there
is no per-method branch here.  ``methods.method_names()`` lists the zoo
(simple_avg/dppf, hard, easgd, lsgd, mgrawa/grawa, ddp, parle, lpf_sgd,
entropy_sgd); DESIGN.md §Method-registry documents the schema.

``apply_round`` is the single entry point. With ``engine=None`` it runs the
stacked-pytree reference path (the parity oracle); with a
``repro.core.engine.ConsensusEngine`` it lowers the method to a short list
of (target-weights, coefficient) stages over the persistent flat view — the
production hot path (DESIGN.md §Consensus-engine). Both paths emit the SAME
metrics pytree from every branch (stable under ``lax.scan``/loggers):
``consensus_dist``, ``pre_dist``, ``pull_force``, ``push_force``.

The flat lowering also runs under a mapped axis (``jax.shard_map``): with
``engine.shard`` set, ``params`` is the full-R-row LOCAL column shard
``(R, n_local)`` and the stages' column contractions psum over the shard's
column axes inside the engine. The lowering itself is shard-oblivious —
target weights, coefficients, and the (R, R) mixing are replicated math —
but ``losses``/``grad_norms`` must then be the GLOBAL (M,) vectors
(all-gathered over the worker axes by the sharded trainer), since lsgd's
argmin and mgrawa's weights are fleet-wide reductions
(DESIGN.md §Sharded-execution).

Remark 1 (paper): DPPF_lsgd with push away from x_A does NOT converge; the
documented fix pushes away from the leader instead (push_from="leader").
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import methods as _methods
from repro.core import pullpush as pp
from repro.core.methods import get_method

# canonical methods with a tree reference path (parity-test surface);
# lpf_sgd is flat-engine-only and excluded by construction
METHODS = _methods.tree_method_names()

EASGD_BETA = _methods.EASGD_BETA   # re-export (pre-registry callers)


def init_state(method, stacked, *, engine=None):
    """Per-method consensus state. With a flat engine, row-shaped state
    (easgd/parle centers) lives in the flat buffer's aux rows instead;
    LPF-SGD's filtered gradient is a worker-shaped EMA buffer that rides
    in ``TrainState.cstate`` either way."""
    spec = get_method(method)
    if engine is not None:
        if spec.filter_mu:
            L = engine.layout
            return {"g_ema": jnp.zeros((L.M, L.n), jnp.float32)}
        return {}
    if spec.center_beta:
        return {"center": pp.tree_mean0(stacked)}
    return {}


def consensus_target(method, stacked, state, *, losses=None, grad_norms=None,
                     easgd_beta=None):
    """Returns (x_C tree [no worker dim] or stacked, new_state, leader_idx).
    ``easgd_beta`` overrides the spec's center step (legacy knob)."""
    spec = get_method(method)
    if spec.tree_target is None:
        raise ValueError(method)
    if easgd_beta is not None and easgd_beta != spec.center_beta:
        spec = dataclasses.replace(spec, center_beta=easgd_beta)
    return spec.tree_target(spec, stacked, state, losses=losses,
                            grad_norms=grad_norms)


def _metrics(consensus_dist, pre_dist, pull_force, push_force):
    """The ONE metrics schema every branch of every path emits."""
    return {
        "consensus_dist": jnp.asarray(consensus_dist, jnp.float32),
        "pre_dist": jnp.asarray(pre_dist, jnp.float32),
        "pull_force": jnp.asarray(pull_force, jnp.float32),
        "push_force": jnp.asarray(push_force, jnp.float32),
    }


def _pull_coef(spec, dcfg, lam_t, pull_scale):
    """The effective pull coefficient: alpha, hard-pulled to 1, ramped by
    the replica-coupling schedule (Parle: lam_t / lam), and scaled by the
    clock's inner/outer plan (Entropy-SGD sub-rounds). Exact alpha for
    every spec without ramp/scale (x * 1.0 is IEEE-exact)."""
    pull = 1.0 if spec.hard_pull else dcfg.alpha
    if spec.pull_ramp and dcfg.lam > 0:
        pull = pull * (lam_t / dcfg.lam)
    return pull * pull_scale


def apply_round(params, dcfg, lam_t, state, *, losses=None, grad_norms=None,
                push_from="average", engine=None, first_gram=None, mask=None,
                push_vec=None, pull_scale=1.0):
    """One communication round. Returns (params, state, metrics).

    ``params`` is a worker-stacked pytree (tree path) or the engine's flat
    ``(R, n)`` view (flat path). Metrics keys are identical either way.
    ``first_gram`` (flat path only) is a precomputed column contraction
    for the FIRST stage — the summed ``engine.stage_comm`` chunks the
    double-buffered overlap dispatches mid-scan; the stage then runs its
    coefficient math + mixing only (DESIGN.md §Overlap). ``mask`` (flat
    path only) is the elastic participation vector ``(M,)`` — inactive
    worker rows drop out of every target-weight combination AND have their
    pull/push coefficients zeroed, so their rows pass through the mixing
    bit-exactly unchanged (DESIGN.md §Overlap, elastic membership).
    ``push_vec`` (flat path only) is the per-worker push direction field
    ``(M, n[_local])`` for specs with ``push_source="filtered_grad"``
    (LPF-SGD's EMA gradient). ``pull_scale`` scales the pull coefficient
    (the RoundClock's inner/outer plan; 1.0 = exact no-op).
    """
    if engine is not None:
        return _apply_round_flat(engine, params, dcfg, lam_t, state,
                                 losses=losses, grad_norms=grad_norms,
                                 push_from=push_from, first_gram=first_gram,
                                 mask=mask, push_vec=push_vec,
                                 pull_scale=pull_scale)
    if first_gram is not None:
        raise ValueError("first_gram requires the flat engine")
    if mask is not None:
        raise ValueError("elastic mask requires the flat engine")
    if push_vec is not None:
        raise ValueError("push_vec requires the flat engine")
    return _apply_round_tree(params, dcfg, lam_t, state, losses=losses,
                             grad_norms=grad_norms, push_from=push_from,
                             pull_scale=pull_scale)


# ---------------------------------------------------------------------------
# Reference path: stacked pytrees (the flat engine's parity oracle)
# ---------------------------------------------------------------------------

def _apply_round_tree(stacked, dcfg, lam_t, state, *, losses, grad_norms,
                      push_from, pull_scale=1.0):
    spec = get_method(dcfg.consensus)
    pull = _pull_coef(spec, dcfg, lam_t, pull_scale)
    push = dcfg.push and spec.pushes

    if not spec.communicates:               # ddp: metrics only
        r = pp.worker_dists(stacked).mean()
        return stacked, state, _metrics(r, r, 0.0, 0.0)

    if spec.fuse_eq5 and push and not dcfg.exact_second_term \
            and push_from == "average":
        new, metrics = pp.pullpush(stacked, pull, lam_t, dcfg.eps)
        return new, state, _metrics(**{k: metrics[k] for k in (
            "consensus_dist", "pre_dist", "pull_force", "push_force")})

    target, state, leader_idx = consensus_target(
        dcfg.consensus, stacked, state, losses=losses, grad_norms=grad_norms)
    pre = jnp.mean(pp.worker_dists(stacked))
    new = pp.pull_only(stacked, target, pull)

    if push:
        if dcfg.exact_second_term:
            new = pp.exact_push(new, lam_t * pp.worker_dists(new).shape[0],
                                dcfg.eps)
        elif push_from == "leader" and leader_idx is not None:
            leader = jax.tree.map(lambda a: a.astype(jnp.float32)[leader_idx],
                                  new)
            new = pp.push_only(new, lam_t, center=leader, eps=dcfg.eps)
        else:
            new = pp.push_only(new, lam_t, eps=dcfg.eps)
    post = jnp.mean(pp.worker_dists(new))
    return new, state, _metrics(post, pre, pull * pre,
                                lam_t if push else 0.0)


# ---------------------------------------------------------------------------
# Flat path: generic MethodSpec -> (target-weights, c0, c1) stage lowering
# ---------------------------------------------------------------------------

def as_participation_mask(mask, n_workers):
    """The membership-provider contract: canonicalize a provider's output
    (heartbeat table, chaos schedule, ``--elastic-drop`` window — anything
    that decides per-round who is in) to the ``(n_workers,)`` float32
    participation vector the masked lowering consumes: entry m is 1.0 when
    worker row m takes part in this round's consensus, 0.0 when it is out.
    Raises ``ValueError`` (never assert — survives ``python -O``) on a
    wrong shape, so a provider bug fails loudly at the boundary instead of
    broadcasting into the mixing stages."""
    act = jnp.asarray(mask, jnp.float32)
    if act.ndim != 1 or act.shape[0] != int(n_workers):
        raise ValueError(
            f"participation mask shape {act.shape} != ({int(n_workers)},) "
            "(one entry per worker row)")
    return act


def lower_stages(engine, dcfg, lam_t, *, losses=None, grad_norms=None,
                 push_from="average", mask=None, pull_scale=1.0):
    """Lower a consensus method's ``MethodSpec`` to its flat-engine stages.

    Returns ``(stages, pull)`` with each stage ``("coef", T, c0, c1)`` (a
    fused target-weight + coefficient mixing stage), ``("exact", lam_r)``
    (the Appendix E.1 two-term push) or ``("vec", cvec)`` (push along the
    external direction field — LPF-SGD's filtered gradient, executed by
    ``engine.vec_stage``). An empty list means no consensus stage (ddp,
    metrics only); ``pull`` is the effective pull coefficient (the
    ``pull_force`` metric). Public so the double-buffered trainer can read
    stage 1's target weights BEFORE the scan — the mid-scan ``stage_comm``
    chunks need T1 — and then execute the identical list via
    ``apply_round(..., first_gram=...)`` (the lowering is a pure function
    of its inputs, so lowering twice is free trace-time work).

    The per-method semantics all come from the spec:

    * ``spec.weight_fn(ctx)`` produces the row-stochastic worker
      combination w (mask semantics INSIDE the rule — the ctx carries the
      active mask and the pre-masked uniform);
    * ``spec.center_beta`` turns w into the elastic-center target
      ``beta * w + (1 - beta) * e_center`` with the aux row adopting it at
      ``spec.aux_pull`` (EASGD/Parle: center update and worker pull are
      ONE mixing stage);
    * ``spec.fuse_eq5`` fuses pull+push into one Eq. 5 stage;
    * the push stage targets the spec's leader, the Appendix E.1 exact
      form, the filtered-gradient field, or the uniform mean.

    ``mask`` is the elastic participation vector ``(M,)`` (1 = active):
    the row-stochastic target weights renormalize over ACTIVE rows only
    and every coefficient vector's inactive worker entries are zeroed, so
    an inactive row neither contributes to nor receives the consensus —
    its flat-view row passes through each mixing stage bit-exactly.
    """
    spec = get_method(dcfg.consensus)
    pull = _pull_coef(spec, dcfg, lam_t, pull_scale)
    push = dcfg.push and spec.pushes
    L = engine.layout
    M, R = L.M, L.R
    eye = jnp.eye(R, dtype=jnp.float32)
    u = engine.uniform                       # (R,) worker mean weights
    zeros = jnp.zeros((R,), jnp.float32)
    act = gate = None
    if mask is not None:
        act = as_participation_mask(mask, M)             # (M,) 1 = active
        mfull = zeros.at[:M].set(act)
        # masked uniform: the worker mean over active rows only
        u = mfull / jnp.maximum(jnp.sum(mfull), 1.0)
        # coefficient gate: inactive worker rows get zero pull/push; aux
        # rows participate while ANY worker row is active (the elastic
        # center keeps tracking the live fleet) but freeze with the fleet
        # when everyone is out — an all-zero mask must make every mixing
        # stage a bit-exact pass-through, not shrink the center toward 0
        aux_on = (jnp.sum(act) > 0).astype(jnp.float32)
        gate = (aux_on * jnp.ones((R,), jnp.float32)).at[:M].set(act)

    def worker_T(w):
        """All worker rows target the combination w; aux rows stay put."""
        T = jnp.broadcast_to(w, (R, R))
        if L.aux:
            T = jnp.concatenate([T[:M], eye[M:]], axis=0)
        return T

    # ---- spec -> stage list -----------------------------------------------
    stages = []      # ("coef", T, c0, c1) | ("exact", lam_r) | ("vec", cvec)
    if spec.communicates:
        if spec.needs_losses and losses is None:
            # ValueError, not assert: user-facing path, must survive -O
            raise ValueError(f"{spec.name} needs per-worker losses")
        if spec.needs_grad_norms and grad_norms is None:
            raise ValueError(f"{spec.name} needs grad norms")
        w = spec.weight_fn(_methods.WeightCtx(
            M=M, R=R, eye=eye, u=u, zeros=zeros, act=act, losses=losses,
            grad_norms=grad_norms))
        c_pull = zeros.at[:M].set(pull)
        if spec.fuse_eq5 and push and not dcfg.exact_second_term \
                and push_from == "average":
            # Eq. 5: pull and push share the x_A target -> ONE fused stage
            stages.append(("coef", worker_T(w), c_pull,
                           zeros.at[:M].set(-lam_t)))
        else:
            if spec.center_beta:
                # every row targets z' = beta (w.x) + (1-beta) z; the aux
                # row adopts it at aux_pull — the center update and the
                # worker pull are ONE mixing stage
                w_z = spec.center_beta * w \
                    + (1.0 - spec.center_beta) * eye[M]
                T1 = jnp.broadcast_to(w_z, (R, R))
                c_pull = c_pull.at[M:].set(spec.aux_pull)
            else:
                T1 = worker_T(w)
            stages.append(("coef", T1, c_pull, zeros))
            if push:
                if spec.push_source == "filtered_grad":
                    stages.append(("vec", zeros.at[:M].set(-lam_t)))
                elif dcfg.exact_second_term:
                    stages.append(("exact", lam_t * M))
                elif push_from == "leader" and spec.leader:
                    stages.append(("coef", worker_T(w), zeros,
                                   zeros.at[:M].set(-lam_t)))
                else:
                    stages.append(("coef", worker_T(u), zeros,
                                   zeros.at[:M].set(-lam_t)))
    if gate is not None:
        if any(s[0] == "exact" for s in stages):
            raise ValueError("elastic mask does not support "
                             "exact_second_term stages")
        gated = []
        for s in stages:
            if s[0] == "coef":
                _, T, c0, c1 = s
                gated.append(("coef", T, c0 * gate, c1 * gate))
            else:                            # ("vec", cvec)
                gated.append(("vec", s[1] * gate))
        stages = gated
    return stages, pull


def _apply_round_flat(engine, flat, dcfg, lam_t, state, *, losses, grad_norms,
                      push_from, first_gram=None, mask=None, push_vec=None,
                      pull_scale=1.0):
    spec = get_method(dcfg.consensus)
    if engine.eps != dcfg.eps:
        # the engine's norm guard must match the config's (tree-path parity)
        engine = dataclasses.replace(engine, eps=dcfg.eps)
    stages, pull = lower_stages(engine, dcfg, lam_t, losses=losses,
                                grad_norms=grad_norms, push_from=push_from,
                                mask=mask, pull_scale=pull_scale)
    if first_gram is not None and (not stages or stages[0][0] != "coef"):
        raise ValueError("first_gram requires a leading coefficient stage "
                         "(every communicating lowering has one)")
    if any(s[0] == "vec" for s in stages) and push_vec is None:
        raise ValueError(f"{spec.name} needs push_vec (the filtered-"
                         f"gradient field) on the flat path")

    # ---- execute stages; each returns its own exact pre/post metrics ------
    # only stage 1's contraction can be precomputed: later stages contract
    # the PREVIOUS stage's output, which does not exist until the boundary
    pre = post = None
    for i, stage in enumerate(stages):
        if stage[0] == "coef":
            _, T, c0, c1 = stage
            flat, _, s_pre, s_post = engine.stage(
                flat, T, c0, c1, gram=first_gram if i == 0 else None)
        elif stage[0] == "vec":
            _, cvec = stage
            flat, _, s_pre, s_post = engine.vec_stage(flat, push_vec, cvec)
        else:
            _, lam_r = stage
            flat, _, s_pre, s_post = engine.exact_stage(flat, lam_r)
        pre = s_pre if pre is None else pre
        post = s_post

    if post is None:                        # no consensus stage: metrics only
        pre = jnp.mean(engine.dists_to_mean(flat))
        return flat, state, _metrics(pre, pre, 0.0, 0.0)

    push = dcfg.push and spec.pushes
    return flat, state, _metrics(
        post, pre, pull * pre, lam_t if push else 0.0)
