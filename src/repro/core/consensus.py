"""Soft-consensus family (paper §3 Alg. 1, §7.1) and their DPPF couplings.

Every method produces a consensus target x_C; the round update is
    pull:  x_m <- (1-alpha) x_m + alpha x_C
    push:  x_m <- x_m + lam (x_m - x_A)/||x_m - x_A||        (if DPPF)
For simple_avg + push the two fuse into Eq. 5 (pullpush.pullpush).

Methods:
  simple_avg — x_C = x_A (soft LocalSGD; the paper's DPPF default)
  hard       — x_C = x_A with alpha = 1 (LocalSGD, Stich'19)
  easgd      — elastic center z: x_C = z; z <- z + beta * mean(x_m - z)
  lsgd       — x_C = worker with lowest loss (Teng et al.'19)
  mgrawa     — x_C = sum_m w_m x_m, w_m ∝ 1/||grad_m|| (Dimlioglu'24)
  ddp        — no round-level consensus (per-step gradient averaging,
               handled by the trainer); kept here for completeness.

Remark 1 (paper): DPPF_lsgd with push away from x_A does NOT converge; the
documented fix pushes away from the leader instead (push_from="leader").
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import pullpush as pp

METHODS = ("simple_avg", "hard", "easgd", "lsgd", "mgrawa", "ddp")


def init_state(method, stacked):
    if method == "easgd":
        return {"center": pp.tree_mean0(stacked)}
    return {}


def consensus_target(method, stacked, state, *, losses=None, grad_norms=None,
                     easgd_beta=0.9):
    """Returns (x_C tree [no worker dim] or stacked, new_state, leader_idx)."""
    if method in ("simple_avg", "hard"):
        return pp.tree_mean0(stacked), state, None
    if method == "easgd":
        z = state["center"]
        xa = pp.tree_mean0(stacked)
        z_new = jax.tree.map(
            lambda zc, a: zc + easgd_beta * (a - zc), z, xa)
        return z_new, {"center": z_new}, None
    if method == "lsgd":
        assert losses is not None, "lsgd needs per-worker losses"
        idx = jnp.argmin(losses)
        leader = jax.tree.map(lambda a: a.astype(jnp.float32)[idx], stacked)
        return leader, state, idx
    if method == "mgrawa":
        assert grad_norms is not None, "mgrawa needs per-worker grad norms"
        w = 1.0 / jnp.maximum(grad_norms, 1e-12)
        w = w / jnp.sum(w)
        target = jax.tree.map(
            lambda a: jnp.tensordot(w, a.astype(jnp.float32), axes=(0, 0)),
            stacked)
        return target, state, None
    raise ValueError(method)


def apply_round(stacked, dcfg, lam_t, state, *, losses=None, grad_norms=None,
                push_from="average"):
    """One communication round. Returns (stacked, state, metrics)."""
    method = dcfg.consensus
    alpha = 1.0 if method == "hard" else dcfg.alpha

    if method == "ddp":
        return stacked, state, {"consensus_dist": pp.worker_dists(stacked).mean()}

    if method == "simple_avg" and dcfg.push and not dcfg.exact_second_term \
            and push_from == "average":
        new, metrics = pp.pullpush(stacked, alpha, lam_t, dcfg.eps)
        return new, state, metrics

    target, state, leader_idx = consensus_target(
        method, stacked, state, losses=losses, grad_norms=grad_norms)
    new = pp.pull_only(stacked, target, alpha)

    metrics = {}
    if dcfg.push:
        if dcfg.exact_second_term:
            new = pp.exact_push(new, lam_t * pp.worker_dists(new).shape[0],
                                dcfg.eps)
        elif push_from == "leader" and leader_idx is not None:
            leader = jax.tree.map(lambda a: a.astype(jnp.float32)[leader_idx], new)
            new = pp.push_only(new, lam_t, center=leader, eps=dcfg.eps)
        else:
            new = pp.push_only(new, lam_t, eps=dcfg.eps)
    r = pp.worker_dists(new)
    metrics.update({
        "consensus_dist": jnp.mean(r),
        "pull_force": alpha * jnp.mean(pp.worker_dists(stacked)),
        "push_force": jnp.float32(lam_t if dcfg.push else 0.0),
    })
    return new, state, metrics
