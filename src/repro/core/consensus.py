"""Soft-consensus family (paper §3 Alg. 1, §7.1) and their DPPF couplings.

Every method produces a consensus target x_C; the round update is
    pull:  x_m <- (1-alpha) x_m + alpha x_C
    push:  x_m <- x_m + lam (x_m - x_A)/||x_m - x_A||        (if DPPF)
For simple_avg + push the two fuse into Eq. 5 (pullpush.pullpush).

Methods:
  simple_avg — x_C = x_A (soft LocalSGD; the paper's DPPF default)
  hard       — x_C = x_A with alpha = 1 (LocalSGD, Stich'19)
  easgd      — elastic center z: x_C = z; z <- z + beta * mean(x_m - z)
  lsgd       — x_C = worker with lowest loss (Teng et al.'19)
  mgrawa     — x_C = sum_m w_m x_m, w_m ∝ 1/||grad_m|| (Dimlioglu'24)
  ddp        — no round-level consensus (per-step gradient averaging,
               handled by the trainer); kept here for completeness.

``apply_round`` is the single entry point. With ``engine=None`` it runs the
stacked-pytree reference path (the parity oracle); with a
``repro.core.engine.ConsensusEngine`` it lowers the method to one or two
(target-weights, coefficient) stages over the persistent flat view — the
production hot path (DESIGN.md §Consensus-engine). Both paths emit the SAME
metrics pytree from every branch (stable under ``lax.scan``/loggers):
``consensus_dist``, ``pre_dist``, ``pull_force``, ``push_force``.

The flat lowering also runs under a mapped axis (``jax.shard_map``): with
``engine.shard`` set, ``params`` is the full-R-row LOCAL column shard
``(R, n_local)`` and the stages' column contractions psum over the shard's
column axes inside the engine. The lowering itself is shard-oblivious —
target weights, coefficients, and the (R, R) mixing are replicated math —
but ``losses``/``grad_norms`` must then be the GLOBAL (M,) vectors
(all-gathered over the worker axes by the sharded trainer), since lsgd's
argmin and mgrawa's weights are fleet-wide reductions
(DESIGN.md §Sharded-execution).

Remark 1 (paper): DPPF_lsgd with push away from x_A does NOT converge; the
documented fix pushes away from the leader instead (push_from="leader").
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import pullpush as pp

METHODS = ("simple_avg", "hard", "easgd", "lsgd", "mgrawa", "ddp")

EASGD_BETA = 0.9  # elastic-center step (paper §7.1 baseline setting)


def init_state(method, stacked, *, engine=None):
    """Per-method consensus state. With a flat engine, row-shaped state
    (easgd's center) lives in the flat buffer's aux rows instead."""
    if engine is not None:
        return {}
    if method == "easgd":
        return {"center": pp.tree_mean0(stacked)}
    return {}


def consensus_target(method, stacked, state, *, losses=None, grad_norms=None,
                     easgd_beta=EASGD_BETA):
    """Returns (x_C tree [no worker dim] or stacked, new_state, leader_idx)."""
    if method in ("simple_avg", "hard"):
        return pp.tree_mean0(stacked), state, None
    if method == "easgd":
        z = state["center"]
        xa = pp.tree_mean0(stacked)
        z_new = jax.tree.map(
            lambda zc, a: zc + easgd_beta * (a - zc), z, xa)
        return z_new, {"center": z_new}, None
    if method == "lsgd":
        if losses is None:
            # ValueError, not assert: user-facing path, must survive -O
            raise ValueError("lsgd needs per-worker losses")
        idx = jnp.argmin(losses)
        leader = jax.tree.map(lambda a: a.astype(jnp.float32)[idx], stacked)
        return leader, state, idx
    if method == "mgrawa":
        if grad_norms is None:
            raise ValueError("mgrawa needs per-worker grad norms")
        w = 1.0 / jnp.maximum(grad_norms, 1e-12)
        w = w / jnp.sum(w)
        target = jax.tree.map(
            lambda a: jnp.tensordot(w, a.astype(jnp.float32), axes=(0, 0)),
            stacked)
        return target, state, None
    raise ValueError(method)


def _metrics(consensus_dist, pre_dist, pull_force, push_force):
    """The ONE metrics schema every branch of every path emits."""
    return {
        "consensus_dist": jnp.asarray(consensus_dist, jnp.float32),
        "pre_dist": jnp.asarray(pre_dist, jnp.float32),
        "pull_force": jnp.asarray(pull_force, jnp.float32),
        "push_force": jnp.asarray(push_force, jnp.float32),
    }


def apply_round(params, dcfg, lam_t, state, *, losses=None, grad_norms=None,
                push_from="average", engine=None, first_gram=None, mask=None):
    """One communication round. Returns (params, state, metrics).

    ``params`` is a worker-stacked pytree (tree path) or the engine's flat
    ``(R, n)`` view (flat path). Metrics keys are identical either way.
    ``first_gram`` (flat path only) is a precomputed column contraction
    for the FIRST stage — the summed ``engine.stage_comm`` chunks the
    double-buffered overlap dispatches mid-scan; the stage then runs its
    coefficient math + mixing only (DESIGN.md §Overlap). ``mask`` (flat
    path only) is the elastic participation vector ``(M,)`` — inactive
    worker rows drop out of every target-weight combination AND have their
    pull/push coefficients zeroed, so their rows pass through the mixing
    bit-exactly unchanged (DESIGN.md §Overlap, elastic membership).
    """
    if engine is not None:
        return _apply_round_flat(engine, params, dcfg, lam_t, state,
                                 losses=losses, grad_norms=grad_norms,
                                 push_from=push_from, first_gram=first_gram,
                                 mask=mask)
    if first_gram is not None:
        raise ValueError("first_gram requires the flat engine")
    if mask is not None:
        raise ValueError("elastic mask requires the flat engine")
    return _apply_round_tree(params, dcfg, lam_t, state, losses=losses,
                             grad_norms=grad_norms, push_from=push_from)


# ---------------------------------------------------------------------------
# Reference path: stacked pytrees (the flat engine's parity oracle)
# ---------------------------------------------------------------------------

def _apply_round_tree(stacked, dcfg, lam_t, state, *, losses, grad_norms,
                      push_from):
    method = dcfg.consensus
    alpha = 1.0 if method == "hard" else dcfg.alpha

    if method == "ddp":
        r = pp.worker_dists(stacked).mean()
        return stacked, state, _metrics(r, r, 0.0, 0.0)

    if method == "simple_avg" and dcfg.push and not dcfg.exact_second_term \
            and push_from == "average":
        new, metrics = pp.pullpush(stacked, alpha, lam_t, dcfg.eps)
        return new, state, _metrics(**{k: metrics[k] for k in (
            "consensus_dist", "pre_dist", "pull_force", "push_force")})

    target, state, leader_idx = consensus_target(
        method, stacked, state, losses=losses, grad_norms=grad_norms)
    pre = jnp.mean(pp.worker_dists(stacked))
    new = pp.pull_only(stacked, target, alpha)

    if dcfg.push:
        if dcfg.exact_second_term:
            new = pp.exact_push(new, lam_t * pp.worker_dists(new).shape[0],
                                dcfg.eps)
        elif push_from == "leader" and leader_idx is not None:
            leader = jax.tree.map(lambda a: a.astype(jnp.float32)[leader_idx],
                                  new)
            new = pp.push_only(new, lam_t, center=leader, eps=dcfg.eps)
        else:
            new = pp.push_only(new, lam_t, eps=dcfg.eps)
    post = jnp.mean(pp.worker_dists(new))
    return new, state, _metrics(post, pre, alpha * pre,
                                lam_t if dcfg.push else 0.0)


# ---------------------------------------------------------------------------
# Flat path: thin method -> (target-weights, c0, c1) lowering over the engine
# ---------------------------------------------------------------------------

def lower_stages(engine, dcfg, lam_t, *, losses=None, grad_norms=None,
                 push_from="average", mask=None):
    """Lower a consensus method to its flat-engine stage list.

    Returns ``(stages, alpha)`` with each stage ``("coef", T, c0, c1)`` (a
    fused target-weight + coefficient mixing stage) or ``("exact", lam_r)``
    (the Appendix E.1 two-term push). An empty list means ddp (metrics
    only). Public so the double-buffered trainer can read stage 1's target
    weights BEFORE the scan — the mid-scan ``stage_comm`` chunks need T1 —
    and then execute the identical list via ``apply_round(...,
    first_gram=...)`` (the lowering is a pure function of its inputs, so
    lowering twice is free trace-time work).

    ``mask`` is the elastic participation vector ``(M,)`` (1 = active):
    the row-stochastic target weights renormalize over ACTIVE rows only
    (uniform and mgrawa weights re-sum to one, lsgd's argmin skips
    inactive losses, easgd's center pulls toward the active mean) and
    every coefficient vector's inactive worker entries are zeroed, so an
    inactive row neither contributes to nor receives the consensus — its
    flat-view row passes through each mixing stage bit-exactly.
    """
    method = dcfg.consensus
    alpha = 1.0 if method == "hard" else dcfg.alpha
    L = engine.layout
    M, R = L.M, L.R
    eye = jnp.eye(R, dtype=jnp.float32)
    u = engine.uniform                       # (R,) worker mean weights
    zeros = jnp.zeros((R,), jnp.float32)
    act = gate = None
    if mask is not None:
        act = jnp.asarray(mask, jnp.float32)             # (M,) 1 = active
        mfull = zeros.at[:M].set(act)
        # masked uniform: the worker mean over active rows only
        u = mfull / jnp.maximum(jnp.sum(mfull), 1.0)
        # coefficient gate: inactive worker rows get zero pull/push; aux
        # rows always participate (easgd's center keeps tracking)
        gate = jnp.ones((R,), jnp.float32).at[:M].set(act)

    def worker_T(w):
        """All worker rows target the combination w; aux rows stay put."""
        T = jnp.broadcast_to(w, (R, R))
        if L.aux:
            T = jnp.concatenate([T[:M], eye[M:]], axis=0)
        return T

    # ---- method -> stage list ---------------------------------------------
    stages = []      # ("coef", T, c0, c1) | ("exact", lam_r)
    leader_w = None
    if method != "ddp":
        c_pull = zeros.at[:M].set(alpha)
        if method == "simple_avg" and dcfg.push and not dcfg.exact_second_term \
                and push_from == "average":
            # Eq. 5: pull and push share the x_A target -> ONE fused stage
            stages.append(("coef", worker_T(u), c_pull,
                           zeros.at[:M].set(-lam_t)))
        else:
            if method in ("simple_avg", "hard"):
                T1 = worker_T(u)
            elif method == "easgd":
                # every row targets z_new = (1-beta) z + beta x_A; the aux
                # row adopts it exactly (coef 1) — the center update and the
                # worker pull are ONE mixing stage
                w_z = EASGD_BETA * u + (1.0 - EASGD_BETA) * eye[M]
                T1 = jnp.broadcast_to(w_z, (R, R))
                c_pull = c_pull.at[M:].set(1.0)
            elif method == "lsgd":
                if losses is None:
                    raise ValueError("lsgd needs per-worker losses")
                lsgd_losses = losses
                if act is not None:
                    # inactive rows can't lead: their (frozen-iterate)
                    # losses are masked out of the argmin
                    lsgd_losses = jnp.where(act > 0, losses, jnp.inf)
                leader_w = jax.nn.one_hot(jnp.argmin(lsgd_losses), R,
                                          dtype=jnp.float32)
                T1 = worker_T(leader_w)
            elif method == "mgrawa":
                if grad_norms is None:
                    raise ValueError("mgrawa needs grad norms")
                w = 1.0 / jnp.maximum(grad_norms, 1e-12)
                if act is not None:
                    w = w * act
                w = w / jnp.maximum(jnp.sum(w), 1e-12)
                T1 = worker_T(zeros.at[:M].set(w))
            else:
                raise ValueError(method)
            stages.append(("coef", T1, c_pull, zeros))
            if dcfg.push:
                if dcfg.exact_second_term:
                    stages.append(("exact", lam_t * M))
                elif push_from == "leader" and leader_w is not None:
                    stages.append(("coef", worker_T(leader_w), zeros,
                                   zeros.at[:M].set(-lam_t)))
                else:
                    stages.append(("coef", worker_T(u), zeros,
                                   zeros.at[:M].set(-lam_t)))
    if gate is not None:
        if any(s[0] == "exact" for s in stages):
            raise ValueError("elastic mask does not support "
                             "exact_second_term stages")
        stages = [("coef", T, c0 * gate, c1 * gate)
                  for (_, T, c0, c1) in stages]
    return stages, alpha


def _apply_round_flat(engine, flat, dcfg, lam_t, state, *, losses, grad_norms,
                      push_from, first_gram=None, mask=None):
    if engine.eps != dcfg.eps:
        # the engine's norm guard must match the config's (tree-path parity)
        engine = dataclasses.replace(engine, eps=dcfg.eps)
    stages, alpha = lower_stages(engine, dcfg, lam_t, losses=losses,
                                 grad_norms=grad_norms, push_from=push_from,
                                 mask=mask)
    if first_gram is not None and (not stages or stages[0][0] != "coef"):
        raise ValueError("first_gram requires a leading coefficient stage "
                         "(every non-ddp lowering has one)")

    # ---- execute stages; each returns its own exact pre/post metrics ------
    # only stage 1's contraction can be precomputed: later stages contract
    # the PREVIOUS stage's output, which does not exist until the boundary
    pre = post = None
    for i, stage in enumerate(stages):
        if stage[0] == "coef":
            _, T, c0, c1 = stage
            flat, _, s_pre, s_post = engine.stage(
                flat, T, c0, c1, gram=first_gram if i == 0 else None)
        else:
            _, lam_r = stage
            flat, _, s_pre, s_post = engine.exact_stage(flat, lam_r)
        pre = s_pre if pre is None else pre
        post = s_post

    if post is None:                                  # ddp: metrics only
        pre = jnp.mean(engine.dists_to_mean(flat))
        return flat, state, _metrics(pre, pre, 0.0, 0.0)

    return flat, state, _metrics(
        post, pre, alpha * pre, lam_t if dcfg.push else 0.0)
