"""MethodSpec registry: every flat-minima consensus method as DATA.

The consensus layer used to hard-code each method as an if/elif branch in
``core/consensus.py`` with per-method special cases leaking into
``core/engine.py`` (aux-row counts) and ``train/trainer.py`` (mask
gating, state plumbing) — an N-file edit per new method. A ``MethodSpec``
declares everything the generic lowering needs:

* **target-weight rule** — ``weight_fn(ctx) -> (R,)``: the row-stochastic
  combination the worker rows pull toward.  ``None`` means the method has
  no round-level consensus stage (ddp: per-step gradient averaging,
  metrics only).  Participation-mask semantics live INSIDE the rule (the
  ``ctx`` carries the active mask): lsgd's argmin skips inactive losses,
  (m)grawa renormalizes over active rows, uniform rules read the
  pre-masked ``ctx.u``.
* **aux-row contract** — ``aux_rows``/``aux_pull``/``center_beta``: how
  many extra state rows ride in the flat ``(R, n)`` view and how they
  move.  ``center_beta > 0`` makes every row target the updated elastic
  center ``z' = beta * (w . x) + (1 - beta) * z`` (EASGD / Parle), with
  the aux row adopting it at coefficient ``aux_pull``.
* **coefficient stages** — ``hard_pull`` (alpha := 1), ``fuse_eq5``
  (pull+push share the mean target: ONE fused Eq. 5 stage), ``pushes``
  (whether ``dcfg.push`` applies at all), ``leader`` (the rule emits a
  leader one-hot, enabling ``push_from="leader"``), ``pull_ramp``
  (Parle's replica-coupling schedule: the pull coefficient ramps with
  ``lam_t / lam``), ``push_source`` (``"params"`` pushes along
  ``x_m - x_A``; ``"filtered_grad"`` pushes along the EMA-filtered
  gradient carried in the train state — LPF-SGD).
* **loss / gradient inputs** — ``needs_losses``/``needs_grad_norms``.
* **inner/outer round plan** — ``inner_rounds``/``inner_pull``:
  Entropy-SGD's local-entropy inner loop as a tau-scheduled plan: the
  ``RoundClock`` splits each round into ``inner_rounds`` sub-rounds whose
  non-final pieces scale the pull by ``inner_pull`` (weak coupling =
  local-entropy exploration), the final piece applies the full pull.
* **state** — ``filter_mu``: EMA coefficient of the filtered-gradient
  buffer (``TrainState.cstate["g_ema"]``), 0 = no buffer.
  ``requires_flat``: the method lowers only on the flat engine.

``core/consensus.py`` consumes specs generically (one lowering for all
methods); ``core/engine.py`` reads ``aux_rows``; ``train/clock.py`` reads
the inner plan; ``launch/train.py`` generates ``--method`` from
``method_names()``.  Adding a method is one ``register()`` call in THIS
file (DESIGN.md §Method-registry).

Methods registered here (canonical name first, aliases after):

  simple_avg (dppf) — pull to the worker mean + unit push away (Eq. 5)
  hard              — LocalSGD: hard parameter averaging (alpha = 1)
  easgd             — elastic averaging around a center aux row
  lsgd              — leader (lowest-loss worker) pull
  mgrawa (grawa)    — gradient-norm-weighted averaging
  ddp               — per-step gradient averaging; no round consensus
  parle             — elastic-averaging ensemble: center aux row +
                      replica-coupling schedule (pull ramps with lam_t)
  lpf_sgd           — mean pull + push along the EMA-filtered gradient
  entropy_sgd       — local-entropy inner loop as weak-pull sub-rounds
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import pullpush as pp

EASGD_BETA = 0.9    # elastic-center step (paper §7.1 baseline setting)
PARLE_BETA = 0.5    # Parle couples replicas harder than EASGD's 0.9 mean
LPF_MU = 0.9        # LPF-SGD gradient-EMA coefficient (Bisla et al.)
ENTROPY_INNER_ROUNDS = 2   # Entropy-SGD: inner exploration + outer pull
ENTROPY_INNER_PULL = 0.25  # weak coupling of the non-final sub-rounds

PUSH_SOURCES = ("params", "filtered_grad")


@dataclasses.dataclass(frozen=True)
class WeightCtx:
    """Inputs a target-weight rule may read (all replicated math)."""
    M: int
    R: int
    eye: Any                    # (R, R) fp32 identity
    u: Any                      # (R,) uniform over ACTIVE worker rows
    zeros: Any                  # (R,) fp32 zeros
    act: Any = None             # (M,) participation mask (1 = active) | None
    losses: Any = None          # (M,) per-worker losses | None
    grad_norms: Any = None      # (M,) per-worker grad norms | None


def _w_uniform(ctx: WeightCtx):
    return ctx.u


def _w_leader(ctx: WeightCtx):
    losses = ctx.losses
    if ctx.act is not None:
        # inactive rows can't lead: their (frozen-iterate) losses are
        # masked out of the argmin
        losses = jnp.where(ctx.act > 0, losses, jnp.inf)
    return jax.nn.one_hot(jnp.argmin(losses), ctx.R, dtype=jnp.float32)


def _w_gradnorm(ctx: WeightCtx):
    w = 1.0 / jnp.maximum(ctx.grad_norms, 1e-12)
    if ctx.act is not None:
        w = w * ctx.act
    w = w / jnp.maximum(jnp.sum(w), 1e-12)
    return ctx.zeros.at[:ctx.M].set(w)


# -- tree-path targets (the flat engine's parity oracles) -------------------

def _t_mean(spec, stacked, state, *, losses, grad_norms):
    return pp.tree_mean0(stacked), state, None


def _t_center(spec, stacked, state, *, losses, grad_norms):
    z = state["center"]
    xa = pp.tree_mean0(stacked)
    z_new = jax.tree.map(
        lambda zc, a: zc + spec.center_beta * (a - zc), z, xa)
    return z_new, {"center": z_new}, None


def _t_leader(spec, stacked, state, *, losses, grad_norms):
    if losses is None:
        # ValueError, not assert: user-facing path, must survive -O
        raise ValueError(f"{spec.name} needs per-worker losses")
    idx = jnp.argmin(losses)
    leader = jax.tree.map(lambda a: a.astype(jnp.float32)[idx], stacked)
    return leader, state, idx


def _t_gradnorm(spec, stacked, state, *, losses, grad_norms):
    if grad_norms is None:
        raise ValueError(f"{spec.name} needs per-worker grad norms")
    w = 1.0 / jnp.maximum(grad_norms, 1e-12)
    w = w / jnp.sum(w)
    target = jax.tree.map(
        lambda a: jnp.tensordot(w, a.astype(jnp.float32), axes=(0, 0)),
        stacked)
    return target, state, None


def _t_flat_only(spec, stacked, state, *, losses, grad_norms):
    raise ValueError(f"{spec.name} requires the flat engine "
                     f"(set engine='flat')")


@dataclasses.dataclass(frozen=True)
class MethodSpec:
    """One consensus method, declaratively (hashable, jit-static)."""
    name: str
    doc: str                           # one-liner (CLI help, README table)
    flags: str = ""                    # README table: notable knobs
    weight_fn: Optional[Callable] = None   # None = no consensus stage (ddp)
    tree_target: Optional[Callable] = None
    needs_losses: bool = False
    needs_grad_norms: bool = False
    hard_pull: bool = False            # alpha := 1 (LocalSGD)
    pull_ramp: bool = False            # pull scales by lam_t / lam (Parle)
    leader: bool = False               # weight_fn emits a leader one-hot
    aux_rows: int = 0                  # extra state rows in the flat view
    aux_pull: float = 0.0              # aux rows' pull coefficient
    center_beta: float = 0.0           # >0: rows target the elastic center
    pushes: bool = True                # dcfg.push applies to this method
    fuse_eq5: bool = False             # pull+push fuse into one Eq.5 stage
    push_source: str = "params"        # "params" | "filtered_grad"
    filter_mu: float = 0.0             # EMA coef of cstate["g_ema"] (LPF)
    inner_rounds: int = 0              # >1: split rounds (Entropy-SGD)
    inner_pull: float = 1.0            # pull scale of non-final sub-rounds
    requires_flat: bool = False        # no tree path (flat engine only)

    def __post_init__(self):
        # ValueError, not assert: the registry is user-extensible config
        # surface and must validate under ``python -O``
        if self.aux_rows < 0:
            raise ValueError(f"{self.name}: aux_rows must be >= 0, got "
                             f"{self.aux_rows}")
        if self.aux_pull and not self.aux_rows:
            raise ValueError(f"{self.name}: aux_pull={self.aux_pull} needs "
                             f"aux_rows >= 1 (no aux row to pull)")
        if self.center_beta and not self.aux_rows:
            raise ValueError(f"{self.name}: center_beta={self.center_beta} "
                             f"needs aux_rows >= 1 (the center IS an aux "
                             f"row)")
        if not 0.0 <= self.center_beta <= 1.0:
            raise ValueError(f"{self.name}: center_beta must be in [0, 1], "
                             f"got {self.center_beta}")
        if self.push_source not in PUSH_SOURCES:
            raise ValueError(f"{self.name}: unknown push_source "
                             f"{self.push_source!r} (expected one of "
                             f"{PUSH_SOURCES})")
        if not 0.0 <= self.filter_mu < 1.0:
            raise ValueError(f"{self.name}: filter_mu must be in [0, 1), "
                             f"got {self.filter_mu}")
        if self.inner_rounds < 0:
            raise ValueError(f"{self.name}: inner_rounds must be >= 0, got "
                             f"{self.inner_rounds}")
        if not 0.0 < self.inner_pull <= 1.0:
            raise ValueError(f"{self.name}: inner_pull must be in (0, 1], "
                             f"got {self.inner_pull}")
        if self.push_source == "filtered_grad" and not self.filter_mu:
            raise ValueError(f"{self.name}: push_source='filtered_grad' "
                             f"needs filter_mu > 0 (the EMA buffer)")

    @property
    def communicates(self) -> bool:
        """Whether the method has a round-level consensus stage at all."""
        return self.weight_fn is not None


_REGISTRY: dict = {}
_ALIASES: dict = {}


def register(spec: MethodSpec, *, aliases: Tuple[str, ...] = ()) -> MethodSpec:
    if spec.name in _REGISTRY or spec.name in _ALIASES:
        raise ValueError(f"method {spec.name!r} already registered")
    _REGISTRY[spec.name] = spec
    for a in aliases:
        if a in _REGISTRY or a in _ALIASES:
            raise ValueError(f"method alias {a!r} already registered")
        _ALIASES[a] = spec.name
    return spec


def get_method(name: str) -> MethodSpec:
    """Resolve a method (or alias) to its spec; ValueError on unknown."""
    spec = _REGISTRY.get(_ALIASES.get(name, name))
    if spec is None:
        raise ValueError(f"unknown consensus method {name!r} (registered: "
                         f"{', '.join(method_names())})")
    return spec


def method_names(*, aliases: bool = True) -> Tuple[str, ...]:
    """Registered names in registration order (canonical first)."""
    names = tuple(_REGISTRY)
    return names + tuple(sorted(_ALIASES)) if aliases else names


def tree_method_names() -> Tuple[str, ...]:
    """Canonical methods with a stacked-pytree (tree) reference path —
    the flat engine's parity-oracle set."""
    return tuple(n for n, s in _REGISTRY.items() if not s.requires_flat)


register(MethodSpec(
    name="simple_avg",
    doc="DPPF soft consensus: pull to the worker mean + unit push away "
        "(paper Eq. 5, fused into one stage)",
    flags="fuses pull+push",
    weight_fn=_w_uniform, tree_target=_t_mean, fuse_eq5=True,
), aliases=("dppf",))

register(MethodSpec(
    name="hard",
    doc="LocalSGD: hard parameter averaging (alpha = 1; Stich'19)",
    flags="alpha forced to 1",
    weight_fn=_w_uniform, tree_target=_t_mean, hard_pull=True,
))

register(MethodSpec(
    name="easgd",
    doc="elastic averaging around a center z (Zhang et al.'15); z rides "
        "in the flat view's aux row",
    flags="center aux row (beta=%.2g)" % EASGD_BETA,
    weight_fn=_w_uniform, tree_target=_t_center,
    aux_rows=1, aux_pull=1.0, center_beta=EASGD_BETA,
))

register(MethodSpec(
    name="lsgd",
    doc="leader SGD: pull to the lowest-loss worker (Teng et al.'19); "
        "push_from='leader' is the paper's Remark 1 fix",
    flags="needs losses; leader push",
    weight_fn=_w_leader, tree_target=_t_leader,
    needs_losses=True, leader=True,
))

register(MethodSpec(
    name="mgrawa",
    doc="gradient-norm-weighted averaging, w_m ∝ 1/||grad_m|| "
        "(Dimlioglu'24)",
    flags="needs grad norms",
    weight_fn=_w_gradnorm, tree_target=_t_gradnorm, needs_grad_norms=True,
), aliases=("grawa",))

register(MethodSpec(
    name="ddp",
    doc="no round-level consensus (per-step gradient averaging in the "
        "trainer); metrics only",
    flags="no consensus stage",
))

register(MethodSpec(
    name="parle",
    doc="Parle elastic-averaging ensemble (Chaudhari et al.'17): center "
        "aux row + replica-coupling schedule (pull ramps with lam_t)",
    flags="center aux row; pull ramps with lam schedule; no push",
    weight_fn=_w_uniform, tree_target=_t_center,
    aux_rows=1, aux_pull=1.0, center_beta=PARLE_BETA,
    pull_ramp=True, pushes=False,
))

register(MethodSpec(
    name="lpf_sgd",
    doc="LPF-SGD (Bisla et al.'22): mean pull + push along the "
        "EMA-filtered gradient carried in TrainState",
    flags="flat engine only; g_ema state (mu=%.2g)" % LPF_MU,
    weight_fn=_w_uniform, tree_target=_t_flat_only,
    push_source="filtered_grad", filter_mu=LPF_MU, requires_flat=True,
))

register(MethodSpec(
    name="entropy_sgd",
    doc="Entropy-SGD (Chaudhari et al.'16): local-entropy inner loop as "
        "weak-pull sub-rounds on the RoundClock's inner/outer plan",
    flags="inner/outer round plan (%d sub-rounds); no push"
         % ENTROPY_INNER_ROUNDS,
    weight_fn=_w_uniform, tree_target=_t_mean, pushes=False,
    inner_rounds=ENTROPY_INNER_ROUNDS, inner_pull=ENTROPY_INNER_PULL,
))
