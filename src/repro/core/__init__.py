"""DPPF core: the paper's contribution (pull-push consensus, MV measure,
sharpness baselines, schedules, theory validation, FL couplings)."""
from repro.core import (
    consensus, engine, fl, pullpush, schedules, sharpness, theory, valley,
)

__all__ = ["consensus", "engine", "fl", "pullpush", "schedules", "sharpness",
           "theory", "valley"]
