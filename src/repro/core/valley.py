"""Mean Valley / Inverse Mean Valley sharpness measure (paper §4, Alg. 2).

Offline analysis tool: given converged worker parameters, line-search from
the average x_A along each worker direction until the train loss reaches
kappa * L_A; MV is the mean boundary distance, Inv. MV its additive inverse.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def normalize_params(tree):
    """Scale-invariance normalization (paper B.1, following Bisla'22):
    every leaf is rescaled to unit Frobenius norm (norm-1 leaves left as-is
    guards: zero leaves untouched)."""
    def leaf(a):
        n = jnp.sqrt(jnp.sum(jnp.square(a.astype(jnp.float32))))
        return jnp.where(n > 0, a / n, a).astype(a.dtype)
    return jax.tree.map(leaf, tree)


def _axpy(x, d, t):
    return jax.tree.map(lambda a, b: (a.astype(jnp.float32)
                                      + t * b.astype(jnp.float32)), x, d)


def _tree_norm(t):
    return float(jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                              for l in jax.tree.leaves(t))))


def mean_valley(loss_fn, workers, *, kappa=2.0, step=0.1, max_steps=200,
                normalize=False, bisect_iters=25):
    """Algorithm 2. ``workers``: list of parameter pytrees (one per worker);
    ``loss_fn(params) -> scalar`` evaluates the train loss (full data or a
    fixed large batch).

    The coarse line-search only BRACKETS the kappa-contour crossing; the
    crossing itself is refined with ``bisect_iters`` of bisection inside
    the bracketing step, so MV is not quantized to the coarse ``step``. A
    direction whose loss never reaches ``kappa * L_A`` within
    ``max_steps * step`` saturates at that boundary and is flagged in the
    returned per-worker ``hit_boundary`` list (previously this saturation
    was silent and indistinguishable from a true crossing).

    Returns dict with mv, inv_mv, per-worker betas, per-worker
    hit_boundary flags, loss_at_avg, kappa.
    """
    if normalize:
        workers = [normalize_params(w) for w in workers]
    M = len(workers)
    x_a = jax.tree.map(lambda *ls: sum(l.astype(jnp.float32) for l in ls) / M,
                       *workers)
    l_a = float(loss_fn(x_a))
    target = kappa * l_a
    loss_jit = jax.jit(loss_fn)

    betas, hit_boundary = [], []
    for w in workers:
        d = jax.tree.map(lambda a, c: a.astype(jnp.float32) - c, w, x_a)
        n = _tree_norm(d)
        if n == 0.0:
            betas.append(0.0)
            hit_boundary.append(False)
            continue
        d = jax.tree.map(lambda a: a / n, d)
        beta, hit = 0.0, True
        for _ in range(max_steps):
            beta += step
            if float(loss_jit(_axpy(x_a, d, beta))) >= target:
                hit = False
                lo, hi = beta - step, beta   # bracket: L(lo) < target <= L(hi)
                for _ in range(bisect_iters):
                    mid = 0.5 * (lo + hi)
                    if float(loss_jit(_axpy(x_a, d, mid))) >= target:
                        hi = mid
                    else:
                        lo = mid
                beta = 0.5 * (lo + hi)
                break
        betas.append(beta)
        hit_boundary.append(hit)
    mv = float(np.mean(betas))
    return {"mv": mv, "inv_mv": -mv, "betas": betas,
            "hit_boundary": hit_boundary, "loss_at_avg": l_a,
            "kappa": kappa}
