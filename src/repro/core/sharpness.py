"""Sharpness measures compared against Inv. MV in paper Table 1 / B.1:
Shannon entropy, epsilon-sharpness, Fisher-Rao, LPF, and Hessian-based
(lambda_max / trace / Frobenius via HVP + Lanczos / Hutchinson).
All take ``loss_fn(params, batch)`` and/or ``logit_fn(params, batch)``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _flat(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in leaves])


def _unflat(vec, tree):
    out, i = [], 0
    leaves, treedef = jax.tree.flatten(tree)
    for l in leaves:
        n = l.size
        out.append(vec[i:i + n].reshape(l.shape).astype(l.dtype))
        i += n
    return jax.tree.unflatten(treedef, out)


# ---------------------------------------------------------------------------

def shannon_entropy(logit_fn, params, batches):
    """Negative mean output entropy (confident nets ~ overfit; B.1)."""
    total, n = 0.0, 0
    for b in batches:
        p = jax.nn.softmax(logit_fn(params, b), axis=-1)
        ent = -jnp.sum(p * jnp.log(jnp.maximum(p, 1e-12)), axis=-1)
        total += float(jnp.sum(ent))
        n += int(np.prod(ent.shape))
    return -total / max(n, 1)


def eps_sharpness(loss_fn, params, batch, eps=1e-3, steps=5):
    """Keskar'16-style: max loss in an eps-box via projected ascent,
    normalized: (max - L) / (1 + L) * 100."""
    l0 = float(loss_fn(params, batch))
    grad_fn = jax.jit(jax.grad(loss_fn))
    x = _flat(params)
    box = eps * (jnp.abs(x) + 1.0)
    pert = jnp.zeros_like(x)
    for _ in range(steps):
        g = _flat(grad_fn(_unflat(x + pert, params), batch))
        pert = jnp.clip(pert + eps * jnp.sign(g) * box, -box, box)
    lmax = float(loss_fn(_unflat(x + pert, params), batch))
    return (lmax - l0) / (1.0 + l0) * 100.0


def hvp_fn(loss_fn, params, batch):
    g = lambda p: jax.grad(loss_fn)(p, batch)
    def hvp(v_tree):
        return jax.jvp(g, (params,), (v_tree,))[1]
    return jax.jit(hvp)


def fisher_rao(loss_fn, params, batch):
    """<x, Hx> approximation of the Fisher-Rao norm (Liang'19)."""
    hvp = hvp_fn(loss_fn, params, batch)
    hx = hvp(params)
    return float(sum(jnp.sum(a.astype(jnp.float32) * b.astype(jnp.float32))
                     for a, b in zip(jax.tree.leaves(params),
                                     jax.tree.leaves(hx))))


def lpf(loss_fn, params, batch, key, sigma=0.01, mcmc=20):
    """Low-pass-filtered loss (Bisla'22): E_{e~N(0, sigma I)} L(x + e)."""
    x = _flat(params)
    total = 0.0
    for i in range(mcmc):
        k = jax.random.fold_in(key, i)
        e = sigma * jax.random.normal(k, x.shape)
        total += float(loss_fn(_unflat(x + e, params), batch))
    return total / mcmc


def lanczos(hvp, dim, key, iters=20):
    """Lanczos tridiagonalization of the Hessian (via HVP). Returns Ritz
    values (approx extreme eigenvalues)."""
    v = jax.random.normal(key, (dim,))
    v = v / jnp.linalg.norm(v)
    alphas, betas_l = [], []
    v_prev = jnp.zeros_like(v)
    beta = 0.0
    vecs = []
    for _ in range(iters):
        vecs.append(v)
        w = hvp(v)
        alpha = float(jnp.dot(w, v))
        w = w - alpha * v - beta * v_prev
        # full reorthogonalization (small iters)
        for u in vecs:
            w = w - jnp.dot(w, u) * u
        beta_new = float(jnp.linalg.norm(w))
        alphas.append(alpha)
        if beta_new < 1e-8:
            break
        betas_l.append(beta_new)
        v_prev, v, beta = v, w / beta_new, beta_new
    T = np.diag(alphas)
    for i, b in enumerate(betas_l[:len(alphas) - 1]):
        T[i, i + 1] = T[i + 1, i] = b
    return np.linalg.eigvalsh(T)


def hessian_measures(loss_fn, params, batch, key, lanczos_iters=20,
                     hutchinson=8):
    """lambda_max, trace, and Frobenius-norm estimates of the Hessian."""
    hvp_tree = hvp_fn(loss_fn, params, batch)
    x = _flat(params)
    dim = x.shape[0]

    def hvp_vec(v):
        return _flat(hvp_tree(_unflat(v, params)))

    ritz = lanczos(hvp_vec, dim, key, iters=lanczos_iters)
    lam_max = float(ritz[-1])
    # Hutchinson: trace = E[v^T H v]; frob^2 = E[||Hv||^2], v ~ Rademacher
    tr, fr = 0.0, 0.0
    for i in range(hutchinson):
        k = jax.random.fold_in(key, 1000 + i)
        v = jax.random.rademacher(k, (dim,), dtype=jnp.float32)
        hv = hvp_vec(v)
        tr += float(jnp.dot(v, hv))
        fr += float(jnp.sum(hv * hv))
    return {"lambda_max": lam_max, "trace": tr / hutchinson,
            "frob": float(np.sqrt(fr / hutchinson))}


def kendall_tau(a, b):
    """Kendall rank correlation (paper Table 1 metric)."""
    from scipy.stats import kendalltau
    return float(kendalltau(np.asarray(a), np.asarray(b)).statistic)
