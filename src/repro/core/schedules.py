"""Schedules: push strength lambda (paper §C.2), QSR communication period
(Gu et al. 2024, §7.2), and cosine LR."""
from __future__ import annotations

import math

import jax.numpy as jnp


def lam_schedule(kind: str, lam: float, t, T):
    """Paper §C.2. t: current iteration (traced ok), T: total iterations.
    increasing (the paper's default for main results): flipped cosine."""
    frac = jnp.clip(jnp.asarray(t, jnp.float32) / max(T, 1), 0.0, 1.0)
    if kind == "fixed":
        return jnp.full_like(frac, lam)
    if kind == "decreasing":
        return lam / 2.0 * (1.0 + jnp.cos(frac * math.pi))
    if kind == "increasing":
        return lam / 2.0 * (1.0 - jnp.cos(frac * math.pi))
    raise ValueError(kind)


def qsr_tau(eta_t: float, tau_base: int, beta: float) -> int:
    """Quadratic Synchronization Rule: tau_t = max(tau_base, floor((beta/eta)^2)).
    Host-side (python) — the trainer re-chunks rounds between compiles."""
    if eta_t <= 0:
        return tau_base
    return max(tau_base, int((beta / eta_t) ** 2))


def cosine_lr(base_lr: float, t, T, warmup: int = 0):
    t = jnp.asarray(t, jnp.float32)
    warm = base_lr * t / max(warmup, 1)
    frac = jnp.clip((t - warmup) / max(T - warmup, 1), 0.0, 1.0)
    cos = base_lr / 2.0 * (1.0 + jnp.cos(frac * math.pi))
    return jnp.where(t < warmup, warm, cos)
