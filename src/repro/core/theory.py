"""Theory validation utilities.

Theorem 1: asymptotic valley width lam/alpha (+ O(eta*sigma + 1/sqrt(M))).
Theorem 3 proof recurrence is simulated exactly in `width_recurrence`.
Algorithm 3: 2D landscape scan around x_A via SVD of worker gap vectors
(used for the Fig. 4/5 visualizations).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def predicted_width(alpha: float, lam: float) -> float:
    """Theorem 1 limit."""
    return lam / alpha


def width_upper_bound(alpha, lam, eta, tau, sigma0, M):
    """Eq. 22 of the proof: the full finite-M, finite-eta bound."""
    beta = eta * (1 - alpha) * np.sqrt(tau) * sigma0 * np.sqrt((M + 1) / M)
    gamma = lam * (1 + 1 / np.sqrt(M))
    return (beta + gamma) / alpha


def width_recurrence(alpha, lam, eta, tau, sigma0, M, d=64, rounds=500,
                     seed=0):
    """Simulate the gap recurrence (proof Eq. 16) on random-walk workers:
    Delta+_{k} = (1-a) Delta+_{k-1} - eta (1-a) Z + lam u_m - lam u_bar.
    Returns the empirical ||Delta+|| trajectory mean over workers."""
    rng = np.random.default_rng(seed)
    delta = np.zeros((M, d))
    traj = []
    for _ in range(rounds):
        # local drift: Z_m = Gbar - G_m with G_m ~ N(0, tau sigma0^2 I)
        G = rng.normal(0.0, sigma0 * np.sqrt(tau), size=(M, d))
        Z = G.mean(0, keepdims=True) - G
        drift = delta - eta * Z
        norms = np.linalg.norm(drift, axis=1, keepdims=True)
        u = np.where(norms > 1e-12, drift / np.maximum(norms, 1e-12),
                     rng.normal(size=(M, d)) / np.sqrt(d))
        u = u / np.maximum(np.linalg.norm(u, axis=1, keepdims=True), 1e-12)
        delta = (1 - alpha) * drift + lam * u - lam * u.mean(0, keepdims=True)
        # re-center (gap is relative to the average)
        delta = delta - delta.mean(0, keepdims=True)
        traj.append(np.linalg.norm(delta, axis=1).mean())
    return np.asarray(traj)


# ---------------------------------------------------------------------------
# Algorithm 3: landscape visualization scan
# ---------------------------------------------------------------------------

def _flat(tree):
    return jnp.concatenate([l.reshape(-1).astype(jnp.float32)
                            for l in jax.tree.leaves(tree)])


def _unflat(vec, tree):
    out, i = [], 0
    leaves, treedef = jax.tree.flatten(tree)
    for l in leaves:
        out.append(vec[i:i + l.size].reshape(l.shape).astype(l.dtype))
        i += l.size
    return jax.tree.unflatten(treedef, out)


def landscape_scan(eval_fn, workers, *, lim=1.0, step=0.25):
    """Algorithm 3. eval_fn(params) -> scalar (loss or error %).

    Returns dict with the grid, the 2D scan values, and each worker's
    projected coordinates on the SVD plane centered at x_A."""
    M = len(workers)
    flats = np.stack([np.asarray(_flat(w)) for w in workers])
    x_a = flats.mean(0)
    gaps = flats - x_a[None]
    # top-2 right singular vectors of the gap matrix
    _, _, vt = np.linalg.svd(gaps, full_matrices=False)
    v1, v2 = vt[0], vt[1] if vt.shape[0] > 1 else (vt[0], vt[0])
    coords = np.stack([gaps @ v1, gaps @ v2], axis=1)  # (M, 2)

    grid = np.arange(-lim, lim + step / 2, step)
    scan = np.zeros((len(grid), len(grid)))
    template = workers[0]
    eval_jit = jax.jit(eval_fn)
    for i, a in enumerate(grid):
        for j, b in enumerate(grid):
            p = _unflat(jnp.asarray(x_a + a * v1 + b * v2), template)
            scan[i, j] = float(eval_jit(p))
    return {"grid": grid, "scan": scan, "worker_coords": coords,
            "dirs": (v1, v2)}
