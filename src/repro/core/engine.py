"""ConsensusEngine: flat, one-pass consensus for every DPPF method.

The round-boundary consensus update (paper §5, Eq. 5; Appendix D.1) is the
system's hottest communication path. The tree implementation in
``repro.core.pullpush``/``repro.core.consensus`` walks the full parameter
pytree 2–4 times per round; the original kernel wrapper additionally
re-materialized a flat copy via ``jnp.concatenate`` on every call.

This engine keeps ONE persistent flat view for the whole training run:

* ``flatten`` is called once at ``init_train_state`` — an ``(R, n)`` fp32
  matrix whose first ``M`` rows are the workers and whose optional aux rows
  carry row-shaped consensus state (EASGD's elastic center lives in row
  ``M``). The treedef/shapes/offsets are cached in a static ``FlatLayout``.
* Between rounds the buffer is donated (``jax.jit(..., donate_argnums)``),
  so the round update runs in place — no per-round ``concatenate``.
* Every consensus method lowers to at most two *stages*, each
  ``x <- W @ x`` with ``W = I + diag(coef) (T - I)`` for a row-stochastic
  target-weight matrix ``T`` and ``coef = c0 + c1 / max(r, eps)``:

    method      target weights T (worker rows)     c0       c1
    ----------  ---------------------------------  -------  ------
    simple_avg  uniform 1/M                        alpha    -lam   (Eq. 5, fused)
    hard        uniform 1/M                        1        0
    easgd       beta*u + (1-beta)*e_z  (z = aux)   alpha    0      (+push stage)
    parle       like easgd; pull ramps with lam_t  alpha*s  0      (no push)
    lsgd        one_hot(argmin losses)             alpha    0      (+push stage)
    mgrawa      w_m ∝ 1/||grad_m||                 alpha    0      (+push stage)
    lpf_sgd     uniform 1/M                        alpha    0      (+vec stage)
    entropy_sgd uniform 1/M (inner/outer plan)     alpha*s  0      (no push)
    push stage  uniform 1/M (or leader)            0        -lam
    vec stage   external field (filtered grad)     0        -lam   (vec_stage)
    ddp         (identity; metrics only)

  The per-method table rows are registry entries (`repro.core.methods`);
  the engine itself only ever sees generic stages.

* All distances are zero-sum quadratic forms of the Gram matrix
  ``G = X X^T``: ``||x_i - T_i x||^2 = v^T G v`` with ``v = e_i - T_i``,
  ``sum(v) = 0``. One Gram (one read of X, MXU-friendly) prices every
  worker's distance for any target at once; the apply is one more GEMM.
  The Pallas path (`kernels.pullpush.fused_round`) runs both phases in a
  single ``pallas_call`` with a *block-centered* Gram, which makes the
  zero-sum forms cancellation-free everywhere. The fast jnp path uses the
  uncentered Gram, whose fp32 forms resolve r only down to
  ~sqrt(eps32) * ||x||: stage distances are floored at that resolution
  (GRAM_NOISE_FACTOR), so a collapsed fleet under-pushes, escaping the
  window geometrically instead of pushing along rounding noise — the one
  documented deviation from the tree oracle, transient and only below
  ~0.4% of the parameter norm.
  ``precise=True`` selects exact gap-space stages instead (one extra
  (R, n) buffer per round) for bit-level parity at every scale.

Method semantics (incl. push-from-recomputed-center ordering) mirror
``repro.core.consensus.apply_round``'s tree path, which remains the parity
oracle. See DESIGN.md §Consensus-engine.

Sharded execution: under ``jax.shard_map`` the same stages run on a
``(R, n_local)`` column shard — set ``engine.shard`` (a ``ShardedLayout``)
and every column contraction (Gram, gap Gram, distances) completes with a
``psum`` over ``shard.col_axes``, while the tiny (R, R) coefficient math
and the mixing GEMM stay shard-local. `train.trainer.
make_sharded_round_step` owns the row all-gather at the round boundary;
DESIGN.md §Sharded-execution has the layout and collective placement.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class ShardedLayout:
    """Mesh partitioning of the flat view under ``jax.shard_map``.

    Inside a mapped round every engine method receives the full-R rows of
    the LOCAL column shard, shape ``(R, n_local)``; worker rows are
    all-gathered over ``row_axes`` at the round boundary by the trainer
    (`make_sharded_round_step`), never inside the engine. Any contraction
    over the column (parameter) dimension — the Gram, gap Grams, distances
    to the mean — is completed with a ``psum`` over ``col_axes``; the
    mixing GEMM is column-local and needs no collective. ``col_axes`` may
    name MULTIPLE mesh axes — on a hierarchical ``workers x fsdp x model``
    mesh it is the whole ``("fsdp", "model")`` group and the one psum
    reduces over all ``fsdp x model`` column shards (DESIGN.md
    §Hierarchical-mesh). Hashable, so a sharded engine stays valid
    jit-static metadata (DESIGN.md §Sharded-execution).
    """
    row_axes: Tuple[str, ...] = ()
    col_axes: Tuple[str, ...] = ()
    rows: int = 1     # number of row (worker-axis) shards
    cols: int = 1     # number of column shards


@dataclass(frozen=True)
class FlatLayout:
    """Static description of the flat view (hashable; safe as jit aux data)."""
    treedef: Any
    shapes: Tuple[Tuple[int, ...], ...]   # per-leaf shapes WITHOUT worker dim
    dtypes: Tuple[str, ...]
    offsets: Tuple[int, ...]
    n: int            # parameters per worker
    M: int            # workers
    aux: int = 0      # extra state rows (easgd center)

    @property
    def R(self) -> int:
        return self.M + self.aux


# The uncentered Gram resolves squared distances only down to
# ~eps32 * max||x_i||^2. The fast jnp path floors every stage distance at
# GRAM_NOISE_FACTOR times that resolution (r_floor ~ 0.4% of the parameter
# norm): sub-resolution distances are treated as at-resolution, so a
# collapsed fleet under-pushes — escaping the window geometrically
# (|1 - coef| per round, O(log(r_floor/r0)) rounds) instead of pushing
# along rounding noise. Above r_floor the path is accurate; ``precise=
# True`` (gap-space) and the kernel path (block-centered Gram) are exact
# at every scale.
GRAM_NOISE_FACTOR = 256.0
_EPS32 = float(jnp.finfo(jnp.float32).eps)


@dataclass(frozen=True)
class ConsensusEngine:
    layout: FlatLayout
    use_kernel: bool = False      # Pallas fused_round vs jnp Gram+GEMM
    interpret: bool = True        # Pallas interpret mode (CPU)
    precise: bool = False         # jnp path: exact gap-space stages
    block_cols: int = 2048
    eps: float = 1e-12
    # set (dataclasses.replace) inside a shard_map'd round: inputs are then
    # (R, n_local) column shards and column contractions psum over
    # shard.col_axes. None = single-shard (whole (R, n) view) execution.
    shard: Optional[ShardedLayout] = None

    # -- construction -------------------------------------------------------

    @classmethod
    def from_stacked(cls, stacked, *, method: str = "simple_avg", **kw):
        """Build the layout from a worker-stacked pytree (leaves (M, ...))."""
        leaves, treedef = jax.tree_util.tree_flatten(stacked)
        M = leaves[0].shape[0]
        shapes = tuple(tuple(l.shape[1:]) for l in leaves)
        dtypes = tuple(str(l.dtype) for l in leaves)
        sizes = [math.prod(s) for s in shapes]
        offsets, o = [], 0
        for s in sizes:
            offsets.append(o)
            o += s
        from repro.core.methods import get_method
        aux = get_method(method).aux_rows
        # the fused kernel is TPU-targeted: compile it there, interpret it
        # when explicitly requested elsewhere (tests); CPU/GPU default to
        # the jnp Gram+GEMM path
        backend = jax.default_backend()
        if "use_kernel" not in kw:
            kw["use_kernel"] = backend == "tpu"
        if "interpret" not in kw:
            kw["interpret"] = backend != "tpu"
        layout = FlatLayout(treedef=treedef, shapes=shapes, dtypes=dtypes,
                            offsets=tuple(offsets), n=o, M=M, aux=aux)
        return cls(layout=layout, **kw)

    # -- flat view management (flatten happens ONCE per training run) -------

    def flatten(self, stacked):
        """Stacked pytree -> (R, n) fp32. Aux rows are initialized here
        (easgd/parle: elastic center = worker mean)."""
        leaves = jax.tree_util.tree_leaves(stacked)
        M = self.layout.M
        flat = jnp.concatenate(
            [l.reshape(M, -1).astype(jnp.float32) for l in leaves], axis=1)
        if self.layout.aux:
            flat = jnp.concatenate(
                [flat, jnp.mean(flat, axis=0, keepdims=True)], axis=0)
        return flat

    def unflatten(self, flat):
        """Worker rows of the flat view -> stacked pytree (original dtypes)."""
        L = self.layout
        rows = flat[:L.M]
        out = [rows[:, off:off + math.prod(shape)]
               .reshape((L.M,) + shape).astype(dtype)
               for shape, dtype, off in zip(L.shapes, L.dtypes, L.offsets)]
        return jax.tree_util.tree_unflatten(L.treedef, out)

    def unflatten_row(self, row, *, cast=True):
        """One (n,) row -> parameter pytree without the worker dim.
        ``cast=False`` keeps the engine's fp32 leaves (e.g. the averaged
        final model, matching the tree path's fp32 ``tree_mean0``)."""
        L = self.layout
        out = [row[off:off + math.prod(shape)].reshape(shape)
               .astype(dtype if cast else jnp.float32)
               for shape, dtype, off in zip(L.shapes, L.dtypes, L.offsets)]
        return jax.tree_util.tree_unflatten(L.treedef, out)

    def workers(self, flat):
        return flat[:self.layout.M]

    def with_workers(self, flat, rows):
        """Write updated worker rows back into the (donated) flat buffer."""
        if not self.layout.aux:
            return rows
        return jax.lax.dynamic_update_slice(flat, rows, (0, 0))

    # -- flat math primitives ------------------------------------------------

    @property
    def uniform(self):
        """(R,) uniform weights over worker rows (zeros on aux rows)."""
        L = self.layout
        return jnp.zeros((L.R,), jnp.float32).at[:L.M].set(1.0 / L.M)

    def _colsum(self, partial):
        """Complete a column-dimension contraction. Single-shard: identity.
        Sharded: psum of the per-shard partial over the column axes — the
        (R, R)-sized reduction is the only collective the engine itself
        ever issues."""
        if self.shard is not None and self.shard.col_axes:
            return jax.lax.psum(partial, self.shard.col_axes)
        return partial

    def gram(self, flat):
        """(R, R) uncentered Gram. Only zero-sum quadratic forms of it are
        meaningful; their fp32 noise floor is ~eps32 * max diag (see
        GRAM_NOISE_FACTOR and the module docstring). Sharded: per-shard
        partial Gram psum'd over the column axes."""
        f = flat.astype(jnp.float32)
        return self._colsum(f @ f.T)

    @staticmethod
    def sq_forms(G, V):
        """r2_i = V_i^T G V_i for each row of V. For an uncentered or
        block-centered Gram the rows must sum to 0 (shift invariance); for
        a gap Gram any V is valid."""
        return jnp.maximum(jnp.sum((V @ G) * V, axis=1), 0.0)

    def mix(self, flat, W):
        """x <- W @ x (one GEMM over the flat view)."""
        return W.astype(jnp.float32) @ flat

    def stage_comm(self, chunk, T):
        """The stage-1 column contraction over a COLUMN CHUNK of the flat
        view, psum-completed — the piece of a stage that the double-
        buffered overlap dispatches mid-scan (DESIGN.md §Overlap).
        Mode-matched to ``stage``: gap Gram (``precise``), plain Gram
        (fast), block-centered partial Gram (kernel). Contributions from
        disjoint column chunks ADD to the full-width contraction (the
        Gram is a sum over columns; the kernel path's per-block centering
        shift cancels in every zero-sum form), so
        ``sum_j stage_comm(x[:, j], T)`` feeds ``stage(x, T, c0, c1,
        gram=...)``. With ONE chunk the ops are identical to the ones
        ``stage`` itself would run — bit-for-bit the un-overlapped stage.
        """
        f = chunk.astype(jnp.float32)
        if self.use_kernel:
            from repro.kernels.pullpush import pullpush as pk
            return self._colsum(pk.partial_gram(
                f, block_cols=self.block_cols, interpret=self.interpret))
        if self.precise:
            g = T.astype(jnp.float32) @ f - f
            return self._colsum(g @ g.T)
        return self._colsum(f @ f.T)

    def _gap_stage(self, flat, T, c0, c1, *, gram=None):
        """Exact (``precise=True``) stage: materialize the targets
        ``tx = T x`` and work in gap space — distances are
        ``diag((tx - x)(tx - x)^T)`` (cancellation-free by construction),
        the apply is the uniform form ``tx + (1 - c)(x - tx)`` (exact both
        for c = 1, reproducing the target bitwise, and for huge |c|, which
        scales a difference of nearby values), and the pre/post metrics are
        forms over the gap Gram. One extra (R, n) buffer + read vs the fast
        path. ``gram`` (a precomputed gap Gram from ``stage_comm`` chunks)
        skips the column contraction — the overlap path.

        Requires (true of every lowering) that all worker rows of T share
        one weight vector w, so d_m = x_m - mean = (e_m - u)^T g.
        """
        R, M = self.layout.R, self.layout.M
        eye = jnp.eye(R, dtype=jnp.float32)
        u = self.uniform
        # T @ x then subtract — NOT (T - I) @ x: the row-stochastic dot is
        # clean (collapsed identical rows reproduce exactly, e.g. after a
        # hard pull) and the subtraction of nearby values is exact, so a
        # degenerate gap is a true zero, matching the tree path's d = x - a
        tx = T @ flat
        Gg = gram
        if Gg is None:
            g = tx - flat
            Gg = self._colsum(g @ g.T)
        r = jnp.sqrt(jnp.maximum(jnp.diagonal(Gg), 0.0))
        coef = c0 + c1 / jnp.maximum(r, self.eps)
        new = tx + (1.0 - coef)[:, None] * (flat - tx)
        # d_m = (u - e_m)^T g;  new_m - mean(new) = ((coef_m - 1) e_m
        #   + u * (1 - coef))^T g  — both exact forms over the gap Gram
        V_pre = jnp.broadcast_to(u, (R, R)) - eye
        pre = jnp.mean(jnp.sqrt(self.sq_forms(Gg, V_pre)[:M]))
        V_post = jnp.diag(coef - 1.0) + jnp.broadcast_to(u * (1.0 - coef),
                                                         (R, R))
        post = jnp.mean(jnp.sqrt(self.sq_forms(Gg, V_post)[:M]))
        return new, r, pre, post

    def stage(self, flat, T, c0, c1, *, gram=None):
        """One fused consensus stage.

        Per row i: ``r_i = ||x_i - T_i x||``, ``coef_i = c0_i + c1_i /
        max(r_i, eps)``, ``x_i <- x_i + coef_i (T_i x - x_i)``.
        Returns ``(new_flat, r, pre_dist, post_dist)`` — pre/post are the
        mean worker distance to the worker mean before/after the stage.

        Fast jnp path: one Gram + one mixing GEMM, with every distance
        floored at the Gram's fp32 resolution (module docstring — the only
        divergence from the tree oracle, transient and geometrically
        escaped). ``precise=True``: exact gap-space stages. Kernel path:
        one two-phase ``pallas_call``, block-centered Gram, exact.

        ``gram`` (the summed ``stage_comm`` chunks, mode-matched) skips
        the column contraction entirely: only the (R, R) coefficient math
        and the mixing GEMM/kernel run — the round-boundary epilogue of
        the double-buffered overlap, whose gather/psum already happened
        mid-scan (DESIGN.md §Overlap).
        """
        R, M = self.layout.R, self.layout.M
        eye = jnp.eye(R, dtype=jnp.float32)
        u = self.uniform
        Vu = eye - jnp.broadcast_to(u, (R, R))

        if self.use_kernel:
            from repro.kernels.pullpush import pullpush as pk
            if gram is not None:
                # gather-free epilogue: coef from the psum-completed Gram,
                # one mixing kernel pass (kernels.pullpush.mix_from_gram)
                new, r, G = pk.mix_from_gram(
                    flat, T, c0, c1, gram, eps=self.eps,
                    block_cols=self.block_cols, interpret=self.interpret)
            elif self.shard is not None and self.shard.col_axes:
                # column shard: partial-Gram kernel + host-side psum
                # epilogue + mixing kernel (pullpush.fused_round_sharded)
                new, r, G = pk.fused_round_sharded(
                    flat, T, c0, c1, axis=self.shard.col_axes, eps=self.eps,
                    block_cols=self.block_cols, interpret=self.interpret)
            else:
                new, r, G = pk.fused_round(flat, T, c0, c1, eps=self.eps,
                                           block_cols=self.block_cols,
                                           interpret=self.interpret)
            coef = c0 + c1 / jnp.maximum(r, self.eps)
            W = eye + coef[:, None] * (T - eye)
            pre = jnp.mean(jnp.sqrt(self.sq_forms(G, Vu)[:M]))
            post = jnp.mean(jnp.sqrt(self.sq_forms(G, Vu @ W)[:M]))
            return new, r, pre, post

        if self.precise:
            return self._gap_stage(flat, T, c0, c1, gram=gram)

        G = self.gram(flat) if gram is None else gram
        # the floor guards coef only — metrics report the (clamped) forms
        floor = GRAM_NOISE_FACTOR * _EPS32 * jnp.max(jnp.diagonal(G))
        r = jnp.sqrt(jnp.maximum(self.sq_forms(G, eye - T), floor))
        coef = c0 + c1 / jnp.maximum(r, self.eps)
        W = eye + coef[:, None] * (T - eye)
        pre = jnp.mean(jnp.sqrt(self.sq_forms(G, Vu)[:M]))
        post = jnp.mean(jnp.sqrt(self.sq_forms(G, Vu @ W)[:M]))
        return self.mix(flat, W), r, pre, post

    def exact_stage(self, flat, lam_r):
        """Exact two-term push (Appendix E.1): x_m += (lam_r / M)
        (u_m - mean u), u_m = (x_m - mean x)/r_m. Gap-space (exact);
        ablation path, not the round hot path.
        Returns ``(new_flat, r, pre_dist, post_dist)``.
        """
        R, M = self.layout.R, self.layout.M
        eye = jnp.eye(R, dtype=jnp.float32)
        u = self.uniform
        T = jnp.broadcast_to(u, (R, R))
        if self.layout.aux:
            T = jnp.concatenate([T[:M], eye[M:]], axis=0)
        g = T @ flat - flat                       # worker rows: mean - x_m
        Gg = self._colsum(g @ g.T)
        r = jnp.sqrt(jnp.maximum(jnp.diagonal(Gg), 0.0))
        inv = 1.0 / jnp.maximum(r, self.eps)
        units = -g[:M] * inv[:M, None]            # (x_m - mean)/r_m
        mean_unit = jnp.mean(units, axis=0, keepdims=True)
        upd = (lam_r / M) * (units - mean_unit)
        new = flat.at[:M].add(upd) if self.layout.aux else flat + upd
        # pre = r (target IS the worker mean). The push preserves the mean,
        # so new_m - mean(new) = (-(1 + (lam_r/M) inv_m) e_m
        #   + (lam_r/M)(u * inv))^T g — an exact form over the gap Gram.
        pre = jnp.mean(r[:M])
        iv = jnp.where(jnp.arange(R) < M, inv, 0.0)
        V_post = (-jnp.diag(1.0 + (lam_r / M) * iv)
                  + (lam_r / M) * jnp.broadcast_to(u * iv, (R, R)))
        post = jnp.mean(jnp.sqrt(self.sq_forms(Gg, V_post)[:M]))
        return new, r, pre, post

    def vec_stage(self, flat, vec, cvec):
        """Push along an EXTERNAL per-worker direction field (LPF-SGD's
        EMA-filtered gradient): row m moves by
        ``(cvec_m / max(r_m, eps)) * vec_m`` with ``r_m = ||vec_m||`` —
        the same normalized-force form as the Eq. 5 push, but the
        direction comes from ``vec`` (shape ``(M, n[_local])``), not from
        the gap to the mean. ``cvec`` is the full ``(R,)`` coefficient
        vector (aux entries 0; the elastic gate zeroes inactive workers,
        whose frozen rows also have a zero delta).
        Returns ``(new_flat, r, pre_dist, post_dist)`` like ``stage``.
        Sharded: the norm's column contraction psums over the column
        axes; the update itself is column-local.
        """
        M = self.layout.M
        v = vec.astype(jnp.float32)
        r = jnp.sqrt(jnp.maximum(
            self._colsum(jnp.sum(jnp.square(v), axis=1)), 0.0))
        upd = (cvec[:M] / jnp.maximum(r, self.eps))[:, None] * v
        pre = jnp.mean(self.dists_to_mean(flat))
        new = flat.at[:M].add(upd) if self.layout.aux else flat + upd
        post = jnp.mean(self.dists_to_mean(new))
        return new, r, pre, post

    def dists_to_mean(self, flat):
        """Exact per-worker distances to the worker mean (gap-space).
        Row-wise sum of squares — O(Mn), no (R, R) Gram for a diagonal
        (the ddp metrics branch hits this every round)."""
        M = self.layout.M
        w = flat[:M].astype(jnp.float32)
        g = jnp.mean(w, axis=0, keepdims=True) - w
        d2 = self._colsum(jnp.sum(g * g, axis=1))
        return jnp.sqrt(jnp.maximum(d2, 0.0))
