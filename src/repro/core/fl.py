"""Non-IID / Federated Learning substrate (paper §8.3, Table 5, §C.3):
Dirichlet partitioning, SCAFFOLD (Karimireddy'20), FedLESAM (Fan'24), and
their DPPF couplings (aggregation replaced by the Eq. 5 pull-push update;
control variates / perturbations untouched).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pullpush as pp


# ---------------------------------------------------------------------------
# Dirichlet non-IID partition (fixed at init, no reshuffling — §C.3)
# ---------------------------------------------------------------------------

def dirichlet_partition(labels, n_workers, alpha, seed=0):
    """Split sample indices across workers with Dir(alpha) class skew.
    Returns a list of index arrays (equal sizes, truncated)."""
    rng = np.random.default_rng(seed)
    labels = np.asarray(labels)
    classes = np.unique(labels)
    shards = [[] for _ in range(n_workers)]
    for c in classes:
        idx = np.flatnonzero(labels == c)
        rng.shuffle(idx)
        props = rng.dirichlet(alpha * np.ones(n_workers))
        cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
        for w, part in enumerate(np.split(idx, cuts)):
            shards[w].extend(part.tolist())
    size = min(len(s) for s in shards)
    return [np.asarray(sorted(rng.permutation(s)[:size])) for s in shards]


def heterogeneity(shards, labels, n_classes):
    """Mean total-variation distance of shard label distributions from the
    global distribution (diagnostic)."""
    labels = np.asarray(labels)
    glob = np.bincount(labels, minlength=n_classes) / len(labels)
    tvs = []
    for s in shards:
        loc = np.bincount(labels[s], minlength=n_classes) / len(s)
        tvs.append(0.5 * np.abs(loc - glob).sum())
    return float(np.mean(tvs))


# ---------------------------------------------------------------------------
# FL rounds (vmapped across workers; stacked params)
# ---------------------------------------------------------------------------

def _zeros_like_tree(t):
    return jax.tree.map(jnp.zeros_like, t)


def init_fl_state(method, stacked):
    """SCAFFOLD: server control c + per-worker controls c_m."""
    st = {"x_prev_global": pp.tree_mean0(stacked)}
    if method == "scaffold":
        center = pp.tree_mean0(stacked)
        st["c"] = _zeros_like_tree(center)
        st["c_m"] = jax.tree.map(lambda a: jnp.zeros_like(a, jnp.float32), stacked)
    return st


def fl_round(method, loss_fn, stacked, state, batches, lr, *,
             dppf=None, lam_t=0.0, rho=1e-3, eps=1e-12):
    """One FL communication round.

    batches: pytree of arrays with leading dims (tau, M, ...) — per local
    step, per worker. Aggregation: FedAvg (dppf None) or DPPF Eq. 5.
    Returns (stacked, state, metrics).
    """
    tau = jax.tree.leaves(batches)[0].shape[0]
    grad_fn = jax.grad(loss_fn)
    x_prev = state["x_prev_global"]

    def _lesam_pert(x_m):
        """Locally estimated global perturbation (Fan'24): direction of the
        drift from the last round's global model, recomputed at the CURRENT
        local iterate (zero at round start, grows as the worker drifts)."""
        d = jax.tree.map(lambda c, a: c - a.astype(jnp.float32), x_prev, x_m)
        n = jnp.sqrt(sum(jnp.sum(jnp.square(l)) for l in jax.tree.leaves(d)))
        return jax.tree.map(lambda l: rho * l / jnp.maximum(n, eps), d)

    def local_step(x_m, batch_m, c_m=None, c=None, lesam=False):
        if lesam:
            pert = _lesam_pert(x_m)
            x_eval = jax.tree.map(lambda a, e: a + e.astype(a.dtype), x_m, pert)
        else:
            x_eval = x_m
        g = grad_fn(x_eval, batch_m)
        if c_m is not None:  # SCAFFOLD correction
            g = jax.tree.map(lambda gg, cm, cc: gg.astype(jnp.float32) - cm + cc,
                             g, c_m, c)
        return jax.tree.map(lambda a, gg: (a.astype(jnp.float32)
                                           - lr * gg.astype(jnp.float32)
                                           ).astype(a.dtype), x_m, g)

    def run_worker(x_m, batches_m, c_m=None):
        def body(x, b):
            if method == "scaffold":
                return local_step(x, b, c_m, state["c"]), None
            if method == "fedlesam":
                return local_step(x, b, lesam=True), None
            return local_step(x, b), None
        x_m, _ = jax.lax.scan(body, x_m,
                              jax.tree.map(lambda a: a, batches_m))
        return x_m

    bt = jax.tree.map(lambda a: jnp.swapaxes(a, 0, 1), batches)  # (M, tau, ...)
    if method == "scaffold":
        new = jax.vmap(run_worker)(stacked, bt, state["c_m"])
    else:
        new = jax.vmap(run_worker)(stacked, bt)

    # ---- aggregation -------------------------------------------------------
    if dppf is not None and dppf.push:
        new, metrics = pp.pullpush(new, dppf.alpha, lam_t, dppf.eps)
    else:  # FedAvg: hard reset to the average
        xa = pp.tree_mean0(new)
        new = jax.tree.map(lambda a, c: jnp.broadcast_to(c[None], a.shape
                                                         ).astype(a.dtype),
                           new, xa)
        metrics = {"consensus_dist": jnp.float32(0.0)}

    # ---- control-variate update (SCAFFOLD option II) ------------------------
    if method == "scaffold":
        def cm_update(c_m, x_m_new):
            # c_m+ = c_m - c + (x_prev - x_m_after_local)/(tau * lr)
            return jax.tree.map(
                lambda cm, cc, xp, xm: cm - cc + (xp - xm.astype(jnp.float32))
                / (tau * lr),
                c_m, state["c"], x_prev, x_m_new)
        new_cm = jax.vmap(lambda cm, xm: cm_update(cm, xm))(state["c_m"], new)
        state = dict(state)
        state["c_m"] = new_cm
        state["c"] = pp.tree_mean0(new_cm)

    state = dict(state)
    state["x_prev_global"] = pp.tree_mean0(new)
    return new, state, metrics
