from repro.train.autotune import (
    Candidate, ProbeResult, TunePlan, TuneSpace, autotune, inject_oom_above,
    is_oom, make_lm_model_fn, make_round_probe_runner,
)
from repro.train.chaos import (
    ChaosEvent, ChaosPlan, FaultInjector, InjectedOOM,
)
from repro.train.clock import (
    OVERLAP_MODES, TAU_SCHEDULES, RoundClock, RoundMetricsLogger, RoundSpec,
)
from repro.train.supervisor import (
    ChaosMembership, HeartbeatMembership, ScheduleMembership, Supervisor,
)
from repro.train.trainer import (
    TrainState, average_params, init_train_state, make_ddp_step,
    make_round_step, make_sharded_round_step, set_participation,
    shard_train_state, stacked_params,
)

__all__ = ["Candidate", "ChaosEvent", "ChaosMembership", "ChaosPlan",
           "FaultInjector", "HeartbeatMembership", "InjectedOOM",
           "OVERLAP_MODES", "ProbeResult", "TAU_SCHEDULES", "RoundClock",
           "RoundMetricsLogger", "RoundSpec", "ScheduleMembership",
           "Supervisor", "TrainState", "TunePlan", "TuneSpace", "autotune",
           "average_params", "init_train_state", "inject_oom_above",
           "is_oom", "make_ddp_step", "make_lm_model_fn",
           "make_round_probe_runner", "make_round_step",
           "make_sharded_round_step", "set_participation",
           "shard_train_state", "stacked_params"]
