from repro.train.clock import (
    OVERLAP_MODES, TAU_SCHEDULES, RoundClock, RoundMetricsLogger, RoundSpec,
)
from repro.train.trainer import (
    TrainState, average_params, init_train_state, make_ddp_step,
    make_round_step, make_sharded_round_step, set_participation,
    shard_train_state, stacked_params,
)

__all__ = ["OVERLAP_MODES", "TAU_SCHEDULES", "RoundClock",
           "RoundMetricsLogger", "RoundSpec", "TrainState",
           "average_params", "init_train_state", "make_ddp_step",
           "make_round_step", "make_sharded_round_step",
           "set_participation", "shard_train_state", "stacked_params"]
