from repro.train.trainer import (
    TrainState, init_train_state, make_ddp_step, make_round_step,
)

__all__ = ["TrainState", "init_train_state", "make_ddp_step",
           "make_round_step"]
