"""Self-tuning performance harness: the ``--autotune`` probe search.

The paper's communication-efficiency claims only hold at a well-chosen
operating point — per-worker batch size, ``overlap_chunks``, and tau
interact through the comm/compute crossover modeled in
``launch/roofline.py::overlap_model``. Before this module that point was
hand-picked per committed hillclimb plan file; now one flag searches it
(DESIGN.md §Autotune):

1. **Batch frontier** — power-of-two scaling probes from
   ``TuneSpace.min_batch`` double until the first OOM (or ``max_batch``),
   then a binary search refines between the largest feasible and smallest
   failed size. Failed sizes are cached and NEVER re-probed; every probe
   (feasible or not) counts against ``probe_budget`` and the search
   returns its best-so-far point when the budget runs dry.
2. **Joint sweep** — at the frontier batch, every (tau, overlap_chunks)
   pair of the ladders is probed (chunks capped by tau; modes without a
   chunk dimension collapse the ladder to ``(1,)``).
3. **Reconciled scoring** — every probe records a measured round wall
   time AND the deterministic roofline model's round time
   (``roofline.probe_round_model``). The median measured/modeled ratio
   calibrates the model to this host (``roofline.reconcile_probes``) and
   candidates are ranked by calibrated-model microseconds PER SAMPLE
   (``round_us / (tau * batch)``). A single positive scale never changes
   an argmin, so the chosen point is a deterministic function of the
   feasibility frontier — noisy host timers cannot flip it, which is what
   lets CI pin the plan structurally (``BENCH_autotune.json``).

The **OOM contract**: a probe failure is any exception whose message
carries a ``RESOURCE_EXHAUSTED`` / out-of-memory token (``is_oom``) —
exactly what jaxlib's ``XlaRuntimeError`` carries on real device OOM.
Injection therefore needs no jaxlib type: ``inject_oom_above`` (the
``--tune-oom-above`` CI hook) and the test fixture raise a plain
``RuntimeError`` with the token, and the backoff path runs without real
memory pressure. Any non-OOM exception propagates — the tuner never
swallows a real bug.

The search emits a :class:`TunePlan` — a deterministic JSON artifact
(probes tried, failures, chosen point, model-vs-measured residual scale)
consumed directly by ``DPPFConfig.apply_tune_plan`` and
``RoundClock.from_tune_plan``, replacing the committed hillclimb plan
files end to end.
"""
from __future__ import annotations

import dataclasses
import json
import time
from dataclasses import dataclass
from typing import Callable, Dict, Optional, Tuple

import repro.launch.roofline as rf

PLAN_VERSION = 1

# substrings that mark an exception as device memory exhaustion; the first
# is jaxlib XlaRuntimeError's canonical status and the injection contract
OOM_TOKENS = ("RESOURCE_EXHAUSTED", "Out of memory", "out of memory",
              "OOM")


def is_oom(exc: BaseException) -> bool:
    """The OOM contract: does this exception mean the probe ran out of
    device memory? Matched on the MESSAGE (jaxlib raises
    ``XlaRuntimeError`` whose text starts with ``RESOURCE_EXHAUSTED`` on
    real OOM), so scripted injection works with a plain RuntimeError and
    no jaxlib import. Everything else is a real bug and must propagate."""
    text = f"{type(exc).__name__}: {exc}"
    return any(tok in text for tok in OOM_TOKENS)


@dataclass(frozen=True)
class Candidate:
    """One operating point of the joint search space."""
    batch: int            # per-worker batch size
    tau: int              # local steps per communication round
    overlap_chunks: int   # mid-scan snapshot-comm chunk count


# overlap modes whose chunk ladder is meaningful (the others dispatch no
# mid-scan chunks, so their ladder collapses to (1,))
_CHUNKED_MODES = ("doublebuf", "staleness_k")


@dataclass(frozen=True)
class TuneSpace:
    """The search space + budget. ValueError (never assert) on malformed
    spaces — these guard the user-facing ``--autotune`` flags and must
    survive ``python -O`` (tests/optcheck.py)."""
    min_batch: int = 1
    max_batch: int = 256
    taus: Tuple[int, ...] = (4, 8)
    chunks: Tuple[int, ...] = (1, 2, 4)
    probe_budget: int = 16
    overlap: str = "doublebuf"
    staleness: int = 1

    def __post_init__(self):
        if self.probe_budget < 1:
            raise ValueError(
                f"probe_budget must be >= 1, got {self.probe_budget}")
        if self.min_batch < 1:
            raise ValueError(f"min_batch must be >= 1, got {self.min_batch}")
        if self.min_batch > self.max_batch:
            raise ValueError(
                f"min_batch {self.min_batch} > max_batch {self.max_batch}")
        if not self.taus or any(t < 1 for t in self.taus):
            raise ValueError(f"taus must be a non-empty tuple of ints >= 1, "
                             f"got {self.taus!r}")
        if not self.chunks or any(c < 1 for c in self.chunks):
            raise ValueError(f"chunks must be a non-empty tuple of ints >= "
                             f"1, got {self.chunks!r}")
        # OVERLAP_MODES lives in train.clock; keep the literal in sync
        if self.overlap not in ("none", "staleness1", "doublebuf",
                                "staleness_k"):
            raise ValueError(f"unknown overlap mode {self.overlap!r}")
        if self.staleness < 1:
            raise ValueError(f"staleness must be >= 1, got {self.staleness}")

    def chunk_ladder(self) -> Tuple[int, ...]:
        """The effective chunk ladder: modes without mid-scan chunk
        dispatch have nothing to tune there."""
        if self.overlap in _CHUNKED_MODES:
            return self.chunks
        return (1,)


@dataclass(frozen=True)
class ProbeResult:
    """One probe of the search: the candidate, whether it was feasible,
    the measured round wall time (timing-class — host-relative), and the
    deterministic roofline-model round time (structural)."""
    batch: int
    tau: int
    overlap_chunks: int
    ok: bool
    us_round: float = 0.0     # measured; 0.0 for failed probes
    modeled_us: float = 0.0   # roofline.probe_round_model, pure arithmetic
    error: str = ""           # the OOM message when not ok

    @property
    def candidate(self) -> Candidate:
        return Candidate(self.batch, self.tau, self.overlap_chunks)


@dataclass(frozen=True)
class TunePlan:
    """The deterministic artifact ``--autotune`` emits and
    ``RoundClock.from_tune_plan`` / ``DPPFConfig.apply_tune_plan``
    consume. Structural fields (chosen point, probe ladder, failures,
    budget accounting, ``dominates_model``) are identical on every host
    for the same feasibility frontier; ``us_round`` / ``residual_scale``
    / ``dominates_measured`` are host-relative timing fields."""
    chosen: Candidate
    probes: Tuple[ProbeResult, ...]
    failures: Tuple[int, ...]     # batch sizes that OOMed (sorted, unique)
    probe_budget: int
    probes_used: int
    overlap: str
    staleness: int
    residual_scale: float         # median(measured / modeled) over ok probes
    dominates_model: bool         # chosen beats every ok probe, calibrated model
    dominates_measured: bool      # same under raw measured time (host-noisy)
    version: int = PLAN_VERSION

    def __post_init__(self):
        # load()-path guards: a hand-edited / wrong-version plan must fail
        # loudly, not train at a garbage operating point (-O safe)
        if self.version != PLAN_VERSION:
            raise ValueError(f"TunePlan version {self.version} != "
                             f"{PLAN_VERSION} (regenerate with --autotune)")
        if self.probe_budget < 1:
            raise ValueError(
                f"probe_budget must be >= 1, got {self.probe_budget}")
        if self.chosen.batch < 1 or self.chosen.tau < 1 \
                or self.chosen.overlap_chunks < 1:
            raise ValueError(f"malformed chosen point {self.chosen}")
        if self.overlap not in ("none", "staleness1", "doublebuf",
                                "staleness_k"):
            raise ValueError(f"unknown overlap mode {self.overlap!r}")
        if self.staleness < 1:
            raise ValueError(f"staleness must be >= 1, got {self.staleness}")

    # -- deterministic JSON -------------------------------------------------

    def to_dict(self) -> dict:
        """JSON form. Floats are rounded at the source (us to 0.1, the
        modeled/scale fields to 6 digits) so the committed
        ``BENCH_autotune.json`` compares stably across hosts and a
        load -> save round-trip is byte-identical."""
        return {
            "version": self.version,
            "chosen": {"batch": self.chosen.batch, "tau": self.chosen.tau,
                       "overlap_chunks": self.chosen.overlap_chunks},
            "overlap": self.overlap,
            "staleness": self.staleness,
            "probe_budget": self.probe_budget,
            "probes_used": self.probes_used,
            "failures": list(self.failures),
            "residual_scale": round(self.residual_scale, 6),
            "dominates_model": self.dominates_model,
            "dominates_measured": self.dominates_measured,
            "probes": [
                {"batch": p.batch, "tau": p.tau,
                 "overlap_chunks": p.overlap_chunks, "ok": p.ok,
                 "us_round": round(p.us_round, 1),
                 "modeled_us": round(p.modeled_us, 6), "error": p.error}
                for p in self.probes],
        }

    @classmethod
    def from_dict(cls, d: dict) -> "TunePlan":
        try:
            chosen = Candidate(int(d["chosen"]["batch"]),
                               int(d["chosen"]["tau"]),
                               int(d["chosen"]["overlap_chunks"]))
            probes = tuple(
                ProbeResult(int(p["batch"]), int(p["tau"]),
                            int(p["overlap_chunks"]), bool(p["ok"]),
                            float(p["us_round"]), float(p["modeled_us"]),
                            str(p.get("error", "")))
                for p in d["probes"])
            return cls(chosen=chosen, probes=probes,
                       failures=tuple(int(b) for b in d["failures"]),
                       probe_budget=int(d["probe_budget"]),
                       probes_used=int(d["probes_used"]),
                       overlap=str(d["overlap"]),
                       staleness=int(d["staleness"]),
                       residual_scale=float(d["residual_scale"]),
                       dominates_model=bool(d["dominates_model"]),
                       dominates_measured=bool(d["dominates_measured"]),
                       version=int(d.get("version", -1)))
        except (KeyError, TypeError) as e:
            raise ValueError(f"malformed TunePlan payload: {e!r}") from e

    def dumps(self) -> str:
        return json.dumps(self.to_dict(), indent=2, sort_keys=True) + "\n"

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.dumps())

    @classmethod
    def load(cls, path: str) -> "TunePlan":
        with open(path) as f:
            return cls.from_dict(json.load(f))


def per_sample_us(us: float, cand: Candidate) -> float:
    """The tuner's objective: round microseconds amortized per training
    sample (GRAWA's time-constrained framing — wall time per unit of
    optimization work, not raw round time, which would always pick the
    smallest batch)."""
    return us / (cand.tau * cand.batch)


def autotune(runner: Callable[[Candidate], float],
             model_fn: Callable[[Candidate], float],
             space: TuneSpace) -> TunePlan:
    """Run the probe search. ``runner(cand)`` returns measured round
    microseconds and raises on OOM (``is_oom`` decides — anything else
    propagates); ``model_fn(cand)`` returns the deterministic roofline
    round microseconds. Raises ValueError when even ``min_batch`` OOMs
    (there is nothing below it to back off to)."""
    probes: list = []
    tried: Dict[Candidate, ProbeResult] = {}

    def probe(cand: Candidate) -> Optional[ProbeResult]:
        if cand in tried:             # never re-run — failed sizes included
            return tried[cand]
        if len(tried) >= space.probe_budget:
            return None               # budget exhausted: best-so-far wins
        modeled = float(model_fn(cand))
        try:
            res = ProbeResult(cand.batch, cand.tau, cand.overlap_chunks,
                              ok=True, us_round=float(runner(cand)),
                              modeled_us=modeled)
        except Exception as e:        # noqa: BLE001 — filtered by is_oom
            if not is_oom(e):
                raise
            res = ProbeResult(cand.batch, cand.tau, cand.overlap_chunks,
                              ok=False, modeled_us=modeled,
                              error=str(e)[:200])
        tried[cand] = res
        probes.append(res)
        return res

    # -- phase 1: power-of-two batch ladder at the base (tau, chunks) point
    base_tau, base_ch = space.taus[0], space.chunk_ladder()[0]
    b, best, first_fail = space.min_batch, 0, None
    while True:
        res = probe(Candidate(b, base_tau, base_ch))
        if res is None:
            break
        if res.ok:
            best = b
            if b >= space.max_batch:
                break
            b = min(b * 2, space.max_batch)
        else:
            first_fail = b
            break
    if best == 0:
        raise ValueError(
            f"autotune: no feasible batch — min_batch={space.min_batch} "
            f"already OOMs ({probes[-1].error if probes else 'no probe ran'}"
            f"); lower min_batch or shrink the model")

    # -- phase 2: binary refinement between largest-ok and smallest-failed.
    # Midpoints are strictly inside (lo, hi), so no tried size repeats.
    lo, hi = best, first_fail
    while hi is not None and hi - lo > 1:
        res = probe(Candidate((lo + hi) // 2, base_tau, base_ch))
        if res is None:
            break
        if res.ok:
            lo = res.batch
        else:
            hi = res.batch
    best_batch = lo

    # -- phase 3: joint (tau, chunks) sweep at the frontier batch (the base
    # point is already cached; chunk counts beyond tau cannot interleave)
    for tau in space.taus:
        for ch in space.chunk_ladder():
            if ch > tau:
                continue
            probe(Candidate(best_batch, tau, ch))

    # -- reconcile + select
    ok_probes = [p for p in probes if p.ok]
    rec = rf.reconcile_probes(
        (p.us_round, p.modeled_us) for p in ok_probes)
    scale = rec["scale"]

    def model_score(p: ProbeResult) -> float:
        return per_sample_us(p.modeled_us * scale, p.candidate)

    # candidates = the joint sweep's feasible probes at the frontier batch;
    # ties (chunking never changes the modeled payload) break to the
    # smallest tau, then fewest chunks — fully deterministic
    cands = [p for p in ok_probes if p.batch == best_batch]
    chosen_p = min(cands, key=lambda p: (model_score(p), p.tau,
                                         p.overlap_chunks))
    dominates_model = all(model_score(chosen_p) <= model_score(p)
                          for p in ok_probes)
    meas = lambda p: per_sample_us(p.us_round, p.candidate)
    dominates_measured = all(meas(chosen_p) <= meas(p) for p in ok_probes)

    return TunePlan(
        chosen=chosen_p.candidate, probes=tuple(probes),
        failures=tuple(sorted({p.batch for p in probes if not p.ok})),
        probe_budget=space.probe_budget, probes_used=len(tried),
        overlap=space.overlap, staleness=space.staleness,
        residual_scale=scale, dominates_model=dominates_model,
        dominates_measured=dominates_measured)


# ---------------------------------------------------------------------------
# probe runners
# ---------------------------------------------------------------------------

def inject_oom_above(runner: Callable[[Candidate], float],
                     max_ok_batch: int) -> Callable[[Candidate], float]:
    """Fault-injection hook (the ``--tune-oom-above`` CI leg): wrap a
    probe runner so any candidate with ``batch > max_ok_batch`` raises a
    scripted RESOURCE_EXHAUSTED BEFORE touching the device — the backoff
    path runs with zero real memory pressure and a deterministic
    frontier."""
    if max_ok_batch < 1:
        raise ValueError(
            f"injected OOM frontier must be >= 1, got {max_ok_batch}")

    def run(cand: Candidate) -> float:
        if cand.batch > max_ok_batch:
            raise RuntimeError(
                f"RESOURCE_EXHAUSTED: injected OOM at batch={cand.batch} "
                f"(frontier {max_ok_batch})")
        return runner(cand)
    return run


def make_round_probe_runner(init_fn, loss_fn, opt, dcfg, workers: int,
                            batch_fn, *, base_lr: float = 0.05,
                            total_steps: int = 100, reps: int = 2,
                            seed: int = 0):
    """The measured probe runner on the REAL round step (the same
    ``make_round_step`` the training loop runs): per candidate, swap the
    candidate's tau/overlap_chunks into ``dcfg``, init a fresh fleet, jit
    one donated round, warm twice (the second warm catches steady-state
    resharding recompiles — the ``_time_donated`` convention), and return
    the mean of ``reps`` timed rounds in microseconds.
    ``batch_fn(cand)`` builds the (tau, M, batch, ...) round batch. A
    real device OOM escapes jit as ``XlaRuntimeError`` and is caught by
    the search's ``is_oom``."""
    import jax
    from repro.train.trainer import init_train_state, make_round_step

    def run(cand: Candidate) -> float:
        dc = dataclasses.replace(dcfg, tau=cand.tau,
                                 overlap_chunks=cand.overlap_chunks)
        st = init_train_state(init_fn, opt, dc, workers,
                              jax.random.PRNGKey(seed))
        step = jax.jit(make_round_step(loss_fn, opt, dc, base_lr=base_lr,
                                       total_steps=total_steps),
                       donate_argnums=0)
        b = batch_fn(cand)
        for _ in range(2):                      # compile + steady-state warm
            st, _ = step(st, b)
            jax.block_until_ready(st.params)
        t0 = time.perf_counter()
        for _ in range(reps):
            st, _ = step(st, b)
        jax.block_until_ready(st.params)
        return (time.perf_counter() - t0) / reps * 1e6
    return run


def make_lm_model_fn(*, n_params: int, seq: int, workers: int,
                     overlap: str, staleness: int = 1):
    """The roofline ``model_fn`` for the training CLI: local-step work is
    the LM rule fwd+bwd ~ 6*N flops per token; the consensus payload is
    the flat engine's worker-row all-gather (R x n fp32) plus the (R, R)
    partial-Gram psum — the same accounting as
    ``microbench.bench_overlap_round``."""
    gather_bytes = workers * n_params * 4 + workers * workers * 4

    def model_us(cand: Candidate) -> float:
        work_s = 6.0 * n_params * cand.batch * seq / rf.PEAK_FLOPS
        return rf.probe_round_model(
            work_s_per_step=work_s, tau=cand.tau,
            gather_bytes=gather_bytes, R=workers, mode=overlap,
            staleness=staleness) * 1e6
    return model_us
