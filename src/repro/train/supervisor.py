"""Fault-tolerant round supervisor: heartbeat membership, quorum degrade,
and crash-safe checkpoint recovery for the DPPF round loop.

The ``Supervisor`` owns the host-side round iteration that used to live
inline in ``launch/train.py``: each round it polls a pluggable
``Membership`` provider, drives ``set_participation`` with the resulting
row mask (the ``core/consensus.py`` mask-provider contract), enforces a
quorum policy (below ``quorum`` active rows the round degrades to
local-only steps — the elastic carry's scalar ``sync`` gate skips the
consensus application bit-exactly — with exponential backoff + jitter),
and recovers from round-level failures by restoring the last good
checkpoint and replaying under a retry budget. ``RESOURCE_EXHAUSTED``
failures reuse the PR 9 ``is_oom`` contract: the per-worker batch shrinks
(down the TunePlan's feasible probe ladder when one is given, else
halving) instead of dying.

Membership providers expose ``workers`` and
``mask_for(round) -> (mask, events)``; three ship here:

* ``HeartbeatMembership`` — the in-process heartbeat table: per-worker
  last-beat deadline + miss counter driving the
  ``ACTIVE -> SUSPECT -> DEAD -> REJOINING`` state machine;
* ``ChaosMembership``  — a ``ChaosPlan``'s kill/stall/netdrop windows
  scripted onto that same table over a virtual round clock (one round =
  ``round_s`` seconds), so CI replays are deterministic;
* ``ScheduleMembership`` — the legacy ``--elastic-drop W,A,B`` demo as
  one trivial provider (no events, bit-for-bit the old behavior).

Everything the supervisor does in response to a fault — suspect, evict,
rejoin, recover, degrade, oom, shrink, restore, restore_corrupt, retry —
is appended to ``events`` (and emitted through ``RoundMetricsLogger``
when one is attached), so a run's fault timeline is a structured,
replayable artifact. Determinism contract: no wall clocks and no global
RNG — backoff jitter is a sha256 of ``(seed, round, attempt)``, recorded
in the event and only actually slept when a ``sleep_fn`` is provided
(CI runs on virtual time).
"""
from __future__ import annotations

import hashlib
import os

import jax
import numpy as np

from repro.checkpoint import load_train_state, save_train_state
from repro.train.autotune import is_oom
from repro.train.trainer import set_participation

ACTIVE = "active"
SUSPECT = "suspect"
DEAD = "dead"
REJOINING = "rejoining"


class HeartbeatMembership:
    """In-process heartbeat table. ``beat(w, now)`` records a worker's
    heartbeat; ``poll(now)`` advances every worker's state machine and
    returns the participation mask. A worker whose last beat is older
    than ``timeout`` seconds accrues one missed poll; ``suspect_after``
    consecutive misses demote ACTIVE -> SUSPECT, ``dead_after`` misses
    SUSPECT -> DEAD (evicted from the mask). The first beat after DEAD
    re-admits the row as REJOINING (it is back in the mask — the elastic
    catch-up pull does the state repair) and the next beat completes
    REJOINING -> ACTIVE; a beat during SUSPECT recovers straight to
    ACTIVE. All guards are ValueError, never assert (python -O)."""

    def __init__(self, workers: int, *, timeout: float,
                 suspect_after: int = 1, dead_after: int = 2,
                 start_time: float = 0.0):
        if workers < 1:
            raise ValueError(f"workers must be >= 1, got {workers}")
        if not timeout > 0:
            raise ValueError(
                f"heartbeat timeout must be > 0 seconds, got {timeout}")
        if not 1 <= suspect_after <= dead_after:
            raise ValueError(
                f"need 1 <= suspect_after ({suspect_after}) <= "
                f"dead_after ({dead_after})")
        self.workers = workers
        self.timeout = float(timeout)
        self.suspect_after = suspect_after
        self.dead_after = dead_after
        self.state = [ACTIVE] * workers
        self.last_beat = [float(start_time)] * workers
        self.missed = [0] * workers

    def beat(self, worker: int, now: float):
        """One heartbeat. Returns the transitions it caused as
        ``(worker, from_state, to_state)`` tuples."""
        if not 0 <= worker < self.workers:
            raise ValueError(f"worker {worker} out of range "
                             f"[0, {self.workers})")
        out = []
        s = self.state[worker]
        if s == DEAD:
            self.state[worker] = REJOINING
            out.append((worker, DEAD, REJOINING))
        elif s in (SUSPECT, REJOINING):
            self.state[worker] = ACTIVE
            out.append((worker, s, ACTIVE))
        self.last_beat[worker] = float(now)
        self.missed[worker] = 0
        return out

    def poll(self, now: float):
        """Advance deadlines and return ``(mask, transitions)`` — mask is
        the (workers,) float32 participation vector (ACTIVE and REJOINING
        rows are in; SUSPECT and DEAD rows are out)."""
        out = []
        for w in range(self.workers):
            if float(now) - self.last_beat[w] > self.timeout:
                self.missed[w] += 1
                s = self.state[w]
                if s in (ACTIVE, REJOINING) \
                        and self.missed[w] >= self.suspect_after:
                    self.state[w] = SUSPECT
                    out.append((w, s, SUSPECT))
                if self.state[w] == SUSPECT \
                        and self.missed[w] >= self.dead_after:
                    self.state[w] = DEAD
                    out.append((w, SUSPECT, DEAD))
        mask = np.asarray(
            [1.0 if s in (ACTIVE, REJOINING) else 0.0
             for s in self.state], np.float32)
        return mask, out


# transition -> recovery-event name (the structured-event vocabulary)
_EVENT_OF = {SUSPECT: "suspect", DEAD: "evict", REJOINING: "rejoin",
             ACTIVE: "recover"}


class ChaosMembership:
    """A ``ChaosPlan``'s kill/stall/netdrop windows driving a
    ``HeartbeatMembership`` table over a virtual round clock: workers not
    inside a down-window beat at ``round * round_s``; the poll runs at
    the same instant, so a worker that has been silent for a full round
    misses its deadline iff ``timeout < round_s``. Pure plan state — a
    replay walks the identical transition sequence."""

    def __init__(self, plan, workers: int, *, timeout: float,
                 round_s: float = 1.0, suspect_after: int = 1,
                 dead_after: int = 2):
        if not round_s > 0:
            raise ValueError(f"round_s must be > 0, got {round_s}")
        self.plan = plan
        self.workers = workers
        self.round_s = float(round_s)
        # everyone "beat" just before round 0, so a round-0 down-window
        # is one full round of silence at the first poll
        self.table = HeartbeatMembership(
            workers, timeout=timeout, suspect_after=suspect_after,
            dead_after=dead_after, start_time=-round_s)
        self._next = 0

    def mask_for(self, round_idx: int):
        if round_idx != self._next:
            raise ValueError(
                f"ChaosMembership.mask_for must advance one round at a "
                f"time (asked {round_idx}, expected {self._next}) — the "
                "supervisor caches replayed rounds")
        self._next += 1
        now = round_idx * self.round_s
        transitions = []
        for w in range(self.workers):
            if not self.plan.is_down(w, round_idx):
                transitions.extend(self.table.beat(w, now))
        mask, polled = self.table.poll(now)
        transitions.extend(polled)
        events = [{"event": _EVENT_OF[to], "worker": w, "from": frm}
                  for (w, frm, to) in transitions]
        return mask, events


class ScheduleMembership:
    """The ``--elastic-drop W,A,B`` demo schedule as a membership
    provider: worker W is out of the mask for rounds [A, B). Emits no
    events (a requested drop is not a fault) — the supervisor-driven loop
    stays bit-for-bit the old inline loop."""

    def __init__(self, workers: int, drops):
        if workers < 1:
            raise ValueError(f"workers must be >= 1, got {workers}")
        self.workers = workers
        self.drops = []
        for (w, a, b) in drops:
            if not 0 <= w < workers:
                raise ValueError(
                    f"drop worker {w} out of range [0, {workers})")
            if not 0 <= a < b:
                raise ValueError(
                    f"drop window [{a}, {b}) is empty or negative — "
                    "need 0 <= A < B")
            self.drops.append((int(w), int(a), int(b)))

    def mask_for(self, round_idx: int):
        mask = np.ones((self.workers,), np.float32)
        for (w, a, b) in self.drops:
            if a <= round_idx < b:
                mask[w] = 0.0
        return mask, []


def _jitter01(seed: int, round_idx: int, attempt: int) -> float:
    """Deterministic uniform in [0, 1) — sha256 of the (seed, round,
    attempt) triple, the tests/_faults.py noisy_time_fn idiom."""
    h = hashlib.sha256(
        f"{seed}:{round_idx}:{attempt}".encode()).digest()
    return int.from_bytes(h[:8], "big") / float(1 << 64)


class Supervisor:
    """Host-side fault-tolerant round loop. See the module docstring for
    the policy; ``run`` is the entry point.

    Parameters (ValueError on bad values — python -O safe):

    * ``clock``        — the run's RoundClock (owns the round specs);
    * ``workers``      — worker-row count (the mask provider contract);
    * ``membership``   — optional provider with ``mask_for(round)``;
      when None the loop never touches participation (non-elastic runs);
    * ``quorum``       — min active rows for a consensus round; below it
      the round degrades to local-only steps (``sync=0``). 0 disables;
    * ``retry_budget`` — max CONSECUTIVE failed rounds before the
      original exception propagates;
    * ``chaos``        — optional ``FaultInjector`` (scripted faults);
    * ``ckpt_dir``     — rotation-checkpoint directory (``sup_last.npz``
      / ``sup_prev.npz``); empty string disables restore (failures then
      propagate immediately);
    * ``tune_plan``    — optional TunePlan whose feasible probe batches
      form the OOM shrink ladder;
    * ``batch_size``   — per-worker batch, threaded to ``batch_fn`` and
      shrunk on OOM;
    * ``logger``       — optional RoundMetricsLogger; recovery events are
      emitted as rows with an ``"event"`` key;
    * ``on_round``     — optional ``f(spec, metrics)`` called after every
      successful round (progress printing);
    * ``place_fn``     — re-places a host-restored TrainState on device
      (the sharded path passes its ``shard_train_state`` closure);
    * ``sleep_fn``     — when given, called with the backoff seconds
      (production); None = virtual time (CI replay determinism).
    """

    def __init__(self, clock, *, workers: int, membership=None,
                 quorum: int = 0, retry_budget: int = 3, chaos=None,
                 ckpt_dir: str = "", ckpt_every: int = 1, tune_plan=None,
                 batch_size: int = 0, logger=None, on_round=None,
                 place_fn=None, sleep_fn=None, seed: int = 0,
                 backoff_base: float = 0.5, backoff_cap: float = 30.0):
        if workers < 1:
            raise ValueError(f"workers must be >= 1, got {workers}")
        if quorum < 0:
            raise ValueError(f"quorum must be >= 0, got {quorum}")
        if quorum > workers:
            raise ValueError(
                f"quorum {quorum} exceeds the worker count {workers} — "
                "no round could ever reach it")
        if retry_budget < 0:
            raise ValueError(
                f"retry_budget must be >= 0, got {retry_budget}")
        if ckpt_every < 1:
            raise ValueError(f"ckpt_every must be >= 1, got {ckpt_every}")
        if not backoff_base > 0:
            raise ValueError(
                f"backoff_base must be > 0, got {backoff_base}")
        if membership is not None \
                and getattr(membership, "workers", workers) != workers:
            raise ValueError(
                f"membership provider covers "
                f"{membership.workers} workers, supervisor drives "
                f"{workers}")
        self.clock = clock
        self.workers = workers
        self.membership = membership
        self.quorum = quorum
        self.retry_budget = retry_budget
        self.chaos = chaos
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.tune_plan = tune_plan
        self.batch_size = int(batch_size)
        self.logger = logger
        self.on_round = on_round
        self.place_fn = place_fn
        self.sleep_fn = sleep_fn
        self.seed = seed
        self.backoff_base = backoff_base
        self.backoff_cap = backoff_cap
        self.events = []
        self.counters = {}
        self._mask_cache = {}
        self._degrade_streak = 0

    # -- events --------------------------------------------------------------

    def _emit(self, round_idx, event, *, worker=None, detail="",
              backoff_s=None, attempt=None):
        ev = {"round": int(round_idx), "event": str(event)}
        if worker is not None:
            ev["worker"] = int(worker)
        if detail:
            ev["detail"] = str(detail)
        if backoff_s is not None:
            ev["backoff_s"] = round(float(backoff_s), 3)
        if attempt is not None:
            ev["attempt"] = int(attempt)
        self.events.append(ev)
        self.counters[ev["event"]] = self.counters.get(ev["event"], 0) + 1
        if self.logger is not None:
            self.logger(int(round_idx),
                        {k: v for k, v in ev.items() if k != "round"})

    def event_seq(self):
        """The compact replay-pinned form: ``r<round>:<event>[:w<worker>]``
        strings in emission order."""
        return [f"r{e['round']}:{e['event']}"
                + (f":w{e['worker']}" if "worker" in e else "")
                for e in self.events]

    def summary(self):
        return {"counters": dict(sorted(self.counters.items())),
                "event_seq": self.event_seq(),
                "final_batch": self.batch_size}

    # -- membership ----------------------------------------------------------

    def _mask(self, round_idx):
        """Provider poll with a per-round cache: a round re-executed after
        a restore re-uses its original mask and does NOT re-emit its
        membership events (the fault timeline stays bit-identical across
        replays)."""
        if round_idx in self._mask_cache:
            return self._mask_cache[round_idx]
        mask, events = self.membership.mask_for(round_idx)
        mask = np.asarray(mask, np.float32)
        for e in events:
            self._emit(round_idx, e["event"], worker=e.get("worker"),
                       detail=e.get("from", ""))
        self._mask_cache[round_idx] = mask
        return mask

    # -- checkpoint rotation + restore ladder --------------------------------

    def _ckpt_paths(self):
        return (os.path.join(self.ckpt_dir, "sup_last.npz"),
                os.path.join(self.ckpt_dir, "sup_prev.npz"))

    def _save(self, state, round_idx):
        last, prev = self._ckpt_paths()
        if os.path.exists(last):
            os.replace(last, prev)
        save_train_state(last, state)      # atomic (checkpoint/io.py)
        self.counters["ckpt_saved"] = self.counters.get("ckpt_saved", 0) + 1
        if self.chaos is not None and self.chaos.after_save(round_idx, last):
            # the fault itself is scripted, not a recovery action — the
            # restore ladder's detection emits restore_corrupt later
            pass

    def _restore(self, failed_round, like):
        """The restore ladder: newest rotation copy first, the corrupt-
        archive ValueError from checkpoint/io.py drops to the next rung."""
        last, prev = self._ckpt_paths()
        for path, tag in ((last, "last"), (prev, "prev")):
            if not os.path.exists(path):
                continue
            try:
                st = load_train_state(path, like, clock=self.clock)
            except ValueError as e:
                self._emit(failed_round, "restore_corrupt",
                           detail=f"{tag}: {str(e)[:100]}")
                continue
            if self.place_fn is not None:
                st = self.place_fn(st)
            else:
                st = jax.tree.map(jax.device_put, st)
            rnd = int(st.round)
            self._emit(failed_round, "restore",
                       detail=f"{tag} (round {rnd})")
            return st, rnd
        raise RuntimeError(
            f"supervisor: no recoverable checkpoint in {self.ckpt_dir!r} "
            f"after round {failed_round} failed (both rotation copies "
            "missing or corrupt)")

    # -- OOM shrink ladder ---------------------------------------------------

    def _shrunk_batch(self):
        """Next smaller feasible per-worker batch: the TunePlan's ok-probe
        ladder below the current size when a plan is given, else halving.
        Returns None when there is nothing smaller to try."""
        cur = self.batch_size
        if self.tune_plan is not None:
            ok = sorted({p.batch for p in self.tune_plan.probes
                         if p.ok and p.batch < cur})
            if ok:
                return ok[-1]
        half = cur // 2
        return half if half >= 1 else None

    def _backoff(self, round_idx, attempt):
        base = min(self.backoff_cap,
                   self.backoff_base * (2.0 ** max(0, attempt - 1)))
        return base * (0.5 + _jitter01(self.seed, round_idx, attempt))

    # -- the loop ------------------------------------------------------------

    def run(self, state, step_fn, batch_fn, *, start_round: int = 0):
        """Drive rounds ``start_round .. len(clock.rounds)`` to completion.

        ``step_fn(state, batch) -> (state, metrics)`` is the (jitted,
        donating) round step; ``batch_fn(spec, batch_size) -> batch``
        builds the round's batch. Returns the final state. Failure policy:
        any exception from the step is retried (restore + replay) up to
        ``retry_budget`` consecutive times when a ``ckpt_dir`` is set —
        OOMs additionally shrink the batch first — after which the
        original exception propagates. NOTE on donation: a failed donated
        step may have invalidated the input buffers, which is exactly why
        recovery always goes through the checkpoint restore, never by
        re-using the pre-step state object."""
        rounds = self.clock.rounds
        like = None
        if self.ckpt_dir:
            os.makedirs(self.ckpt_dir, exist_ok=True)
            # host-side template for restores, captured BEFORE the first
            # donated call while the buffers are valid
            like = jax.tree.map(
                lambda a: np.asarray(jax.device_get(a)), state)
            self._save(state, start_round - 1)
        i = start_round
        consec_fail = 0
        while i < len(rounds):
            spec = rounds[i]
            sync = 1.0
            if self.membership is not None:
                mask = self._mask(spec.index)
                n_active = int(mask.sum())
                if self.quorum and n_active < self.quorum:
                    # below quorum: the round degrades to local-only
                    # steps (sync=0 skips the consensus application
                    # bit-exactly) and the NEXT consensus attempt backs
                    # off exponentially with deterministic jitter —
                    # progress continues, the fleet never spins
                    self._degrade_streak += 1
                    sync = 0.0
                    b = self._backoff(spec.index, self._degrade_streak)
                    self._emit(spec.index, "degrade",
                               detail=f"active {n_active} < quorum "
                                      f"{self.quorum}",
                               backoff_s=b, attempt=self._degrade_streak)
                    if self.sleep_fn is not None:
                        self.sleep_fn(b)
                else:
                    self._degrade_streak = 0
                state = set_participation(state, mask, sync=sync)
            try:
                if self.chaos is not None:
                    self.chaos.before_step(spec.index, self.batch_size)
                batch = batch_fn(spec, self.batch_size)
                state, metrics = step_fn(state, batch)
            except Exception as e:   # noqa: BLE001 — policy: retry w/ budget
                consec_fail += 1
                oom = is_oom(e)
                if oom:
                    self._emit(spec.index, "oom", detail=str(e)[:120])
                if like is None or consec_fail > self.retry_budget:
                    raise
                if oom:
                    smaller = self._shrunk_batch()
                    if smaller is None:
                        raise
                    self._emit(spec.index, "shrink",
                               detail=f"batch {self.batch_size} -> "
                                      f"{smaller}")
                    self.batch_size = smaller
                state, restored = self._restore(spec.index, like)
                b = self._backoff(spec.index, consec_fail)
                self._emit(spec.index, "retry",
                           detail=f"replay from round {restored}",
                           backoff_s=b, attempt=consec_fail)
                if self.sleep_fn is not None:
                    self.sleep_fn(b)
                i = restored
                continue
            consec_fail = 0
            if self.on_round is not None:
                self.on_round(spec, metrics)
            if self.logger is not None:
                self.logger(spec, metrics)
            if self.ckpt_dir and (spec.index + 1) % self.ckpt_every == 0:
                self._save(state, spec.index)
            i += 1
        return state
