"""RoundClock: the single source of truth for step/round accounting.

The paper's communication-efficiency axis is the round clock — how often
workers synchronize (tau) and how hard they push (lam_t, §C.2), with §7.2
adapting tau to the LR via the Quadratic Synchronization Rule (Gu et al.
2024). Before this module, each callsite kept its own fragment of that
clock and each fragment was subtly wrong:

* the round builders derived ``round_idx = t // tau`` AFTER the scan had
  advanced ``t``, so ``lam_schedule`` never evaluated at round 0 and the
  whole "increasing" trajectory (the paper's main-results default) ran one
  round early;
* ``launch/train.py`` iterated ``steps // tau`` rounds, silently dropping
  the ``steps % tau`` remainder;
* ``schedules.qsr_tau`` was dead code reachable only from its unit test.

The ``RoundClock`` precomputes the ENTIRE round plan host-side at
construction — a tuple of ``RoundSpec(index, start, tau)`` covering every
one of ``total_steps`` steps (the final round absorbs the remainder; with
``tau_schedule="qsr"`` each round's tau comes from the cosine LR at the
round's first step) — and owns the two traced-compatible schedule reads:

* ``lam_at(round_idx)``: lam_t for the round ABOUT TO RUN, evaluated over
  ``total_rounds - 1`` so round 0 sees ``lam_schedule(·, 0, ·)`` (zero for
  "increasing") and the final round sees the full ``lam``;
* ``lr_at(t)``: the cosine LR at global step ``t``.

Drivers (``launch/train.py``, ``benchmarks/common.run_distributed``)
iterate ``clock.rounds`` and cut each round's batch to ``spec.tau`` steps
seeded by ``spec.start`` (the GLOBAL step — adaptive runs replay the same
data stream as fixed-tau runs over the same step budget). A tau change
between rounds changes the batch's leading dim, so ``jax.jit``'s
shape-keyed cache IS the per-tau compiled-step cache — no extra machinery.
The clock position (``TrainState.round``) persists through
``checkpoint/io.py`` save/resume. See DESIGN.md §Round-clock.
"""
from __future__ import annotations

import json
import math
import os
from dataclasses import dataclass
from functools import cached_property
from typing import Tuple

from repro.core.schedules import cosine_lr, lam_schedule, qsr_tau

TAU_SCHEDULES = ("fixed", "qsr")
OVERLAP_MODES = ("none", "staleness1", "doublebuf", "staleness_k")


@dataclass(frozen=True)
class RoundSpec:
    """One communication round of the plan (host ints, known up front)."""
    index: int      # 0-based round index
    start: int      # GLOBAL step of the round's first local step
    tau: int        # local steps this round (>= 1; the last round may be
                    # shorter — the remainder is run, never dropped)
    # inner/outer plan (Entropy-SGD): "inner" sub-rounds apply the weak
    # ``inner_pull``-scaled pull (local-entropy exploration), the final
    # "outer" piece of each base round applies the full pull. Plans
    # without an inner loop are all-"outer".
    scope: str = "outer"

    @property
    def stop(self) -> int:
        """Global step after the round (== next round's ``start``)."""
        return self.start + self.tau


def _host_cosine_lr(base_lr: float, t: int, total: int, warmup: int) -> float:
    """Pure-python twin of ``schedules.cosine_lr`` for the host-side round
    plan (no jnp dispatch per round; the traced reads go through
    ``lr_at``)."""
    if t < warmup:
        return base_lr * t / max(warmup, 1)
    frac = min(max((t - warmup) / max(total - warmup, 1), 0.0), 1.0)
    return base_lr / 2.0 * (1.0 + math.cos(frac * math.pi))


@dataclass(frozen=True)
class RoundClock:
    """Step/round accounting for one training run (hashable, host-side).

    ``rounds`` is derived lazily (cached on first read — DDP drivers only
    touch ``lr_at`` and never pay for a plan) and covers exactly
    ``total_steps`` steps. ``lam_at``/``lr_at`` accept traced scalars and
    are the ONLY schedule reads the round builders perform.
    """
    total_steps: int
    tau: int                         # base communication period
    base_lr: float = 0.0
    warmup: int = 0
    lam: float = 0.0
    lam_kind: str = "increasing"     # fixed | increasing | decreasing (§C.2)
    tau_schedule: str = "fixed"      # fixed | qsr (§7.2)
    qsr_beta: float = 0.0            # QSR: tau_t = max(tau, floor((beta/eta)^2))
    # overlap-aware QSR: with a stale consensus ("staleness1"/"doublebuf"/
    # "staleness_k", DESIGN.md §Overlap) round r applies the consensus of
    # round r-k's iterate (k = ``staleness_depth``), so the QSR period of
    # round r is sized from the LR of the round-(r-k) start — the stale LR
    # — keeping sync frequency matched to the iterate actually being
    # synchronized. The plan stays a host-side pure function of the config
    # (static-shaped rounds).
    overlap: str = "none"
    # pipeline depth k of overlap="staleness_k" (ignored by the other
    # modes, whose depth is fixed at 1)
    staleness: int = 1
    # inner/outer plan (Entropy-SGD, from the MethodSpec registry):
    # inner_rounds > 1 splits every base round into that many sub-rounds;
    # the non-final pieces are "inner" and scale the pull coefficient by
    # inner_pull (``pull_scale_at``), the final piece is the full-pull
    # "outer" round. 0/1 = no inner loop (every round "outer").
    inner_rounds: int = 0
    inner_pull: float = 1.0

    def __post_init__(self):
        # ValueError, not assert: these guard user-facing config plumbing
        # and must survive ``python -O``
        if self.total_steps < 1:
            raise ValueError(f"total_steps must be >= 1, got {self.total_steps}")
        if self.tau < 1:
            raise ValueError(f"tau must be >= 1, got {self.tau}")
        if self.tau_schedule not in TAU_SCHEDULES:
            raise ValueError(f"unknown tau schedule {self.tau_schedule!r} "
                             f"(expected one of {TAU_SCHEDULES})")
        if self.tau_schedule == "qsr":
            if self.qsr_beta <= 0:
                raise ValueError("tau_schedule='qsr' needs qsr_beta > 0")
            if self.base_lr <= 0:
                raise ValueError("tau_schedule='qsr' adapts tau to the "
                                 "cosine LR and needs base_lr > 0")
        if self.overlap not in OVERLAP_MODES:
            raise ValueError(f"unknown overlap mode {self.overlap!r} "
                             f"(expected one of {OVERLAP_MODES})")
        if self.warmup < 0:
            raise ValueError(f"warmup must be >= 0, got {self.warmup}")
        if self.staleness < 1:
            raise ValueError(f"staleness must be >= 1, got {self.staleness}")
        if self.inner_rounds < 0:
            raise ValueError(f"inner_rounds must be >= 0, got "
                             f"{self.inner_rounds}")
        if not 0.0 < self.inner_pull <= 1.0:
            raise ValueError(f"inner_pull must be in (0, 1], got "
                             f"{self.inner_pull}")
        if self.overlap == "staleness_k" and self.warmup > 0 and \
                math.ceil(self.warmup / self.tau) < self.staleness:
            # the first k rounds are exact-consensus pipeline fill; a
            # warmup shorter than k rounds would end mid-fill, so the
            # stale-LR QSR reads would straddle the warmup boundary
            raise ValueError(
                f"overlap='staleness_k' needs warmup >= k rounds so the "
                f"pipeline fill never straddles the warmup boundary: "
                f"warmup={self.warmup} steps covers "
                f"{math.ceil(self.warmup / self.tau)} rounds at tau="
                f"{self.tau} but staleness k={self.staleness} (use "
                f"warmup=0 or warmup >= {self.staleness * self.tau})")

    @classmethod
    def from_config(cls, dcfg, *, base_lr: float, total_steps: int,
                    warmup: int = 0) -> "RoundClock":
        """Build the clock from a ``DPPFConfig`` + the LR triple. A config
        with ``qsr_beta > 0`` opts into QSR even if ``tau_schedule`` was
        left at "fixed" (the pre-clock opt-in convention)."""
        tau_schedule = getattr(dcfg, "tau_schedule", "fixed")
        if tau_schedule == "fixed" and dcfg.qsr_beta > 0:
            tau_schedule = "qsr"
        # the method registry owns the inner/outer plan (Entropy-SGD's
        # local-entropy loop is clock structure, not trainer code)
        from repro.core.methods import get_method
        spec = get_method(getattr(dcfg, "consensus", "simple_avg"))
        return cls(total_steps=total_steps, tau=dcfg.tau, base_lr=base_lr,
                   warmup=warmup, lam=dcfg.lam, lam_kind=dcfg.lam_schedule,
                   tau_schedule=tau_schedule, qsr_beta=dcfg.qsr_beta,
                   overlap=getattr(dcfg, "overlap", "none"),
                   staleness=getattr(dcfg, "staleness", 1),
                   inner_rounds=spec.inner_rounds,
                   inner_pull=spec.inner_pull)

    @classmethod
    def from_tune_plan(cls, plan, *, base_lr: float, total_steps: int,
                       warmup: int = 0, dcfg=None) -> "RoundClock":
        """Build the clock from an autotune ``TunePlan`` (the
        ``--autotune`` / ``--tune-plan`` path, DESIGN.md §Autotune). The
        plan pins tau to the searched point with ``tau_schedule="fixed"``
        — autotune already placed tau at the measured comm/compute
        crossover, so no schedule re-adapts it. With ``dcfg`` the plan is
        grafted onto the config via ``dcfg.apply_tune_plan`` and routed
        through ``from_config`` (keeping lam and the method registry's
        inner/outer plan); without, a bare fixed-tau clock. Accepts the
        dataclass or its ``to_dict()`` JSON form — replay through either
        is bit-identical (``tests/test_autotune.py`` pins it)."""
        if isinstance(plan, dict):
            tau = int(plan["chosen"]["tau"])
            overlap = str(plan.get("overlap", "none"))
            staleness = int(plan.get("staleness", 1))
        else:
            tau = int(plan.chosen.tau)
            overlap = plan.overlap
            staleness = int(plan.staleness)
        if dcfg is not None:
            return cls.from_config(dcfg.apply_tune_plan(plan),
                                   base_lr=base_lr, total_steps=total_steps,
                                   warmup=warmup)
        return cls(total_steps=total_steps, tau=tau, base_lr=base_lr,
                   warmup=warmup, tau_schedule="fixed", overlap=overlap,
                   staleness=staleness)

    @property
    def staleness_depth(self) -> int:
        """Pipeline depth of the overlap mode: 0 (no overlap), 1
        (staleness1/doublebuf) or k (staleness_k). Round r >= depth applies
        the consensus of round r - depth; rounds 0..depth-1 are fill."""
        if self.overlap == "none":
            return 0
        if self.overlap == "staleness_k":
            return self.staleness
        return 1

    # -- round plan ---------------------------------------------------------

    @cached_property
    def rounds(self) -> Tuple[RoundSpec, ...]:
        # cached_property writes the result straight into __dict__, which a
        # frozen dataclass permits; the plan is a pure function of the
        # (compared, hashed) config fields, so equality/hash are unaffected
        rounds, t = [], 0
        while t < self.total_steps:
            if self.tau_schedule == "qsr":
                if t < self.warmup:
                    # warmup-aware QSR: the warmup LR is tiny, so the raw
                    # rule (beta/eta)^2 would blow tau up exactly when the
                    # model changes fastest — warmup rounds keep the base
                    # tau (Gu et al. 2024 sync frequently during warmup)
                    # and never straddle the warmup boundary, so the first
                    # cosine-ruled round starts AT ``warmup``
                    tau_t = min(self.tau, self.warmup - t)
                else:
                    # overlap-aware QSR: under a stale consensus round r
                    # applies the round-(r-k) iterate (k = staleness
                    # depth), so its period is ruled by the STALE LR — the
                    # start of the round k back (fill rounds / the first
                    # post-warmup rounds have no stale predecessor and use
                    # their own LR)
                    t_lr = t
                    d = self.staleness_depth
                    if d >= 1 and len(rounds) >= d and \
                            rounds[-d].start >= self.warmup:
                        t_lr = rounds[-d].start
                    eta = _host_cosine_lr(self.base_lr, t_lr,
                                          self.total_steps, self.warmup)
                    tau_t = qsr_tau(eta, self.tau, self.qsr_beta)
            else:
                tau_t = self.tau
            tau_t = min(tau_t, self.total_steps - t)   # never drop remainder
            for piece, scope in self._split_inner(tau_t):
                rounds.append(RoundSpec(index=len(rounds), start=t,
                                        tau=piece, scope=scope))
                t += piece
        return tuple(rounds)

    def _split_inner(self, tau_t: int):
        """Split one base round's tau into the inner/outer sub-round plan:
        ``inner_rounds`` near-equal pieces, all but the last "inner" (weak
        pull). A tau too short to split keeps fewer (non-empty) pieces; no
        inner loop -> the single "outer" round."""
        k = self.inner_rounds
        if k <= 1 or tau_t <= 1:
            return [(tau_t, "outer")]
        k = min(k, tau_t)
        base, rem = divmod(tau_t, k)
        pieces = [base + 1] * rem + [base] * (k - rem)
        return [(p, "inner" if i < len(pieces) - 1 else "outer")
                for i, p in enumerate(pieces)]

    @property
    def total_rounds(self) -> int:
        return len(self.rounds)

    @property
    def fixed_rounds(self) -> int:
        """Rounds (= consensus all-reduces) a fixed-tau clock would pay for
        the same step budget — the baseline for QSR's savings."""
        return math.ceil(self.total_steps / self.tau)

    def round_of_step(self, t: int) -> int:
        """Round index containing global step ``t`` (== ``total_rounds``
        when ``t == total_steps``, i.e. training finished). Used by resume
        paths to recover the clock position from a step counter alone."""
        if t < 0 or t > self.total_steps:
            raise ValueError(f"step {t} outside [0, {self.total_steps}]")
        for spec in self.rounds:
            if t < spec.stop:
                return spec.index
        return self.total_rounds

    def taus(self) -> Tuple[int, ...]:
        return tuple(spec.tau for spec in self.rounds)

    # -- traced-compatible schedule reads ------------------------------------

    def lam_at(self, round_idx):
        """Push strength for round ``round_idx`` (the round ABOUT TO RUN —
        evaluate BEFORE the scan advances t). The denominator is
        ``total_rounds - 1`` so the trajectory spans both endpoints: round
        0 sees ``lam_schedule(·, 0, ·)`` and the final round sees the full
        ``lam``. A single-round plan has no trajectory to span — its one
        round is both endpoints, and it applies the FULL lam (a zero-push
        round would silently disable the paper's push term). Accepts a
        traced scalar."""
        if self.total_rounds == 1:
            return lam_schedule("fixed", self.lam, round_idx, 1)
        return lam_schedule(self.lam_kind, self.lam, round_idx,
                            self.total_rounds - 1)

    def lr_at(self, t):
        """Cosine LR at global step ``t`` (traced ok)."""
        return cosine_lr(self.base_lr, t, self.total_steps, self.warmup)

    def pull_scale_at(self, round_idx):
        """Pull-coefficient scale of round ``round_idx`` from the
        inner/outer plan: ``inner_pull`` on "inner" sub-rounds, 1.0 on
        "outer" rounds. Plans without an inner loop return the python
        float 1.0 (an IEEE-exact no-op for every caller — the round
        builders multiply it in unconditionally). Accepts a traced scalar
        (jnp.take over the host-side plan)."""
        if self.inner_rounds <= 1:
            return 1.0
        import jax.numpy as jnp
        scales = jnp.asarray(
            tuple(self.inner_pull if r.scope == "inner" else 1.0
                  for r in self.rounds), jnp.float32)
        return jnp.take(scales, jnp.clip(round_idx, 0,
                                         self.total_rounds - 1))

    def _host_lam(self, round_idx: int) -> float:
        """Pure-python twin of ``lam_at`` for the host-side plan report."""
        T = max(self.total_rounds - 1, 1)
        if self.total_rounds == 1:
            return self.lam
        frac = min(max(round_idx / T, 0.0), 1.0)
        if self.lam_kind == "fixed":
            return self.lam
        if self.lam_kind == "decreasing":
            return self.lam / 2.0 * (1.0 + math.cos(frac * math.pi))
        if self.lam_kind == "increasing":
            return self.lam / 2.0 * (1.0 - math.cos(frac * math.pi))
        raise ValueError(self.lam_kind)

    def describe(self) -> dict:
        """Machine-readable summary + full round plan (the committed
        ``BENCH_roundclock.json`` baseline and the dry-run report's table
        both render this). ``plan`` has one row per round: index, global
        start step, tau, the lam the round applies, and the LR window
        ``[lr_start, lr_end]`` its local steps sweep (floats rounded to 6
        digits so the committed baseline compares stably across hosts).

        Worked QSR example — ``RoundClock(total_steps=64, tau=4,
        base_lr=0.3, tau_schedule="qsr", qsr_beta=0.4)``: a round starting
        at step t gets ``tau_t = max(4, floor((0.4 / eta_t)^2))`` from the
        cosine LR ``eta_t``. Early rounds keep tau=4 (eta(0) = 0.3 ->
        floor(1.77) = 1 < 4); at step 32, eta = 0.15 -> floor(7.11) = 7;
        at step 39, eta ~ 0.0995 -> 16; the round at step 55 would get a
        huge tau but is capped to the 9 remaining steps. Full plan: taus
        (4,4,4,4,4,4,4,4,7,16,9) — 11 rounds vs 16 fixed, 5 consensus
        all-reduces saved (``tests/test_clock.py`` pins exactly this
        plan)."""
        taus = self.taus()
        depth = self.staleness_depth
        inner = self.inner_rounds > 1
        plan = []
        for spec in self.rounds:
            row = {
                "round": spec.index,
                "start": spec.start,
                "tau": spec.tau,
                "lam": round(self._host_lam(spec.index), 6),
                "lr_start": round(_host_cosine_lr(
                    self.base_lr, spec.start, self.total_steps,
                    self.warmup), 6),
                "lr_end": round(_host_cosine_lr(
                    self.base_lr, spec.stop - 1, self.total_steps,
                    self.warmup), 6),
                "warmup": spec.start < self.warmup,
                # staleness depth of the consensus this round applies:
                # rounds 0..depth-1 are exact fill (0), later rounds apply
                # the round-(r-depth) snapshot (depth)
                "staleness": depth if spec.index >= depth else 0,
            }
            if inner:
                # conditional key: plans without an inner loop keep the
                # exact legacy row schema (committed BENCH baselines)
                row["scope"] = spec.scope
            plan.append(row)
        out = {
            "total_steps": self.total_steps,
            "tau_base": self.tau,
            "tau_schedule": self.tau_schedule,
            "qsr_beta": self.qsr_beta,
            "warmup": self.warmup,
            "warmup_rounds": sum(1 for r in plan if r["warmup"]),
            "overlap": self.overlap,
            "staleness": depth,
            "rounds": self.total_rounds,
            "fixed_rounds": self.fixed_rounds,
            "allreduces_saved": self.fixed_rounds - self.total_rounds,
            "tau_min": min(taus),
            "tau_max": max(taus),
            "plan": plan,
        }
        if inner:
            out["inner_rounds"] = self.inner_rounds
            out["inner_pull"] = self.inner_pull
        return out

    def plan_table(self, max_rows: int = 12) -> str:
        """The round plan as a markdown table (the dry-run report prints
        this). Long plans elide the middle, keeping the first and last
        ``max_rows // 2`` rounds."""
        d = self.describe()
        rows = d["plan"]
        extra = ""
        if d["warmup"]:
            extra += (f", warmup {d['warmup']} steps = "
                      f"{d['warmup_rounds']} rounds")
        if d["overlap"] != "none":
            extra += f", overlap {d['overlap']} (k={d['staleness']})"
            if d["tau_schedule"] == "qsr":
                extra += " (stale-LR QSR)"
        if d.get("inner_rounds"):
            extra += (f", inner/outer plan x{d['inner_rounds']} "
                      f"(inner pull {d['inner_pull']})")
        head = [f"round plan: {d['rounds']} rounds over "
                f"{d['total_steps']} steps (tau_schedule="
                f"{d['tau_schedule']}, tau {d['tau_min']}..{d['tau_max']}, "
                f"all-reduces saved vs fixed: {d['allreduces_saved']}"
                f"{extra})",
                "| round | start | tau | lam | lr window | staleness |",
                "|---|---|---|---|---|---|"]
        if len(rows) > max_rows:
            half = max(max_rows // 2, 1)
            shown = list(rows[:half]) + [None] + list(rows[-half:])
        else:
            shown = rows
        for r in shown:
            if r is None:
                head.append("| ... | | | | | |")
                continue
            tau_cell = f"{r['tau']} (warm)" if r["warmup"] else f"{r['tau']}"
            if r.get("scope") == "inner":
                tau_cell += " (inner)"
            head.append(f"| {r['round']} | {r['start']} | {tau_cell} | "
                        f"{r['lam']:.4f} | {r['lr_start']:.4f} -> "
                        f"{r['lr_end']:.4f} | {r['staleness']} |")
        return "\n".join(head)


class RoundMetricsLogger:
    """Per-round metrics hook: one JSON line per communication round.

    Drivers that iterate ``clock.rounds`` call the logger with the round's
    ``RoundSpec`` and the unified round-metrics dict every round builder
    emits (``consensus_dist``/``pre_dist``/``pull_force``/``push_force``/
    ``train_loss``/``lam_t``/``staleness`` — the ddp branch included, where
    the consensus fields are zeros and the clock is the tau=1 per-step
    clock; pass a plain step index instead of a spec there). ``staleness``
    is the integer depth of the consensus the round applied (0 = exact,
    k = the round-(r-k) snapshot); a legacy boolean ``stale`` key (the
    pre-staleness_k schema, where 0/1 IS the depth) is normalized to
    ``staleness`` so old emitters and old JSONL stay readable. Each line
    carries the clock position (round, global start step, tau) plus the
    metrics, so a QSR-adaptive run's log is self-describing. Values are
    converted via ``float`` — call it OUTSIDE jit (on the returned
    metrics), never inside a traced function.
    ``launch/train.py --log-every-round PATH`` wires it
    (``--legacy-metrics`` for the PR 7 compat ``stale`` boolean).
    """

    def __init__(self, path: str, *, legacy: bool = False):
        self.path = path
        # legacy=True re-emits the pre-staleness_k boolean ``stale`` key
        # NEXT TO the integer ``staleness`` (old downstream parsers); the
        # default emits only ``staleness`` — no double key
        self.legacy = legacy
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        self._fh = open(path, "w")

    def __call__(self, spec, metrics: dict):
        if isinstance(spec, RoundSpec):
            row = {"round": spec.index, "start": spec.start, "tau": spec.tau}
        else:   # ddp / per-step drivers: a bare global step index
            row = {"round": int(spec), "start": int(spec), "tau": 1}
        for k, v in metrics.items():
            if k == "stale":
                if "staleness" in metrics:
                    # modern emitters carry the integer depth; drop the
                    # duplicate boolean instead of double-emitting it
                    continue
                # legacy emitters: the boolean flag's 0/1 IS the depth
                k = "staleness"
            try:
                row[k] = float(v)
            except (TypeError, ValueError):
                row[k] = str(v)
        if self.legacy and "staleness" in row:
            row["stale"] = bool(row["staleness"] > 0)
        self._fh.write(json.dumps(row) + "\n")
        self._fh.flush()
        return row

    def close(self):
        self._fh.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
