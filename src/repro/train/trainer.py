"""DPPF trainer: a communication ROUND is one compiled function —
``lax.scan`` over tau purely-local optimizer steps (zero worker-axis
collectives) followed by the consensus pull-push update (the round's single
all-reduce). The DDP baseline is a separate per-step function whose gradient
mean over the worker axis lowers to the classic every-step all-reduce.

Both are generic over ``loss_fn(params, batch) -> (loss, metrics)`` so the
same trainer drives the 10 assigned LM architectures and the small
paper-table stand-in models.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import DPPFConfig
from repro.core import consensus
from repro.core.schedules import cosine_lr, lam_schedule
from repro.optim import Optimizer, sam_gradient


@jax.tree_util.register_dataclass
@dataclass
class TrainState:
    params: Any          # worker-stacked (M, ...) for DPPF; flat for DDP
    opt: Any
    cstate: Any          # consensus state (EASGD center etc.)
    t: jnp.ndarray       # local-step counter (scalar int32)


def _grad_norm(grads):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(grads)))


def init_train_state(loss_params_init, opt: Optimizer, dcfg: DPPFConfig,
                     n_workers: int, key, *, same_init=True):
    """Stack per-worker params. The paper initializes all workers from the
    same random model (Alg. 1); ``same_init=False`` gives per-worker seeds
    (useful for the width ablations)."""
    if same_init:
        p0 = loss_params_init(key)
        params = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (n_workers,) + a.shape), p0)
        # materialize (broadcast arrays are lazy views)
        params = jax.tree.map(jnp.array, params)
    else:
        keys = jax.random.split(key, n_workers)
        params = jax.vmap(loss_params_init)(keys)
    opt_state = jax.vmap(opt.init)(params)
    cstate = consensus.init_state(dcfg.consensus, params)
    return TrainState(params=params, opt=opt_state, cstate=cstate,
                      t=jnp.zeros((), jnp.int32))


def make_round_step(loss_fn, opt: Optimizer, dcfg: DPPFConfig, *,
                    base_lr: float, total_steps: int, warmup: int = 0,
                    sam_rho: float = 0.0, total_rounds: Optional[int] = None):
    """Build the fused DPPF round: scan(tau local steps) + consensus.

    Input batch pytree has leading dims (tau, M, ...). Returns
    round_step(state, batch) -> (state, metrics). jit/shard at callsite.
    """
    total_rounds = total_rounds or max(total_steps // max(dcfg.tau, 1), 1)

    def local_step(p, o, b, t):
        if sam_rho > 0:
            (loss, _), g = sam_gradient(loss_fn, p, b, sam_rho)
        else:
            (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(p, b)
        lr = cosine_lr(base_lr, t, total_steps, warmup)
        gn = _grad_norm(g)
        p, o = opt.step(p, g, o, lr)
        return p, o, loss, gn

    def round_step(state: TrainState, batch):
        def micro(carry, mb):
            params, opt_st, t = carry
            params, opt_st, losses, gns = jax.vmap(
                local_step, in_axes=(0, 0, 0, None))(params, opt_st, mb, t)
            return (params, opt_st, t + 1), (losses, gns)

        (params, opt_st, t), (losses, gns) = jax.lax.scan(
            micro, (state.params, state.opt, state.t), batch)

        round_idx = t // max(dcfg.tau, 1)
        lam_t = lam_schedule(dcfg.lam_schedule, dcfg.lam, round_idx,
                             total_rounds)
        params, cstate, metrics = consensus.apply_round(
            params, dcfg, lam_t, state.cstate,
            losses=losses[-1], grad_norms=gns[-1])
        metrics = dict(metrics)
        metrics["train_loss"] = losses.mean()
        metrics["lam_t"] = lam_t
        new_state = TrainState(params=params, opt=opt_st, cstate=cstate, t=t)
        return new_state, metrics

    return round_step


def make_ddp_step(loss_fn, opt: Optimizer, *, base_lr: float,
                  total_steps: int, warmup: int = 0, sam_rho: float = 0.0):
    """DDP baseline: one replica; per-worker micro-grads are averaged every
    step (lowers to the per-step all-reduce on the mesh). Batch leading dim
    is M (the worker/data axis)."""
    def step(state: TrainState, batch):
        def per_worker(b):
            if sam_rho > 0:
                (loss, _), g = sam_gradient(loss_fn, state.params, b, sam_rho)
            else:
                (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    state.params, b)
            return loss, g

        losses, grads = jax.vmap(per_worker)(batch)
        g = jax.tree.map(lambda a: jnp.mean(a.astype(jnp.float32), axis=0),
                         grads)
        lr = cosine_lr(base_lr, state.t, total_steps, warmup)
        params, opt_st = opt.step(state.params, g, state.opt, lr)
        new_state = TrainState(params=params, opt=opt_st, cstate=state.cstate,
                               t=state.t + 1)
        return new_state, {"train_loss": losses.mean()}

    return step


def average_params(state: TrainState):
    """Final returned model: the worker average (Alg. 1 last line)."""
    if jax.tree.leaves(state.params)[0].ndim == 0:
        return state.params
    from repro.core import pullpush as pp
    return pp.tree_mean0(state.params)
