"""DPPF trainer: a communication ROUND is one compiled function —
``lax.scan`` over tau purely-local optimizer steps (zero worker-axis
collectives) followed by the consensus pull-push update (the round's single
all-reduce). The DDP baseline is a separate per-step function whose gradient
mean over the worker axis lowers to the classic every-step all-reduce.

Both are generic over ``loss_fn(params, batch) -> (loss, metrics)`` so the
same trainer drives the 10 assigned LM architectures and the small
paper-table stand-in models.

With ``DPPFConfig.engine == "flat"`` the worker parameters live in the
ConsensusEngine's persistent ``(R, n)`` fp32 view for the WHOLE run: it is
built once in ``init_train_state``, local steps differentiate through cheap
slice/reshape views of it (``engine.unflatten_row``), and the consensus
update runs as flat Gram+mixing passes — no per-round flatten/concatenate.
Donate the state (``jax.jit(round_step, donate_argnums=0)``) so the buffer
is reused in place across rounds (DESIGN.md §Consensus-engine).

Two round-level extensions on top of the flat engine:

* ``make_sharded_round_step`` lowers the WHOLE round under
  ``jax.shard_map``: worker rows of the (R, n) view shard over the plan's
  worker axes, columns over its fsdp/model axes; the round's collectives
  are one worker-row all-gather at the round boundary plus the engine's
  (R, R) partial-Gram psum (DESIGN.md §Sharded-execution).
* ``DPPFConfig.overlap`` runs the stale-consensus recursion
  (DESIGN.md §Overlap): ``"staleness1"`` applies the consensus computed
  from the PREVIOUS round's snapshot (carried in ``TrainState.snap``), so
  the consensus collectives have no data dependence on the current round's
  local steps and the scheduler hides them behind tau steps of compute;
  ``"doublebuf"`` additionally carries the snapshot ROW-SHARDED and
  dispatches its worker-row gather + stage-1 Gram psum in
  ``overlap_chunks`` column chunks interleaved with the scan's segments,
  leaving only coefficient math + the mix GEMM at the round boundary
  (round 0 fills the pipeline with an EXACT consensus of the fresh view);
  ``"staleness_k"`` generalizes doublebuf to a k-deep snapshot RING —
  round r applies the consensus of the round-(r-k) snapshot, rounds
  0..k-1 are exact-consensus pipeline fill, the sharded worker-row gather
  runs as a ``launch.mesh.ring_gather`` ppermute ring (R-1 single-row
  hops interleaved with the scan segments), and ``DPPFConfig.elastic``
  adds bounded-async membership: a per-row participation mask rides the
  carry, an inactive row freezes and drops out of the consensus weights
  for up to k rounds, then rejoins with an EASGD-style catch-up pull
  (``set_participation`` is the host-side driver hook).

Step/round accounting is owned by ``repro.train.clock.RoundClock``
(DESIGN.md §Round-clock): every builder reads lam_t via
``clock.lam_at(state.round)`` — the index of the round ABOUT TO RUN, so
round 0 evaluates ``lam_schedule(·, 0, ·)`` and the final round the full
lam — and the LR via ``clock.lr_at(t)``. The builders are tau-oblivious:
``t`` advances by the batch's leading (scan) dim and ``round`` by one, so
ONE builder serves fixed, remainder, and QSR-adaptive round lengths
(``jax.jit``'s shape-keyed cache is the per-tau compile cache).
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import DPPFConfig
from repro.core import consensus
from repro.core.engine import ConsensusEngine, ShardedLayout
from repro.core.methods import get_method
from repro.optim import Optimizer, sam_gradient
from repro.train.clock import RoundClock


@dataclass
class TrainState:
    params: Any          # worker-stacked (M, ...) for DPPF; flat for DDP;
                         # the engine's (R, n) flat view when engine is set
    opt: Any
    cstate: Any          # consensus state (EASGD center etc.)
    t: jnp.ndarray       # local-step counter (scalar int32)
    snap: Any = None     # overlap carry (flat engine only). staleness1/
                         # doublebuf: {"x": (R, n) snapshot, "losses": (M,),
                         # "gns": (M,)}; staleness_k: a k-deep ring ordered
                         # oldest -> newest — {"x": (k, R, n), "losses":
                         # (k, M), "gns": (k, M)} plus, when elastic,
                         # {"act": (k, M) participation at snapshot time,
                         # "active": (M,) requested membership,
                         # "missed": (M,) int32 consecutive misses}
    round: Any = None    # round counter (scalar int32) — the clock position;
                         # None on hand-built/DDP states (builders fall back
                         # to the pre-scan ``t // tau``)
    engine: Any = None   # ConsensusEngine (static metadata) or None


# ``engine`` is hashable static metadata: jit recompiles if the layout
# changes, and donation/vmap only ever see the array fields.
jax.tree_util.register_dataclass(
    TrainState, data_fields=("params", "opt", "cstate", "t", "snap", "round"),
    meta_fields=("engine",))


def _chunk_bounds(n: int, k: int):
    """Split ``range(n)`` into ``k`` contiguous near-equal pieces (host
    ints; first pieces absorb the remainder). The one copy of the
    double-buffered overlap's chunk arithmetic — used for both the
    snapshot's column chunks and the scan's step segments."""
    base, rem = divmod(n, k)
    bounds, a = [], 0
    for i in range(k):
        b = a + base + (1 if i < rem else 0)
        bounds.append((a, b))
        a = b
    return bounds


def _round_index(state: TrainState, dcfg: DPPFConfig):
    """The index of the round about to run. States built by
    ``init_train_state`` carry the clock position; legacy hand-built states
    fall back to the PRE-scan ``t // tau`` (correct for fixed tau — the
    historical post-scan ``t // tau`` was the off-by-one)."""
    if state.round is not None:
        return state.round
    return state.t // max(dcfg.tau, 1)


def _legacy_clock(dcfg, base_lr, total_steps, warmup, who):
    if base_lr is None or total_steps is None:
        raise ValueError(f"{who} needs a RoundClock (clock=...) or the "
                         "legacy base_lr/total_steps pair")
    return RoundClock.from_config(dcfg, base_lr=base_lr,
                                  total_steps=total_steps, warmup=warmup)


def _grad_norm(grads):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(grads)))


def _scan_local_steps(loss, opt: Optimizer, p0, opt_st, t0, batch, *,
                      clock: RoundClock, sam_rho):
    """The tau purely-local steps shared by every round builder:
    ``lax.scan`` over the batch's leading (tau) dim, vmap over the worker
    dim of ``p0``/``opt_st``/``batch[:, m]``. Returns
    ``(params, opt_st, t, losses, gns)`` with losses/gns shaped (tau, M)."""
    def local_step(p, o, b, t):
        if sam_rho > 0:
            (loss_v, _), g = sam_gradient(loss, p, b, sam_rho)
        else:
            (loss_v, _), g = jax.value_and_grad(loss, has_aux=True)(p, b)
        lr = clock.lr_at(t)
        gn = _grad_norm(g)
        p, o = opt.step(p, g, o, lr)
        return p, o, loss_v, gn

    def micro(carry, mb):
        params, opt_state, t = carry
        params, opt_state, losses, gns = jax.vmap(
            local_step, in_axes=(0, 0, 0, None))(params, opt_state, mb, t)
        return (params, opt_state, t + 1), (losses, gns)

    (params, opt_st, t), (losses, gns) = jax.lax.scan(
        micro, (p0, opt_st, t0), batch)
    return params, opt_st, t, losses, gns


def init_train_state(loss_params_init, opt: Optimizer, dcfg: DPPFConfig,
                     n_workers: int, key, *, same_init=True, engine=None):
    """Stack per-worker params. The paper initializes all workers from the
    same random model (Alg. 1); ``same_init=False`` gives per-worker seeds
    (useful for the width ablations).

    With ``dcfg.engine == "flat"`` (or an explicit ``engine``) the stacked
    tree is flattened ONCE here into the engine's persistent (R, n) view;
    every subsequent round reuses/donates that buffer.
    """
    if same_init:
        p0 = loss_params_init(key)
        params = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (n_workers,) + a.shape), p0)
        # materialize (broadcast arrays are lazy views)
        params = jax.tree.map(jnp.array, params)
    else:
        keys = jax.random.split(key, n_workers)
        params = jax.vmap(loss_params_init)(keys)
    if engine is None and getattr(dcfg, "engine", "tree") == "flat" \
            and get_method(dcfg.consensus).communicates:
        engine = ConsensusEngine.from_stacked(
            params, method=dcfg.consensus, eps=dcfg.eps)
    snap = None
    if engine is not None:
        params = engine.flatten(params)           # the ONE flatten per run
        opt_state = jax.vmap(opt.init)(engine.workers(params))
        cstate = consensus.init_state(dcfg.consensus, params, engine=engine)
        overlap_mode = getattr(dcfg, "overlap", "none")
        if overlap_mode == "staleness_k":
            # k-deep snapshot ring, oldest -> newest: slot 0 is the
            # round-(r-k) snapshot whose consensus applies after round r's
            # scan; rounds 0..k-1 are exact-consensus pipeline fill. The
            # + 0.0 copy keeps the ring and params donation-distinct.
            k = dcfg.staleness
            snap = {"x": jnp.broadcast_to(
                        params[None], (k,) + params.shape) + 0.0,
                    "losses": jnp.zeros((k, n_workers), jnp.float32),
                    "gns": jnp.ones((k, n_workers), jnp.float32)}
            if dcfg.elastic:
                # sync is the scalar quorum gate (train/supervisor.py):
                # 1 = normal round, 0 = quorum-degraded — local steps run
                # but the consensus application is skipped bit-exactly
                snap.update(
                    act=jnp.ones((k, n_workers), jnp.float32),
                    active=jnp.ones((n_workers,), jnp.float32),
                    missed=jnp.zeros((n_workers,), jnp.int32),
                    sync=jnp.ones((), jnp.float32))
        elif overlap_mode != "none":
            # round-0 snapshot: the (degenerate) init fleet. staleness1
            # gates the first delta off (explicit pipeline bubble, round 0
            # is local steps only); doublebuf instead runs an EXACT
            # consensus of the fresh post-scan view in round 0 (pipeline
            # fill, DESIGN.md §Overlap). Either way the pipeline fills in
            # one round. The + 0.0 copy keeps snap and params
            # donation-distinct.
            snap = {"x": params + 0.0,
                    "losses": jnp.zeros((n_workers,), jnp.float32),
                    "gns": jnp.ones((n_workers,), jnp.float32)}
    else:
        if getattr(dcfg, "overlap", "none") != "none":
            raise ValueError(
                f"overlap={dcfg.overlap!r} requires engine='flat' (the "
                "stale snapshot is an extra (R, n) flat buffer)")
        opt_state = jax.vmap(opt.init)(params)
        cstate = consensus.init_state(dcfg.consensus, params)
    return TrainState(params=params, opt=opt_state, cstate=cstate,
                      t=jnp.zeros((), jnp.int32), snap=snap,
                      round=jnp.zeros((), jnp.int32), engine=engine)


def _row_select(active, new, old):
    """Per-worker-row select: rows with ``active > 0`` take ``new``, the
    rest keep ``old`` BIT-exactly (``jnp.where``, not arithmetic blending
    — a frozen elastic row must not drift by even one ulp)."""
    cond = (active > 0).reshape(active.shape + (1,) * (new.ndim - 1))
    return jnp.where(cond, new, old)


def set_participation(state: TrainState, active, *,
                      sync=None) -> TrainState:
    """Host-side elastic-membership hook: set which worker rows take part
    in the NEXT rounds (1 = active, 0 = dropped). The mask rides the
    snapshot carry; a dropped row freezes (its local steps revert, its
    pull/push coefficients zero, and its row leaves the consensus target
    weights) until it is re-activated here — or until it has missed
    ``dcfg.staleness`` consecutive rounds, when the bounded-staleness rule
    forces it back in. Requires an elastic staleness_k state
    (``DPPFConfig.elastic=True``).

    ``sync`` (the supervisor's quorum gate) sets the scalar degrade flag:
    0.0 makes the next round local-only — the scan runs but the consensus
    application (stale delta, catch-up pull, center move) is skipped
    bit-exactly; 1.0 restores normal rounds. ``None`` leaves the carried
    flag untouched (the pre-supervisor call signature)."""
    if state.snap is None or "active" not in state.snap:
        raise ValueError(
            "set_participation requires an elastic staleness_k TrainState "
            "(DPPFConfig.overlap='staleness_k', elastic=True)")
    act = consensus.as_participation_mask(
        active, state.snap["active"].shape[0])
    new_snap = dict(state.snap, active=act)
    if sync is not None:
        if "sync" not in state.snap:
            raise ValueError(
                "sync gating requires a state whose elastic carry has the "
                "sync scalar (init_train_state adds it; legacy restored "
                "states are backfilled by load_train_state)")
        new_snap["sync"] = jnp.asarray(sync, jnp.float32).reshape(())
    return dataclasses.replace(state, snap=new_snap)


def make_round_step(loss_fn, opt: Optimizer, dcfg: DPPFConfig, *,
                    clock: Optional[RoundClock] = None,
                    base_lr: Optional[float] = None,
                    total_steps: Optional[int] = None, warmup: int = 0,
                    sam_rho: float = 0.0):
    """Build the fused DPPF round: scan(tau local steps) + consensus.

    Input batch pytree has leading dims (tau_r, M, ...) where tau_r is THIS
    round's length (``RoundSpec.tau`` — fixed, remainder, or QSR-adaptive;
    a new length just retraces under jit). Schedules come from ``clock``
    (built from the legacy ``base_lr``/``total_steps`` pair when omitted).
    Returns round_step(state, batch) -> (state, metrics). jit/shard at
    callsite (``donate_argnums=0`` recommended — required for in-place
    flat-view reuse when the state carries a ConsensusEngine).
    """
    if clock is None:
        clock = _legacy_clock(dcfg, base_lr, total_steps, warmup,
                              "make_round_step")
    overlap_mode = getattr(dcfg, "overlap", "none")
    overlap = overlap_mode != "none"
    spec = get_method(dcfg.consensus)
    lpf = spec.push_source == "filtered_grad"

    def round_step(state: TrainState, batch):
        engine = state.engine
        if overlap and engine is None:
            raise ValueError(
                f"overlap={overlap_mode!r} requires the flat engine")
        if engine is None:
            loss, p0 = loss_fn, state.params
        else:
            # local steps differentiate through the flat rows directly:
            # unflatten_row is slices+reshapes, so grads arrive flat and the
            # optimizer state stays (M, n) — no per-step re-flatten
            loss = lambda row, b: loss_fn(engine.unflatten_row(row), b)
            p0 = engine.workers(state.params)

        params, opt_st, t, losses, gns = _scan_local_steps(
            loss, opt, p0, state.opt, state.t, batch, clock=clock,
            sam_rho=sam_rho)
        if engine is not None:
            params = engine.with_workers(state.params, params)

        # the round ABOUT TO apply its consensus — read the lam schedule at
        # the clock position, not the post-scan ``t // tau`` (the old
        # off-by-one that skipped round 0 and shifted the whole trajectory)
        round_idx = _round_index(state, dcfg)
        lam_t = clock.lam_at(round_idx)
        ps = clock.pull_scale_at(round_idx)
        staleness_depth = jnp.int32(0)

        def lpf_update(params_now, cst):
            # EMA-filtered local progress (LPF-SGD): the per-round
            # parameter delta is the accumulated gradient direction;
            # filtering it gives the alternative push force. Frozen
            # elastic rows contribute a zero delta (their scan reverted).
            if not lpf:
                return None, cst
            g = spec.filter_mu * cst["g_ema"] \
                + (1.0 - spec.filter_mu) * (p0 - engine.workers(params_now))
            return g, {"g_ema": g}

        if overlap_mode == "staleness1":
            # staleness-1: consensus of the PREVIOUS round's snapshot; its
            # collectives have no data dependence on this round's scan, so
            # the scheduler overlaps them with the tau local steps. The
            # delta is applied to the fresh post-local-step view; the fresh
            # view becomes the next round's snapshot.
            snap = state.snap
            push_vec, cstate_in = lpf_update(params, state.cstate)
            c_out, cstate, metrics = consensus.apply_round(
                snap["x"], dcfg, lam_t, cstate_in,
                losses=snap["losses"], grad_norms=snap["gns"], engine=engine,
                push_vec=push_vec, pull_scale=ps)
            new_snap = {"x": params, "losses": losses[-1], "gns": gns[-1]}
            # explicit round-0 pipeline bubble: the init snapshot is
            # (usually) collapsed, and consensus of a collapsed fleet is
            # noise-floor push (engine docstring) — skip the first delta
            live = (state.t > 0).astype(jnp.float32)
            params = params + live * (c_out - snap["x"])
            staleness_depth = live.astype(jnp.int32)
        elif overlap_mode == "doublebuf":
            # double-buffered: the snapshot's stage-1 column contraction is
            # dispatched in ``overlap_chunks`` pieces with no data
            # dependence on the scan (under shard_map the matching gather/
            # psum chunks interleave with the local steps — this builder is
            # the single-shard reference of the same recursion); the round
            # boundary runs coefficient math + mixing only. Round 0 is the
            # pipeline-fill bubble: an EXACT consensus of the fresh q (not
            # a skipped round — the init snapshot is the collapsed fleet
            # and carries no information).
            snap = state.snap
            push_vec, cstate = lpf_update(params, state.cstate)
            stages, _ = consensus.lower_stages(
                engine, dcfg, lam_t, losses=snap["losses"],
                grad_norms=snap["gns"], pull_scale=ps)
            T1 = stages[0][1]
            n_eff = max(1, min(dcfg.overlap_chunks, engine.layout.n))
            gram = None
            for a, b in _chunk_bounds(engine.layout.n, n_eff):
                part = engine.stage_comm(snap["x"][:, a:b], T1)
                gram = part if gram is None else gram + part
            new_snap = {"x": params, "losses": losses[-1], "gns": gns[-1]}
            q = params

            def _stale(_):
                c_out, _, m = consensus.apply_round(
                    snap["x"], dcfg, lam_t, cstate, losses=snap["losses"],
                    grad_norms=snap["gns"], engine=engine, first_gram=gram,
                    push_vec=push_vec, pull_scale=ps)
                return q + (c_out - snap["x"]), m

            def _bubble(_):
                new, _, m = consensus.apply_round(
                    q, dcfg, lam_t, cstate, losses=losses[-1],
                    grad_norms=gns[-1], engine=engine,
                    push_vec=push_vec, pull_scale=ps)
                return new, m

            params, metrics = jax.lax.cond(state.t > 0, _stale, _bubble,
                                           None)
            staleness_depth = (state.t > 0).astype(jnp.int32)
        elif overlap_mode == "staleness_k":
            # staleness-k pipeline (DESIGN.md §Overlap): the snapshot
            # carry is a k-deep ring ordered oldest -> newest; slot 0
            # holds the round-(r-k) snapshot whose consensus applies
            # after THIS round's scan (doublebuf is the k=1 special case
            # of the same recursion). Rounds 0..k-1 are pipeline fill:
            # an EXACT consensus of the fresh post-scan view, gated by a
            # traced cond on the carried round index (resume-correct).
            k = dcfg.staleness
            snap = state.snap
            s_old = snap["x"][0]
            sl, sg = snap["losses"][0], snap["gns"][0]
            elastic = bool(getattr(dcfg, "elastic", False))
            act_old = eff = None
            if elastic:
                active, missed = snap["active"], snap["missed"]
                # bounded staleness: a row that already missed k rounds
                # is forced back in this round
                eff = jnp.where(missed >= k, jnp.float32(1.0), active)
                act_old = snap["act"][0]
                # dropped rows freeze: revert this round's local steps
                # (params AND optimizer state) bit-exactly
                params = engine.with_workers(
                    params, _row_select(eff, engine.workers(params), p0))
                opt_st = jax.tree.map(
                    lambda nw, ow: _row_select(eff, nw, ow),
                    opt_st, state.opt)
            # filtered-grad update AFTER the elastic freeze: frozen rows'
            # reverted scans contribute a zero delta to the EMA
            push_vec, cstate = lpf_update(params, state.cstate)
            # the old slot's stage-1 contraction, chunked like doublebuf
            # (under shard_map the matching ring-gather + psum chunks
            # interleave with the scan — this is the single-shard
            # reference of the same recursion)
            stages, _ = consensus.lower_stages(
                engine, dcfg, lam_t, losses=sl, grad_norms=sg,
                mask=act_old, pull_scale=ps)
            T1 = stages[0][1]
            n_eff = max(1, min(dcfg.overlap_chunks, engine.layout.n))
            gram = None
            for a, b in _chunk_bounds(engine.layout.n, n_eff):
                part = engine.stage_comm(s_old[:, a:b], T1)
                gram = part if gram is None else gram + part
            q = params

            def _stale(_):
                c_out, _, m = consensus.apply_round(
                    s_old, dcfg, lam_t, cstate, losses=sl, grad_norms=sg,
                    engine=engine, first_gram=gram, mask=act_old,
                    push_vec=push_vec, pull_scale=ps)
                return q + (c_out - s_old), m

            def _fill(_):
                new, _, m = consensus.apply_round(
                    q, dcfg, lam_t, cstate, losses=losses[-1],
                    grad_norms=gns[-1], engine=engine, mask=eff,
                    push_vec=push_vec, pull_scale=ps)
                return new, m

            params, metrics = jax.lax.cond(round_idx >= k, _stale, _fill,
                                           None)
            if elastic:
                # reception gate: the stale delta was masked by the
                # SNAPSHOT-time participation (act_old); a row inactive
                # NOW must not receive it either — keep it at its frozen q
                params = engine.with_workers(
                    params,
                    _row_select(eff, engine.workers(params),
                                engine.workers(q)))
                # EASGD-style catch-up: a row rejoining after >= 1 missed
                # rounds pulls toward the active-fleet mean
                rejoin = eff * (missed > 0).astype(jnp.float32)
                w = engine.workers(params)
                mean = jnp.sum(eff[:, None] * w, axis=0) \
                    / jnp.maximum(jnp.sum(eff), 1.0)
                w = w + (dcfg.elastic_catchup * rejoin)[:, None] \
                    * (mean[None] - w)
                params = engine.with_workers(params, w)
                if "sync" in snap:
                    # quorum-degrade gate (train/supervisor.py): sync == 0
                    # reverts the whole consensus application — stale
                    # delta, catch-up pull, and the aux-center move —
                    # leaving every row at its post-freeze local view q
                    # BIT-exactly (a where select, never arithmetic
                    # blending); the ring still advances below so the
                    # pipeline stays resume-correct
                    params = jnp.where(snap["sync"] > 0, params, q)
            # advance the ring: drop the consumed slot, append fresh q
            new_snap = {
                "x": jnp.concatenate([snap["x"][1:], q[None]], axis=0),
                "losses": jnp.concatenate(
                    [snap["losses"][1:], losses[-1][None]], axis=0),
                "gns": jnp.concatenate(
                    [snap["gns"][1:], gns[-1][None]], axis=0)}
            if elastic:
                new_snap.update(
                    act=jnp.concatenate([snap["act"][1:], eff[None]],
                                        axis=0),
                    active=active,
                    missed=jnp.where(eff > 0, 0, missed + 1)
                    .astype(jnp.int32))
                if "sync" in snap:
                    new_snap["sync"] = snap["sync"]
            staleness_depth = jnp.where(round_idx >= k, k, 0) \
                .astype(jnp.int32)
        else:
            push_vec, cstate_in = lpf_update(params, state.cstate)
            params, cstate, metrics = consensus.apply_round(
                params, dcfg, lam_t, cstate_in,
                losses=losses[-1], grad_norms=gns[-1], engine=engine,
                push_vec=push_vec, pull_scale=ps)
            new_snap = state.snap
        metrics = dict(metrics)
        metrics["train_loss"] = losses.mean()
        metrics["lam_t"] = lam_t
        metrics["staleness"] = staleness_depth
        new_state = TrainState(params=params, opt=opt_st, cstate=cstate, t=t,
                               snap=new_snap,
                               round=jnp.asarray(round_idx + 1, jnp.int32),
                               engine=engine)
        return new_state, metrics

    return round_step


def _axis_entry(axes):
    """PartitionSpec entry for an axis group (None when empty)."""
    if not axes:
        return None
    return axes if len(axes) > 1 else axes[0]


def _lin_index(axes, sizes):
    """Linear shard index over an ordered axis group (row-major, matching
    ``lax.all_gather(..., axes, tiled=True)`` concatenation order)."""
    idx = 0
    for a in axes:
        idx = idx * sizes[a] + jax.lax.axis_index(a)
    return idx


def make_sharded_round_step(loss_fn, opt: Optimizer, dcfg: DPPFConfig, *,
                            mesh, plan, clock: Optional[RoundClock] = None,
                            base_lr: Optional[float] = None,
                            total_steps: Optional[int] = None,
                            warmup: int = 0, sam_rho: float = 0.0):
    """Build the DPPF round lowered under ``jax.shard_map`` (flat engine
    only): worker rows of the (R, n) view shard over ``plan.worker_axes``,
    columns over ``plan.fsdp_axes + plan.model_axes``.

    Collective placement (DESIGN.md §Sharded-execution): the tau local
    steps run on column-gathered local worker rows with ZERO worker-axis
    collectives; the round boundary all-gathers worker rows per column
    shard (the paper's one consensus all-reduce, Table 2) and the engine
    completes its Gram with an (R, R) psum over the column axes. The
    (M, M)-sized coefficient math and the mixing GEMM are shard-local.
    With ``dcfg.overlap == "staleness1"`` the consensus reads the
    round-(k-1) snapshot (rows replicated, columns sharded), so its
    gather/psum have no data dependence on this round's scan and overlap
    with the local compute.

    On a hierarchical ``workers x fsdp x model`` mesh
    (`launch.mesh.make_hier_engine_mesh`) the column group spans BOTH the
    fsdp and model axes and the partial-Gram psum reduces over the full
    group. Requires M divisible by the worker-axes size; the column group
    falls back per `launch.mesh.flat_col_axes` (full fsdp+model group ->
    divisible sub-group -> replicated with the psum a no-op) when n is not
    divisible. jit with ``donate_argnums=0`` at the callsite, like
    ``make_round_step``.

    With ``dcfg.overlap == "doublebuf"`` the snapshot is carried
    ROW-SHARDED and the round is split into ``overlap_chunks`` segments:
    before each segment's local steps, one column chunk of the snapshot's
    worker-row all-gather and its stage-1 partial-Gram psum are dispatched
    — neither depends on the scan, so the scheduler hides ALL of the
    round's heavy communication behind compute; the boundary runs only the
    (R, R) coefficient math and the column-local mix GEMM (no fresh
    gather: each device applies its own rows of the delta). Round 0 is
    the pipeline-fill bubble and applies an EXACT consensus of the fresh
    view (DESIGN.md §Overlap).

    ``dcfg.overlap == "staleness_k"`` runs the k-deep generalization of
    the same recursion: the snapshot carry is a ring of ``k`` row-sharded
    buffers (oldest -> newest), each chunk's worker-row gather runs as a
    ``launch.mesh.ring_gather`` ppermute ring (R-1 hops of one local row
    block, bit-for-bit the tiled all_gather concatenation order, so
    precise-mode parity is preserved while the peak per-hop payload drops
    by 1/R), and rounds 0..k-1 fill the pipeline with exact consensus.
    ``dcfg.elastic`` threads the per-row participation mask through the
    same carry on flat Wx1 and hierarchical WxFxM meshes.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.launch.mesh import ring_gather

    if clock is None:
        clock = _legacy_clock(dcfg, base_lr, total_steps, warmup,
                              "make_sharded_round_step")
    overlap_mode = getattr(dcfg, "overlap", "none")
    stale1 = overlap_mode == "staleness1"
    dbuf = overlap_mode == "doublebuf"
    sk = overlap_mode == "staleness_k"
    k_depth = getattr(dcfg, "staleness", 1)
    elastic = sk and bool(getattr(dcfg, "elastic", False))
    spec = get_method(dcfg.consensus)
    lpf = spec.push_source == "filtered_grad"
    row_axes = tuple(plan.worker_axes)
    sizes = dict(mesh.shape)
    row_size = math.prod(sizes[a] for a in row_axes) if row_axes else 1

    def round_step(state: TrainState, batch):
        engine = state.engine
        if engine is None:
            raise ValueError("make_sharded_round_step requires the flat "
                             "engine (DPPFConfig.engine='flat')")
        L = engine.layout
        M, n, aux = L.M, L.n, L.aux
        if row_size > 1 and M % row_size:
            raise ValueError(
                f"workers ({M}) not divisible over worker axes "
                f"{row_axes} (size {row_size})")
        from repro.launch.mesh import flat_col_axes
        # the shared column rule (launch.mesh.flat_col_axes): the full
        # fsdp+model group when divisible — the partial-Gram psum then
        # spans both axes — else the divisible sub-group, else replicated
        # columns with the psum a no-op
        eff_cols = flat_col_axes(mesh, n, plan)
        col_e = _axis_entry(eff_cols)
        cols = math.prod(sizes[a] for a in eff_cols) if eff_cols else 1
        n_loc, m_loc = n // cols, M // row_size
        s_engine = dataclasses.replace(engine, shard=ShardedLayout(
            row_axes=row_axes, col_axes=eff_cols, rows=row_size, cols=cols))
        row_e = _axis_entry(row_axes)
        # the scalar quorum gate rides the elastic carry when present
        # (init_train_state always adds it; load_train_state backfills
        # legacy elastic checkpoints)
        has_sync = elastic and state.snap is not None \
            and "sync" in state.snap

        # GSPMD workaround (jax 0.4.37): when the specs leave mesh axes
        # unmentioned (the replicated-columns fallback), a
        # jnp.concatenate of shard_map outputs that is returned from jit
        # alongside ANY other shard_map output comes back multiplied by
        # the unmentioned-group size — the reshard of the concat SUMS
        # the replicas instead of selecting one (metrics stay exact
        # while params blow up 4x on a 2x2x2 mesh with cols=()).
        # Pinning the concat fully replicated sidesteps the bad
        # reshard; only the fallback case pays for it.
        unmentioned = mesh.size // (row_size * cols)

        def stitch(parts, axis=0):
            out = jnp.concatenate(parts, axis=axis)
            if unmentioned > 1:
                from jax.sharding import NamedSharding
                out = jax.lax.with_sharding_constraint(
                    out, NamedSharding(mesh, P(*([None] * out.ndim))))
            return out
        tau = jnp.shape(jax.tree.leaves(batch)[0])[0]

        def leading_dim_spec(leaf, entry, offset=0):
            nd = jnp.ndim(leaf)
            return P(*([None] * offset + [entry] + [None] * (nd - offset - 1))) \
                if nd > offset else P()

        def mapped(w_loc, opt_loc, t0, rnd0, b_loc, *rest):
            rest = list(rest)
            # the filtered-gradient EMA rides LAST in the operand list
            # (rows replicated, columns sharded) — pop it from the end
            # first so the positional front-pops below stay stable
            g_ema = rest.pop() if lpf else None
            aux_loc = rest.pop(0) if aux else None
            snap_x = snap_aux = snap_l = snap_g = None
            act_ring = active = missed = sync = None
            if stale1:
                snap_x, snap_l, snap_g = rest
            elif dbuf:
                snap_x = rest.pop(0)             # (m_loc, n_loc) row-sharded
                if aux:
                    snap_aux = rest.pop(0)       # (aux, n_loc)
                snap_l, snap_g = rest
            elif sk:
                snap_x = rest.pop(0)        # (k, m_loc, n_loc) row-sharded
                if aux:
                    snap_aux = rest.pop(0)       # (k, aux, n_loc)
                snap_l = rest.pop(0)             # (k, M)
                snap_g = rest.pop(0)             # (k, M)
                if elastic:
                    act_ring = rest.pop(0)       # (k, M)
                    active = rest.pop(0)         # (M,)
                    missed = rest.pop(0)         # (M,) int32
                    if has_sync:
                        sync = rest.pop(0)       # () quorum gate

            # clock position of the round about to mix (pre-scan index —
            # same off-by-one fix as make_round_step)
            lam_t = clock.lam_at(rnd0)
            ps = clock.pull_scale_at(rnd0)
            loss = lambda row, b: loss_fn(engine.unflatten_row(row), b)
            w_full = jax.lax.all_gather(w_loc, eff_cols, axis=1, tiled=True) \
                if eff_cols else w_loc

            if dbuf or sk:
                # the tau local steps split into n_eff segments; ahead of
                # each segment one column chunk of the round-(r-k)
                # snapshot's worker-row gather + stage-1 contraction psum
                # is dispatched — no data dependence on the scan, so the
                # collectives run under the segment's compute. staleness_k
                # consumes ring slot 0 (the oldest snapshot) and moves
                # each chunk over the ppermute ring: R-1 single-row-block
                # hops instead of one monolithic all-gather, identical
                # concatenation order (launch.mesh.ring_gather contract)
                sx0 = snap_x[0] if sk else snap_x       # (m_loc, n_loc)
                sa0 = (snap_aux[0] if sk else snap_aux) if aux else None
                sl0 = snap_l[0] if sk else snap_l
                sg0 = snap_g[0] if sk else snap_g
                act0 = act_ring[0] if elastic else None
                stages, _ = consensus.lower_stages(
                    s_engine, dcfg, lam_t, losses=sl0, grad_norms=sg0,
                    mask=act0, pull_scale=ps)
                T1 = stages[0][1]
                n_eff = max(1, min(dcfg.overlap_chunks, tau, n_loc))
                gram, gath = None, []
                params, opt_st, t = w_full, opt_loc, t0
                l_parts, g_parts = [], []
                for (ca, cz), (sa, sz) in zip(_chunk_bounds(n_loc, n_eff),
                                              _chunk_bounds(tau, n_eff)):
                    piece = sx0[:, ca:cz]
                    if row_size > 1:
                        piece = ring_gather(
                            piece, row_axes, world=row_size, axis=0) \
                            if sk else jax.lax.all_gather(
                                piece, row_axes, axis=0, tiled=True)
                    if aux:
                        piece = jnp.concatenate(
                            [piece, sa0[:, ca:cz]], axis=0)
                    gath.append(piece)
                    part = s_engine.stage_comm(piece, T1)
                    gram = part if gram is None else gram + part
                    seg = jax.tree.map(lambda l: l[sa:sz], b_loc)
                    params, opt_st, t, lj, gj = _scan_local_steps(
                        loss, opt, params, opt_st, t, seg, clock=clock,
                        sam_rho=sam_rho)
                    l_parts.append(lj)
                    g_parts.append(gj)
                losses = jnp.concatenate(l_parts, axis=0)
                gns = jnp.concatenate(g_parts, axis=0)
                s_full = jnp.concatenate(gath, axis=1)    # (R, n_loc)
            else:
                params, opt_st, t, losses, gns = _scan_local_steps(
                    loss, opt, w_full, opt_loc, t0, b_loc, clock=clock,
                    sam_rho=sam_rho)

            eff = eff_loc = None
            r_off = 0
            if elastic:
                # bounded staleness: a row that already missed k rounds is
                # forced back in; dropped rows freeze bit-exactly (local
                # steps revert on params AND optimizer state)
                eff = jnp.where(missed >= k_depth, jnp.float32(1.0), active)
                if row_size > 1:
                    r_off = _lin_index(row_axes, sizes) * m_loc
                    eff_loc = jax.lax.dynamic_slice_in_dim(
                        eff, r_off, m_loc, 0)
                else:
                    eff_loc = eff
                params = _row_select(eff_loc, params, w_full)
                opt_st = jax.tree.map(
                    lambda nw, ow: _row_select(eff_loc, nw, ow),
                    opt_st, opt_loc)

            # round boundary: back to own columns
            if eff_cols:
                c_idx = _lin_index(eff_cols, sizes)
                q_loc = jax.lax.dynamic_slice_in_dim(
                    params, c_idx * n_loc, n_loc, 1)
            else:
                q_loc = params
            if row_size > 1:
                l_last = jax.lax.all_gather(losses[-1], row_axes, tiled=True)
                g_last = jax.lax.all_gather(gns[-1], row_axes, tiled=True)
            else:
                l_last, g_last = losses[-1], gns[-1]

            push_vec = None
            if lpf:
                # EMA-filtered local progress (LPF-SGD): the own-row,
                # own-column delta of this round's scan (zero for frozen
                # elastic rows — their q reverted to w), row-gathered to
                # the full (M, n_loc) slab every column shard mixes with
                delta = w_loc - q_loc
                if row_size > 1:
                    delta = jax.lax.all_gather(delta, row_axes, axis=0,
                                               tiled=True)
                push_vec = spec.filter_mu * g_ema \
                    + (1.0 - spec.filter_mu) * delta

            def gather_rows(x_loc, *, ring=False):
                """Own-column worker rows + aux -> the full (R, n_loc)
                view (THE consensus all-reduce of the paper). With
                ``ring=True`` the gather runs over the ppermute ring
                (bit-identical result, R-1 one-block hops)."""
                if row_size > 1:
                    rows = ring_gather(x_loc, row_axes, world=row_size,
                                       axis=0) if ring \
                        else jax.lax.all_gather(x_loc, row_axes, axis=0,
                                                tiled=True)
                else:
                    rows = x_loc
                return jnp.concatenate([rows, aux_loc], axis=0) if aux \
                    else rows

            def own_rows(full):
                """Slice this device's worker rows back out."""
                if row_size > 1:
                    return jax.lax.dynamic_slice_in_dim(
                        full[:M], _lin_index(row_axes, sizes) * m_loc,
                        m_loc, 0)
                return full[:M]

            if dbuf or sk:
                # boundary: coefficient math + mix GEMM only. The delta is
                # applied shard-locally (own worker rows + aux) — no fresh
                # row gather; the new snapshot is the row-SHARDED q
                # (staleness_k: appended to the ring, displacing slot 0).
                def _stale(_):
                    c_out, _, m = consensus.apply_round(
                        s_full, dcfg, lam_t, state.cstate, losses=sl0,
                        grad_norms=sg0, engine=s_engine, first_gram=gram,
                        mask=act0, push_vec=push_vec, pull_scale=ps)
                    delta = c_out - s_full
                    outs = [q_loc + own_rows(delta)]
                    if aux:
                        outs.append(aux_loc + delta[M:])
                    return tuple(outs + [m])

                def _fill(_):
                    # pipeline fill: EXACT consensus of the fresh q
                    X = gather_rows(q_loc, ring=sk)
                    newX, _, m = consensus.apply_round(
                        X, dcfg, lam_t, state.cstate, losses=l_last,
                        grad_norms=g_last, engine=s_engine, mask=eff,
                        push_vec=push_vec, pull_scale=ps)
                    outs = [own_rows(newX)]
                    if aux:
                        outs.append(newX[M:])
                    return tuple(outs + [m])

                pred = (rnd0 >= k_depth) if sk else (t0 > 0)
                res = jax.lax.cond(pred, _stale, _fill, None)
                new_w = res[0]
                new_aux = res[1] if aux else None
                metrics = dict(res[-1])
                if elastic:
                    # reception gate: a row inactive NOW keeps its frozen
                    # q (the stale delta's mask is snapshot-time)
                    new_w = _row_select(eff_loc, new_w, q_loc)
                    # EASGD-style catch-up: a row rejoining after >= 1
                    # missed rounds pulls toward the active-fleet mean
                    rejoin = eff * (missed > 0).astype(jnp.float32)
                    partial = jnp.sum(eff_loc[:, None] * new_w, axis=0)
                    if row_size > 1:
                        partial = jax.lax.psum(partial, row_axes)
                    mean = partial / jnp.maximum(jnp.sum(eff), 1.0)
                    cj = dcfg.elastic_catchup * rejoin
                    cj_loc = jax.lax.dynamic_slice_in_dim(
                        cj, r_off, m_loc, 0) if row_size > 1 else cj
                    new_w = new_w + cj_loc[:, None] * (mean[None] - new_w)
                    if has_sync:
                        # quorum-degrade gate: sync == 0 reverts the whole
                        # consensus application — every worker row keeps
                        # its frozen/post-scan q and the aux center its
                        # pre-round slab, bit-exactly (where select); the
                        # ring still advances below
                        new_w = jnp.where(sync > 0, new_w, q_loc)
                        if aux:
                            new_aux = jnp.where(sync > 0, new_aux, aux_loc)
                if sk:
                    new_snap_x = jnp.concatenate(
                        [snap_x[1:], q_loc[None]], axis=0)
                    new_snap_aux = jnp.concatenate(
                        [snap_aux[1:], aux_loc[None]], axis=0) if aux \
                        else None
                    staleness_depth = jnp.where(
                        rnd0 >= k_depth, k_depth, 0).astype(jnp.int32)
                else:
                    new_snap_x, new_snap_aux = q_loc, aux_loc
                    staleness_depth = (t0 > 0).astype(jnp.int32)
            elif stale1:
                X = gather_rows(q_loc)
                c_out, cstate, metrics = consensus.apply_round(
                    snap_x, dcfg, lam_t, state.cstate,
                    losses=snap_l, grad_norms=snap_g, engine=s_engine,
                    push_vec=push_vec, pull_scale=ps)
                new_snap_x, new_snap_aux = X, None
                # round-0 pipeline bubble, as in make_round_step
                live = (t0 > 0).astype(jnp.float32)
                newX = X + live * (c_out - snap_x)
                new_w = own_rows(newX)
                new_aux = newX[M:] if aux else None
                metrics = dict(metrics)
                staleness_depth = live.astype(jnp.int32)
            else:
                X = gather_rows(q_loc)
                newX, cstate, metrics = consensus.apply_round(
                    X, dcfg, lam_t, state.cstate,
                    losses=l_last, grad_norms=g_last, engine=s_engine,
                    push_vec=push_vec, pull_scale=ps)
                new_snap_x = new_snap_aux = None
                new_w = own_rows(newX)
                new_aux = newX[M:] if aux else None
                metrics = dict(metrics)
                staleness_depth = jnp.int32(0)

            train_loss = losses.mean()
            if row_size > 1:
                train_loss = jax.lax.pmean(train_loss, row_axes)
            metrics["train_loss"] = train_loss
            metrics["lam_t"] = lam_t
            metrics["staleness"] = staleness_depth
            outs = [new_w, opt_st, t, rnd0 + 1, metrics]
            if aux:
                outs.append(new_aux)
            if stale1:
                outs.extend([new_snap_x, l_last, g_last])
            elif dbuf:
                outs.append(new_snap_x)
                if aux:
                    outs.append(new_snap_aux)
                outs.extend([l_last, g_last])
            elif sk:
                outs.append(new_snap_x)
                if aux:
                    outs.append(new_snap_aux)
                outs.extend([
                    jnp.concatenate([snap_l[1:], l_last[None]], axis=0),
                    jnp.concatenate([snap_g[1:], g_last[None]], axis=0)])
                if elastic:
                    outs.extend([
                        jnp.concatenate([act_ring[1:], eff[None]], axis=0),
                        active,
                        jnp.where(eff > 0, 0, missed + 1)
                        .astype(jnp.int32)])
                    if has_sync:
                        outs.append(sync)
            if lpf:
                outs.append(push_vec)       # rides LAST, like the input
            return tuple(outs)

        opt_in = jax.tree.map(lambda l: leading_dim_spec(l, row_e), state.opt)
        batch_in = jax.tree.map(lambda l: leading_dim_spec(l, row_e, 1),
                                batch)
        metric_out = {k: P() for k in ("consensus_dist", "pre_dist",
                                       "pull_force", "push_force",
                                       "train_loss", "lam_t", "staleness")}
        rnd0 = jnp.asarray(_round_index(state, dcfg), jnp.int32)
        args = [engine.workers(state.params), state.opt, state.t, rnd0,
                batch]
        in_specs = [P(row_e, col_e), opt_in, P(), P(), batch_in]
        out_specs = [P(row_e, col_e), opt_in, P(), P(), metric_out]
        if aux:
            args.append(state.params[M:])
            in_specs.append(P(None, col_e))
            out_specs.append(P(None, col_e))
        if stale1:
            # snapshot rows are replicated (every column shard needs the
            # full R rows to mix), columns sharded like the live view
            args.extend([state.snap["x"], state.snap["losses"],
                         state.snap["gns"]])
            in_specs.extend([P(None, col_e), P(), P()])
            out_specs.extend([P(None, col_e), P(), P()])
        elif dbuf:
            # the snapshot enters ROW-SHARDED (its worker-row gather is the
            # comm the next round hides mid-scan); aux rows columns-only
            args.append(state.snap["x"][:M])
            in_specs.append(P(row_e, col_e))
            out_specs.append(P(row_e, col_e))
            if aux:
                args.append(state.snap["x"][M:])
                in_specs.append(P(None, col_e))
                out_specs.append(P(None, col_e))
            args.extend([state.snap["losses"], state.snap["gns"]])
            in_specs.extend([P(), P()])
            out_specs.extend([P(), P()])
        elif sk:
            # the snapshot RING enters row-sharded per slot (ring dim
            # replicated); aux slabs columns-only; losses/gns/elastic
            # vectors replicated
            args.append(state.snap["x"][:, :M])
            in_specs.append(P(None, row_e, col_e))
            out_specs.append(P(None, row_e, col_e))
            if aux:
                args.append(state.snap["x"][:, M:])
                in_specs.append(P(None, None, col_e))
                out_specs.append(P(None, None, col_e))
            args.extend([state.snap["losses"], state.snap["gns"]])
            in_specs.extend([P(), P()])
            out_specs.extend([P(), P()])
            if elastic:
                args.extend([state.snap["act"], state.snap["active"],
                             state.snap["missed"]])
                in_specs.extend([P(), P(), P()])
                out_specs.extend([P(), P(), P()])
                if has_sync:
                    args.append(state.snap["sync"])
                    in_specs.append(P())
                    out_specs.append(P())
        if lpf:
            # the filtered-gradient EMA: rows replicated (every column
            # shard mixes the full M rows), columns sharded — LAST operand
            args.append(state.cstate["g_ema"])
            in_specs.append(P(None, col_e))
            out_specs.append(P(None, col_e))

        res = list(shard_map(
            mapped, mesh=mesh, in_specs=tuple(in_specs),
            out_specs=tuple(out_specs), check_rep=False)(*args))
        new_w, opt_st, t, rnd, metrics = res[:5]
        rest = res[5:]
        cstate = {"g_ema": rest.pop()} if lpf else state.cstate
        params = stitch([new_w, rest.pop(0)]) if aux else new_w
        if stale1:
            snap = {"x": rest[0], "losses": rest[1], "gns": rest[2]}
        elif dbuf:
            sx = rest.pop(0)
            if aux:
                sx = stitch([sx, rest.pop(0)])
            snap = {"x": sx, "losses": rest[0], "gns": rest[1]}
        elif sk:
            sx = rest.pop(0)
            if aux:
                sx = stitch([sx, rest.pop(0)], axis=1)
            snap = {"x": sx, "losses": rest.pop(0), "gns": rest.pop(0)}
            if elastic:
                snap.update(act=rest.pop(0), active=rest.pop(0),
                            missed=rest.pop(0))
                if has_sync:
                    snap["sync"] = rest.pop(0)
        else:
            snap = state.snap
        new_state = TrainState(params=params, opt=opt_st,
                               cstate=cstate, t=t, snap=snap,
                               round=rnd, engine=engine)
        return new_state, metrics

    return round_step


def shard_train_state(state: TrainState, mesh, plan, *, dcfg=None):
    """Place a flat-engine ``TrainState`` for ``make_sharded_round_step``:
    the (R, n) view under the flat-view rule (`launch.mesh.
    flat_view_sharding`), optimizer state over the worker axes, scalars
    replicated. The overlap snapshot defaults to replicated rows (what
    staleness-1 consumes); pass the run's ``dcfg`` so a doublebuf
    snapshot is placed ROW-SHARDED up front — the round emits it
    row-sharded, and a mismatched initial placement costs one silent
    recompile at round 1 (jit's cache keys include input shardings)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import flat_col_entry, flat_view_sharding

    if state.engine is None:
        raise ValueError("shard_train_state requires a flat-engine "
                         "TrainState (DPPFConfig.engine='flat')")
    row_e = _axis_entry(tuple(plan.worker_axes))

    def put(leaf, spec):
        return jax.device_put(leaf, NamedSharding(mesh, spec))

    def opt_put(leaf):
        nd = jnp.ndim(leaf)
        return put(leaf, P(*([row_e] + [None] * (nd - 1))) if nd else P())

    params = jax.device_put(
        state.params, flat_view_sharding(mesh, state.params.shape, plan))
    snap = state.snap
    if snap is not None:
        col_e = flat_col_entry(mesh, snap["x"].shape[-1], plan)
        if snap["x"].ndim == 3 or \
                getattr(dcfg, "overlap", None) == "doublebuf":
            # doublebuf / the staleness_k ring (3-D snap): worker rows
            # sharded like the live view (aux rows keep the flat-view
            # fallback: replicated when they break divisibility)
            x = jax.device_put(
                snap["x"], flat_view_sharding(mesh, snap["x"].shape, plan))
        else:
            x = put(snap["x"], P(None, col_e))
        snap = dict({key: put(v, P()) for key, v in snap.items()
                     if key != "x"}, x=x)
    rnd = put(state.round, P()) if state.round is not None else None
    cstate = state.cstate
    if cstate:
        # method aux state (e.g. the LPF filtered-gradient EMA): 2-D
        # (M, n) slabs shard like replicated-row snapshots, scalars/
        # vectors replicate
        cstate = {
            key: put(v, P(None, flat_col_entry(mesh, v.shape[-1], plan))
                     if jnp.ndim(v) == 2 else P())
            for key, v in cstate.items()}
    return TrainState(params=params, opt=jax.tree.map(opt_put, state.opt),
                      cstate=cstate, t=put(state.t, P()), snap=snap,
                      round=rnd, engine=state.engine)


def make_ddp_step(loss_fn, opt: Optimizer, *,
                  clock: Optional[RoundClock] = None,
                  base_lr: Optional[float] = None,
                  total_steps: Optional[int] = None, warmup: int = 0,
                  sam_rho: float = 0.0):
    """DDP baseline: one replica; per-worker micro-grads are averaged every
    step (lowers to the per-step all-reduce on the mesh). Batch leading dim
    is M (the worker/data axis). The LR position comes from the same
    ``RoundClock`` the round builders use (tau is irrelevant here — DDP is
    the tau=1-per-step clock)."""
    if clock is None:
        if base_lr is None or total_steps is None:
            raise ValueError("make_ddp_step needs a RoundClock (clock=...) "
                             "or the legacy base_lr/total_steps pair")
        clock = RoundClock(total_steps=total_steps, tau=1, base_lr=base_lr,
                           warmup=warmup)

    def step(state: TrainState, batch):
        def per_worker(b):
            if sam_rho > 0:
                (loss, _), g = sam_gradient(loss_fn, state.params, b, sam_rho)
            else:
                (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    state.params, b)
            return loss, g

        losses, grads = jax.vmap(per_worker)(batch)
        g = jax.tree.map(lambda a: jnp.mean(a.astype(jnp.float32), axis=0),
                         grads)
        lr = clock.lr_at(state.t)
        params, opt_st = opt.step(state.params, g, state.opt, lr)
        new_state = TrainState(params=params, opt=opt_st, cstate=state.cstate,
                               t=state.t + 1)
        # the unified round-metrics schema (consensus.py::_metrics + the
        # trainer keys), so per-round loggers see one stable dict from
        # every branch; DDP's single replica has no worker spread and no
        # stale consensus — the consensus fields are true zeros
        zero = jnp.float32(0.0)
        return new_state, {"train_loss": losses.mean(),
                           "consensus_dist": zero, "pre_dist": zero,
                           "pull_force": zero, "push_force": zero,
                           "lam_t": zero, "staleness": jnp.int32(0)}

    return step


def stacked_params(state: TrainState):
    """The worker-stacked parameter pytree, whichever engine holds it."""
    if state.engine is not None:
        return state.engine.unflatten(state.params)
    return state.params


def average_params(state: TrainState):
    """Final returned model: the worker average (Alg. 1 last line).
    fp32 leaves on every engine (the tree path's tree_mean0 is fp32)."""
    if state.engine is not None:
        return state.engine.unflatten_row(
            jnp.mean(state.engine.workers(state.params), axis=0), cast=False)
    if jax.tree.leaves(state.params)[0].ndim == 0:
        return state.params
    from repro.core import pullpush as pp
    return pp.tree_mean0(state.params)
