"""DPPF trainer: a communication ROUND is one compiled function —
``lax.scan`` over tau purely-local optimizer steps (zero worker-axis
collectives) followed by the consensus pull-push update (the round's single
all-reduce). The DDP baseline is a separate per-step function whose gradient
mean over the worker axis lowers to the classic every-step all-reduce.

Both are generic over ``loss_fn(params, batch) -> (loss, metrics)`` so the
same trainer drives the 10 assigned LM architectures and the small
paper-table stand-in models.

With ``DPPFConfig.engine == "flat"`` the worker parameters live in the
ConsensusEngine's persistent ``(R, n)`` fp32 view for the WHOLE run: it is
built once in ``init_train_state``, local steps differentiate through cheap
slice/reshape views of it (``engine.unflatten_row``), and the consensus
update runs as flat Gram+mixing passes — no per-round flatten/concatenate.
Donate the state (``jax.jit(round_step, donate_argnums=0)``) so the buffer
is reused in place across rounds (DESIGN.md §Consensus-engine).

Two round-level extensions on top of the flat engine:

* ``make_sharded_round_step`` lowers the WHOLE round under
  ``jax.shard_map``: worker rows of the (R, n) view shard over the plan's
  worker axes, columns over its fsdp/model axes; the round's collectives
  are one worker-row all-gather at the round boundary plus the engine's
  (R, R) partial-Gram psum (DESIGN.md §Sharded-execution).
* ``DPPFConfig.overlap == "staleness1"`` applies the consensus computed
  from the PREVIOUS round's snapshot (carried in ``TrainState.snap``), so
  the consensus collectives have no data dependence on the current round's
  local steps and the scheduler hides them behind tau steps of compute.

Step/round accounting is owned by ``repro.train.clock.RoundClock``
(DESIGN.md §Round-clock): every builder reads lam_t via
``clock.lam_at(state.round)`` — the index of the round ABOUT TO RUN, so
round 0 evaluates ``lam_schedule(·, 0, ·)`` and the final round the full
lam — and the LR via ``clock.lr_at(t)``. The builders are tau-oblivious:
``t`` advances by the batch's leading (scan) dim and ``round`` by one, so
ONE builder serves fixed, remainder, and QSR-adaptive round lengths
(``jax.jit``'s shape-keyed cache is the per-tau compile cache).
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import DPPFConfig
from repro.core import consensus
from repro.core.engine import ConsensusEngine, ShardedLayout
from repro.optim import Optimizer, sam_gradient
from repro.train.clock import RoundClock


@dataclass
class TrainState:
    params: Any          # worker-stacked (M, ...) for DPPF; flat for DDP;
                         # the engine's (R, n) flat view when engine is set
    opt: Any
    cstate: Any          # consensus state (EASGD center etc.)
    t: jnp.ndarray       # local-step counter (scalar int32)
    snap: Any = None     # staleness-1 carry: {"x": (R, n) snapshot,
                         # "losses": (M,), "gns": (M,)} (flat engine only)
    round: Any = None    # round counter (scalar int32) — the clock position;
                         # None on hand-built/DDP states (builders fall back
                         # to the pre-scan ``t // tau``)
    engine: Any = None   # ConsensusEngine (static metadata) or None


# ``engine`` is hashable static metadata: jit recompiles if the layout
# changes, and donation/vmap only ever see the array fields.
jax.tree_util.register_dataclass(
    TrainState, data_fields=("params", "opt", "cstate", "t", "snap", "round"),
    meta_fields=("engine",))


def _round_index(state: TrainState, dcfg: DPPFConfig):
    """The index of the round about to run. States built by
    ``init_train_state`` carry the clock position; legacy hand-built states
    fall back to the PRE-scan ``t // tau`` (correct for fixed tau — the
    historical post-scan ``t // tau`` was the off-by-one)."""
    if state.round is not None:
        return state.round
    return state.t // max(dcfg.tau, 1)


def _legacy_clock(dcfg, base_lr, total_steps, warmup, who):
    if base_lr is None or total_steps is None:
        raise ValueError(f"{who} needs a RoundClock (clock=...) or the "
                         "legacy base_lr/total_steps pair")
    return RoundClock.from_config(dcfg, base_lr=base_lr,
                                  total_steps=total_steps, warmup=warmup)


def _grad_norm(grads):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(grads)))


def _scan_local_steps(loss, opt: Optimizer, p0, opt_st, t0, batch, *,
                      clock: RoundClock, sam_rho):
    """The tau purely-local steps shared by every round builder:
    ``lax.scan`` over the batch's leading (tau) dim, vmap over the worker
    dim of ``p0``/``opt_st``/``batch[:, m]``. Returns
    ``(params, opt_st, t, losses, gns)`` with losses/gns shaped (tau, M)."""
    def local_step(p, o, b, t):
        if sam_rho > 0:
            (loss_v, _), g = sam_gradient(loss, p, b, sam_rho)
        else:
            (loss_v, _), g = jax.value_and_grad(loss, has_aux=True)(p, b)
        lr = clock.lr_at(t)
        gn = _grad_norm(g)
        p, o = opt.step(p, g, o, lr)
        return p, o, loss_v, gn

    def micro(carry, mb):
        params, opt_state, t = carry
        params, opt_state, losses, gns = jax.vmap(
            local_step, in_axes=(0, 0, 0, None))(params, opt_state, mb, t)
        return (params, opt_state, t + 1), (losses, gns)

    (params, opt_st, t), (losses, gns) = jax.lax.scan(
        micro, (p0, opt_st, t0), batch)
    return params, opt_st, t, losses, gns


def init_train_state(loss_params_init, opt: Optimizer, dcfg: DPPFConfig,
                     n_workers: int, key, *, same_init=True, engine=None):
    """Stack per-worker params. The paper initializes all workers from the
    same random model (Alg. 1); ``same_init=False`` gives per-worker seeds
    (useful for the width ablations).

    With ``dcfg.engine == "flat"`` (or an explicit ``engine``) the stacked
    tree is flattened ONCE here into the engine's persistent (R, n) view;
    every subsequent round reuses/donates that buffer.
    """
    if same_init:
        p0 = loss_params_init(key)
        params = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (n_workers,) + a.shape), p0)
        # materialize (broadcast arrays are lazy views)
        params = jax.tree.map(jnp.array, params)
    else:
        keys = jax.random.split(key, n_workers)
        params = jax.vmap(loss_params_init)(keys)
    if engine is None and getattr(dcfg, "engine", "tree") == "flat" \
            and dcfg.consensus != "ddp":
        engine = ConsensusEngine.from_stacked(
            params, method=dcfg.consensus, eps=dcfg.eps)
    snap = None
    if engine is not None:
        params = engine.flatten(params)           # the ONE flatten per run
        opt_state = jax.vmap(opt.init)(engine.workers(params))
        cstate = consensus.init_state(dcfg.consensus, params, engine=engine)
        if getattr(dcfg, "overlap", "none") == "staleness1":
            # round-0 snapshot: the (degenerate) init fleet. The round
            # builders gate the first delta off (explicit pipeline bubble),
            # so round 0 is local steps only and the pipeline fills in one
            # round. The + 0.0 copy keeps snap and params
            # donation-distinct.
            snap = {"x": params + 0.0,
                    "losses": jnp.zeros((n_workers,), jnp.float32),
                    "gns": jnp.ones((n_workers,), jnp.float32)}
    else:
        if getattr(dcfg, "overlap", "none") == "staleness1":
            raise ValueError(
                "overlap='staleness1' requires engine='flat' (the stale "
                "snapshot is an extra (R, n) flat buffer)")
        opt_state = jax.vmap(opt.init)(params)
        cstate = consensus.init_state(dcfg.consensus, params)
    return TrainState(params=params, opt=opt_state, cstate=cstate,
                      t=jnp.zeros((), jnp.int32), snap=snap,
                      round=jnp.zeros((), jnp.int32), engine=engine)


def make_round_step(loss_fn, opt: Optimizer, dcfg: DPPFConfig, *,
                    clock: Optional[RoundClock] = None,
                    base_lr: Optional[float] = None,
                    total_steps: Optional[int] = None, warmup: int = 0,
                    sam_rho: float = 0.0):
    """Build the fused DPPF round: scan(tau local steps) + consensus.

    Input batch pytree has leading dims (tau_r, M, ...) where tau_r is THIS
    round's length (``RoundSpec.tau`` — fixed, remainder, or QSR-adaptive;
    a new length just retraces under jit). Schedules come from ``clock``
    (built from the legacy ``base_lr``/``total_steps`` pair when omitted).
    Returns round_step(state, batch) -> (state, metrics). jit/shard at
    callsite (``donate_argnums=0`` recommended — required for in-place
    flat-view reuse when the state carries a ConsensusEngine).
    """
    if clock is None:
        clock = _legacy_clock(dcfg, base_lr, total_steps, warmup,
                              "make_round_step")
    overlap = getattr(dcfg, "overlap", "none") == "staleness1"

    def round_step(state: TrainState, batch):
        engine = state.engine
        if overlap and engine is None:
            raise ValueError("overlap='staleness1' requires the flat engine")
        if engine is None:
            loss, p0 = loss_fn, state.params
        else:
            # local steps differentiate through the flat rows directly:
            # unflatten_row is slices+reshapes, so grads arrive flat and the
            # optimizer state stays (M, n) — no per-step re-flatten
            loss = lambda row, b: loss_fn(engine.unflatten_row(row), b)
            p0 = engine.workers(state.params)

        params, opt_st, t, losses, gns = _scan_local_steps(
            loss, opt, p0, state.opt, state.t, batch, clock=clock,
            sam_rho=sam_rho)
        if engine is not None:
            params = engine.with_workers(state.params, params)

        # the round ABOUT TO apply its consensus — read the lam schedule at
        # the clock position, not the post-scan ``t // tau`` (the old
        # off-by-one that skipped round 0 and shifted the whole trajectory)
        round_idx = _round_index(state, dcfg)
        lam_t = clock.lam_at(round_idx)
        if overlap:
            # staleness-1: consensus of the PREVIOUS round's snapshot; its
            # collectives have no data dependence on this round's scan, so
            # the scheduler overlaps them with the tau local steps. The
            # delta is applied to the fresh post-local-step view; the fresh
            # view becomes the next round's snapshot.
            snap = state.snap
            c_out, cstate, metrics = consensus.apply_round(
                snap["x"], dcfg, lam_t, state.cstate,
                losses=snap["losses"], grad_norms=snap["gns"], engine=engine)
            new_snap = {"x": params, "losses": losses[-1], "gns": gns[-1]}
            # explicit round-0 pipeline bubble: the init snapshot is
            # (usually) collapsed, and consensus of a collapsed fleet is
            # noise-floor push (engine docstring) — skip the first delta
            live = (state.t > 0).astype(jnp.float32)
            params = params + live * (c_out - snap["x"])
        else:
            params, cstate, metrics = consensus.apply_round(
                params, dcfg, lam_t, state.cstate,
                losses=losses[-1], grad_norms=gns[-1], engine=engine)
            new_snap = state.snap
        metrics = dict(metrics)
        metrics["train_loss"] = losses.mean()
        metrics["lam_t"] = lam_t
        new_state = TrainState(params=params, opt=opt_st, cstate=cstate, t=t,
                               snap=new_snap,
                               round=jnp.asarray(round_idx + 1, jnp.int32),
                               engine=engine)
        return new_state, metrics

    return round_step


def _axis_entry(axes):
    """PartitionSpec entry for an axis group (None when empty)."""
    if not axes:
        return None
    return axes if len(axes) > 1 else axes[0]


def _lin_index(axes, sizes):
    """Linear shard index over an ordered axis group (row-major, matching
    ``lax.all_gather(..., axes, tiled=True)`` concatenation order)."""
    idx = 0
    for a in axes:
        idx = idx * sizes[a] + jax.lax.axis_index(a)
    return idx


def make_sharded_round_step(loss_fn, opt: Optimizer, dcfg: DPPFConfig, *,
                            mesh, plan, clock: Optional[RoundClock] = None,
                            base_lr: Optional[float] = None,
                            total_steps: Optional[int] = None,
                            warmup: int = 0, sam_rho: float = 0.0):
    """Build the DPPF round lowered under ``jax.shard_map`` (flat engine
    only): worker rows of the (R, n) view shard over ``plan.worker_axes``,
    columns over ``plan.fsdp_axes + plan.model_axes``.

    Collective placement (DESIGN.md §Sharded-execution): the tau local
    steps run on column-gathered local worker rows with ZERO worker-axis
    collectives; the round boundary all-gathers worker rows per column
    shard (the paper's one consensus all-reduce, Table 2) and the engine
    completes its Gram with an (R, R) psum over the column axes. The
    (M, M)-sized coefficient math and the mixing GEMM are shard-local.
    With ``dcfg.overlap == "staleness1"`` the consensus reads the
    round-(k-1) snapshot (rows replicated, columns sharded), so its
    gather/psum have no data dependence on this round's scan and overlap
    with the local compute.

    On a hierarchical ``workers x fsdp x model`` mesh
    (`launch.mesh.make_hier_engine_mesh`) the column group spans BOTH the
    fsdp and model axes and the partial-Gram psum reduces over the full
    group. Requires M divisible by the worker-axes size; the column group
    falls back per `launch.mesh.flat_col_axes` (full fsdp+model group ->
    divisible sub-group -> replicated with the psum a no-op) when n is not
    divisible. jit with ``donate_argnums=0`` at the callsite, like
    ``make_round_step``.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    if clock is None:
        clock = _legacy_clock(dcfg, base_lr, total_steps, warmup,
                              "make_sharded_round_step")
    overlap = getattr(dcfg, "overlap", "none") == "staleness1"
    row_axes = tuple(plan.worker_axes)
    sizes = dict(mesh.shape)
    row_size = math.prod(sizes[a] for a in row_axes) if row_axes else 1

    def round_step(state: TrainState, batch):
        engine = state.engine
        if engine is None:
            raise ValueError("make_sharded_round_step requires the flat "
                             "engine (DPPFConfig.engine='flat')")
        L = engine.layout
        M, n, aux = L.M, L.n, L.aux
        if row_size > 1 and M % row_size:
            raise ValueError(
                f"workers ({M}) not divisible over worker axes "
                f"{row_axes} (size {row_size})")
        from repro.launch.mesh import flat_col_axes
        # the shared column rule (launch.mesh.flat_col_axes): the full
        # fsdp+model group when divisible — the partial-Gram psum then
        # spans both axes — else the divisible sub-group, else replicated
        # columns with the psum a no-op
        eff_cols = flat_col_axes(mesh, n, plan)
        col_e = _axis_entry(eff_cols)
        cols = math.prod(sizes[a] for a in eff_cols) if eff_cols else 1
        n_loc, m_loc = n // cols, M // row_size
        s_engine = dataclasses.replace(engine, shard=ShardedLayout(
            row_axes=row_axes, col_axes=eff_cols, rows=row_size, cols=cols))
        row_e = _axis_entry(row_axes)

        def leading_dim_spec(leaf, entry, offset=0):
            nd = jnp.ndim(leaf)
            return P(*([None] * offset + [entry] + [None] * (nd - offset - 1))) \
                if nd > offset else P()

        def mapped(w_loc, opt_loc, t0, rnd0, b_loc, *rest):
            rest = list(rest)
            aux_loc = rest.pop(0) if aux else None
            snap_x, snap_l, snap_g = (rest if overlap else (None, None, None))

            # tau local steps on column-gathered local worker rows
            w_full = jax.lax.all_gather(w_loc, eff_cols, axis=1, tiled=True) \
                if eff_cols else w_loc
            loss = lambda row, b: loss_fn(engine.unflatten_row(row), b)
            params, opt_st, t, losses, gns = _scan_local_steps(
                loss, opt, w_full, opt_loc, t0, b_loc, clock=clock,
                sam_rho=sam_rho)

            # round boundary: back to own columns, gather worker rows
            if eff_cols:
                c_idx = _lin_index(eff_cols, sizes)
                q_loc = jax.lax.dynamic_slice_in_dim(
                    params, c_idx * n_loc, n_loc, 1)
            else:
                q_loc = params
            if row_size > 1:
                q_rows = jax.lax.all_gather(q_loc, row_axes, axis=0,
                                            tiled=True)
                l_last = jax.lax.all_gather(losses[-1], row_axes, tiled=True)
                g_last = jax.lax.all_gather(gns[-1], row_axes, tiled=True)
            else:
                q_rows, l_last, g_last = q_loc, losses[-1], gns[-1]
            X = jnp.concatenate([q_rows, aux_loc], axis=0) if aux else q_rows

            # clock position of the round about to mix (pre-scan index —
            # same off-by-one fix as make_round_step)
            lam_t = clock.lam_at(rnd0)
            if overlap:
                c_out, cstate, metrics = consensus.apply_round(
                    snap_x, dcfg, lam_t, state.cstate,
                    losses=snap_l, grad_norms=snap_g, engine=s_engine)
                new_snap_x = X
                # round-0 pipeline bubble, as in make_round_step
                live = (t0 > 0).astype(jnp.float32)
                newX = X + live * (c_out - snap_x)
            else:
                newX, cstate, metrics = consensus.apply_round(
                    X, dcfg, lam_t, state.cstate,
                    losses=l_last, grad_norms=g_last, engine=s_engine)
                new_snap_x = None

            # slice own worker rows back out of the mixed view
            if row_size > 1:
                new_w = jax.lax.dynamic_slice_in_dim(
                    newX[:M], _lin_index(row_axes, sizes) * m_loc, m_loc, 0)
            else:
                new_w = newX[:M]
            train_loss = losses.mean()
            if row_size > 1:
                train_loss = jax.lax.pmean(train_loss, row_axes)
            metrics = dict(metrics)
            metrics["train_loss"] = train_loss
            metrics["lam_t"] = lam_t
            outs = [new_w, opt_st, t, rnd0 + 1, metrics]
            if aux:
                outs.append(newX[M:])
            if overlap:
                outs.extend([new_snap_x, l_last, g_last])
            return tuple(outs)

        opt_in = jax.tree.map(lambda l: leading_dim_spec(l, row_e), state.opt)
        batch_in = jax.tree.map(lambda l: leading_dim_spec(l, row_e, 1),
                                batch)
        metric_out = {k: P() for k in ("consensus_dist", "pre_dist",
                                       "pull_force", "push_force",
                                       "train_loss", "lam_t")}
        rnd0 = jnp.asarray(_round_index(state, dcfg), jnp.int32)
        args = [engine.workers(state.params), state.opt, state.t, rnd0,
                batch]
        in_specs = [P(row_e, col_e), opt_in, P(), P(), batch_in]
        out_specs = [P(row_e, col_e), opt_in, P(), P(), metric_out]
        if aux:
            args.append(state.params[M:])
            in_specs.append(P(None, col_e))
            out_specs.append(P(None, col_e))
        if overlap:
            # snapshot rows are replicated (every column shard needs the
            # full R rows to mix), columns sharded like the live view
            args.extend([state.snap["x"], state.snap["losses"],
                         state.snap["gns"]])
            in_specs.extend([P(None, col_e), P(), P()])
            out_specs.extend([P(None, col_e), P(), P()])

        res = list(shard_map(
            mapped, mesh=mesh, in_specs=tuple(in_specs),
            out_specs=tuple(out_specs), check_rep=False)(*args))
        new_w, opt_st, t, rnd, metrics = res[:5]
        rest = res[5:]
        params = jnp.concatenate([new_w, rest.pop(0)], axis=0) if aux \
            else new_w
        snap = {"x": rest[0], "losses": rest[1], "gns": rest[2]} \
            if overlap else state.snap
        new_state = TrainState(params=params, opt=opt_st,
                               cstate=state.cstate, t=t, snap=snap,
                               round=rnd, engine=engine)
        return new_state, metrics

    return round_step


def shard_train_state(state: TrainState, mesh, plan):
    """Place a flat-engine ``TrainState`` for ``make_sharded_round_step``:
    the (R, n) view under the flat-view rule (`launch.mesh.
    flat_view_sharding`), optimizer state over the worker axes, the
    staleness-1 snapshot with replicated rows, scalars replicated."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import flat_col_entry, flat_view_sharding

    if state.engine is None:
        raise ValueError("shard_train_state requires a flat-engine "
                         "TrainState (DPPFConfig.engine='flat')")
    row_e = _axis_entry(tuple(plan.worker_axes))

    def put(leaf, spec):
        return jax.device_put(leaf, NamedSharding(mesh, spec))

    def opt_put(leaf):
        nd = jnp.ndim(leaf)
        return put(leaf, P(*([row_e] + [None] * (nd - 1))) if nd else P())

    params = jax.device_put(
        state.params, flat_view_sharding(mesh, state.params.shape, plan))
    snap = state.snap
    if snap is not None:
        col_e = flat_col_entry(mesh, snap["x"].shape[1], plan)
        snap = {"x": put(snap["x"], P(None, col_e)),
                "losses": put(snap["losses"], P()),
                "gns": put(snap["gns"], P())}
    rnd = put(state.round, P()) if state.round is not None else None
    return TrainState(params=params, opt=jax.tree.map(opt_put, state.opt),
                      cstate=state.cstate, t=put(state.t, P()), snap=snap,
                      round=rnd, engine=state.engine)


def make_ddp_step(loss_fn, opt: Optimizer, *,
                  clock: Optional[RoundClock] = None,
                  base_lr: Optional[float] = None,
                  total_steps: Optional[int] = None, warmup: int = 0,
                  sam_rho: float = 0.0):
    """DDP baseline: one replica; per-worker micro-grads are averaged every
    step (lowers to the per-step all-reduce on the mesh). Batch leading dim
    is M (the worker/data axis). The LR position comes from the same
    ``RoundClock`` the round builders use (tau is irrelevant here — DDP is
    the tau=1-per-step clock)."""
    if clock is None:
        if base_lr is None or total_steps is None:
            raise ValueError("make_ddp_step needs a RoundClock (clock=...) "
                             "or the legacy base_lr/total_steps pair")
        clock = RoundClock(total_steps=total_steps, tau=1, base_lr=base_lr,
                           warmup=warmup)

    def step(state: TrainState, batch):
        def per_worker(b):
            if sam_rho > 0:
                (loss, _), g = sam_gradient(loss_fn, state.params, b, sam_rho)
            else:
                (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    state.params, b)
            return loss, g

        losses, grads = jax.vmap(per_worker)(batch)
        g = jax.tree.map(lambda a: jnp.mean(a.astype(jnp.float32), axis=0),
                         grads)
        lr = clock.lr_at(state.t)
        params, opt_st = opt.step(state.params, g, state.opt, lr)
        new_state = TrainState(params=params, opt=opt_st, cstate=state.cstate,
                               t=state.t + 1)
        return new_state, {"train_loss": losses.mean()}

    return step


def stacked_params(state: TrainState):
    """The worker-stacked parameter pytree, whichever engine holds it."""
    if state.engine is not None:
        return state.engine.unflatten(state.params)
    return state.params


def average_params(state: TrainState):
    """Final returned model: the worker average (Alg. 1 last line).
    fp32 leaves on every engine (the tree path's tree_mean0 is fp32)."""
    if state.engine is not None:
        return state.engine.unflatten_row(
            jnp.mean(state.engine.workers(state.params), axis=0), cast=False)
    if jax.tree.leaves(state.params)[0].ndim == 0:
        return state.params
    from repro.core import pullpush as pp
    return pp.tree_mean0(state.params)
