"""DPPF trainer: a communication ROUND is one compiled function —
``lax.scan`` over tau purely-local optimizer steps (zero worker-axis
collectives) followed by the consensus pull-push update (the round's single
all-reduce). The DDP baseline is a separate per-step function whose gradient
mean over the worker axis lowers to the classic every-step all-reduce.

Both are generic over ``loss_fn(params, batch) -> (loss, metrics)`` so the
same trainer drives the 10 assigned LM architectures and the small
paper-table stand-in models.

With ``DPPFConfig.engine == "flat"`` the worker parameters live in the
ConsensusEngine's persistent ``(R, n)`` fp32 view for the WHOLE run: it is
built once in ``init_train_state``, local steps differentiate through cheap
slice/reshape views of it (``engine.unflatten_row``), and the consensus
update runs as flat Gram+mixing passes — no per-round flatten/concatenate.
Donate the state (``jax.jit(round_step, donate_argnums=0)``) so the buffer
is reused in place across rounds (DESIGN.md §Consensus-engine).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import DPPFConfig
from repro.core import consensus
from repro.core.engine import ConsensusEngine
from repro.core.schedules import cosine_lr, lam_schedule
from repro.optim import Optimizer, sam_gradient


@dataclass
class TrainState:
    params: Any          # worker-stacked (M, ...) for DPPF; flat for DDP;
                         # the engine's (R, n) flat view when engine is set
    opt: Any
    cstate: Any          # consensus state (EASGD center etc.)
    t: jnp.ndarray       # local-step counter (scalar int32)
    engine: Any = None   # ConsensusEngine (static metadata) or None


# ``engine`` is hashable static metadata: jit recompiles if the layout
# changes, and donation/vmap only ever see the array fields.
jax.tree_util.register_dataclass(
    TrainState, data_fields=("params", "opt", "cstate", "t"),
    meta_fields=("engine",))


def _grad_norm(grads):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(grads)))


def init_train_state(loss_params_init, opt: Optimizer, dcfg: DPPFConfig,
                     n_workers: int, key, *, same_init=True, engine=None):
    """Stack per-worker params. The paper initializes all workers from the
    same random model (Alg. 1); ``same_init=False`` gives per-worker seeds
    (useful for the width ablations).

    With ``dcfg.engine == "flat"`` (or an explicit ``engine``) the stacked
    tree is flattened ONCE here into the engine's persistent (R, n) view;
    every subsequent round reuses/donates that buffer.
    """
    if same_init:
        p0 = loss_params_init(key)
        params = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (n_workers,) + a.shape), p0)
        # materialize (broadcast arrays are lazy views)
        params = jax.tree.map(jnp.array, params)
    else:
        keys = jax.random.split(key, n_workers)
        params = jax.vmap(loss_params_init)(keys)
    if engine is None and getattr(dcfg, "engine", "tree") == "flat" \
            and dcfg.consensus != "ddp":
        engine = ConsensusEngine.from_stacked(
            params, method=dcfg.consensus, eps=dcfg.eps)
    if engine is not None:
        params = engine.flatten(params)           # the ONE flatten per run
        opt_state = jax.vmap(opt.init)(engine.workers(params))
        cstate = consensus.init_state(dcfg.consensus, params, engine=engine)
    else:
        opt_state = jax.vmap(opt.init)(params)
        cstate = consensus.init_state(dcfg.consensus, params)
    return TrainState(params=params, opt=opt_state, cstate=cstate,
                      t=jnp.zeros((), jnp.int32), engine=engine)


def make_round_step(loss_fn, opt: Optimizer, dcfg: DPPFConfig, *,
                    base_lr: float, total_steps: int, warmup: int = 0,
                    sam_rho: float = 0.0, total_rounds: Optional[int] = None):
    """Build the fused DPPF round: scan(tau local steps) + consensus.

    Input batch pytree has leading dims (tau, M, ...). Returns
    round_step(state, batch) -> (state, metrics). jit/shard at callsite
    (``donate_argnums=0`` recommended — required for in-place flat-view
    reuse when the state carries a ConsensusEngine).
    """
    total_rounds = total_rounds or max(total_steps // max(dcfg.tau, 1), 1)

    def round_step(state: TrainState, batch):
        engine = state.engine
        if engine is None:
            loss, p0 = loss_fn, state.params
        else:
            # local steps differentiate through the flat rows directly:
            # unflatten_row is slices+reshapes, so grads arrive flat and the
            # optimizer state stays (M, n) — no per-step re-flatten
            loss = lambda row, b: loss_fn(engine.unflatten_row(row), b)
            p0 = engine.workers(state.params)

        def local_step(p, o, b, t):
            if sam_rho > 0:
                (loss_v, _), g = sam_gradient(loss, p, b, sam_rho)
            else:
                (loss_v, _), g = jax.value_and_grad(loss, has_aux=True)(p, b)
            lr = cosine_lr(base_lr, t, total_steps, warmup)
            gn = _grad_norm(g)
            p, o = opt.step(p, g, o, lr)
            return p, o, loss_v, gn

        def micro(carry, mb):
            params, opt_st, t = carry
            params, opt_st, losses, gns = jax.vmap(
                local_step, in_axes=(0, 0, 0, None))(params, opt_st, mb, t)
            return (params, opt_st, t + 1), (losses, gns)

        (params, opt_st, t), (losses, gns) = jax.lax.scan(
            micro, (p0, state.opt, state.t), batch)
        if engine is not None:
            params = engine.with_workers(state.params, params)

        round_idx = t // max(dcfg.tau, 1)
        lam_t = lam_schedule(dcfg.lam_schedule, dcfg.lam, round_idx,
                             total_rounds)
        params, cstate, metrics = consensus.apply_round(
            params, dcfg, lam_t, state.cstate,
            losses=losses[-1], grad_norms=gns[-1], engine=engine)
        metrics = dict(metrics)
        metrics["train_loss"] = losses.mean()
        metrics["lam_t"] = lam_t
        new_state = TrainState(params=params, opt=opt_st, cstate=cstate, t=t,
                               engine=engine)
        return new_state, metrics

    return round_step


def make_ddp_step(loss_fn, opt: Optimizer, *, base_lr: float,
                  total_steps: int, warmup: int = 0, sam_rho: float = 0.0):
    """DDP baseline: one replica; per-worker micro-grads are averaged every
    step (lowers to the per-step all-reduce on the mesh). Batch leading dim
    is M (the worker/data axis)."""
    def step(state: TrainState, batch):
        def per_worker(b):
            if sam_rho > 0:
                (loss, _), g = sam_gradient(loss_fn, state.params, b, sam_rho)
            else:
                (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    state.params, b)
            return loss, g

        losses, grads = jax.vmap(per_worker)(batch)
        g = jax.tree.map(lambda a: jnp.mean(a.astype(jnp.float32), axis=0),
                         grads)
        lr = cosine_lr(base_lr, state.t, total_steps, warmup)
        params, opt_st = opt.step(state.params, g, state.opt, lr)
        new_state = TrainState(params=params, opt=opt_st, cstate=state.cstate,
                               t=state.t + 1)
        return new_state, {"train_loss": losses.mean()}

    return step


def stacked_params(state: TrainState):
    """The worker-stacked parameter pytree, whichever engine holds it."""
    if state.engine is not None:
        return state.engine.unflatten(state.params)
    return state.params


def average_params(state: TrainState):
    """Final returned model: the worker average (Alg. 1 last line).
    fp32 leaves on every engine (the tree path's tree_mean0 is fp32)."""
    if state.engine is not None:
        return state.engine.unflatten_row(
            jnp.mean(state.engine.workers(state.params), axis=0), cast=False)
    if jax.tree.leaves(state.params)[0].ndim == 0:
        return state.params
    from repro.core import pullpush as pp
    return pp.tree_mean0(state.params)
