"""Replayable chaos injection for the fault-tolerant round supervisor.

A ``ChaosPlan`` is the fault analog of the autotuner's ``TunePlan``: a
byte-stable JSON artifact scripting per-round fault events, so the SAME
faults replay bit-identically in CI and the pinned recovery-event sequence
is a committed contract, not a flaky observation. Event kinds:

* ``kill``         — worker ``w`` stops heartbeating for ``duration``
                     rounds (process death; rejoins after the window);
* ``stall``        — straggler: same heartbeat silence, conventionally a
                     short window (the worker is late, not gone);
* ``netdrop``      — partition: heartbeats lost in transit, same observable
                     effect on the membership table as a kill;
* ``oom``          — the training step raises ``RESOURCE_EXHAUSTED`` at
                     this round while the per-worker batch exceeds
                     ``batch_above`` (the PR 9 ``is_oom`` contract — the
                     supervisor shrinks the batch and replays);
* ``corrupt_ckpt`` — the checkpoint written at this round is torn after
                     the (atomic) save, exercising the restore ladder's
                     corrupt-archive fallback.

The first three only differ in intent; the membership table sees missed
heartbeats either way and walks the same ACTIVE -> SUSPECT -> DEAD ->
REJOINING machine. ``FaultInjector`` is the trainer-boundary hook set
(``before_step`` / ``after_save``) the supervisor calls; it is pure state
read from the plan — no clocks, no randomness — so a replay of the same
plan takes the same branches.

``InjectedOOM`` lives here (shared with ``tests/_faults.py``) so autotune
and supervisor tests stop duplicating the OOM-matching message contract.
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from typing import Tuple

from repro.train.autotune import is_oom  # noqa: F401  (re-export: the
#   supervisor and the fault tests import the OOM contract from ONE place)

PLAN_VERSION = 1

KINDS = ("kill", "stall", "oom", "corrupt_ckpt", "netdrop")
# kinds observable as missed heartbeats (drive the membership table)
MEMBERSHIP_KINDS = ("kill", "stall", "netdrop")


class InjectedOOM(RuntimeError):
    """Scripted allocator failure. A plain RuntimeError whose message
    carries the ``RESOURCE_EXHAUSTED`` token, so ``is_oom`` (the PR 9
    message contract) recognizes it with no jaxlib import."""

    def __init__(self, batch, round_idx=None):
        where = f" (round {round_idx})" if round_idx is not None else ""
        super().__init__(
            f"RESOURCE_EXHAUSTED: injected OOM at batch={batch}{where}")
        self.batch = batch
        self.round_idx = round_idx


@dataclass(frozen=True)
class ChaosEvent:
    """One scripted fault. ``worker`` is required (>= 0) for the
    membership kinds; ``batch_above`` is required (>= 1) for ``oom`` —
    the fault clears once the supervisor has shrunk the per-worker batch
    to ``batch_above`` or below, which is what makes the OOM recoverable
    rather than a death loop."""
    round: int
    kind: str
    worker: int = -1
    duration: int = 1
    batch_above: int = 0

    def __post_init__(self):
        # ValueError, never assert: plans are user-authored JSON and the
        # guards must survive python -O (tests/optcheck.py)
        if self.kind not in KINDS:
            raise ValueError(
                f"unknown chaos kind {self.kind!r} (one of {KINDS})")
        if self.round < 0:
            raise ValueError(f"event round must be >= 0, got {self.round}")
        if self.duration < 1:
            raise ValueError(
                f"event duration must be >= 1, got {self.duration}")
        if self.kind in MEMBERSHIP_KINDS and self.worker < 0:
            raise ValueError(
                f"{self.kind} event needs a worker index >= 0")
        if self.kind == "oom" and self.batch_above < 1:
            raise ValueError(
                "oom event needs batch_above >= 1 (the per-worker batch "
                "size at which the injected allocator stops failing)")

    def to_dict(self) -> dict:
        d = {"round": self.round, "kind": self.kind}
        if self.kind in MEMBERSHIP_KINDS:
            d["worker"] = self.worker
            d["duration"] = self.duration
        if self.kind == "oom":
            d["batch_above"] = self.batch_above
        return d


@dataclass(frozen=True)
class ChaosPlan:
    """The replayable fault script. Same serialization idiom as TunePlan:
    ``to_dict`` emits canonically ordered, source-rounded JSON so a
    load -> save round-trip is byte-identical; ``from_dict`` wraps any
    payload shape error in one clear ValueError. ``seed`` feeds the
    supervisor's deterministic backoff jitter."""
    events: Tuple[ChaosEvent, ...] = ()
    seed: int = 0
    version: int = PLAN_VERSION

    def __post_init__(self):
        if self.version != PLAN_VERSION:
            raise ValueError(f"ChaosPlan version {self.version} != "
                             f"{PLAN_VERSION} (re-author the plan)")
        # canonical event order — makes dumps() independent of authoring
        # order and the replayed injection order well-defined
        object.__setattr__(
            self, "events",
            tuple(sorted(self.events,
                         key=lambda e: (e.round, e.kind, e.worker))))

    # -- queries -------------------------------------------------------------

    def membership_events(self) -> Tuple[ChaosEvent, ...]:
        return tuple(e for e in self.events
                     if e.kind in MEMBERSHIP_KINDS)

    def is_down(self, worker: int, round_idx: int) -> bool:
        """Is this worker's heartbeat silenced at this round?"""
        return any(e.worker == worker
                   and e.round <= round_idx < e.round + e.duration
                   for e in self.membership_events())

    # -- deterministic JSON --------------------------------------------------

    def to_dict(self) -> dict:
        return {"version": self.version, "seed": self.seed,
                "events": [e.to_dict() for e in self.events]}

    @classmethod
    def from_dict(cls, d: dict) -> "ChaosPlan":
        try:
            events = tuple(
                ChaosEvent(round=int(e["round"]), kind=str(e["kind"]),
                           worker=int(e.get("worker", -1)),
                           duration=int(e.get("duration", 1)),
                           batch_above=int(e.get("batch_above", 0)))
                for e in d["events"])
            return cls(events=events, seed=int(d.get("seed", 0)),
                       version=int(d.get("version", -1)))
        except (KeyError, TypeError) as e:
            raise ValueError(f"malformed ChaosPlan payload: {e!r}") from e

    def dumps(self) -> str:
        return json.dumps(self.to_dict(), indent=2, sort_keys=True) + "\n"

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.dumps())

    @classmethod
    def load(cls, path: str) -> "ChaosPlan":
        with open(path) as f:
            return cls.from_dict(json.load(f))


class FaultInjector:
    """Trainer-boundary chaos hooks. The supervisor calls ``before_step``
    ahead of every round's step and ``after_save`` after every checkpoint
    write; both are pure functions of (plan, round, argument) so the same
    plan replays to the same faults — including on the re-executed rounds
    after a restore (an oom event keeps firing until the batch is small
    enough; a corrupt_ckpt event re-tears the re-written file)."""

    def __init__(self, plan: ChaosPlan):
        self.plan = plan

    def before_step(self, round_idx: int, batch: int) -> None:
        """Raise InjectedOOM when an oom event covers this round and the
        per-worker batch is still above its clearing threshold."""
        for e in self.plan.events:
            if e.kind == "oom" and e.round == round_idx \
                    and batch > e.batch_above:
                raise InjectedOOM(batch, round_idx=round_idx)

    def after_save(self, round_idx: int, path: str) -> bool:
        """Tear the just-written checkpoint (truncate to half its bytes —
        an un-openable zip) when a corrupt_ckpt event covers this round.
        Returns True when the file was corrupted."""
        for e in self.plan.events:
            if e.kind == "corrupt_ckpt" and e.round == round_idx:
                with open(path, "rb") as f:
                    data = f.read()
                with open(path, "wb") as f:
                    f.write(data[:max(1, len(data) // 2)])
                return True
        return False
