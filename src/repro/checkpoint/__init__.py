from repro.checkpoint.io import (
    load_pytree, load_train_state, save_pytree, save_train_state,
)

__all__ = ["load_pytree", "load_train_state", "save_pytree",
           "save_train_state"]
