"""Checkpointing: pytrees <-> npz with path-flattened keys.

Sharding-aware: arrays are gathered to host (``jax.device_get``) on save;
on restore the caller re-applies shardings (``jax.device_put`` with the
plan's sharding), so checkpoints are mesh-shape independent — a checkpoint
written on the 16x16 mesh restores onto the 2x16x16 multi-pod mesh.

``save_train_state``/``load_train_state`` round-trip a full flat-engine
``TrainState`` — the (R, n) view, optimizer state, consensus state, the
overlap snapshot (the staleness-1 buffer or the staleness-k ring, whose
nested dict keys path-flatten the same way), and the step counter — for
mid-run resume (``launch/train.py --ckpt``).
"""
from __future__ import annotations

import dataclasses
import os
import tempfile
import zipfile
import zlib

import jax
import numpy as np

_SEP = "::"

# exception types a truncated / torn / garbled npz archive surfaces as;
# load_pytree converts them into one clear ValueError naming the path (the
# contract the supervisor's restore ladder relies on — a corrupt "last"
# checkpoint must be a recoverable condition, not a raw zip traceback)
_CORRUPT_ERRORS = (zipfile.BadZipFile, EOFError, OSError, zlib.error,
                   ValueError, KeyError)


def _corrupt(path, err):
    return ValueError(
        f"checkpoint {path!r} is truncated or corrupt "
        f"({type(err).__name__}: {err}) — restore from an older copy")


def _flatten(tree):
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def save_pytree(path, tree, extra=None):
    """Crash-safe save: the archive is written to a same-directory temp
    file and ``os.replace``d into place, so a crash mid-save can never
    leave a torn ``.npz`` under the final name — the previous checkpoint
    (if any) survives intact until the new one is fully on disk."""
    flat = _flatten(tree)
    if extra:
        for k, v in extra.items():
            flat[f"__extra__{_SEP}{k}"] = np.asarray(v)
    final = path if path.endswith(".npz") else path + ".npz"
    d = os.path.dirname(os.path.abspath(final)) or "."
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, prefix=os.path.basename(final) + ".",
                               suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, **flat)
        os.replace(tmp, final)
    except BaseException:
        if os.path.exists(tmp):
            os.remove(tmp)
        raise


def load_pytree(path, like):
    """Restore into the structure of ``like`` (shape/dtype template).

    A truncated or garbled archive (torn write, injected corruption)
    raises ``ValueError`` naming the path — never a raw ``BadZipFile`` /
    EOF traceback — so callers like the supervisor's restore ladder can
    fall back to an older checkpoint. A missing file still raises
    ``FileNotFoundError``."""
    file = path if path.endswith(".npz") else path + ".npz"
    try:
        data = np.load(file)
    except FileNotFoundError:
        raise
    except _CORRUPT_ERRORS as e:
        raise _corrupt(file, e) from e
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    paths = jax.tree_util.tree_flatten_with_path(like)[0]
    out = []
    try:
        files = set(data.files)
    except _CORRUPT_ERRORS as e:
        raise _corrupt(file, e) from e
    for (path_keys, leaf) in paths:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path_keys)
        if key not in files:
            raise ValueError(
                f"checkpoint {file!r} has no leaf {key!r} (template "
                "mismatch or truncated archive)")
        try:
            arr = data[key]
        except _CORRUPT_ERRORS as e:
            raise _corrupt(file, e) from e
        if arr.shape != tuple(leaf.shape):
            # ValueError, not assert: restore is a user-facing path and the
            # shape check must survive python -O
            raise ValueError(
                f"checkpoint leaf {key!r} has shape {arr.shape}, template "
                f"expects {tuple(leaf.shape)}")
        out.append(arr.astype(leaf.dtype))
    try:
        extra = {k.split(_SEP, 1)[1]: data[k] for k in files
                 if k.startswith("__extra__")}
    except _CORRUPT_ERRORS as e:
        raise _corrupt(file, e) from e
    return jax.tree_util.tree_unflatten(treedef, out), extra


def _state_tree(state):
    tree = {"params": state.params, "opt": state.opt, "cstate": state.cstate}
    if state.snap is not None:
        tree["snap"] = state.snap
    return tree


def save_train_state(path, state):
    """Full ``TrainState`` -> npz: the flat (R, n) view (or stacked tree),
    optimizer + consensus state, staleness-1 snapshot, and the clock
    position (step AND round counters — with an adaptive tau schedule the
    round index is not derivable from the step count and a naive
    ``t // tau`` would mis-place the lam schedule on resume). The engine is
    static metadata and is NOT saved — the resume path rebuilds it from the
    same config (`train.init_train_state`)."""
    extra = {"t": np.asarray(jax.device_get(state.t))}
    if state.round is not None:
        extra["round"] = np.asarray(jax.device_get(state.round))
    save_pytree(path, _state_tree(state), extra=extra)


def load_train_state(path, like, *, shardings=None, clock=None):
    """Restore a ``save_train_state`` checkpoint into the structure of
    ``like`` (a freshly initialized ``TrainState`` from the same config —
    its engine metadata is kept). ``shardings``, when given, is a pytree of
    ``NamedSharding`` matching ``{"params", "opt", "cstate", "snap"}``
    subtrees and is re-applied on the restored arrays (the module's
    mesh-independence contract). A checkpoint saved without a staleness-1
    snapshot (an exact-mode run) resumes into an overlap run with the
    RESTORED params as warm-start snapshot — the steady-state carry, not
    the init fleet, whose stale delta would jolt late-training params (the
    round-0 bubble only gates t == 0).

    The clock position restores from the checkpoint's ``round`` extra; for
    pre-RoundClock checkpoints that only carried ``t``, pass the run's
    ``clock`` (`train.RoundClock`) and the round is recovered via
    ``clock.round_of_step``. Without a clock the restored ``round`` is None
    — NOT the template's fresh 0, which would restart the lam schedule —
    so the round builders' pre-scan ``t // tau`` fallback engages (correct
    for the fixed-tau runs all pre-clock checkpoints came from). Returns
    the resumed ``TrainState``.
    """
    file = path if path.endswith(".npz") else path + ".npz"
    try:
        with np.load(file) as data:
            keys = set(data.files)
    except FileNotFoundError:
        raise
    except _CORRUPT_ERRORS as e:
        raise _corrupt(file, e) from e
    if f"__extra__{_SEP}t" not in keys:
        raise ValueError(
            f"{path} is not a train-state checkpoint (no step counter) — "
            "final-params checkpoints (save_pytree) are a different format")
    template = _state_tree(like)
    missing_snap = "snap" in template and not any(
        k.startswith(f"snap{_SEP}") for k in keys)
    if missing_snap:
        del template["snap"]
    # elastic checkpoints written before the quorum sync gate existed have
    # no snap::sync scalar — drop it from the template and backfill the
    # fully-synced default after the load (graceful format upgrade)
    fill_sync = (not missing_snap and "snap" in template
                 and "sync" in template["snap"]
                 and f"snap{_SEP}sync" not in keys)
    if fill_sync:
        template["snap"] = {k: v for k, v in template["snap"].items()
                            if k != "sync"}
    tree, extra = load_pytree(path, template)
    if fill_sync:
        tree["snap"] = dict(tree["snap"], sync=np.ones((), np.float32))
    if missing_snap:
        sx = tree["params"] + 0.0
        if like.snap["x"].ndim == sx.ndim + 1:
            # staleness-k ring template: warm-start every slot of the
            # (k, R, n) ring with the restored params
            sx = np.broadcast_to(sx[None], like.snap["x"].shape) + 0.0
        tree["snap"] = dict(like.snap, x=sx)
    if shardings is not None:
        for k, sh in shardings.items():
            if k in tree:
                tree[k] = jax.device_put(tree[k], sh)
    jnp = jax.numpy
    if "round" in extra:
        rnd = jnp.asarray(extra["round"], jnp.int32)
    elif clock is not None:
        rnd = jnp.asarray(clock.round_of_step(int(extra["t"])), jnp.int32)
    else:
        rnd = None
    return dataclasses.replace(
        like, params=tree["params"], opt=tree["opt"], cstate=tree["cstate"],
        snap=tree.get("snap", like.snap), round=rnd,
        t=jnp.asarray(extra["t"], jnp.int32))
