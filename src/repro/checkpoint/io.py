"""Checkpointing: pytrees <-> npz with path-flattened keys.

Sharding-aware: arrays are gathered to host (``jax.device_get``) on save;
on restore the caller re-applies shardings (``jax.device_put`` with the
plan's sharding), so checkpoints are mesh-shape independent — a checkpoint
written on the 16x16 mesh restores onto the 2x16x16 multi-pod mesh.

``save_train_state``/``load_train_state`` round-trip a full flat-engine
``TrainState`` — the (R, n) view, optimizer state, consensus state, the
overlap snapshot (the staleness-1 buffer or the staleness-k ring, whose
nested dict keys path-flatten the same way), and the step counter — for
mid-run resume (``launch/train.py --ckpt``).
"""
from __future__ import annotations

import dataclasses
import os

import jax
import numpy as np

_SEP = "::"


def _flatten(tree):
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def save_pytree(path, tree, extra=None):
    flat = _flatten(tree)
    if extra:
        for k, v in extra.items():
            flat[f"__extra__{_SEP}{k}"] = np.asarray(v)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    np.savez(path, **flat)


def load_pytree(path, like):
    """Restore into the structure of ``like`` (shape/dtype template)."""
    data = np.load(path if path.endswith(".npz") else path + ".npz")
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    paths = jax.tree_util.tree_flatten_with_path(like)[0]
    out = []
    for (path_keys, leaf) in paths:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path_keys)
        arr = data[key]
        if arr.shape != tuple(leaf.shape):
            # ValueError, not assert: restore is a user-facing path and the
            # shape check must survive python -O
            raise ValueError(
                f"checkpoint leaf {key!r} has shape {arr.shape}, template "
                f"expects {tuple(leaf.shape)}")
        out.append(arr.astype(leaf.dtype))
    extra = {k.split(_SEP, 1)[1]: data[k] for k in data.files
             if k.startswith("__extra__")}
    return jax.tree_util.tree_unflatten(treedef, out), extra


def _state_tree(state):
    tree = {"params": state.params, "opt": state.opt, "cstate": state.cstate}
    if state.snap is not None:
        tree["snap"] = state.snap
    return tree


def save_train_state(path, state):
    """Full ``TrainState`` -> npz: the flat (R, n) view (or stacked tree),
    optimizer + consensus state, staleness-1 snapshot, and the clock
    position (step AND round counters — with an adaptive tau schedule the
    round index is not derivable from the step count and a naive
    ``t // tau`` would mis-place the lam schedule on resume). The engine is
    static metadata and is NOT saved — the resume path rebuilds it from the
    same config (`train.init_train_state`)."""
    extra = {"t": np.asarray(jax.device_get(state.t))}
    if state.round is not None:
        extra["round"] = np.asarray(jax.device_get(state.round))
    save_pytree(path, _state_tree(state), extra=extra)


def load_train_state(path, like, *, shardings=None, clock=None):
    """Restore a ``save_train_state`` checkpoint into the structure of
    ``like`` (a freshly initialized ``TrainState`` from the same config —
    its engine metadata is kept). ``shardings``, when given, is a pytree of
    ``NamedSharding`` matching ``{"params", "opt", "cstate", "snap"}``
    subtrees and is re-applied on the restored arrays (the module's
    mesh-independence contract). A checkpoint saved without a staleness-1
    snapshot (an exact-mode run) resumes into an overlap run with the
    RESTORED params as warm-start snapshot — the steady-state carry, not
    the init fleet, whose stale delta would jolt late-training params (the
    round-0 bubble only gates t == 0).

    The clock position restores from the checkpoint's ``round`` extra; for
    pre-RoundClock checkpoints that only carried ``t``, pass the run's
    ``clock`` (`train.RoundClock`) and the round is recovered via
    ``clock.round_of_step``. Without a clock the restored ``round`` is None
    — NOT the template's fresh 0, which would restart the lam schedule —
    so the round builders' pre-scan ``t // tau`` fallback engages (correct
    for the fixed-tau runs all pre-clock checkpoints came from). Returns
    the resumed ``TrainState``.
    """
    file = path if path.endswith(".npz") else path + ".npz"
    with np.load(file) as data:
        keys = set(data.files)
    if f"__extra__{_SEP}t" not in keys:
        raise ValueError(
            f"{path} is not a train-state checkpoint (no step counter) — "
            "final-params checkpoints (save_pytree) are a different format")
    template = _state_tree(like)
    missing_snap = "snap" in template and not any(
        k.startswith(f"snap{_SEP}") for k in keys)
    if missing_snap:
        del template["snap"]
    tree, extra = load_pytree(path, template)
    if missing_snap:
        sx = tree["params"] + 0.0
        if like.snap["x"].ndim == sx.ndim + 1:
            # staleness-k ring template: warm-start every slot of the
            # (k, R, n) ring with the restored params
            sx = np.broadcast_to(sx[None], like.snap["x"].shape) + 0.0
        tree["snap"] = dict(like.snap, x=sx)
    if shardings is not None:
        for k, sh in shardings.items():
            if k in tree:
                tree[k] = jax.device_put(tree[k], sh)
    jnp = jax.numpy
    if "round" in extra:
        rnd = jnp.asarray(extra["round"], jnp.int32)
    elif clock is not None:
        rnd = jnp.asarray(clock.round_of_step(int(extra["t"])), jnp.int32)
    else:
        rnd = None
    return dataclasses.replace(
        like, params=tree["params"], opt=tree["opt"], cstate=tree["cstate"],
        snap=tree.get("snap", like.snap), round=rnd,
        t=jnp.asarray(extra["t"], jnp.int32))
