"""Checkpointing: pytrees <-> npz with path-flattened keys.

Sharding-aware: arrays are gathered to host (``jax.device_get``) on save;
on restore the caller re-applies shardings (``jax.device_put`` with the
plan's sharding), so checkpoints are mesh-shape independent — a checkpoint
written on the 16x16 mesh restores onto the 2x16x16 multi-pod mesh.
"""
from __future__ import annotations

import os

import jax
import numpy as np

_SEP = "::"


def _flatten(tree):
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def save_pytree(path, tree, extra=None):
    flat = _flatten(tree)
    if extra:
        for k, v in extra.items():
            flat[f"__extra__{_SEP}{k}"] = np.asarray(v)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    np.savez(path, **flat)


def load_pytree(path, like):
    """Restore into the structure of ``like`` (shape/dtype template)."""
    data = np.load(path if path.endswith(".npz") else path + ".npz")
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    paths = jax.tree_util.tree_flatten_with_path(like)[0]
    out = []
    for (path_keys, leaf) in paths:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path_keys)
        arr = data[key]
        assert arr.shape == leaf.shape, f"{key}: {arr.shape} != {leaf.shape}"
        out.append(arr.astype(leaf.dtype))
    extra = {k.split(_SEP, 1)[1]: data[k] for k in data.files
             if k.startswith("__extra__")}
    return jax.tree_util.tree_unflatten(treedef, out), extra
