from repro.data.synthetic import (
    TokenTask, classification_task, make_lm_batch, make_round_batch,
)

__all__ = ["TokenTask", "classification_task", "make_lm_batch",
           "make_round_batch"]
