"""Deterministic synthetic data pipelines.

LM task: a learnable affine-recurrence token stream — worker shards are
disjoint by construction (stateless PRNG keyed by (worker, step)), matching
the paper's exclusive-shard setup (Alg. 1). Every batch is reproducible
from (seed, worker, step) with no pipeline state, which is what makes the
multi-pod input pipeline trivially resumable.

Classification task: Gaussian clusters with class-dependent means — the
CPU-scale stand-in for CIFAR in the paper-table benchmarks, with a held-out
test split so generalization gaps are measurable.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# LM token stream
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class TokenTask:
    vocab_size: int
    seq_len: int
    mult: int = 31
    add: int = 17
    noise: float = 0.05

    def sample(self, key, batch):
        """(batch, seq) token sequences following a noisy affine recurrence
        t_{i+1} = (mult * t_i + add) mod V  — learnable next-token structure."""
        k1, k2, k3 = jax.random.split(key, 3)
        start = jax.random.randint(k1, (batch,), 0, self.vocab_size)

        def step(tok, k):
            nxt = (tok * self.mult + self.add) % self.vocab_size
            flip = jax.random.bernoulli(k, self.noise, (batch,))
            rnd = jax.random.randint(jax.random.fold_in(k, 1), (batch,),
                                     0, self.vocab_size)
            nxt = jnp.where(flip, rnd, nxt)
            return nxt, nxt

        keys = jax.random.split(k2, self.seq_len - 1)
        _, rest = jax.lax.scan(step, start, keys)
        toks = jnp.concatenate([start[None], rest], axis=0).T
        del k3
        return toks.astype(jnp.int32)


def make_lm_batch(task: TokenTask, seed: int, worker: int, step: int, batch: int,
                  cfg=None):
    """Deterministic per-(worker, step) batch; shards never overlap because
    the key space is partitioned by worker id."""
    key = jax.random.fold_in(jax.random.fold_in(jax.random.PRNGKey(seed),
                                                worker), step)
    toks = task.sample(key, batch)
    labels = jnp.roll(toks, -1, axis=1).at[:, -1].set(-1)
    out = {"tokens": toks, "labels": labels}
    if cfg is not None and cfg.n_prefix and not cfg.n_enc_layers:
        out["prefix"] = 0.02 * jax.random.normal(
            jax.random.fold_in(key, 7), (batch, cfg.n_prefix, cfg.d_model))
    if cfg is not None and cfg.n_enc_layers:
        out["enc"] = 0.02 * jax.random.normal(
            jax.random.fold_in(key, 8), (batch, cfg.n_prefix, cfg.d_model))
    return out


def make_round_batch(task: TokenTask, seed: int, n_workers: int, tau: int,
                     start_step: int, local_batch: int, cfg=None):
    """Stacked round input (tau, M, B, S) for the fused DPPF round step.

    ``start_step`` is the round's first GLOBAL step (``RoundSpec.start``
    from the RoundClock). Seeding by global step — not ``round_idx * tau``
    — means adaptive-tau (QSR) and remainder rounds replay the exact token
    stream a fixed-tau run sees over the same step budget, keeping adaptive
    runs reproducible and comparable."""
    def one(t, m):
        return make_lm_batch(task, seed, m, start_step + t, local_batch, cfg)
    rows = [[one(t, m) for m in range(n_workers)] for t in range(tau)]
    stacked_rows = [jax.tree.map(lambda *xs: jnp.stack(xs), *row) for row in rows]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *stacked_rows)


# ---------------------------------------------------------------------------
# Classification task (CIFAR stand-in for the paper tables)
# ---------------------------------------------------------------------------

def classification_task(n_train=2048, n_test=1024, dim=32, n_classes=10,
                        noise=1.8, label_noise=0.15, seed=0):
    """Gaussian clusters with feature noise + TRAIN-set label noise.
    Label noise creates a memorization regime: models overfit the flipped
    labels, so generalization gaps are visible and flatness matters —
    the CPU stand-in for the paper's CIFAR setting."""
    rng = np.random.default_rng(seed)
    means = rng.normal(0.0, 1.0, size=(n_classes, dim))
    def draw(n, flip):
        y = rng.integers(0, n_classes, size=n)
        x = means[y] + noise * rng.normal(size=(n, dim))
        if flip > 0:
            mask = rng.random(n) < flip
            y = np.where(mask, rng.integers(0, n_classes, size=n), y)
        return x.astype(np.float32), y.astype(np.int32)
    xtr, ytr = draw(n_train, label_noise)
    xte, yte = draw(n_test, 0.0)
    return {"x_train": jnp.asarray(xtr), "y_train": jnp.asarray(ytr),
            "x_test": jnp.asarray(xte), "y_test": jnp.asarray(yte),
            "n_classes": n_classes, "dim": dim}
