"""Beyond-paper perf variants must be EXACT (or tolerance-equal) to their
faithful baselines: chunkwise-parallel mLSTM vs per-step recurrence, bf16
MoE combine vs fp32, bf16 momentum SGD trajectory sanity."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.models.xlstm import init_mlstm, mlstm_forward


@pytest.mark.parametrize("chunk", [8, 16, 64])
@pytest.mark.parametrize("seq", [31, 32, 50, 64])
def test_chunked_mlstm_exact(chunk, seq):
    cfg_r = reduced(ARCHS["xlstm-350m"])
    cfg_c = dataclasses.replace(cfg_r, xlstm_chunk=chunk)
    key = jax.random.PRNGKey(chunk * 100 + seq)
    p = init_mlstm(key, cfg_r, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, seq, cfg_r.d_model))
    y_r, st_r = mlstm_forward(p, x, cfg_r)
    y_c, st_c = mlstm_forward(p, x, cfg_c)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_r),
                               rtol=1e-4, atol=1e-4)
    for a, b in zip(st_r, st_c):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=1e-4, atol=1e-4)


def test_chunked_mlstm_decode_continuation():
    """Decode from a chunked-prefill state == decode from recurrent state."""
    cfg_r = reduced(ARCHS["xlstm-350m"])
    cfg_c = dataclasses.replace(cfg_r, xlstm_chunk=16)
    key = jax.random.PRNGKey(7)
    p = init_mlstm(key, cfg_r, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 40, cfg_r.d_model))
    _, st_r = mlstm_forward(p, x, cfg_r)
    _, st_c = mlstm_forward(p, x, cfg_c)
    x1 = jax.random.normal(jax.random.fold_in(key, 2), (2, 1, cfg_r.d_model))
    y_r, _ = mlstm_forward(p, x1, cfg_r, st_r)
    y_c, _ = mlstm_forward(p, x1, cfg_c, st_c)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_r),
                               rtol=1e-4, atol=1e-4)


def test_moe_bf16_combine_close_to_fp32():
    from repro.models.moe import init_moe, moe_mlp
    cfg32 = reduced(ARCHS["dbrx-132b"])
    cfg16 = dataclasses.replace(cfg32, moe_combine_dtype="bfloat16")
    key = jax.random.PRNGKey(0)
    p = init_moe(key, cfg32, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 32, cfg32.d_model))
    y32, aux32 = moe_mlp(p, x, cfg32)
    y16, aux16 = moe_mlp(p, x, cfg16)
    np.testing.assert_allclose(np.asarray(y16), np.asarray(y32),
                               rtol=5e-2, atol=5e-2)
    assert float(aux32) == pytest.approx(float(aux16), rel=1e-5)


def test_bf16_momentum_still_descends():
    from repro.optim import make_optimizer
    opt = make_optimizer("sgd", momentum=0.9, state_dtype="bfloat16")
    p = {"x": jnp.ones(64) * 3.0}
    st = opt.init(p)
    assert st["mu"]["x"].dtype == jnp.bfloat16
    for _ in range(120):
        g = jax.grad(lambda q: 0.5 * jnp.sum(q["x"] ** 2))(p)
        p, st = opt.step(p, g, st, 0.05)
    assert float(jnp.abs(p["x"]).max()) < 0.25
