"""Beyond-paper perf variants must be EXACT (or tolerance-equal) to their
faithful baselines: chunkwise-parallel mLSTM vs per-step recurrence, bf16
MoE combine vs fp32, bf16 momentum SGD trajectory sanity."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.models.xlstm import init_mlstm, mlstm_forward


@pytest.mark.parametrize("chunk", [8, 16, 64])
@pytest.mark.parametrize("seq", [31, 32, 50, 64])
def test_chunked_mlstm_exact(chunk, seq):
    cfg_r = reduced(ARCHS["xlstm-350m"])
    cfg_c = dataclasses.replace(cfg_r, xlstm_chunk=chunk)
    key = jax.random.PRNGKey(chunk * 100 + seq)
    p = init_mlstm(key, cfg_r, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, seq, cfg_r.d_model))
    y_r, st_r = mlstm_forward(p, x, cfg_r)
    y_c, st_c = mlstm_forward(p, x, cfg_c)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_r),
                               rtol=1e-4, atol=1e-4)
    for a, b in zip(st_r, st_c):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=1e-4, atol=1e-4)


def test_chunked_mlstm_decode_continuation():
    """Decode from a chunked-prefill state == decode from recurrent state."""
    cfg_r = reduced(ARCHS["xlstm-350m"])
    cfg_c = dataclasses.replace(cfg_r, xlstm_chunk=16)
    key = jax.random.PRNGKey(7)
    p = init_mlstm(key, cfg_r, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 40, cfg_r.d_model))
    _, st_r = mlstm_forward(p, x, cfg_r)
    _, st_c = mlstm_forward(p, x, cfg_c)
    x1 = jax.random.normal(jax.random.fold_in(key, 2), (2, 1, cfg_r.d_model))
    y_r, _ = mlstm_forward(p, x1, cfg_r, st_r)
    y_c, _ = mlstm_forward(p, x1, cfg_c, st_c)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_r),
                               rtol=1e-4, atol=1e-4)


def test_moe_bf16_combine_close_to_fp32():
    from repro.models.moe import init_moe, moe_mlp
    cfg32 = reduced(ARCHS["dbrx-132b"])
    cfg16 = dataclasses.replace(cfg32, moe_combine_dtype="bfloat16")
    key = jax.random.PRNGKey(0)
    p = init_moe(key, cfg32, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 32, cfg32.d_model))
    y32, aux32 = moe_mlp(p, x, cfg32)
    y16, aux16 = moe_mlp(p, x, cfg16)
    np.testing.assert_allclose(np.asarray(y16), np.asarray(y32),
                               rtol=5e-2, atol=5e-2)
    assert float(aux32) == pytest.approx(float(aux16), rel=1e-5)


def test_bf16_momentum_still_descends():
    from repro.optim import make_optimizer
    opt = make_optimizer("sgd", momentum=0.9, state_dtype="bfloat16")
    p = {"x": jnp.ones(64) * 3.0}
    st = opt.init(p)
    assert st["mu"]["x"].dtype == jnp.bfloat16
    for _ in range(120):
        g = jax.grad(lambda q: 0.5 * jnp.sum(q["x"] ** 2))(p)
        p, st = opt.step(p, g, st, 0.05)
    assert float(jnp.abs(p["x"]).max()) < 0.25


def test_staleness_k_elastic_under_tuned_plan():
    """Perf-variant combo under an autotuned operating point: a TunePlan
    searched over the staleness-k space (scripted OOM frontier, no
    devices) drives BOTH the plain and the elastic staleness-k trainers.
    Full participation must not perturb elastic vs plain — the bounded
    -async carry is free when nobody drops — and a dropped round must
    actually change the dropped row (the mask is live, not decorative)."""
    from _faults import default_time_fn, scripted_runner
    from repro.configs import DPPFConfig
    from repro.optim import make_optimizer
    from repro.train import (
        TuneSpace, autotune, init_train_state, make_round_step,
        set_participation,
    )
    from benchmarks.common import mlp_init, mlp_loss

    space = TuneSpace(min_batch=1, max_batch=8, taus=(2, 4), chunks=(1, 2),
                      probe_budget=16, overlap="staleness_k", staleness=2)
    plan = autotune(scripted_runner(fail_above=5), default_time_fn, space)
    assert plan.chosen.batch == 5 and plan.overlap == "staleness_k"

    M, dim, ncls = 4, 16, 4
    base = DPPFConfig(alpha=0.2, lam=0.4, engine="flat",
                      overlap="staleness_k", staleness=2,
                      lam_schedule="fixed")
    dcfg_p = base.apply_tune_plan(plan)
    dcfg_e = dataclasses.replace(base, elastic=True).apply_tune_plan(plan)
    assert dcfg_p.tau == plan.chosen.tau
    assert dcfg_p.overlap_chunks == plan.chosen.overlap_chunks
    assert dcfg_e.elastic and dcfg_e.staleness == 2

    opt = make_optimizer("sgd", momentum=0.9)
    p0 = lambda k: mlp_init(k, dim, ncls, 8)

    def batches(seed):
        k = jax.random.PRNGKey(seed)
        shape = (plan.chosen.tau, M, plan.chosen.batch)
        return {"x": jax.random.normal(k, shape + (dim,)),
                "y": jax.random.randint(jax.random.fold_in(k, 1),
                                        shape, 0, ncls)}

    st_p = init_train_state(p0, opt, dcfg_p, M, jax.random.PRNGKey(0))
    st_e = init_train_state(p0, opt, dcfg_e, M, jax.random.PRNGKey(0))
    step_p = jax.jit(make_round_step(mlp_loss, opt, dcfg_p, base_lr=0.05,
                                     total_steps=40))
    step_e = jax.jit(make_round_step(mlp_loss, opt, dcfg_e, base_lr=0.05,
                                     total_steps=40))
    for r in range(4):
        st_e = set_participation(st_e, jnp.ones((M,)))
        st_p, m_p = step_p(st_p, batches(r))
        st_e, m_e = step_e(st_e, batches(r))
    np.testing.assert_array_equal(np.asarray(st_p.params),
                                  np.asarray(st_e.params))
    assert float(m_p["train_loss"]) == float(m_e["train_loss"])

    # a dropped round diverges: the dropped row freezes in the elastic
    # run while the plain run keeps training it
    mask = np.ones(M, np.float32)
    mask[2] = 0.0
    st_e = set_participation(st_e, jnp.asarray(mask))
    st_p, _ = step_p(st_p, batches(7))
    st_e, _ = step_e(st_e, batches(7))
    row_p = np.asarray(st_p.engine.workers(st_p.params)[2])
    row_e = np.asarray(st_e.engine.workers(st_e.params)[2])
    assert np.abs(row_p - row_e).max() > 0.0
    assert np.isfinite(np.asarray(st_e.params)).all()
