import os
import sys

# repo root on sys.path so tests can import the benchmarks package
# (src/ comes from PYTHONPATH; do NOT set XLA device-count flags here —
# smoke tests must see 1 device, the dry-run sets its own flags).
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
