"""Tests for the Mean Valley measure (Alg. 2) and the sharpness baselines:
analytic quadratic landscapes give exact expected boundary distances."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.sharpness import (
    eps_sharpness, fisher_rao, hessian_measures, kendall_tau, lpf,
)
from repro.core.valley import mean_valley, normalize_params


def quad_loss_factory(curv):
    """L(x) = 0.5 * sum_i curv_i x_i^2 + 1 (offset keeps kappa*L_A finite)."""
    c = jnp.asarray(curv)

    def loss(params):
        x = params["x"]
        return 0.5 * jnp.sum(c * x * x) + 1.0
    return loss


def test_mean_valley_exact_on_isotropic_quadratic():
    """Isotropic quadratic with curvature c: from x_A = 0 (loss 1), the
    kappa=2 contour along any unit direction sits at beta = sqrt(2/c).
    MV must find it (up to the line-search step size)."""
    c = 0.5
    loss = quad_loss_factory([c] * 8)
    workers = [{"x": jnp.eye(8)[i] * 0.3} for i in range(4)]
    res = mean_valley(loss, workers, kappa=2.0, step=0.02, max_steps=400)
    expect = float(np.sqrt(2.0 / c))
    assert abs(res["mv"] - expect) < 0.06
    assert res["inv_mv"] == -res["mv"]


def test_mean_valley_bisection_not_quantized_to_coarse_step():
    """With a deliberately coarse line-search step the bisection pass must
    still pin the kappa-contour crossing to ~1e-4, not to the step grid."""
    c = 0.5
    loss = quad_loss_factory([c] * 8)
    # symmetric pair -> x_A = 0 exactly, so the kappa=2 contour sits at
    # beta = sqrt(2/c) analytically (no average-offset correction)
    workers = [{"x": jnp.eye(8)[0] * 0.3}, {"x": -jnp.eye(8)[0] * 0.3}]
    res = mean_valley(loss, workers, kappa=2.0, step=0.5, max_steps=20)
    expect = float(np.sqrt(2.0 / c))
    assert abs(res["mv"] - expect) < 1e-3         # << the 0.5 coarse step
    assert res["hit_boundary"] == [False, False]


def test_mean_valley_flags_boundary_saturation():
    """A bounded loss never reaches kappa * L_A: previously MV silently
    saturated at max_steps * step; now each saturated direction is
    flagged."""
    def flat_loss(params):
        return 1.0 + 0.0 * jnp.sum(params["x"])   # constant: never crosses
    workers = [{"x": jnp.eye(4)[i]} for i in range(2)]
    res = mean_valley(flat_loss, workers, kappa=2.0, step=0.1, max_steps=30)
    assert res["hit_boundary"] == [True, True]
    assert res["mv"] == pytest.approx(30 * 0.1, rel=1e-6)
    # a zero-direction worker (sitting AT the average) is not a saturation
    res0 = mean_valley(flat_loss, [{"x": jnp.zeros(4)}, {"x": jnp.zeros(4)}],
                       kappa=2.0, step=0.1, max_steps=5)
    assert res0["hit_boundary"] == [False, False]
    assert res0["mv"] == 0.0


def test_mean_valley_orders_curvatures():
    """Wider valley (smaller curvature) => larger MV => smaller Inv. MV."""
    flat = quad_loss_factory([0.1] * 6)
    sharp = quad_loss_factory([5.0] * 6)
    workers = [{"x": jnp.eye(6)[i] * 0.2} for i in range(3)]
    mv_flat = mean_valley(flat, workers, step=0.05, max_steps=500)["mv"]
    mv_sharp = mean_valley(sharp, workers, step=0.05, max_steps=500)["mv"]
    assert mv_flat > mv_sharp


def test_normalize_params_unit_frobenius():
    p = {"a": jnp.ones((3, 3)) * 7.0, "b": jnp.zeros((2,))}
    n = normalize_params(p)
    np.testing.assert_allclose(float(jnp.linalg.norm(n["a"])), 1.0, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(n["b"]), 0.0)


# ---------------------------------------------------------------------------
# sharpness baselines on analytic quadratics: L = 0.5 x^T diag(c) x
# ---------------------------------------------------------------------------

def _quad_batch_loss(c):
    cv = jnp.asarray(c)

    def loss(params, batch):
        del batch
        return 0.5 * jnp.sum(cv * params["x"] * params["x"])
    return loss


def test_fisher_rao_quadratic():
    """<x, Hx> = sum c_i x_i^2 exactly for the quadratic."""
    c = [1.0, 2.0, 3.0]
    x = jnp.asarray([1.0, 1.0, 2.0])
    got = fisher_rao(_quad_batch_loss(c), {"x": x}, None)
    assert got == pytest.approx(float(jnp.sum(jnp.asarray(c) * x * x)), rel=1e-5)


def test_hessian_measures_quadratic():
    c = [1.0, 2.0, 8.0, 0.5]
    res = hessian_measures(_quad_batch_loss(c), {"x": jnp.ones(4)}, None,
                           jax.random.PRNGKey(0), lanczos_iters=8,
                           hutchinson=64)
    assert res["lambda_max"] == pytest.approx(8.0, rel=1e-3)
    assert res["trace"] == pytest.approx(sum(c), rel=0.35)  # Hutchinson noise
    frob = float(np.sqrt(sum(x * x for x in c)))
    assert res["frob"] == pytest.approx(frob, rel=0.35)


def test_eps_sharpness_orders_curvature():
    flat = eps_sharpness(_quad_batch_loss([0.1] * 4), {"x": jnp.ones(4)},
                         None, eps=1e-2)
    sharp = eps_sharpness(_quad_batch_loss([10.0] * 4), {"x": jnp.ones(4)},
                          None, eps=1e-2)
    assert sharp > flat >= 0.0


def test_lpf_orders_curvature():
    key = jax.random.PRNGKey(1)
    flat = lpf(_quad_batch_loss([0.1] * 4), {"x": jnp.zeros(4)}, None, key,
               sigma=0.5, mcmc=64)
    sharp = lpf(_quad_batch_loss([10.0] * 4), {"x": jnp.zeros(4)}, None, key,
                sigma=0.5, mcmc=64)
    assert sharp > flat


def test_kendall_tau():
    assert kendall_tau([1, 2, 3], [10, 20, 30]) == pytest.approx(1.0)
    assert kendall_tau([1, 2, 3], [30, 20, 10]) == pytest.approx(-1.0)
