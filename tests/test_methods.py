"""MethodSpec registry: registry-vs-legacy bit parity for the five
pre-refactor methods, row-stochastic coefficient stages under arbitrary
participation masks (hypothesis property), registry error surfaces, the
three new methods (parle / lpf_sgd / entropy_sgd) under staleness_k +
checkpoint resume, and the 8-device sharded trajectory pins on the flat
8x1 and hierarchical 2x2x2 meshes.

The legacy lowering below is the pre-registry ``consensus.lower_stages``
embedded VERBATIM (if/elif ladder and all): the generic MethodSpec-driven
lowering must reproduce its stage lists bit-for-bit — same stage kinds,
same order, bit-identical (T, c0, c1) arrays — for every pre-existing
method, push variant, and elastic mask. Bit-identical stage lists make
every downstream path (exact, staleness1, doublebuf, staleness_k; fast /
precise / kernel execution) identical by construction; the subprocess leg
additionally pins the sharded trajectories themselves."""
from __future__ import annotations

import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import DPPFConfig
from repro.core import consensus, methods
from repro.core.engine import ConsensusEngine
from repro.optim import make_optimizer
from repro.train import init_train_state, make_round_step
from repro.checkpoint import load_train_state, save_train_state
from tests._hyp import given, settings, st

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
ROOT = os.path.join(os.path.dirname(__file__), "..")

LEGACY_METHODS = ("simple_avg", "hard", "easgd", "lsgd", "mgrawa")
EASGD_BETA = methods.EASGD_BETA


# ---------------------------------------------------------------------------
# the pre-registry lowering, embedded verbatim (the bit-parity oracle)
# ---------------------------------------------------------------------------

def _legacy_lower_stages(engine, dcfg, lam_t, *, losses=None,
                         grad_norms=None, push_from="average", mask=None):
    method = dcfg.consensus
    alpha = 1.0 if method == "hard" else dcfg.alpha
    L = engine.layout
    M, R = L.M, L.R
    eye = jnp.eye(R, dtype=jnp.float32)
    u = engine.uniform
    zeros = jnp.zeros((R,), jnp.float32)
    act = gate = None
    if mask is not None:
        act = jnp.asarray(mask, jnp.float32)
        mfull = zeros.at[:M].set(act)
        u = mfull / jnp.maximum(jnp.sum(mfull), 1.0)
        gate = jnp.ones((R,), jnp.float32).at[:M].set(act)

    def worker_T(w):
        T = jnp.broadcast_to(w, (R, R))
        if L.aux:
            T = jnp.concatenate([T[:M], eye[M:]], axis=0)
        return T

    stages = []
    leader_w = None
    if method != "ddp":
        c_pull = zeros.at[:M].set(alpha)
        if method == "simple_avg" and dcfg.push \
                and not dcfg.exact_second_term and push_from == "average":
            stages.append(("coef", worker_T(u), c_pull,
                           zeros.at[:M].set(-lam_t)))
        else:
            if method in ("simple_avg", "hard"):
                T1 = worker_T(u)
            elif method == "easgd":
                w_z = EASGD_BETA * u + (1.0 - EASGD_BETA) * eye[M]
                T1 = jnp.broadcast_to(w_z, (R, R))
                c_pull = c_pull.at[M:].set(1.0)
            elif method == "lsgd":
                if losses is None:
                    raise ValueError("lsgd needs per-worker losses")
                lsgd_losses = losses
                if act is not None:
                    lsgd_losses = jnp.where(act > 0, losses, jnp.inf)
                leader_w = jax.nn.one_hot(jnp.argmin(lsgd_losses), R,
                                          dtype=jnp.float32)
                T1 = worker_T(leader_w)
            elif method == "mgrawa":
                if grad_norms is None:
                    raise ValueError("mgrawa needs grad norms")
                w = 1.0 / jnp.maximum(grad_norms, 1e-12)
                if act is not None:
                    w = w * act
                w = w / jnp.maximum(jnp.sum(w), 1e-12)
                T1 = worker_T(zeros.at[:M].set(w))
            else:
                raise ValueError(method)
            stages.append(("coef", T1, c_pull, zeros))
            if dcfg.push:
                if dcfg.exact_second_term:
                    stages.append(("exact", lam_t * M))
                elif push_from == "leader" and leader_w is not None:
                    stages.append(("coef", worker_T(leader_w), zeros,
                                   zeros.at[:M].set(-lam_t)))
                else:
                    stages.append(("coef", worker_T(u), zeros,
                                   zeros.at[:M].set(-lam_t)))
    if gate is not None:
        if any(s[0] == "exact" for s in stages):
            raise ValueError("elastic mask does not support "
                             "exact_second_term stages")
        stages = [("coef", T, c0 * gate, c1 * gate)
                  for (_, T, c0, c1) in stages]
    return stages, alpha


def _engine(method, M=6):
    key = jax.random.PRNGKey(3)
    stacked = {"w": jax.random.normal(key, (M, 11, 5)),
               "b": jax.random.normal(jax.random.fold_in(key, 1), (M, 9))}
    return ConsensusEngine.from_stacked(stacked, method=method)


def _assert_stages_bitwise(got, want):
    assert len(got) == len(want), (len(got), len(want))
    for sg, sw in zip(got, want):
        assert sg[0] == sw[0]
        for a, b in zip(sg[1:], sw[1:]):
            a = np.asarray(a, np.float32)
            b = np.asarray(b, np.float32)
            assert np.array_equal(a, b), (sg[0], np.abs(a - b).max())


# ---------------------------------------------------------------------------
# registry surface
# ---------------------------------------------------------------------------

def test_registry_names_aliases_and_errors():
    names = methods.method_names(aliases=False)
    assert tuple(names) == ("simple_avg", "hard", "easgd", "lsgd",
                            "mgrawa", "ddp", "parle", "lpf_sgd",
                            "entropy_sgd")
    assert "dppf" in methods.method_names()
    assert methods.get_method("dppf") is methods.get_method("simple_avg")
    assert methods.get_method("grawa") is methods.get_method("mgrawa")
    # tree-capable methods (what consensus.METHODS exposes) exclude the
    # flat-only lpf_sgd but include the two other new methods
    assert consensus.METHODS == ("simple_avg", "hard", "easgd", "lsgd",
                                 "mgrawa", "ddp", "parle", "entropy_sgd")
    with pytest.raises(ValueError, match="unknown consensus method"):
        methods.get_method("nope")


def test_methodspec_contract_validation():
    with pytest.raises(ValueError, match="aux_pull"):
        methods.MethodSpec(name="x", doc="", aux_pull=0.5)
    with pytest.raises(ValueError, match="center_beta"):
        methods.MethodSpec(name="x", doc="", aux_rows=1, aux_pull=1.0,
                           center_beta=1.5)
    with pytest.raises(ValueError, match="push_source"):
        methods.MethodSpec(name="x", doc="", push_source="telepathy")
    with pytest.raises(ValueError, match="filter_mu"):
        methods.MethodSpec(name="x", doc="", push_source="filtered_grad",
                           filter_mu=1.0)
    with pytest.raises(ValueError, match="requires engine='flat'"):
        DPPFConfig(consensus="lpf_sgd", engine="tree")
    with pytest.raises(ValueError, match="unknown consensus method"):
        DPPFConfig(consensus="sgd")


# ---------------------------------------------------------------------------
# registry-vs-legacy bit parity (the tentpole pin)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", LEGACY_METHODS + ("ddp",))
@pytest.mark.parametrize("variant", ["fused", "push", "no_push", "exact",
                                     "leader"])
@pytest.mark.parametrize("masked", [False, True])
def test_registry_matches_legacy_lowering_bitwise(method, variant, masked):
    eng = _engine(method)
    M = eng.layout.M
    losses = jnp.asarray([3.0, 1.0, 2.0, 4.0, 0.5, 2.5])
    gns = jnp.asarray([1.0, 2.0, 0.5, 1.0, 4.0, 0.25])
    kw = dict(push=variant != "no_push",
              exact_second_term=variant == "exact")
    push_from = "leader" if variant == "leader" else "average"
    mask = jnp.asarray([1.0, 0.0, 1.0, 1.0, 0.0, 1.0]) if masked else None
    if masked and variant == "exact":
        dcfg = DPPFConfig(alpha=0.3, lam=0.4, consensus=method,
                          engine="flat", **kw)
        for fn in (_legacy_lower_stages, consensus.lower_stages):
            if method == "ddp":
                continue        # empty stage list, nothing to gate
            with pytest.raises(ValueError, match="elastic mask"):
                fn(eng, dcfg, 0.25, losses=losses, grad_norms=gns,
                   push_from=push_from, mask=mask)
        return
    dcfg = DPPFConfig(alpha=0.3, lam=0.4, consensus=method, engine="flat",
                      **kw)
    want, alpha_l = _legacy_lower_stages(
        eng, dcfg, 0.25, losses=losses, grad_norms=gns,
        push_from=push_from, mask=mask)
    got, alpha_n = consensus.lower_stages(
        eng, dcfg, 0.25, losses=losses, grad_norms=gns,
        push_from=push_from, mask=mask)
    assert float(alpha_l) == float(alpha_n)
    _assert_stages_bitwise(got, want)


# ---------------------------------------------------------------------------
# hypothesis property: coefficient stages stay row-stochastic under
# arbitrary participation masks
# ---------------------------------------------------------------------------

@settings(max_examples=24, deadline=None)
@given(method=st.sampled_from([m for m in consensus.METHODS
                               if m != "ddp"]),
       mask_bits=st.integers(min_value=1, max_value=62),
       alpha=st.floats(min_value=0.01, max_value=1.0),
       seed=st.integers(min_value=0, max_value=2 ** 16))
def test_coef_stages_row_stochastic_under_masks(method, mask_bits, alpha,
                                                seed):
    """Every registered method's target-weight matrix T is row-stochastic
    (rows sum to 1 — a mixing stage moves rows toward convex combinations),
    its masked renormalization puts zero weight on inactive rows, and the
    coefficient gate zeroes inactive pull/push entries."""
    eng = _engine(method)
    M, R = eng.layout.M, eng.layout.R
    mask = jnp.asarray([(mask_bits >> i) & 1 for i in range(M)],
                       jnp.float32)
    if float(mask.sum()) == 0:
        mask = mask.at[0].set(1.0)
    key = jax.random.PRNGKey(seed)
    losses = jax.random.uniform(key, (M,), minval=0.1, maxval=5.0)
    gns = jax.random.uniform(jax.random.fold_in(key, 1), (M,),
                             minval=0.1, maxval=5.0)
    dcfg = DPPFConfig(alpha=float(alpha), lam=0.4, consensus=method,
                      engine="flat")
    stages, _ = consensus.lower_stages(eng, dcfg, 0.25, losses=losses,
                                       grad_norms=gns, mask=mask)
    act = np.asarray(mask)
    for kind, T, c0, c1 in stages:
        assert kind == "coef"
        T = np.asarray(T, np.float32)
        np.testing.assert_allclose(T.sum(axis=1), np.ones(R), atol=1e-5)
        # no target weight on inactive worker rows
        assert np.abs(T[:, :M] * (1.0 - act)).max() < 1e-6
        # inactive rows neither pull nor push
        for c in (np.asarray(c0), np.asarray(c1)):
            assert np.abs(c[:M] * (1.0 - act)).max() == 0.0


# ---------------------------------------------------------------------------
# the three new methods: staleness_k + checkpoint resume
# ---------------------------------------------------------------------------

def _mlp_setup():
    from benchmarks.common import mlp_init, mlp_loss
    dim, ncls, width, M, tau = 10, 3, 6, 4, 3
    opt = make_optimizer("sgd", momentum=0.9)
    p0 = lambda k: mlp_init(k, dim, ncls, width)

    def batches(seed):
        k = jax.random.PRNGKey(seed)
        return {"x": jax.random.normal(k, (tau, M, 6, dim)),
                "y": jax.random.randint(jax.random.fold_in(k, 1),
                                        (tau, M, 6), 0, ncls)}
    return mlp_loss, opt, p0, batches, M, tau


@pytest.mark.parametrize("method", ["parle", "lpf_sgd", "entropy_sgd"])
def test_new_methods_staleness_k_checkpoint_resume(method, tmp_path):
    """Each new method trains under the deepest overlap mode and survives
    a mid-pipeline checkpoint round trip: save after round 2, reload into
    a FRESH init (params, optimizer, snapshot ring, and method aux state
    like the LPF g_ema all restored), continue, and land bit-exactly on
    the uninterrupted trajectory."""
    loss, opt, p0, batches, M, tau = _mlp_setup()
    dcfg = DPPFConfig(alpha=0.2, lam=0.3, tau=tau, consensus=method,
                      engine="flat", overlap="staleness_k", staleness=2,
                      overlap_chunks=2, lam_schedule="fixed")
    step = jax.jit(make_round_step(loss, opt, dcfg, base_lr=0.05,
                                   total_steps=tau * 6))
    key = jax.random.PRNGKey(0)

    st_a = init_train_state(p0, opt, dcfg, M, key)
    for r in range(6):
        st_a, m_a = step(st_a, batches(r))

    st_b = init_train_state(p0, opt, dcfg, M, key)
    for r in range(3):
        st_b, _ = step(st_b, batches(r))
    path = str(tmp_path / f"{method}.state.npz")
    save_train_state(path, st_b)
    st_c = load_train_state(path, init_train_state(p0, opt, dcfg, M, key))
    if method == "lpf_sgd":
        assert "g_ema" in st_c.cstate
        assert float(jnp.abs(st_c.cstate["g_ema"]).sum()) > 0
    for r in range(3, 6):
        st_c, m_c = step(st_c, batches(r))
    assert np.array_equal(np.asarray(st_a.params), np.asarray(st_c.params))
    assert float(m_a["train_loss"]) == float(m_c["train_loss"])


def test_parle_center_and_ramp():
    """Parle keeps an EASGD-style center aux row (beta=0.5) and ramps its
    replica coupling with the lam schedule instead of pushing."""
    spec = methods.get_method("parle")
    assert spec.aux_rows == 1 and spec.center_beta == 0.5
    assert spec.pull_ramp and not spec.pushes
    eng = _engine("parle")
    dcfg = DPPFConfig(alpha=0.4, lam=0.5, consensus="parle", engine="flat")
    # at lam_t = lam/2 the coupling ramp halves the pull coefficient
    stages, pull = consensus.lower_stages(eng, dcfg, 0.25)
    assert len(stages) == 1          # no push stage
    np.testing.assert_allclose(float(pull), 0.4 * 0.5)
    c0 = np.asarray(stages[0][2])
    np.testing.assert_allclose(c0[:eng.layout.M], 0.2, atol=1e-6)
    assert c0[-1] == 1.0             # center row adopts its target exactly


def test_entropy_sgd_inner_outer_plan():
    """Entropy-SGD splits each base round into inner_rounds sub-rounds;
    inner sub-rounds scale the pull by inner_pull (the local-entropy
    exploration phase), the closing outer sub-round restores full pull."""
    from repro.train.clock import RoundClock
    dcfg = DPPFConfig(tau=4, consensus="entropy_sgd", engine="flat")
    clock = RoundClock.from_config(dcfg, base_lr=0.1, total_steps=8)
    d = clock.describe()
    assert d["inner_rounds"] == 2 and d["inner_pull"] == 0.25
    scopes = [r["scope"] for r in d["plan"]]
    assert scopes == ["inner", "outer", "inner", "outer"]
    assert float(clock.pull_scale_at(0)) == 0.25
    assert float(clock.pull_scale_at(1)) == 1.0
    # non-entropy methods keep the legacy single-phase plan untouched
    base = RoundClock.from_config(
        DPPFConfig(tau=4, consensus="simple_avg"), base_lr=0.1,
        total_steps=8)
    assert base.total_rounds == 2
    assert "inner_rounds" not in base.describe()
    assert base.pull_scale_at(0) == 1.0


def test_lpf_sgd_filtered_push_moves_along_ema():
    """The LPF-SGD vec stage pushes along the NORMALIZED filtered
    gradient: row i moves by -lam_t * g_i / ||g|| and the EMA field is
    carried in cstate (not an aux row)."""
    eng = _engine("lpf_sgd")
    M, n = eng.layout.M, eng.layout.n
    assert methods.get_method("lpf_sgd").aux_rows == 0
    dcfg = DPPFConfig(alpha=0.0, lam=0.5, consensus="lpf_sgd",
                      engine="flat", push=True)
    key = jax.random.PRNGKey(5)
    flat = jax.random.normal(key, (M, n))
    g = jax.random.normal(jax.random.fold_in(key, 1), (M, n))
    new, _, _ = consensus.apply_round(
        flat, dcfg, 0.25, {"g_ema": g}, engine=eng, push_vec=g)
    r = jnp.sqrt(jnp.sum(g.astype(jnp.float32) ** 2, axis=1))
    want = flat - 0.25 * g / jnp.maximum(r, eng.eps)[:, None]
    np.testing.assert_allclose(np.asarray(new), np.asarray(want),
                               atol=1e-5)
    with pytest.raises(ValueError, match="push_vec"):
        consensus.apply_round(flat, dcfg, 0.25, {"g_ema": g}, engine=eng)


# ---------------------------------------------------------------------------
# 8-device sharded pins: flat 8x1 + hier 2x2x2, all overlap modes
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_methods_sharded_8dev_flat_and_hier():
    """On 8 forced host devices, the registry lowering's sharded
    trajectories (flat 8x1 and hierarchical 2x2x2 meshes) match the
    single-device trace for the legacy AND the new methods across
    exact / staleness1 / doublebuf / staleness_k (precise engine,
    <= 1e-6). Together with the bit-identical stage lists pinned above,
    this pins registry-vs-legacy parity on both meshes."""
    body = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro.configs import DPPFConfig, MeshPlan
from repro.train import (init_train_state, make_round_step,
                         make_sharded_round_step, shard_train_state)
from repro.optim import make_optimizer
from benchmarks.common import mlp_init, mlp_loss
from repro.launch.mesh import make_hier_engine_mesh

dim, ncls, width, M, tau = 12, 3, 6, 8, 3
key = jax.random.PRNGKey(0)
opt = make_optimizer("sgd", momentum=0.9)
p0 = lambda k: mlp_init(k, dim, ncls, width)
def batches(seed):
    k = jax.random.PRNGKey(seed)
    return {"x": jax.random.normal(k, (tau, M, 6, dim)),
            "y": jax.random.randint(jax.random.fold_in(k, 1),
                                    (tau, M, 6), 0, ncls)}

fmesh = Mesh(np.asarray(jax.devices()).reshape(8, 1), ("data", "model"))
fplan = MeshPlan(worker_axes=("data",), model_axes=("model",))
hmesh, hplan = make_hier_engine_mesh(2, 2, 2)

def run(dcfg, mesh=None, plan=None, rounds=3):
    st = init_train_state(p0, opt, dcfg, M, key)
    st = dataclasses.replace(
        st, engine=dataclasses.replace(st.engine, precise=True))
    if mesh is not None:
        st = shard_train_state(st, mesh, plan, dcfg=dcfg)
        fn = jax.jit(make_sharded_round_step(
            mlp_loss, opt, dcfg, mesh=mesh, plan=plan, base_lr=0.05,
            total_steps=30))
    else:
        fn = jax.jit(make_round_step(mlp_loss, opt, dcfg, base_lr=0.05,
                                     total_steps=30))
    for r in range(rounds):
        st, m = fn(st, batches(r))
    return st

OVERLAPS = (("none", {}), ("staleness1", {}),
            ("doublebuf", dict(overlap_chunks=2)),
            ("staleness_k", dict(staleness=2, overlap_chunks=2)))
for method in ("simple_avg", "hard", "easgd", "lsgd", "mgrawa",
               "parle", "lpf_sgd", "entropy_sgd"):
    for overlap, extra in OVERLAPS:
        if method == "hard" and extra:
            # hard's pull fully collapses the fleet, so its push sits at
            # the documented Gram noise floor (engine docstring); chunked
            # overlap changes the Gram summation order and the floor
            # noise amplifies chaotically. Pre-existing behavior — the
            # sharded-vs-sharded doublebuf pins in test_sharded_round.py
            # compare identical chunkings instead.
            continue
        base = dict(alpha=0.2, lam=0.4, tau=tau, consensus=method,
                    engine="flat", lam_schedule="fixed", overlap=overlap,
                    **extra)
        s_ref = run(DPPFConfig(**base))
        for mname, mesh, plan in (("flat8x1", fmesh, fplan),
                                  ("hier2x2x2", hmesh, hplan)):
            s_sh = run(DPPFConfig(**base), mesh, plan)
            dp = float(jnp.max(jnp.abs(s_ref.params - s_sh.params)))
            assert dp <= 1e-6, (method, overlap, mname, dp)
            if method == "lpf_sgd":
                dg = float(jnp.max(jnp.abs(
                    s_ref.cstate["g_ema"] - s_sh.cstate["g_ema"])))
                assert dg <= 1e-6, (method, overlap, mname, dg)
        print(method, overlap, "ok")
print("ALL OK")
"""
    env = dict(os.environ, PYTHONPATH=SRC + os.pathsep + ROOT)
    out = subprocess.run([sys.executable, "-c", body], capture_output=True,
                         text=True, env=env, timeout=560, cwd=ROOT)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "ALL OK" in out.stdout
