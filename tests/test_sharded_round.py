"""Sharded ConsensusEngine rounds: shard_map parity for every method,
staleness-1 overlap (two-buffer reference + convergence), split kernel
phases with the psum epilogue, and train-state checkpoint resume.

Multi-device lowering runs in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (same pattern as
test_launch_sharding.py); single-device tests exercise the identical code
path on a 1x1 mesh in-process."""
from __future__ import annotations

import dataclasses
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_train_state, save_train_state
from repro.configs import DPPFConfig, MeshPlan
from repro.core import consensus
from repro.optim import make_optimizer
from repro.train import (
    init_train_state, make_round_step, make_sharded_round_step,
    shard_train_state,
)
from repro.train.trainer import TrainState

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
ROOT = os.path.join(os.path.dirname(__file__), "..")


def _mlp_setup(M=4, tau=2, dim=16, ncls=4, width=8):
    from benchmarks.common import mlp_init, mlp_loss
    opt = make_optimizer("sgd", momentum=0.9)
    p0 = lambda k: mlp_init(k, dim, ncls, width)

    def batches(seed):
        k = jax.random.PRNGKey(seed)
        return {"x": jax.random.normal(k, (tau, M, 8, dim)),
                "y": jax.random.randint(jax.random.fold_in(k, 1),
                                        (tau, M, 8), 0, ncls)}
    return opt, p0, mlp_loss, batches


# ---------------------------------------------------------------------------
# staleness-1 overlap: config plumbing, reference parity, convergence
# ---------------------------------------------------------------------------

def test_overlap_requires_flat_engine():
    with pytest.raises(ValueError, match="staleness1"):
        DPPFConfig(engine="tree", overlap="staleness1")
    with pytest.raises(ValueError, match="doublebuf"):
        DPPFConfig(engine="tree", overlap="doublebuf")
    with pytest.raises(ValueError, match="bogus"):
        DPPFConfig(overlap="bogus")
    with pytest.raises(ValueError, match="overlap_chunks"):
        DPPFConfig(engine="flat", overlap="doublebuf", overlap_chunks=0)
    # ddp never builds a flat engine -> the snapshot has nowhere to live
    opt, p0, loss, _ = _mlp_setup()
    for mode in ("staleness1", "doublebuf"):
        dcfg = DPPFConfig(engine="flat", overlap=mode, consensus="ddp")
        with pytest.raises(ValueError, match=mode):
            init_train_state(p0, opt, dcfg, 4, jax.random.PRNGKey(0))


@pytest.mark.parametrize("method", ["simple_avg", "easgd"])
def test_overlap_matches_two_buffer_reference(method):
    """The fused staleness-1 round must equal the explicit two-buffer
    scheme: x_{k+1} = q_k + (C(s_k) - s_k), s_{k+1} = q_k, with q from a
    pure-local-steps (identity-consensus) round and C the exact engine
    consensus of the snapshot."""
    M, tau = 4, 2
    opt, p0, loss, batches = _mlp_setup(M=M, tau=tau)
    dcfg = DPPFConfig(alpha=0.2, lam=0.4, tau=tau, consensus=method,
                      engine="flat", overlap="staleness1",
                      lam_schedule="fixed")
    key = jax.random.PRNGKey(0)

    st = init_train_state(p0, opt, dcfg, M, key)
    eng = st.engine
    assert st.snap is not None and st.snap["x"].shape == st.params.shape
    step = jax.jit(make_round_step(loss, opt, dcfg, base_lr=0.05,
                                   total_steps=20))

    # reference: local steps via an identity-consensus (ddp) round on the
    # same engine, stale consensus applied by hand
    dcfg_local = dataclasses.replace(dcfg, consensus="ddp", overlap="none")
    local_only = jax.jit(make_round_step(loss, opt, dcfg_local, base_lr=0.05,
                                         total_steps=20))
    st_ref = TrainState(params=st.params + 0.0,
                        opt=jax.tree.map(jnp.copy, st.opt),
                        cstate={}, t=st.t, engine=eng)
    snap = st.params + 0.0
    cstate = {}
    for r in range(4):
        b = batches(r)
        st, m = step(st, b)
        st_ref, _ = local_only(st_ref, b)
        q = st_ref.params
        c_out, cstate, _ = consensus.apply_round(
            snap, dcfg, float(m["lam_t"]), cstate, engine=eng)
        # round 0 is the explicit pipeline bubble (no delta applied)
        st_ref = dataclasses.replace(
            st_ref, params=q + (c_out - snap) if r > 0 else q)
        snap = q
        np.testing.assert_allclose(np.asarray(st.params),
                                   np.asarray(st_ref.params),
                                   atol=1e-5, rtol=1e-5, err_msg=f"round {r}")
        np.testing.assert_allclose(np.asarray(st.snap["x"]),
                                   np.asarray(snap), atol=1e-5, rtol=1e-5)


def test_overlap_round0_is_local_steps_only():
    """Round 0 is the explicit pipeline bubble: zero consensus delta, so
    params match a pure-local-step round (up to XLA fusion ulps — the two
    jit programs schedule the scan differently)."""
    M, tau = 4, 2
    opt, p0, loss, batches = _mlp_setup(M=M, tau=tau)
    base = dict(alpha=0.2, lam=0.4, tau=tau, engine="flat")
    key = jax.random.PRNGKey(3)
    st_o = init_train_state(p0, opt, DPPFConfig(overlap="staleness1", **base),
                            M, key)
    eng = st_o.engine
    st_l = TrainState(params=st_o.params + 0.0,
                      opt=jax.tree.map(jnp.copy, st_o.opt), cstate={},
                      t=st_o.t, engine=eng)
    b = batches(0)
    st_o, _ = jax.jit(make_round_step(
        loss, opt, DPPFConfig(overlap="staleness1", **base),
        base_lr=0.05, total_steps=20))(st_o, b)
    st_l, _ = jax.jit(make_round_step(
        loss, opt, DPPFConfig(consensus="ddp", **base),
        base_lr=0.05, total_steps=20))(st_l, b)
    np.testing.assert_allclose(np.asarray(st_o.params),
                               np.asarray(st_l.params), atol=1e-7, rtol=0)


# ---------------------------------------------------------------------------
# double-buffered overlap: bit-parity with staleness1, chunked numerics,
# the round-0 exact-consensus bubble, and the two-buffer reference
# ---------------------------------------------------------------------------

def _warm_pair(dcfg_s1, dcfg_db, M, tau, key, *, precise=True):
    """Two identical warm states (one staleness1 round from init — bit-
    identical under both modes by the acceptance bar) plus the two step
    fns, ready to diverge modes from round 1 on."""
    opt, p0, loss, batches = _mlp_setup(M=M, tau=tau)
    st = init_train_state(p0, opt, dcfg_s1, M, key)
    if precise:
        st = dataclasses.replace(
            st, engine=dataclasses.replace(st.engine, precise=True))
    st2 = dataclasses.replace(
        st, params=st.params + 0.0, opt=jax.tree.map(jnp.copy, st.opt),
        snap=jax.tree.map(jnp.copy, st.snap))
    f1 = jax.jit(make_round_step(loss, opt, dcfg_s1, base_lr=0.05,
                                 total_steps=40))
    f2 = jax.jit(make_round_step(loss, opt, dcfg_db, base_lr=0.05,
                                 total_steps=40))
    b0 = batches(0)
    st, _ = f1(st, b0)
    st2, _ = f1(st2, b0)
    return st, st2, f1, f2, batches


@pytest.mark.parametrize("method", ["simple_avg", "hard", "easgd", "lsgd",
                                    "mgrawa"])
def test_doublebuf_chunks1_bitwise_equals_staleness1(method):
    """The correctness bar: doublebuf with ONE chunk runs the identical
    ops as staleness1 (same gather values, same single Gram psum, same
    stage math) — bit-for-bit in precise mode, metrics included, for
    every consensus method (ddp carries no overlap snapshot at all).
    Warm states (t > 0): round 0 differs by design — staleness1 skips
    its bubble, doublebuf fills the pipeline with an exact consensus
    (test below)."""
    M, tau = 4, 4
    base = dict(alpha=0.2, lam=0.4, tau=tau, consensus=method,
                engine="flat", lam_schedule="fixed")
    dcfg_s1 = DPPFConfig(overlap="staleness1", **base)
    dcfg_db = DPPFConfig(overlap="doublebuf", overlap_chunks=1, **base)
    st1, st2, f1, f2, batches = _warm_pair(dcfg_s1, dcfg_db, M, tau,
                                           jax.random.PRNGKey(0))
    for r in range(1, 4):
        b = batches(r)
        st1, m1 = f1(st1, b)
        st2, m2 = f2(st2, b)
    np.testing.assert_array_equal(np.asarray(st1.params),
                                  np.asarray(st2.params))
    np.testing.assert_array_equal(np.asarray(st1.snap["x"]),
                                  np.asarray(st2.snap["x"]))
    for k in m1:
        assert abs(float(m1[k]) - float(m2[k])) == 0.0, k


def test_doublebuf_chunked_gram_within_fp32_bounds():
    """The chunked-psum numerics contract (DESIGN.md §Overlap): splitting
    the stage-1 contraction into chunks only reorders fp32 reductions.
    Pinned at two levels: the summed per-chunk ``stage_comm`` matches the
    unchunked contraction to fp32 reduction-order tolerance in every
    engine mode, and a training trajectory stays close (NOT bit-identical
    — the unit-normed push amplifies ulps across rounds)."""
    from repro.core.engine import ConsensusEngine
    key = jax.random.PRNGKey(1)
    stacked = {"w": jax.random.normal(key, (4, 1000)) * 2.0 + 1.0}
    T = jax.nn.softmax(jax.random.normal(jax.random.fold_in(key, 1), (4, 4)))
    for patch in ({}, {"precise": True},
                  {"use_kernel": True, "interpret": True, "block_cols": 64}):
        eng = ConsensusEngine.from_stacked(stacked, use_kernel=False,
                                           interpret=True)
        eng = dataclasses.replace(eng, **patch)
        flat = eng.flatten(stacked)
        whole = np.asarray(eng.stage_comm(flat, T))
        for k in (2, 4, 7):
            bounds, a = [], 0
            for i in range(k):
                b = a + 1000 // k + (1 if i < 1000 % k else 0)
                bounds.append((a, b))
                a = b
            chunked = sum(np.asarray(eng.stage_comm(flat[:, s:e], T))
                          for s, e in bounds)
            scale = max(abs(whole).max(), 1.0)
            assert abs(chunked - whole).max() <= 1e-5 * scale, (patch, k)

    # trajectory: reduction-order ulps amplify through the unit-normed
    # push but stay small over a short run
    M, tau = 4, 4
    base = dict(alpha=0.2, lam=0.4, tau=tau, engine="flat",
                lam_schedule="fixed", overlap="doublebuf")
    d1 = DPPFConfig(overlap_chunks=1, **base)
    d4 = DPPFConfig(overlap_chunks=4, **base)
    opt, p0, loss, batches = _mlp_setup(M=M, tau=tau)
    st1 = init_train_state(p0, opt, d1, M, key)
    st4 = init_train_state(p0, opt, d4, M, key)
    f1 = jax.jit(make_round_step(loss, opt, d1, base_lr=0.05,
                                 total_steps=40))
    f4 = jax.jit(make_round_step(loss, opt, d4, base_lr=0.05,
                                 total_steps=40))
    for r in range(4):
        b = batches(r)
        st1, m1 = f1(st1, b)
        st4, m4 = f4(st4, b)
    np.testing.assert_allclose(np.asarray(st1.params),
                               np.asarray(st4.params), atol=2e-4, rtol=1e-3)
    for k in ("consensus_dist", "pre_dist", "train_loss"):
        np.testing.assert_allclose(float(m1[k]), float(m4[k]), rtol=1e-3,
                                   atol=1e-4)


def test_doublebuf_round0_bubble_is_exact_consensus():
    """The round-0 pipeline bubble under doublebuf APPLIES an exact
    consensus of the fresh post-scan view — it is not a skipped round.
    Per-worker inits make the consensus delta unambiguously nonzero."""
    M, tau = 4, 2
    opt, p0, loss, batches = _mlp_setup(M=M, tau=tau)
    key = jax.random.PRNGKey(3)
    base = dict(alpha=0.2, lam=0.4, tau=tau, engine="flat",
                lam_schedule="fixed")
    d_db = DPPFConfig(overlap="doublebuf", overlap_chunks=3, **base)
    d_ex = DPPFConfig(**base)
    d_s1 = DPPFConfig(overlap="staleness1", **base)
    st_db = init_train_state(p0, opt, d_db, M, key, same_init=False)
    st_ex = TrainState(params=st_db.params + 0.0,
                       opt=jax.tree.map(jnp.copy, st_db.opt), cstate={},
                       t=st_db.t, round=st_db.round, engine=st_db.engine)
    st_s1 = dataclasses.replace(
        st_db, params=st_db.params + 0.0,
        opt=jax.tree.map(jnp.copy, st_db.opt),
        snap=jax.tree.map(jnp.copy, st_db.snap))
    b = batches(0)
    st_db, m_db = jax.jit(make_round_step(loss, opt, d_db, base_lr=0.05,
                                          total_steps=20))(st_db, b)
    st_ex, _ = jax.jit(make_round_step(loss, opt, d_ex, base_lr=0.05,
                                       total_steps=20))(st_ex, b)
    st_s1, m_s1 = jax.jit(make_round_step(loss, opt, d_s1, base_lr=0.05,
                                          total_steps=20))(st_s1, b)
    # bubble == the exact round (up to cross-program fusion ulps)
    np.testing.assert_allclose(np.asarray(st_db.params),
                               np.asarray(st_ex.params), atol=1e-6, rtol=0)
    # ... and NOT the staleness1 skip (the consensus really applied)
    assert float(jnp.max(jnp.abs(st_db.params - st_s1.params))) > 1e-3
    # the staleness depth marks the bubble from the steady state
    assert float(m_db["staleness"]) == 0.0 and float(m_s1["staleness"]) == 0.0
    st_db, m_db = jax.jit(make_round_step(loss, opt, d_db, base_lr=0.05,
                                          total_steps=20))(st_db, batches(1))
    assert float(m_db["staleness"]) == 1.0


def test_doublebuf_matches_two_buffer_reference():
    """The doublebuf recursion against the explicit reference:
    x_1 = C(q_0) (exact bubble), then x_{k+1} = q_k + (C(s_k) - s_k) with
    s_{k+1} = q_k — the same two-buffer scheme as staleness1 with the
    bubble filled by an exact consensus instead of a skip."""
    M, tau = 4, 2
    opt, p0, loss, batches = _mlp_setup(M=M, tau=tau)
    dcfg = DPPFConfig(alpha=0.2, lam=0.4, tau=tau, consensus="easgd",
                      engine="flat", overlap="doublebuf", overlap_chunks=1,
                      lam_schedule="fixed")
    key = jax.random.PRNGKey(0)
    st = init_train_state(p0, opt, dcfg, M, key)
    eng = st.engine
    step = jax.jit(make_round_step(loss, opt, dcfg, base_lr=0.05,
                                   total_steps=20))
    dcfg_local = dataclasses.replace(dcfg, consensus="ddp", overlap="none")
    local_only = jax.jit(make_round_step(loss, opt, dcfg_local,
                                         base_lr=0.05, total_steps=20))
    st_ref = TrainState(params=st.params + 0.0,
                        opt=jax.tree.map(jnp.copy, st.opt),
                        cstate={}, t=st.t, engine=eng)
    snap = st.params + 0.0
    for r in range(4):
        b = batches(r)
        st, m = step(st, b)
        st_ref, _ = local_only(st_ref, b)
        q = st_ref.params
        if r == 0:
            new, _, _ = consensus.apply_round(
                q, dcfg, float(m["lam_t"]), {}, engine=eng)
        else:
            c_out, _, _ = consensus.apply_round(
                snap, dcfg, float(m["lam_t"]), {}, engine=eng)
            new = q + (c_out - snap)
        st_ref = dataclasses.replace(st_ref, params=new)
        snap = q
        np.testing.assert_allclose(np.asarray(st.params),
                                   np.asarray(st_ref.params),
                                   atol=1e-5, rtol=1e-5, err_msg=f"round {r}")
        np.testing.assert_allclose(np.asarray(st.snap["x"]),
                                   np.asarray(snap), atol=1e-5, rtol=1e-5)


def test_doublebuf_converges_close_to_exact():
    from benchmarks.common import default_data, run_distributed
    data = default_data()
    base = DPPFConfig(alpha=0.2, lam=0.8, tau=4, engine="flat",
                      lam_schedule="fixed")
    r_exact = run_distributed(data, base, M=4, steps=200)
    r_db = run_distributed(
        data, dataclasses.replace(base, overlap="doublebuf"), M=4,
        steps=200)
    assert np.isfinite(r_db.test_err)
    assert abs(r_db.test_err - r_exact.test_err) < 10.0
    assert np.isfinite(r_db.consensus_dist)


def test_overlap_converges_close_to_exact():
    from benchmarks.common import default_data, run_distributed
    data = default_data()
    base = DPPFConfig(alpha=0.2, lam=0.8, tau=4, engine="flat",
                      lam_schedule="fixed")
    r_exact = run_distributed(data, base, M=4, steps=200)
    r_stale = run_distributed(
        data, dataclasses.replace(base, overlap="staleness1"), M=4,
        steps=200)
    assert np.isfinite(r_stale.test_err)
    # staleness-1 shifts forces by one round; end-task quality must hold
    assert abs(r_stale.test_err - r_exact.test_err) < 10.0
    assert np.isfinite(r_stale.consensus_dist)


# ---------------------------------------------------------------------------
# split kernel phases: partial Grams add across column shards
# ---------------------------------------------------------------------------

def test_partial_gram_plus_mix_match_fused_round():
    from repro.kernels.pullpush import (
        fused_round, fused_round_ref, mix_shard, partial_gram,
    )
    key = jax.random.PRNGKey(1)
    R, n = 5, 1000
    flat = jax.random.normal(key, (R, n)) * 2.0 + 1.0
    T = jax.nn.softmax(jax.random.normal(jax.random.fold_in(key, 1), (R, R)))
    c0 = jnp.linspace(0.1, 0.5, R)
    c1 = jnp.linspace(-0.4, -0.1, R)
    want, r_want = fused_round_ref(flat, T, c0, c1)
    got_fused, r_fused, _ = fused_round(flat, T, c0, c1, block_cols=256)

    # simulate 4 column shards: psum == plain sum of the partial Grams
    shards = jnp.split(flat, 4, axis=1)
    G = sum(partial_gram(s, block_cols=256) for s in shards)
    V = jnp.eye(R) - T
    r = jnp.sqrt(jnp.maximum(jnp.sum((V @ G) * V, axis=1), 0.0))
    coef = c0 + c1 / jnp.maximum(r, 1e-12)
    out = jnp.concatenate(
        [mix_shard(s, T, coef, block_cols=256) for s in shards], axis=1)
    np.testing.assert_allclose(np.asarray(r), np.asarray(r_want), rtol=1e-4)
    np.testing.assert_allclose(np.asarray(r), np.asarray(r_fused), rtol=1e-4)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(out), np.asarray(got_fused),
                               rtol=1e-5, atol=1e-5)


def test_partial_gram_centered_cancellation_safe():
    """Per-shard block-centering must survive the cross-shard sum: workers
    clustered far from the origin keep ~1e-5 relative distance accuracy."""
    from repro.kernels.pullpush import partial_gram
    key = jax.random.PRNGKey(2)
    n, M = 4096, 4
    base = jax.random.normal(key, (n,)) * 3.0 + 5.0
    flat = base[None] + 0.05 * jax.random.normal(
        jax.random.fold_in(key, 1), (M, n))
    G = sum(partial_gram(s, block_cols=512) for s in jnp.split(flat, 2, 1))
    T = jnp.full((M, M), 1.0 / M)
    V = jnp.eye(M) - T
    r = np.sqrt(np.maximum(np.asarray(jnp.sum((V @ G) * V, axis=1)), 0.0))
    f64 = np.asarray(flat, np.float64)
    r_true = np.sqrt(((f64 - f64.mean(0)) ** 2).sum(1))
    np.testing.assert_allclose(r, r_true, rtol=1e-5)


# ---------------------------------------------------------------------------
# sharded round on a 1x1 mesh (same program, trivial collectives)
# ---------------------------------------------------------------------------

def test_sharded_round_single_device_mesh_matches_plain():
    from repro.launch.mesh import make_cpu_mesh
    M, tau = 4, 2
    opt, p0, loss, batches = _mlp_setup(M=M, tau=tau)
    mesh = make_cpu_mesh()
    plan = MeshPlan(worker_axes=("data",), model_axes=("model",))
    dcfg = DPPFConfig(alpha=0.2, lam=0.4, tau=tau, engine="flat")
    key = jax.random.PRNGKey(0)
    st1 = init_train_state(p0, opt, dcfg, M, key)
    st2 = shard_train_state(init_train_state(p0, opt, dcfg, M, key),
                            mesh, plan)
    f1 = jax.jit(make_round_step(loss, opt, dcfg, base_lr=0.05,
                                 total_steps=20))
    f2 = jax.jit(make_sharded_round_step(loss, opt, dcfg, mesh=mesh,
                                         plan=plan, base_lr=0.05,
                                         total_steps=20))
    for r in range(2):
        st1, m1 = f1(st1, batches(r))
        st2, m2 = f2(st2, batches(r))
    np.testing.assert_allclose(np.asarray(st1.params), np.asarray(st2.params),
                               atol=1e-6, rtol=1e-6)
    for k in ("consensus_dist", "pre_dist", "train_loss"):
        np.testing.assert_allclose(float(m1[k]), float(m2[k]), rtol=1e-5,
                                   atol=1e-6)


def test_sharded_round_multi_axis_worker_group_and_tree_rejection():
    import numpy as onp
    from jax.sharding import Mesh
    M, tau = 3, 2
    opt, p0, loss, batches = _mlp_setup(M=M, tau=tau)
    dcfg = DPPFConfig(alpha=0.2, lam=0.4, tau=tau, engine="flat")
    st = init_train_state(p0, opt, dcfg, M, jax.random.PRNGKey(0))
    devs = onp.asarray(jax.devices()[:1]).reshape(1, 1)
    mesh = Mesh(devs, ("data", "model"))
    # a multi-axis worker group (size 1x1 here) must plumb through
    plan = MeshPlan(worker_axes=("data", "model"), model_axes=())
    step = make_sharded_round_step(loss, opt, dcfg, mesh=mesh, plan=plan,
                                   base_lr=0.05, total_steps=20)
    st, _ = jax.jit(step)(st, batches(0))
    assert st.params.shape == (M, st.engine.layout.n)
    # tree-engine state must be rejected outright
    dcfg_tree = DPPFConfig(alpha=0.2, lam=0.4, tau=tau, engine="tree")
    st_tree = init_train_state(p0, opt, dcfg_tree, M, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="flat"):
        make_sharded_round_step(loss, opt, dcfg_tree, mesh=mesh, plan=plan,
                                base_lr=0.05, total_steps=20)(
                                    st_tree, batches(0))


# ---------------------------------------------------------------------------
# hierarchical mesh: builders + 3-axis round on a 1x1x1 mesh
# ---------------------------------------------------------------------------

def test_make_hier_engine_mesh_validates_device_count():
    from repro.launch.mesh import make_hier_engine_mesh
    ndev = len(jax.devices())
    with pytest.raises(ValueError, match="host has"):
        make_hier_engine_mesh(ndev + 1, 2, 2)
    with pytest.raises(ValueError, match=">= 1"):
        make_hier_engine_mesh(0, 1, 1)


def test_hier_round_1x1x1_matches_plain():
    """The 3-axis plan (worker rows on data, columns on fsdp x model) runs
    the identical program on a trivial 1x1x1 mesh — parity with the plain
    single-shard round, aux row (easgd) included."""
    from repro.launch.mesh import make_hier_engine_mesh
    M, tau = 4, 2
    opt, p0, loss, batches = _mlp_setup(M=M, tau=tau)
    mesh, plan = make_hier_engine_mesh(1, 1, 1)
    assert plan.fsdp_axes == ("fsdp",)
    dcfg = DPPFConfig(alpha=0.2, lam=0.4, tau=tau, consensus="easgd",
                      engine="flat")
    key = jax.random.PRNGKey(0)
    st1 = init_train_state(p0, opt, dcfg, M, key)
    st2 = shard_train_state(init_train_state(p0, opt, dcfg, M, key),
                            mesh, plan)
    f1 = jax.jit(make_round_step(loss, opt, dcfg, base_lr=0.05,
                                 total_steps=20))
    f2 = jax.jit(make_sharded_round_step(loss, opt, dcfg, mesh=mesh,
                                         plan=plan, base_lr=0.05,
                                         total_steps=20))
    for r in range(2):
        st1, m1 = f1(st1, batches(r))
        st2, m2 = f2(st2, batches(r))
    np.testing.assert_allclose(np.asarray(st1.params), np.asarray(st2.params),
                               atol=1e-6, rtol=1e-6)
    for k in ("consensus_dist", "pre_dist", "train_loss"):
        np.testing.assert_allclose(float(m1[k]), float(m2[k]), rtol=1e-5,
                                   atol=1e-6)


def test_flat_col_axes_subgroup_fallback():
    """The shared column rule: full fsdp+model group when divisible, else
    the divisible sub-group, else replicated. Pure function of the mesh
    SHAPE — a stub mesh suffices (no devices needed)."""
    from types import SimpleNamespace
    from repro.launch.mesh import flat_col_axes, flat_col_entry
    from repro.configs import MeshPlan
    mesh = SimpleNamespace(shape={"data": 2, "fsdp": 2, "model": 3})
    plan = MeshPlan(worker_axes=("data",), fsdp_axes=("fsdp",),
                    model_axes=("model",))
    # n divisible by 6: the psum group spans both axes
    assert flat_col_axes(mesh, 12, plan) == ("fsdp", "model")
    assert flat_col_entry(mesh, 12, plan) == ("fsdp", "model")
    # n % 3 != 0 but n % 2 == 0: fsdp-only fallback
    assert flat_col_axes(mesh, 8, plan) == ("fsdp",)
    assert flat_col_entry(mesh, 8, plan) == "fsdp"
    # n % 2 != 0 but n % 3 == 0: model-only fallback
    assert flat_col_axes(mesh, 9, plan) == ("model",)
    # prime n: replicate
    assert flat_col_axes(mesh, 7, plan) == ()
    assert flat_col_entry(mesh, 7, plan) is None


# ---------------------------------------------------------------------------
# checkpoint: mid-run resume == straight-through
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("overlap", ["none", "staleness1", "doublebuf"])
def test_train_state_checkpoint_resume_matches_straight_run(tmp_path,
                                                            overlap):
    """Mid-run resume == straight-through for every overlap mode: the
    snapshot (the overlap's persistent comm buffer) round-trips through
    the checkpoint, so a doublebuf resume continues the stale recursion
    exactly — no re-bubble."""
    M, tau = 4, 2
    opt, p0, loss, batches = _mlp_setup(M=M, tau=tau)
    dcfg = DPPFConfig(alpha=0.2, lam=0.4, tau=tau, engine="flat",
                      overlap=overlap, overlap_chunks=2)
    key = jax.random.PRNGKey(0)
    step = jax.jit(make_round_step(loss, opt, dcfg, base_lr=0.05,
                                   total_steps=20), donate_argnums=0)

    straight = init_train_state(p0, opt, dcfg, M, key)
    resumed = init_train_state(p0, opt, dcfg, M, key)
    for r in range(2):
        straight, _ = step(straight, batches(r))
        resumed, _ = step(resumed, batches(r))
    path = str(tmp_path / "state.npz")
    save_train_state(path, resumed)

    # fresh template (same config/seed), restore, continue
    template = init_train_state(p0, opt, dcfg, M, key)
    resumed = load_train_state(path, template)
    assert int(resumed.t) == 2 * tau
    if overlap != "none":
        assert resumed.snap is not None
    for r in range(2, 4):
        straight, _ = step(straight, batches(r))
        resumed, _ = step(resumed, batches(r))
    np.testing.assert_array_equal(np.asarray(straight.params),
                                  np.asarray(resumed.params))
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), straight.opt, resumed.opt)


def test_load_train_state_format_guard_and_snap_fallback(tmp_path):
    from repro.checkpoint import save_pytree
    M, tau = 4, 2
    opt, p0, loss, batches = _mlp_setup(M=M, tau=tau)
    key = jax.random.PRNGKey(0)

    # a final-params (serving) checkpoint is a different format: clear error
    bad = str(tmp_path / "final.npz")
    save_pytree(bad, {"w": np.zeros((3, 3))})
    dcfg = DPPFConfig(alpha=0.2, lam=0.4, tau=tau, engine="flat")
    template = init_train_state(p0, opt, dcfg, M, key)
    with pytest.raises(ValueError, match="train-state"):
        load_train_state(bad, template)

    # a mid-run checkpoint saved WITHOUT a snapshot (exact mode) resumes
    # into an overlap run with the RESTORED params as warm-start snapshot
    # (not the init fleet — its stale delta would jolt trained params)
    exact_state = init_train_state(p0, opt, dcfg, M, key)
    step = jax.jit(make_round_step(loss, opt, dcfg, base_lr=0.05,
                                   total_steps=20))
    exact_state, _ = step(exact_state, batches(0))
    path = str(tmp_path / "exact.npz")
    save_train_state(path, exact_state)
    for mode in ("staleness1", "doublebuf"):
        dcfg_o = dataclasses.replace(dcfg, overlap=mode)
        tmpl_o = init_train_state(p0, opt, dcfg_o, M, key)
        resumed = load_train_state(path, tmpl_o)
        assert resumed.snap is not None and int(resumed.t) == tau
        np.testing.assert_array_equal(np.asarray(resumed.snap["x"]),
                                      np.asarray(exact_state.params))
        np.testing.assert_array_equal(np.asarray(resumed.params),
                                      np.asarray(exact_state.params))
        # resuming mid-overlap never re-bubbles: t > 0 keeps the stale
        # recursion live, seeded by the warm-start snapshot; the step fn
        # runs cleanly from here
        step_o = jax.jit(make_round_step(loss, opt, dcfg_o, base_lr=0.05,
                                         total_steps=20))
        cont, m = step_o(resumed, batches(1))
        assert float(m["staleness"]) == 1.0
        assert np.isfinite(float(m["consensus_dist"]))


# ---------------------------------------------------------------------------
# the real thing: 8 forced host devices, every method, both engine modes
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_sharded_parity_8dev_all_methods():
    """One shard_map round on a (4 workers x 2 columns) host mesh vs the
    single-device flat engine, for every consensus method: bit-for-bit in
    precise mode (ulp-level for lsgd's argmin tie-breaks), Gram-floor
    tolerance otherwise; kernel path and staleness-1 overlap included."""
    body = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses, json
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro.configs import DPPFConfig, MeshPlan
from repro.core import consensus
from repro.train import (init_train_state, make_round_step,
                         make_sharded_round_step, shard_train_state)
from repro.optim import make_optimizer
from benchmarks.common import mlp_init, mlp_loss

dim, ncls, width, M, tau = 16, 4, 8, 4, 2
key = jax.random.PRNGKey(0)
opt = make_optimizer("sgd", momentum=0.9)
p0 = lambda k: mlp_init(k, dim, ncls, width)
def batches(seed):
    k = jax.random.PRNGKey(seed)
    return {"x": jax.random.normal(k, (tau, M, 8, dim)),
            "y": jax.random.randint(jax.random.fold_in(k, 1),
                                    (tau, M, 8), 0, ncls)}
mesh = Mesh(np.asarray(jax.devices()).reshape(4, 2), ("data", "model"))
plan = MeshPlan(worker_axes=("data",), model_axes=("model",))
MKEYS = ("consensus_dist", "pre_dist", "pull_force", "push_force",
         "train_loss", "lam_t")

def run_pair(dcfg, engine_patch=None, rounds=2):
    st1 = init_train_state(p0, opt, dcfg, M, key)
    if st1.engine is None:  # ddp: reuse the simple_avg layout (aux = 0)
        st1 = init_train_state(
            p0, opt, dataclasses.replace(dcfg, consensus="simple_avg"),
            M, key)
    if engine_patch:
        st1 = dataclasses.replace(
            st1, engine=dataclasses.replace(st1.engine, **engine_patch))
    st2 = shard_train_state(st1, mesh, plan)
    f1 = jax.jit(make_round_step(mlp_loss, opt, dcfg, base_lr=0.05,
                                 total_steps=20))
    f2 = jax.jit(make_sharded_round_step(mlp_loss, opt, dcfg, mesh=mesh,
                                         plan=plan, base_lr=0.05,
                                         total_steps=20))
    for r in range(rounds):
        st1, m1 = f1(st1, batches(r))
        st2, m2 = f2(st2, batches(r))
    dp = float(jnp.max(jnp.abs(st1.params - st2.params)))
    dm = max(abs(float(m1[k]) - float(m2[k])) for k in MKEYS)
    return dp, dm

for method in consensus.METHODS:
    dcfg = DPPFConfig(alpha=0.2, lam=0.4, tau=tau, consensus=method,
                      engine="flat")
    dp, dm = run_pair(dcfg)
    assert dp < 2e-5 and dm < 1e-4, (method, "fast", dp, dm)
    dp, dm = run_pair(dcfg, engine_patch={"precise": True})
    # bit-for-bit up to reduction-order ulps in the lsgd argmin input
    assert dp <= 1e-7 and dm < 1e-6, (method, "precise", dp, dm)
print("parity OK")

# kernel path (interpret mode): split phases + psum epilogue under shard_map
dcfg = DPPFConfig(alpha=0.2, lam=0.4, tau=tau, engine="flat")
dp, dm = run_pair(dcfg, engine_patch={"use_kernel": True, "interpret": True,
                                      "block_cols": 64})
assert dp < 2e-5 and dm < 1e-4, ("kernel", dp, dm)
print("kernel OK")

# staleness-1 overlap: sharded == single-device (precise engine)
dcfg = DPPFConfig(alpha=0.2, lam=0.4, tau=tau, engine="flat",
                  overlap="staleness1", lam_schedule="fixed")
dp, dm = run_pair(dcfg, engine_patch={"precise": True}, rounds=3)
assert dp < 1e-6 and dm < 1e-5, ("overlap", dp, dm)
print("overlap OK")
print("ALL OK")
"""
    env = dict(os.environ, PYTHONPATH=SRC + os.pathsep + ROOT)
    out = subprocess.run([sys.executable, "-c", body], capture_output=True,
                         text=True, env=env, timeout=560, cwd=ROOT)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "ALL OK" in out.stdout


@pytest.mark.slow
def test_doublebuf_parity_8dev_flat_and_hier():
    """THE overlap acceptance leg (ISSUE 5): on 8 forced host devices,
    doublebuf with n_chunks=1 is bit-for-bit staleness1 in precise mode
    (<= 1e-7; exact-zero in practice) for every consensus method incl.
    the easgd aux row, on BOTH the flat 8x1 row-sharded mesh and the hier
    2x2x2 workers x fsdp x model mesh (where the mid-scan chunks really
    gather over the worker axis and psum over both column axes). Fast
    mode stays within the documented Gram-floor bounds with chunking
    (overlap_chunks=4), kernel path included. Warm states: round 0 runs
    under staleness1 for both trajectories (the doublebuf bubble is an
    exact consensus BY DESIGN and is pinned separately)."""
    body = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro.configs import DPPFConfig, MeshPlan
from repro.core import consensus
from repro.train import (init_train_state, make_sharded_round_step,
                         shard_train_state)
from repro.optim import make_optimizer
from benchmarks.common import mlp_init, mlp_loss
from repro.launch.mesh import make_hier_engine_mesh

dim, ncls, width, M, tau = 16, 4, 8, 8, 4
key = jax.random.PRNGKey(0)
opt = make_optimizer("sgd", momentum=0.9)
p0 = lambda k: mlp_init(k, dim, ncls, width)
def batches(seed):
    k = jax.random.PRNGKey(seed)
    return {"x": jax.random.normal(k, (tau, M, 8, dim)),
            "y": jax.random.randint(jax.random.fold_in(k, 1),
                                    (tau, M, 8), 0, ncls)}

fmesh = Mesh(np.asarray(jax.devices()).reshape(8, 1), ("data", "model"))
fplan = MeshPlan(worker_axes=("data",), model_axes=("model",))
hmesh, hplan = make_hier_engine_mesh(2, 2, 2)
MK = ("consensus_dist", "pre_dist", "pull_force", "push_force",
      "train_loss", "lam_t", "staleness")

def run_pair(mesh, plan, dcfg_s1, dcfg_db, engine_patch=None, rounds=4):
    st0 = init_train_state(p0, opt, dcfg_s1, M, key)
    if engine_patch:
        st0 = dataclasses.replace(
            st0, engine=dataclasses.replace(st0.engine, **engine_patch))
    st1 = shard_train_state(st0, mesh, plan)
    st2 = shard_train_state(st0, mesh, plan)
    f1 = jax.jit(make_sharded_round_step(mlp_loss, opt, dcfg_s1, mesh=mesh,
                                         plan=plan, base_lr=0.05,
                                         total_steps=40))
    f2 = jax.jit(make_sharded_round_step(mlp_loss, opt, dcfg_db, mesh=mesh,
                                         plan=plan, base_lr=0.05,
                                         total_steps=40))
    b0 = batches(0)          # warm both through one staleness1 round
    st1, _ = f1(st1, b0)
    st2, _ = f1(st2, b0)
    for r in range(1, rounds):
        b = batches(r)
        st1, m1 = f1(st1, b)
        st2, m2 = f2(st2, b)
    dp = float(jnp.max(jnp.abs(st1.params - st2.params)))
    ds = float(jnp.max(jnp.abs(st1.snap["x"] - st2.snap["x"])))
    dm = max(abs(float(m1[k]) - float(m2[k])) for k in MK)
    return dp, ds, dm

# ddp carries no overlap snapshot (init_train_state rejects it): the bar
# covers the five consensus methods
for mname, mesh, plan in (("flat8x1", fmesh, fplan),
                          ("hier2x2x2", hmesh, hplan)):
    for method in ("simple_avg", "hard", "easgd", "lsgd", "mgrawa"):
        base = dict(alpha=0.2, lam=0.4, tau=tau, consensus=method,
                    engine="flat", lam_schedule="fixed")
        d_s1 = DPPFConfig(overlap="staleness1", **base)
        d_db1 = DPPFConfig(overlap="doublebuf", overlap_chunks=1, **base)
        d_db4 = DPPFConfig(overlap="doublebuf", overlap_chunks=4, **base)
        dp, ds, dm = run_pair(mesh, plan, d_s1, d_db1,
                              engine_patch={"precise": True})
        assert dp <= 1e-7 and ds <= 1e-7 and dm <= 1e-6, \
            (mname, method, "precise", dp, ds, dm)
        # fast mode + chunked dispatch: within the documented Gram floor
        dp, ds, dm = run_pair(mesh, plan, d_s1, d_db4)
        assert dp < 2e-5 and dm < 1e-4, (mname, method, "fast", dp, ds, dm)
print("doublebuf parity OK")

# kernel path: per-chunk partial_gram emission + mix_from_gram epilogue
base = dict(alpha=0.2, lam=0.4, tau=tau, engine="flat",
            lam_schedule="fixed")
d_s1 = DPPFConfig(overlap="staleness1", **base)
d_db = DPPFConfig(overlap="doublebuf", overlap_chunks=2, **base)
dp, ds, dm = run_pair(hmesh, hplan, d_s1, d_db,
                      engine_patch={"use_kernel": True, "interpret": True,
                                    "block_cols": 32})
assert dp < 2e-5 and dm < 1e-4, ("kernel", dp, ds, dm)
print("doublebuf kernel OK")

# sharded round-0 bubble: doublebuf round 0 == the exact sharded round
d_ex = DPPFConfig(**base)
d_db = DPPFConfig(overlap="doublebuf", overlap_chunks=4, **base)
st0 = init_train_state(p0, opt, d_db, M, key, same_init=False)
st_ex0 = dataclasses.replace(st0, snap=None)
st_db = shard_train_state(st0, hmesh, hplan)
st_ex = shard_train_state(st_ex0, hmesh, hplan)
f_db = jax.jit(make_sharded_round_step(mlp_loss, opt, d_db, mesh=hmesh,
                                       plan=hplan, base_lr=0.05,
                                       total_steps=40))
f_ex = jax.jit(make_sharded_round_step(mlp_loss, opt, d_ex, mesh=hmesh,
                                       plan=hplan, base_lr=0.05,
                                       total_steps=40))
st_db, m_db = f_db(st_db, batches(0))
st_ex, _ = f_ex(st_ex, batches(0))
dp = float(jnp.max(jnp.abs(st_db.params - st_ex.params)))
assert dp <= 1e-6 and float(m_db["staleness"]) == 0.0, (dp, m_db)
st_db, m_db = f_db(st_db, batches(1))
assert float(m_db["staleness"]) == 1.0
print("doublebuf bubble OK")
print("ALL OK")
"""
    env = dict(os.environ, PYTHONPATH=SRC + os.pathsep + ROOT)
    out = subprocess.run([sys.executable, "-c", body], capture_output=True,
                         text=True, env=env, timeout=560, cwd=ROOT)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "ALL OK" in out.stdout


@pytest.mark.slow
def test_hierarchical_2x2x2_parity_and_cross_mesh_resume_8dev():
    """The acceptance leg (ISSUE 4): on 8 forced host devices, a 2x2x2
    workers x fsdp x model round — the partial-Gram psum spanning BOTH
    column axes, aux rows + fsdp column shards together — is bit-for-bit
    equal to the flat 8x1 row-sharded round in precise mode for every
    consensus method (<= 1 ulp of fp32; lsgd's argmin sees ulp-level loss
    inputs), within the Gram floor in fast mode, kernel path included;
    and a checkpoint saved mid-run on the 2x2x2 mesh resumes onto the 8x1
    mesh bit-for-bit (mesh-shape-independent checkpoints)."""
    body = r"""
import os, tempfile
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro.checkpoint import load_train_state, save_train_state
from repro.configs import DPPFConfig, MeshPlan
from repro.core import consensus
from repro.train import (init_train_state, make_sharded_round_step,
                         shard_train_state)
from repro.optim import make_optimizer
from benchmarks.common import mlp_init, mlp_loss

dim, ncls, width, M, tau = 16, 4, 8, 8, 2
key = jax.random.PRNGKey(0)
opt = make_optimizer("sgd", momentum=0.9)
p0 = lambda k: mlp_init(k, dim, ncls, width)
def batches(seed):
    k = jax.random.PRNGKey(seed)
    return {"x": jax.random.normal(k, (tau, M, 8, dim)),
            "y": jax.random.randint(jax.random.fold_in(k, 1),
                                    (tau, M, 8), 0, ncls)}

from repro.launch.mesh import flat_col_axes, make_hier_engine_mesh
hmesh, hplan = make_hier_engine_mesh(2, 2, 2)
fmesh = Mesh(np.asarray(jax.devices()).reshape(8, 1), ("data", "model"))
fplan = MeshPlan(worker_axes=("data",), model_axes=("model",))
MKEYS = ("consensus_dist", "pre_dist", "pull_force", "push_force",
         "train_loss", "lam_t")

def run_pair(dcfg, engine_patch=None, rounds=2):
    st0 = init_train_state(p0, opt, dcfg, M, key)
    if st0.engine is None:  # ddp: reuse the simple_avg layout (aux = 0)
        st0 = init_train_state(
            p0, opt, dataclasses.replace(dcfg, consensus="simple_avg"),
            M, key)
    if engine_patch:
        st0 = dataclasses.replace(
            st0, engine=dataclasses.replace(st0.engine, **engine_patch))
    # the column group must really span both axes (4 shards)
    assert flat_col_axes(hmesh, st0.engine.layout.n, hplan) == \
        ("fsdp", "model")
    st1 = shard_train_state(st0, hmesh, hplan)
    st2 = shard_train_state(st0, fmesh, fplan)
    f1 = jax.jit(make_sharded_round_step(mlp_loss, opt, dcfg, mesh=hmesh,
                                         plan=hplan, base_lr=0.05,
                                         total_steps=20))
    f2 = jax.jit(make_sharded_round_step(mlp_loss, opt, dcfg, mesh=fmesh,
                                         plan=fplan, base_lr=0.05,
                                         total_steps=20))
    for r in range(rounds):
        st1, m1 = f1(st1, batches(r))
        st2, m2 = f2(st2, batches(r))
    dp = float(jnp.max(jnp.abs(st1.params - st2.params)))
    dm = max(abs(float(m1[k]) - float(m2[k])) for k in MKEYS)
    return dp, dm

for method in consensus.METHODS:
    dcfg = DPPFConfig(alpha=0.2, lam=0.4, tau=tau, consensus=method,
                      engine="flat")
    dp, dm = run_pair(dcfg, engine_patch={"precise": True})
    # bit-for-bit up to reduction-order ulps in the (R, R) psums
    assert dp <= 1e-7 and dm < 1e-5, (method, "precise", dp, dm)
    dp, dm = run_pair(dcfg)
    assert dp < 2e-5 and dm < 1e-4, (method, "fast", dp, dm)
print("hier parity OK")

# kernel path: partial_gram/mix_shard + psum epilogue over BOTH axes
dcfg = DPPFConfig(alpha=0.2, lam=0.4, tau=tau, engine="flat")
dp, dm = run_pair(dcfg, engine_patch={"use_kernel": True, "interpret": True,
                                      "block_cols": 32})
assert dp < 2e-5 and dm < 1e-4, ("kernel", dp, dm)
print("hier kernel OK")

# cross-mesh resume: 2 rounds on 2x2x2, save, resume on 8x1, 2 more
# rounds on each -> identical params/opt (checkpoints gather to host and
# reshard on load)
dcfg = DPPFConfig(alpha=0.2, lam=0.4, tau=tau, consensus="easgd",
                  engine="flat")
st0 = init_train_state(p0, opt, dcfg, M, key)
st0 = dataclasses.replace(
    st0, engine=dataclasses.replace(st0.engine, precise=True))
f_h = jax.jit(make_sharded_round_step(mlp_loss, opt, dcfg, mesh=hmesh,
                                      plan=hplan, base_lr=0.05,
                                      total_steps=20))
f_f = jax.jit(make_sharded_round_step(mlp_loss, opt, dcfg, mesh=fmesh,
                                      plan=fplan, base_lr=0.05,
                                      total_steps=20))
st_h = shard_train_state(st0, hmesh, hplan)
for r in range(2):
    st_h, _ = f_h(st_h, batches(r))
with tempfile.TemporaryDirectory() as d:
    path = os.path.join(d, "hier.npz")
    save_train_state(path, st_h)
    template = dataclasses.replace(
        init_train_state(p0, opt, dcfg, M, key), engine=st_h.engine)
    st_f = shard_train_state(load_train_state(path, template), fmesh, fplan)
assert int(st_f.t) == 2 * tau and int(st_f.round) == 2
for r in range(2, 4):
    st_h, _ = f_h(st_h, batches(r))
    st_f, _ = f_f(st_f, batches(r))
# the two continuations run on different mesh shapes, so the (R, R) psum
# reduction order differs: ulp-level agreement, same bound as the parity
# legs above
np.testing.assert_allclose(np.asarray(st_h.params),
                           np.asarray(st_f.params), atol=1e-6, rtol=0)
jax.tree.map(lambda a, b: np.testing.assert_allclose(
    np.asarray(a), np.asarray(b), atol=1e-6, rtol=0), st_h.opt, st_f.opt)
print("cross-mesh resume OK")
print("ALL OK")
"""
    env = dict(os.environ, PYTHONPATH=SRC + os.pathsep + ROOT)
    out = subprocess.run([sys.executable, "-c", body], capture_output=True,
                         text=True, env=env, timeout=560, cwd=ROOT)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "ALL OK" in out.stdout
