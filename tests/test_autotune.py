"""The autotune test rig (DESIGN.md §Autotune): scripted-OOM backoff,
hypothesis properties of the search loop, and deterministic TunePlan
replay through RoundClock/DPPFConfig.

The probe runner is the ONLY part of the search that touches a device,
so `tests/_faults.py::scripted_runner` substitutes a deterministic
feasibility frontier (InjectedOOM carries the RESOURCE_EXHAUSTED token
— the same message-matching contract real jaxlib OOM satisfies) and the
whole backoff/budget/selection logic runs device-free. The end-to-end
leg at the bottom runs the REAL round-step probe runner on a small MLP
with `inject_oom_above`."""
from __future__ import annotations

import dataclasses
import json
import os

import numpy as np
import pytest

from _faults import InjectedOOM, default_time_fn, noisy_time_fn, \
    scripted_runner
from _hyp import given, settings, st  # hypothesis, or deterministic fallback

import repro.launch.roofline as rf
from repro.configs import DPPFConfig
from repro.train import RoundClock
from repro.train.autotune import (
    Candidate, ProbeResult, TunePlan, TuneSpace, autotune,
    inject_oom_above, is_oom, make_lm_model_fn, per_sample_us,
)

ROOT = os.path.join(os.path.dirname(__file__), "..")


def model_fn(cand):
    """Noise-free model oracle matching the scripted runner's default
    timing — selection under it is exactly per-sample-time-optimal."""
    return default_time_fn(cand)


def run_search(*, fail_above=None, fail_batches=(), time_fn=None, log=None,
               **space_kw):
    kw = dict(min_batch=1, max_batch=32, taus=(2, 4), chunks=(1, 2),
              probe_budget=16)
    kw.update(space_kw)
    space = TuneSpace(**kw)
    runner = scripted_runner(fail_above=fail_above,
                             fail_batches=fail_batches, time_fn=time_fn,
                             log=log)
    return autotune(runner, model_fn, space)


# ---------------------------------------------------------------------------
# OOM contract
# ---------------------------------------------------------------------------

def test_is_oom_matches_resource_exhausted_tokens():
    assert is_oom(RuntimeError("RESOURCE_EXHAUSTED: out of memory "
                               "allocating 1073741824 bytes"))
    assert is_oom(InjectedOOM(16))
    assert is_oom(MemoryError("Out of memory"))
    class XlaRuntimeError(Exception):  # message-only contract: any type
        pass
    assert is_oom(XlaRuntimeError("RESOURCE_EXHAUSTED: Allocator ran out"))


def test_is_oom_rejects_ordinary_errors():
    assert not is_oom(ValueError("tau must be >= 1"))
    assert not is_oom(RuntimeError("device disconnected"))


def test_non_oom_exception_propagates():
    def broken(cand):
        raise ZeroDivisionError("a real bug, not memory pressure")
    with pytest.raises(ZeroDivisionError):
        autotune(broken, model_fn, TuneSpace(min_batch=1, max_batch=4,
                                             taus=(2,), chunks=(1,)))


# ---------------------------------------------------------------------------
# backoff: halve-and-refine to the frontier, never retry, stay in budget
# ---------------------------------------------------------------------------

def test_backoff_refines_to_largest_feasible_batch():
    # frontier 13: doubling 1,2,4,8 ok -> 16 OOM; binary 12 ok, 14 OOM,
    # 13 ok -> the frontier exactly
    plan = run_search(fail_above=13)
    assert plan.chosen.batch == 13
    assert set(plan.failures) == {14, 16}


def test_backoff_probe_ladder_is_the_worked_trace():
    log = []
    run_search(fail_above=13, log=log)
    batches_phase_a = [c.batch for c in log if (c.tau, c.overlap_chunks)
                      == (2, 1)]
    assert batches_phase_a == [1, 2, 4, 8, 16, 12, 14, 13]


def test_never_retries_any_candidate():
    log = []
    run_search(fail_above=13, log=log)
    assert len(log) == len(set(log)), "a candidate was probed twice"


def test_known_failed_size_never_rerun():
    log = []
    run_search(fail_above=7, log=log)    # 8 OOMs in doubling, 7 is frontier
    assert [c.batch for c in log].count(8) == 1


def test_joint_sweep_reuses_cached_base_probe():
    log = []
    plan = run_search(fail_above=None, max_batch=8)
    log = []
    plan = run_search(fail_above=None, max_batch=8, log=log)
    # (max_batch, taus[0], chunks[0]) is probed by phase A and REUSED by
    # the joint sweep — exactly one run
    base = [c for c in log if c == Candidate(8, 2, 1)]
    assert len(base) == 1
    assert plan.chosen.batch == 8


def test_no_oom_chooses_max_batch():
    plan = run_search(fail_above=None, max_batch=32)
    assert plan.chosen.batch == 32
    assert plan.failures == ()


def test_budget_exhaustion_returns_best_so_far():
    # budget 3 covers only doubling probes 1, 2, 4 — refinement and the
    # joint sweep are cut off; the best feasible point found wins
    plan = run_search(fail_above=None, max_batch=64, probe_budget=3)
    assert plan.probes_used == 3
    assert plan.chosen == Candidate(4, 2, 1)


def test_terminates_within_probe_budget():
    for frontier in (1, 3, 9, 31, None):
        for budget in (1, 2, 5, 16):
            plan = run_search(fail_above=frontier, probe_budget=budget)
            assert plan.probes_used <= budget
            assert len(plan.probes) == plan.probes_used


def test_min_batch_oom_is_a_value_error():
    with pytest.raises(ValueError, match="no feasible batch"):
        run_search(fail_batches={1})


def test_failures_recorded_sorted_unique():
    plan = run_search(fail_above=5)      # 8 OOM, then binary 6(OOM)?
    assert list(plan.failures) == sorted(set(plan.failures))
    assert all(b > 5 for b in plan.failures)
    assert plan.chosen.batch == 5


def test_mid_ladder_hole_backs_off_below_it():
    # a non-monotone frontier (fragmentation): 8 fails but 12 would fit;
    # the search treats the first failure as the frontier and lands on 7
    # — documented behavior, monotone-frontier assumption
    plan = run_search(fail_batches={8})
    assert plan.chosen.batch == 7
    assert 8 in plan.failures


def test_selection_prefers_better_per_sample_point():
    # default_time_fn amortizes per sample as batch*tau grows, so at the
    # frontier batch the joint sweep picks the largest tau and most chunks
    plan = run_search(fail_above=None, max_batch=16, taus=(2, 4),
                      chunks=(1, 2))
    assert plan.chosen.tau == 4
    assert plan.chosen.overlap_chunks == 2
    assert plan.dominates_model and plan.dominates_measured


def test_chunks_capped_by_tau():
    log = []
    run_search(fail_above=None, max_batch=4, taus=(2,), chunks=(1, 4),
               log=log)
    assert all(c.overlap_chunks <= c.tau for c in log)


def test_chunk_ladder_collapses_for_unchunked_modes():
    assert TuneSpace(overlap="none").chunk_ladder() == (1,)
    assert TuneSpace(overlap="staleness1").chunk_ladder() == (1,)
    assert TuneSpace(overlap="doublebuf",
                     chunks=(1, 2)).chunk_ladder() == (1, 2)
    assert TuneSpace(overlap="staleness_k", staleness=2,
                     chunks=(1, 2)).chunk_ladder() == (1, 2)


def test_inject_oom_above_wrapper():
    seen = []
    runner = inject_oom_above(lambda c: seen.append(c) or 7.0, 4)
    assert runner(Candidate(4, 2, 1)) == 7.0
    with pytest.raises(RuntimeError, match="RESOURCE_EXHAUSTED"):
        runner(Candidate(5, 2, 1))
    assert len(seen) == 1, "the injected failure must fire pre-device"
    with pytest.raises(ValueError, match=">= 1"):
        inject_oom_above(lambda c: 0.0, 0)


# ---------------------------------------------------------------------------
# TuneSpace validation (-O-safe ValueError surface)
# ---------------------------------------------------------------------------

def test_space_rejects_nonpositive_budget():
    with pytest.raises(ValueError, match="probe_budget"):
        TuneSpace(probe_budget=0)


def test_space_rejects_min_over_max():
    with pytest.raises(ValueError, match="min_batch 8 > max_batch 4"):
        TuneSpace(min_batch=8, max_batch=4)


def test_space_rejects_bad_min_batch():
    with pytest.raises(ValueError, match="min_batch"):
        TuneSpace(min_batch=0)


def test_space_rejects_bad_ladders():
    with pytest.raises(ValueError, match="taus"):
        TuneSpace(taus=())
    with pytest.raises(ValueError, match="taus"):
        TuneSpace(taus=(4, 0))
    with pytest.raises(ValueError, match="chunks"):
        TuneSpace(chunks=(0,))
    with pytest.raises(ValueError, match="staleness"):
        TuneSpace(staleness=0)


def test_space_rejects_unknown_overlap():
    with pytest.raises(ValueError, match="overlap"):
        TuneSpace(overlap="bogus")


# ---------------------------------------------------------------------------
# hypothesis properties of the search loop
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(frontier=st.integers(min_value=1, max_value=64),
       max_batch=st.integers(min_value=1, max_value=48))
def test_prop_search_finds_the_frontier_exactly(frontier, max_batch):
    """Monotone frontier + ample budget: binary refinement lands EXACTLY
    on min(frontier, max_batch) — stronger than the within-one-step
    acceptance bound."""
    space_max = max(max_batch, 1)
    plan = run_search(fail_above=frontier, max_batch=space_max,
                      probe_budget=64)
    assert plan.chosen.batch == min(frontier, space_max)
    assert plan.chosen.batch not in plan.failures


@settings(max_examples=40, deadline=None)
@given(frontier=st.integers(min_value=1, max_value=64),
       budget=st.integers(min_value=1, max_value=24))
def test_prop_terminates_feasible_within_budget(frontier, budget):
    plan = run_search(fail_above=frontier, max_batch=64,
                      probe_budget=budget)
    assert plan.probes_used <= budget
    assert plan.chosen.batch <= frontier          # feasible
    assert plan.chosen.batch >= 1


@settings(max_examples=25, deadline=None)
@given(frontier=st.integers(min_value=2, max_value=40),
       noise=st.floats(min_value=0.0, max_value=0.2),
       seed=st.integers(min_value=0, max_value=9))
def test_prop_noisy_oracle_keeps_model_dominance(frontier, noise, seed):
    """A noisy-but-bounded timing oracle cannot flip the chosen point:
    selection goes through the calibrated MODEL score, so
    ``dominates_model`` holds regardless of timer noise."""
    tf = noisy_time_fn(default_time_fn, noise=noise, seed=seed)
    plan = run_search(fail_above=frontier, time_fn=tf, probe_budget=32)
    assert plan.chosen.batch == min(frontier, 32)
    assert plan.dominates_model
    ok = [p for p in plan.probes if p.ok]
    best = min(per_sample_us(p.modeled_us, p.candidate) for p in ok)
    assert per_sample_us(plan.chosen and next(
        p for p in ok if p.candidate == plan.chosen).modeled_us,
        plan.chosen) == pytest.approx(best)


@settings(max_examples=25, deadline=None)
@given(frontier=st.integers(min_value=1, max_value=64))
def test_prop_within_one_probe_step_of_frontier(frontier):
    """The acceptance-bound form: even with a budget too small to finish
    refinement, the chosen batch is feasible and no feasible PROBED batch
    beats it (the search never returns a dominated point it has seen)."""
    plan = run_search(fail_above=frontier, max_batch=64, probe_budget=6)
    ok_batches = [p.batch for p in plan.probes if p.ok]
    assert plan.chosen.batch == max(ok_batches)


# ---------------------------------------------------------------------------
# TunePlan: deterministic JSON round-trip
# ---------------------------------------------------------------------------

def test_plan_roundtrip_is_bit_identical(tmp_path):
    plan = run_search(fail_above=13)
    blob = plan.dumps()
    assert blob == plan.dumps()                       # deterministic
    assert TunePlan.from_dict(json.loads(blob)).dumps() == blob
    path = str(tmp_path / "plan.json")
    plan.save(path)
    loaded = TunePlan.load(path)
    assert loaded == TunePlan.from_dict(json.loads(blob))
    assert loaded.chosen == plan.chosen
    loaded.save(str(tmp_path / "plan2.json"))
    assert open(path).read() == open(str(tmp_path / "plan2.json")).read()


def test_plan_rejects_wrong_version():
    plan = run_search(fail_above=5)
    d = plan.to_dict()
    d["version"] = 99
    with pytest.raises(ValueError, match="version"):
        TunePlan.from_dict(d)


def test_plan_rejects_missing_keys():
    with pytest.raises(ValueError, match="malformed TunePlan"):
        TunePlan.from_dict({"chosen": {"batch": 2}})


def test_plan_post_init_guards():
    ok = run_search(fail_above=5)
    with pytest.raises(ValueError, match="probe_budget"):
        dataclasses.replace(ok, probe_budget=0)
    with pytest.raises(ValueError, match="overlap"):
        dataclasses.replace(ok, overlap="bogus")
    with pytest.raises(ValueError, match="chosen"):
        dataclasses.replace(ok, chosen=Candidate(0, 2, 1))


# ---------------------------------------------------------------------------
# replay: TunePlan -> DPPFConfig / RoundClock, bit-identical either form
# ---------------------------------------------------------------------------

def make_plan(**kw):
    kw.setdefault("fail_above", 13)
    return run_search(**kw)


def test_apply_tune_plan_plumbs_every_field():
    plan = make_plan(overlap="doublebuf", taus=(2, 4), chunks=(1, 2))
    base = DPPFConfig(alpha=0.2, lam=0.4, engine="flat", tau=7,
                      overlap_chunks=9)
    d = base.apply_tune_plan(plan)
    assert d.tau == plan.chosen.tau
    assert d.overlap_chunks == plan.chosen.overlap_chunks
    assert d.overlap == "doublebuf"
    assert d.tau_schedule == "fixed"
    assert (d.alpha, d.lam) == (0.2, 0.4)            # untouched
    # dict form lands on the identical config
    assert base.apply_tune_plan(plan.to_dict()) == d


def test_apply_tune_plan_rejects_qsr():
    plan = make_plan()
    with pytest.raises(ValueError, match="qsr"):
        DPPFConfig(engine="flat", tau_schedule="qsr",
                   qsr_beta=0.4).apply_tune_plan(plan)
    with pytest.raises(ValueError, match="qsr"):
        DPPFConfig(engine="flat", qsr_beta=0.4).apply_tune_plan(plan)


def test_apply_tune_plan_surfaces_engine_conflict():
    plan = make_plan(overlap="doublebuf")
    with pytest.raises(ValueError, match="flat"):
        DPPFConfig(engine="tree").apply_tune_plan(plan)


def test_clock_from_tune_plan_matches_hand_written():
    plan = make_plan(overlap="doublebuf")
    c1 = RoundClock.from_tune_plan(plan, base_lr=0.3, total_steps=64,
                                   warmup=4)
    hand = RoundClock(total_steps=64, tau=plan.chosen.tau, base_lr=0.3,
                      warmup=4, tau_schedule="fixed", overlap="doublebuf")
    assert c1.describe() == hand.describe()
    assert c1.plan_table() == hand.plan_table()
    # dict and dataclass forms replay bit-identically
    c2 = RoundClock.from_tune_plan(plan.to_dict(), base_lr=0.3,
                                   total_steps=64, warmup=4)
    assert c2 == c1


def test_clock_from_tune_plan_with_dcfg_keeps_method_plan():
    plan = make_plan(overlap="doublebuf")
    dcfg = DPPFConfig(alpha=0.2, lam=0.4, engine="flat",
                      consensus="entropy_sgd")
    c = RoundClock.from_tune_plan(plan, base_lr=0.3, total_steps=64,
                                  dcfg=dcfg)
    ref = RoundClock.from_config(dcfg.apply_tune_plan(plan), base_lr=0.3,
                                 total_steps=64)
    assert c == ref
    assert c.inner_rounds > 1        # the registry's inner/outer plan rode
    assert c.lam == 0.4


def test_staleness_k_depth_plumbs_through_plan():
    plan = make_plan(overlap="staleness_k", staleness=2)
    c = RoundClock.from_tune_plan(plan, base_lr=0.3, total_steps=32)
    assert c.overlap == "staleness_k"
    assert c.staleness_depth == 2
    d = DPPFConfig(engine="flat").apply_tune_plan(plan)
    assert (d.overlap, d.staleness) == ("staleness_k", 2)


def test_committed_bench_plan_replays_structurally():
    """The committed BENCH_autotune.json plan is a live replay fixture:
    its structural gates hold and it builds the same clock from either
    serialized form."""
    path = os.path.join(ROOT, "BENCH_autotune.json")
    with open(path) as f:
        rec = json.load(f)["autotune"]
    plan = TunePlan.from_dict(rec["plan"])
    assert plan.probes_used <= plan.probe_budget
    assert plan.dominates_model
    assert plan.failures, "the committed plan must exercise backoff"
    c1 = RoundClock.from_tune_plan(plan, base_lr=0.1, total_steps=32)
    c2 = RoundClock.from_tune_plan(rec["plan"], base_lr=0.1,
                                   total_steps=32)
    assert c1 == c2
    assert TunePlan.from_dict(json.loads(plan.dumps())) == plan


def test_resume_under_tuned_plan_matches_straight_through(tmp_path):
    """Mid-run resume == straight-through when the whole run (tau,
    chunks, batch) comes from a TunePlan — the tuned operating point
    changes shapes, not checkpoint semantics (extends the
    test_sharded_round resume-parity pattern)."""
    import jax
    import jax.numpy as jnp
    from repro.checkpoint import load_train_state, save_train_state
    from repro.optim import make_optimizer
    from repro.train import init_train_state, make_round_step
    from benchmarks.common import mlp_init, mlp_loss

    plan = make_plan(overlap="doublebuf", max_batch=8, taus=(2, 4))
    M, dim, ncls = 4, 16, 4
    base = DPPFConfig(alpha=0.2, lam=0.4, engine="flat")
    dcfg = base.apply_tune_plan(plan)
    tau, bs = dcfg.tau, plan.chosen.batch
    opt = make_optimizer("sgd", momentum=0.9)
    p0 = lambda k: mlp_init(k, dim, ncls, width=8)

    def batches(seed):
        k = jax.random.PRNGKey(seed)
        return {"x": jax.random.normal(k, (tau, M, bs, dim)),
                "y": jax.random.randint(jax.random.fold_in(k, 1),
                                        (tau, M, bs), 0, ncls)}

    key = jax.random.PRNGKey(0)
    step = jax.jit(make_round_step(mlp_loss, opt, dcfg, base_lr=0.05,
                                   total_steps=4 * tau), donate_argnums=0)
    straight = init_train_state(p0, opt, dcfg, M, key)
    resumed = init_train_state(p0, opt, dcfg, M, key)
    for r in range(2):
        straight, _ = step(straight, batches(r))
        resumed, _ = step(resumed, batches(r))
    path = str(tmp_path / "state.npz")
    save_train_state(path, resumed)
    template = init_train_state(p0, opt, dcfg, M, key)
    resumed = load_train_state(path, template)
    assert int(resumed.t) == 2 * tau
    for r in range(2, 4):
        straight, _ = step(straight, batches(r))
        resumed, _ = step(resumed, batches(r))
    np.testing.assert_array_equal(np.asarray(straight.params),
                                  np.asarray(resumed.params))
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), straight.opt, resumed.opt)


# ---------------------------------------------------------------------------
# roofline reconciliation + probe model
# ---------------------------------------------------------------------------

def test_reconcile_median_scale():
    rec = rf.reconcile_probes([(2.0, 1.0), (3.0, 1.0), (10.0, 1.0)])
    assert rec["scale"] == 3.0 and rec["n"] == 3
    rec = rf.reconcile_probes([(2.0, 1.0), (4.0, 1.0)])
    assert rec["scale"] == 3.0
    assert rf.reconcile_probes([]) == {"scale": 1.0,
                                      "max_abs_log_residual": 0.0, "n": 0}
    # degenerate (zero-model) pairs are skipped, not divided by
    assert rf.reconcile_probes([(1.0, 0.0)])["n"] == 0


def test_reconcile_scale_never_changes_argmin():
    probes = [ProbeResult(b, t, 1, True, 0.0, default_time_fn(
        Candidate(b, t, 1))) for b in (4, 8) for t in (2, 4)]
    def argmin(scale):
        return min(probes, key=lambda p: per_sample_us(
            p.modeled_us * scale, p.candidate)).candidate
    assert argmin(1.0) == argmin(1e-3) == argmin(1e3)


def test_probe_round_model_mode_ordering():
    kw = dict(work_s_per_step=1e-4, tau=4, gather_bytes=5e7, R=8)
    exact = rf.probe_round_model(mode="none", **kw)
    s1 = rf.probe_round_model(mode="staleness1", **kw)
    db = rf.probe_round_model(mode="doublebuf", **kw)
    sk = rf.probe_round_model(mode="staleness_k", staleness=4, **kw)
    assert sk <= db <= s1 <= exact
    # deeper ring hides more; any k (cached or recomputed) is honored
    assert rf.probe_round_model(mode="staleness_k", staleness=3, **kw) <= \
        rf.probe_round_model(mode="staleness_k", staleness=1, **kw)


def test_probe_round_model_validation():
    with pytest.raises(ValueError, match="overlap mode"):
        rf.probe_round_model(work_s_per_step=1e-4, tau=4,
                             gather_bytes=1e6, mode="bogus")
    with pytest.raises(ValueError, match="tau"):
        rf.probe_round_model(work_s_per_step=1e-4, tau=0,
                             gather_bytes=1e6)
    with pytest.raises(ValueError, match="staleness"):
        rf.probe_round_model(work_s_per_step=1e-4, tau=2,
                             gather_bytes=1e6, mode="staleness_k",
                             staleness=0)


def test_lm_model_fn_per_sample_monotone():
    """The dominance gate's premise: modeled round time PER SAMPLE is
    non-increasing in batch and in tau (the comm residual amortizes), so
    the max-feasible-batch / best-(tau, chunks) point wins under the
    model for ANY calibration scale."""
    mf = make_lm_model_fn(n_params=10 ** 6, seq=64, workers=8,
                          overlap="doublebuf")
    for tau in (2, 4, 8):
        scores = [per_sample_us(mf(Candidate(b, tau, 1)),
                                Candidate(b, tau, 1))
                  for b in (1, 2, 4, 8, 16, 32)]
        assert all(a >= b - 1e-12 for a, b in zip(scores, scores[1:]))
    for b in (2, 8):
        scores = [per_sample_us(mf(Candidate(b, t, 1)), Candidate(b, t, 1))
                  for t in (1, 2, 4, 8)]
        assert all(a >= x - 1e-12 for a, x in zip(scores, scores[1:]))


def test_lm_model_fn_staleness_depth():
    k1 = make_lm_model_fn(n_params=10 ** 6, seq=64, workers=8,
                          overlap="staleness_k", staleness=1)
    k4 = make_lm_model_fn(n_params=10 ** 6, seq=64, workers=8,
                          overlap="staleness_k", staleness=4)
    c = Candidate(2, 2, 1)
    assert k4(c) <= k1(c)


# ---------------------------------------------------------------------------
# end-to-end: the real round-step probe runner
# ---------------------------------------------------------------------------

def test_real_probe_runner_with_injected_oom():
    """The full stack on a small MLP: the REAL make_round_step probes
    (jit + donation + timing) under an injected frontier, the plan
    applies to the config, and one training round runs at the chosen
    point."""
    import jax
    from repro.optim import make_optimizer
    from repro.train import init_train_state, make_round_step
    from repro.train.autotune import autotune as run, \
        make_round_probe_runner
    from benchmarks.common import mlp_init, mlp_loss
    import jax.numpy as jnp

    M, dim, ncls = 2, 8, 4
    dcfg = DPPFConfig(alpha=0.1, lam=0.5, tau=2, engine="flat",
                      overlap="doublebuf", overlap_chunks=1)
    opt = make_optimizer("sgd", momentum=0.9)
    p0 = lambda k: mlp_init(k, dim, ncls, width=8)

    def batch_fn(cand):
        return {"x": jnp.zeros((cand.tau, M, cand.batch, dim)),
                "y": jnp.zeros((cand.tau, M, cand.batch), jnp.int32)}

    runner = inject_oom_above(
        make_round_probe_runner(p0, mlp_loss, opt, dcfg, M, batch_fn,
                                reps=1), 3)
    mf = make_lm_model_fn(n_params=dim * 8 + 8 * ncls, seq=1, workers=M,
                          overlap="doublebuf")
    plan = run(runner, mf, TuneSpace(min_batch=1, max_batch=8,
                                     taus=(2,), chunks=(1,),
                                     probe_budget=8))
    assert plan.chosen.batch == 3             # 1, 2, 4(OOM), 3 backoff
    assert plan.failures == (4,)
    assert all(p.us_round > 0 for p in plan.probes if p.ok)

    tuned = dcfg.apply_tune_plan(plan)
    st0 = init_train_state(p0, opt, tuned, M, jax.random.PRNGKey(0))
    step = jax.jit(make_round_step(mlp_loss, opt, tuned, base_lr=0.05,
                                   total_steps=8))
    st1, m = step(st0, batch_fn(plan.chosen))
    assert np.isfinite(float(m["train_loss"]))
    assert int(st1.t) == tuned.tau
