"""Config exactness: every assigned architecture carries EXACTLY the
assigned dimensions, every input shape matches the assignment, the smoke
reduction respects its contract, and the dry-run spec builders produce
consistent abstract shapes."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, INPUT_SHAPES, get_arch, get_shape, reduced
from repro.launch import specs as specs_lib

ASSIGNED = {
    # name: (layers, d_model, heads, kv, d_ff, vocab)
    "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256206),
    "internlm2-20b": (48, 6144, 48, 8, 16384, 92544),
    "llama4-scout-17b-a16e": (48, 5120, 40, 8, 8192, 202048),
    "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
    "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
    "gemma2-2b": (26, 2304, 8, 4, 9216, 256000),
    "internvl2-2b": (24, 2048, 16, 8, 8192, 92553),
    "qwen2-72b": (80, 8192, 64, 8, 29568, 152064),
    "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
    "yi-6b": (32, 4096, 32, 4, 11008, 64000),
}

MOE = {"llama4-scout-17b-a16e": (16, 1), "dbrx-132b": (16, 4)}


def test_all_ten_archs_present():
    assert set(ARCHS) == set(ASSIGNED)


@pytest.mark.parametrize("name", sorted(ASSIGNED))
def test_assigned_dims_exact(name):
    cfg = get_arch(name)
    L, d, h, kv, ff, v = ASSIGNED[name]
    assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
            cfg.d_ff, cfg.vocab_size) == (L, d, h, kv, ff, v)
    assert cfg.source  # every config cites its source


def test_moe_configs():
    for name, (e, k) in MOE.items():
        cfg = get_arch(name)
        assert (cfg.n_experts, cfg.top_k) == (e, k)
    assert get_arch("llama4-scout-17b-a16e").shared_expert
    assert not get_arch("dbrx-132b").shared_expert


def test_family_features():
    assert get_arch("qwen2-72b").qkv_bias
    assert get_arch("gemma2-2b").sliding_window == 4096
    assert get_arch("gemma2-2b").attn_logit_softcap == 50.0
    assert get_arch("zamba2-7b").ssm_state == 64
    assert get_arch("zamba2-7b").blocks().count("shared_attn") == 13
    assert get_arch("seamless-m4t-medium").n_enc_layers == 12
    assert get_arch("internvl2-2b").n_prefix == 256
    assert get_arch("xlstm-350m").blocks().count("slstm") == 6


def test_input_shapes_exact():
    want = {
        "train_4k": (4096, 256, "train"),
        "prefill_32k": (32768, 32, "prefill"),
        "decode_32k": (32768, 128, "decode"),
        "long_500k": (524288, 1, "decode"),
    }
    assert set(INPUT_SHAPES) == set(want)
    for k, (s, b, kind) in want.items():
        sh = get_shape(k)
        assert (sh.seq_len, sh.global_batch, sh.kind) == (s, b, kind)


@pytest.mark.parametrize("name", sorted(ASSIGNED))
def test_reduced_contract(name):
    """Smoke variants: <=512 d_model, <=4 experts, full pattern coverage."""
    cfg = reduced(get_arch(name))
    assert cfg.d_model <= 512
    assert cfg.n_experts <= 4
    assert cfg.vocab_size <= 512
    assert cfg.n_layers >= len(cfg.layer_pattern)
    assert set(cfg.blocks()) == set(get_arch(name).blocks())


@pytest.mark.parametrize("shape", sorted(INPUT_SHAPES))
def test_serve_window_policy(shape):
    """long_500k is sub-quadratic for every arch: recurrent archs keep
    native state; full-attention archs get a window."""
    sh = get_shape(shape)
    for name, cfg in ARCHS.items():
        w = specs_lib.serve_window_for(cfg, sh)
        if shape != "long_500k":
            assert w == 0
        elif cfg.is_recurrent:
            assert w == 0
        else:
            assert 0 < w <= 8192
            buf = specs_lib.buf_len_for(cfg, sh)
            assert buf == w  # ring buffer, not 500k cache


def test_train_specs_shapes():
    cfg = get_arch("yi-6b")
    sh = get_shape("train_4k")
    specs = specs_lib.train_batch_specs(cfg, sh, n_workers=16, tau=4)
    assert specs["tokens"].shape == (4, 16, 16, 4096)
    assert specs["labels"].dtype == jnp.int32


def test_decode_specs_cache_length():
    cfg = get_arch("yi-6b")
    sh = get_shape("decode_32k")
    tok, idx, states = specs_lib.decode_step_specs(cfg, sh)
    assert tok.shape == (128, 1)
    # stacked KV cache: (L, B, buf, kv, hd)
    k = states["stack"]["attn"] if "stack" in states else states
    leaf = jax.tree.leaves(states)[0]
    assert 32768 in leaf.shape  # full-length cache buffer


def test_param_counts_vs_nameplate():
    approx = {"zamba2-7b": 7e9, "xlstm-350m": 0.35e9,
              "seamless-m4t-medium": 1.2e9, "internvl2-2b": 1.9e9,
              "dbrx-132b": 132e9, "llama4-scout-17b-a16e": 109e9}
    for name, want in approx.items():
        got = ARCHS[name].param_count()
        assert 0.5 * want < got < 1.8 * want, (name, got, want)
