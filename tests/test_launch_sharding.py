"""Launch-layer tests. Sharding rules are pure functions — testable without
devices; actual multi-device lowering runs in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count (kept small for CI; the
full 256/512-chip sweep is the dry-run deliverable)."""
from __future__ import annotations

import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.configs import ARCHS, MeshPlan, get_shape
from repro.launch import roofline as rf

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_param_count_sane():
    # dense param counts should be within ~15% of the nameplate sizes
    approx = {
        "yi-6b": 6e9, "qwen2-72b": 72e9, "internlm2-20b": 20e9,
        "gemma2-2b": 2.6e9,
    }
    for name, want in approx.items():
        got = ARCHS[name].param_count()
        assert abs(got - want) / want < 0.2, (name, got)


def test_moe_active_params_smaller():
    for name in ("dbrx-132b", "llama4-scout-17b-a16e"):
        cfg = ARCHS[name]
        assert cfg.active_param_count() < 0.5 * cfg.param_count()


def test_roofline_shape_bytes():
    assert rf._type_info("f32[2,3]{1,0}")[0] == 24
    assert rf._type_info("(bf16[4,4]{1,0}, pred[])")[0] == 33
    assert rf._type_info("token[]")[0] == 0


def test_roofline_hlo_analyzer_counts_trips():
    hlo = """
HloModule test

%body (p: (s32[], f32[8,128])) -> (s32[], f32[8,128]) {
  %p = parameter(0)
  %dot.1 = f32[8,8]{1,0} dot(%gte1, %gte1), lhs_contracting_dims={1}, rhs_contracting_dims={1}
  %ar = f32[8,128]{1,0} all-reduce(%gte1), channel_id=1
  ROOT %t = tuple(%i, %gte1)
}

%cond (p: (s32[], f32[8,128])) -> pred[] {
  %p2 = parameter(0)
}

ENTRY %main (a: f32[8,128]) -> f32[8,128] {
  %a = f32[8,128]{1,0} parameter(0)
  %gte1 = f32[8,128]{1,0} copy(%a)
  %w = (s32[], f32[8,128]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
}
"""
    res = rf.analyze_hlo(hlo)
    # all-reduce payload counted 5x
    assert res["collectives"]["all-reduce"]["bytes"] == 5 * 8 * 128 * 4
    assert res["collectives"]["all-reduce"]["count"] == 5


def test_hierarchical_mesh_bad_shape_raises_value_error():
    """Shape validation must survive ``python -O`` (ValueError, not a bare
    assert) and name the offending shape."""
    from repro.launch.mesh import make_hierarchical_mesh
    with pytest.raises(ValueError, match=r"4x4x4 = 64 .* 256"):
        make_hierarchical_mesh(4, 4, 4)
    with pytest.raises(ValueError, match=r"multi-pod"):
        make_hierarchical_mesh(8, 4, 4, multi_pod=True)


def test_flat_view_and_batch_shardings_8dev():
    """flat_view_sharding (rows -> worker axes, cols -> fsdp/model axes,
    divisibility fallbacks) and batch_shardings on an 8-device
    (data, fsdp, model) host mesh."""
    body = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np, json
from jax.sharding import Mesh
from repro.launch.mesh import batch_shardings, flat_view_sharding
from repro.configs import MeshPlan
mesh = Mesh(np.asarray(jax.devices()).reshape(2, 2, 2),
            ("data", "fsdp", "model"))
plan = MeshPlan(worker_axes=("data",), fsdp_axes=("fsdp",),
                model_axes=("model",))
out = {}
# rows 8 % 2 == 0, cols 1000 % 4 == 0 -> fully sharded
out["full"] = str(flat_view_sharding(mesh, (8, 1000), plan).spec)
# aux row breaks row divisibility -> rows replicate
out["aux"] = str(flat_view_sharding(mesh, (9, 1000), plan).spec)
# odd column count -> columns replicate
out["oddcol"] = str(flat_view_sharding(mesh, (8, 1001), plan).spec)
# no fsdp axes -> cols over model only
plan2 = MeshPlan(worker_axes=("data", "fsdp"), model_axes=("model",))
out["wide_workers"] = str(flat_view_sharding(mesh, (8, 1000), plan2).spec)
# round batches (tau, M, B, ...): M over workers, B over fsdp
batch = {"x": np.zeros((2, 8, 16, 32)), "y": np.zeros((2, 8, 16))}
sh = batch_shardings(mesh, batch, plan)
out["bx"] = str(sh["x"].spec)
out["by"] = str(sh["y"].spec)
print(json.dumps(out))
"""
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", body], capture_output=True,
                         text=True, env=env, timeout=240)
    assert out.returncode == 0, out.stderr
    got = json.loads(out.stdout.strip().splitlines()[-1])
    assert got["full"] == "PartitionSpec('data', ('fsdp', 'model'))"
    assert got["aux"] == "PartitionSpec(None, ('fsdp', 'model'))"
    assert got["oddcol"] == "PartitionSpec('data', None)"
    assert got["wide_workers"] == "PartitionSpec(('data', 'fsdp'), 'model')"
    assert got["bx"] == "PartitionSpec(None, 'data', 'fsdp', None)"
    assert got["by"] == "PartitionSpec(None, 'data', 'fsdp')"


def test_leaf_spec_divisibility_fallback():
    """Vocab 256206 is not divisible by 16 -> the model axis must fall back
    to the d_model dim; undividable head dims replicate."""
    body = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np, json
from jax.sharding import PartitionSpec as P
from repro.launch.mesh import param_shardings
from repro.configs import MeshPlan
mesh = jax.make_mesh((2, 4), ("data", "model"))
plan = MeshPlan(worker_axes=("data",), model_axes=("model",))
params = {"embed": np.zeros((2, 256206, 1024)),
          "blocks": {"stack": {"attn": {"wq": np.zeros((2, 12, 1024, 512)),
                                         "bq": np.zeros((2, 12, 6))}}}}
sh = param_shardings(mesh, params, plan, stacked=True)
print(json.dumps({
  "embed": str(sh["embed"].spec),
  "wq": str(sh["blocks"]["stack"]["attn"]["wq"].spec),
  "bq": str(sh["blocks"]["stack"]["attn"]["bq"].spec),
}))
"""
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", body], capture_output=True,
                         text=True, env=env, timeout=240)
    assert out.returncode == 0, out.stderr
    got = json.loads(out.stdout.strip().splitlines()[-1])
    assert "model" in got["embed"] and "256206" not in got["embed"]
    assert got["wq"] == "PartitionSpec('data', None, None, 'model')"
    assert got["bq"] == "PartitionSpec('data', None, None)"  # 6 % 4 != 0


def test_dryrun_report_prints_round_plan(tmp_path):
    """The dry-run report leads with the RoundClock.describe() plan table
    (ISSUE 4 / ROADMAP RoundClock item). Runs main() with the one combo
    pre-seeded as cached, so no 512-device compile happens."""
    out_dir = tmp_path / "dryrun"
    out_dir.mkdir()
    (out_dir / "gemma2-2b_train_4k_single_train_baseline.json").write_text(
        "{}")
    body = rf"""
import sys
sys.argv = ["dryrun", "--arch", "gemma2-2b", "--shape", "train_4k",
            "--mesh", "single", "--tau", "4", "--out", {str(out_dir)!r}]
from repro.launch import dryrun
dryrun.main()
"""
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", body], capture_output=True,
                         text=True, env=env, timeout=560)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "round plan: 250 rounds over 1000 steps" in out.stdout
    assert "| round | start | tau | lam | lr window |" in out.stdout
    assert "[skip]" in out.stdout and "all dry-runs passed" in out.stdout


@pytest.mark.slow
def test_dryrun_reduced_multidevice():
    """End-to-end: lower+compile the DPPF round for a REDUCED arch on an
    8-device (2 workers x 4 model) host mesh in a subprocess."""
    body = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np, dataclasses
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.configs import ARCHS, DPPFConfig, MeshPlan, reduced
from repro.launch import mesh as mesh_lib
from repro.models import build_model
from repro.optim import make_optimizer
from repro.train import init_train_state, make_round_step

mesh = Mesh(np.asarray(jax.devices()).reshape(2, 4), ("data", "model"))
plan = MeshPlan(worker_axes=("data",), model_axes=("model",))
cfg = reduced(ARCHS["gemma2-2b"], vocab_size=512, d_model=256)
model = build_model(cfg)
dcfg = DPPFConfig(tau=2)
opt = make_optimizer("sgd")
state = init_train_state(model.init, opt, dcfg, 2, jax.random.PRNGKey(0))
p_sh = mesh_lib.param_shardings(mesh, state.params, plan)
state = dataclasses.replace(
    state,
    params=jax.device_put(state.params, p_sh),
    opt=jax.device_put(state.opt, {"mu": p_sh}))
step = jax.jit(make_round_step(model.loss, opt, dcfg, base_lr=0.05,
                               total_steps=10))
B, S = 4, 32
batch = {"tokens": jnp.zeros((2, 2, B, S), jnp.int32),
         "labels": jnp.zeros((2, 2, B, S), jnp.int32)}
b_sh = mesh_lib.batch_shardings(mesh, batch, plan)
batch = jax.device_put(batch, b_sh)
with mesh:
    state2, m = step(state, batch)
    jax.block_until_ready(m["train_loss"])
print("OK", float(m["train_loss"]))
"""
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", body], capture_output=True,
                         text=True, env=env, timeout=560)
    assert out.returncode == 0, out.stderr
    assert "OK" in out.stdout
