"""``hypothesis`` shim: use the real library when installed, otherwise run
each ``@given`` test on a small deterministic sample drawn from the declared
strategy bounds (endpoints + seeded interior points).

The seed image ships without hypothesis, which used to make the whole suite
fail at collection. Property tests lose exhaustiveness without the real
library (install via requirements-dev.txt to get it back) but still execute
and catch regressions.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on the seed image
    import random

    HAVE_HYPOTHESIS = False
    _FALLBACK_EXAMPLES = 5

    class _Floats:
        def __init__(self, lo, hi):
            self.lo, self.hi = float(lo), float(hi)

        def sample(self, rng, i):
            if i == 0:
                return self.lo
            if i == 1:
                return self.hi
            return rng.uniform(self.lo, self.hi)

    class _Integers:
        def __init__(self, lo, hi):
            self.lo, self.hi = int(lo), int(hi)

        def sample(self, rng, i):
            if i == 0:
                return self.lo
            if i == 1:
                return self.hi
            return rng.randint(self.lo, self.hi)

    class _SampledFrom:
        def __init__(self, elements):
            self.elements = list(elements)

        def sample(self, rng, i):
            if i < len(self.elements):
                return self.elements[i]
            return rng.choice(self.elements)

    class _St:
        @staticmethod
        def floats(min_value, max_value, **kw):
            return _Floats(min_value, max_value)

        @staticmethod
        def integers(min_value, max_value, **kw):
            return _Integers(min_value, max_value)

        @staticmethod
        def sampled_from(elements):
            return _SampledFrom(elements)

    st = _St()

    def settings(max_examples=None, **kw):
        def deco(fn):
            if max_examples is not None:
                fn._hyp_max_examples = min(max_examples, _FALLBACK_EXAMPLES)
            return fn
        return deco

    def given(**strategies):
        def deco(fn):
            def wrapper():
                n = getattr(wrapper, "_hyp_max_examples", _FALLBACK_EXAMPLES)
                rng = random.Random(0xD99F)
                for i in range(n):
                    kwargs = {k: s.sample(rng, i)
                              for k, s in strategies.items()}
                    try:
                        fn(**kwargs)
                    except AssertionError as e:
                        raise AssertionError(
                            f"falsifying example (fallback strategies): "
                            f"{kwargs}") from e
            # NOT functools.wraps: pytest would follow __wrapped__ to the
            # original signature and demand fixtures for every parameter
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper
        return deco
