"""Unit + property tests for the DPPF core math (Eq. 4/5, E.1, Theorem 1)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis, or deterministic fallback

from repro.configs import DPPFConfig
from repro.core import consensus, pullpush as pp
from repro.core.schedules import lam_schedule, qsr_tau


def _stacked(key, M=4, shapes=((8, 8), (5,))):
    ks = jax.random.split(key, len(shapes))
    return {f"p{i}": jax.random.normal(ks[i], (M,) + s)
            for i, s in enumerate(shapes)}


def test_eq5_equals_pull_then_push_limit():
    """Eq. 5 fused == pull-only followed by push-only when x_C = x_A and the
    push is computed w.r.t. the ORIGINAL gap direction (algebraic identity:
    both scale the same gap vector)."""
    x = _stacked(jax.random.PRNGKey(0))
    alpha, lam = 0.3, 0.2
    fused, _ = pp.pullpush(x, alpha, lam)
    center = pp.tree_mean0(x)
    r = pp.worker_dists(x, center)
    # manual: x + (a-x) * (alpha - lam/r)
    coef = alpha - lam / r
    for k in x:
        gap = np.asarray(center[k])[None] - np.asarray(x[k])
        want = np.asarray(x[k]) + gap * np.asarray(coef).reshape(
            (-1,) + (1,) * (x[k].ndim - 1))
        np.testing.assert_allclose(np.asarray(fused[k]), want, rtol=1e-5)


def test_mean_preserved_by_pullpush():
    """Workers at equal radius: the average is invariant under Eq. 5."""
    key = jax.random.PRNGKey(1)
    d = jax.random.normal(key, (3, 64))
    d = d / jnp.linalg.norm(d, axis=1, keepdims=True)
    x = {"w": jnp.concatenate([d, -d]) * 2.0 + 1.5}
    new, _ = pp.pullpush(x, 0.2, 0.4)
    np.testing.assert_allclose(np.asarray(new["w"].mean(0)),
                               np.asarray(x["w"].mean(0)), atol=1e-5)


def test_push_only_increases_distance():
    x = _stacked(jax.random.PRNGKey(2))
    r0 = pp.worker_dists(x)
    pushed = pp.push_only(x, 0.5)
    r1 = pp.worker_dists(pushed)
    assert np.all(np.asarray(r1) > np.asarray(r0))


def test_exact_push_drops_to_simplified_under_symmetry():
    """D.1: with workers symmetric around x_A the mean unit direction is 0,
    so the exact two-term update == the simplified push (up to lam_r/M)."""
    key = jax.random.PRNGKey(3)
    d = jax.random.normal(key, (4, 32))
    d = d / jnp.linalg.norm(d, axis=1, keepdims=True)
    x = {"w": jnp.concatenate([d, -d]) * 3.0}
    M = 8
    lam = 0.25
    exact = pp.exact_push(x, lam_r=lam * M)
    simple = pp.push_only(x, lam)
    np.testing.assert_allclose(np.asarray(exact["w"]),
                               np.asarray(simple["w"]), rtol=1e-4, atol=1e-5)


def test_push_terms_norms_t2_small_when_symmetric():
    key = jax.random.PRNGKey(4)
    d = jax.random.normal(key, (4, 32))
    d = d / jnp.linalg.norm(d, axis=1, keepdims=True)
    x = {"w": jnp.concatenate([d, -d]) * 3.0}
    n1, n2, n12 = pp.push_terms_norms(x, lam_r=2.0)
    assert float(n2) < 1e-5
    np.testing.assert_allclose(np.asarray(n1), np.asarray(n12), rtol=1e-4)


@settings(max_examples=15, deadline=None)
@given(alpha=st.floats(0.05, 0.9), lam=st.floats(0.05, 1.0),
       m=st.integers(2, 5))
def test_theorem1_convergence_on_random_walk(alpha, lam, m):
    """Noisy quadratic toy: repeated rounds drive E||Delta|| to lam/alpha
    within the theory's O(eta*sigma + 1/sqrt(M)) slack."""
    key = jax.random.PRNGKey(int(alpha * 1000) + m)
    x = {"w": jax.random.normal(key, (2 * m, 48))}
    dcfg = DPPFConfig(alpha=alpha, lam=lam, consensus="simple_avg")
    state = consensus.init_state("simple_avg", x)
    eta = 0.005
    for k in range(250):
        noise = jax.random.normal(jax.random.fold_in(key, k), x["w"].shape)
        x = {"w": x["w"] - eta * x["w"] + eta * noise}
        x, state, metrics = consensus.apply_round(x, dcfg, lam, state)
    target = lam / alpha
    got = float(metrics["consensus_dist"])
    assert abs(got - target) < 0.35 * target + 10 * eta


def test_consensus_methods_run_and_pull():
    key = jax.random.PRNGKey(5)
    x = _stacked(key, M=4)
    losses = jnp.asarray([3.0, 1.0, 2.0, 4.0])
    gnorms = jnp.asarray([1.0, 2.0, 0.5, 1.0])
    for method in ("simple_avg", "hard", "easgd", "lsgd", "mgrawa"):
        dcfg = DPPFConfig(alpha=0.5, lam=0.0, push=False, consensus=method)
        state = consensus.init_state(method, x)
        new, state, m = consensus.apply_round(x, dcfg, 0.0, state,
                                              losses=losses, grad_norms=gnorms)
        assert float(m["consensus_dist"]) <= float(pp.worker_dists(x).mean())


def test_lsgd_pulls_toward_leader():
    x = {"w": jnp.asarray([[0.0, 0.0], [10.0, 10.0]])}
    losses = jnp.asarray([0.1, 5.0])  # worker 0 is leader
    target, _, idx = consensus.consensus_target("lsgd", x, {}, losses=losses)
    assert int(idx) == 0
    np.testing.assert_allclose(np.asarray(target["w"]), [0.0, 0.0])


def test_mgrawa_weights_inverse_grad_norm():
    x = {"w": jnp.asarray([[0.0], [1.0]])}
    gn = jnp.asarray([1e9, 1.0])  # worker 0 has huge grads -> tiny weight
    target, _, _ = consensus.consensus_target("mgrawa", x, {}, grad_norms=gn)
    np.testing.assert_allclose(np.asarray(target["w"]), [1.0], atol=1e-6)


def test_lam_schedules():
    assert float(lam_schedule("fixed", 0.5, 0, 100)) == 0.5
    assert float(lam_schedule("increasing", 0.5, 0, 100)) == pytest.approx(0.0)
    assert float(lam_schedule("increasing", 0.5, 100, 100)) == pytest.approx(0.5)
    assert float(lam_schedule("decreasing", 0.5, 0, 100)) == pytest.approx(0.5)
    assert float(lam_schedule("decreasing", 0.5, 100, 100)) == pytest.approx(0.0)


def test_qsr_rule():
    assert qsr_tau(0.8, 2, 0.25) == 2          # high lr -> tau_base
    assert qsr_tau(0.01, 2, 0.25) == 625       # low lr -> (beta/eta)^2
    assert qsr_tau(0.0, 4, 0.25) == 4
