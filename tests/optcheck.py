"""``python -O`` smoke for the de-asserted validation paths.

Run as a SCRIPT under ``python -O`` (the CI leg): with asserts stripped,
every user-facing check must still raise a real exception. Uses explicit
raises (not ``assert``) to report, since asserts are off by construction.

    PYTHONPATH=src python -O tests/optcheck.py
"""
from __future__ import annotations

import dataclasses
import sys

import numpy as np


def expect_raises(exc, fn, label):
    try:
        fn()
    except exc:
        print(f"ok: {label}")
        return
    raise SystemExit(f"FAIL: {label} did not raise {exc.__name__} "
                     f"(python -O stripped the check?)")


def main():
    if __debug__:
        print("warning: running with asserts ON — use python -O",
              file=sys.stderr)

    from repro.configs.base import DPPFConfig
    expect_raises(ValueError, lambda: DPPFConfig(engine="nope"),
                  "DPPFConfig unknown engine")
    expect_raises(ValueError, lambda: DPPFConfig(tau_schedule="nope"),
                  "DPPFConfig unknown tau schedule")
    expect_raises(ValueError, lambda: DPPFConfig(tau_schedule="qsr"),
                  "DPPFConfig qsr without beta")
    expect_raises(ValueError, lambda: DPPFConfig(overlap="bogus"),
                  "DPPFConfig unknown overlap mode")
    expect_raises(ValueError,
                  lambda: DPPFConfig(engine="flat", overlap="doublebuf",
                                     overlap_chunks=0),
                  "DPPFConfig overlap_chunks < 1")
    expect_raises(ValueError, lambda: DPPFConfig(overlap="doublebuf"),
                  "DPPFConfig doublebuf on tree engine")

    from repro.train import RoundClock
    expect_raises(ValueError,
                  lambda: RoundClock(total_steps=8, tau=4,
                                     tau_schedule="qsr", qsr_beta=0.0),
                  "RoundClock qsr without beta")
    expect_raises(ValueError,
                  lambda: RoundClock(total_steps=8, tau=4, overlap="bogus"),
                  "RoundClock unknown overlap mode")
    expect_raises(ValueError,
                  lambda: RoundClock(total_steps=8, tau=4, warmup=-1),
                  "RoundClock negative warmup")

    from repro.core import consensus
    import jax.numpy as jnp
    stacked = {"w": jnp.zeros((2, 3))}
    expect_raises(ValueError,
                  lambda: consensus.consensus_target("lsgd", stacked, {}),
                  "lsgd without losses (tree)")
    expect_raises(ValueError,
                  lambda: consensus.consensus_target("mgrawa", stacked, {}),
                  "mgrawa without grad norms (tree)")
    from repro.core.engine import ConsensusEngine
    eng = ConsensusEngine.from_stacked(stacked, method="lsgd")
    flat = eng.flatten(stacked)
    dcfg = DPPFConfig(consensus="lsgd", engine="flat")
    expect_raises(ValueError,
                  lambda: consensus.apply_round(flat, dcfg, 0.1, {},
                                                engine=eng),
                  "lsgd without losses (flat)")

    # method registry: unknown names, malformed specs, flat-only methods
    # on the tree engine, and the flat-path filtered-grad contract — all
    # ValueError (the registry validates in __post_init__, not assert)
    from repro.core.methods import MethodSpec, get_method
    expect_raises(ValueError, lambda: get_method("sgd_flavour_9000"),
                  "registry unknown method")
    expect_raises(ValueError,
                  lambda: MethodSpec(name="bad", doc="", weight_fn="uniform",
                                     aux_pull=1.0),
                  "MethodSpec aux_pull without aux row")
    expect_raises(ValueError,
                  lambda: MethodSpec(name="bad", doc="", weight_fn="uniform",
                                     push_source="filtered_grad",
                                     filter_mu=1.5),
                  "MethodSpec filter_mu out of range")
    expect_raises(ValueError,
                  lambda: DPPFConfig(consensus="lpf_sgd", engine="tree"),
                  "flat-only method on tree engine")
    lcfg = DPPFConfig(consensus="lpf_sgd", engine="flat")
    leng = ConsensusEngine.from_stacked(stacked, method="lpf_sgd")
    expect_raises(ValueError,
                  lambda: consensus.apply_round(leng.flatten(stacked), lcfg,
                                                0.1, {}, engine=leng),
                  "lpf_sgd without push_vec (flat)")
    ecfg = dataclasses.replace(dcfg, exact_second_term=True)
    expect_raises(ValueError,
                  lambda: consensus.apply_round(
                      flat, ecfg, 0.1, {}, losses=jnp.zeros((2,)),
                      engine=eng, mask=jnp.ones((2,))),
                  "elastic mask with exact second term")

    from repro.launch.mesh import make_hier_engine_mesh, make_hierarchical_mesh
    expect_raises(ValueError, lambda: make_hierarchical_mesh(7, 5, 3),
                  "hierarchical mesh with impossible factors")
    expect_raises(ValueError, lambda: make_hierarchical_mesh(0, 2, 2),
                  "hierarchical mesh with zero-size axis")
    import jax
    devs = jax.devices()
    expect_raises(ValueError,
                  lambda: make_hierarchical_mesh(2, 2, 2, devices=devs[:1]),
                  "hierarchical mesh product != given devices")
    expect_raises(ValueError,
                  lambda: make_hier_engine_mesh(len(devs) + 1, 2, 2),
                  "hierarchical engine mesh beyond host devices")

    from repro.launch.specs import train_batch_specs
    from repro.configs.base import InputShape
    from repro.configs import ARCHS
    shape = InputShape("odd", 8, 7, "train")
    expect_raises(ValueError,
                  lambda: train_batch_specs(ARCHS["yi-6b"], shape, 4, 2),
                  "train batch not divisible by workers")

    # serving surface: slot overflow, bad sampling params, ring-contract
    # conflicts — all ValueError (never assert) so they survive -O
    from repro.serving import Request, SamplingParams, Scheduler, SlotEngine
    from repro.serving import generate
    expect_raises(ValueError, lambda: SamplingParams(temperature=-1.0),
                  "SamplingParams negative temperature")
    expect_raises(ValueError, lambda: SamplingParams(top_k=-1),
                  "SamplingParams negative top_k")
    expect_raises(ValueError, lambda: SamplingParams(top_p=0.0),
                  "SamplingParams top_p out of range")
    expect_raises(ValueError, lambda: Scheduler(0),
                  "Scheduler zero slots")
    expect_raises(ValueError, lambda: Scheduler(1, mode="adaptive"),
                  "Scheduler unknown mode")
    expect_raises(ValueError,
                  lambda: Request(rid=0, tokens=np.zeros((0,)),
                                  max_new_tokens=1),
                  "Request empty prompt")

    from repro.configs import reduced
    from repro.models import build_model
    scfg = reduced(ARCHS["yi-6b"])
    smodel = build_model(scfg)
    sparams = smodel.init(jax.random.PRNGKey(0))
    expect_raises(ValueError,
                  lambda: SlotEngine(smodel, sparams, max_slots=0, buf_len=8),
                  "SlotEngine zero slots")
    expect_raises(ValueError,
                  lambda: SlotEngine(smodel, sparams, max_slots=1, buf_len=8,
                                     window=9),
                  "SlotEngine window exceeds buf_len")
    expect_raises(ValueError,
                  lambda: SlotEngine(smodel, sparams, max_slots=1, buf_len=16,
                                     window=16, chunk=8),
                  "SlotEngine chunk clobbers live ring slots")
    seng = SlotEngine(smodel, sparams, max_slots=1, buf_len=16)
    expect_raises(ValueError,
                  lambda: seng.insert(seng.blank_slots(), None, 1, 0, 0, 4,
                                      np.zeros(2, np.uint32)),
                  "SlotEngine slot overflow")
    expect_raises(ValueError,
                  lambda: Scheduler(1).submit(
                      Request(rid=0, tokens=np.ones((10,), np.int64),
                              max_new_tokens=10), seng),
                  "Scheduler submit beyond windowless buf_len")
    expect_raises(ValueError,
                  lambda: generate(smodel, sparams,
                                   {"tokens": np.zeros((1, 20), np.int32)},
                                   max_new_tokens=2, buf_len=16),
                  "generate windowless prompt overflow")

    from repro.models.attention import cache_update, init_cache
    import jax.numpy as jnp2
    cache = init_cache(1, 1, 4, 2, jnp2.float32)
    big = jnp2.zeros((1, 5, 1, 2))
    expect_raises(ValueError, lambda: cache_update(cache, big, big, 0),
                  "cache_update write exceeds buf_len")

    from repro.launch.roofline import serving_model
    expect_raises(ValueError,
                  lambda: serving_model(ARCHS["gemma2-2b"], max_slots=0,
                                        chunk=1, state_bytes_per_slot=1),
                  "serving_model zero slots")

    # autotune surface (--autotune CI leg runs under -O): the search
    # space, the qsr/autotune conflict, and the probe roofline all
    # validate via ValueError, never assert
    from repro.train.autotune import Candidate, TunePlan, TuneSpace
    expect_raises(ValueError, lambda: TuneSpace(probe_budget=0),
                  "TuneSpace probe budget < 1")
    expect_raises(ValueError, lambda: TuneSpace(min_batch=8, max_batch=4),
                  "TuneSpace min_batch > max_batch")
    qsr_plan = TunePlan(chosen=Candidate(batch=4, tau=4, overlap_chunks=1),
                        probes=(), failures=(), probe_budget=1,
                        probes_used=1, overlap="doublebuf", staleness=1,
                        residual_scale=1.0, dominates_model=True,
                        dominates_measured=True)
    expect_raises(ValueError,
                  lambda: DPPFConfig(engine="flat", tau_schedule="qsr",
                                     qsr_beta=0.4).apply_tune_plan(qsr_plan),
                  "apply_tune_plan under a qsr schedule")
    from repro.launch.roofline import probe_round_model
    expect_raises(ValueError,
                  lambda: probe_round_model(work_s_per_step=1e-6, tau=4,
                                            gather_bytes=1e6, mode="bogus"),
                  "probe_round_model unknown overlap mode")

    import tempfile, os
    from repro.checkpoint import load_pytree, save_pytree
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "x.npz")
        save_pytree(p, {"w": np.zeros((3, 3))})
        expect_raises(ValueError,
                      lambda: load_pytree(p, {"w": np.zeros((2, 2))}),
                      "checkpoint shape mismatch")
        with open(p, "r+b") as f:
            f.truncate(40)
        expect_raises(ValueError,
                      lambda: load_pytree(p, {"w": np.zeros((3, 3))}),
                      "checkpoint truncated archive")

    # fault-tolerance surface (--chaos CI leg runs under -O): ChaosPlan
    # authoring/payload guards, the membership tables, and the supervisor
    # policy knobs all validate via ValueError, never assert
    from repro.train import (ChaosEvent, ChaosPlan, HeartbeatMembership,
                             ScheduleMembership, Supervisor)
    expect_raises(ValueError, lambda: ChaosEvent(round=0, kind="meteor"),
                  "ChaosEvent unknown kind")
    expect_raises(ValueError, lambda: ChaosEvent(round=0, kind="oom"),
                  "ChaosEvent oom without batch_above")
    expect_raises(ValueError, lambda: ChaosEvent(round=0, kind="kill"),
                  "ChaosEvent kill without worker")
    expect_raises(ValueError, lambda: ChaosPlan.from_dict({"seed": 1}),
                  "ChaosPlan malformed payload")
    expect_raises(ValueError, lambda: ChaosPlan(version=99),
                  "ChaosPlan version mismatch")
    expect_raises(ValueError,
                  lambda: HeartbeatMembership(2, timeout=0.0),
                  "HeartbeatMembership timeout <= 0")
    expect_raises(ValueError,
                  lambda: ScheduleMembership(4, [(1, 3, 3)]),
                  "ScheduleMembership empty drop window")
    clk = RoundClock(total_steps=8, tau=4)
    expect_raises(ValueError, lambda: Supervisor(clk, workers=4, quorum=-1),
                  "Supervisor negative quorum")
    expect_raises(ValueError, lambda: Supervisor(clk, workers=4, quorum=5),
                  "Supervisor quorum > workers")
    expect_raises(ValueError,
                  lambda: Supervisor(clk, workers=4, retry_budget=-1),
                  "Supervisor negative retry budget")
    from repro.launch.roofline import supervisor_model
    expect_raises(ValueError,
                  lambda: supervisor_model(rounds=2, tau=2,
                                           work_s_per_step=1e-3,
                                           gather_bytes=1e6,
                                           degraded_rounds=3),
                  "supervisor_model degraded_rounds > rounds")

    # launcher flag surface (argparse exits with code 2 on ap.error)
    from repro.launch import train as train_mod
    expect_raises(SystemExit,
                  lambda: train_mod.main(["--smoke", "--elastic-drop",
                                          "2,5,3", "--overlap",
                                          "staleness_k"]),
                  "--elastic-drop empty/negative window")
    expect_raises(SystemExit,
                  lambda: train_mod.main(["--smoke", "--quorum", "2"]),
                  "--quorum without a membership source")
    expect_raises(SystemExit,
                  lambda: train_mod.main(["--smoke", "--elastic-drop",
                                          "1,0,2", "--quorum", "2",
                                          "--heartbeat-timeout", "0",
                                          "--overlap", "staleness_k"]),
                  "--heartbeat-timeout <= 0")
    print("python -O validation smoke: all checks raise")


if __name__ == "__main__":
    main()
