"""``python -O`` smoke for the de-asserted validation paths.

Run as a SCRIPT under ``python -O`` (the CI leg): with asserts stripped,
every user-facing check must still raise a real exception. Uses explicit
raises (not ``assert``) to report, since asserts are off by construction.

    PYTHONPATH=src python -O tests/optcheck.py
"""
from __future__ import annotations

import sys

import numpy as np


def expect_raises(exc, fn, label):
    try:
        fn()
    except exc:
        print(f"ok: {label}")
        return
    raise SystemExit(f"FAIL: {label} did not raise {exc.__name__} "
                     f"(python -O stripped the check?)")


def main():
    if __debug__:
        print("warning: running with asserts ON — use python -O",
              file=sys.stderr)

    from repro.configs.base import DPPFConfig
    expect_raises(ValueError, lambda: DPPFConfig(engine="nope"),
                  "DPPFConfig unknown engine")
    expect_raises(ValueError, lambda: DPPFConfig(tau_schedule="nope"),
                  "DPPFConfig unknown tau schedule")
    expect_raises(ValueError, lambda: DPPFConfig(tau_schedule="qsr"),
                  "DPPFConfig qsr without beta")
    expect_raises(ValueError, lambda: DPPFConfig(overlap="bogus"),
                  "DPPFConfig unknown overlap mode")
    expect_raises(ValueError,
                  lambda: DPPFConfig(engine="flat", overlap="doublebuf",
                                     overlap_chunks=0),
                  "DPPFConfig overlap_chunks < 1")
    expect_raises(ValueError, lambda: DPPFConfig(overlap="doublebuf"),
                  "DPPFConfig doublebuf on tree engine")

    from repro.train import RoundClock
    expect_raises(ValueError,
                  lambda: RoundClock(total_steps=8, tau=4,
                                     tau_schedule="qsr", qsr_beta=0.0),
                  "RoundClock qsr without beta")
    expect_raises(ValueError,
                  lambda: RoundClock(total_steps=8, tau=4, overlap="bogus"),
                  "RoundClock unknown overlap mode")
    expect_raises(ValueError,
                  lambda: RoundClock(total_steps=8, tau=4, warmup=-1),
                  "RoundClock negative warmup")

    from repro.core import consensus
    import jax.numpy as jnp
    stacked = {"w": jnp.zeros((2, 3))}
    expect_raises(ValueError,
                  lambda: consensus.consensus_target("lsgd", stacked, {}),
                  "lsgd without losses (tree)")
    expect_raises(ValueError,
                  lambda: consensus.consensus_target("mgrawa", stacked, {}),
                  "mgrawa without grad norms (tree)")
    from repro.core.engine import ConsensusEngine
    eng = ConsensusEngine.from_stacked(stacked, method="lsgd")
    flat = eng.flatten(stacked)
    dcfg = DPPFConfig(consensus="lsgd", engine="flat")
    expect_raises(ValueError,
                  lambda: consensus.apply_round(flat, dcfg, 0.1, {},
                                                engine=eng),
                  "lsgd without losses (flat)")

    from repro.launch.mesh import make_hier_engine_mesh, make_hierarchical_mesh
    expect_raises(ValueError, lambda: make_hierarchical_mesh(7, 5, 3),
                  "hierarchical mesh with impossible factors")
    expect_raises(ValueError, lambda: make_hierarchical_mesh(0, 2, 2),
                  "hierarchical mesh with zero-size axis")
    import jax
    devs = jax.devices()
    expect_raises(ValueError,
                  lambda: make_hierarchical_mesh(2, 2, 2, devices=devs[:1]),
                  "hierarchical mesh product != given devices")
    expect_raises(ValueError,
                  lambda: make_hier_engine_mesh(len(devs) + 1, 2, 2),
                  "hierarchical engine mesh beyond host devices")

    from repro.launch.specs import train_batch_specs
    from repro.configs.base import InputShape
    from repro.configs import ARCHS
    shape = InputShape("odd", 8, 7, "train")
    expect_raises(ValueError,
                  lambda: train_batch_specs(ARCHS["yi-6b"], shape, 4, 2),
                  "train batch not divisible by workers")

    import tempfile, os
    from repro.checkpoint import load_pytree, save_pytree
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "x.npz")
        save_pytree(p, {"w": np.zeros((3, 3))})
        expect_raises(ValueError,
                      lambda: load_pytree(p, {"w": np.zeros((2, 2))}),
                      "checkpoint shape mismatch")
    print("python -O validation smoke: all checks raise")


if __name__ == "__main__":
    main()
