"""Fault-tolerant round supervisor (DESIGN.md §Fault-tolerance): the
replayable ChaosPlan artifact, the heartbeat membership state machine
(ACTIVE -> SUSPECT -> DEAD -> REJOINING), quorum degrade through the
elastic carry's scalar ``sync`` gate, crash-safe checkpoint rotation with
the corrupt-archive restore ladder, and the OOM shrink + replay path.

The acceptance contracts pinned here:

* an empty plan (no membership, no chaos) makes the supervisor loop
  bit-for-bit the plain ``for spec in clock.rounds`` loop it replaced;
* ``ScheduleMembership`` (the ``--elastic-drop`` provider) is bit-for-bit
  the old inline ``set_participation`` loop;
* the SAME plan replayed from a fresh init walks a bit-identical
  recovery-event sequence and lands on bit-identical params;
* the committed 8-device CI leg (``results/chaos/plan_ci.json``) emits
  exactly the pinned sequence in ``results/chaos/events_ci.json``.

Multi-device legs run in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (the
test_staleness_k.py pattern)."""
from __future__ import annotations

import dataclasses
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    load_pytree, load_train_state, save_pytree, save_train_state,
)
from repro.configs import DPPFConfig
from repro.optim import make_optimizer
from repro.train import (
    ChaosEvent, ChaosMembership, ChaosPlan, FaultInjector,
    HeartbeatMembership, InjectedOOM, RoundClock, ScheduleMembership,
    Supervisor, init_train_state, is_oom, make_round_step,
    set_participation,
)
from repro.train.supervisor import ACTIVE, DEAD, REJOINING, SUSPECT

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
ROOT = os.path.join(os.path.dirname(__file__), "..")

M, TAU, K = 4, 2, 2


def _setup(steps=12, elastic=True):
    from benchmarks.common import mlp_init, mlp_loss
    dim, ncls, width = 16, 4, 8
    opt = make_optimizer("sgd", momentum=0.9)
    p0 = lambda k: mlp_init(k, dim, ncls, width)
    dcfg = DPPFConfig(alpha=0.2, lam=0.4, tau=TAU, engine="flat",
                      overlap="staleness_k", staleness=K, elastic=elastic,
                      lam_schedule="fixed")
    clock = RoundClock.from_config(dcfg, base_lr=0.05, total_steps=steps)
    step = jax.jit(make_round_step(mlp_loss, opt, dcfg, clock=clock))

    def batch_fn(spec, bs):
        k = jax.random.fold_in(jax.random.PRNGKey(1), spec.index)
        return {"x": jax.random.normal(k, (spec.tau, M, bs, dim)),
                "y": jax.random.randint(jax.random.fold_in(k, 1),
                                        (spec.tau, M, bs), 0, ncls)}
    state = init_train_state(p0, opt, dcfg, M, jax.random.PRNGKey(0))
    return dcfg, clock, step, state, batch_fn, (p0, opt)


def _params(state):
    return np.asarray(jax.device_get(state.params))


# ---------------------------------------------------------------------------
# ChaosPlan: the byte-stable fault script
# ---------------------------------------------------------------------------

def test_chaos_plan_roundtrip_bytes(tmp_path):
    """save -> load -> dumps is byte-identical, and the canonical event
    sort makes dumps() independent of authoring order (the TunePlan
    idiom)."""
    a = ChaosPlan(events=(
        ChaosEvent(round=5, kind="oom", batch_above=2),
        ChaosEvent(round=1, kind="kill", worker=3, duration=2),
        ChaosEvent(round=1, kind="corrupt_ckpt"),
    ), seed=3)
    b = ChaosPlan(events=tuple(reversed(a.events)), seed=3)
    assert a.dumps() == b.dumps()
    path = str(tmp_path / "plan.json")
    a.save(path)
    assert ChaosPlan.load(path).dumps() == a.dumps()
    with open(path) as f:
        assert f.read() == a.dumps()
    # membership window query
    assert a.is_down(3, 1) and a.is_down(3, 2) and not a.is_down(3, 3)
    assert not a.is_down(0, 1)
    assert len(a.membership_events()) == 1


def test_chaos_plan_validation():
    with pytest.raises(ValueError, match="unknown chaos kind"):
        ChaosEvent(round=0, kind="meteor")
    with pytest.raises(ValueError, match="round"):
        ChaosEvent(round=-1, kind="corrupt_ckpt")
    with pytest.raises(ValueError, match="duration"):
        ChaosEvent(round=0, kind="kill", worker=0, duration=0)
    with pytest.raises(ValueError, match="worker"):
        ChaosEvent(round=0, kind="netdrop")
    with pytest.raises(ValueError, match="batch_above"):
        ChaosEvent(round=0, kind="oom")
    with pytest.raises(ValueError, match="version"):
        ChaosPlan(version=99)
    with pytest.raises(ValueError, match="malformed ChaosPlan"):
        ChaosPlan.from_dict({"seed": 0})        # no events key
    with pytest.raises(ValueError, match="malformed ChaosPlan"):
        ChaosPlan.from_dict({"events": [{"kind": "oom"}]})  # no round
    # the injected failure satisfies the PR 9 message contract
    assert is_oom(InjectedOOM(8))
    assert is_oom(InjectedOOM(8, round_idx=3))
    assert "round 3" in str(InjectedOOM(8, round_idx=3))


def test_fault_injector_hooks(tmp_path):
    plan = ChaosPlan(events=(
        ChaosEvent(round=2, kind="oom", batch_above=2),
        ChaosEvent(round=1, kind="corrupt_ckpt"),
    ))
    inj = FaultInjector(plan)
    inj.before_step(1, 8)                     # wrong round: no fault
    inj.before_step(2, 2)                     # at the threshold: cleared
    with pytest.raises(InjectedOOM):
        inj.before_step(2, 4)
    path = str(tmp_path / "c.npz")
    save_pytree(path, {"w": np.arange(64.0)})
    assert not inj.after_save(0, path)        # wrong round: untouched
    load_pytree(path, {"w": np.zeros(64)})
    assert inj.after_save(1, path)            # torn to half its bytes
    with pytest.raises(ValueError, match="corrupt"):
        load_pytree(path, {"w": np.zeros(64)})


# ---------------------------------------------------------------------------
# membership state machine
# ---------------------------------------------------------------------------

def test_heartbeat_state_machine():
    hb = HeartbeatMembership(3, timeout=0.9, suspect_after=1, dead_after=2)
    mask, tr = hb.poll(0.0)                   # everyone fresh
    np.testing.assert_array_equal(mask, [1, 1, 1])
    assert tr == []
    hb.beat(0, 1.0), hb.beat(1, 1.0)          # worker 2 silent
    mask, tr = hb.poll(1.0)
    assert tr == [(2, ACTIVE, SUSPECT)]
    np.testing.assert_array_equal(mask, [1, 1, 0])
    hb.beat(0, 2.0), hb.beat(1, 2.0)
    mask, tr = hb.poll(2.0)
    assert tr == [(2, SUSPECT, DEAD)]
    # first beat after DEAD: back in the mask as REJOINING
    assert hb.beat(2, 3.0) == [(2, DEAD, REJOINING)]
    mask, _ = hb.poll(3.0)
    np.testing.assert_array_equal(mask, [0, 0, 1])  # 0/1 now silent
    assert hb.beat(2, 4.0) == [(2, REJOINING, ACTIVE)]
    # a SUSPECT beat recovers straight to ACTIVE
    assert hb.beat(0, 4.0) == [(0, SUSPECT, ACTIVE)]
    with pytest.raises(ValueError, match="out of range"):
        hb.beat(3, 0.0)
    with pytest.raises(ValueError, match="timeout"):
        HeartbeatMembership(2, timeout=0.0)
    with pytest.raises(ValueError, match="suspect_after"):
        HeartbeatMembership(2, timeout=1.0, suspect_after=3, dead_after=2)


def test_chaos_membership_windows_and_monotonic_advance():
    plan = ChaosPlan(events=(
        ChaosEvent(round=1, kind="kill", worker=1, duration=2),))
    cm = ChaosMembership(plan, 2, timeout=0.9)
    mask, ev = cm.mask_for(0)
    np.testing.assert_array_equal(mask, [1, 1])
    assert ev == []
    mask, ev = cm.mask_for(1)
    np.testing.assert_array_equal(mask, [1, 0])
    assert ev == [{"event": "suspect", "worker": 1, "from": ACTIVE}]
    with pytest.raises(ValueError, match="one round at a time"):
        cm.mask_for(1)                        # replays go through the cache
    mask, ev = cm.mask_for(2)
    assert [e["event"] for e in ev] == ["evict"]
    _, ev = cm.mask_for(3)                    # window over: beat -> rejoin
    assert [e["event"] for e in ev] == ["rejoin"]
    _, ev = cm.mask_for(4)
    assert [e["event"] for e in ev] == ["recover"]
    with pytest.raises(ValueError, match="round_s"):
        ChaosMembership(plan, 2, timeout=0.9, round_s=0.0)


def test_schedule_membership_validation():
    with pytest.raises(ValueError, match="out of range"):
        ScheduleMembership(4, [(7, 0, 2)])
    with pytest.raises(ValueError, match="empty or negative"):
        ScheduleMembership(4, [(1, 3, 3)])
    sm = ScheduleMembership(4, [(1, 1, 3)])
    np.testing.assert_array_equal(sm.mask_for(0)[0], [1, 1, 1, 1])
    np.testing.assert_array_equal(sm.mask_for(2)[0], [1, 0, 1, 1])


# ---------------------------------------------------------------------------
# the sync gate: degraded rounds skip consensus bit-exactly
# ---------------------------------------------------------------------------

def test_sync_gate_value_identity_and_degrade():
    """``sync=1.0`` is value-identical to the pre-supervisor call (bit
    parity of the old --elastic-drop path); ``sync=0`` changes the round
    (consensus skipped) but carries through the ring unchanged."""
    _, clock, step, st0, batch_fn, _ = _setup()
    assert float(st0.snap["sync"]) == 1.0
    mask = jnp.ones((M,), jnp.float32)
    a = set_participation(st0, mask)               # sync untouched
    b = set_participation(st0, mask, sync=1.0)     # explicit
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    # run two rounds so the consensus application actually lands
    on = set_participation(st0, mask, sync=1.0)
    off = set_participation(st0, mask, sync=0.0)
    for spec in clock.rounds[:2]:
        on, _ = step(on, batch_fn(spec, 8))
        off, _ = step(off, batch_fn(spec, 8))
    assert np.abs(_params(on) - _params(off)).max() > 0.0
    assert float(off.snap["sync"]) == 0.0          # carried, not reset
    assert np.isfinite(_params(off)).all()
    # flipping the gate back re-enables consensus mid-run
    off = set_participation(off, mask, sync=1.0)
    off, _ = step(off, batch_fn(clock.rounds[2], 8))
    assert np.isfinite(_params(off)).all()


def test_sync_gate_requires_elastic_carry():
    # non-elastic states have no participation carry at all
    _, _, _, st, _, _ = _setup(elastic=False)
    with pytest.raises(ValueError, match="elastic"):
        set_participation(st, jnp.ones((M,)), sync=0.0)
    # an elastic state whose snap predates the gate (legacy, in-memory)
    # refuses a sync override with a clear error
    _, _, _, st_e, _, _ = _setup()
    legacy = dataclasses.replace(
        st_e, snap={k: v for k, v in st_e.snap.items() if k != "sync"})
    with pytest.raises(ValueError, match="sync"):
        set_participation(legacy, jnp.ones((M,)), sync=0.0)


# ---------------------------------------------------------------------------
# supervisor: parity, recovery, determinism
# ---------------------------------------------------------------------------

def test_supervisor_empty_plan_is_plain_loop():
    """THE transparency acceptance: no membership, no chaos, no ckpt_dir
    -> the supervisor is bit-for-bit the inline round loop."""
    _, clock, step, st_a, batch_fn, _ = _setup()
    for spec in clock.rounds:
        st_a, _ = step(st_a, batch_fn(spec, 8))
    _, _, step2, st_b, _, _ = _setup()
    sup = Supervisor(clock, workers=M, batch_size=8)
    st_b = sup.run(st_b, step2, batch_fn)
    np.testing.assert_array_equal(_params(st_a), _params(st_b))
    assert sup.events == [] and sup.summary()["counters"] == {}


def test_supervisor_schedule_membership_parity():
    """ScheduleMembership == the old inline --elastic-drop loop, bit for
    bit (mask applied every round, sync pinned at its carried 1.0)."""
    drop = (1, 1, 3)
    _, clock, step, st_a, batch_fn, _ = _setup()
    for spec in clock.rounds:
        mask = np.ones(M, np.float32)
        if drop[1] <= spec.index < drop[2]:
            mask[drop[0]] = 0.0
        st_a = set_participation(st_a, jnp.asarray(mask))
        st_a, _ = step(st_a, batch_fn(spec, 8))
    _, _, step2, st_b, _, _ = _setup()
    sup = Supervisor(clock, workers=M,
                     membership=ScheduleMembership(M, [drop]),
                     batch_size=8)
    st_b = sup.run(st_b, step2, batch_fn)
    np.testing.assert_array_equal(_params(st_a), _params(st_b))
    assert sup.events == []                   # a requested drop: no fault


def _chaos_supervised_run(tmp_path, plan, tag, *, quorum=M, logger=None,
                          retry_budget=3, batch=8):
    _, clock, step, state, batch_fn, _ = _setup()
    d = str(tmp_path / tag)
    sup = Supervisor(clock, workers=M,
                     membership=ChaosMembership(plan, M, timeout=0.9),
                     quorum=quorum, chaos=FaultInjector(plan), ckpt_dir=d,
                     batch_size=batch, logger=logger,
                     retry_budget=retry_budget, seed=plan.seed)
    state = sup.run(state, step, batch_fn)
    return sup, state


def test_supervisor_oom_shrink_restore_replay(tmp_path):
    plan = ChaosPlan(events=(
        ChaosEvent(round=2, kind="oom", batch_above=4),), seed=5)
    sup, state = _chaos_supervised_run(tmp_path, plan, "a")
    # saves: the pre-loop anchor + 6 rounds, round 2 saved once on replay
    assert sup.summary()["counters"] == {
        "ckpt_saved": 7, "oom": 1, "restore": 1, "retry": 1, "shrink": 1}
    assert sup.batch_size == 4                # halved 8 -> 4
    seq = sup.event_seq()
    assert seq[:2] == ["r2:oom", "r2:shrink"]
    assert "r2:restore" in seq and "r2:retry" in seq
    # replay determinism: fresh init, same plan -> identical timeline
    # AND identical final params
    sup2, state2 = _chaos_supervised_run(tmp_path, plan, "b")
    assert sup2.event_seq() == seq
    np.testing.assert_array_equal(_params(state), _params(state2))
    # every recovery action also went through the metrics logger path
    rows = []
    sup3, _ = _chaos_supervised_run(
        tmp_path, plan, "c",
        logger=lambda spec, m: rows.append((spec, dict(m))))
    evs = [m["event"] for _, m in rows if "event" in m]
    assert evs == ["oom", "shrink", "restore", "retry"]


def test_supervisor_corrupt_ckpt_ladder(tmp_path):
    """A torn sup_last drops the restore to the prev rotation copy; the
    recovery replays one extra round and still completes."""
    plan = ChaosPlan(events=(
        ChaosEvent(round=1, kind="corrupt_ckpt"),
        ChaosEvent(round=2, kind="oom", batch_above=4),), seed=5)
    sup, state = _chaos_supervised_run(tmp_path, plan, "a")
    c = sup.summary()["counters"]
    assert c["restore_corrupt"] == 1 and c["restore"] == 1
    seq = sup.event_seq()
    assert seq.index("r2:restore_corrupt") < seq.index("r2:restore")
    # the prev copy holds round 1's state -> replay from round 1
    assert any(e["event"] == "restore" and "round 1" in e["detail"]
               for e in sup.events)
    assert np.isfinite(_params(state)).all()


def test_supervisor_quorum_degrade_backoff(tmp_path):
    """Below-quorum rounds degrade (sync=0), emit deterministic backoff,
    and never fail the run; the recorded jitter is pure sha256 state."""
    plan = ChaosPlan(events=(
        ChaosEvent(round=1, kind="kill", worker=0, duration=1),
        ChaosEvent(round=1, kind="netdrop", worker=2, duration=1),), seed=9)
    sup, state = _chaos_supervised_run(tmp_path, plan, "a", quorum=3)
    c = sup.summary()["counters"]
    assert c["degrade"] == 1 and "restore" not in c
    deg = [e for e in sup.events if e["event"] == "degrade"]
    assert deg[0]["attempt"] == 1 and deg[0]["backoff_s"] > 0
    sup2, _ = _chaos_supervised_run(tmp_path, plan, "b", quorum=3)
    assert [e.get("backoff_s") for e in sup2.events] == \
        [e.get("backoff_s") for e in sup.events]
    assert np.isfinite(_params(state)).all()


def test_supervisor_retry_budget_and_non_oom(tmp_path):
    """A persistent non-OOM failure propagates after retry_budget
    consecutive restore+replay attempts; with no ckpt_dir it propagates
    immediately (nothing to restore a donated state from)."""
    _, clock, step, state, batch_fn, _ = _setup()

    calls = {"n": 0}

    def bad_step(st, batch):
        calls["n"] += 1
        raise RuntimeError("xla miscompile of the week")

    sup = Supervisor(clock, workers=M, ckpt_dir=str(tmp_path / "d"),
                     batch_size=8, retry_budget=2)
    with pytest.raises(RuntimeError, match="miscompile"):
        sup.run(state, bad_step, batch_fn)
    assert calls["n"] == 3                    # 1 try + 2 retries
    assert sup.summary()["counters"]["retry"] == 2
    assert "oom" not in sup.summary()["counters"]

    _, _, _, state2, _, _ = _setup()
    sup2 = Supervisor(clock, workers=M, batch_size=8)   # no ckpt_dir
    with pytest.raises(RuntimeError):
        sup2.run(state2, bad_step, batch_fn)
    assert sup2.events == []


def test_supervisor_oom_floor_propagates(tmp_path):
    """When the batch cannot shrink further (size 1), the OOM
    propagates instead of death-looping."""
    _, clock, _, state, batch_fn, _ = _setup()

    def oom_step(st, batch):
        raise RuntimeError("RESOURCE_EXHAUSTED: out of memory allocating")

    sup = Supervisor(clock, workers=M, ckpt_dir=str(tmp_path / "d"),
                     batch_size=1)
    with pytest.raises(RuntimeError, match="RESOURCE_EXHAUSTED"):
        sup.run(state, oom_step, batch_fn)
    c = sup.summary()["counters"]
    assert c["oom"] == 1 and "shrink" not in c


def test_supervisor_validation():
    _, clock, _, _, _, _ = _setup()
    with pytest.raises(ValueError, match="workers"):
        Supervisor(clock, workers=0)
    with pytest.raises(ValueError, match="quorum"):
        Supervisor(clock, workers=M, quorum=-1)
    with pytest.raises(ValueError, match="exceeds the worker count"):
        Supervisor(clock, workers=M, quorum=M + 1)
    with pytest.raises(ValueError, match="retry_budget"):
        Supervisor(clock, workers=M, retry_budget=-1)
    with pytest.raises(ValueError, match="ckpt_every"):
        Supervisor(clock, workers=M, ckpt_every=0)
    with pytest.raises(ValueError, match="backoff_base"):
        Supervisor(clock, workers=M, backoff_base=0.0)
    with pytest.raises(ValueError, match="membership provider"):
        Supervisor(clock, workers=M,
                   membership=ScheduleMembership(M + 1, []))


# ---------------------------------------------------------------------------
# crash-safe checkpoints (checkpoint/io.py)
# ---------------------------------------------------------------------------

def test_checkpoint_atomic_write_and_corrupt_errors(tmp_path):
    tree = {"w": np.arange(32.0).reshape(8, 4), "b": np.zeros(4)}
    path = str(tmp_path / "ck.npz")
    save_pytree(path, tree)
    # atomic rename: no stray temp files next to the final archive
    assert os.listdir(str(tmp_path)) == ["ck.npz"]
    out, _ = load_pytree(path, jax.tree.map(np.zeros_like, tree))
    np.testing.assert_array_equal(np.asarray(out["w"]), tree["w"])
    # a truncated archive is a clear ValueError naming the path, NOT a
    # raw zipfile/zlib traceback
    with open(path, "rb") as f:
        data = f.read()
    with open(path, "wb") as f:
        f.write(data[:len(data) // 2])
    with pytest.raises(ValueError, match="truncated or corrupt") as ei:
        load_pytree(path, jax.tree.map(np.zeros_like, tree))
    assert "ck.npz" in str(ei.value)
    # non-zip garbage: same contract
    with open(path, "wb") as f:
        f.write(b"\x00" * 100)
    with pytest.raises(ValueError, match="truncated or corrupt"):
        load_pytree(path, jax.tree.map(np.zeros_like, tree))
    # a MISSING file stays FileNotFoundError (never re-wrapped)
    with pytest.raises(FileNotFoundError):
        load_pytree(str(tmp_path / "nope.npz"),
                    jax.tree.map(np.zeros_like, tree))


def test_legacy_checkpoint_sync_backfill(tmp_path):
    """A pre-supervisor elastic checkpoint (no snap::sync entry) loads
    into today's template with the gate backfilled to 1.0 — consensus
    stays ON, bit-compatible with the old behavior."""
    _, clock, step, st, batch_fn, _ = _setup()
    st, _ = step(st, batch_fn(clock.rounds[0], 8))
    legacy = dataclasses.replace(
        st, snap={k: v for k, v in st.snap.items() if k != "sync"})
    path = str(tmp_path / "legacy.npz")
    save_train_state(path, legacy)
    _, _, _, like, _, _ = _setup()
    res = load_train_state(path, like, clock=clock)
    assert float(res.snap["sync"]) == 1.0
    np.testing.assert_array_equal(np.asarray(res.snap["x"]),
                                  np.asarray(st.snap["x"]))


# ---------------------------------------------------------------------------
# the committed CI plan: pinned recovery-event sequence, 8 devices
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_chaos_ci_plan_pinned_sequence_8dev():
    """THE chaos acceptance leg: the committed plan
    (results/chaos/plan_ci.json) driven through the real launcher on 8
    forced host devices (sharded round, donated buffers, shard_map
    restore placement) reproduces results/chaos/events_ci.json exactly
    — recovery-event sequence, counters, and final batch."""
    with open(os.path.join(ROOT, "results", "chaos",
                           "events_ci.json")) as f:
        pinned = json.load(f)
    env = dict(os.environ, PYTHONPATH=SRC + os.pathsep + ROOT,
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "yi-6b",
         "--smoke", "--d-model", "32", "--layers", "1", "--seq", "16",
         "--workers", "8", "--tau", "2", "--steps", "16", "--batch", "2",
         "--overlap", "staleness_k", "--staleness", "2", "--sharded",
         "--chaos", os.path.join("results", "chaos", "plan_ci.json"),
         "--quorum", "7", "--heartbeat-timeout", "0.9"],
        capture_output=True, text=True, env=env, timeout=560, cwd=ROOT)
    assert out.returncode == 0, out.stderr[-4000:]
    ev_line = [l for l in out.stdout.splitlines()
               if l.startswith("supervisor events: ")]
    assert ev_line, out.stdout[-2000:]
    assert ev_line[0].split(": ", 1)[1].split() == pinned["event_seq"]
    ct_line = [l for l in out.stdout.splitlines()
               if l.startswith("supervisor counters: ")][0]
    got = dict(kv.split("=") for kv in ct_line.split(": ", 1)[1].split())
    assert int(got.pop("final_batch")) == pinned["final_batch"]
    assert {k: int(v) for k, v in got.items()} == pinned["counters"]
