"""Per-architecture smoke tests: instantiate the REDUCED variant of each
assigned family and run one forward/loss, one train-gradient step, and a
prefill+decode step on CPU, asserting shapes and no NaNs."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.models import build_model

SEQ = 32
BATCH = 2


def _batch(cfg, key):
    k1, k2 = jax.random.split(key)
    tokens = jax.random.randint(k1, (BATCH, SEQ), 0, cfg.vocab_size)
    labels = jnp.roll(tokens, -1, axis=1).at[:, -1].set(-1)
    batch = {"tokens": tokens, "labels": labels}
    if cfg.n_enc_layers:
        batch["enc"] = jax.random.normal(k2, (BATCH, cfg.n_prefix, cfg.d_model),
                                         jnp.float32)
    elif cfg.n_prefix:
        batch["prefix"] = jax.random.normal(
            k2, (BATCH, cfg.n_prefix, cfg.d_model), jnp.float32)
    return batch


@pytest.fixture(scope="module", params=sorted(ARCHS))
def arch(request):
    return request.param


def test_smoke_loss_and_grad(arch):
    cfg = reduced(ARCHS[arch])
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    batch = _batch(cfg, jax.random.PRNGKey(1))

    (loss, metrics), grads = jax.value_and_grad(model.loss, has_aux=True)(
        params, batch)
    assert np.isfinite(float(loss)), f"{arch}: loss not finite"
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0, f"{arch}: bad grads"

    # one SGD step moves the loss
    lr = 0.05
    params2 = jax.tree.map(lambda p, g: p - lr * g.astype(p.dtype), params, grads)
    loss2, _ = model.loss(params2, batch)
    assert np.isfinite(float(loss2))


def test_smoke_prefill_decode(arch):
    cfg = reduced(ARCHS[arch])
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))

    buf = SEQ + cfg.n_prefix + 8 if not cfg.n_enc_layers else SEQ + 8
    logits, states = model.prefill(params, batch, buf_len=buf)
    assert logits.shape == (BATCH, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), f"{arch}: prefill NaN"

    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    start = SEQ + (cfg.n_prefix if not cfg.n_enc_layers else 0)
    logits2, states = model.decode_step(params, states, tok, jnp.int32(start))
    assert logits2.shape == (BATCH, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits2, np.float32)).all(), f"{arch}: decode NaN"


def test_decode_matches_teacher_forcing(arch):
    """Prefill+decode logits must match the train-mode logits at the same
    position (the KV-cache path is consistent with the parallel path)."""
    cfg = reduced(ARCHS[arch])
    if cfg.n_enc_layers:
        pytest.skip("covered by dense comparison below for decoder-only")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))

    from repro.models.transformer import lm_logits
    full, _ = lm_logits(cfg, params, batch["tokens"], batch.get("prefix"))

    # prefill on all but the last token, then decode the last token
    short = dict(batch)
    short["tokens"] = batch["tokens"][:, :-1]
    buf = SEQ + cfg.n_prefix + 8
    _, states = model.prefill(params, short, buf_len=buf)
    pos = SEQ - 1 + cfg.n_prefix
    logits, _ = model.decode_step(params, states, batch["tokens"][:, -1:],
                                  jnp.int32(pos))
    np.testing.assert_allclose(np.asarray(logits), np.asarray(full[:, -1]),
                               rtol=2e-2, atol=2e-2)
