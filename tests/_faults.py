"""Fault-injection fixtures for the autotune test rig (DESIGN.md
§Autotune).

The OOM contract is message-based (``autotune.is_oom`` token-matches
``RESOURCE_EXHAUSTED`` / out-of-memory text), so a scripted runner can
exercise the real backoff path — doubling probes, binary refinement,
never-retry caching, budget exhaustion — with zero devices and a
deterministic feasibility frontier. ``scripted_runner`` is that runner;
``noisy_time_fn`` perturbs a timing oracle with bounded, seed-stable
multiplicative noise for the property tests (noise must never flip the
chosen point — selection goes through the calibrated MODEL score).

``InjectedOOM`` itself now lives in ``repro.train.chaos`` — ONE shared
fault-injection helper for the autotune rig, the chaos supervisor, and
their tests — and is re-exported here for the existing imports.
"""
from __future__ import annotations

import hashlib

from repro.train.chaos import InjectedOOM  # noqa: F401 (shared contract)


def default_time_fn(cand) -> float:
    """Smooth deterministic pseudo-round-time in microseconds: a fixed
    per-round overhead, linear work in batch*tau, and a small chunking
    overhead — shaped so larger batch and tau amortize better per sample
    (matching the roofline model's monotonicity)."""
    return 100.0 + 5.0 * cand.batch * cand.tau + 3.0 / cand.overlap_chunks


def scripted_runner(*, fail_above=None, fail_batches=(), time_fn=None,
                    log=None):
    """A probe runner with a scripted feasibility frontier: candidates
    with ``batch > fail_above`` or ``batch in fail_batches`` raise
    :class:`InjectedOOM`; the rest return ``time_fn(cand)`` microseconds.
    ``log`` (a list) records every candidate actually RUN — the
    never-retry tests assert on it."""
    tf = time_fn or default_time_fn

    def run(cand):
        if log is not None:
            log.append(cand)
        if fail_above is not None and cand.batch > fail_above:
            raise InjectedOOM(cand.batch)
        if cand.batch in fail_batches:
            raise InjectedOOM(cand.batch)
        return float(tf(cand))
    return run


def noisy_time_fn(base_fn, *, noise=0.05, seed=0):
    """Wrap a timing oracle with bounded multiplicative noise in
    ``[1 - noise, 1 + noise]``, deterministic per (seed, candidate) via
    sha256 — hypothesis property runs stay reproducible without any
    global RNG state."""

    def tf(cand):
        h = hashlib.sha256(
            f"{seed}:{cand.batch}:{cand.tau}:{cand.overlap_chunks}"
            .encode()).digest()
        u = int.from_bytes(h[:8], "big") / float(1 << 64)   # [0, 1)
        return base_fn(cand) * (1.0 + noise * (2.0 * u - 1.0))
    return tf
