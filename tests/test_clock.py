"""RoundClock: the lam-schedule off-by-one regression (round 0 sees
``lam_schedule(·, 0, T)``, the final round the full lam, in EVERY round
builder), QSR adaptive tau (constant-tau runs bit-for-bit equal to fixed
tau; adaptive runs save rounds at matching loss), remainder-step
accounting, checkpointed clock position, and the serving ``generate``
edge cases (max_new_tokens=1; first-sample key vs the fold-in chain)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks.common import mlp_init, mlp_loss
from repro.checkpoint import load_train_state, save_train_state
from repro.configs import DPPFConfig, MeshPlan
from repro.core.schedules import lam_schedule
from repro.optim import make_optimizer
from repro.train import (
    RoundClock, init_train_state, make_round_step, make_sharded_round_step,
    shard_train_state,
)
from repro.train.trainer import TrainState

LAM = 0.5


def _setup(M=4, dim=16, ncls=4, width=8):
    opt = make_optimizer("sgd", momentum=0.9)
    p0 = lambda k: mlp_init(k, dim, ncls, width)

    def batch(tau, start):
        k = jax.random.fold_in(jax.random.PRNGKey(7), start)
        return {"x": jax.random.normal(k, (tau, M, 8, dim)),
                "y": jax.random.randint(jax.random.fold_in(k, 1),
                                        (tau, M, 8), 0, ncls)}
    return opt, p0, mlp_loss, batch


# ---------------------------------------------------------------------------
# the round plan
# ---------------------------------------------------------------------------

def test_round_plan_fixed_with_remainder():
    clock = RoundClock(total_steps=10, tau=4)
    assert [(s.index, s.start, s.tau) for s in clock.rounds] == [
        (0, 0, 4), (1, 4, 4), (2, 8, 2)]          # remainder runs, 10 == 10
    assert clock.total_rounds == 3 == clock.fixed_rounds
    assert sum(clock.taus()) == 10
    assert clock.round_of_step(0) == 0
    assert clock.round_of_step(4) == 1
    assert clock.round_of_step(9) == 2
    assert clock.round_of_step(10) == 3           # finished
    with pytest.raises(ValueError):
        clock.round_of_step(11)


def test_describe_returns_full_round_plan():
    """describe() carries the per-round plan (the dry-run report table and
    the committed BENCH_roundclock.json baseline both render it); the
    docstring's worked QSR example is pinned here."""
    clock = RoundClock(total_steps=10, tau=4, base_lr=0.1, lam=0.5,
                       lam_kind="increasing")
    d = clock.describe()
    assert [(r["round"], r["start"], r["tau"]) for r in d["plan"]] == [
        (0, 0, 4), (1, 4, 4), (2, 8, 2)]
    # lam spans both endpoints: round 0 zero (increasing), last round full
    assert d["plan"][0]["lam"] == 0.0
    assert abs(d["plan"][-1]["lam"] - 0.5) < 1e-6
    # lam matches the traced read the builders use
    for r in d["plan"]:
        assert abs(r["lam"] - float(clock.lam_at(r["round"]))) < 1e-6
    # lr window: cosine from base_lr down toward 0
    assert abs(d["plan"][0]["lr_start"] - 0.1) < 1e-6
    assert d["plan"][-1]["lr_end"] < d["plan"][0]["lr_start"]
    for r in d["plan"]:
        assert abs(r["lr_start"] - float(clock.lr_at(r["start"]))) < 1e-6

    # the worked QSR example from the describe() docstring
    qsr = RoundClock(total_steps=64, tau=4, base_lr=0.3,
                     tau_schedule="qsr", qsr_beta=0.4)
    assert qsr.taus() == (4, 4, 4, 4, 4, 4, 4, 4, 7, 16, 9)
    dq = qsr.describe()
    assert dq["rounds"] == 11 and dq["fixed_rounds"] == 16
    assert dq["allreduces_saved"] == 5


def test_plan_table_renders_and_elides():
    clock = RoundClock(total_steps=10, tau=4, base_lr=0.1)
    table = clock.plan_table()
    assert "| round | start | tau | lam | lr window | staleness |" in table
    assert table.count("\n") == 2 + 3  # header x3 + one line per round
    long = RoundClock(total_steps=400, tau=4, base_lr=0.1)
    elided = long.plan_table(max_rows=6)
    assert "| ... |" in elided
    assert "| 0 | 0 | 4 |" in elided and "| 99 | 396 | 4 |" in elided


def test_qsr_warmup_rounds_keep_base_tau():
    """Warmup-aware QSR: the plan samples the FULL LR schedule. Rounds
    starting inside the warmup keep the base tau (the raw rule
    (beta/eta)^2 on the tiny warmup LR would blow tau up exactly when the
    model changes fastest) and never straddle the warmup boundary; the
    cosine-ruled plan takes over at ``warmup``. describe()/plan_table()
    mark the warmup rounds."""
    clock = RoundClock(total_steps=64, tau=4, base_lr=0.3, warmup=10,
                       tau_schedule="qsr", qsr_beta=0.4)
    taus = clock.taus()
    assert sum(taus) == 64
    # warmup covers steps 0..9: rounds (0,0,4), (1,4,4), (2,8,2) — the
    # third round is clipped at the boundary, NOT a huge QSR round
    assert [(s.start, s.tau) for s in clock.rounds[:3]] == [
        (0, 4), (4, 4), (8, 2)]
    assert clock.rounds[3].start == 10
    # without the warmup guard, eta(0) = 0 would still fall back to tau
    # but eta(1) ~ 0.03 gives (0.4/0.03)^2 ~ 178 — the guard is what
    # keeps every warmup-resident round at tau_base
    d = clock.describe()
    assert d["warmup"] == 10 and d["warmup_rounds"] == 3
    assert [r["warmup"] for r in d["plan"][:4]] == [True, True, True, False]
    table = clock.plan_table()
    assert "(warm)" in table and "warmup 10 steps = 3 rounds" in table
    # zero-warmup clocks render without the marker (back-compat)
    plain = RoundClock(total_steps=10, tau=4, base_lr=0.1)
    assert "(warm)" not in plain.plan_table()
    assert "warmup" not in plain.plan_table()


def test_qsr_overlap_uses_stale_lr():
    """Overlap-aware QSR: with a stale consensus, round k applies round
    k-1's iterate, so its tau is ruled by the PREVIOUS round's start LR.
    The plan stays host-static, covers every step, and lags the exact
    plan by exactly one round in its tau growth."""
    exact = RoundClock(total_steps=64, tau=4, base_lr=0.3,
                       tau_schedule="qsr", qsr_beta=0.4)
    for mode in ("staleness1", "doublebuf"):
        stale = RoundClock(total_steps=64, tau=4, base_lr=0.3,
                           tau_schedule="qsr", qsr_beta=0.4, overlap=mode)
        assert sum(stale.taus()) == 64
        assert stale.describe()["overlap"] == mode
        # the exact plan (docstring example) grows tau at step 32 (7) and
        # step 39 (16); the stale plan sizes those rounds from the
        # previous round's LR, so growth arrives one round later and the
        # stale plan pays at least as many rounds
        assert stale.total_rounds >= exact.total_rounds
        for spec, prev in zip(stale.rounds[1:], stale.rounds):
            from repro.core.schedules import qsr_tau
            from repro.train.clock import _host_cosine_lr
            eta_prev = _host_cosine_lr(0.3, prev.start, 64, 0)
            want = min(qsr_tau(eta_prev, 4, 0.4), 64 - spec.start)
            assert spec.tau == want, (spec, want)
    # overlap="none" keeps the pinned worked example untouched
    assert exact.taus() == (4, 4, 4, 4, 4, 4, 4, 4, 7, 16, 9)


def test_qsr_staleness_k_looks_back_k_rounds():
    """staleness_k QSR: round r applies round r-k's iterate, so its tau is
    ruled by the LR from k rounds back; k=1 reproduces the staleness1
    plan exactly, and describe()/plan_table() carry the depth (fill
    rounds 0..k-1 report depth 0)."""
    from repro.core.schedules import qsr_tau
    from repro.train.clock import _host_cosine_lr
    s1 = RoundClock(total_steps=64, tau=4, base_lr=0.3,
                    tau_schedule="qsr", qsr_beta=0.4, overlap="staleness1")
    k1 = RoundClock(total_steps=64, tau=4, base_lr=0.3,
                    tau_schedule="qsr", qsr_beta=0.4,
                    overlap="staleness_k", staleness=1)
    assert k1.taus() == s1.taus()
    assert k1.staleness_depth == 1 and s1.staleness_depth == 1
    k2 = RoundClock(total_steps=64, tau=4, base_lr=0.3,
                    tau_schedule="qsr", qsr_beta=0.4,
                    overlap="staleness_k", staleness=2)
    assert sum(k2.taus()) == 64 and k2.staleness_depth == 2
    for i, spec in enumerate(k2.rounds):
        if i < 2:
            continue
        eta = _host_cosine_lr(0.3, k2.rounds[i - 2].start, 64, 0)
        want = min(qsr_tau(eta, 4, 0.4), 64 - spec.start)
        assert spec.tau == want, (spec, want)
    d = k2.describe()
    assert d["overlap"] == "staleness_k" and d["staleness"] == 2
    assert [r["staleness"] for r in d["plan"][:3]] == [0, 0, 2]
    assert "(k=2)" in k2.plan_table()


def test_staleness_k_warmup_validation():
    """A k-deep pipeline needs at least k warmup rounds of exact fill:
    warmup shorter than k rounds raises; exactly k rounds passes."""
    with pytest.raises(ValueError, match="warmup"):
        RoundClock(total_steps=64, tau=4, base_lr=0.3, warmup=4,
                   overlap="staleness_k", staleness=2)
    clock = RoundClock(total_steps=64, tau=4, base_lr=0.3, warmup=8,
                       overlap="staleness_k", staleness=2)
    assert clock.describe()["warmup_rounds"] >= 2
    # depth validation rides the config path too
    with pytest.raises(ValueError, match="staleness"):
        DPPFConfig(engine="flat", overlap="staleness_k", staleness=0)
    # from_config plumbs the overlap mode through
    dcfg = DPPFConfig(tau=4, engine="flat", overlap="doublebuf",
                      tau_schedule="qsr", qsr_beta=0.4)
    c = RoundClock.from_config(dcfg, base_lr=0.3, total_steps=64)
    assert c.overlap == "doublebuf"


def test_round_plan_validation():
    with pytest.raises(ValueError, match="tau schedule"):
        RoundClock(total_steps=8, tau=4, tau_schedule="bogus")
    with pytest.raises(ValueError, match="overlap"):
        RoundClock(total_steps=8, tau=4, overlap="bogus")
    with pytest.raises(ValueError, match="warmup"):
        RoundClock(total_steps=8, tau=4, warmup=-2)
    with pytest.raises(ValueError, match="qsr_beta"):
        RoundClock(total_steps=8, tau=4, tau_schedule="qsr")
    with pytest.raises(ValueError, match="base_lr"):
        RoundClock(total_steps=8, tau=4, tau_schedule="qsr", qsr_beta=0.1)
    with pytest.raises(ValueError, match="total_steps"):
        RoundClock(total_steps=0, tau=4)


def test_qsr_plan_grows_tau_as_lr_decays():
    clock = RoundClock(total_steps=64, tau=4, base_lr=0.3, lam=LAM,
                       tau_schedule="qsr", qsr_beta=0.4)
    taus = clock.taus()
    assert sum(taus) == 64                        # every step accounted for
    assert taus[0] == 4                           # high lr -> tau_base
    assert max(taus) > 4                          # low lr -> longer rounds
    assert clock.total_rounds < clock.fixed_rounds
    d = clock.describe()
    assert d["allreduces_saved"] == clock.fixed_rounds - clock.total_rounds


def test_lam_at_endpoints():
    clock = RoundClock(total_steps=8, tau=2, lam=LAM, lam_kind="increasing")
    assert clock.total_rounds == 4
    assert float(clock.lam_at(0)) == 0.0          # round 0: lam_schedule(·,0,T)
    assert float(clock.lam_at(3)) == pytest.approx(LAM, rel=1e-6)
    # trajectory == lam_schedule evaluated over total_rounds - 1
    for k in range(4):
        assert float(clock.lam_at(k)) == pytest.approx(
            float(lam_schedule("increasing", LAM, k, 3)), rel=1e-6)


def test_lam_at_single_round_applies_full_lam():
    """A plan with ONE round has no trajectory to span: its only round is
    also the final round and must apply the full lam, not a silent zero
    push."""
    for kind in ("fixed", "increasing", "decreasing"):
        clock = RoundClock(total_steps=4, tau=4, lam=LAM, lam_kind=kind)
        assert clock.total_rounds == 1
        assert float(clock.lam_at(0)) == pytest.approx(LAM, rel=1e-6)


def test_round_plan_is_lazy():
    """DDP drivers only read lr_at: constructing a clock must not eagerly
    allocate one RoundSpec per step (a 1M-step DDP baseline would pay
    seconds of host time for a plan nobody reads)."""
    clock = RoundClock(total_steps=1_000_000, tau=1, base_lr=0.1)
    assert "rounds" not in clock.__dict__         # cached_property unset
    assert float(clock.lr_at(0)) == pytest.approx(0.1, rel=1e-6)
    assert "rounds" not in clock.__dict__


# ---------------------------------------------------------------------------
# the off-by-one regression: every builder, round 0 -> 0, final -> lam
# ---------------------------------------------------------------------------

def _lam_trajectory(step_fn, state, clock, batch):
    lams = []
    for spec in clock.rounds:
        state, m = step_fn(state, batch(spec.tau, spec.start))
        lams.append(float(m["lam_t"]))
    return state, lams


@pytest.mark.parametrize("mode", ["tree", "flat", "overlap", "sharded"])
def test_lam_schedule_endpoints_in_every_builder(mode):
    """With lam_schedule='increasing' (the paper's main-results default),
    round 0 must produce lam_t == 0 and the final round lam_t == lam. The
    pre-clock builders read ``t // tau`` AFTER the scan advanced t, so
    round 0 was skipped and the whole trajectory ran one round early."""
    M = 4
    opt, p0, loss, batch = _setup(M=M)
    kw = dict(alpha=0.2, lam=LAM, tau=2, lam_schedule="increasing")
    if mode == "tree":
        dcfg = DPPFConfig(engine="tree", **kw)
    elif mode == "overlap":
        dcfg = DPPFConfig(engine="flat", overlap="staleness1", **kw)
    else:
        dcfg = DPPFConfig(engine="flat", **kw)
    clock = RoundClock.from_config(dcfg, base_lr=0.05, total_steps=8)
    state = init_train_state(p0, opt, dcfg, M, jax.random.PRNGKey(0))
    if mode == "sharded":
        from repro.launch.mesh import make_cpu_mesh
        mesh = make_cpu_mesh()
        plan = MeshPlan(worker_axes=("data",), model_axes=("model",))
        state = shard_train_state(state, mesh, plan)
        fn = jax.jit(make_sharded_round_step(loss, opt, dcfg, mesh=mesh,
                                             plan=plan, clock=clock))
    else:
        fn = jax.jit(make_round_step(loss, opt, dcfg, clock=clock))
    state, lams = _lam_trajectory(fn, state, clock, batch)
    want = [float(clock.lam_at(k)) for k in range(clock.total_rounds)]
    np.testing.assert_allclose(lams, want, rtol=1e-6, atol=0)
    assert lams[0] == 0.0
    assert lams[-1] == pytest.approx(LAM, rel=1e-6)
    assert int(state.t) == 8 and int(state.round) == clock.total_rounds


def test_legacy_state_without_round_counter_uses_prescan_index():
    """Hand-built TrainStates (no round counter) fall back to the PRE-scan
    ``t // tau`` — still fixing the off-by-one for fixed tau."""
    M = 2
    opt, p0, loss, batch = _setup(M=M)
    dcfg = DPPFConfig(alpha=0.2, lam=LAM, tau=2, lam_schedule="increasing")
    st = init_train_state(p0, opt, dcfg, M, jax.random.PRNGKey(0))
    legacy = TrainState(params=st.params, opt=st.opt, cstate=st.cstate,
                        t=st.t, engine=st.engine)
    assert legacy.round is None
    fn = jax.jit(make_round_step(loss, opt, dcfg, base_lr=0.05,
                                 total_steps=8))
    _, m = fn(legacy, batch(2, 0))
    assert float(m["lam_t"]) == 0.0               # round 0, not round 1


# ---------------------------------------------------------------------------
# QSR: constant-tau parity, remainder accounting, adaptive savings
# ---------------------------------------------------------------------------

def test_qsr_constant_tau_bitwise_equals_fixed():
    """beta small enough that QSR always returns tau_base -> the adaptive
    run must be bit-for-bit the fixed-tau run (same plan, same lam
    denominator, same global-step batch seeding)."""
    M = 4
    opt, p0, loss, batch = _setup(M=M)
    base = dict(alpha=0.2, lam=LAM, tau=2, engine="flat",
                lam_schedule="increasing")
    d_fixed = DPPFConfig(**base)
    d_qsr = DPPFConfig(tau_schedule="qsr", qsr_beta=1e-6, **base)
    c_fixed = RoundClock.from_config(d_fixed, base_lr=0.05, total_steps=8)
    c_qsr = RoundClock.from_config(d_qsr, base_lr=0.05, total_steps=8)
    assert c_fixed.rounds == c_qsr.rounds

    outs = []
    for dcfg, clock in ((d_fixed, c_fixed), (d_qsr, c_qsr)):
        st = init_train_state(p0, opt, dcfg, M, jax.random.PRNGKey(0))
        fn = jax.jit(make_round_step(loss, opt, dcfg, clock=clock))
        for spec in clock.rounds:
            st, m = fn(st, batch(spec.tau, spec.start))
        outs.append((np.asarray(st.params), float(m["lam_t"])))
    np.testing.assert_array_equal(outs[0][0], outs[1][0])
    assert outs[0][1] == outs[1][1]


def test_remainder_steps_run_and_counted():
    """steps % tau used to be silently dropped by the launcher; the clock
    plans a short final round instead."""
    M = 2
    opt, p0, loss, batch = _setup(M=M)
    dcfg = DPPFConfig(alpha=0.2, lam=LAM, tau=4)
    clock = RoundClock.from_config(dcfg, base_lr=0.05, total_steps=10)
    st = init_train_state(p0, opt, dcfg, M, jax.random.PRNGKey(0))
    fn = jax.jit(make_round_step(loss, opt, dcfg, clock=clock))
    for spec in clock.rounds:
        st, m = fn(st, batch(spec.tau, spec.start))
    assert int(st.t) == 10                        # all 10 steps ran
    assert int(st.round) == 3
    assert float(m["lam_t"]) == pytest.approx(LAM, rel=1e-6)


def test_qsr_saves_rounds_at_matching_loss():
    """The §7.2 scenario end-to-end on the MLP task: QSR communicates in
    fewer rounds than fixed tau while the final test error stays within
    ERR_TOL percentage points (the adaptive run trains on the SAME step
    budget; only the consensus cadence changes, so the end error moves a
    little but must not degrade materially)."""
    ERR_TOL = 8.0   # pct points; MLP task std across seeds is ~2-3
    from benchmarks.common import default_data, run_distributed
    data = default_data()
    base = dict(alpha=0.1, lam=0.5, tau=4, engine="flat",
                lam_schedule="increasing")
    r_fixed = run_distributed(data, DPPFConfig(**base), M=4, steps=240)
    r_qsr = run_distributed(
        data, DPPFConfig(tau_schedule="qsr", qsr_beta=0.05, **base),
        M=4, steps=240)
    assert r_qsr.comm_pct < r_fixed.comm_pct      # fewer all-reduces
    assert abs(r_qsr.test_err - r_fixed.test_err) <= ERR_TOL


def test_launcher_resume_revalidates_clock_position(tmp_path):
    """Resuming with a LONGER --steps builds a different plan: the launcher
    must re-derive the round index from the step counter (the saved index
    belongs to the plan that wrote the checkpoint) and keep training;
    a step count that lands mid-round in the new plan must raise."""
    import shutil
    from repro.launch.train import main
    ck = str(tmp_path / "ck.npz")
    args = ["--arch", "yi-6b", "--smoke", "--workers", "2", "--tau", "4",
            "--seq", "16", "--batch", "2", "--lr", "0.3", "--ckpt", ck]
    main(args + ["--steps", "8"])                 # writes resume at t=8
    shutil.copy(str(tmp_path / "ck.state.npz"),
                str(tmp_path / "t8.state.npz"))
    loss = main(args + ["--steps", "16"])         # t=8 is round 2 of 4
    assert np.isfinite(loss)
    shutil.copy(str(tmp_path / "t8.state.npz"),
                str(tmp_path / "ck.state.npz"))   # back to the t=8 point
    with pytest.raises(ValueError, match="mid-round"):
        main(args + ["--steps", "15", "--tau", "6"])   # plan: 6,6,3 — no 8


def test_launcher_cli_qsr_smoke():
    """`--tau-schedule qsr` through the real launcher: completes, returns a
    finite eval loss, and exercises the remainder + re-chunk path."""
    from repro.launch.train import main
    loss = main(["--arch", "yi-6b", "--smoke", "--workers", "2",
                 "--tau", "4", "--steps", "10", "--seq", "16", "--batch",
                 "2", "--lr", "0.3", "--tau-schedule", "qsr", "--qsr-beta",
                 "0.35"])
    assert np.isfinite(loss)


# ---------------------------------------------------------------------------
# per-round metrics logging hook (RoundMetricsLogger + --log-every-round)
# ---------------------------------------------------------------------------

def test_round_metrics_logger_jsonl(tmp_path):
    """The clock-driven hook: one JSON line per round carrying the clock
    position + the unified metrics dict; bare-int specs (the ddp per-step
    clock) log as tau=1 rows."""
    import json
    from repro.train import RoundMetricsLogger, RoundSpec
    path = str(tmp_path / "rounds.jsonl")
    with RoundMetricsLogger(path) as log:
        # a legacy "stale" flag maps onto the unified "staleness" key
        row = log(RoundSpec(index=0, start=0, tau=4),
                  {"consensus_dist": jnp.float32(1.5), "stale": 0.0,
                   "note": "x"})
        assert row == {"round": 0, "start": 0, "tau": 4,
                       "consensus_dist": 1.5, "staleness": 0.0, "note": "x"}
        log(3, {"train_loss": 2.0})
    lines = [json.loads(l) for l in open(path)]
    assert len(lines) == 2
    assert lines[0]["tau"] == 4 and lines[0]["consensus_dist"] == 1.5
    assert lines[1] == {"round": 3, "start": 3, "tau": 1, "train_loss": 2.0}


def test_launcher_log_every_round_jsonl(tmp_path):
    """--log-every-round through the real launcher: one line per plan
    round with the unified schema (staleness depth included) for a
    doublebuf run, and one line per STEP for the ddp branch."""
    import json
    from repro.launch.train import main
    path = str(tmp_path / "rounds.jsonl")
    loss = main(["--arch", "yi-6b", "--smoke", "--workers", "2",
                 "--tau", "4", "--steps", "10", "--seq", "16", "--batch",
                 "2", "--lr", "0.3", "--overlap", "doublebuf",
                 "--overlap-chunks", "2", "--log-every-round", path])
    assert np.isfinite(loss)
    rows = [json.loads(l) for l in open(path)]
    clock = RoundClock(total_steps=10, tau=4, base_lr=0.3,
                       overlap="doublebuf")
    assert len(rows) == clock.total_rounds
    for want, got in zip(clock.rounds, rows):
        assert (got["round"], got["start"], got["tau"]) == (
            want.index, want.start, want.tau)
        for k in ("consensus_dist", "pre_dist", "pull_force", "push_force",
                  "train_loss", "lam_t", "staleness"):
            assert k in got, k
    # the bubble round is exact (depth 0), the steady state depth-1 stale
    assert rows[0]["staleness"] == 0.0
    assert all(r["staleness"] == 1.0 for r in rows[1:])

    ddp_path = str(tmp_path / "ddp.jsonl")
    loss = main(["--arch", "yi-6b", "--smoke", "--workers", "2",
                 "--consensus", "ddp", "--steps", "3", "--seq", "16",
                 "--batch", "2", "--log-every-round", ddp_path])
    assert np.isfinite(loss)
    rows = [json.loads(l) for l in open(ddp_path)]
    assert len(rows) == 3 and all(r["tau"] == 1 for r in rows)
    assert all(r["staleness"] == 0.0 and r["consensus_dist"] == 0.0
               for r in rows)


# ---------------------------------------------------------------------------
# checkpoint: the clock position survives save/resume
# ---------------------------------------------------------------------------

def test_checkpoint_persists_clock_position_qsr(tmp_path):
    """Mid-run resume of an ADAPTIVE run must restore the round index from
    the checkpoint (with QSR it is not derivable as t // tau) and continue
    bit-for-bit with the straight-through run."""
    M = 4
    opt, p0, loss, batch = _setup(M=M)
    dcfg = DPPFConfig(alpha=0.2, lam=LAM, tau=2, engine="flat",
                      lam_schedule="increasing", tau_schedule="qsr",
                      qsr_beta=0.25)
    clock = RoundClock.from_config(dcfg, base_lr=0.3, total_steps=16)
    assert clock.taus() != (2,) * (16 // 2)       # genuinely adaptive
    key = jax.random.PRNGKey(0)
    fn = jax.jit(make_round_step(loss, opt, dcfg, clock=clock))

    straight = init_train_state(p0, opt, dcfg, M, key)
    resumed = init_train_state(p0, opt, dcfg, M, key)
    cut = 2
    for spec in clock.rounds[:cut]:
        straight, _ = fn(straight, batch(spec.tau, spec.start))
        resumed, _ = fn(resumed, batch(spec.tau, spec.start))
    path = str(tmp_path / "state.npz")
    save_train_state(path, resumed)

    template = init_train_state(p0, opt, dcfg, M, key)
    resumed = load_train_state(path, template)
    assert int(resumed.round) == cut
    assert int(resumed.t) == clock.rounds[cut].start
    for spec in clock.rounds[cut:]:
        straight, _ = fn(straight, batch(spec.tau, spec.start))
        resumed, _ = fn(resumed, batch(spec.tau, spec.start))
    np.testing.assert_array_equal(np.asarray(straight.params),
                                  np.asarray(resumed.params))


def test_checkpoint_without_round_extra_recovers_via_clock(tmp_path):
    """Pre-RoundClock checkpoints carried only ``t``: the loader recovers
    the round index through clock.round_of_step."""
    import numpy as onp
    from repro.checkpoint.io import _SEP, _state_tree
    M = 2
    opt, p0, loss, batch = _setup(M=M)
    dcfg = DPPFConfig(alpha=0.2, lam=LAM, tau=2, engine="flat")
    clock = RoundClock.from_config(dcfg, base_lr=0.05, total_steps=8)
    st = init_train_state(p0, opt, dcfg, M, jax.random.PRNGKey(0))
    fn = jax.jit(make_round_step(loss, opt, dcfg, clock=clock))
    st, _ = fn(st, batch(2, 0))
    # simulate an old checkpoint: same tree, only the ``t`` extra
    from repro.checkpoint import save_pytree
    path = str(tmp_path / "old.npz")
    save_pytree(path, _state_tree(st),
                extra={"t": onp.asarray(jax.device_get(st.t))})
    template = init_train_state(p0, opt, dcfg, M, jax.random.PRNGKey(0))
    resumed = load_train_state(path, template, clock=clock)
    assert int(resumed.t) == 2
    assert int(resumed.round) == 1                # recovered from the plan

    # without a clock the loader must NOT adopt the template's fresh 0
    # (that would restart the lam schedule): round is None and the round
    # builders' pre-scan t // tau fallback produces the correct index
    blind = load_train_state(path, template)
    assert blind.round is None
    _, m = fn(blind, batch(2, 2))
    assert float(m["lam_t"]) == pytest.approx(
        float(clock.lam_at(1)), rel=1e-6)


# ---------------------------------------------------------------------------
# serving: generate() edges
# ---------------------------------------------------------------------------

def _tiny_model():
    from repro.configs import ARCHS, reduced
    from repro.models import build_model
    cfg = reduced(ARCHS["yi-6b"], n_layers=2, d_model=64, n_heads=2,
                  n_kv_heads=2, head_dim=32, d_ff=128, vocab_size=64)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def test_generate_max_new_tokens_one():
    """max_new_tokens=1 is prefill-then-pick: the zero-length decode scan
    must not break shapes, and greedy output == argmax of the prefill
    logits."""
    from repro.serving import generate
    model, params = _tiny_model()
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 64)
    toks, logits = generate(model, params, {"tokens": prompt},
                            max_new_tokens=1, buf_len=16)
    assert toks.shape == (2, 1)
    np.testing.assert_array_equal(
        np.asarray(toks[:, 0]), np.asarray(jnp.argmax(logits, axis=-1)))
    # sampled flavor: one token drawn with the CALLER's key itself
    key = jax.random.PRNGKey(3)
    toks_s, logits_s = generate(model, params, {"tokens": prompt},
                                max_new_tokens=1, buf_len=16, greedy=False,
                                key=key)
    assert toks_s.shape == (2, 1)
    np.testing.assert_array_equal(
        np.asarray(toks_s[:, 0]),
        np.asarray(jax.random.categorical(key, logits_s)))


def test_generate_sample_keys_first_vs_fold_in_chain():
    """The first sampled token consumes the caller's key; tokens i >= 1
    use fold_in(key, i). The keys are pairwise distinct and the whole
    chain is reproducible from that contract (decode_key)."""
    from repro.serving import decode_key, generate
    model, params = _tiny_model()
    key = jax.random.PRNGKey(9)
    # the contract itself: decode_key(k, 0) IS k; the chain never collides
    assert np.array_equal(np.asarray(decode_key(key, 0)), np.asarray(key))
    raw = [np.asarray(decode_key(key, i)).tobytes() for i in range(4)]
    assert len(set(raw)) == 4

    prompt = jax.random.randint(jax.random.PRNGKey(2), (2, 6), 0, 64)
    N = 3
    toks, _ = generate(model, params, {"tokens": prompt}, max_new_tokens=N,
                       buf_len=16, greedy=False, key=key)
    # reference replay straight from the ModelAPI + decode_key chain
    logits, states = model.prefill(params, {"tokens": prompt}, buf_len=16)
    tok = jax.random.categorical(decode_key(key, 0), logits).astype(jnp.int32)
    ref = [tok]
    for i in range(1, N):
        # token i-1 occupies position prompt_len + i - 1 (the first
        # generated token extends the prompt with no position gap)
        lg, states = model.decode_step(params, states, tok[:, None],
                                       prompt.shape[1] + i - 1)
        tok = jax.random.categorical(decode_key(key, i), lg).astype(jnp.int32)
        ref.append(tok)
    np.testing.assert_array_equal(np.asarray(toks),
                                  np.asarray(jnp.stack(ref, axis=1)))
