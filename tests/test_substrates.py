"""Substrate tests: optimizers, data pipeline determinism + sharding
discipline, checkpoint round-trip, FL partitioning, serving engine."""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis, or deterministic fallback

from repro.checkpoint import load_pytree, save_pytree
from repro.core.fl import dirichlet_partition, heterogeneity
from repro.data import TokenTask, classification_task, make_lm_batch
from repro.optim import make_optimizer, sam_gradient


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------

def _quad(params, batch):
    del batch
    return 0.5 * jnp.sum(params["x"] ** 2), {}


@pytest.mark.parametrize("name", ["sgd", "adamw"])
def test_optimizer_descends(name):
    opt = make_optimizer(name, weight_decay=0.0)
    p = {"x": jnp.ones(8) * 3.0}
    st_ = opt.init(p)
    for _ in range(100):
        g = jax.grad(lambda q: _quad(q, None)[0])(p)
        p, st_ = opt.step(p, g, st_, 0.1)
    assert float(jnp.abs(p["x"]).max()) < 0.2


def test_sgd_momentum_matches_manual():
    opt = make_optimizer("sgd", momentum=0.9, weight_decay=0.0)
    p = {"x": jnp.asarray([1.0])}
    s = opt.init(p)
    g = {"x": jnp.asarray([0.5])}
    p1, s = opt.step(p, g, s, 0.1)
    assert float(p1["x"][0]) == pytest.approx(1.0 - 0.1 * 0.5)
    p2, s = opt.step(p1, g, s, 0.1)
    # mu = 0.9*0.5 + 0.5 = 0.95
    assert float(p2["x"][0]) == pytest.approx(float(p1["x"][0]) - 0.1 * 0.95)


def test_sam_gradient_is_ascent_point_grad():
    """For the quadratic, SAM grad at p is H(p + rho p/|p|) = p + rho p/|p|."""
    p = {"x": jnp.asarray([3.0, 4.0])}  # |p| = 5
    loss = lambda q, b: (0.5 * jnp.sum(q["x"] ** 2), {})
    (l0, _), g = sam_gradient(loss, p, None, rho=1.0)
    want = np.asarray([3.0, 4.0]) * (1.0 + 1.0 / 5.0)
    np.testing.assert_allclose(np.asarray(g["x"]), want, rtol=1e-5)


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------

def test_lm_batches_deterministic_and_disjoint():
    task = TokenTask(vocab_size=128, seq_len=16)
    b1 = make_lm_batch(task, seed=0, worker=0, step=3, batch=4)
    b2 = make_lm_batch(task, seed=0, worker=0, step=3, batch=4)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = make_lm_batch(task, seed=0, worker=1, step=3, batch=4)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))
    # labels are next-token-shifted with the tail masked
    np.testing.assert_array_equal(np.asarray(b1["labels"][:, :-1]),
                                  np.asarray(b1["tokens"][:, 1:]))
    assert (np.asarray(b1["labels"][:, -1]) == -1).all()


def test_lm_task_has_learnable_structure():
    task = TokenTask(vocab_size=97, seq_len=32, noise=0.0)
    toks = np.asarray(task.sample(jax.random.PRNGKey(0), 2))
    np.testing.assert_array_equal(toks[:, 1:],
                                  (toks[:, :-1] * task.mult + task.add) % 97)


def test_classification_task_split_and_gap_potential():
    data = classification_task(seed=1)
    assert data["x_train"].shape[0] == 2048
    assert data["x_test"].shape[0] == 1024
    # train labels contain noise (flips) but test labels are clean
    assert data["n_classes"] == 10


# ---------------------------------------------------------------------------
# dirichlet partition
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(alpha=st.sampled_from([0.1, 0.6, 10.0]), m=st.integers(2, 6))
def test_dirichlet_partition_properties(alpha, m):
    labels = np.repeat(np.arange(10), 100)
    shards = dirichlet_partition(labels, m, alpha, seed=1)
    assert len(shards) == m
    sizes = {len(s) for s in shards}
    assert len(sizes) == 1  # equalized
    flat = np.concatenate(shards)
    assert len(np.unique(flat)) == len(flat)  # disjoint


def test_dirichlet_smaller_alpha_more_heterogeneous():
    labels = np.repeat(np.arange(10), 200)
    h_strong = heterogeneity(dirichlet_partition(labels, 4, 0.1, seed=0),
                             labels, 10)
    h_weak = heterogeneity(dirichlet_partition(labels, 4, 10.0, seed=0),
                           labels, 10)
    assert h_strong > h_weak


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": {"w": jnp.arange(6.0).reshape(2, 3),
                  "b": jnp.ones((4,), jnp.int32)},
            "c": jnp.asarray(2.5)}
    path = os.path.join(tmp_path, "ckpt.npz")
    save_pytree(path, tree, extra={"step": 7})
    like = jax.tree.map(lambda a: jnp.zeros_like(a), tree)
    got, extra = load_pytree(path, like)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(extra["step"]) == 7


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def test_generate_greedy_learns_recurrence():
    """After a short training run the sampler should follow the affine
    recurrence (integration: trainer -> average -> serving engine)."""
    from repro.configs import ARCHS, DPPFConfig, reduced
    from repro.models import build_model
    from repro.data import make_round_batch
    from repro.optim import make_optimizer
    from repro.serving import generate
    from repro.train import init_train_state, make_round_step
    from repro.train.trainer import average_params

    cfg = reduced(ARCHS["yi-6b"], n_layers=2)
    model = build_model(cfg)
    task = TokenTask(vocab_size=cfg.vocab_size, seq_len=24, noise=0.02)
    dcfg = DPPFConfig(alpha=0.1, lam=0.3, tau=4)
    opt = make_optimizer("sgd", momentum=0.9)
    state = init_train_state(model.init, opt, dcfg, 2, jax.random.PRNGKey(0))
    step = jax.jit(make_round_step(model.loss, opt, dcfg, base_lr=0.3,
                                   total_steps=100))
    for r in range(25):
        # make_round_batch seeds by GLOBAL step (RoundSpec.start)
        state, _ = step(state, make_round_batch(task, 0, 2, 4, 4 * r, 4, cfg))
    avg = average_params(state)
    prompt = task.sample(jax.random.PRNGKey(5), 2)
    toks, _ = generate(model, avg, {"tokens": prompt}, max_new_tokens=6,
                       buf_len=40)
    want = np.asarray(prompt[:, -1])
    correct = 0
    for i in range(6):
        want = (want * task.mult + task.add) % cfg.vocab_size
        correct += int((np.asarray(toks[:, i]) == want).sum())
    assert correct >= 8  # of 12; recurrence mostly learned
