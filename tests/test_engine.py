"""ConsensusEngine: flat-vs-tree parity for every method, flatten round
trips, donation semantics, metrics-schema stability, fused kernel oracle."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import DPPFConfig
from repro.core import consensus, pullpush as pp
from repro.core.engine import ConsensusEngine
from repro.kernels.pullpush import fused_round, fused_round_ref

METRIC_KEYS = {"consensus_dist", "pre_dist", "pull_force", "push_force"}


def _stacked(key, M=4, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    return {"w": jax.random.normal(ks[0], (M, 33, 7), dtype),
            "b": jax.random.normal(ks[1], (M, 17), dtype),
            "s": jax.random.normal(ks[2], (M, 5, 3, 2), dtype)}


def _tol(dtype):
    # tree path round-trips through the leaf dtype between pull and push;
    # the flat engine stays fp32 — bf16 parity is bounded by bf16 rounding
    return dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 \
        else dict(atol=5e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# parity: every method, both engine execution paths, fp32 + bf16
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", consensus.METHODS)
@pytest.mark.parametrize("use_kernel", [False, True])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flat_engine_matches_tree(method, use_kernel, dtype):
    key = jax.random.PRNGKey(7)
    stacked = _stacked(key, M=4, dtype=dtype)
    losses = jnp.asarray([3.0, 1.0, 2.0, 4.0])
    gns = jnp.asarray([1.0, 2.0, 0.5, 1.0])
    dcfg = DPPFConfig(alpha=0.3, lam=0.4, consensus=method)

    eng = ConsensusEngine.from_stacked(stacked, method=method,
                                       use_kernel=use_kernel)
    flat = eng.flatten(stacked)
    new_t, _, m_t = consensus.apply_round(
        stacked, dcfg, 0.25, consensus.init_state(method, stacked),
        losses=losses, grad_norms=gns)
    new_f, _, m_f = consensus.apply_round(
        flat, dcfg, 0.25, consensus.init_state(method, stacked, engine=eng),
        losses=losses, grad_norms=gns, engine=eng)

    tree_f = eng.unflatten(new_f)
    for k in stacked:
        np.testing.assert_allclose(np.asarray(tree_f[k], np.float32),
                                   np.asarray(new_t[k], np.float32),
                                   **_tol(dtype))
    assert set(m_f) == set(m_t) == METRIC_KEYS
    np.testing.assert_allclose(float(m_f["consensus_dist"]),
                               float(m_t["consensus_dist"]),
                               rtol=5e-2 if dtype == jnp.bfloat16 else 1e-3,
                               atol=1e-4)  # hard collapses to exactly 0


@pytest.mark.parametrize("method", [m for m in consensus.METHODS
                                    if m != "ddp"])
def test_flat_engine_push_variants_match_tree(method):
    """push on/off, exact second term, push-from-leader."""
    key = jax.random.PRNGKey(11)
    stacked = _stacked(key, M=4)
    losses = jnp.asarray([3.0, 1.0, 2.0, 4.0])
    gns = jnp.asarray([1.0, 2.0, 0.5, 1.0])
    cases = [dict(push=False), dict(push=True),
             dict(push=True, exact_second_term=True)]
    froms = ["average"] + (["leader"] if method == "lsgd" else [])
    for case in cases:
        for push_from in froms:
            dcfg = DPPFConfig(alpha=0.3, lam=0.4, consensus=method, **case)
            eng = ConsensusEngine.from_stacked(stacked, method=method)
            flat = eng.flatten(stacked)
            new_t, _, m_t = consensus.apply_round(
                stacked, dcfg, 0.25, consensus.init_state(method, stacked),
                losses=losses, grad_norms=gns, push_from=push_from)
            new_f, _, m_f = consensus.apply_round(
                flat, dcfg, 0.25, {}, losses=losses, grad_norms=gns,
                push_from=push_from, engine=eng)
            tree_f = eng.unflatten(new_f)
            for k in stacked:
                np.testing.assert_allclose(
                    np.asarray(tree_f[k]), np.asarray(new_t[k]),
                    atol=5e-4, rtol=1e-4,
                    err_msg=f"{method} {case} push_from={push_from}")
            assert set(m_f) == METRIC_KEYS


def test_easgd_center_rides_in_aux_row():
    """The flat easgd state is the aux row; it must track the tree center."""
    key = jax.random.PRNGKey(3)
    stacked = _stacked(key, M=4)
    dcfg = DPPFConfig(alpha=0.2, lam=0.0, push=False, consensus="easgd")
    eng = ConsensusEngine.from_stacked(stacked, method="easgd")
    assert eng.layout.aux == 1
    flat = eng.flatten(stacked)
    st_t = consensus.init_state("easgd", stacked)
    for _ in range(3):
        stacked, st_t, _ = consensus.apply_round(stacked, dcfg, 0.0, st_t)
        flat, _, _ = consensus.apply_round(flat, dcfg, 0.0, {}, engine=eng)
    z_tree = st_t["center"]
    z_flat = eng.unflatten_row(flat[eng.layout.M])
    for k in z_tree:
        np.testing.assert_allclose(np.asarray(z_flat[k], np.float32),
                                   np.asarray(z_tree[k]), atol=1e-5)


# ---------------------------------------------------------------------------
# metrics schema: stable pytree across every branch (lax.scan-safe)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", consensus.METHODS)
@pytest.mark.parametrize("push", [False, True])
def test_metrics_schema_stable(method, push):
    key = jax.random.PRNGKey(0)
    stacked = _stacked(key, M=4)
    dcfg = DPPFConfig(alpha=0.3, lam=0.4, consensus=method, push=push)
    losses = jnp.arange(4.0)
    gns = jnp.ones((4,))
    _, _, m = consensus.apply_round(
        stacked, dcfg, 0.1, consensus.init_state(method, stacked),
        losses=losses, grad_norms=gns)
    assert set(m) == METRIC_KEYS
    assert all(jnp.asarray(v).dtype == jnp.float32 for v in m.values())


# ---------------------------------------------------------------------------
# flatten round trip + donation contract
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flatten_roundtrip_preserves_shapes_dtypes(dtype):
    key = jax.random.PRNGKey(5)
    stacked = _stacked(key, M=3, dtype=dtype)
    eng = ConsensusEngine.from_stacked(stacked)
    flat = eng.flatten(stacked)
    assert flat.shape == (3, eng.layout.n) and flat.dtype == jnp.float32
    back = eng.unflatten(flat)
    assert jax.tree_util.tree_structure(back) == \
        jax.tree_util.tree_structure(stacked)
    for k in stacked:
        assert back[k].shape == stacked[k].shape
        assert back[k].dtype == stacked[k].dtype
        np.testing.assert_allclose(np.asarray(back[k], np.float32),
                                   np.asarray(stacked[k], np.float32),
                                   rtol=1e-6, atol=1e-6)
    row = eng.unflatten_row(flat[1])
    for k in stacked:
        assert row[k].shape == stacked[k].shape[1:]
        assert row[k].dtype == stacked[k].dtype
    # cast=False keeps the fp32 master leaves (average_params contract:
    # the final model is fp32 on every engine, like tree_mean0)
    row32 = eng.unflatten_row(flat[1], cast=False)
    assert all(l.dtype == jnp.float32 for l in jax.tree.leaves(row32))


def test_donated_round_does_not_alias_stale_buffers():
    """The donated flat view must be consumed (stale handle dies) and the
    result must equal the undonated computation — no aliasing bugs."""
    key = jax.random.PRNGKey(9)
    stacked = _stacked(key, M=4)
    dcfg = DPPFConfig(alpha=0.1, lam=0.5)
    eng = ConsensusEngine.from_stacked(stacked)

    plain = jax.jit(lambda f: consensus.apply_round(
        f, dcfg, 0.3, {}, engine=eng)[0])
    donating = jax.jit(lambda f: consensus.apply_round(
        f, dcfg, 0.3, {}, engine=eng)[0], donate_argnums=0)

    want = np.asarray(plain(eng.flatten(stacked)))
    flat = eng.flatten(stacked)
    out = donating(flat)
    np.testing.assert_allclose(np.asarray(out), want, rtol=1e-6)
    assert flat.is_deleted()  # input buffer really was donated
    # chaining rounds through the donated buffer stays self-consistent
    out2 = donating(out)
    want2 = plain(plain(eng.flatten(stacked)))
    np.testing.assert_allclose(np.asarray(out2), np.asarray(want2),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# trainer integration: flat engine end-to-end
# ---------------------------------------------------------------------------

def test_trainer_flat_engine_matches_tree_engine():
    from benchmarks.common import default_data, run_distributed
    data = default_data()
    base = DPPFConfig(alpha=0.2, lam=0.8, tau=4, lam_schedule="fixed")
    r_tree = run_distributed(data, dataclasses.replace(base, engine="tree"),
                             M=4, steps=40)
    r_flat = run_distributed(data, dataclasses.replace(base, engine="flat"),
                             M=4, steps=40)
    assert abs(r_flat.consensus_dist - r_tree.consensus_dist) < 1e-3
    for k in r_tree.params_avg:
        np.testing.assert_allclose(
            np.asarray(r_flat.params_avg[k]["w"]),
            np.asarray(r_tree.params_avg[k]["w"]), atol=1e-4, rtol=1e-4)


def test_trainer_flat_engine_easgd_and_lsgd_run():
    from benchmarks.common import default_data, run_distributed
    data = default_data()
    for method in ("easgd", "lsgd"):
        r = run_distributed(
            data, DPPFConfig(alpha=0.3, lam=0.2, tau=4, consensus=method,
                             engine="flat"), M=4, steps=16)
        assert np.isfinite(r.test_err)


# ---------------------------------------------------------------------------
# fused kernel vs oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(4, 300), (8, 4097), (3, 128)])
def test_fused_round_kernel_vs_ref(shape):
    R, n = shape
    key = jax.random.PRNGKey(R * n)
    flat = jax.random.normal(key, (R, n)) * 2.0 + 1.0
    # a non-trivial row-stochastic target mix
    T = jax.nn.softmax(jax.random.normal(jax.random.fold_in(key, 1), (R, R)))
    c0 = jnp.linspace(0.1, 0.5, R)
    c1 = jnp.linspace(-0.4, -0.1, R)
    got, r_got, G = fused_round(flat, T, c0, c1, block_cols=256)
    want, r_want = fused_round_ref(flat, T, c0, c1)
    np.testing.assert_allclose(np.asarray(r_got), np.asarray(r_want),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("mode", ["precise", "kernel"])
def test_near_consensus_push_matches_tree(mode):
    """Workers within 1e-4 of each other, fixed-lam push: the exact engine
    modes must restore the paper's width like the tree path does, even
    though r is far below the uncentered Gram's fp32 resolution."""
    key = jax.random.PRNGKey(0)
    M, n = 4, 10000
    base = jax.random.normal(key, (n,))
    stacked = {"w": base[None] + 1e-4 * jax.random.normal(
        jax.random.fold_in(key, 1), (M, n))}
    dcfg = DPPFConfig(alpha=0.1, lam=0.5)
    eng = ConsensusEngine.from_stacked(stacked,
                                       use_kernel=(mode == "kernel"),
                                       precise=(mode == "precise"))
    flat = eng.flatten(stacked)
    new_t, _, m_t = consensus.apply_round(stacked, dcfg, 0.5, {})
    new_f, _, m_f = consensus.apply_round(flat, dcfg, 0.5, {}, engine=eng)
    np.testing.assert_allclose(np.asarray(eng.unflatten(new_f)["w"]),
                               np.asarray(new_t["w"]), atol=5e-4)
    np.testing.assert_allclose(float(m_f["consensus_dist"]),
                               float(m_t["consensus_dist"]), rtol=1e-3)


def test_fast_path_floor_is_bounded_and_monotone():
    """The fast jnp path cannot resolve r below ~sqrt(eps32)*||x|| and
    floors it there (engine.GRAM_NOISE_FACTOR): inside that window the
    push is attenuated but must still move workers APART monotonically
    (never along rounding noise), and above the window it must agree with
    the tree path again."""
    from repro.core.engine import GRAM_NOISE_FACTOR, _EPS32
    key = jax.random.PRNGKey(0)
    M, n = 4, 10000
    base = jax.random.normal(key, (n,))
    stacked = {"w": base[None] + 1e-4 * jax.random.normal(
        jax.random.fold_in(key, 1), (M, n))}
    dcfg = DPPFConfig(alpha=0.1, lam=0.5)
    eng = ConsensusEngine.from_stacked(stacked)  # fast jnp path
    assert not eng.precise and not eng.use_kernel
    flat = eng.flatten(stacked)
    floor_r = float(jnp.sqrt(GRAM_NOISE_FACTOR * _EPS32
                             * jnp.max(jnp.sum(jnp.square(flat), axis=1))))
    dists = [float(eng.dists_to_mean(flat).mean())]
    for _ in range(40):
        flat, _, _ = consensus.apply_round(flat, dcfg, 0.5, {}, engine=eng)
        dists.append(float(eng.dists_to_mean(flat).mean()))
        if dists[-1] > floor_r:
            break
    # monotone escape from the sub-resolution window...
    assert all(b > a for a, b in zip(dists, dists[1:]))
    assert dists[-1] > floor_r
    # ...and exact tree agreement once resolvable
    stacked_now = eng.unflatten(flat)
    new_t, _, m_t = consensus.apply_round(stacked_now, dcfg, 0.5, {})
    new_f, _, m_f = consensus.apply_round(flat, dcfg, 0.5, {}, engine=eng)
    np.testing.assert_allclose(np.asarray(eng.unflatten(new_f)["w"]),
                               np.asarray(new_t["w"]), atol=1e-3)


@pytest.mark.parametrize("use_kernel", [False, True])
def test_pullpush_fused_exact_near_consensus(use_kernel):
    """The convenience wrapper keeps plain Eq. 5 semantics at every scale
    on BOTH execution paths (it must not inherit the fast path's floor)."""
    from repro.kernels.pullpush import pullpush_fused
    key = jax.random.PRNGKey(1)
    M, n = 8, 4096
    base = jax.random.normal(key, (n,))
    stacked = {"w": base[None] + 1e-5 * jax.random.normal(
        jax.random.fold_in(key, 1), (M, n))}
    got, r = pullpush_fused(stacked, 0.1, 0.5, use_kernel=use_kernel)
    want, m = pp.pullpush(stacked, 0.1, 0.5)
    np.testing.assert_allclose(np.asarray(r),
                               np.asarray(pp.worker_dists(stacked)),
                               rtol=1e-3)
    # both paths are fp32-limited to ~3e-4 here (coef ~ -800 amplifies the
    # fp32 distance rounding identically); the floor bug this guards
    # against produced O(0.5) errors
    np.testing.assert_allclose(np.asarray(got["w"]), np.asarray(want["w"]),
                               atol=2e-3)


def test_fused_round_centered_gram_is_cancellation_safe():
    """Workers clustered far from the origin: the kernel's block-centered
    Gram keeps relative distance error ~1e-6 where a naive uncentered
    x @ x.T Gram loses several digits."""
    key = jax.random.PRNGKey(2)
    n, M = 4096, 4
    base = jax.random.normal(key, (n,)) * 3.0 + 5.0
    flat = base[None] + 0.05 * jax.random.normal(
        jax.random.fold_in(key, 1), (M, n))
    T = jnp.full((M, M), 1.0 / M)
    _, r, _ = fused_round(flat, T, jnp.zeros(M), jnp.zeros(M),
                          block_cols=512)
    f64 = np.asarray(flat, np.float64)
    r_true = np.sqrt(((f64 - f64.mean(0)) ** 2).sum(1))
    np.testing.assert_allclose(np.asarray(r), r_true, rtol=1e-5)
