"""Trainer integration: DPPF round dynamics on real models, DDP equivalence
at tau=1/alpha=1/no-push, FL rounds, Theorem-1 width on a DNN."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks.common import (
    default_data, mlp_init, mlp_loss, round_batches, run_distributed,
    worker_shards,
)
from repro.configs import DPPFConfig
from repro.core import pullpush as pp
from repro.optim import make_optimizer
from repro.train import init_train_state, make_round_step
from repro.train.trainer import average_params


def test_hard_localsgd_resets_workers_to_average():
    """alpha=1 (LocalSGD): after each round all workers are identical."""
    data = default_data()
    dcfg = DPPFConfig(consensus="hard", tau=4, push=False)
    opt = make_optimizer("sgd")
    state = init_train_state(
        lambda k: mlp_init(k, data["dim"], data["n_classes"]), opt, dcfg, 4,
        jax.random.PRNGKey(0))
    step = jax.jit(make_round_step(mlp_loss, opt, dcfg, base_lr=0.05,
                                   total_steps=40))
    shards = worker_shards(2048, 4)
    rng = np.random.default_rng(0)
    state, m = step(state, round_batches(data, shards, rng, 4, 4, 32))
    assert float(m["consensus_dist"]) < 1e-5


def test_dppf_width_converges_on_mlp():
    data = default_data()
    r = run_distributed(data, DPPFConfig(alpha=0.2, lam=0.8, tau=4,
                                         lam_schedule="fixed"),
                        M=8, steps=300)
    assert abs(r.consensus_dist - 4.0) < 0.8


def test_no_push_weak_pull_collapses():
    data = default_data()
    r = run_distributed(data, DPPFConfig(alpha=0.05, lam=0.0, push=False,
                                         tau=4), M=4, steps=500,
                        track_every=5)
    h = r.history["consensus_dist"]
    assert r.consensus_dist < 0.6 * max(h[:3])  # valley collapse (Fig. 2b)


def test_round_counter_advances_tau_steps():
    data = default_data()
    dcfg = DPPFConfig(tau=8)
    opt = make_optimizer("sgd")
    state = init_train_state(
        lambda k: mlp_init(k, data["dim"], data["n_classes"]), opt, dcfg, 2,
        jax.random.PRNGKey(0))
    step = jax.jit(make_round_step(mlp_loss, opt, dcfg, base_lr=0.05,
                                   total_steps=80))
    shards = worker_shards(2048, 2)
    rng = np.random.default_rng(0)
    state, _ = step(state, round_batches(data, shards, rng, 8, 2, 16))
    assert int(state.t) == 8


def test_average_params_matches_manual_mean():
    data = default_data()
    dcfg = DPPFConfig(tau=2)
    opt = make_optimizer("sgd")
    state = init_train_state(
        lambda k: mlp_init(k, data["dim"], data["n_classes"]), opt, dcfg, 4,
        jax.random.PRNGKey(0))
    avg = average_params(state)
    for k in avg:
        np.testing.assert_allclose(np.asarray(avg[k]["w"]),
                                   np.asarray(state.params[k]["w"].mean(0)),
                                   rtol=1e-6)


def test_fl_scaffold_round_runs_and_dppf_keeps_spread():
    from repro.core import fl
    data = default_data()
    M = 4
    p0 = mlp_init(jax.random.PRNGKey(0), data["dim"], data["n_classes"])
    stacked = jax.tree.map(
        lambda a: jnp.array(jnp.broadcast_to(a[None], (M,) + a.shape)), p0)
    key = jax.random.PRNGKey(9)
    batches = {"x": jax.random.normal(key, (4, M, 16, data["dim"])),
               "y": jax.random.randint(jax.random.fold_in(key, 1),
                                       (4, M, 16), 0, data["n_classes"])}
    loss = lambda p, b: mlp_loss(p, b)[0]

    st_plain = fl.init_fl_state("scaffold", stacked)
    new_plain, _, _ = fl.fl_round("scaffold", loss, stacked, st_plain,
                                  batches, 0.05)
    assert float(pp.worker_dists(new_plain).mean()) < 1e-6  # FedAvg reset

    dcfg = DPPFConfig(alpha=0.9, lam=1.8)
    st_d = fl.init_fl_state("scaffold", stacked)
    new_d, _, m = fl.fl_round("scaffold", loss, stacked, st_d, batches, 0.05,
                              dppf=dcfg, lam_t=1.8)
    # push keeps workers apart (post-round spread ~ lam for small pre-gap)
    assert float(pp.worker_dists(new_d).mean()) > 0.5
