"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracle,
sweeping shapes and dtypes (+ hypothesis property tests for pullpush)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis, or deterministic fallback

from repro.kernels import mamba_scan as mk
from repro.kernels import pullpush as pk
from repro.kernels import swa_attention as ak


# ---------------------------------------------------------------------------
# pullpush
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [128, 1000, 32768, 40001])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pullpush_sq_dist(n, dtype):
    key = jax.random.PRNGKey(n)
    x = jax.random.normal(key, (n,), dtype)
    a = jax.random.normal(jax.random.fold_in(key, 1), (n,), dtype)
    got = pk.sq_dist(x, a)
    want = pk.sq_dist_ref(x, a)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3 if dtype == jnp.bfloat16 else 1e-5)


@pytest.mark.parametrize("n", [256, 5000, 33000])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pullpush_apply(n, dtype):
    key = jax.random.PRNGKey(n + 7)
    x = jax.random.normal(key, (n,), dtype)
    a = jax.random.normal(jax.random.fold_in(key, 1), (n,), dtype)
    coef = 0.1 - 0.5 / 3.0
    got = pk.apply_update(x, a, coef)
    want = pk.apply_ref(x, a, coef)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 1e-6,
                               atol=1e-2 if dtype == jnp.bfloat16 else 1e-6)


def test_pullpush_fused_matches_core():
    """Kernel path == repro.core.pullpush.pullpush on a stacked pytree."""
    from repro.core import pullpush as core_pp
    key = jax.random.PRNGKey(0)
    stacked = {"w": jax.random.normal(key, (4, 33, 65)),
               "b": jax.random.normal(jax.random.fold_in(key, 1), (4, 17))}
    alpha, lam = 0.1, 0.5
    got, r = pk.pullpush_fused(stacked, alpha, lam)
    want, _ = core_pp.pullpush(stacked, alpha, lam)
    for k in stacked:
        np.testing.assert_allclose(np.asarray(got[k]), np.asarray(want[k]),
                                   rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(r),
                               np.asarray(core_pp.worker_dists(stacked)),
                               rtol=1e-5)


@settings(max_examples=25, deadline=None)
@given(n=st.integers(4, 2000), pairs=st.integers(1, 3),
       alpha=st.floats(0.01, 1.0), lam=st.floats(0.01, 2.0),
       r0=st.floats(0.1, 10.0))
def test_pullpush_width_property(n, pairs, alpha, lam, r0):
    """Property (Theorem 1 recurrence, noiseless): with workers arranged in
    +/- pairs at equal radius r0 around x_A, one Eq. 5 round moves every
    radius to |r0 (1 - alpha) + lam| — and lam/alpha is the fixed point."""
    key = jax.random.PRNGKey(n * 31 + pairs)
    d = jax.random.normal(key, (pairs, n))
    d = d / jnp.linalg.norm(d, axis=1, keepdims=True)
    dirs = jnp.concatenate([d, -d])                 # mean exactly 0
    x = {"w": dirs * r0}
    got, r = pk.pullpush_fused(x, alpha, lam)
    np.testing.assert_allclose(np.asarray(r), r0, rtol=1e-4)
    from repro.core.pullpush import worker_dists
    r_new = np.asarray(worker_dists(got))
    expect = abs(r0 * (1.0 - alpha) + lam)
    np.testing.assert_allclose(r_new, expect, rtol=2e-3, atol=2e-3)
    # fixed point check
    fp = {"w": dirs * (lam / alpha)}
    fp_new, _ = pk.pullpush_fused(fp, alpha, lam)
    np.testing.assert_allclose(np.asarray(worker_dists(fp_new)), lam / alpha,
                               rtol=2e-3)


# ---------------------------------------------------------------------------
# swa_attention
# ---------------------------------------------------------------------------

ATTN_CASES = [
    # (B, H, Hkv, Sq, Skv, hd, window, cap)
    (1, 4, 4, 128, 128, 64, 0, 0.0),
    (2, 4, 2, 256, 256, 64, 0, 0.0),          # GQA
    (1, 8, 4, 384, 384, 128, 128, 0.0),       # window
    (1, 2, 1, 512, 512, 64, 0, 50.0),         # softcap
    (2, 4, 4, 200, 200, 64, 96, 30.0),        # padding + window + cap
    (1, 4, 2, 128, 1024, 64, 256, 0.0),       # long kv, banded
]


@pytest.mark.parametrize("case", ATTN_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_swa_attention_vs_ref(case, dtype):
    B, H, Hkv, Sq, Skv, hd, window, cap = case
    key = jax.random.PRNGKey(hash(case) % (2 ** 31))
    q = jax.random.normal(key, (B, H, Sq, hd), dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, Hkv, Skv, hd), dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, Hkv, Skv, hd), dtype)
    got = ak.swa_attention(q, k, v, window=window, cap=cap, bq=128, bk=128)
    want = ak.swa_attention_ref(q, k, v, window=window, cap=cap)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_swa_attention_matches_model_attend():
    """Kernel agrees with the model-side chunked online-softmax path."""
    from repro.models.attention import attend
    key = jax.random.PRNGKey(3)
    B, S, H, Hkv, hd, W = 2, 256, 4, 2, 64, 64
    q = jax.random.normal(key, (B, S, H, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, Hkv, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, Hkv, hd))
    pos = jnp.arange(S)
    want = attend(q, k, v, q_pos=pos, kv_pos=pos, causal=True, window=W)
    got = ak.attention(q, k, v, causal=True, window=W)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# mamba_scan
# ---------------------------------------------------------------------------

SSD_CASES = [
    # (B, H, nc, L, P, N)
    (1, 2, 2, 32, 16, 8),
    (2, 4, 3, 64, 32, 16),
    (1, 1, 4, 128, 64, 64),
]


@pytest.mark.parametrize("case", SSD_CASES)
def test_mamba_chunks_vs_ref(case):
    B, H, nc, L, P, N = case
    key = jax.random.PRNGKey(sum(case))
    x = jax.random.normal(key, (B, H, nc, L, P))
    B_ = jax.random.normal(jax.random.fold_in(key, 1), (B, nc, L, N))
    C_ = jax.random.normal(jax.random.fold_in(key, 2), (B, nc, L, N))
    a_log = -jax.nn.softplus(
        jax.random.normal(jax.random.fold_in(key, 3), (B, H, nc, L)))
    got_y, got_st = mk.ssd_chunks(x, B_, C_, a_log)
    want_y, want_st = mk.ssd_chunks_ref(x, B_, C_, a_log)
    np.testing.assert_allclose(np.asarray(got_y), np.asarray(want_y),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(got_st), np.asarray(want_st),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# slstm_step
# ---------------------------------------------------------------------------

SLSTM_CASES = [
    # (B, T, H, P, t_blk)
    (2, 50, 2, 16, 16),
    (1, 128, 4, 32, 128),
    (2, 37, 2, 8, 64),     # heavy padding
    (1, 16, 1, 8, 32),     # t_blk > T
]


@pytest.mark.parametrize("case", SLSTM_CASES)
def test_slstm_kernel_vs_ref(case):
    from repro.kernels.slstm_step import slstm_scan, slstm_steps_ref
    B, T, H, P, blk = case
    key = jax.random.PRNGKey(sum(case))
    g = jax.random.normal(key, (B, T, H, 4 * P))
    R = jax.random.normal(jax.random.fold_in(key, 1), (H, P, 4 * P)) * P ** -0.5
    zero = jnp.zeros((B, H, P))
    state = (zero, zero + 1e-6, zero, zero - 1e30)
    want, st_w = slstm_steps_ref(g, R, state)
    got, st_g = slstm_scan(g, R, state, t_blk=blk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    for a, b in zip(st_w, st_g):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=2e-4, atol=2e-4)


def test_slstm_ref_matches_model_scan():
    """The kernel oracle reproduces the model's slstm_forward inner scan."""
    from repro.configs import ARCHS, reduced
    from repro.models.xlstm import init_slstm, slstm_forward, dims
    from repro.models.layers import rms_norm
    from repro.kernels.slstm_step import slstm_steps_ref
    cfg = reduced(ARCHS["xlstm-350m"])
    d_in, H, P = dims(cfg)
    key = jax.random.PRNGKey(4)
    p = init_slstm(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 20, cfg.d_model))
    want, _ = slstm_forward(p, x, cfg)

    # re-derive via the kernel oracle using the same projections
    u = rms_norm(x, p["ln"], cfg.norm_eps)
    up = u @ p["w_up"]
    xi, zgate = up[..., :d_in], up[..., d_in:]
    g_in = (xi @ p["w_gates"] + p["b_gates"]).reshape(2, 20, H, 4 * P)
    zero = jnp.zeros((2, H, P))
    state = (zero, zero + 1e-6, zero, zero - 1e30)
    hs, _ = slstm_steps_ref(g_in, p["r_gates"], state)
    h = hs.reshape(2, 20, d_in)
    h = rms_norm(h * jax.nn.silu(zgate), p["norm"], cfg.norm_eps)
    got = h @ p["w_down"]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_mamba_full_scan_matches_model():
    """Kernel-backed full scan == the model's _ssd_chunked (same layout)."""
    from repro.models.ssm import _ssd_chunked
    key = jax.random.PRNGKey(11)
    Bt, S, H, P, N, L = 2, 96, 2, 16, 8, 32
    xh = jax.random.normal(key, (Bt, S, H, P))
    B_ = jax.random.normal(jax.random.fold_in(key, 1), (Bt, S, N))
    C_ = jax.random.normal(jax.random.fold_in(key, 2), (Bt, S, N))
    a_log = -jax.nn.softplus(
        jax.random.normal(jax.random.fold_in(key, 3), (Bt, S, H)))
    want_y, want_h = _ssd_chunked(xh, B_, C_, a_log, L)

    nc = S // L
    xk = xh.reshape(Bt, nc, L, H, P).transpose(0, 3, 1, 2, 4)
    ak_ = a_log.reshape(Bt, nc, L, H).transpose(0, 3, 1, 2)
    got_y, got_h = mk.ssd_scan(xk, B_.reshape(Bt, nc, L, N),
                               C_.reshape(Bt, nc, L, N), ak_)
    got_y = got_y.transpose(0, 2, 3, 1, 4).reshape(Bt, S, H, P)
    np.testing.assert_allclose(np.asarray(got_y), np.asarray(want_y),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(got_h), np.asarray(want_h),
                               rtol=1e-4, atol=1e-4)
