"""Deeper model tests: attention properties (hypothesis), enc-dec decode
consistency, gemma2 window semantics, MoE load balance, landscape scan."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis, or deterministic fallback

from repro.configs import ARCHS, reduced
from repro.models import build_model
from repro.models.attention import attend


# ---------------------------------------------------------------------------
# attention properties
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(s=st.integers(2, 40), h=st.sampled_from([2, 4]),
       g=st.sampled_from([1, 2]), w=st.integers(0, 16))
def test_attend_rows_are_convex_combinations(s, h, g, w):
    """Attention output lies in the convex hull of V rows: max|out| <=
    max|v| (softmax weights sum to 1)."""
    key = jax.random.PRNGKey(s * 100 + h + w)
    nq, nkv = h * g, h
    q = jax.random.normal(key, (1, s, nq, 8))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, s, nkv, 8))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, s, nkv, 8))
    pos = jnp.arange(s)
    out = attend(q, k, v, q_pos=pos, kv_pos=pos, causal=True, window=w)
    assert float(jnp.abs(out).max()) <= float(jnp.abs(v).max()) + 1e-4


def test_attend_first_token_attends_only_itself():
    key = jax.random.PRNGKey(0)
    S = 8
    q = jax.random.normal(key, (1, S, 2, 4))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, S, 2, 4))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, S, 2, 4))
    pos = jnp.arange(S)
    out = attend(q, k, v, q_pos=pos, kv_pos=pos, causal=True)
    np.testing.assert_allclose(np.asarray(out[0, 0]), np.asarray(v[0, 0]),
                               rtol=1e-5, atol=1e-5)


def test_attend_window_equals_full_when_window_ge_seq():
    key = jax.random.PRNGKey(1)
    S = 12
    q = jax.random.normal(key, (2, S, 4, 8))
    k = jax.random.normal(jax.random.fold_in(key, 1), (2, S, 2, 8))
    v = jax.random.normal(jax.random.fold_in(key, 2), (2, S, 2, 8))
    pos = jnp.arange(S)
    full = attend(q, k, v, q_pos=pos, kv_pos=pos, causal=True, window=0)
    wide = attend(q, k, v, q_pos=pos, kv_pos=pos, causal=True, window=S + 5)
    np.testing.assert_allclose(np.asarray(full), np.asarray(wide), rtol=1e-5)


def test_attend_window_restricts_context():
    """With window=1 every token attends only to itself."""
    key = jax.random.PRNGKey(2)
    S = 6
    q = jax.random.normal(key, (1, S, 2, 4))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, S, 2, 4))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, S, 2, 4))
    pos = jnp.arange(S)
    out = attend(q, k, v, q_pos=pos, kv_pos=pos, causal=True, window=1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(v), rtol=1e-5,
                               atol=1e-5)


def test_chunked_attend_matches_small_path():
    """Force the chunked online-softmax path (Skv > _CHUNK) and compare to
    a monkeypatched single-block computation."""
    from repro.models import attention as A
    key = jax.random.PRNGKey(3)
    S = A._CHUNK + 64
    q = jax.random.normal(key, (1, 8, 2, 16))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, S, 2, 16))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, S, 2, 16))
    q_pos = jnp.arange(S - 8, S)
    kv_pos = jnp.arange(S)
    chunked = A.attend(q, k, v, q_pos=q_pos, kv_pos=kv_pos, causal=True)
    old = A._CHUNK
    try:
        A._CHUNK = S  # single-block path
        single = A.attend(q, k, v, q_pos=q_pos, kv_pos=kv_pos, causal=True)
    finally:
        A._CHUNK = old
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(single),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# enc-dec decode consistency (closes the skip in test_arch_smoke)
# ---------------------------------------------------------------------------

def test_encdec_decode_matches_teacher_forcing():
    cfg = reduced(ARCHS["seamless-m4t-medium"])
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    B, S = 2, 16
    toks = jax.random.randint(jax.random.fold_in(key, 1), (B, S), 0,
                              cfg.vocab_size)
    enc = jax.random.normal(jax.random.fold_in(key, 2),
                            (B, cfg.n_prefix, cfg.d_model))
    from repro.models.encdec import encode, decode_stack
    from repro.models.transformer import _embed, _head
    enc_out = encode(cfg, params, enc)
    x = _embed(params, cfg, toks)
    x, _ = decode_stack(cfg, params, x, enc_out=enc_out)
    full = _head(params, cfg, x)

    batch = {"tokens": toks[:, :-1], "enc": enc}
    _, states = model.prefill(params, batch, buf_len=S + 4)
    logits, _ = model.decode_step(params, states, toks[:, -1:],
                                  jnp.int32(S - 1))
    np.testing.assert_allclose(np.asarray(logits), np.asarray(full[:, -1]),
                               rtol=2e-2, atol=2e-2)


# ---------------------------------------------------------------------------
# MoE router behaviour
# ---------------------------------------------------------------------------

def test_moe_aux_loss_uniform_router_is_one():
    """With a zeroed router the importance/load are uniform -> aux == 1."""
    from repro.models.moe import init_moe, moe_mlp
    cfg = reduced(ARCHS["dbrx-132b"])
    p = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    p = dict(p)
    p["router"] = jnp.zeros_like(p["router"])
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    _, aux = moe_mlp(p, x, cfg)
    # ties in top_k make load slightly non-uniform; aux stays near 1
    assert 0.8 < float(aux) < 2.0


def test_moe_capacity_drops_tokens_when_tight():
    from repro.models.moe import init_moe, moe_mlp
    cfg = dataclasses.replace(reduced(ARCHS["dbrx-132b"]),
                              capacity_factor=0.25)
    p = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, cfg.d_model))
    out, _ = moe_mlp(p, x, cfg)
    # dropped tokens produce zero expert output rows
    row_norm = jnp.linalg.norm(out[0], axis=-1)
    assert float((row_norm < 1e-6).sum()) > 0


# ---------------------------------------------------------------------------
# landscape scan (Algorithm 3)
# ---------------------------------------------------------------------------

def test_landscape_scan_quadratic():
    from repro.core.theory import landscape_scan
    def loss(p):
        return jnp.sum(p["x"] ** 2)
    workers = [{"x": jnp.eye(4)[i] * 2.0} for i in range(3)]
    res = landscape_scan(loss, workers, lim=2.0, step=1.0)
    scan = np.asarray(res["scan"])
    mid = len(res["grid"]) // 2
    # minimum at x_A's plane origin (x_A is the worker mean, not 0, but the
    # quadratic grows away from the grid center monotonically)
    assert scan[mid, mid] == scan.min()
    assert res["worker_coords"].shape == (3, 2)
