"""Continuous-batching serving tests: SlotEngine vs generate() parity
(greedy + ring wraparound) across all five families, zero-recompile
compile-counter pins, the decode_key sampling contract end-to-end, the
static-vs-continuous structural step ordering, fused-sampling units, and
the serving ValueError surface."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.models import build_model
from repro.serving import (
    GREEDY, Request, SamplingParams, SlotEngine, decode_loop_cache_size,
    generate, serve,
)
from repro.serving.sampling import NEG_INF, mask_logits, sample_batch

# one arch per ModelAPI family (dense / moe / hybrid-ssm / xlstm / enc-dec)
FAMILIES = ["yi-6b", "dbrx-132b", "zamba2-7b", "xlstm-350m",
            "seamless-m4t-medium"]


@functools.lru_cache(maxsize=None)
def _mp(arch):
    """Shared (cfg, model, params) per arch — one init, shared jit caches."""
    cfg = reduced(ARCHS[arch])
    model = build_model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, (l,)) for l in lens]


def _enc(cfg, rid):
    return 0.02 * np.asarray(jax.random.normal(
        jax.random.fold_in(jax.random.PRNGKey(9), rid),
        (cfg.n_prefix, cfg.d_model)))


def _requests(cfg, lens, news, seed=0):
    return [Request(rid=i, tokens=t, max_new_tokens=n,
                    enc=_enc(cfg, i) if cfg.n_enc_layers else None)
            for i, (t, n) in enumerate(zip(_prompts(cfg, lens, seed), news))]


def _example(cfg):
    ex = {"tokens": np.zeros((1, 1), np.int32)}
    if cfg.n_enc_layers:
        ex["enc"] = np.zeros((1, cfg.n_prefix, cfg.d_model), np.float32)
    return ex


def _gen_batch(cfg, req):
    batch = {"tokens": np.asarray(req.tokens)[None].astype(np.int32)}
    if req.enc is not None:
        batch["enc"] = np.asarray(req.enc)[None].astype(np.float32)
    return batch


# ---------------------------------------------------------------------------
# continuous batching == generate(), per family + zero-recompile pin
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", FAMILIES)
def test_continuous_matches_generate_and_never_recompiles(arch):
    """Mixed-length requests admitted/evicted mid-decode produce EXACTLY
    the tokens of per-request generate() (greedy), and a second,
    differently-mixed stream leaves every compiled lane at cache size 1."""
    cfg, model, params = _mp(arch)
    engine = SlotEngine(model, params, max_slots=2, buf_len=32, chunk=4,
                        example=_example(cfg))

    lens, news = [5, 11, 3], [6, 4, 5]
    reqs = _requests(cfg, lens, news)
    report = serve(engine, reqs)
    assert sorted(report.results) == [0, 1, 2]
    assert report.generated == sum(news)
    for req in reqs:
        want, _ = generate(model, params, _gen_batch(cfg, req),
                           max_new_tokens=req.max_new_tokens, buf_len=32)
        assert report.results[req.rid].tokens == [int(t) for t in want[0]], \
            f"{arch}: rid {req.rid} diverged from generate()"

    # every lane compiled exactly once during the first stream; a second
    # stream with a different admission/eviction mix must not retrace
    sizes = engine.compile_cache_sizes()
    assert sizes == {"fresh": 1, "chunk": 1, "decode": 1, "insert": 1}, sizes
    serve(engine, _requests(cfg, [9, 2, 6], [3, 5, 2], seed=1))
    assert engine.compile_cache_sizes() == sizes


@pytest.mark.parametrize("arch", FAMILIES)
def test_ring_wraparound_matches_generate(arch):
    """Prompts longer than buf_len stream through the ring (window mode);
    decode continues past the wrap point. Exact parity with windowed
    generate() pins the slot == pos % buf invariant and the
    buf_len >= window + chunk - 1 streaming contract."""
    cfg, model, params = _mp(arch)
    window, chunk, buf = 16, 4, 19     # buf == window + chunk - 1 exactly
    engine = SlotEngine(model, params, max_slots=2, buf_len=buf,
                        window=window, chunk=chunk, example=_example(cfg))
    reqs = _requests(cfg, [24, 20], [8, 8])   # prompt_len + new > window
    report = serve(engine, reqs)
    for req in reqs:
        want, _ = generate(model, params, _gen_batch(cfg, req),
                           max_new_tokens=8, buf_len=buf, window=window,
                           chunk=chunk)
        assert report.results[req.rid].tokens == [int(t) for t in want[0]], \
            f"{arch}: ring-wraparound rid {req.rid} diverged"


# ---------------------------------------------------------------------------
# sampled path: reproducibility, slot independence, decode_key contract
# ---------------------------------------------------------------------------

def test_sampled_stream_reproducible_and_slot_independent():
    """Per-request keys are derived from rid, so sampled outputs are a
    function of the request alone: same stream twice -> identical tokens,
    and submission order (hence slot placement / co-residents) is
    irrelevant."""
    cfg, model, params = _mp("yi-6b")
    sp = SamplingParams(temperature=0.8, top_k=8)
    engine = SlotEngine(model, params, max_slots=2, buf_len=48, chunk=4,
                        sampling=sp)
    lens, news = [7, 5, 9], [6, 6, 6]
    key = jax.random.PRNGKey(5)
    a = serve(engine, _requests(cfg, lens, news), key=key)
    b = serve(engine, _requests(cfg, lens, news), key=key)
    c = serve(engine, list(reversed(_requests(cfg, lens, news))), key=key)
    for rid in range(3):
        assert a.results[rid].tokens == b.results[rid].tokens
        assert a.results[rid].tokens == c.results[rid].tokens, \
            f"rid {rid}: tokens depend on submission order"


def test_engine_sampling_follows_decode_key_contract():
    """Manual replay: generated token 0 is sampled with the request key
    itself, token i >= 1 with fold_in(key, i) — independent of how the
    prompt was chunked into the slot."""
    from repro.serving import decode_key
    from repro.serving.sampling import sample_token

    cfg, model, params = _mp("yi-6b")
    sp = SamplingParams(temperature=0.8, top_k=8)
    engine = SlotEngine(model, params, max_slots=1, buf_len=32, chunk=4,
                        sampling=sp)
    prompt = _prompts(cfg, [6])[0]
    base = jax.random.PRNGKey(7)
    rkey = np.asarray(jax.random.fold_in(base, 0), np.uint32)
    report = serve(engine, [Request(rid=0, tokens=prompt, max_new_tokens=5)],
                   key=base)

    logits, states = model.prefill(
        params, {"tokens": prompt[None].astype(np.int32)}, buf_len=32)
    tok = int(sample_token(logits[0].astype(jnp.float32),
                           decode_key(rkey, 0), sp))
    want = [tok]
    start = prompt.size
    for i in range(1, 5):
        lg, states = model.decode_step(
            params, states, np.asarray([[tok]], np.int32),
            jnp.int32(start + i - 1))
        tok = int(sample_token(lg[0].astype(jnp.float32),
                               decode_key(rkey, i), sp))
        want.append(tok)
    assert report.results[0].tokens == want


# ---------------------------------------------------------------------------
# generate(): jitted decode loop never retraces on identical shapes
# ---------------------------------------------------------------------------

def test_generate_decode_loop_no_retrace():
    cfg, model, params = _mp("yi-6b")
    batch = {"tokens": _prompts(cfg, [10], seed=3)[0][None].astype(np.int32)}
    t1, _ = generate(model, params, batch, max_new_tokens=7, buf_len=24)
    t2, _ = generate(model, params, batch, max_new_tokens=7, buf_len=24)
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))
    assert decode_loop_cache_size(model, 7, 0) == 1
    # a different prompt length reuses the SAME compile (start is traced)
    generate(model, params,
             {"tokens": _prompts(cfg, [14], seed=4)[0][None].astype(np.int32)},
             max_new_tokens=7, buf_len=24)
    assert decode_loop_cache_size(model, 7, 0) == 1


# ---------------------------------------------------------------------------
# static vs continuous: structural ordering on a mixed trace
# ---------------------------------------------------------------------------

def test_continuous_needs_no_more_steps_than_static():
    """Both modes run the same compiled decode step, so step counts are a
    timer-free efficiency metric; greedy tokens must be identical."""
    cfg, model, params = _mp("gemma2-2b")
    engine = SlotEngine(model, params, max_slots=2, buf_len=32, chunk=4)
    lens, news = [10, 3, 5, 7], [8, 2, 4, 6]
    cont = serve(engine, _requests(cfg, lens, news), mode="continuous")
    stat = serve(engine, _requests(cfg, lens, news), mode="static")
    assert cont.steps <= stat.steps
    assert cont.occupancy >= stat.occupancy
    for rid in range(4):
        assert cont.results[rid].tokens == stat.results[rid].tokens


# ---------------------------------------------------------------------------
# fused sampling units
# ---------------------------------------------------------------------------

def test_mask_logits_top_k_keeps_exactly_k():
    logits = jnp.asarray([0.1, 3.0, -1.0, 2.0, 0.5, -2.0])
    out = mask_logits(logits, SamplingParams(top_k=2))
    kept = np.flatnonzero(np.asarray(out) > NEG_INF / 2)
    np.testing.assert_array_equal(kept, [1, 3])


def test_mask_logits_top_p_never_empties_and_keeps_nucleus():
    logits = jnp.asarray([10.0, 1.0, 0.0, -1.0])
    # p tiny: the argmax alone always survives (exclusive cumsum)
    out = mask_logits(logits, SamplingParams(top_p=1e-6))
    kept = np.flatnonzero(np.asarray(out) > NEG_INF / 2)
    np.testing.assert_array_equal(kept, [0])
    # p = 1 keeps everything
    out = mask_logits(logits, SamplingParams(top_p=1.0))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(logits))


def test_mask_logits_temperature_and_greedy_passthrough():
    logits = jnp.asarray([1.0, 2.0, 4.0])
    np.testing.assert_allclose(
        np.asarray(mask_logits(logits, SamplingParams(temperature=2.0))),
        np.asarray(logits) / 2.0, rtol=1e-6)
    # greedy and the no-op params return the input bit-identically
    assert mask_logits(logits, GREEDY) is logits
    assert mask_logits(logits, SamplingParams()) is logits


def test_sample_batch_independent_rows():
    logits = jnp.tile(jnp.asarray([0.0, 0.0, 0.0, 5.0]), (3, 1))
    keys = jnp.stack([jax.random.PRNGKey(i) for i in range(3)])
    toks = sample_batch(logits, keys, SamplingParams(temperature=1e-3))
    np.testing.assert_array_equal(np.asarray(toks), [3, 3, 3])
    assert sample_batch(logits, keys, GREEDY).dtype == jnp.int32


# ---------------------------------------------------------------------------
# ring cache unit: wrap-scatter
# ---------------------------------------------------------------------------

def test_cache_update_chunk_wraps_around_ring_seam():
    from repro.models.attention import cache_update, init_cache
    cache = init_cache(1, 1, 8, 4, jnp.float32)
    k = jnp.arange(4 * 4, dtype=jnp.float32).reshape(1, 4, 1, 4)
    out = cache_update(cache, k, k, 6)          # positions 6..9
    np.testing.assert_array_equal(
        np.asarray(out["pos"]), [8, 9, -1, -1, -1, -1, 6, 7])
    # slot p % buf holds position p's row
    np.testing.assert_array_equal(np.asarray(out["k"][0, 6, 0]),
                                  np.asarray(k[0, 0, 0]))
    np.testing.assert_array_equal(np.asarray(out["k"][0, 1, 0]),
                                  np.asarray(k[0, 3, 0]))


# ---------------------------------------------------------------------------
# ValueError surface (mirrored under python -O by tests/optcheck.py)
# ---------------------------------------------------------------------------

def test_sampling_params_validation():
    for bad in (dict(temperature=-0.1), dict(top_k=-1), dict(top_p=0.0),
                dict(top_p=1.5)):
        with pytest.raises(ValueError):
            SamplingParams(**bad)


def test_generate_validation():
    cfg, model, params = _mp("yi-6b")
    batch = {"tokens": np.zeros((1, 6), np.int32)}
    with pytest.raises(ValueError, match="max_new_tokens"):
        generate(model, params, batch, max_new_tokens=0, buf_len=16)
    with pytest.raises(ValueError, match="window"):
        generate(model, params, batch, max_new_tokens=2, buf_len=8, window=9)
    with pytest.raises(ValueError, match="silently truncate"):
        # prompt exceeds buf_len and no sliding window
        generate(model, params, {"tokens": np.zeros((1, 20), np.int32)},
                 max_new_tokens=2, buf_len=16)


def test_slot_engine_validation():
    cfg, model, params = _mp("yi-6b")
    for kw in (dict(max_slots=0, buf_len=8), dict(max_slots=1, buf_len=0),
               dict(max_slots=1, buf_len=8, window=-1),
               dict(max_slots=1, buf_len=8, window=9),
               # chunk write would clobber live ring slots
               dict(max_slots=1, buf_len=16, window=16, chunk=8)):
        with pytest.raises(ValueError):
            SlotEngine(model, params, **kw)
    ecfg, emodel, eparams = _mp("seamless-m4t-medium")
    with pytest.raises(ValueError, match="example"):
        SlotEngine(emodel, eparams, max_slots=1, buf_len=8)

    engine = SlotEngine(model, params, max_slots=2, buf_len=16)
    slots = engine.blank_slots()
    state, start = engine.request_state({"tokens": np.asarray([[0]], np.int32)})
    with pytest.raises(ValueError, match="slot"):
        engine.insert(slots, state, 2, 0, 0, 4, np.zeros(2, np.uint32))
    with pytest.raises(ValueError, match="max_new_tokens"):
        engine.insert(slots, state, 0, 0, 0, 0, np.zeros(2, np.uint32))
    with pytest.raises(ValueError, match="empty prompt"):
        engine.prefill_chunks(state, np.zeros((0,), np.int64), start)


def test_scheduler_and_request_validation():
    from repro.serving import Scheduler
    cfg, model, params = _mp("yi-6b")
    with pytest.raises(ValueError, match="max_slots"):
        Scheduler(0)
    with pytest.raises(ValueError, match="mode"):
        Scheduler(1, mode="adaptive")
    with pytest.raises(ValueError, match="empty prompt"):
        Request(rid=0, tokens=np.zeros((0,)), max_new_tokens=1)
    with pytest.raises(ValueError, match="max_new_tokens"):
        Request(rid=0, tokens=np.ones((3,)), max_new_tokens=0)
    # window == 0 capacity check at submit time
    engine = SlotEngine(model, params, max_slots=1, buf_len=16)
    sched = Scheduler(1)
    with pytest.raises(ValueError, match="buf_len"):
        sched.submit(Request(rid=0, tokens=np.ones((10,), np.int64),
                             max_new_tokens=10), engine)


def test_cache_update_rejects_oversized_write():
    from repro.models.attention import cache_update, init_cache
    cache = init_cache(1, 1, 4, 2, jnp.float32)
    k = jnp.zeros((1, 5, 1, 2))
    with pytest.raises(ValueError, match="buf_len"):
        cache_update(cache, k, k, 0)


def test_serving_roofline_validation_and_bounds():
    from repro.launch.roofline import serving_model
    cfg = ARCHS["gemma2-2b"]
    with pytest.raises(ValueError):
        serving_model(cfg, max_slots=0, chunk=1, state_bytes_per_slot=1)
    with pytest.raises(ValueError):
        serving_model(cfg, max_slots=1, chunk=0, state_bytes_per_slot=1)
    r = serving_model(cfg, max_slots=64, chunk=256,
                      state_bytes_per_slot=10 ** 9)
    assert r["decode_bound"] in ("memory", "compute")
    assert r["prefill_tok_s"] > r["decode_tok_s"]
    assert r["prefill_tokens_per_decode_step"] > 0
