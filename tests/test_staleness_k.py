"""Staleness-k ring-pipelined consensus: the k-deep snapshot ring as the
generalization of the two-buffer doublebuf recursion (k=1 bit-parity), the
explicit k-buffer reference, the ppermute ring gather's concatenation-order
contract, bounded-async elastic rounds (drop / freeze / forced rejoin /
EASGD-style catch-up), and checkpoint resume mid-pipeline.

Multi-device legs run in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (same pattern as
test_sharded_round.py); single-device tests exercise the identical traced
code path in-process."""
from __future__ import annotations

import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_train_state, save_train_state
from repro.configs import DPPFConfig
from repro.core import consensus
from repro.optim import make_optimizer
from repro.train import (
    RoundClock, init_train_state, make_round_step, set_participation,
)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
ROOT = os.path.join(os.path.dirname(__file__), "..")


def _mlp_setup(M=4, tau=2, dim=16, ncls=4, width=8):
    from benchmarks.common import mlp_init, mlp_loss
    opt = make_optimizer("sgd", momentum=0.9)
    p0 = lambda k: mlp_init(k, dim, ncls, width)

    def batches(seed):
        k = jax.random.PRNGKey(seed)
        return {"x": jax.random.normal(k, (tau, M, 8, dim)),
                "y": jax.random.randint(jax.random.fold_in(k, 1),
                                        (tau, M, 8), 0, ncls)}
    return opt, p0, mlp_loss, batches


# ---------------------------------------------------------------------------
# config plumbing
# ---------------------------------------------------------------------------

def test_staleness_k_config_validation():
    with pytest.raises(ValueError, match="staleness_k"):
        DPPFConfig(engine="tree", overlap="staleness_k")
    with pytest.raises(ValueError, match="staleness"):
        DPPFConfig(engine="flat", overlap="staleness_k", staleness=0)
    # elastic rides the staleness_k carry only
    with pytest.raises(ValueError, match="elastic"):
        DPPFConfig(engine="flat", overlap="doublebuf", elastic=True)
    with pytest.raises(ValueError, match="exact_second_term"):
        DPPFConfig(engine="flat", overlap="staleness_k", elastic=True,
                   exact_second_term=True)
    with pytest.raises(ValueError, match="elastic_catchup"):
        DPPFConfig(engine="flat", overlap="staleness_k", elastic=True,
                   elastic_catchup=1.5)
    dcfg = DPPFConfig(engine="flat", overlap="staleness_k", staleness=3,
                      elastic=True)
    assert dcfg.staleness == 3 and dcfg.elastic


def test_staleness_k_ring_state_shape():
    """init builds the (k, R, n) ring — every slot the init fleet — and
    the elastic carry (participation ring + membership + missed counter)
    only when requested."""
    M, k = 4, 3
    opt, p0, _, _ = _mlp_setup(M=M)
    dcfg = DPPFConfig(engine="flat", overlap="staleness_k", staleness=k)
    st = init_train_state(p0, opt, dcfg, M, jax.random.PRNGKey(0))
    assert st.snap["x"].shape == (k,) + st.params.shape
    np.testing.assert_array_equal(np.asarray(st.snap["x"][0]),
                                  np.asarray(st.snap["x"][k - 1]))
    assert st.snap["losses"].shape == (k, M)
    assert "active" not in st.snap
    st_e = init_train_state(
        p0, opt, dataclasses.replace(dcfg, elastic=True), M,
        jax.random.PRNGKey(0))
    assert st_e.snap["act"].shape == (k, M)
    assert st_e.snap["active"].shape == (M,)
    assert st_e.snap["missed"].shape == (M,)
    assert st_e.snap["missed"].dtype == jnp.int32


# ---------------------------------------------------------------------------
# k=1 == doublebuf, and the explicit k-buffer reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method",
                         ["simple_avg", "hard", "easgd", "lsgd", "mgrawa"])
def test_staleness_k1_bitwise_equals_doublebuf(method):
    """The acceptance bar's single-device half: staleness_k with k=1 and
    one chunk IS the doublebuf recursion — same exact-consensus fill
    round, same stale delta, same snapshot advance — bit-for-bit in
    precise mode from init, for every consensus method (easgd's aux row
    rides the ring too). The staleness metric counts depth, not a flag."""
    M, tau = 4, 2
    opt, p0, loss, batches = _mlp_setup(M=M, tau=tau)
    base = dict(alpha=0.2, lam=0.4, tau=tau, consensus=method,
                engine="flat", lam_schedule="fixed")
    d_db = DPPFConfig(overlap="doublebuf", overlap_chunks=1, **base)
    d_k1 = DPPFConfig(overlap="staleness_k", staleness=1, overlap_chunks=1,
                      **base)
    key = jax.random.PRNGKey(0)
    sts, fns, ms = [], [], [None, None]
    for d in (d_db, d_k1):
        st = init_train_state(p0, opt, d, M, key)
        st = dataclasses.replace(
            st, engine=dataclasses.replace(st.engine, precise=True))
        sts.append(st)
        fns.append(jax.jit(make_round_step(loss, opt, d, base_lr=0.05,
                                           total_steps=20)))
    for r in range(4):
        b = batches(r)
        for i in range(2):
            sts[i], ms[i] = fns[i](sts[i], b)
        dp = float(jnp.max(jnp.abs(sts[0].params - sts[1].params)))
        ds = float(jnp.max(jnp.abs(sts[0].snap["x"] - sts[1].snap["x"][0])))
        assert dp == 0.0 and ds == 0.0, (method, r, dp, ds)
        assert float(ms[0]["staleness"]) == float(ms[1]["staleness"]) \
            == (0.0 if r == 0 else 1.0)


@pytest.mark.parametrize("method", ["simple_avg", "easgd"])
def test_staleness_k_matches_k_buffer_reference(method):
    """The fused staleness-k round against the explicit k-buffer scheme
    (k=2): rounds 0..k-1 are exact-consensus pipeline fill
    x_{r+1} = C(q_r); from round k on, x_{r+1} = q_r + (C(s_{r-k}) -
    s_{r-k}) with the ring advanced by one snapshot per round."""
    M, tau, k = 4, 2, 2
    opt, p0, loss, batches = _mlp_setup(M=M, tau=tau)
    dcfg = DPPFConfig(alpha=0.2, lam=0.4, tau=tau, consensus=method,
                      engine="flat", overlap="staleness_k", staleness=k,
                      overlap_chunks=1, lam_schedule="fixed")
    key = jax.random.PRNGKey(0)
    st = init_train_state(p0, opt, dcfg, M, key)
    eng = st.engine
    step = jax.jit(make_round_step(loss, opt, dcfg, base_lr=0.05,
                                   total_steps=20))

    # reference: pure local steps via an identity-consensus (ddp) round on
    # the same engine, the ring and the stale delta maintained by hand
    from repro.train.trainer import TrainState
    dcfg_local = dataclasses.replace(dcfg, consensus="ddp", overlap="none",
                                     staleness=1)
    local_only = jax.jit(make_round_step(loss, opt, dcfg_local, base_lr=0.05,
                                         total_steps=20))
    st_ref = TrainState(params=st.params + 0.0,
                        opt=jax.tree.map(jnp.copy, st.opt),
                        cstate={}, t=st.t, engine=eng)
    ring = [st.params + 0.0 for _ in range(k)]
    cstate = {}
    for r in range(5):
        b = batches(r)
        st, m = step(st, b)
        st_ref, _ = local_only(st_ref, b)
        q = st_ref.params
        if r >= k:
            s_old = ring[0]
            c_out, cstate, _ = consensus.apply_round(
                s_old, dcfg, float(m["lam_t"]), cstate, engine=eng)
            new_x = q + (c_out - s_old)
            assert float(m["staleness"]) == k
        else:
            c_out, cstate, _ = consensus.apply_round(
                q, dcfg, float(m["lam_t"]), cstate, engine=eng)
            new_x = c_out
            assert float(m["staleness"]) == 0.0
        st_ref = dataclasses.replace(st_ref, params=new_x)
        ring = ring[1:] + [q]
        np.testing.assert_allclose(np.asarray(st.params),
                                   np.asarray(st_ref.params),
                                   atol=1e-5, rtol=1e-5, err_msg=f"round {r}")
        np.testing.assert_allclose(np.asarray(st.snap["x"][0]),
                                   np.asarray(ring[0]), atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# elastic: masked lowering unit + drop/freeze/rejoin through the round
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method",
                         ["simple_avg", "easgd", "lsgd", "mgrawa"])
def test_lower_stages_elastic_mask(method):
    """The row-stochastic lowering under a participation mask: inactive
    worker rows get zero pull/push coefficients (their flat-view row
    passes through each mixing stage bit-exactly), active target weights
    renormalize, aux rows keep their coefficients; exact_second_term
    stages refuse the mask."""
    M = 4
    opt, p0, _, _ = _mlp_setup(M=M)
    dcfg = DPPFConfig(alpha=0.2, lam=0.4, consensus=method, engine="flat")
    st = init_train_state(p0, opt, dataclasses.replace(
        dcfg, overlap="staleness1"), M, jax.random.PRNGKey(0))
    eng = st.engine
    mask = jnp.asarray([1.0, 1.0, 0.0, 1.0])
    kw = {}
    if method == "lsgd":
        kw["losses"] = jnp.asarray([3.0, 2.0, 0.1, 4.0])
    if method == "mgrawa":
        kw["grad_norms"] = jnp.ones((M,))
    stages, _ = consensus.lower_stages(eng, dcfg, 0.3, mask=mask, **kw)
    assert stages, method
    for kind, T, c0, c1 in stages:
        assert kind == "coef"
        # dropped row 2 neither pulls nor pushes
        assert float(c0[2]) == 0.0 and float(c1[2]) == 0.0
        # surviving target weights renormalize (row-stochastic over the
        # ACTIVE workers — easgd splits the mass with its aux center row)
        # and the dropped worker never appears as a target
        w_row = np.asarray(T[0])
        if w_row.sum() > 0:
            assert abs(w_row.sum() - 1.0) < 1e-6
            assert w_row[2] == 0.0
    if method == "lsgd":
        # the masked argmin skips row 2's (smallest) loss: row 1 leads
        T1 = stages[0][1]
        assert float(T1[0][1]) == 1.0 and float(T1[0][2]) == 0.0
    if method == "easgd" and eng.layout.aux:
        # the center row keeps its coefficient (tracks the ACTIVE mean)
        assert float(stages[0][2][M]) > 0.0
    with pytest.raises(ValueError, match="exact_second_term"):
        consensus.lower_stages(
            eng, dataclasses.replace(dcfg, consensus="simple_avg",
                                     exact_second_term=True),
            0.3, mask=mask)


def test_elastic_drop_freeze_and_forced_rejoin():
    """Bounded-async semantics through the traced round: a dropped row's
    worker params freeze bit-exactly (local steps reverted, no stale
    delta received), the missed counter rides the carry, and after k
    missed rounds the bounded-staleness clamp forces the row back in with
    an EASGD-style catch-up pull toward the active mean."""
    M, tau, k = 4, 2, 2
    opt, p0, loss, batches = _mlp_setup(M=M, tau=tau)
    dcfg = DPPFConfig(alpha=0.2, lam=0.4, tau=tau, engine="flat",
                      overlap="staleness_k", staleness=k, elastic=True,
                      elastic_catchup=0.5, lam_schedule="fixed")
    st = init_train_state(p0, opt, dcfg, M, jax.random.PRNGKey(0))
    step = jax.jit(make_round_step(loss, opt, dcfg, base_lr=0.05,
                                   total_steps=40))
    frozen_row = None
    for r in range(6):
        mask = np.ones(M, np.float32)
        if r in (2, 3, 4):          # requested out for 3 rounds > k
            mask[1] = 0.0
        st = set_participation(st, jnp.asarray(mask))
        before = np.asarray(st.engine.workers(st.params)[1])
        st, m = step(st, batches(r))
        after = np.asarray(st.engine.workers(st.params)[1])
        missed = int(st.snap["missed"][1])
        if r in (2, 3):
            np.testing.assert_array_equal(before, after)
            assert missed == r - 1
            frozen_row = after
        elif r == 4:
            # k rounds missed -> the clamp forces eff=1 despite the
            # requested drop: the row moves again and the counter resets
            assert np.abs(after - frozen_row).max() > 0.0
            assert missed == 0
        else:
            assert missed == 0
    assert np.isfinite(np.asarray(st.params)).all()
    # other rows never froze
    assert float(m["train_loss"]) < 10.0


def test_set_participation_validates():
    M = 4
    opt, p0, _, _ = _mlp_setup(M=M)
    st = init_train_state(
        p0, opt, DPPFConfig(engine="flat", overlap="staleness_k",
                            staleness=2), M, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="elastic"):
        set_participation(st, jnp.ones((M,)))
    st_e = init_train_state(
        p0, opt, DPPFConfig(engine="flat", overlap="staleness_k",
                            staleness=2, elastic=True), M,
        jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="shape"):
        set_participation(st_e, jnp.ones((M + 1,)))
    out = set_participation(st_e, jnp.zeros((M,)))
    np.testing.assert_array_equal(np.asarray(out.snap["active"]),
                                  np.zeros(M))


# ---------------------------------------------------------------------------
# checkpoint: resume mid-pipeline (fill and steady state)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("stop_round", [1, 3])
def test_checkpoint_resume_mid_pipeline(tmp_path, stop_round):
    """A staleness-k (k=2) run checkpointed mid-pipeline — during the
    exact-consensus fill (round 1 < k) and in the steady stale state
    (round 3 >= k) — resumes bit-for-bit: the ring, the carried round
    index (which gates the fill cond), and the clock position all
    round-trip through the npz."""
    M, tau, k = 4, 2, 2
    opt, p0, loss, batches = _mlp_setup(M=M, tau=tau)
    dcfg = DPPFConfig(alpha=0.2, lam=0.4, tau=tau, engine="flat",
                      overlap="staleness_k", staleness=k,
                      lam_schedule="fixed")
    clock = RoundClock.from_config(dcfg, base_lr=0.05, total_steps=12)
    step = jax.jit(make_round_step(loss, opt, dcfg, clock=clock))
    key = jax.random.PRNGKey(0)

    st_full = init_train_state(p0, opt, dcfg, M, key)
    st_half = init_train_state(p0, opt, dcfg, M, key)
    for r in range(6):
        st_full, _ = step(st_full, batches(r))
        if r < stop_round:
            st_half, _ = step(st_half, batches(r))
    path = str(tmp_path / "mid.npz")
    save_train_state(path, st_half)
    like = init_train_state(p0, opt, dcfg, M, key)
    st_res = load_train_state(path, like, clock=clock)
    assert int(st_res.round) == stop_round
    np.testing.assert_array_equal(np.asarray(st_res.snap["x"]),
                                  np.asarray(st_half.snap["x"]))
    for r in range(stop_round, 6):
        st_res, m = step(st_res, batches(r))
    assert float(m["staleness"]) == k
    np.testing.assert_allclose(np.asarray(st_res.params),
                               np.asarray(st_full.params), atol=1e-7,
                               rtol=0)


def test_checkpoint_snapless_resume_broadcasts_ring(tmp_path):
    """An exact-mode checkpoint (no snapshot) resuming into a staleness-k
    run warm-starts EVERY ring slot with the restored params (the 3-D
    generalization of the staleness-1 fallback)."""
    M, tau, k = 4, 2, 3
    opt, p0, loss, batches = _mlp_setup(M=M, tau=tau)
    d_ex = DPPFConfig(alpha=0.2, lam=0.4, tau=tau, engine="flat",
                      lam_schedule="fixed")
    st = init_train_state(p0, opt, d_ex, M, jax.random.PRNGKey(0))
    st, _ = jax.jit(make_round_step(loss, opt, d_ex, base_lr=0.05,
                                    total_steps=20))(st, batches(0))
    path = str(tmp_path / "exact.npz")
    save_train_state(path, st)
    d_k = dataclasses.replace(d_ex, overlap="staleness_k", staleness=k)
    like = init_train_state(p0, opt, d_k, M, jax.random.PRNGKey(1))
    st_res = load_train_state(path, like)
    assert st_res.snap["x"].shape == (k,) + st.params.shape
    for slot in range(k):
        np.testing.assert_array_equal(np.asarray(st_res.snap["x"][slot]),
                                      np.asarray(st.params))


# ---------------------------------------------------------------------------
# 8-device legs: ring-gather contract + sharded parity + elastic
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_ring_gather_matches_all_gather_8dev():
    """The ppermute ring delivers the SAME assembled view as one tiled
    all_gather — bit-for-bit, every block in row-major worker order (the
    concatenation-order contract precise mode rests on) — including
    non-unit per-device blocks; multi-axis groups fall back to
    all_gather."""
    body = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P
from repro.launch.mesh import make_flat_engine_mesh, ring_gather

mesh, plan = make_flat_engine_mesh(8)
for m_loc in (1, 3):
    x = jnp.arange(8 * m_loc * 5, dtype=jnp.float32).reshape(8 * m_loc, 5)
    def both(v):
        r = ring_gather(v, ("data",), world=8, axis=0)
        g = jax.lax.all_gather(v, ("data",), axis=0, tiled=True)
        return r, g
    r, g = shard_map(both, mesh=mesh, in_specs=P("data", None),
                     out_specs=P(None, None), check_rep=False)(x)
    assert np.array_equal(np.asarray(r), np.asarray(g)), m_loc
    assert np.array_equal(np.asarray(r), np.asarray(x)), m_loc
print("ALL OK")
"""
    env = dict(os.environ, PYTHONPATH=SRC + os.pathsep + ROOT)
    out = subprocess.run([sys.executable, "-c", body], capture_output=True,
                         text=True, env=env, timeout=560, cwd=ROOT)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "ALL OK" in out.stdout


@pytest.mark.slow
def test_staleness_k_parity_8dev_flat_and_hier():
    """THE staleness-k acceptance leg: on 8 forced host devices,
    staleness_k(k=1, one chunk) is bit-for-bit doublebuf(one chunk) in
    precise mode (<= 1e-7; exact-zero in practice) for every consensus
    method incl. the easgd aux row, on BOTH the flat 8x1 mesh (where the
    mid-scan gather really runs the ppermute ring) and the hier 2x2x2
    mesh; a k=2 sharded run matches the single-device trace; and an
    elastic drop/rejoin schedule agrees across the sharded and
    single-device paths."""
    body = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro.configs import DPPFConfig, MeshPlan
from repro.train import (init_train_state, make_round_step,
                         make_sharded_round_step, set_participation,
                         shard_train_state)
from repro.optim import make_optimizer
from benchmarks.common import mlp_init, mlp_loss
from repro.launch.mesh import make_hier_engine_mesh

dim, ncls, width, M, tau = 16, 4, 8, 8, 4
key = jax.random.PRNGKey(0)
opt = make_optimizer("sgd", momentum=0.9)
p0 = lambda k: mlp_init(k, dim, ncls, width)
def batches(seed):
    k = jax.random.PRNGKey(seed)
    return {"x": jax.random.normal(k, (tau, M, 8, dim)),
            "y": jax.random.randint(jax.random.fold_in(k, 1),
                                    (tau, M, 8), 0, ncls)}

fmesh = Mesh(np.asarray(jax.devices()).reshape(8, 1), ("data", "model"))
fplan = MeshPlan(worker_axes=("data",), model_axes=("model",))
hmesh, hplan = make_hier_engine_mesh(2, 2, 2)

def run(dcfg, mesh=None, plan=None, rounds=4, drop=None):
    st = init_train_state(p0, opt, dcfg, M, key)
    st = dataclasses.replace(
        st, engine=dataclasses.replace(st.engine, precise=True))
    if mesh is not None:
        st = shard_train_state(st, mesh, plan, dcfg=dcfg)
        fn = jax.jit(make_sharded_round_step(
            mlp_loss, opt, dcfg, mesh=mesh, plan=plan, base_lr=0.05,
            total_steps=40))
    else:
        fn = jax.jit(make_round_step(mlp_loss, opt, dcfg, base_lr=0.05,
                                     total_steps=40))
    m = None
    for r in range(rounds):
        if drop:
            mask = np.ones(M, np.float32)
            if r in drop[1]:
                mask[drop[0]] = 0.0
            st = set_participation(st, jnp.asarray(mask))
        st, m = fn(st, batches(r))
    return st, m

# k=1 == doublebuf bitwise, both meshes, all five methods
for mname, mesh, plan in (("flat8x1", fmesh, fplan),
                          ("hier2x2x2", hmesh, hplan)):
    for method in ("simple_avg", "hard", "easgd", "lsgd", "mgrawa"):
        base = dict(alpha=0.2, lam=0.4, tau=tau, consensus=method,
                    engine="flat", lam_schedule="fixed")
        s_db, m_db = run(DPPFConfig(overlap="doublebuf", overlap_chunks=1,
                                    **base), mesh, plan)
        s_k1, m_k1 = run(DPPFConfig(overlap="staleness_k", staleness=1,
                                    overlap_chunks=1, **base), mesh, plan)
        dp = float(jnp.max(jnp.abs(s_db.params - s_k1.params)))
        ds = float(jnp.max(jnp.abs(s_db.snap["x"] - s_k1.snap["x"][0])))
        assert dp <= 1e-7 and ds <= 1e-7, (mname, method, dp, ds)
        assert float(m_db["staleness"]) == float(m_k1["staleness"]) == 1.0
print("k1 parity OK")

# k=2 sharded (ring gather over 8 worker rows) == single-device trace
base = dict(alpha=0.2, lam=0.4, tau=tau, engine="flat",
            lam_schedule="fixed")
d_k2 = DPPFConfig(overlap="staleness_k", staleness=2, overlap_chunks=2,
                  **base)
s_sh, m_sh = run(d_k2, fmesh, fplan, rounds=5)
s_1d, m_1d = run(d_k2, rounds=5)
dp = float(jnp.max(jnp.abs(s_sh.params - s_1d.params)))
assert dp <= 1e-6, dp
assert float(m_sh["staleness"]) == float(m_1d["staleness"]) == 2.0
print("k2 sharded OK")

# elastic drop/rejoin: sharded == single-device
d_el = DPPFConfig(overlap="staleness_k", staleness=2, overlap_chunks=2,
                  elastic=True, elastic_catchup=0.5, **base)
s_a, _ = run(d_el, rounds=6, drop=(5, (2, 3)))
s_b, _ = run(d_el, hmesh, hplan, rounds=6, drop=(5, (2, 3)))
dp = float(jnp.max(jnp.abs(s_a.params - s_b.params)))
assert dp <= 2e-6, dp
assert np.isfinite(np.asarray(s_b.params)).all()
print("elastic OK")
print("ALL OK")
"""
    env = dict(os.environ, PYTHONPATH=SRC + os.pathsep + ROOT)
    out = subprocess.run([sys.executable, "-c", body], capture_output=True,
                         text=True, env=env, timeout=560, cwd=ROOT)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "ALL OK" in out.stdout


def test_elastic_rejoin_across_checkpoint_resume(tmp_path):
    """Elastic membership state survives a checkpoint boundary: a run
    saved MID-DROP (worker 1 out, missed counter live, catch-up still
    ahead) resumes bit-for-bit against the uninterrupted run — the
    participation ring, the missed counters, the new scalar ``sync``
    gate, and the EASGD catch-up pull all round-trip through the npz."""
    tau, k = 2, 2
    Mw = 4
    opt, p0, loss, batches = _mlp_setup(M=Mw, tau=tau)
    dcfg = DPPFConfig(alpha=0.2, lam=0.4, tau=tau, engine="flat",
                      overlap="staleness_k", staleness=k, elastic=True,
                      elastic_catchup=0.5, lam_schedule="fixed")
    clock = RoundClock.from_config(dcfg, base_lr=0.05, total_steps=12)
    step = jax.jit(make_round_step(loss, opt, dcfg, clock=clock))
    key = jax.random.PRNGKey(0)

    def mask(r):
        m = np.ones(Mw, np.float32)
        if r in (2, 3):                    # dropped across the save point
            m[1] = 0.0
        return jnp.asarray(m)

    full = init_train_state(p0, opt, dcfg, Mw, key)
    half = init_train_state(p0, opt, dcfg, Mw, key)
    for r in range(6):
        full = set_participation(full, mask(r))
        full, _ = step(full, batches(r))
        if r < 3:
            half = set_participation(half, mask(r))
            half, _ = step(half, batches(r))
    # checkpoint after round 2: worker 1 has missed one round and is
    # still inside its drop window
    assert int(half.snap["missed"][1]) == 1
    path = str(tmp_path / "middrop.npz")
    save_train_state(path, half)
    like = init_train_state(p0, opt, dcfg, Mw, key)
    res = load_train_state(path, like, clock=clock)
    assert int(res.round) == 3
    assert int(res.snap["missed"][1]) == 1
    assert float(res.snap["sync"]) == 1.0  # the quorum gate round-trips
    np.testing.assert_array_equal(np.asarray(res.snap["active"]),
                                  np.asarray(half.snap["active"]))
    # finish the drop window and the rejoin catch-up post-resume
    for r in range(3, 6):
        res = set_participation(res, mask(r))
        res, _ = step(res, batches(r))
    np.testing.assert_array_equal(np.asarray(res.params),
                                  np.asarray(full.params))
    np.testing.assert_array_equal(np.asarray(res.snap["missed"]),
                                  np.asarray(full.snap["missed"]))
    np.testing.assert_array_equal(np.asarray(res.snap["x"]),
                                  np.asarray(full.snap["x"]))


def test_elastic_convergence_single_device():
    """End-task sanity: an elastic run with a transient dropout stays
    finite and close to the always-on run (the drop is bounded by k)."""
    M, tau = 4, 2
    opt, p0, loss, batches = _mlp_setup(M=M, tau=tau)
    dcfg = DPPFConfig(alpha=0.2, lam=0.4, tau=tau, engine="flat",
                      overlap="staleness_k", staleness=2, elastic=True,
                      lam_schedule="fixed")
    step = jax.jit(make_round_step(loss, opt, dcfg, base_lr=0.05,
                                   total_steps=40))
    losses = {}
    for drop in (False, True):
        st = init_train_state(p0, opt, dcfg, M, jax.random.PRNGKey(0))
        for r in range(10):
            mask = np.ones(M, np.float32)
            if drop and r in (3, 4):
                mask[2] = 0.0
            st = set_participation(st, jnp.asarray(mask))
            st, m = step(st, batches(r))
        losses[drop] = float(m["train_loss"])
        assert np.isfinite(np.asarray(st.params)).all()
    assert abs(losses[True] - losses[False]) < 1.0, losses
