"""Paper Figures 4/5 (+ Appendix F): 2D landscape scan around x_A via the
SVD-plane procedure (Algorithm 3), comparing SimpleAvg (valley collapse)
with DPPF (workers spanning a wide basin). Renders ASCII contours.

  PYTHONPATH=src:. python examples/valley_visualization.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np

from benchmarks.common import default_data, error_pct, mlp_logits, run_distributed
from repro.configs import DPPFConfig
from repro.core.theory import landscape_scan


def ascii_contour(scan, coords, grid, title):
    """Rough terminal rendering: characters bucket the error level; '*'
    marks projected worker positions."""
    lv = np.asarray(scan)
    chars = " .:-=+*#%@"
    lo, hi = lv.min(), max(lv.max(), lv.min() + 1e-9)
    print(f"\n{title}  (error {lo:.1f}%..{hi:.1f}%, grid "
          f"{grid[0]:.1f}..{grid[-1]:.1f})")
    marks = set()
    for cx, cy in np.asarray(coords):
        i = int(np.clip(np.searchsorted(grid, cx), 0, len(grid) - 1))
        j = int(np.clip(np.searchsorted(grid, cy), 0, len(grid) - 1))
        marks.add((i, j))
    for i in range(len(grid)):
        row = ""
        for j in range(len(grid)):
            if (i, j) in marks:
                row += "O"
            else:
                v = (lv[i, j] - lo) / (hi - lo)
                row += chars[min(int(v * (len(chars) - 1)), len(chars) - 1)]
        print(row)


def main():
    data = default_data()

    def err_fn_factory():
        x, y = data["x_train"], data["y_train"]

        def err(params):
            import jax.numpy as jnp
            pred = jnp.argmax(mlp_logits(params, x), axis=-1)
            return 100.0 * jnp.mean((pred != y).astype(jnp.float32))
        return err

    err_fn = err_fn_factory()

    plain = run_distributed(data, DPPFConfig(alpha=0.1, lam=0.0, push=False,
                                             tau=4), M=4, steps=400)
    dppf = run_distributed(data, DPPFConfig(alpha=0.1, lam=0.5, tau=4),
                           M=4, steps=400)

    for name, r in (("SimpleAvg (valley collapse)", plain),
                    ("DPPF (workers span the valley)", dppf)):
        res = landscape_scan(err_fn, r.workers, lim=6.0, step=0.5)
        ascii_contour(res["scan"], res["worker_coords"], res["grid"],
                      f"{name}: test err {r.test_err:.2f}%  "
                      f"spread {r.consensus_dist:.2f}")
        spread = np.linalg.norm(res["worker_coords"], axis=1)
        print(f"worker spread on plane: {np.round(spread, 2).tolist()}")


if __name__ == "__main__":
    main()
