"""Batched serving example: prefill a batch of prompts through the KV-cache
engine and decode greedily — full-cache and sliding-window (long-context)
variants on the gemma2 family (native local/global attention).

  PYTHONPATH=src python examples/serve_decode.py
"""
from repro.launch.serve import main

if __name__ == "__main__":
    print("== full cache ==")
    main(["--arch", "gemma2-2b", "--smoke", "--batch", "4",
          "--prompt-len", "64", "--new-tokens", "16"])
    print("\n== sliding-window ring buffer (sub-quadratic long-context) ==")
    main(["--arch", "gemma2-2b", "--smoke", "--batch", "4",
          "--prompt-len", "64", "--new-tokens", "16", "--window", "64"])
    print("\n== recurrent-state serving (attention-free xLSTM) ==")
    main(["--arch", "xlstm-350m", "--smoke", "--batch", "4",
          "--prompt-len", "64", "--new-tokens", "16"])
