"""Continuous-batching serving example: stream mixed-length requests
through the SlotEngine — full-cache, sliding-window ring-buffer
(long-context), sampled, and recurrent-state (attention-free) variants.

Each run prints compile time separately from warm throughput, plus the
per-lane compile counts (all 1 after warmup: admissions/evictions never
retrace).

  PYTHONPATH=src python examples/serve_decode.py
"""
from repro.launch.serve import main

if __name__ == "__main__":
    print("== continuous batching, full cache ==")
    main(["--arch", "gemma2-2b", "--smoke", "--requests", "6",
          "--max-slots", "3", "--prompt-len", "24", "--new-tokens", "12"])
    print("\n== static-batching baseline (admission barrier) ==")
    main(["--arch", "gemma2-2b", "--smoke", "--requests", "6",
          "--max-slots", "3", "--prompt-len", "24", "--new-tokens", "12",
          "--static"])
    print("\n== sliding-window ring buffer (prompts stream through) ==")
    main(["--arch", "gemma2-2b", "--smoke", "--requests", "4",
          "--max-slots", "2", "--prompt-len", "24", "--new-tokens", "8",
          "--window", "32", "--chunk", "8", "--buf-len", "48"])
    print("\n== fused sampling (temperature + top-k + top-p in-compile) ==")
    main(["--arch", "gemma2-2b", "--smoke", "--requests", "4",
          "--max-slots", "2", "--prompt-len", "16", "--new-tokens", "8",
          "--temp", "0.8", "--topk", "40", "--topp", "0.95"])
    print("\n== recurrent-state serving (attention-free xLSTM) ==")
    main(["--arch", "xlstm-350m", "--smoke", "--requests", "4",
          "--max-slots", "2", "--prompt-len", "24", "--new-tokens", "8"])
