"""End-to-end driver: DPPF-train a language model from the assigned
architecture pool for a few hundred steps and evaluate held-out loss.

Default is a CPU-runnable reduced yi-6b (llama-family). For the ~100M-class
run on real hardware, pass e.g.:
  --d-model 768 --layers 12          (~110M params with the 64k vocab)

  PYTHONPATH=src python examples/train_dppf_lm.py [--steps 200]
"""
import sys

from repro.launch.train import main

if __name__ == "__main__":
    argv = sys.argv[1:]
    defaults = ["--arch", "yi-6b", "--smoke", "--workers", "4",
                "--tau", "4", "--alpha", "0.1", "--lam", "0.5",
                "--steps", "200", "--ckpt", "results/dppf_lm.npz"]
    # user args win
    main(defaults + argv)
