"""Quickstart: DPPF in ~40 lines of user code.

Trains M=4 workers on the synthetic classification task with the pull-push
consensus, shows (a) the consensus distance settling at lambda/alpha
(Theorem 1) and (b) the test error against plain LocalSGD.

  PYTHONPATH=src:. python examples/quickstart.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import default_data, run_distributed
from repro.configs import DPPFConfig


def main():
    data = default_data()

    dppf = DPPFConfig(alpha=0.1, lam=0.5, tau=4)      # target width 5.0
    r = run_distributed(data, dppf, M=4, steps=300, track_every=5)
    print(f"DPPF      : test err {r.test_err:5.2f}%  "
          f"consensus distance {r.consensus_dist:.2f} "
          f"(Theorem 1 target {dppf.valley_width})  comm {r.comm_pct:.0f}%")

    local = DPPFConfig(consensus="hard", tau=4, push=False)
    r2 = run_distributed(data, local, M=4, steps=300)
    print(f"LocalSGD  : test err {r2.test_err:5.2f}%  comm {r2.comm_pct:.0f}%")

    ddp = DPPFConfig(consensus="ddp")
    r3 = run_distributed(data, ddp, M=4, steps=300)
    print(f"DDP SGD   : test err {r3.test_err:5.2f}%  comm {r3.comm_pct:.0f}%")


if __name__ == "__main__":
    main()
