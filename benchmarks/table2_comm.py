"""Paper Table 2 / Figure 1: communication volume vs test error.
DDP vs LocalSGD(tau) vs LocalSGD+QSR vs DPPF(tau)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import RunResult, csv, default_data, run_distributed
from repro.configs import DPPFConfig

SEEDS = (182, 437)


def _avg(results):
    return (float(np.mean([r.test_err for r in results])),
            float(np.std([r.test_err for r in results])),
            float(np.mean([r.comm_pct for r in results])))


def run(steps=400, M=4):
    data = default_data()
    rows = []

    def several(dcfg, **kw):
        return [run_distributed(data, dcfg, M=M, steps=steps, seed=s, **kw)
                for s in SEEDS]

    rows.append(("DDP-SGD", _avg(several(DPPFConfig(consensus="ddp")))))
    for tau in (4, 8, 16):
        rows.append((f"LocalSGD(tau={tau})", _avg(several(
            DPPFConfig(consensus="hard", tau=tau, push=False)))))
    for tb in (2, 4):
        rows.append((f"QSR(tau_base={tb})", _avg(several(
            DPPFConfig(consensus="hard", tau=tb, push=False,
                       qsr_beta=0.015)))))
    for tau in (4, 8, 16):
        rows.append((f"DPPF(tau={tau})", _avg(several(
            DPPFConfig(consensus="simple_avg", alpha=0.1, lam=0.5, tau=tau,
                       push=True)))))

    best_base = min(r[1][0] for r in rows[:6])
    for name, (err, std, comm) in rows:
        csv("table2", method=name, test_err=round(err, 2),
            std=round(std, 2), comm_pct=round(comm, 1))
    dppf_best = min(r[1][0] for r in rows[6:])
    csv("table2_summary", dppf_best=round(dppf_best, 2),
        baseline_best=round(best_base, 2),
        dppf_beats_baselines=bool(dppf_best <= best_base + 0.25))
    return rows


if __name__ == "__main__":
    run()
