"""Paper §D.2: empirical support for Theorem 2's assumptions and claim.

(a) sensitivity of test error to lambda at fixed alpha (Fig. 8a shape:
    too-narrow and too-wide valleys are suboptimal, broad sweet spot);
(b) ||x_A||_2 grows with lambda (the bounded-drift assumption
    ||mu_r||^2 <= D0 r^beta with beta < 1 — Fig. 9a);
(c) width/norm ratio grows with lambda (Fig. 9b).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import csv, default_data, run_distributed
from repro.configs import DPPFConfig


def run(steps=400, M=4, alpha=0.5):
    data = default_data()
    rows = []
    for lam in (0.1, 0.5, 1.0, 2.5, 5.0, 10.0):
        r = run_distributed(
            data, DPPFConfig(alpha=alpha, lam=lam, tau=4,
                             lam_schedule="fixed"),
            M=M, steps=steps)
        import jax, jax.numpy as jnp
        flat = jnp.concatenate([l.reshape(-1) for l in
                                jax.tree.leaves(r.params_avg)])
        norm = float(jnp.linalg.norm(flat))
        rows.append((lam, r.test_err, r.consensus_dist, norm))
        csv("d2_theorem2", alpha=alpha, lam=lam,
            test_err=round(r.test_err, 2),
            width=round(r.consensus_dist, 3),
            xa_norm=round(norm, 3),
            width_over_norm=round(r.consensus_dist / norm, 4))
    # assumption checks
    norms = [n for (_, _, _, n) in rows]
    ratios = [w / n for (_, _, w, n) in rows]
    csv("d2_summary",
        xa_norm_monotone_up=bool(all(b >= a - 1e-3 for a, b in
                                     zip(norms, norms[1:]))),
        ratio_monotone_up=bool(all(b >= a - 1e-3 for a, b in
                                   zip(ratios, ratios[1:]))),
        best_lam=rows[int(np.argmin([e for (_, e, _, _) in rows]))][0])
    return rows


if __name__ == "__main__":
    run()
