"""Worker-count scaling (the 4-worker/8-worker axis of paper Tables 3/4):
does the push mechanism keep its edge as M grows, and does the final width
stay at lambda/alpha independent of M (Theorem 1's M-robustness)?"""
from __future__ import annotations

import numpy as np

from benchmarks.common import csv, default_data, run_distributed
from repro.configs import DPPFConfig

SEEDS = (182, 437)


def run(steps=400):
    data = default_data()
    for M in (2, 4, 8):
        for name, dcfg in (
            ("SimpleAvg", DPPFConfig(alpha=0.1, lam=0.0, push=False, tau=4)),
            ("DPPF", DPPFConfig(alpha=0.1, lam=0.5, tau=4)),
        ):
            errs, widths = [], []
            for s in SEEDS:
                r = run_distributed(data, dcfg, M=M, steps=steps, seed=s)
                errs.append(r.test_err)
                widths.append(r.consensus_dist)
            csv("ablate_workers", M=M, method=name,
                test_err=round(float(np.mean(errs)), 2),
                std=round(float(np.std(errs)), 2),
                width=round(float(np.mean(widths)), 3))


if __name__ == "__main__":
    run()
