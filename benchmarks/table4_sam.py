"""Paper Table 4: flatness mechanisms at local vs distributed level —
DDP-SGD / DPPF-SGD / DDP-SAM / DPPF-SAM grid."""
from __future__ import annotations

import numpy as np

from benchmarks.common import csv, default_data, run_distributed
from repro.configs import DPPFConfig

SEEDS = (182, 437)


def run(steps=400, M=4):
    data = default_data()
    grid = {
        "DDP_SGD": (DPPFConfig(consensus="ddp"), 0.0),
        "DPPF_SGD": (DPPFConfig(alpha=0.1, lam=0.5, tau=4), 0.0),
        "DDP_SAM": (DPPFConfig(consensus="ddp"), 0.1),
        "DPPF_SAM": (DPPFConfig(alpha=0.1, lam=0.1, tau=4), 0.1),
    }
    out = {}
    for name, (dcfg, rho) in grid.items():
        errs = [run_distributed(data, dcfg, M=M, steps=steps, seed=s,
                                sam_rho=rho).test_err for s in SEEDS]
        out[name] = (float(np.mean(errs)), float(np.std(errs)))
        csv("table4", method=name, test_err=round(out[name][0], 2),
            std=round(out[name][1], 2))
    csv("table4_summary",
        dppf_sgd_vs_ddp_sgd=round(out["DDP_SGD"][0] - out["DPPF_SGD"][0], 2),
        dppf_sam_vs_ddp_sam=round(out["DDP_SAM"][0] - out["DPPF_SAM"][0], 2))
    return out


if __name__ == "__main__":
    run()
