"""Render EXPERIMENTS.md — the committed experiment front door.

Deterministic from COMMITTED inputs only (the suite/artifact registry in
``benchmarks/run.py``, the ``BENCH_roundclock.json`` baseline, and the
RoundClock plan it pins), so CI regenerates it and fails on drift:

  PYTHONPATH=src:. python -m benchmarks.render_experiments --check

After changing a registry entry / the bench baseline / this module,
regenerate and commit:

  PYTHONPATH=src:. python -m benchmarks.render_experiments --out EXPERIMENTS.md

The dry-run/roofline tables additionally render from
``results/dryrun/*.json`` WHEN present (those records are not committed —
the sections carry a regeneration hint otherwise).
"""
from __future__ import annotations

import argparse
import difflib
import glob
import json
import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(out_dir="results/dryrun"):
    """Records keyed by (arch, shape, mesh, mode, plan). Overlap-mode
    records (``--overlap``) are kept OUT of the standard tables — they
    compile a different program; the modeled comparison every train
    record carries (``overlap_model``) feeds §Overlap-roofline."""
    recs = {}
    for p in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        r = json.load(open(p))
        if r.get("overlap", "none") != "none":
            continue
        recs[(r["arch"], r["shape"], r["mesh"], r["mode"], r["plan"])] = r
    return recs


def fmt_s(x):
    return f"{x:.2e}"


def dryrun_table(recs, mesh):
    rows = [
        "| arch | shape | mode | compile s | HLO GFLOP/dev | HBM GB/dev | "
        "coll GB/dev (data/model) | arg GB/dev | bottleneck |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for (a, s, m, mode, plan), r in sorted(recs.items()):
        if m != mesh or plan != "baseline" or mode == "ddp":
            continue
        ax = r.get("collective_axis_bytes", {})
        coll = sum(v["bytes"] for v in r["collectives"].values())
        mem = r.get("memory", {}).get("argument_size_in_bytes", 0)
        rows.append(
            f"| {a} | {s} | {mode} | {r['compile_s']} | "
            f"{r['hlo_flops_per_dev']/1e9:.1f} | "
            f"{r['hlo_bytes_per_dev']/1e9:.1f} | "
            f"{coll/1e9:.1f} ({ax.get('data',0)/1e9:.1f}/{ax.get('model',0)/1e9:.1f}) | "
            f"{mem/1e9:.1f} | {r['roofline']['bottleneck'][:-2]} |")
    return "\n".join(rows)


def roofline_table(recs, mesh="single"):
    rows = [
        "| arch | shape | compute s | memory s | collective s | bottleneck | "
        "useful-FLOP ratio | what moves the dominant term |",
        "|---|---|---|---|---|---|---|---|",
    ]
    hints = {
        "compute": "more chips / lower-precision matmuls",
        "memory": "remat policy, fused kernels (flash attn / SSD / pull-push), "
                  "chunked recurrences, bf16 states",
        "collective": "longer tau (DPPF!), sharding constraints on routed "
                      "tensors, bf16 payloads, overlap",
    }
    for (a, s, m, mode, plan), r in sorted(recs.items()):
        if m != mesh or plan != "baseline" or mode == "ddp":
            continue
        t = r["roofline"]
        rows.append(
            f"| {a} | {s} | {fmt_s(t['compute_s'])} | {fmt_s(t['memory_s'])} "
            f"| {fmt_s(t['collective_s'])} | **{t['bottleneck'][:-2]}** | "
            f"{r['useful_flop_ratio']:.3f} | {hints[t['bottleneck'][:-2]]} |")
    return "\n".join(rows)


def overlap_table(recs, mesh="single"):
    """§Overlap-roofline: modeled round time exact vs staleness1 vs
    doublebuf vs the staleness-k ring (launch.roofline.overlap_model)
    against the comm/compute crossover, from the baseline train records."""
    rows = [
        "| arch | shape | exact s | staleness1 s | doublebuf s | k=2 ring s "
        "| ring B/hop | crossover (comm/compute) | overlap gain |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for (a, s, m, mode, plan), r in sorted(recs.items()):
        om = r.get("overlap_model")
        if m != mesh or plan != "baseline" or mode != "train" or not om:
            continue
        ks = om.get("staleness_k_s", {})
        k2 = fmt_s(ks["2"]) if "2" in ks else "—"
        hop = (f"{om['ring_bytes_per_hop']/1e9:.2f} GB"
               if "ring_bytes_per_hop" in om else "—")
        rows.append(
            f"| {a} | {s} | {fmt_s(om['exact_s'])} | "
            f"{fmt_s(om['staleness1_s'])} | {fmt_s(om['doublebuf_s'])} | "
            f"{k2} | {hop} | "
            f"{om['crossover']:.2e} | {om['overlap_gain']:.4f} |")
    return "\n".join(rows)


def perf_compare(recs, arch, shape, plans, mesh="single", mode=None):
    mode = mode or "train"
    rows = [f"**{arch} × {shape}** (per-device, per local step where applicable)",
            "", "| plan | compute s | memory s | collective s | arg GB | "
            "coll data-axis GB | coll model-axis GB |", "|---|---|---|---|---|---|---|"]
    for plan in plans:
        r = recs.get((arch, shape, mesh, mode, plan))
        if not r:
            rows.append(f"| {plan} | (missing) |")
            continue
        t = r["roofline"]
        ax = r.get("collective_axis_bytes", {})
        mem = r.get("memory", {}).get("argument_size_in_bytes", 0)
        rows.append(
            f"| {plan} | {fmt_s(t['compute_s'])} | {fmt_s(t['memory_s'])} | "
            f"{fmt_s(t['collective_s'])} | {mem/1e9:.1f} | "
            f"{ax.get('data',0)/1e9:.2f} | {ax.get('model',0)/1e9:.1f} |")
    return "\n".join(rows)


def ddp_compare(recs, archs, mesh="single"):
    rows = ["| arch | mode | data-axis coll GB/dev per STEP | "
            "model-axis GB/dev per step | comm ratio (DPPF/DDP, data axis) |",
            "|---|---|---|---|---|"]
    for a in archs:
        d = recs.get((a, "train_4k", mesh, "ddp", "baseline"))
        p = recs.get((a, "train_4k", mesh, "train", "baseline"))
        if not (d and p):
            continue
        tau = p["tau"]
        d_ax = d.get("collective_axis_bytes", {}).get("data", 0)
        p_ax = p.get("collective_axis_bytes", {}).get("data", 0) / tau
        d_m = d.get("collective_axis_bytes", {}).get("model", 0)
        p_m = p.get("collective_axis_bytes", {}).get("model", 0) / tau
        ratio = p_ax / d_ax if d_ax else float("nan")
        rows.append(f"| {a} | DDP | {d_ax/1e9:.2f} | {d_m/1e9:.1f} | — |")
        rows.append(f"| {a} | DPPF τ=4 | {p_ax/1e9:.2f} | {p_m/1e9:.1f} | "
                    f"**{ratio:.2f}×** |")
    return "\n".join(rows)


def artifact_table():
    from benchmarks.run import ARTIFACTS
    rows = ["| suite (`--only`) | paper artifact | script | reproduces |",
            "|---|---|---|---|"]
    for name, (artifact, script, what) in ARTIFACTS.items():
        rows.append(f"| `{name}` | {artifact} | `{script}` | {what} |")
    return "\n".join(rows)


def _overlap_bench_line():
    """The committed BENCH_overlap.json acceptance rows (overlap_round:
    exact vs staleness1 vs doublebuf vs staleness-k on the 2x2x2 mesh,
    plus the ring_gather ring-vs-gather unit)."""
    path = os.path.join(ROOT, "BENCH_overlap.json")
    if not os.path.exists(path):
        return ("* `overlap_round` (`BENCH_overlap.json`): not committed "
                "yet — run the microbench on 8 forced host devices.")
    with open(path) as f:
        bench = json.load(f)
    row = bench["overlap_round"]
    if not row:
        return ("* `overlap_round` (`BENCH_overlap.json`): skipped "
                "(needs 8 forced host devices).")
    chunks = row["modes"]["doublebuf"]["overlap_chunks"]
    k = row["modes"].get("staleness_k", {}).get("staleness", 2)
    lines = [
        f"* `overlap_round` (`BENCH_overlap.json`): exact vs "
        f"staleness1 vs doublebuf vs staleness-k (k={k}) round "
        f"throughput on the {row['mesh']} mesh ({row['workers']} workers, "
        f"tau {row['tau']}) — doublebuf dispatches the snapshot gather + "
        f"partial-Gram psum in {chunks} chunks mid-scan, staleness-k "
        f"spreads it over k rounds on a ppermute ring; the modeled "
        f"ordering staleness_k >= doublebuf >= staleness1 >= exact is a "
        f"structural field (`modeled_order_ok`), measured speedups are "
        f"host-relative timing fields (`check_bench.py` gates the "
        f"structure)."]
    ring = bench.get("ring_gather")
    if ring:
        lines.append(
            f"* `ring_round` (`BENCH_overlap.json`): the staleness-k "
            f"`ppermute` ring vs one tiled `all_gather` of the same "
            f"({ring['workers']}, {ring['cols']}) view — "
            f"{ring['ring_hops']} hops of "
            f"{ring['ring_bytes_per_hop']} B against a "
            f"{ring['gather_bytes']} B gather; `ring_ok` "
            f"(per-hop bytes <= gather bytes) and `ring_matches_gather` "
            f"(bit-for-bit assembled-view parity, the concatenation-order "
            f"contract precise mode rests on) are structural fields.")
    return "\n".join(lines)


def bench_section():
    """Render the committed BENCH_roundclock.json baseline: the QSR round
    plan (RoundClock.describe) and the engine/hierarchical rows."""
    path = os.path.join(ROOT, "BENCH_roundclock.json")
    with open(path) as f:
        bench = json.load(f)
    rc = bench["roundclock"]
    out = [
        "Committed baseline: `BENCH_roundclock.json` (regenerated by the "
        "CI microbench smoke on 8 forced host devices; "
        "`benchmarks/check_bench.py` fails the build on structural drift "
        "and surfaces timing deltas in the job summary).",
        "",
        f"* step budget {rc['qsr']['total_steps']}, base tau "
        f"{rc['qsr']['tau_base']}, QSR beta {rc['qsr']['qsr_beta']}: "
        f"**{rc['qsr']['rounds']} rounds vs {rc['fixed']['rounds']} "
        f"fixed** — {bench['roundclock']['allreduces_saved']} consensus "
        f"all-reduces saved "
        f"({bench['roundclock']['allreduces_saved_pct']}%).",
        f"* flat ConsensusEngine vs tree path: "
        f"{bench['engine_vs_tree']['workers']} workers x "
        f"{bench['engine_vs_tree']['params_per_worker']} params "
        f"(timing is host-relative; the full-size target is >= 1.5x).",
        "* `hierarchical_round`: the same 8-worker round on the "
        "`2x2x2` workers x fsdp x model mesh vs the flat `8x1` mesh — "
        "parity is pinned bit-for-bit in "
        "`tests/test_sharded_round.py`; timings live in the JSON.",
        _overlap_bench_line(),
        "",
        "QSR round plan (the committed baseline's "
        "`roundclock.qsr.plan`):",
        "",
        "| round | start | tau | lr window |",
        "|---|---|---|---|",
    ]
    for r in rc["qsr"]["plan"]:
        out.append(f"| {r['round']} | {r['start']} | {r['tau']} | "
                   f"{r['lr_start']:.4f} -> {r['lr_end']:.4f} |")
    return "\n".join(out)


def serving_section():
    """Render the committed BENCH_serving.json baseline: continuous vs
    static batching (structural step/occupancy ordering) and the
    prefill-vs-decode serving roofline rows."""
    path = os.path.join(ROOT, "BENCH_serving.json")
    if not os.path.exists(path):
        return ("*(`BENCH_serving.json` not committed yet — run "
                "`PYTHONPATH=src:. python benchmarks/bench_serving.py "
                "--smoke` and commit it.)*")
    with open(path) as f:
        bench = json.load(f)
    s = bench["serving"]
    rl = bench["roofline"]
    shape = rl["shape"]
    out = [
        "Committed baseline: `BENCH_serving.json` (regenerated by the CI "
        "serving smoke; `benchmarks/check_bench.py` gates the structural "
        "fields — step counts, occupancy, the continuous >= static "
        "ordering, roofline rows — and reports tok/s / TTFT as timing "
        "deltas).",
        "",
        f"* `{s['arch']}`, {s['max_slots']} slots, chunk {s['chunk']}, "
        f"buf {s['buf_len']}: the mixed trace ({len(s['trace_lens'])} "
        f"requests, prompts {min(s['trace_lens'])}-{max(s['trace_lens'])}, "
        f"budgets {min(s['trace_new'])}-{max(s['trace_new'])}) runs the "
        f"SAME compiled decode step under both schedulers.",
        f"* **continuous batching: {s['continuous']['steps']} steps at "
        f"{s['continuous']['occupancy']:.0%} occupancy vs static "
        f"{s['static']['steps']} steps at {s['static']['occupancy']:.0%}** "
        f"— {s['steps_saved_pct']}% device steps saved "
        f"(`continuous_ge_static` is the structural gate; wall speedup is "
        f"a timing field).",
        "",
        f"Prefill-vs-decode roofline (TPU v5e model, {shape['max_slots']} "
        f"slots, chunk {shape['chunk']}, buf {shape['buf_len']}; per-slot "
        f"state bytes MEASURED from the `make_state` pytree via "
        f"`jax.eval_shape` — `launch/roofline.py::serving_model`):",
        "",
        "| arch | state GB/slot | decode bound | decode tok/s | prefill "
        "bound | prefill tok/s | prefill tokens per decode step |",
        "|---|---|---|---|---|---|---|",
    ]
    for arch, row in sorted(rl.items()):
        if arch == "shape":
            continue
        out.append(
            f"| {arch} | {row['state_bytes_per_slot']/1e9:.2f} | "
            f"{row['decode_bound']} | {row['decode_tok_s']} | "
            f"{row['prefill_bound']} | {row['prefill_tok_s']} | "
            f"{row['prefill_tokens_per_decode_step']} |")
    out += [
        "",
        "Decode streams every live parameter plus each slot's cache per "
        "token (memory-bound until `crossover_slots`); a prefill chunk is "
        "compute-dense. The last column is the admission-packing budget: "
        "that many chunked-prefill tokens cost one decode step, so "
        "admitting mid-decode is roofline-free below it (DESIGN.md "
        "§Serving).",
    ]
    return "\n".join(out)


def zoo_section():
    """Render the committed ``results/method_zoo.json``: every registered
    consensus method under heterogeneous workers (Dirichlet label skew +
    speed skew), with the Mean Valley width per method."""
    path = os.path.join(ROOT, "results", "method_zoo.json")
    if not os.path.exists(path):
        return ("*(`results/method_zoo.json` not committed yet — run "
                "`PYTHONPATH=src:. python -m benchmarks.run "
                "--only method_zoo` and commit it alongside the "
                "re-rendered file.)*")
    with open(path) as f:
        zoo = json.load(f)
    cfg = zoo["config"]
    speeds = "/".join(f"{s:g}" for s in cfg["speeds"])
    out = [
        f"Committed run: `results/method_zoo.json` — {cfg['workers']} "
        f"workers, Dirichlet({cfg['dir_alpha']}) label skew, per-worker "
        f"speeds {speeds} (a speed-s worker refreshes its batch on only "
        f"`round(tau * s)` of its tau local steps), {cfg['steps']} steps, "
        f"the shared flat-engine trainer for every method "
        f"(`benchmarks/table5_noniid.py::run_zoo`). `mean_valley` is the "
        f"paper's Alg. 2 width from the worker average; ddp trains ONE "
        f"model, so it has no worker spread to measure.",
        "",
        "| method | test err % | gen gap | consensus dist | mean_valley |"
        " flags |",
        "|---|---|---|---|---|---|",
    ]
    for name, row in zoo["methods"].items():
        mv = "—" if row["mean_valley"] is None else f"{row['mean_valley']}"
        out.append(
            f"| {name} | {row['test_err']} | {row['gen_gap']} | "
            f"{row['consensus_dist']} | {mv} | {row['flags']} |")
    return "\n".join(out)


def autotune_section():
    """Render the committed ``BENCH_autotune.json``: the ``--autotune``
    probe search on the real round step under an injected
    RESOURCE_EXHAUSTED frontier — the searched operating point that
    superseded the hand-written hillclimb (``opt``/``seqshard``/
    ``hier_opt``) plan records."""
    path = os.path.join(ROOT, "BENCH_autotune.json")
    if not os.path.exists(path):
        return ("*(`BENCH_autotune.json` not committed yet — run "
                "`PYTHONPATH=src:. python benchmarks/microbench.py "
                "--smoke` and commit it.)*")
    with open(path) as f:
        bench = json.load(f)
    a = bench["autotune"]
    plan, chosen = a["plan"], a["plan"]["chosen"]
    gates = ", ".join(f"`{k}`={a[k]}" for k in (
        "probes_within_budget", "chosen_dominates_model",
        "backoff_exercised"))
    out = [
        "One flag (`launch/train.py --autotune`) replaces the committed "
        "hillclimb plan sweeps: a probe search over (batch, tau, "
        "overlap_chunks) runs REAL rounds, doubles batch until the device "
        "(or the `--tune-oom-above` CI fault hook) raises "
        "RESOURCE_EXHAUSTED, binary-refines to the feasibility frontier, "
        "then sweeps (tau, chunks) at that batch. Selection goes through "
        "the roofline model calibrated against the measured probes "
        "(`launch/roofline.py::reconcile_probes`), so the chosen point is "
        "a host-independent argmin; the former `opt`/`seqshard`/"
        "`hier_opt` dry-run records are retired (DESIGN.md §Autotune).",
        "",
        f"Committed baseline: `BENCH_autotune.json` — {a['workers']} "
        f"workers, width {a['width']}, injected OOM frontier at batch "
        f"{a['oom_limit']}, budget {plan['probe_budget']} "
        f"({plan['probes_used']} probes used). Structural gates: {gates}; "
        f"chosen point **batch {chosen['batch']}, tau {chosen['tau']}, "
        f"chunks {chosen['overlap_chunks']}** ({plan['overlap']}), "
        f"failures at batches {plan['failures']}.",
        "",
        "| probe | batch | tau | chunks | ok | modeled us |",
        "|---|---|---|---|---|---|",
    ]
    for i, p in enumerate(plan["probes"]):
        ok = "yes" if p["ok"] else "**OOM**"
        out.append(f"| {i} | {p['batch']} | {p['tau']} | "
                   f"{p['overlap_chunks']} | {ok} | {p['modeled_us']} |")
    out += [
        "",
        "Per-probe `us_round`, the measured/modeled `residual_scale`, and "
        "`dominates_measured` are host-relative timing fields; the ladder "
        "itself (batches/taus/chunks/ok flags) and the chosen point are "
        "structural (`benchmarks/check_bench.py`).",
    ]
    return "\n".join(out)


def chaos_section():
    """Render the committed ``BENCH_chaos.json``: the fault-tolerant
    round supervisor under a scripted ChaosPlan — recovery-event
    timeline, determinism/parity gates, and the degraded-round roofline
    accounting."""
    path = os.path.join(ROOT, "BENCH_chaos.json")
    if not os.path.exists(path):
        return ("*(`BENCH_chaos.json` not committed yet — run "
                "`PYTHONPATH=src:. python benchmarks/bench_chaos.py "
                "--smoke` and commit it.)*")
    with open(path) as f:
        bench = json.load(f)
    c = bench["chaos"]
    gates = ", ".join(f"`{k}`={c[k]}" for k in (
        "replay_identical", "empty_plan_parity", "schedule_parity",
        "completed"))
    counters = ", ".join(f"{k}={v}" for k, v in sorted(
        c["counters"].items()))
    m = c["modeled"]
    out = [
        "The round supervisor (`train/supervisor.py`) owns the host-side "
        "round loop: a heartbeat membership table (ACTIVE -> SUSPECT -> "
        "DEAD -> REJOINING) drives the participation mask, below-quorum "
        "rounds degrade to local-only steps via the elastic carry's "
        "scalar `sync` gate (a bit-exact consensus skip, backed off with "
        "deterministic jitter), and failed rounds restore the "
        "`sup_last`/`sup_prev` rotation checkpoint and replay — OOMs "
        "shrink the per-worker batch first (the PR 9 `is_oom` contract). "
        "Faults come from a replayable `ChaosPlan` (the TunePlan JSON "
        "idiom), so the recovery-event sequence below is a committed "
        "contract, not a flaky observation (DESIGN.md §Fault-tolerance).",
        "",
        f"Committed baseline: `BENCH_chaos.json` — {c['workers']} workers "
        f"x {c['rounds']} rounds (tau {c['tau']}, staleness "
        f"{c['staleness']}, quorum {c['quorum']}), plan seed "
        f"{c['plan']['seed']} with {len(c['plan']['events'])} scripted "
        f"faults. Structural gates: {gates}. Per-worker batch "
        f"{c['batch']} -> {c['final_batch']} after the injected "
        f"RESOURCE_EXHAUSTED. Counters: {counters}.",
        "",
        "| round | recovery event |",
        "|---|---|",
    ]
    for ev in c["event_seq"]:
        rnd, rest = ev.split(":", 1)
        out.append(f"| {rnd[1:]} | `{rest}` |")
    out += [
        "",
        f"Modeled degraded-round accounting (`launch/roofline.py::"
        f"supervisor_model`, pure arithmetic): fault-free "
        f"{m['fault_free_s']}s vs faulted {m['faulted_s']}s "
        f"(+{100 * m['overhead_frac']:.1f}%) — each retried round "
        f"re-executes in full ({m['retry_s']}s) plus the checkpoint "
        f"restore stream ({m['restore_s']}s at DISK_BW), while degraded "
        f"rounds SAVE whatever ring-gather tail the k-deep carry could "
        f"not hide ({m['degraded_saved_s']}s here). Backoff is recorded "
        f"in the events ({c['backoff_recorded_s']}s total) but not slept "
        f"— the bench runs on virtual time. `wall_s` is host-relative "
        f"timing; everything above is structural "
        f"(`benchmarks/check_bench.py`).",
    ]
    return "\n".join(out)


MISSING_DRYRUN = (
    "*(dry-run records not present — populate `results/dryrun/` with "
    "`PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both` "
    "[+ `--plan hier` for the hierarchical rows] and re-render to fill "
    "this table. The CI drift check renders from committed inputs only, "
    "so commit the records alongside the re-rendered file.)*")


def render() -> str:
    recs = load(os.path.join(ROOT, "results", "dryrun"))
    sections = [
        "# EXPERIMENTS",
        "",
        "<!-- GENERATED FILE — edit benchmarks/render_experiments.py and "
        "regenerate:",
        "     PYTHONPATH=src:. python -m benchmarks.render_experiments "
        "--out EXPERIMENTS.md",
        "     CI fails when this file drifts from the generator. -->",
        "",
        "How to run everything:",
        "",
        "```bash",
        "PYTHONPATH=src:. python -m benchmarks.run [--fast] "
        "[--only table2,ablate_schedule,...]",
        "```",
        "",
        "Suites print CSV rows `name,key=value,...`; default budgets "
        "reproduce the qualitative paper orderings on CPU in ~10-20 min "
        "(`--fast` shrinks them for CI).",
        "",
        "## Paper artifacts",
        "",
        artifact_table(),
        "",
        "The `ablate_schedule` suite carries the round-clock row "
        "(`schedule=increasing+qsr`): QSR-adaptive tau (§7.2) on the "
        "paper's main-results lambda schedule, reporting `comm_pct` next "
        "to test error.",
        "",
        "## Round-clock / engine benchmarks",
        "",
        bench_section(),
        "",
        "## Method zoo — heterogeneous workers (label + speed skew)",
        "",
        "Every consensus method registered in `core/methods.py` runs "
        "through the SAME flat-engine trainer (one `MethodSpec` entry per "
        "method — DESIGN.md §Method-registry), so the zoo is a config "
        "sweep, not a code fork: the registry declares each method's "
        "target-weight rule, aux-row contract, push source, and round "
        "plan, and the generic lowering does the rest.",
        "",
        zoo_section(),
        "",
        "## Serving — continuous batching vs static, prefill/decode "
        "roofline",
        "",
        serving_section(),
        "",
        "## Dry-run — single-pod 16x16 (256 chips), baseline plan",
        "",
        dryrun_table(recs, "single") if any(
            k[2] == "single" for k in recs) else MISSING_DRYRUN,
        "",
        "## Dry-run — multi-pod 2x16x16 (512 chips), baseline plan",
        "",
        dryrun_table(recs, "multi") if any(
            k[2] == "multi" for k in recs) else MISSING_DRYRUN,
        "",
        "## Roofline — single-pod baseline",
        "",
        roofline_table(recs) if any(
            k[2] == "single" for k in recs) else MISSING_DRYRUN,
        "",
        "## Overlap roofline — exact vs staleness1 vs doublebuf vs "
        "staleness-k ring (modeled round time)",
        "",
        "`DPPFConfig.overlap` moves the round's consensus collectives off "
        "the boundary critical path: staleness-1 hides the (R, R) "
        "partial-Gram psum behind the tau local steps; double-buffered "
        "consensus additionally chunk-dispatches the snapshot's "
        "worker-row all-gather mid-scan, leaving only the mix GEMM at "
        "the boundary; staleness-k generalizes the carry to a k-deep "
        "snapshot ring whose gather runs as a `ppermute` ring of R-1 "
        "one-row hops, giving each consensus k rounds of compute to hide "
        "behind (DESIGN.md §Overlap). Modeled per-round seconds from the "
        "dry-run collective split (`launch/roofline.py::overlap_model`); "
        "crossover < 1 means doublebuf hides ALL consensus traffic, and "
        "the k=2 ring column caps the residual at "
        "`max(ring_s - k*work, 0)`. Measured host rows: `benchmarks/"
        "microbench.py` `overlap_round` + `ring_round` (committed "
        "`BENCH_overlap.json`).",
        "",
        overlap_table(recs) if any(
            k[2] == "single" and k[3] == "train" and
            "overlap_model" in recs[k] for k in recs) else MISSING_DRYRUN,
        "",
        "## DPPF vs DDP communication (data-axis collectives)",
        "",
        ddp_compare(recs, ["gemma2-2b", "yi-6b", "qwen2-72b",
                           "llama4-scout-17b-a16e", "dbrx-132b"])
        if any(k[3] == "ddp" for k in recs) else MISSING_DRYRUN,
        "",
        "## Autotune — searched operating point (`--autotune`)",
        "",
        autotune_section(),
        "",
        "## Chaos — fault-tolerant round supervisor (`--chaos`)",
        "",
        chaos_section(),
        "",
        "## Hierarchical-mesh comparison",
        "",
    ]
    if recs:
        sections += [
            perf_compare(recs, "qwen2-72b", "train_4k",
                         ["baseline", "hier"]),
        ]
    else:
        sections.append(MISSING_DRYRUN)
    sections += [
        "",
        "Hierarchical-mesh plans (`--plan hier`; "
        "`launch/train.py --mesh workers,fsdp,model` for CPU-runnable "
        "smokes) FSDP-shard weight storage within each DPPF worker — see "
        "DESIGN.md §Hierarchical-mesh for the axis layout and collective "
        "placement.",
        "",
    ]
    return "\n".join(sections)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="",
                    help="write to this path instead of stdout")
    ap.add_argument("--check", action="store_true",
                    help="diff against the committed EXPERIMENTS.md; "
                         "non-zero exit on drift (the CI gate)")
    args = ap.parse_args(argv)
    text = render()
    if args.check:
        path = os.path.join(ROOT, "EXPERIMENTS.md")
        committed = open(path).read() if os.path.exists(path) else ""
        if committed == text:
            print("EXPERIMENTS.md is up to date")
            return 0
        sys.stdout.writelines(difflib.unified_diff(
            committed.splitlines(keepends=True),
            text.splitlines(keepends=True),
            fromfile="EXPERIMENTS.md (committed)",
            tofile="EXPERIMENTS.md (regenerated)"))
        print("\nEXPERIMENTS.md drifted — regenerate with:\n"
              "  PYTHONPATH=src:. python -m benchmarks.render_experiments "
              "--out EXPERIMENTS.md")
        return 1
    if args.out:
        with open(args.out, "w") as f:
            f.write(text)
        print(f"wrote {args.out}")
    else:
        print(text)
    return 0


if __name__ == "__main__":
    sys.exit(main())
