"""Render the data-driven sections of EXPERIMENTS.md (§Dry-run, §Roofline
tables) from results/dryrun/*.json. Run after the dry-run sweep:

  PYTHONPATH=src python -m benchmarks.render_experiments > results/roofline_tables.md
"""
from __future__ import annotations

import glob
import json
import os
from collections import defaultdict

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(out_dir="results/dryrun"):
    recs = {}
    for p in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        r = json.load(open(p))
        recs[(r["arch"], r["shape"], r["mesh"], r["mode"], r["plan"])] = r
    return recs


def fmt_s(x):
    return f"{x:.2e}"


def dryrun_table(recs, mesh):
    rows = [
        "| arch | shape | mode | compile s | HLO GFLOP/dev | HBM GB/dev | "
        "coll GB/dev (data/model) | arg GB/dev | bottleneck |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for (a, s, m, mode, plan), r in sorted(recs.items()):
        if m != mesh or plan != "baseline" or mode == "ddp":
            continue
        ax = r.get("collective_axis_bytes", {})
        coll = sum(v["bytes"] for v in r["collectives"].values())
        mem = r.get("memory", {}).get("argument_size_in_bytes", 0)
        rows.append(
            f"| {a} | {s} | {mode} | {r['compile_s']} | "
            f"{r['hlo_flops_per_dev']/1e9:.1f} | "
            f"{r['hlo_bytes_per_dev']/1e9:.1f} | "
            f"{coll/1e9:.1f} ({ax.get('data',0)/1e9:.1f}/{ax.get('model',0)/1e9:.1f}) | "
            f"{mem/1e9:.1f} | {r['roofline']['bottleneck'][:-2]} |")
    return "\n".join(rows)


def roofline_table(recs, mesh="single"):
    rows = [
        "| arch | shape | compute s | memory s | collective s | bottleneck | "
        "useful-FLOP ratio | what moves the dominant term |",
        "|---|---|---|---|---|---|---|---|",
    ]
    hints = {
        "compute": "more chips / lower-precision matmuls",
        "memory": "remat policy, fused kernels (flash attn / SSD / pull-push), "
                  "chunked recurrences, bf16 states",
        "collective": "longer tau (DPPF!), sharding constraints on routed "
                      "tensors, bf16 payloads, overlap",
    }
    for (a, s, m, mode, plan), r in sorted(recs.items()):
        if m != mesh or plan != "baseline" or mode == "ddp":
            continue
        t = r["roofline"]
        rows.append(
            f"| {a} | {s} | {fmt_s(t['compute_s'])} | {fmt_s(t['memory_s'])} "
            f"| {fmt_s(t['collective_s'])} | **{t['bottleneck'][:-2]}** | "
            f"{r['useful_flop_ratio']:.3f} | {hints[t['bottleneck'][:-2]]} |")
    return "\n".join(rows)


def perf_compare(recs, arch, shape, plans, mesh="single", mode=None):
    mode = mode or "train"
    rows = [f"**{arch} × {shape}** (per-device, per local step where applicable)",
            "", "| plan | compute s | memory s | collective s | arg GB | "
            "coll data-axis GB | coll model-axis GB |", "|---|---|---|---|---|---|---|"]
    for plan in plans:
        r = recs.get((arch, shape, mesh, mode, plan))
        if not r:
            rows.append(f"| {plan} | (missing) |")
            continue
        t = r["roofline"]
        ax = r.get("collective_axis_bytes", {})
        mem = r.get("memory", {}).get("argument_size_in_bytes", 0)
        rows.append(
            f"| {plan} | {fmt_s(t['compute_s'])} | {fmt_s(t['memory_s'])} | "
            f"{fmt_s(t['collective_s'])} | {mem/1e9:.1f} | "
            f"{ax.get('data',0)/1e9:.2f} | {ax.get('model',0)/1e9:.1f} |")
    return "\n".join(rows)


def ddp_compare(recs, archs, mesh="single"):
    rows = ["| arch | mode | data-axis coll GB/dev per STEP | "
            "model-axis GB/dev per step | comm ratio (DPPF/DDP, data axis) |",
            "|---|---|---|---|---|"]
    for a in archs:
        d = recs.get((a, "train_4k", mesh, "ddp", "baseline"))
        p = recs.get((a, "train_4k", mesh, "train", "baseline"))
        if not (d and p):
            continue
        tau = p["tau"]
        d_ax = d.get("collective_axis_bytes", {}).get("data", 0)
        p_ax = p.get("collective_axis_bytes", {}).get("data", 0) / tau
        d_m = d.get("collective_axis_bytes", {}).get("model", 0)
        p_m = p.get("collective_axis_bytes", {}).get("model", 0) / tau
        ratio = p_ax / d_ax if d_ax else float("nan")
        rows.append(f"| {a} | DDP | {d_ax/1e9:.2f} | {d_m/1e9:.1f} | — |")
        rows.append(f"| {a} | DPPF τ=4 | {p_ax/1e9:.2f} | {p_m/1e9:.1f} | "
                    f"**{ratio:.2f}×** |")
    return "\n".join(rows)


def main():
    recs = load()
    print("## §Dry-run — single-pod 16×16 (256 chips), baseline plan\n")
    print(dryrun_table(recs, "single"))
    print("\n## §Dry-run — multi-pod 2×16×16 (512 chips), baseline plan\n")
    print(dryrun_table(recs, "multi"))
    print("\n## §Roofline — single-pod baseline\n")
    print(roofline_table(recs))
    print("\n## DPPF vs DDP communication (data-axis collectives)\n")
    print(ddp_compare(recs, ["gemma2-2b", "yi-6b", "qwen2-72b",
                             "llama4-scout-17b-a16e", "dbrx-132b"]))
    print("\n## Hillclimb comparisons\n")
    print(perf_compare(recs, "xlstm-350m", "train_4k", ["baseline", "opt"]))
    print()
    print(perf_compare(recs, "xlstm-350m", "prefill_32k", ["baseline", "opt"],
                       mode="prefill"))
    print()
    print(perf_compare(recs, "llama4-scout-17b-a16e", "train_4k",
                       ["baseline", "opt", "seqshard"]))
    print()
    print(perf_compare(recs, "gemma2-2b", "train_4k",
                       ["baseline", "seqshard"]))
    print()
    print(perf_compare(recs, "yi-6b", "train_4k", ["baseline", "seqshard"]))
    print()
    print(perf_compare(recs, "qwen2-72b", "train_4k",
                       ["baseline", "hier", "opt", "hier_opt"]))


if __name__ == "__main__":
    main()
