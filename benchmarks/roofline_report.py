"""§Roofline: render the per-(arch x shape x mesh) roofline table from the
dry-run JSON records (results/dryrun/). Reads only; run the dry-run first:
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import csv


def load(out_dir="results/dryrun"):
    recs = []
    for p in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def run(out_dir="results/dryrun"):
    recs = load(out_dir)
    if not recs:
        csv("roofline", status="no dry-run records found; run repro.launch.dryrun")
        return []
    for r in recs:
        t = r["roofline"]
        coll = sum(v["bytes"] for v in r["collectives"].values())
        csv("roofline",
            arch=r["arch"], shape=r["shape"], mesh=r["mesh"], mode=r["mode"],
            plan=r["plan"],
            compute_s=f"{t['compute_s']:.3e}",
            memory_s=f"{t['memory_s']:.3e}",
            collective_s=f"{t['collective_s']:.3e}",
            bottleneck=t["bottleneck"].replace("_s", ""),
            flops_dev=f"{r['hlo_flops_per_dev']:.3e}",
            coll_bytes_dev=f"{coll:.3e}",
            useful_flop_ratio=round(r.get("useful_flop_ratio", 0.0), 3),
            compile_s=r["compile_s"])
    return recs


if __name__ == "__main__":
    run()
