"""§Roofline: render the per-(arch x shape x mesh) roofline table from the
dry-run JSON records (results/dryrun/). Reads only; run the dry-run first:
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import csv


def load(out_dir="results/dryrun"):
    recs = []
    for p in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def run(out_dir="results/dryrun"):
    recs = load(out_dir)
    if not recs:
        csv("roofline", status="no dry-run records found; run repro.launch.dryrun")
        return []
    for r in recs:
        t = r["roofline"]
        coll = sum(v["bytes"] for v in r["collectives"].values())
        csv("roofline",
            arch=r["arch"], shape=r["shape"], mesh=r["mesh"], mode=r["mode"],
            plan=r["plan"], overlap=r.get("overlap", "none"),
            compute_s=f"{t['compute_s']:.3e}",
            memory_s=f"{t['memory_s']:.3e}",
            collective_s=f"{t['collective_s']:.3e}",
            bottleneck=t["bottleneck"].replace("_s", ""),
            flops_dev=f"{r['hlo_flops_per_dev']:.3e}",
            coll_bytes_dev=f"{coll:.3e}",
            useful_flop_ratio=round(r.get("useful_flop_ratio", 0.0), 3),
            compile_s=r["compile_s"])
    # the overlap-model comparison (launch.roofline.overlap_model): modeled
    # round time exact vs staleness1 vs doublebuf vs the staleness-k ring
    # (k in {1, 2, 4}) against the comm/compute crossover, one row per
    # train-mode record
    for r in recs:
        om = r.get("overlap_model")
        if not om or r.get("overlap", "none") != "none":
            continue
        ks = om.get("staleness_k_s", {})
        csv("roofline_overlap",
            arch=r["arch"], shape=r["shape"], mesh=r["mesh"], plan=r["plan"],
            exact_s=f"{om['exact_s']:.3e}",
            staleness1_s=f"{om['staleness1_s']:.3e}",
            doublebuf_s=f"{om['doublebuf_s']:.3e}",
            stalek1_s=f"{ks['1']:.3e}" if "1" in ks else "-",
            stalek2_s=f"{ks['2']:.3e}" if "2" in ks else "-",
            stalek4_s=f"{ks['4']:.3e}" if "4" in ks else "-",
            crossover=round(om["crossover"], 3),
            overlap_gain=round(om["overlap_gain"], 3),
            note="crossover<1: doublebuf hides ALL consensus comm behind "
                 "the tau local steps; staleness-k widens the window "
                 "k-fold")
    # ring-vs-gather wire comparison: the staleness-k gather runs as a
    # ppermute ring of R-1 single-row hops — per-hop bytes are 1/R of the
    # all-gather payload (the elastic rejoin rides the same hops)
    for r in recs:
        om = r.get("overlap_model")
        if not om or "ring_bytes_per_hop" not in om \
                or r.get("overlap", "none") != "none":
            continue
        csv("roofline_ring",
            arch=r["arch"], shape=r["shape"], mesh=r["mesh"], plan=r["plan"],
            gather_bytes=f"{om['gather_bytes']:.3e}",
            ring_bytes_per_hop=f"{om['ring_bytes_per_hop']:.3e}",
            ring_hops=om["ring_hops"],
            ring_s=f"{om['ring_s']:.3e}")
    return recs


if __name__ == "__main__":
    run()
