"""Continuous- vs static-batching serving microbench + prefill/decode
roofline rows. Writes ``BENCH_serving.json`` at the repo root (committed;
``benchmarks/check_bench.py`` guards it in CI like the roundclock and
overlap benches).

Field classes follow check_bench's contract:

* **structural** — step counts, occupancy, ``continuous_ge_static``, and
  the roofline rows: pure functions of the deterministic request trace /
  config arithmetic, identical on every host. The headline claim is the
  step ordering: BOTH modes run the SAME compiled decode step, so
  ``steps`` is a timer-free measure of scheduling efficiency, and on a
  mixed-length trace continuous batching needs no more steps than the
  static-batching admission barrier.
* **timing** — ``tok_s`` / ``ttft_ms`` / ``wall_s`` / ``compile_s``:
  host-relative, reported as deltas only.

The roofline rows use ``jax.eval_shape`` over ``ModelAPI.make_state`` to
MEASURE each arch's per-slot decode-state bytes from the actual state
pytree (never a hand formula), then feed ``roofline.serving_model``.

  PYTHONPATH=src:. python benchmarks/bench_serving.py --smoke
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, reduced
from repro.launch.roofline import serving_model
from repro.models import build_model
from repro.serving import Request, SlotEngine, serve

# deterministic mixed trace: prompt lengths x per-request decode budgets
# chosen so static batches barrier on their longest member
TRACE_LENS = [40, 6, 13, 9, 40, 6, 13, 9]
TRACE_NEW = [24, 4, 8, 16, 4, 24, 16, 8]
MAX_SLOTS = 4
CHUNK = 8

ROOFLINE_ARCHS = ("gemma2-2b", "dbrx-132b", "zamba2-7b")
ROOFLINE_SHAPE = {"max_slots": 64, "chunk": 256, "buf_len": 8192}


def measured_state_bytes(cfg, buf_len: int) -> int:
    """Per-slot decode-state bytes via abstract evaluation of the real
    ``make_state`` pytree (B=1): counts every cache/recurrent leaf."""
    model = build_model(cfg)
    params_s = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    batch = {"tokens": jax.ShapeDtypeStruct((1, 1), jnp.int32)}
    if cfg.n_enc_layers:
        batch["enc"] = jax.ShapeDtypeStruct((1, cfg.n_prefix, cfg.d_model),
                                            jnp.float32)
    elif cfg.n_prefix:
        batch["prefix"] = jax.ShapeDtypeStruct((1, cfg.n_prefix, cfg.d_model),
                                               jnp.float32)
    states, _ = jax.eval_shape(
        lambda p, b: model.make_state(p, b, buf_len), params_s, batch)
    return int(sum(int(np.prod(l.shape)) * l.dtype.itemsize
                   for l in jax.tree.leaves(states)))


def _mode_metrics(report):
    return {
        "steps": report.steps,
        "generated": report.generated,
        "occupancy": round(report.occupancy, 4),
        "wall_s": round(report.wall_s, 4),
        "tok_s": round(report.tok_s, 1),
        "ttft_ms": round(report.ttft_mean_s * 1e3, 2),
    }


def bench_serving(*, smoke=False):
    cfg = reduced(get_arch("gemma2-2b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, tokens=rng.integers(0, cfg.vocab_size, (l,)),
                    max_new_tokens=n)
            for i, (l, n) in enumerate(zip(TRACE_LENS, TRACE_NEW))]
    buf = max(TRACE_LENS) + max(TRACE_NEW)
    engine = SlotEngine(model, params, max_slots=MAX_SLOTS, buf_len=buf,
                        chunk=CHUNK)

    # warmup stream compiles every lane (incl. chunked prefill); timed
    # streams below are compile-free (microbench _time_donated discipline)
    t0 = time.perf_counter()
    serve(engine, [Request(rid=0, tokens=rng.integers(0, cfg.vocab_size,
                                                      (max(TRACE_LENS),)),
                           max_new_tokens=2),
                   Request(rid=1, tokens=rng.integers(0, cfg.vocab_size,
                                                      (3,)),
                           max_new_tokens=2)])
    compile_s = time.perf_counter() - t0

    cont = serve(engine, reqs, mode="continuous")
    stat = serve(engine, reqs, mode="static")

    out = {
        "arch": cfg.name,
        "max_slots": MAX_SLOTS,
        "chunk": CHUNK,
        "buf_len": buf,
        "trace_lens": TRACE_LENS,
        "trace_new": TRACE_NEW,
        "compile_s": round(compile_s, 2),
        "continuous": _mode_metrics(cont),
        "static": _mode_metrics(stat),
        # structural ordering: same compiled step in both modes, so fewer
        # steps == strictly less device work for the same tokens
        "continuous_ge_static": cont.steps <= stat.steps,
        "steps_saved_pct": round(100.0 * (stat.steps - cont.steps)
                                 / stat.steps, 2),
        "speedup_vs_static": round(stat.wall_s / cont.wall_s, 2)
        if cont.wall_s > 0 else 1.0,
    }
    return out


def bench_roofline():
    rows = {}
    for arch in ROOFLINE_ARCHS:
        cfg = get_arch(arch)
        sb = measured_state_bytes(cfg, ROOFLINE_SHAPE["buf_len"])
        r = serving_model(cfg, max_slots=ROOFLINE_SHAPE["max_slots"],
                          chunk=ROOFLINE_SHAPE["chunk"],
                          state_bytes_per_slot=sb)
        rows[arch] = {
            "state_bytes_per_slot": int(sb),
            "decode_bound": r["decode_bound"],
            "prefill_bound": r["prefill_bound"],
            "decode_tok_s": round(r["decode_tok_s"], 1),
            "prefill_tok_s": round(r["prefill_tok_s"], 1),
            "crossover_slots": (round(r["crossover_slots"], 1)
                                if np.isfinite(r["crossover_slots"])
                                else None),
            "prefill_tokens_per_decode_step": round(
                r["prefill_tokens_per_decode_step"], 1),
        }
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default="BENCH_serving.json")
    args = ap.parse_args(argv)
    result = {
        "backend": jax.default_backend(),
        "smoke": True,  # trace is fixed; flag kept for CLI symmetry
        "serving": bench_serving(smoke=args.smoke),
        "roofline": {"shape": dict(ROOFLINE_SHAPE), **bench_roofline()},
    }
    with open(args.out, "w") as f:
        json.dump(result, f, indent=1, sort_keys=True)
        f.write("\n")
    s = result["serving"]
    print(f"continuous: {s['continuous']['steps']} steps "
          f"(occ {s['continuous']['occupancy']}) vs static "
          f"{s['static']['steps']} steps (occ {s['static']['occupancy']}) "
          f"-> saved {s['steps_saved_pct']}% steps, "
          f"{s['speedup_vs_static']}x wall")
    print(f"wrote {args.out}")
    return result


if __name__ == "__main__":
    main()
