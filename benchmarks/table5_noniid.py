"""Paper Table 5 / §8.3: non-IID FL — SCAFFOLD and FedLESAM with and
without the DPPF aggregation, under Dirichlet(0.1 / 0.6) splits.

Plus the heterogeneous-worker METHOD ZOO (`run_zoo` / the `method_zoo`
suite): every registered consensus method from `core.methods` trained by
the shared flat-engine trainer under per-worker label skew
(Dirichlet-partitioned shards) and speed skew (slow workers refresh their
batch less often inside a round, so a fraction of their tau local steps
recompute a stale gradient), recording test error, generalization gap,
consensus distance, and the Mean Valley width (paper Alg. 2) per method.
Writes the committed ``results/method_zoo.json`` that
``render_experiments.py`` turns into the EXPERIMENTS.md §Method-zoo
table."""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (
    csv, default_data, error_pct, mlp_init, mlp_loss,
)
from repro.configs import DPPFConfig
from repro.core import fl
from repro.core import pullpush as pp
from repro.core.methods import get_method, method_names
from repro.core.schedules import lam_schedule
from repro.core.valley import mean_valley

SEEDS = (182, 437)


def _loss(params, batch):
    return mlp_loss(params, batch)[0]


def run_fl_training(data, method, *, dppf=None, M=4, tau=16, rounds=25,
                    bs=64, lr=0.25, dir_alpha=0.6, seed=0):
    shards = fl.dirichlet_partition(np.asarray(data["y_train"]), M, dir_alpha,
                                    seed=seed)
    key = jax.random.PRNGKey(seed)
    p0 = mlp_init(key, data["dim"], data["n_classes"])
    stacked = jax.tree.map(lambda a: jnp.broadcast_to(a[None], (M,) + a.shape),
                           p0)
    stacked = jax.tree.map(jnp.array, stacked)
    state = fl.init_fl_state(method, stacked)
    rng = np.random.default_rng(seed + 5)
    x_tr, y_tr = np.asarray(data["x_train"]), np.asarray(data["y_train"])
    round_jit = jax.jit(
        lambda s, st, b, lam: fl.fl_round(method, _loss, s, st, b, lr,
                                          dppf=dppf, lam_t=lam))

    for r in range(rounds):
        # one index draw per (t, m) so features and labels correspond
        idx = np.stack([[rng.choice(shards[m], bs) for m in range(M)]
                        for _ in range(tau)])
        bx, by = x_tr[idx], y_tr[idx]
        lam = (float(lam_schedule(dppf.lam_schedule, dppf.lam, r, rounds))
               if dppf else 0.0)
        stacked, state, _ = round_jit(stacked, state,
                                      {"x": jnp.asarray(bx),
                                       "y": jnp.asarray(by)},
                                      jnp.float32(lam))
    avg = jax.tree.map(lambda a: jnp.mean(a, axis=0), stacked)
    return error_pct(avg, data["x_test"], data["y_test"])


def run(rounds=25, M=4):
    data = default_data()
    out = {}
    for dir_alpha in (0.1, 0.6):
        for method in ("scaffold", "fedlesam"):
            for use_dppf in (False, True):
                # paper C.3: lam=1.8 for SCAFFOLD; conservative lam for
                # FedLESAM (two flatness mechanisms compose)
                lam = 1.8 if method == "scaffold" else 0.6
                dcfg = (DPPFConfig(alpha=0.9, lam=lam, tau=16)
                        if use_dppf else None)
                errs = [run_fl_training(data, method, dppf=dcfg, M=M,
                                        rounds=rounds, dir_alpha=dir_alpha,
                                        seed=s) for s in SEEDS]
                name = ("DPPF_" if use_dppf else "") + method
                key = f"{name}@dir{dir_alpha}"
                out[key] = (float(np.mean(errs)), float(np.std(errs)))
                csv("table5", method=name, dirichlet=dir_alpha,
                    test_err=round(out[key][0], 2),
                    std=round(out[key][1], 2))
    wins = sum(out[f"DPPF_{m}@dir{d}"][0] <= out[f"{m}@dir{d}"][0] + 0.3
               for m in ("scaffold", "fedlesam") for d in (0.1, 0.6))
    csv("table5_summary", dppf_wins_of_4=wins)
    return out


# ---------------------------------------------------------------------------
# Heterogeneous-worker method zoo
# ---------------------------------------------------------------------------

ZOO_SPEEDS = (1.0, 1.0, 0.5, 0.25)   # per-worker speed skew (fresh-batch rate)


def _zoo_batches(data, shards, rng, tau, bs, speeds):
    """One round of per-worker batches under label + speed skew: worker m
    draws from ITS Dirichlet shard, and only refreshes its batch on
    ``ceil(t / (1/speed))`` boundaries — a speed-s worker computes
    ``round(tau * s)`` fresh gradients per round and replays its last
    batch for the rest (the stale-compute model of a straggler that
    cannot keep the fleet's step cadence)."""
    M = len(speeds)
    x_tr, y_tr = np.asarray(data["x_train"]), np.asarray(data["y_train"])
    xs = np.empty((tau, M, bs, x_tr.shape[1]), x_tr.dtype)
    ys = np.empty((tau, M, bs), y_tr.dtype)
    for m, s in enumerate(speeds):
        fresh = max(1, int(round(tau * s)))
        picks = [rng.choice(shards[m], size=bs, replace=False)
                 for _ in range(fresh)]
        for t in range(tau):
            pick = picks[min(t * fresh // tau, fresh - 1)]
            xs[t, m], ys[t, m] = x_tr[pick], y_tr[pick]
    return {"x": jnp.asarray(xs), "y": jnp.asarray(ys)}


def _zoo_config(method):
    """Per-method DPPFConfig: the shared pull/push operating point from
    the table-3 soft-consensus grid; method-specific behavior (hard's
    alpha := 1, parle's ramp, lpf_sgd's filtered push, entropy_sgd's
    inner plan) comes from the registry spec, not per-method tuning."""
    spec = get_method(method)
    if not spec.communicates:
        return DPPFConfig(consensus=method)
    return DPPFConfig(consensus=method, alpha=0.1, lam=0.5, tau=4,
                      engine="flat")


def _zoo_train(data, method, shards, *, steps, bs, lr, speeds, seed):
    from repro.optim import make_optimizer
    from repro.train import (
        RoundClock, TrainState, average_params, init_train_state,
        make_ddp_step, make_round_step, stacked_params,
    )
    M = len(speeds)
    dcfg = _zoo_config(method)
    key = jax.random.PRNGKey(seed)
    opt = make_optimizer("sgd", momentum=0.9, weight_decay=1e-3)
    p0 = lambda k: mlp_init(k, data["dim"], data["n_classes"])
    rng = np.random.default_rng(seed + 1)

    if not get_method(method).communicates:          # ddp: per-step path
        params = p0(key)
        state = TrainState(params=params, opt=opt.init(params), cstate={},
                           t=jnp.zeros((), jnp.int32))
        step_fn = jax.jit(make_ddp_step(mlp_loss, opt, base_lr=lr,
                                        total_steps=steps))
        tau = 4
        for _ in range(steps // tau):
            b = _zoo_batches(data, shards, rng, tau, bs, speeds)
            for t in range(tau):
                state, _ = step_fn(state, jax.tree.map(lambda a, t=t: a[t],
                                                       b))
        return state.params, None, 0.0

    state = init_train_state(p0, opt, dcfg, M, key)
    clock = RoundClock.from_config(dcfg, base_lr=lr, total_steps=steps)
    step_fn = jax.jit(make_round_step(mlp_loss, opt, dcfg, clock=clock),
                      donate_argnums=0)
    for spec in clock.rounds:
        b = _zoo_batches(data, shards, rng, spec.tau, bs, speeds)
        state, _ = step_fn(state, b)
    avg = average_params(state)
    stacked = stacked_params(state)
    workers = [jax.tree.map(lambda a, i=i: a[i], stacked) for i in range(M)]
    cdist = float(pp.worker_dists(stacked).mean())
    return avg, workers, cdist


def run_zoo(steps=240, bs=48, lr=0.05, dir_alpha=0.3, speeds=ZOO_SPEEDS,
            seed=0, out_json="results/method_zoo.json"):
    """The full registered-method zoo under label + speed skew. One row
    per canonical method; ``mean_valley`` is the paper's Alg. 2 width
    from the average point along each worker direction (None for ddp —
    a single model has no worker spread to measure)."""
    data = default_data()
    M = len(speeds)
    shards = fl.dirichlet_partition(np.asarray(data["y_train"]), M,
                                    dir_alpha, seed=seed)
    loss_on_train = lambda p: mlp_loss(
        p, {"x": jnp.asarray(data["x_train"]),
            "y": jnp.asarray(data["y_train"])})[0]
    out = {"config": {"steps": steps, "bs": bs, "lr": lr,
                      "dir_alpha": dir_alpha, "speeds": list(speeds),
                      "workers": M, "seed": seed},
           "methods": {}}
    for method in method_names(aliases=False):
        avg, workers, cdist = _zoo_train(
            data, method, shards, steps=steps, bs=bs, lr=lr,
            speeds=speeds, seed=seed)
        test_err = error_pct(avg, data["x_test"], data["y_test"])
        train_err = error_pct(avg, data["x_train"], data["y_train"])
        mv = None
        if workers is not None and len(workers) > 1:
            mv = mean_valley(loss_on_train, workers, kappa=2.0, step=0.05,
                             max_steps=120)["mv"]
        row = {"test_err": round(test_err, 2),
               "gen_gap": round(test_err - train_err, 2),
               "consensus_dist": round(cdist, 4),
               "mean_valley": round(mv, 4) if mv is not None else None,
               "flags": get_method(method).flags}
        out["methods"][method] = row
        csv("method_zoo", method=method, **{
            k: v for k, v in row.items() if k != "flags"})
    if out_json:
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        path = os.path.join(root, out_json)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        with open(path, "w") as f:
            json.dump(out, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {path}")
    return out


if __name__ == "__main__":
    run()
    run_zoo()
