"""Paper Table 5 / §8.3: non-IID FL — SCAFFOLD and FedLESAM with and
without the DPPF aggregation, under Dirichlet(0.1 / 0.6) splits."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv, default_data, error_pct, mlp_init, mlp_loss
from repro.configs import DPPFConfig
from repro.core import fl
from repro.core.schedules import lam_schedule

SEEDS = (182, 437)


def _loss(params, batch):
    return mlp_loss(params, batch)[0]


def run_fl_training(data, method, *, dppf=None, M=4, tau=16, rounds=25,
                    bs=64, lr=0.25, dir_alpha=0.6, seed=0):
    shards = fl.dirichlet_partition(np.asarray(data["y_train"]), M, dir_alpha,
                                    seed=seed)
    key = jax.random.PRNGKey(seed)
    p0 = mlp_init(key, data["dim"], data["n_classes"])
    stacked = jax.tree.map(lambda a: jnp.broadcast_to(a[None], (M,) + a.shape),
                           p0)
    stacked = jax.tree.map(jnp.array, stacked)
    state = fl.init_fl_state(method, stacked)
    rng = np.random.default_rng(seed + 5)
    x_tr, y_tr = np.asarray(data["x_train"]), np.asarray(data["y_train"])
    round_jit = jax.jit(
        lambda s, st, b, lam: fl.fl_round(method, _loss, s, st, b, lr,
                                          dppf=dppf, lam_t=lam))

    for r in range(rounds):
        # one index draw per (t, m) so features and labels correspond
        idx = np.stack([[rng.choice(shards[m], bs) for m in range(M)]
                        for _ in range(tau)])
        bx, by = x_tr[idx], y_tr[idx]
        lam = (float(lam_schedule(dppf.lam_schedule, dppf.lam, r, rounds))
               if dppf else 0.0)
        stacked, state, _ = round_jit(stacked, state,
                                      {"x": jnp.asarray(bx),
                                       "y": jnp.asarray(by)},
                                      jnp.float32(lam))
    avg = jax.tree.map(lambda a: jnp.mean(a, axis=0), stacked)
    return error_pct(avg, data["x_test"], data["y_test"])


def run(rounds=25, M=4):
    data = default_data()
    out = {}
    for dir_alpha in (0.1, 0.6):
        for method in ("scaffold", "fedlesam"):
            for use_dppf in (False, True):
                # paper C.3: lam=1.8 for SCAFFOLD; conservative lam for
                # FedLESAM (two flatness mechanisms compose)
                lam = 1.8 if method == "scaffold" else 0.6
                dcfg = (DPPFConfig(alpha=0.9, lam=lam, tau=16)
                        if use_dppf else None)
                errs = [run_fl_training(data, method, dppf=dcfg, M=M,
                                        rounds=rounds, dir_alpha=dir_alpha,
                                        seed=s) for s in SEEDS]
                name = ("DPPF_" if use_dppf else "") + method
                key = f"{name}@dir{dir_alpha}"
                out[key] = (float(np.mean(errs)), float(np.std(errs)))
                csv("table5", method=name, dirichlet=dir_alpha,
                    test_err=round(out[key][0], 2),
                    std=round(out[key][1], 2))
    wins = sum(out[f"DPPF_{m}@dir{d}"][0] <= out[f"{m}@dir{d}"][0] + 0.3
               for m in ("scaffold", "fedlesam") for d in (0.1, 0.6))
    csv("table5_summary", dppf_wins_of_4=wins)
    return out


if __name__ == "__main__":
    run()
