"""Benchmark harness — one module per paper table/figure. Prints CSV lines
``name,key=value,...`` per row. ``--fast`` shrinks budgets for CI; default
budgets reproduce the qualitative paper orderings on CPU in ~10-20 min.

  PYTHONPATH=src python -m benchmarks.run [--fast] [--only table2,...]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    fast = args.fast

    from benchmarks import (
        ablate_schedule, ablate_second_term, ablate_workers, d2_theorem2,
        fig2_valley_collapse, microbench, roofline_report, table1_sharpness,
        table2_comm, table3_softconsensus, table4_sam, table5_noniid,
        theorem1_width,
    )

    suites = {
        "microbench": lambda: microbench.run(),
        "theorem1": lambda: theorem1_width.run(steps=200 if fast else 600),
        "fig2": lambda: fig2_valley_collapse.run(steps=200 if fast else 600),
        "table2": lambda: table2_comm.run(steps=150 if fast else 400),
        "table3": lambda: table3_softconsensus.run(steps=150 if fast else 400),
        "table4": lambda: table4_sam.run(steps=150 if fast else 400),
        "table5": lambda: table5_noniid.run(rounds=8 if fast else 25),
        "ablate_schedule": lambda: ablate_schedule.run(
            steps=150 if fast else 400),
        "ablate_second_term": lambda: ablate_second_term.run(
            steps=150 if fast else 400),
        "d2_theorem2": lambda: d2_theorem2.run(steps=150 if fast else 400),
        "ablate_workers": lambda: ablate_workers.run(
            steps=150 if fast else 400),
        "table1": lambda: table1_sharpness.run(steps=120 if fast else 300),
        "roofline": lambda: roofline_report.run(),
    }
    only = [s for s in args.only.split(",") if s]
    failures = []
    for name, fn in suites.items():
        if only and name not in only:
            continue
        t0 = time.time()
        print(f"# === {name} ===", flush=True)
        try:
            fn()
            print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)
        except Exception as e:
            failures.append(name)
            print(f"# {name} FAILED: {e!r}", flush=True)
            traceback.print_exc()
    if failures:
        print(f"# FAILURES: {failures}")
        sys.exit(1)
    print("# all benchmarks completed")


if __name__ == "__main__":
    main()
