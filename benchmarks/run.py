"""Benchmark harness — one module per paper table/figure. Prints CSV lines
``name,key=value,...`` per row. ``--fast`` shrinks budgets for CI; default
budgets reproduce the qualitative paper orderings on CPU in ~10-20 min.

  PYTHONPATH=src python -m benchmarks.run [--fast] [--only table2,...]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

# Paper-artifact registry: one row per suite — (paper artifact, script,
# what it reproduces). ``render_experiments.py`` turns this into the
# EXPERIMENTS.md / README.md artifact tables (CI fails when EXPERIMENTS.md
# drifts), so a new suite needs its row here to be documented.
ARTIFACTS = {
    "microbench": (
        "—", "benchmarks/microbench.py",
        "hot-path microbenches (engine_vs_tree, sharded_round, "
        "hierarchical_round, overlap_round, method_zoo, autotune, "
        "roundclock); writes BENCH_roundclock.json + BENCH_overlap.json "
        "+ BENCH_autotune.json (the --autotune probe-search baseline)"),
    "theorem1": (
        "Thm. 1", "benchmarks/theorem1_width.py",
        "asymptotic valley width -> lambda/alpha on the proof recurrence "
        "and on real DNN training"),
    "fig2": (
        "Fig. 2-3", "benchmarks/fig2_valley_collapse.py",
        "valley collapse without the push force; pull/push tug-of-war"),
    "table1": (
        "Table 1", "benchmarks/table1_sharpness.py",
        "Kendall rank correlation of sharpness measures vs generalization "
        "gap"),
    "table2": (
        "Table 2 / Fig. 1", "benchmarks/table2_comm.py",
        "communication volume vs test error: DDP / LocalSGD / QSR / DPPF"),
    "table3": (
        "Table 3", "benchmarks/table3_softconsensus.py",
        "soft-consensus optimizers with/without the push (incl. Remark 1: "
        "LSGD push-from-leader vs push-from-average)"),
    "table4": (
        "Table 4", "benchmarks/table4_sam.py",
        "local vs distributed flatness: DDP/DPPF x SGD/SAM grid"),
    "table5": (
        "Table 5", "benchmarks/table5_noniid.py",
        "non-IID FL: SCAFFOLD / FedLESAM with and without DPPF "
        "aggregation"),
    "method_zoo": (
        "§2 related methods", "benchmarks/table5_noniid.py",
        "heterogeneous-worker zoo: every registered consensus method "
        "(core.methods) under Dirichlet label skew + speed skew, with "
        "Mean Valley width per method; writes results/method_zoo.json"),
    "ablate_schedule": (
        "§C.2 + §7.2", "benchmarks/ablate_schedule.py",
        "lambda-schedule ablation (fixed/increasing/decreasing) plus the "
        "increasing+qsr round-clock row: QSR-adaptive tau on the best "
        "schedule, reporting comm volume next to error"),
    "ablate_second_term": (
        "§D.1 / Fig. 7", "benchmarks/ablate_second_term.py",
        "is the dropped second push term T2 negligible?"),
    "d2_theorem2": (
        "§D.2 / Thm. 2", "benchmarks/d2_theorem2.py",
        "sensitivity of test error to lambda; Theorem 2's assumptions"),
    "ablate_workers": (
        "Tables 3-4 (M axis)", "benchmarks/ablate_workers.py",
        "worker-count scaling of the push edge and width M-robustness"),
    "roofline": (
        "—", "benchmarks/roofline_report.py",
        "per-(arch x shape x mesh) roofline from dry-run records"),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    fast = args.fast

    from benchmarks import (
        ablate_schedule, ablate_second_term, ablate_workers, d2_theorem2,
        fig2_valley_collapse, microbench, roofline_report, table1_sharpness,
        table2_comm, table3_softconsensus, table4_sam, table5_noniid,
        theorem1_width,
    )

    suites = {
        "microbench": lambda: microbench.run(),
        "theorem1": lambda: theorem1_width.run(steps=200 if fast else 600),
        "fig2": lambda: fig2_valley_collapse.run(steps=200 if fast else 600),
        "table2": lambda: table2_comm.run(steps=150 if fast else 400),
        "table3": lambda: table3_softconsensus.run(steps=150 if fast else 400),
        "table4": lambda: table4_sam.run(steps=150 if fast else 400),
        "table5": lambda: table5_noniid.run(rounds=8 if fast else 25),
        "method_zoo": lambda: table5_noniid.run_zoo(
            steps=80 if fast else 240,
            out_json="" if fast else "results/method_zoo.json"),
        "ablate_schedule": lambda: ablate_schedule.run(
            steps=150 if fast else 400),
        "ablate_second_term": lambda: ablate_second_term.run(
            steps=150 if fast else 400),
        "d2_theorem2": lambda: d2_theorem2.run(steps=150 if fast else 400),
        "ablate_workers": lambda: ablate_workers.run(
            steps=150 if fast else 400),
        "table1": lambda: table1_sharpness.run(steps=120 if fast else 300),
        "roofline": lambda: roofline_report.run(),
    }
    if set(suites) != set(ARTIFACTS):
        raise SystemExit("ARTIFACTS registry out of sync with suites: "
                         f"{sorted(set(suites) ^ set(ARTIFACTS))}")
    only = [s for s in args.only.split(",") if s]
    failures = []
    for name, fn in suites.items():
        if only and name not in only:
            continue
        t0 = time.time()
        print(f"# === {name} ===", flush=True)
        try:
            fn()
            print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)
        except Exception as e:
            failures.append(name)
            print(f"# {name} FAILED: {e!r}", flush=True)
            traceback.print_exc()
    if failures:
        print(f"# FAILURES: {failures}")
        sys.exit(1)
    print("# all benchmarks completed")


if __name__ == "__main__":
    main()
