"""Micro-benchmarks: us_per_call for the hot paths (fused pull-push vs
naive, DPPF round vs DDP steps at equal token budget) on this host CPU.
Wall-times are host-relative — the TPU story is §Roofline — but the
RELATIVE comparison (fused consensus cost, round amortization) holds."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import csv, default_data, mlp_init, mlp_loss
from repro.configs import DPPFConfig
from repro.core import pullpush as pp
from repro.optim import make_optimizer
from repro.train import init_train_state, make_round_step, make_ddp_step
from repro.train.trainer import TrainState


def _time(fn, *args, n=20):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6


def run():
    # fused pull-push vs naive multi-pass
    key = jax.random.PRNGKey(0)
    stacked = {"w": jax.random.normal(key, (8, 1_000_000))}
    fused = jax.jit(lambda s: pp.pullpush(s, 0.1, 0.5)[0])

    def naive(s):
        a = jax.tree.map(lambda x: jnp.mean(x, 0), s)
        d = jax.tree.map(lambda x, c: x - c[None], s, a)
        r = jnp.sqrt(sum(jnp.sum(jnp.square(l), axis=tuple(range(1, l.ndim)))
                         for l in jax.tree.leaves(d)))
        coef = 0.1 - 0.5 / jnp.maximum(r, 1e-12)
        return jax.tree.map(lambda x, c: x + (c[None] - x) * coef.reshape(
            (-1,) + (1,) * (x.ndim - 1)), s, a)

    csv("microbench", op="pullpush_fused_8x1M",
        us_per_call=round(_time(fused, stacked), 1))
    csv("microbench", op="pullpush_naive_8x1M",
        us_per_call=round(_time(jax.jit(naive), stacked), 1))

    # DPPF round vs tau DDP steps at the same token budget
    data = default_data()
    opt = make_optimizer("sgd")
    M, bs, tau = 4, 64, 4
    dcfg = DPPFConfig(alpha=0.1, lam=0.5, tau=tau)
    st = init_train_state(lambda k: mlp_init(k, data["dim"],
                                             data["n_classes"]),
                          opt, dcfg, M, key)
    round_fn = jax.jit(make_round_step(mlp_loss, opt, dcfg, base_lr=0.05,
                                       total_steps=100))
    batch = {"x": jnp.zeros((tau, M, bs, data["dim"])),
             "y": jnp.zeros((tau, M, bs), jnp.int32)}
    us_round = _time(lambda s, b: round_fn(s, b)[0], st, batch)

    p0 = mlp_init(key, data["dim"], data["n_classes"])
    dstate = TrainState(params=p0, opt=opt.init(p0), cstate={},
                        t=jnp.zeros((), jnp.int32))
    ddp_fn = jax.jit(make_ddp_step(mlp_loss, opt, base_lr=0.05,
                                   total_steps=100))
    db = {"x": jnp.zeros((M, bs, data["dim"])),
          "y": jnp.zeros((M, bs), jnp.int32)}
    us_ddp = _time(lambda s, b: ddp_fn(s, b)[0], dstate, db)
    csv("microbench", op=f"dppf_round_tau{tau}", us_per_call=round(us_round, 1),
        derived=f"per_local_step={round(us_round / tau, 1)}")
    csv("microbench", op="ddp_step", us_per_call=round(us_ddp, 1),
        derived=f"tau_steps={round(us_ddp * tau, 1)}")


if __name__ == "__main__":
    run()
