"""Micro-benchmarks: us_per_call for the hot paths (flat ConsensusEngine vs
tree-path consensus, fused pull-push vs naive, DPPF round vs DDP steps at
equal token budget, QSR RoundClock vs fixed tau) on this host CPU.
Wall-times are host-relative — the TPU story is §Roofline — but the
RELATIVE comparison (flat-engine speedup, fused consensus cost, round
amortization, all-reduces saved) holds.

Besides the CSV rows, ``run`` writes ``BENCH_roundclock.json`` at the repo
root — rounds, all-reduce counts, and the engine-vs-tree row — so the perf
trajectory is machine-readable across PRs.

``--smoke`` shrinks every size so the whole file runs in seconds (CI).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp

from benchmarks.common import csv, default_data, mlp_init, mlp_loss
from repro.configs import DPPFConfig
from repro.core import consensus
from repro.core import pullpush as pp
from repro.core.engine import ConsensusEngine
from repro.optim import make_optimizer
from repro.train import (
    RoundClock, init_train_state, make_round_step, make_ddp_step,
    make_sharded_round_step, shard_train_state,
)
from repro.train.trainer import TrainState


def _time(fn, *args, n=20):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6


def _time_donated(fn, arg, n=20):
    """Time a donating jit'd fn by threading its output back in (this is
    exactly how the trainer reuses the flat view between rounds). Warms
    TWICE: the first output's shardings are the steady-state cache key
    (e.g. the doublebuf snapshot comes back row-sharded), so the second
    call is where any residual recompile lands."""
    out = fn(arg)
    jax.block_until_ready(out)
    out = fn(out)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(out)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6


def _transformer_like_stacked(key, M, target_params):
    """Worker-stacked pytree with a realistic leaf census — hundreds of
    mixed matrix/vector leaves, like a real LM checkpoint (a 1M-param model
    has ~750 leaves; a 6B one has ~400 larger ones). Per-leaf dispatch is
    exactly what the tree path pays for and the flat engine amortizes."""
    block = [(64, 64), (64,), (64, 16), (16,)]
    per_block = sum(s[0] * (s[1] if len(s) > 1 else 1) for s in block)
    shapes = block * max(target_params // per_block, 1)
    ks = jax.random.split(key, len(shapes))
    return {f"p{i}": jax.random.normal(ks[i], (M,) + s)
            for i, s in enumerate(shapes)}


def bench_engine_vs_tree(*, smoke=False):
    """THE acceptance row: flat ConsensusEngine vs the stacked-tree path on
    the same 8-worker x ~1M-param consensus round (Eq. 5)."""
    M = 8
    target = 20_000 if smoke else 1_000_000
    n_it = 3 if smoke else 20
    stacked = _transformer_like_stacked(jax.random.PRNGKey(0), M, target)
    dcfg = DPPFConfig(alpha=0.1, lam=0.5)
    lam_t = 0.3

    tree_fn = jax.jit(
        lambda s: consensus.apply_round(s, dcfg, lam_t, {})[0])
    us_tree = _time(tree_fn, stacked, n=n_it)

    engine = ConsensusEngine.from_stacked(stacked)
    flat = engine.flatten(stacked)          # ONCE per run — not timed
    flat_fn = jax.jit(
        lambda f: consensus.apply_round(f, dcfg, lam_t, {}, engine=engine)[0],
        donate_argnums=0)
    us_flat = _time_donated(flat_fn, flat, n=n_it)

    n = engine.layout.n
    csv("microbench", op=f"consensus_tree_{M}x{n}",
        us_per_call=round(us_tree, 1))
    csv("microbench", op=f"consensus_engine_{M}x{n}",
        us_per_call=round(us_flat, 1))
    csv("microbench", op="engine_vs_tree",
        speedup=round(us_tree / us_flat, 2),
        note="flat ConsensusEngine (persistent donated view) vs "
             "stacked-tree apply_round")
    return {"workers": M, "params_per_worker": n,
            "us_tree": round(us_tree, 1), "us_engine": round(us_flat, 1),
            "speedup": round(us_tree / us_flat, 2)}


def bench_pullpush(*, smoke=False):
    # fused pull-push vs naive multi-pass
    key = jax.random.PRNGKey(0)
    n = 20_000 if smoke else 1_000_000
    n_it = 3 if smoke else 20
    stacked = {"w": jax.random.normal(key, (8, n))}
    fused = jax.jit(lambda s: pp.pullpush(s, 0.1, 0.5)[0])

    def naive(s):
        a = jax.tree.map(lambda x: jnp.mean(x, 0), s)
        d = jax.tree.map(lambda x, c: x - c[None], s, a)
        r = jnp.sqrt(sum(jnp.sum(jnp.square(l), axis=tuple(range(1, l.ndim)))
                         for l in jax.tree.leaves(d)))
        coef = 0.1 - 0.5 / jnp.maximum(r, 1e-12)
        return jax.tree.map(lambda x, c: x + (c[None] - x) * coef.reshape(
            (-1,) + (1,) * (x.ndim - 1)), s, a)

    csv("microbench", op=f"pullpush_fused_8x{n}",
        us_per_call=round(_time(fused, stacked, n=n_it), 1))
    csv("microbench", op=f"pullpush_naive_8x{n}",
        us_per_call=round(_time(jax.jit(naive), stacked, n=n_it), 1))


def bench_round_vs_ddp(*, smoke=False):
    # DPPF round vs tau DDP steps at the same token budget
    key = jax.random.PRNGKey(0)
    data = default_data()
    opt = make_optimizer("sgd")
    M, bs, tau = 4, 16 if smoke else 64, 4
    n_it = 3 if smoke else 20
    dcfg = DPPFConfig(alpha=0.1, lam=0.5, tau=tau)
    st = init_train_state(lambda k: mlp_init(k, data["dim"],
                                             data["n_classes"]),
                          opt, dcfg, M, key)
    round_fn = jax.jit(make_round_step(mlp_loss, opt, dcfg, base_lr=0.05,
                                       total_steps=100))
    batch = {"x": jnp.zeros((tau, M, bs, data["dim"])),
             "y": jnp.zeros((tau, M, bs), jnp.int32)}
    us_round = _time(lambda s, b: round_fn(s, b)[0], st, batch, n=n_it)

    p0 = mlp_init(key, data["dim"], data["n_classes"])
    dstate = TrainState(params=p0, opt=opt.init(p0), cstate={},
                        t=jnp.zeros((), jnp.int32))
    ddp_fn = jax.jit(make_ddp_step(mlp_loss, opt, base_lr=0.05,
                                   total_steps=100))
    db = {"x": jnp.zeros((M, bs, data["dim"])),
          "y": jnp.zeros((M, bs), jnp.int32)}
    us_ddp = _time(lambda s, b: ddp_fn(s, b)[0], dstate, db, n=n_it)
    csv("microbench", op=f"dppf_round_tau{tau}", us_per_call=round(us_round, 1),
        derived=f"per_local_step={round(us_round / tau, 1)}")
    csv("microbench", op="ddp_step", us_per_call=round(us_ddp, 1),
        derived=f"tau_steps={round(us_ddp * tau, 1)}")


def bench_sharded_round(*, smoke=False):
    """Sharded vs single-shard flat-engine round on the host devices.
    Needs a multi-device CPU mesh (run with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``); emits a
    skipped row on one device so the CSV schema is stable."""
    ndev = len(jax.devices())
    if ndev < 2:
        csv("microbench", op="sharded_round", skipped=1,
            note="single device; set "
                 "XLA_FLAGS=--xla_force_host_platform_device_count=8")
        return
    from repro.launch.mesh import make_flat_engine_mesh
    data = default_data()
    opt = make_optimizer("sgd")
    M, bs, tau = 8, 16 if smoke else 64, 4
    n_it = 3 if smoke else 20
    mesh, plan = make_flat_engine_mesh(M)
    batch = {"x": jnp.zeros((tau, M, bs, data["dim"])),
             "y": jnp.zeros((tau, M, bs), jnp.int32)}
    init = lambda k: mlp_init(k, data["dim"], data["n_classes"],
                              width=32 if smoke else 256)
    rows = {}
    for overlap in ("none", "staleness1"):
        dcfg = DPPFConfig(alpha=0.1, lam=0.5, tau=tau, engine="flat",
                          overlap=overlap)
        st = init_train_state(init, opt, dcfg, M, jax.random.PRNGKey(0))
        single = jax.jit(make_round_step(mlp_loss, opt, dcfg, base_lr=0.05,
                                         total_steps=100), donate_argnums=0)
        us_single = _time_donated(lambda s: single(s, batch)[0], st, n=n_it)
        st = shard_train_state(
            init_train_state(init, opt, dcfg, M, jax.random.PRNGKey(0)),
            mesh, plan, dcfg=dcfg)
        sharded = jax.jit(make_sharded_round_step(
            mlp_loss, opt, dcfg, mesh=mesh, plan=plan, base_lr=0.05,
            total_steps=100), donate_argnums=0)
        us_sharded = _time_donated(lambda s: sharded(s, batch)[0], st,
                                   n=n_it)
        rows[overlap] = (us_single, us_sharded)
        csv("microbench", op=f"sharded_round_overlap_{overlap}",
            us_single_device=round(us_single, 1),
            us_sharded=round(us_sharded, 1),
            mesh="x".join(str(s) for s in mesh.devices.shape))
    us_exact, us_stale = rows["none"][1], rows["staleness1"][1]
    csv("microbench", op="sharded_round",
        overlap_speedup=round(us_exact / us_stale, 2),
        note="shard_map round (collective Gram); staleness-1 hides the "
             "consensus behind the tau local steps")


def bench_hierarchical_round(*, smoke=False):
    """Hierarchical 2x2x2 (workers x fsdp x model) round vs the flat 8x1
    row-sharded round on the same 8 workers: the column group spans both
    fsdp and model axes, so the partial-Gram psum reduces over 4 column
    shards (DESIGN.md §Hierarchical-mesh). Needs 8 forced host devices;
    emits a skipped row otherwise so the CSV schema is stable."""
    if len(jax.devices()) < 8:
        csv("microbench", op="hierarchical_round", skipped=1,
            note="needs 8 devices; set "
                 "XLA_FLAGS=--xla_force_host_platform_device_count=8")
        return None
    from repro.launch.mesh import make_flat_engine_mesh, make_hier_engine_mesh
    data = default_data()
    opt = make_optimizer("sgd")
    M, bs, tau = 8, 16 if smoke else 64, 4
    n_it = 3 if smoke else 20
    batch = {"x": jnp.zeros((tau, M, bs, data["dim"])),
             "y": jnp.zeros((tau, M, bs), jnp.int32)}
    init = lambda k: mlp_init(k, data["dim"], data["n_classes"],
                              width=32 if smoke else 256)
    dcfg = DPPFConfig(alpha=0.1, lam=0.5, tau=tau, engine="flat")
    out = {}
    for name, (mesh, plan) in (("flat_8x1", make_flat_engine_mesh(M)),
                               ("hier_2x2x2", make_hier_engine_mesh(2, 2, 2))):
        st = shard_train_state(
            init_train_state(init, opt, dcfg, M, jax.random.PRNGKey(0)),
            mesh, plan)
        fn = jax.jit(make_sharded_round_step(
            mlp_loss, opt, dcfg, mesh=mesh, plan=plan, base_lr=0.05,
            total_steps=100), donate_argnums=0)
        us = _time_donated(lambda s: fn(s, batch)[0], st, n=n_it)
        # us_ prefix: check_bench treats these as host-relative timing
        out[f"us_{name}"] = round(us, 1)
        csv("microbench", op=f"hierarchical_round_{name}",
            us_per_call=round(us, 1),
            mesh="x".join(str(s) for s in mesh.devices.shape))
    csv("microbench", op="hierarchical_round",
        flat_vs_hier=round(out["us_flat_8x1"] / out["us_hier_2x2x2"], 2),
        note="same 8 workers; hier column-shards the (R, n) view over "
             "fsdp x model with the Gram psum spanning both axes")
    return out


def bench_overlap_round(*, smoke=False):
    """THE overlap acceptance rows: exact vs staleness1 vs doublebuf round
    throughput on the 8-device mesh (hier 2x2x2 — both the worker-row
    gather and the column-axis partial-Gram psum are real collectives).
    doublebuf dispatches the snapshot's gather/Gram chunks mid-scan and
    leaves only the mix GEMM at the boundary.

    Two kinds of rows per mode:

    * ``us_per_round`` — measured host wall time. Host-relative and
      report-only (forced host devices run collectives as shared-memory
      memcpys, so there is little latency to hide on CPU; check_bench
      treats ``us_*``/``speedup_*`` as timing fields).
    * ``modeled_round_us`` — the §Roofline hardware model (TPU v5e ICI /
      peak-flops constants, `launch/roofline.py`) applied to this exact
      config: per-round compute window + boundary-serial consensus bytes
      (exact: gather + psum; staleness1: gather only — the stale psum
      hides; doublebuf: ZERO — all snapshot comm dispatches mid-scan,
      capped by the compute window). Deterministic arithmetic, so it is
      a STRUCTURAL field: the committed ``BENCH_overlap.json`` pins the
      doublebuf >= staleness1 >= exact throughput ordering in CI
      (``modeled_order_ok``).

    Emits a skipped row on fewer than 8 devices so the CSV schema is
    stable."""
    if len(jax.devices()) < 8:
        csv("microbench", op="overlap_round", skipped=1,
            note="needs 8 devices; set "
                 "XLA_FLAGS=--xla_force_host_platform_device_count=8")
        return None
    from repro.launch import roofline as rf
    from repro.launch.mesh import make_hier_engine_mesh
    data = default_data()
    opt = make_optimizer("sgd")
    M, bs, tau = 8, 16 if smoke else 64, 8
    width = 32 if smoke else 256
    n_it = 10 if smoke else 20
    mesh, plan = make_hier_engine_mesh(2, 2, 2)
    rows_sz, cols_sz = 2, 4          # worker shards x (fsdp x model) shards
    batch = {"x": jnp.zeros((tau, M, bs, data["dim"])),
             "y": jnp.zeros((tau, M, bs), jnp.int32)}
    init = lambda k: mlp_init(k, data["dim"], data["n_classes"], width=width)
    out = {"mesh": "x".join(str(s) for s in mesh.devices.shape),
           "workers": M, "tau": tau, "modes": {}}

    def modeled_us(mode, R, n, k=2):
        # per-device round: compute window = tau local steps of the MLP
        # (fwd+bwd ~ 3x fwd flops) on m_loc workers; consensus bytes =
        # worker-row all-gather + (R, R) partial-Gram psum. The per-mode
        # formulas live in launch.roofline (probe_round_model routes
        # through overlap_model — the ONE copy, shared with the autotune
        # probes and the dry-run §Overlap-roofline table). staleness_k
        # reads the k-deep ring entry (ppermute ring wire + k compute
        # windows to hide it behind).
        dims = [data["dim"], width, width, data["n_classes"]]
        fwd = 2 * bs * sum(a * b for a, b in zip(dims, dims[1:]))
        data_bytes = R * (n // cols_sz) * 4 + R * R * 4
        return rf.probe_round_model(
            work_s_per_step=3 * fwd * (M // rows_sz) / rf.PEAK_FLOPS,
            tau=tau, gather_bytes=data_bytes, R=R, mode=mode,
            staleness=k if mode == "staleness_k" else 1) * 1e6

    K_DEPTH = 2
    for mode, chunks in (("none", 1), ("staleness1", 1), ("doublebuf", 4),
                         ("staleness_k", 4)):
        dcfg = DPPFConfig(alpha=0.1, lam=0.5, tau=tau, engine="flat",
                          overlap=mode, overlap_chunks=chunks,
                          staleness=K_DEPTH if mode == "staleness_k" else 1)
        st = init_train_state(init, opt, dcfg, M, jax.random.PRNGKey(0))
        L = st.engine.layout
        st = shard_train_state(st, mesh, plan, dcfg=dcfg)
        fn = jax.jit(make_sharded_round_step(
            mlp_loss, opt, dcfg, mesh=mesh, plan=plan, base_lr=0.05,
            total_steps=100), donate_argnums=0)
        us = _time_donated(lambda s: fn(s, batch)[0], st, n=n_it)
        mus = modeled_us(mode, L.R, L.n, k=K_DEPTH)
        row = {"overlap_chunks": chunks, "us_per_round": round(us, 1),
               "modeled_round_us": round(mus, 3)}
        if mode == "staleness_k":
            row["staleness"] = K_DEPTH
        out["modes"][mode] = row
        csv("microbench", op=f"overlap_round_{mode}",
            us_per_round=round(us, 1), modeled_round_us=round(mus, 3),
            overlap_chunks=chunks, mesh=out["mesh"])
    us = {m: out["modes"][m]["us_per_round"] for m in out["modes"]}
    mus = {m: out["modes"][m]["modeled_round_us"] for m in out["modes"]}
    out["speedup_staleness1"] = round(us["none"] / us["staleness1"], 2)
    out["speedup_doublebuf"] = round(us["none"] / us["doublebuf"], 2)
    out["speedup_staleness_k"] = round(us["none"] / us["staleness_k"], 2)
    out["modeled_order_ok"] = bool(
        mus["staleness_k"] <= mus["doublebuf"]
        <= mus["staleness1"] <= mus["none"])
    csv("microbench", op="overlap_round",
        speedup_staleness1=out["speedup_staleness1"],
        speedup_doublebuf=out["speedup_doublebuf"],
        speedup_staleness_k=out["speedup_staleness_k"],
        modeled_order_ok=out["modeled_order_ok"],
        note="round throughput vs exact on the hier 2x2x2 mesh; doublebuf "
             "chunks the snapshot gather+Gram mid-scan (boundary = mix "
             "GEMM only); modeled_* pins staleness_k >= doublebuf >= "
             "staleness1 >= exact on the roofline hardware model")
    return out


def bench_ring_round(*, smoke=False):
    """Ring-vs-gather acceptance rows: the staleness-k mid-scan gather as
    a ``ppermute`` ring (R-1 hops of one worker row each,
    launch.mesh.ring_gather) against one ``all_gather`` of the same
    payload, on the flat 8x1 mesh.

    * ``us_ring`` / ``us_gather`` — measured host wall time (timing
      fields; forced host devices make collectives memcpys, so the ring's
      latency-hiding advantage does not show on CPU).
    * ``ring_bytes_per_hop`` / ``gather_bytes`` / ``ring_hops`` — the
      modeled wire schedule (deterministic arithmetic). STRUCTURAL:
      the committed baseline pins ``ring_ok`` =
      ``ring_bytes_per_hop <= gather_bytes`` and the hop count R-1.
    * ``ring_matches_gather`` — bit-for-bit parity of the two assembled
      (R, n) views (the concatenation-order contract precise mode
      depends on). STRUCTURAL.
    """
    if len(jax.devices()) < 8:
        csv("microbench", op="ring_round", skipped=1,
            note="needs 8 devices; set "
                 "XLA_FLAGS=--xla_force_host_platform_device_count=8")
        return None
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import make_flat_engine_mesh, ring_gather
    R = 8
    n = 4096 if smoke else 65536
    n_it = 10 if smoke else 20
    mesh, plan = make_flat_engine_mesh(R)
    x = jax.device_put(
        jnp.arange(R * n, dtype=jnp.float32).reshape(R, n),
        jax.sharding.NamedSharding(mesh, P("data", None)))

    def _ring(v):
        return ring_gather(v, ("data",), world=R, axis=0)

    def _gather(v):
        return jax.lax.all_gather(v, ("data",), axis=0, tiled=True)

    f_ring = jax.jit(shard_map(_ring, mesh=mesh, in_specs=P("data", None),
                               out_specs=P(None, None), check_rep=False))
    f_gather = jax.jit(shard_map(_gather, mesh=mesh,
                                 in_specs=P("data", None),
                                 out_specs=P(None, None),
                                 check_rep=False))
    same = bool(jnp.array_equal(f_ring(x), f_gather(x)))
    us_ring = _time(f_ring, x, n=n_it)
    us_gather = _time(f_gather, x, n=n_it)
    gather_bytes = R * n * 4
    out = {"workers": R, "cols": n,
           "us_ring": round(us_ring, 1), "us_gather": round(us_gather, 1),
           "gather_bytes": gather_bytes,
           "ring_bytes_per_hop": gather_bytes // R,
           "ring_hops": R - 1,
           "ring_ok": gather_bytes // R <= gather_bytes,
           "ring_matches_gather": same}
    csv("microbench", op="ring_round", us_ring=round(us_ring, 1),
        us_gather=round(us_gather, 1), gather_bytes=gather_bytes,
        ring_bytes_per_hop=out["ring_bytes_per_hop"],
        ring_hops=out["ring_hops"], ring_ok=out["ring_ok"],
        ring_matches_gather=same,
        note="ppermute ring (R-1 one-row hops) vs one tiled all_gather of "
             "the full (R, n) view; parity is the staleness-k "
             "concatenation-order contract")
    return out


def bench_method_zoo(*, smoke=False):
    """One flat-engine round per REGISTERED consensus method on the same
    model/optimizer/tau: the method-zoo cost matrix. Methods come from
    the registry (``core.methods.method_names``), so a newly registered
    method lands a row here (and in the committed ``BENCH_overlap.json``
    ``method_zoo`` key) without touching this file. The canonical name
    LIST is structural (a registry change must regenerate the baseline);
    ``us_per_round`` rides the ``us_`` timing prefix. ddp has no round —
    its row times tau per-step gradient-averaging steps instead."""
    from repro.core.methods import get_method, method_names
    data = default_data()
    opt = make_optimizer("sgd")
    M, bs, tau = 8, 16 if smoke else 64, 4
    n_it = 3 if smoke else 20
    init = lambda k: mlp_init(k, data["dim"], data["n_classes"])
    batch = {"x": jnp.zeros((tau, M, bs, data["dim"])),
             "y": jnp.zeros((tau, M, bs), jnp.int32)}
    names = method_names(aliases=False)
    out = {"workers": M, "tau": tau, "engine": "flat",
           "method_names": list(names), "methods": {}}
    for name in names:
        spec = get_method(name)
        if not spec.communicates:     # ddp: tau per-step grad averages
            p0 = init(jax.random.PRNGKey(0))
            st = TrainState(params=p0, opt=opt.init(p0), cstate={},
                            t=jnp.zeros((), jnp.int32))
            fn = jax.jit(make_ddp_step(mlp_loss, opt, base_lr=0.05,
                                       total_steps=100))
            db = jax.tree.map(lambda a: a[0], batch)
            us = _time(lambda s, b: fn(s, b)[0], st, db, n=n_it) * tau
        else:
            dcfg = DPPFConfig(consensus=name, alpha=0.1, lam=0.5, tau=tau,
                              engine="flat")
            st = init_train_state(init, opt, dcfg, M, jax.random.PRNGKey(0))
            fn = jax.jit(make_round_step(mlp_loss, opt, dcfg, base_lr=0.05,
                                         total_steps=100), donate_argnums=0)
            us = _time_donated(lambda s: fn(s, batch)[0], st, n=n_it)
        out["methods"][name] = {"us_per_round": round(us, 1)}
        csv("microbench", op=f"method_zoo_{name}", us_per_round=round(us, 1),
            aux_rows=spec.aux_rows, communicates=spec.communicates)
    csv("microbench", op="method_zoo", methods=len(names),
        note="one flat-engine round per registered method (ddp = tau "
             "per-step grad averages); registry-driven rows")
    return out


def bench_autotune(*, smoke=False):
    """THE autotune acceptance row (DESIGN.md §Autotune): the probe
    search on the REAL round step with an INJECTED OOM frontier
    (``inject_oom_above`` — the same ``--tune-oom-above`` CI hook), so
    the committed record pins a deterministic ladder: doubling 2, 4, 8
    ok -> 16 OOM, binary refine 12 ok / 14, 13 OOM -> frontier 12, then
    the joint (tau, chunks) sweep at batch 12.

    Structural keys (host-independent; check_bench guards them on the
    committed ``BENCH_autotune.json``):

    * ``probes_within_budget`` — probe count bounded by the budget,
    * ``chosen_dominates_model`` — the chosen point beats every probed
      neighbor under the calibrated roofline model (per-sample round
      time; the calibration scale cannot flip an argmin),
    * ``backoff_exercised`` — the injected-OOM path really ran
      (``failures`` non-empty),
    * the plan's probe ladder itself (batches/taus/chunks/ok flags).

    Measured ``us_round`` per probe and ``residual_scale`` are
    host-relative timing fields."""
    from repro.train.autotune import (
        TuneSpace, autotune, inject_oom_above, make_round_probe_runner,
    )
    from repro.launch import roofline as rf
    data = default_data()
    M = 4
    width = 32 if smoke else 128
    reps = 2 if smoke else 10
    LIMIT = 12                       # injected feasibility frontier
    dcfg = DPPFConfig(alpha=0.1, lam=0.5, tau=4, engine="flat",
                      overlap="doublebuf", overlap_chunks=1)
    opt = make_optimizer("sgd")
    init = lambda k: mlp_init(k, data["dim"], data["n_classes"],
                              width=width)

    def batch_fn(cand):
        return {"x": jnp.zeros((cand.tau, M, cand.batch, data["dim"])),
                "y": jnp.zeros((cand.tau, M, cand.batch), jnp.int32)}

    runner = inject_oom_above(
        make_round_probe_runner(init, mlp_loss, opt, dcfg, M, batch_fn,
                                reps=reps), LIMIT)
    n = init_train_state(init, opt, dcfg, M,
                         jax.random.PRNGKey(0)).engine.layout.n

    def model_fn(cand):
        # the same accounting as bench_overlap_round: MLP fwd+bwd ~ 3x
        # fwd flops per local step, worker-row gather + (R, R) psum
        dims = [data["dim"], width, width, data["n_classes"]]
        fwd = 2 * cand.batch * sum(a * b for a, b in zip(dims, dims[1:]))
        return rf.probe_round_model(
            work_s_per_step=3 * fwd * M / rf.PEAK_FLOPS, tau=cand.tau,
            gather_bytes=M * n * 4 + M * M * 4, R=M,
            mode="doublebuf") * 1e6

    space = TuneSpace(min_batch=2, max_batch=32, taus=(2, 4),
                      chunks=(1, 2), probe_budget=16, overlap="doublebuf")
    plan = autotune(runner, model_fn, space)
    out = {
        "workers": M, "width": width, "oom_limit": LIMIT,
        "space": {"min_batch": space.min_batch,
                  "max_batch": space.max_batch, "taus": list(space.taus),
                  "chunks": list(space.chunks),
                  "probe_budget": space.probe_budget,
                  "overlap": space.overlap},
        "plan": plan.to_dict(),
        "probes_within_budget": plan.probes_used <= space.probe_budget,
        "chosen_dominates_model": plan.dominates_model,
        "backoff_exercised": bool(plan.failures),
        "dominates_measured": plan.dominates_measured,
    }
    csv("microbench", op="autotune",
        chosen=f"batch{plan.chosen.batch}_tau{plan.chosen.tau}"
               f"_ch{plan.chosen.overlap_chunks}",
        probes_used=plan.probes_used,
        oom_batches="/".join(str(b) for b in plan.failures),
        probes_within_budget=out["probes_within_budget"],
        chosen_dominates_model=out["chosen_dominates_model"],
        backoff_exercised=out["backoff_exercised"],
        note="probe search on the real round step under an injected "
             "RESOURCE_EXHAUSTED frontier (batch > 12 fails); chosen "
             "point beats every probed neighbor under the calibrated "
             "roofline model")
    return out


def bench_roundclock(*, smoke=False):
    """QSR RoundClock vs fixed tau: communication rounds (= consensus
    all-reduces) saved at the same step budget, and the wall cost of the
    re-chunked adaptive loop (incl. its extra per-tau compiles)."""
    data = default_data()
    opt = make_optimizer("sgd")
    M, bs = 4, 16 if smoke else 64
    steps = 64 if smoke else 512
    lr, beta = 0.3, 0.4
    batch = lambda tau: {"x": jnp.zeros((tau, M, bs, data["dim"])),
                         "y": jnp.zeros((tau, M, bs), jnp.int32)}
    init = lambda k: mlp_init(k, data["dim"], data["n_classes"])
    out = {}
    for sched, qb in (("fixed", 0.0), ("qsr", beta)):
        dcfg = DPPFConfig(alpha=0.1, lam=0.5, tau=4, engine="flat",
                          tau_schedule=sched, qsr_beta=qb)
        clock = RoundClock.from_config(dcfg, base_lr=lr, total_steps=steps)
        st = init_train_state(init, opt, dcfg, M, jax.random.PRNGKey(0))
        fn = jax.jit(make_round_step(mlp_loss, opt, dcfg, clock=clock),
                     donate_argnums=0)
        t0 = time.perf_counter()
        for spec in clock.rounds:
            st, _ = fn(st, batch(spec.tau))
        jax.block_until_ready(st.params)
        wall = time.perf_counter() - t0
        out[sched] = dict(clock.describe(), wall_s=round(wall, 3))
        csv("microbench", op=f"roundclock_{sched}",
            rounds=clock.total_rounds, allreduces=clock.total_rounds,
            tau_min=min(clock.taus()), tau_max=max(clock.taus()),
            wall_s=round(wall, 3))
    saved = out["fixed"]["rounds"] - out["qsr"]["rounds"]
    csv("microbench", op="roundclock",
        allreduces_saved=saved,
        saved_pct=round(100.0 * saved / out["fixed"]["rounds"], 1),
        note="QSR adaptive tau vs fixed tau at the same step budget "
             "(one consensus all-reduce per round)")
    out["allreduces_saved"] = saved
    out["allreduces_saved_pct"] = round(
        100.0 * saved / out["fixed"]["rounds"], 1)
    return out


def run(*, smoke=False):
    engine_row = bench_engine_vs_tree(smoke=smoke)
    bench_pullpush(smoke=smoke)
    bench_round_vs_ddp(smoke=smoke)
    bench_sharded_round(smoke=smoke)
    hier_row = bench_hierarchical_round(smoke=smoke)
    overlap_row = bench_overlap_round(smoke=smoke)
    ring_row = bench_ring_round(smoke=smoke)
    zoo_row = bench_method_zoo(smoke=smoke)
    autotune_row = bench_autotune(smoke=smoke)
    roundclock = bench_roundclock(smoke=smoke)
    # machine-readable perf trajectory across PRs (repo root)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    payload = {"smoke": smoke, "backend": jax.default_backend(),
               "roundclock": roundclock, "engine_vs_tree": engine_row,
               "hierarchical_round": hier_row}
    path = os.path.join(root, "BENCH_roundclock.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {path}")
    # the overlap acceptance baseline rides in its own file so its
    # structural gate (mode set, mesh, chunk counts) can evolve without
    # churning the round-clock baseline (benchmarks/check_bench.py checks
    # both in CI)
    opath = os.path.join(root, "BENCH_overlap.json")
    with open(opath, "w") as f:
        json.dump({"smoke": smoke, "backend": jax.default_backend(),
                   "overlap_round": overlap_row,
                   "ring_gather": ring_row,
                   "method_zoo": zoo_row}, f, indent=2,
                  sort_keys=True)
        f.write("\n")
    print(f"wrote {opath}")
    # the autotune acceptance baseline: the searched TunePlan (probe
    # ladder, injected-OOM failures, chosen point) plus the structural
    # gates check_bench pins (probe budget, model dominance, backoff)
    apath = os.path.join(root, "BENCH_autotune.json")
    with open(apath, "w") as f:
        json.dump({"smoke": smoke, "backend": jax.default_backend(),
                   "autotune": autotune_row}, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {apath}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes, few iterations (CI)")
    args = ap.parse_args()
    run(smoke=args.smoke)
