"""Paper Table 3: soft-consensus optimizers (SimpleAvg/EASGD/LSGD/MGRAWA)
with and without the DPPF push mechanism. Reproduces Remark 1: DPPF_LSGD
with push-from-average does not converge; push-from-leader does."""
from __future__ import annotations

import numpy as np

from benchmarks.common import csv, default_data, run_distributed
from repro.configs import DPPFConfig

SEEDS = (182, 437)


def run(steps=400, M=4):
    data = default_data()
    out = {}
    for method in ("simple_avg", "easgd", "lsgd", "mgrawa"):
        for push in (False, True):
            errs = []
            for s in SEEDS:
                d = DPPFConfig(consensus=method, alpha=0.1,
                               lam=0.5 if push else 0.0, tau=4, push=push)
                r = run_distributed(data, d, M=M, steps=steps, seed=s)
                errs.append(r.test_err)
            name = ("DPPF_" if push else "") + method
            out[name] = (float(np.mean(errs)), float(np.std(errs)))
            csv("table3", method=name, test_err=round(out[name][0], 2),
                std=round(out[name][1], 2))
    wins = sum(out[f"DPPF_{m}"][0] <= out[m][0] + 0.3
               for m in ("simple_avg", "easgd", "mgrawa"))
    csv("table3_summary", push_wins_of_3=wins)
    return out


if __name__ == "__main__":
    run()
