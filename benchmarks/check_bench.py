"""Compare freshly generated bench JSONs (``BENCH_roundclock.json``,
``BENCH_overlap.json``, ``BENCH_serving.json``, ``BENCH_autotune.json``,
``BENCH_chaos.json``) against their committed baselines (ROADMAP
bench-tracking item).

Two classes of fields:

* **structural** — round counts, taus, the full round plan, all-reduce
  savings: pure functions of the clock config, identical on every host.
  Any mismatch is a real behavior change and FAILS the check (commit the
  regenerated file if the change is intended).
* **timing** — ``wall_s``/``us_*``/``speedup`` numbers: host-relative, so
  they are REPORTED as deltas (and surfaced in the CI job summary via
  ``$GITHUB_STEP_SUMMARY``) but never fail the check.

The overlap baseline's ring fields (``ring_bytes_per_hop``,
``gather_bytes``, ``ring_hops``, ``ring_ok``, ``ring_matches_gather``,
``modeled_order_ok``) are structural — deterministic arithmetic and
bit-parity booleans pinning ``ring_bytes_per_hop <= gather_bytes`` and the
``staleness_k >= doublebuf >= staleness1 >= exact`` modeled-throughput
ordering; ``us_ring``/``us_gather``/``speedup_staleness_k`` ride the
timing prefixes.

The autotune baseline (``BENCH_autotune.json``) pins the searched
TunePlan's STRUCTURAL surface: the probe ladder (batches/taus/chunks/ok
flags under an injected RESOURCE_EXHAUSTED frontier), the chosen point,
``probes_within_budget``, ``chosen_dominates_model`` (selection goes
through the calibrated roofline model — a host-independent argmin), and
``backoff_exercised``. Per-probe ``us_round`` measurements,
``residual_scale`` (the measured/modeled calibration), its
``max_abs_log_residual``, and ``dominates_measured`` are host-relative
timing fields.

The chaos baseline (``BENCH_chaos.json``) pins the fault-tolerant
supervisor's STRUCTURAL surface: the committed ChaosPlan, the recovery
counters and the full pinned ``event_seq`` (every suspect/evict/rejoin/
degrade/oom/shrink/restore/retry in emission order — replays are
bit-identical by contract), ``final_batch``, the determinism/parity
gates (``replay_identical``, ``empty_plan_parity``, ``schedule_parity``,
``completed``), the deterministic ``backoff_recorded_s`` (sha256 jitter,
never slept), and the ``modeled`` degraded-round roofline block; only
``wall_s`` rides the timing keys.

The ``method_zoo`` key (also in ``BENCH_overlap.json``) is registry
driven: its ``method_names`` list and per-method dict KEYS are structural
— registering/renaming a consensus method in ``core/methods.py`` must
regenerate the committed baseline — while each method's ``us_per_round``
rides the ``us_`` timing prefix automatically (no per-method allowlist
here).

CI usage (the microbench smoke step overwrites the repo-root files, so the
baselines are stashed first). ``--baseline``/``--fresh`` repeat and are
zipped into pairs:

    cp BENCH_roundclock.json /tmp/bench_baseline.json
    cp BENCH_overlap.json /tmp/bench_overlap_baseline.json
    PYTHONPATH=src:. XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python benchmarks/microbench.py --smoke
    python benchmarks/check_bench.py \
        --baseline /tmp/bench_baseline.json \
        --baseline /tmp/bench_overlap_baseline.json \
        --fresh BENCH_roundclock.json --fresh BENCH_overlap.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys

TIMING_KEYS = ("wall_s", "speedup", "flat_vs_hier",
               # serving bench (BENCH_serving.json): throughput/latency are
               # host-relative; steps/occupancy stay structural
               "tok_s", "ttft_ms", "compile_s",
               # autotune bench (BENCH_autotune.json): the measured/modeled
               # calibration and measured-time dominance are host-relative;
               # the probe ladder, chosen point, and model-dominance gate
               # stay structural (per-probe us_round rides the us_ prefix)
               "residual_scale", "max_abs_log_residual",
               "dominates_measured")
TIMING_PREFIXES = ("us_", "speedup_")
# environment fields: allowed to differ, reported only
INFO_KEYS = ("backend",)


def _is_timing(key: str) -> bool:
    return key in TIMING_KEYS or any(key.startswith(p)
                                     for p in TIMING_PREFIXES)


def _walk(base, fresh, path, *, errors, timing, info):
    if isinstance(base, dict) and isinstance(fresh, dict):
        for k in sorted(set(base) | set(fresh)):
            p = f"{path}.{k}" if path else k
            if k not in base:
                errors.append(f"{p}: new field (regenerate the committed "
                              f"baseline): {fresh[k]!r}")
            elif k not in fresh:
                errors.append(f"{p}: missing from fresh run (was "
                              f"{base[k]!r})")
            elif _is_timing(k):
                timing.append((p, base[k], fresh[k]))
            elif k in INFO_KEYS:
                if base[k] != fresh[k]:
                    info.append((p, base[k], fresh[k]))
            else:
                _walk(base[k], fresh[k], p, errors=errors, timing=timing,
                      info=info)
        return
    if isinstance(base, list) and isinstance(fresh, list):
        if len(base) != len(fresh):
            errors.append(f"{path}: length {len(base)} -> {len(fresh)}")
            return
        for i, (b, f) in enumerate(zip(base, fresh)):
            _walk(b, f, f"{path}[{i}]", errors=errors, timing=timing,
                  info=info)
        return
    if isinstance(base, float) or isinstance(fresh, float):
        # floats in structural fields (lam/lr plan columns) are rounded to
        # 6 digits at the source; the 1.5e-6 threshold gives the last
        # digit's jitter headroom over IEEE representation error (a strict
        # 1e-6 would flag abs(0.005463 - 0.005462) ~ 1.0000000000001e-06)
        try:
            if abs(float(base) - float(fresh)) > 1.5e-6:
                errors.append(f"{path}: {base} -> {fresh}")
        except (TypeError, ValueError):
            errors.append(f"{path}: {base!r} -> {fresh!r}")
        return
    if base != fresh:
        errors.append(f"{path}: {base!r} -> {fresh!r}")


def compare(base: dict, fresh: dict):
    errors, timing, info = [], [], []
    _walk(base, fresh, "", errors=errors, timing=timing, info=info)
    return errors, timing, info


def render_summary(errors, timing, info, *, name="BENCH_roundclock.json") -> str:
    lines = [f"## {name} vs committed baseline", ""]
    if errors:
        lines += ["**STRUCTURAL DRIFT (check failed)** — regenerate and "
                  "commit the baseline if intended:", ""]
        lines += [f"- `{e}`" for e in errors]
        lines.append("")
    else:
        lines.append("Structural fields match the committed baseline.")
        lines.append("")
    if timing:
        lines += ["| timing field | baseline | this run | delta |",
                  "|---|---|---|---|"]
        for p, b, f in timing:
            try:
                d = f"{(float(f) - float(b)) / max(abs(float(b)), 1e-12):+.0%}"
            except (TypeError, ValueError):
                d = "n/a"
            lines.append(f"| `{p}` | {b} | {f} | {d} |")
        lines.append("")
    for p, b, f in info:
        lines.append(f"- `{p}`: {b!r} (baseline) vs {f!r} (this run)")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True, action="append",
                    help="a committed bench baseline (stash it before the "
                         "microbench run overwrites it); repeatable — "
                         "pairs up with --fresh positionally")
    ap.add_argument("--fresh", action="append",
                    help="the freshly generated file for the matching "
                         "--baseline (default: BENCH_roundclock.json for "
                         "a single pair)")
    args = ap.parse_args(argv)
    fresh_paths = args.fresh or ["BENCH_roundclock.json"]
    if len(fresh_paths) != len(args.baseline):
        ap.error("--baseline and --fresh must pair up")
    failed = False
    for base_path, fresh_path in zip(args.baseline, fresh_paths):
        with open(base_path) as f:
            base = json.load(f)
        with open(fresh_path) as f:
            fresh = json.load(f)
        errors, timing, info = compare(base, fresh)
        summary = render_summary(errors, timing, info,
                                 name=os.path.basename(fresh_path))
        print(summary)
        step_summary = os.environ.get("GITHUB_STEP_SUMMARY")
        if step_summary:
            with open(step_summary, "a") as f:
                f.write(summary + "\n")
        failed = failed or bool(errors)
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
