"""Theorem 1 validation: the asymptotic valley width E||Delta+|| converges
to lambda/alpha, on (a) the exact proof recurrence and (b) real DNN training
with the DPPF trainer, across a (lambda, alpha, M) grid."""
from __future__ import annotations

import numpy as np

from benchmarks.common import csv, default_data, run_distributed
from repro.configs import DPPFConfig
from repro.core.theory import predicted_width, width_recurrence


def run(steps=600):
    # (a) exact recurrence from the proof (Eq. 16)
    for (alpha, lam, M) in [(0.1, 0.5, 4), (0.1, 0.5, 32), (0.5, 2.5, 8),
                            (0.2, 0.2, 8)]:
        traj = width_recurrence(alpha, lam, eta=0.01, tau=4, sigma0=1.0, M=M,
                                rounds=400)
        emp = float(traj[-50:].mean())
        pred = predicted_width(alpha, lam)
        csv("theorem1_recurrence", alpha=alpha, lam=lam, M=M,
            predicted=pred, empirical=round(emp, 3),
            rel_err=round(abs(emp - pred) / pred, 3))

    # (b) real training
    data = default_data()
    for (alpha, lam) in [(0.1, 0.5), (0.1, 1.0), (0.5, 2.5)]:
        r = run_distributed(
            data, DPPFConfig(alpha=alpha, lam=lam, tau=4,
                             lam_schedule="fixed"),
            M=8, steps=steps)
        pred = predicted_width(alpha, lam)
        csv("theorem1_training", alpha=alpha, lam=lam, predicted=pred,
            empirical=round(r.consensus_dist, 3),
            rel_err=round(abs(r.consensus_dist - pred) / pred, 3))


if __name__ == "__main__":
    run()
