"""Fault-tolerance microbench: the round supervisor under a scripted
ChaosPlan. Writes ``BENCH_chaos.json`` at the repo root (committed;
``benchmarks/check_bench.py`` guards it in CI like the other benches).

Field classes follow check_bench's contract:

* **structural** — the plan itself, the recovery counters, the pinned
  ``event_seq``, ``final_batch``, and the three determinism/parity bools:
  ``replay_identical`` (the SAME plan run twice from a fresh init walks a
  bit-identical event sequence AND lands on bit-identical params),
  ``empty_plan_parity`` (with no membership and no chaos the supervisor
  loop is bit-for-bit the plain round loop it replaced), and
  ``schedule_parity`` (ScheduleMembership — the ``--elastic-drop`` path —
  matches the old inline set_participation loop bit-for-bit). Also the
  ``modeled`` block: ``roofline.supervisor_model`` degraded-round
  accounting, pure arithmetic.
* **timing** — ``wall_s``: host-relative, reported as a delta only.

The run is a small elastic staleness-k MLP fleet (no transformer — the
supervisor policy is host-side and model-agnostic), with every fault
class exercised: a kill window long enough to evict + rejoin, a quorum
degrade, an injected RESOURCE_EXHAUSTED (batch shrink + replay), and a
corrupt checkpoint (restore-ladder fallback to the rotation copy).

  PYTHONPATH=src:. python benchmarks/bench_chaos.py --smoke
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import mlp_init, mlp_loss
from repro.configs import DPPFConfig
from repro.launch.roofline import supervisor_model
from repro.optim import make_optimizer
from repro.train import (
    ChaosEvent, ChaosPlan, ChaosMembership, FaultInjector, RoundClock,
    ScheduleMembership, Supervisor, init_train_state, make_round_step,
    set_participation,
)

M, TAU, K, STEPS = 4, 2, 2, 16
DIM, NCLS, WIDTH, BATCH = 16, 4, 8, 8
QUORUM = 4
SEED = 0

# the committed fault script: one of everything (see module docstring)
PLAN = ChaosPlan(events=(
    ChaosEvent(round=2, kind="kill", worker=2, duration=2),
    ChaosEvent(round=4, kind="corrupt_ckpt"),
    ChaosEvent(round=5, kind="oom", batch_above=4),
), seed=7)


def _setup():
    dcfg = DPPFConfig(engine="flat", overlap="staleness_k", staleness=K,
                      elastic=True, tau=TAU)
    clock = RoundClock.from_config(dcfg, base_lr=0.1, total_steps=STEPS)
    opt = make_optimizer("sgd", momentum=0.9)
    p0 = lambda k: mlp_init(k, DIM, NCLS, WIDTH)
    step = jax.jit(make_round_step(mlp_loss, opt, dcfg, clock=clock),
                   donate_argnums=0)
    state = init_train_state(p0, opt, dcfg, M, jax.random.PRNGKey(SEED))
    return dcfg, clock, step, state


def _batch_fn(spec, bs):
    k = jax.random.fold_in(jax.random.PRNGKey(SEED + 1), spec.index)
    return {"x": jax.random.normal(k, (spec.tau, M, bs, DIM)),
            "y": jax.random.randint(jax.random.fold_in(k, 1),
                                    (spec.tau, M, bs), 0, NCLS)}


def _params(state):
    return np.asarray(jax.device_get(state.params))


def chaos_run(workdir):
    """One full supervised run under PLAN; returns (summary, params,
    restore_bytes, backoff_total)."""
    _, clock, step, state = _setup()
    sup = Supervisor(
        clock, workers=M,
        membership=ChaosMembership(PLAN, M, timeout=0.9),
        quorum=QUORUM, chaos=FaultInjector(PLAN), ckpt_dir=workdir,
        batch_size=BATCH, seed=PLAN.seed)
    state = sup.run(state, step, _batch_fn)
    rb = os.path.getsize(os.path.join(workdir, "sup_last.npz"))
    backoff = sum(e.get("backoff_s", 0.0) for e in sup.events)
    return sup.summary(), _params(state), rb, backoff


def manual_run(drop=None):
    """The pre-supervisor inline loop (bit-parity reference)."""
    _, clock, step, state = _setup()
    for spec in clock.rounds:
        if drop is not None:
            w, a, b = drop
            mask = jnp.ones((M,), jnp.float32)
            if a <= spec.index < b:
                mask = mask.at[w].set(0.0)
            state = set_participation(state, mask)
        state, _ = step(state, _batch_fn(spec, BATCH))
    return _params(state)


def supervised_run(membership=None):
    """Supervisor with no chaos and no checkpointing (the parity legs)."""
    _, clock, step, state = _setup()
    sup = Supervisor(clock, workers=M, membership=membership,
                     batch_size=BATCH)
    return _params(sup.run(state, step, _batch_fn))


def bench_chaos():
    t0 = time.perf_counter()
    with tempfile.TemporaryDirectory() as d1, \
            tempfile.TemporaryDirectory() as d2:
        s1, p1, restore_bytes, backoff = chaos_run(d1)
        s2, p2, _, _ = chaos_run(d2)
    replay_identical = (s1["event_seq"] == s2["event_seq"]
                        and np.array_equal(p1, p2))

    empty_plan_parity = np.array_equal(manual_run(), supervised_run())
    drop = (2, 1, 3)
    schedule_parity = np.array_equal(
        manual_run(drop=drop),
        supervised_run(membership=ScheduleMembership(M, [drop])))

    c = s1["counters"]
    modeled = supervisor_model(
        rounds=len(RoundClock.from_config(
            DPPFConfig(engine="flat", overlap="staleness_k", staleness=K,
                       elastic=True, tau=TAU),
            base_lr=0.1, total_steps=STEPS).rounds),
        tau=TAU, work_s_per_step=2e-3, gather_bytes=1e6, R=M, staleness=K,
        degraded_rounds=c.get("degrade", 0),
        retried_rounds=c.get("retry", 0),
        restores=c.get("restore", 0), restore_bytes=float(restore_bytes),
        # the bench runs on virtual time (no sleep_fn) — the recorded
        # backoff seconds are reported separately, not priced as wall
        backoff_s=0.0)
    return {
        "workers": M, "tau": TAU, "staleness": K, "rounds": STEPS // TAU,
        "quorum": QUORUM, "batch": BATCH,
        "plan": PLAN.to_dict(),
        "counters": c,
        "event_seq": s1["event_seq"],
        "final_batch": s1["final_batch"],
        "completed": True,
        "replay_identical": bool(replay_identical),
        "empty_plan_parity": bool(empty_plan_parity),
        "schedule_parity": bool(schedule_parity),
        "restore_bytes": int(restore_bytes),
        "backoff_recorded_s": round(backoff, 3),
        "modeled": modeled,
        "wall_s": round(time.perf_counter() - t0, 2),
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default="BENCH_chaos.json")
    args = ap.parse_args(argv)
    result = {
        "backend": jax.default_backend(),
        "smoke": True,  # the plan is fixed; flag kept for CLI symmetry
        "chaos": bench_chaos(),
    }
    with open(args.out, "w") as f:
        json.dump(result, f, indent=1, sort_keys=True)
        f.write("\n")
    c = result["chaos"]
    print(f"events: {' '.join(c['event_seq'])}")
    print(f"replay_identical={c['replay_identical']} "
          f"empty_plan_parity={c['empty_plan_parity']} "
          f"schedule_parity={c['schedule_parity']} "
          f"final_batch={c['final_batch']} "
          f"overhead {c['modeled']['overhead_frac']:.3f}")
    print(f"wrote {args.out}")
    return result


if __name__ == "__main__":
    main()
